//! Acceptance tests for the issue's headline criteria:
//!
//! * a seeded violation of each rule L1–L4 makes the pass fail
//!   (non-empty findings ⇒ the CLI exits non-zero),
//! * the real repo tree lints clean,
//! * the extracted wire-constant tables match the agreed snapshot.

use std::fs;
use std::path::{Path, PathBuf};

use stormlint::{lint_tree, mirror, rules};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../..")
}

/// Build a throwaway tree seeded with one violation per rule.
fn write_seeded_tree(root: &Path) {
    let w = |rel: &str, body: &str| {
        let path = root.join(rel);
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(path, body).unwrap();
    };

    // L1: unsafe outside simd.rs, and unsafe in simd.rs without SAFETY.
    w(
        "rust/src/sketch/race.rs",
        "pub fn f(p: *const u8) -> u8 { unsafe { *p } }\n",
    );
    w(
        "rust/src/lsh/simd.rs",
        "pub unsafe fn kernel(x: *const f32) -> f32 { unsafe { *x } }\n",
    );

    // L2: randomized hasher, wall clock, raw spawn, FMA.
    w(
        "rust/src/lsh/query.rs",
        "use std::collections::HashMap;\npub fn t() { let _ = std::time::Instant::now(); }\n\
         pub fn s() { std::thread::spawn(|| {}); }\npub fn m(a: f64) -> f64 { a.mul_add(2.0, 1.0) }\n",
    );

    // L3: indexing, unwrap and unchecked arithmetic in a decode path,
    // plus a drifted constant table for L4.
    w(
        "rust/src/sketch/serialize.rs",
        "const MAGIC: u32 = 0x53544F51;\n\
         pub fn decode(bytes: &[u8]) -> u32 {\n\
             let n = bytes.len() + 4;\n\
             let _ = bytes.get(0).unwrap();\n\
             (bytes[0] as u32) + (n as u32)\n\
         }\n",
    );

    // L4 python side: present but drifted too.
    w("python/tests/wire_mirror.py", "MAGIC = 0x53544F50\n");
}

#[test]
fn seeded_violations_trip_every_rule() {
    let dir = std::env::temp_dir().join(format!("stormlint-seeded-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    write_seeded_tree(&dir);

    let findings = lint_tree(&dir);
    let hit = |rule: &str| findings.iter().any(|f| f.rule == rule);

    assert!(hit(rules::RULE_UNSAFE_OUTSIDE_SIMD), "L1 containment: {findings:#?}");
    assert!(hit(rules::RULE_MISSING_SAFETY_COMMENT), "L1 SAFETY: {findings:#?}");
    assert!(hit(rules::RULE_RANDOMIZED_HASHER), "L2 hasher: {findings:#?}");
    assert!(hit(rules::RULE_WALL_CLOCK), "L2 clock: {findings:#?}");
    assert!(hit(rules::RULE_RAW_THREAD_SPAWN), "L2 spawn: {findings:#?}");
    assert!(hit(rules::RULE_FMA_CONTRACTION), "L2 fma: {findings:#?}");
    assert!(hit(rules::RULE_WIRE_PANIC), "L3 panic: {findings:#?}");
    assert!(hit(rules::RULE_WIRE_INDEX), "L3 index: {findings:#?}");
    assert!(hit(rules::RULE_WIRE_ARITH), "L3 arith: {findings:#?}");
    assert!(hit(rules::RULE_WIRE_MIRROR_DRIFT), "L4 drift: {findings:#?}");

    // Non-empty findings are exactly what makes the CLI exit non-zero.
    assert!(!findings.is_empty());

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn repo_tree_is_clean() {
    let findings = lint_tree(&repo_root());
    assert!(
        findings.is_empty(),
        "the repo tree must lint clean; found:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn wire_constant_tables_match_the_snapshot() {
    let rust_src = fs::read_to_string(repo_root().join(mirror::RUST_WIRE_PATH))
        .expect("rust wire codec readable");
    let py_src = fs::read_to_string(repo_root().join(mirror::PY_MIRROR_PATH))
        .expect("python wire mirror readable");

    let rust = mirror::extract_rust_constants(&rust_src);
    let py = mirror::extract_python_constants(&py_src);

    for &(name, want) in mirror::EXPECTED {
        assert_eq!(
            rust.get(name).map(|v| v.0),
            Some(want),
            "rust constant {name} drifted from the agreed table"
        );
        assert_eq!(
            py.get(name).map(|v| v.0),
            Some(want),
            "python constant {name} drifted from the agreed table"
        );
    }
}
