//! A deliberately small lexical view of a Rust source file.
//!
//! stormlint's rules are token-level, not AST-level, so all the lexer
//! has to get right is *what is code and what is not*: comments
//! (line, nested block, doc), string literals (plain, raw, byte), and
//! char literals are blanked out of the "code" view and comment text is
//! kept per line (the `// SAFETY:` and `stormlint::allow(...)` checks
//! read it). On top of the blanked text it resolves three kinds of
//! regions by brace matching:
//!
//! * `#[cfg(test)] mod` bodies (skipped by the determinism and wire
//!   rules — tests may index, unwrap and sleep as they like),
//! * `fn` bodies with their names,
//! * `impl` blocks with their header text.
//!
//! Line numbers are 1-based everywhere, matching compiler diagnostics.

/// One source line, split into its code and comment parts.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// The line with comment text, string contents and char literals
    /// replaced by spaces. Quote characters themselves are kept.
    pub code: String,
    /// Concatenated comment text on this line (without the `//` / `/*`
    /// markers), both standalone and trailing comments.
    pub comment: String,
}

/// A function body region: `[body_start, body_end]` line range of the
/// braces, plus the line the `fn` keyword sits on.
#[derive(Debug, Clone)]
pub struct FnRegion {
    pub name: String,
    pub fn_line: usize,
    pub body_start: usize,
    pub body_end: usize,
}

/// An `impl` block region with its full header text (everything between
/// the `impl` keyword and the opening brace, whitespace-normalized).
#[derive(Debug, Clone)]
pub struct ImplRegion {
    pub header: String,
    pub body_start: usize,
    pub body_end: usize,
}

/// The lexed file: per-line code/comment views plus resolved regions.
#[derive(Debug, Default)]
pub struct FileView {
    pub lines: Vec<Line>,
    /// Line ranges (inclusive) of `#[cfg(test)] mod` bodies.
    pub test_regions: Vec<(usize, usize)>,
    pub fns: Vec<FnRegion>,
    pub impls: Vec<ImplRegion>,
}

impl FileView {
    pub fn parse(source: &str) -> FileView {
        let lines = blank(source);
        let code: String = lines
            .iter()
            .map(|l| l.code.as_str())
            .collect::<Vec<_>>()
            .join("\n");
        let test_regions = find_test_regions(&code);
        let fns = find_fns(&code);
        let impls = find_impls(&code);
        FileView { lines, test_regions, fns, impls }
    }

    /// Is `line` (1-based) inside a `#[cfg(test)] mod` body?
    pub fn in_test_region(&self, line: usize) -> bool {
        self.test_regions.iter().any(|&(s, e)| line >= s && line <= e)
    }
}

/// Blank comments, strings and char literals out of `source`,
/// collecting comment text per line.
fn blank(source: &str) -> Vec<Line> {
    let mut lines: Vec<Line> = vec![Line::default()];
    let mut code = String::new();
    let mut comment = String::new();
    let push_line = |lines: &mut Vec<Line>, code: &mut String, comment: &mut String| {
        let n = lines.len();
        lines[n - 1] = Line { code: std::mem::take(code), comment: std::mem::take(comment) };
        lines.push(Line::default());
    };

    let b = source.as_bytes();
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                push_line(&mut lines, &mut code, &mut comment);
                i += 1;
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                // Line comment (incl. /// and //!): comment text until EOL.
                code.push(' ');
                code.push(' ');
                i += 2;
                while i < b.len() && b[i] != b'\n' {
                    comment.push(b[i] as char);
                    i += 1;
                }
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                // Block comment; Rust block comments nest.
                let mut depth = 1usize;
                code.push(' ');
                code.push(' ');
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        push_line(&mut lines, &mut code, &mut comment);
                        i += 1;
                    } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        comment.push_str("/*");
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        if depth > 0 {
                            comment.push_str("*/");
                        }
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                    } else {
                        comment.push(b[i] as char);
                        code.push(' ');
                        i += 1;
                    }
                }
            }
            b'"' => {
                // Plain (or byte) string literal: blank the contents.
                code.push('"');
                i += 1;
                while i < b.len() {
                    match b[i] {
                        b'\\' if i + 1 < b.len() => {
                            code.push(' ');
                            code.push(' ');
                            i += 2;
                        }
                        b'"' => {
                            code.push('"');
                            i += 1;
                            break;
                        }
                        b'\n' => {
                            push_line(&mut lines, &mut code, &mut comment);
                            i += 1;
                        }
                        _ => {
                            code.push(' ');
                            i += 1;
                        }
                    }
                }
            }
            b'r' if is_raw_string_start(b, i) => {
                // Raw string r"..." / r#"..."# (any hash count).
                code.push(' ');
                i += 1;
                let mut hashes = 0usize;
                while i < b.len() && b[i] == b'#' {
                    hashes += 1;
                    code.push(' ');
                    i += 1;
                }
                code.push('"');
                i += 1; // opening quote
                'raw: while i < b.len() {
                    if b[i] == b'\n' {
                        push_line(&mut lines, &mut code, &mut comment);
                        i += 1;
                        continue;
                    }
                    if b[i] == b'"' {
                        let mut ok = true;
                        for k in 0..hashes {
                            if i + 1 + k >= b.len() || b[i + 1 + k] != b'#' {
                                ok = false;
                                break;
                            }
                        }
                        if ok {
                            code.push('"');
                            for _ in 0..hashes {
                                code.push(' ');
                            }
                            i += 1 + hashes;
                            break 'raw;
                        }
                    }
                    code.push(' ');
                    i += 1;
                }
            }
            b'\'' if is_char_literal(b, i) => {
                // Char literal (not a lifetime): blank the contents.
                code.push('\'');
                i += 1;
                if i < b.len() && b[i] == b'\\' {
                    code.push(' ');
                    i += 1;
                }
                while i < b.len() && b[i] != b'\'' && b[i] != b'\n' {
                    code.push(' ');
                    i += 1;
                }
                if i < b.len() && b[i] == b'\'' {
                    code.push('\'');
                    i += 1;
                }
            }
            _ => {
                code.push(c as char);
                i += 1;
            }
        }
    }
    let n = lines.len();
    lines[n - 1] = Line { code, comment };
    lines
}

fn is_raw_string_start(b: &[u8], i: usize) -> bool {
    // `r"`, `r#"`, `r##"`, ... — and not part of a longer identifier.
    if i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_') {
        return false;
    }
    let mut j = i + 1;
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"'
}

fn is_char_literal(b: &[u8], i: usize) -> bool {
    // 'x' or '\n' (escape) — a lifetime like 'a has no closing quote
    // right after one payload char.
    if i + 2 < b.len() && b[i + 1] == b'\\' {
        return true;
    }
    i + 2 < b.len() && b[i + 2] == b'\'' && b[i + 1] != b'\''
}

/// Map a byte offset in the joined code string to a 1-based line.
fn line_of(code: &str, offset: usize) -> usize {
    code.as_bytes()[..offset].iter().filter(|&&c| c == b'\n').count() + 1
}

/// Find the matching close brace for the `{` at `open`, returning its
/// byte offset (the input is blanked, so braces in strings/comments are
/// already gone).
fn match_brace(code: &str, open: usize) -> Option<usize> {
    let b = code.as_bytes();
    debug_assert_eq!(b[open], b'{');
    let mut depth = 0usize;
    for (k, &c) in b.iter().enumerate().skip(open) {
        if c == b'{' {
            depth += 1;
        } else if c == b'}' {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// All word-bounded occurrences of `word` in `code`, as byte offsets.
pub fn word_offsets(code: &str, word: &str) -> Vec<usize> {
    let b = code.as_bytes();
    let w = word.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let before_ok = at == 0 || !is_ident_byte(b[at - 1]);
        let end = at + w.len();
        let after_ok = end >= b.len() || !is_ident_byte(b[end]);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + 1;
    }
    out
}

fn find_test_regions(code: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = code[from..].find("#[cfg(test)]") {
        let at = from + pos;
        from = at + 1;
        // The attribute must introduce a `mod` (possibly after more
        // attributes); find the next `mod` keyword, then its `{`.
        let tail = &code[at..];
        let Some(mod_rel) = word_offsets(tail, "mod").first().copied() else { continue };
        let Some(brace_rel) = tail[mod_rel..].find('{') else { continue };
        let open = at + mod_rel + brace_rel;
        if let Some(close) = match_brace(code, open) {
            out.push((line_of(code, open), line_of(code, close)));
        }
    }
    out
}

fn find_fns(code: &str) -> Vec<FnRegion> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    for at in word_offsets(code, "fn") {
        // Identifier after `fn`.
        let mut j = at + 2;
        while j < b.len() && (b[j] as char).is_whitespace() {
            j += 1;
        }
        let name_start = j;
        while j < b.len() && is_ident_byte(b[j]) {
            j += 1;
        }
        if j == name_start {
            continue;
        }
        let name = code[name_start..j].to_string();
        // Body: the next `{` before a `;` (a `;` first means a trait
        // method declaration without a body).
        let mut k = j;
        let mut open = None;
        while k < b.len() {
            match b[k] {
                b'{' => {
                    open = Some(k);
                    break;
                }
                b';' => break,
                _ => k += 1,
            }
        }
        let Some(open) = open else { continue };
        if let Some(close) = match_brace(code, open) {
            out.push(FnRegion {
                name,
                fn_line: line_of(code, at),
                body_start: line_of(code, open),
                body_end: line_of(code, close),
            });
        }
    }
    out
}

fn find_impls(code: &str) -> Vec<ImplRegion> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    for at in word_offsets(code, "impl") {
        let mut k = at;
        let mut open = None;
        while k < b.len() {
            match b[k] {
                b'{' => {
                    open = Some(k);
                    break;
                }
                b';' => break,
                _ => k += 1,
            }
        }
        let Some(open) = open else { continue };
        if let Some(close) = match_brace(code, open) {
            let header: String = code[at..open].split_whitespace().collect::<Vec<_>>().join(" ");
            out.push(ImplRegion {
                header,
                body_start: line_of(code, open),
                body_end: line_of(code, close),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "let x = \"unsafe HashMap\"; // unsafe comment\nlet c = 'u';\n";
        let v = FileView::parse(src);
        assert!(!v.lines[0].code.contains("unsafe"));
        assert!(!v.lines[0].code.contains("HashMap"));
        assert!(v.lines[0].comment.contains("unsafe comment"));
        assert!(!v.lines[1].code.contains('u'));
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let src = "/* a /* nested */ still comment */ fn f() {}\nlet r = r#\"raw \"q\" unsafe\"#;\n";
        let v = FileView::parse(src);
        assert!(v.lines[0].code.contains("fn f()"));
        assert!(v.lines[0].comment.contains("still comment"));
        assert!(!v.lines[1].code.contains("unsafe"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a [u8]) -> &'a [u8] { x }\n";
        let v = FileView::parse(src);
        assert!(v.lines[0].code.contains("&'a [u8]"));
        assert_eq!(v.fns.len(), 1);
        assert_eq!(v.fns[0].name, "f");
    }

    #[test]
    fn test_regions_and_fn_bodies_resolve() {
        let src = "\
fn outer() {
    inner();
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        assert!(true);
    }
}
";
        let v = FileView::parse(src);
        assert_eq!(v.test_regions.len(), 1);
        assert!(v.in_test_region(8));
        assert!(!v.in_test_region(2));
        let names: Vec<_> = v.fns.iter().map(|f| f.name.as_str()).collect();
        assert!(names.contains(&"outer") && names.contains(&"t"));
    }

    #[test]
    fn impl_headers_resolve() {
        let src = "struct W;\nimpl<'a> Wire<'a> for W {\n    fn go(&self) {}\n}\n";
        let v = FileView::parse(src);
        assert_eq!(v.impls.len(), 1);
        assert!(v.impls[0].header.contains("Wire"));
        assert_eq!(v.impls[0].body_start, 2);
        assert_eq!(v.impls[0].body_end, 4);
    }

    #[test]
    fn word_offsets_respect_boundaries() {
        let code = "unsafe unsafer do_unsafe unsafe";
        assert_eq!(word_offsets(code, "unsafe").len(), 2);
    }
}
