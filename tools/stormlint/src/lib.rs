//! stormlint — STORM's repo-specific static-analysis pass.
//!
//! The merge proofs (bit-identical folds, panic-free decode of
//! untrusted frames, audited `unsafe`) rest on coding rules the
//! compiler does not enforce. This crate enforces them:
//!
//! * **L1 unsafe containment** — `unsafe` only in `lsh/simd.rs`, and
//!   every site there carries a `// SAFETY:` comment
//!   (`unsafe-outside-simd`, `missing-safety-comment`).
//! * **L2 determinism** — no randomized-hasher `HashMap`/`HashSet`, no
//!   wall-clock reads outside `util/timer.rs`/benches, no raw
//!   `thread::spawn` outside the executor/fleet, no `mul_add` (FMA) in
//!   bit-identity-critical modules (`randomized-hasher`, `wall-clock`,
//!   `raw-thread-spawn`, `fma-contraction`).
//! * **L3 wire safety** — decode paths in `sketch/serialize.rs` must be
//!   panic-free: no indexing, no `unwrap`/`expect`, no unchecked
//!   arithmetic (`wire-panic`, `wire-index`, `wire-arith`).
//! * **L4 mirror drift** — the wire constant table in `serialize.rs`
//!   must match `python/tests/wire_mirror.py` and the snapshot in
//!   [`mirror::EXPECTED`] (`wire-mirror-drift`).
//!
//! Escape hatch: a comment containing `stormlint::allow(rule-name)` on
//! the offending line (trailing) or the line above suppresses that rule
//! there. See `tools/stormlint/README.md` for the catalog.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

pub mod lexer;
pub mod mirror;
pub mod rules;

/// One lint violation, printed as `file:line: error[rule]: message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    pub fn new(file: &str, line: usize, rule: &'static str, message: &str) -> Finding {
        Finding { file: file.to_string(), line, rule, message: message.to_string() }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: error[{}]: {}", self.file, self.line, self.rule, self.message)
    }
}

/// Lint one source file given its repo-relative path (the path selects
/// which rules and allowlists apply).
pub fn lint_source(rel_path: &str, source: &str) -> Vec<Finding> {
    let view = lexer::FileView::parse(source);
    rules::check_file(rel_path, &view)
}

/// The directories lint_tree walks, relative to the repo root. Test
/// *files* are still scanned — only `#[cfg(test)] mod` regions get the
/// relaxed determinism/wire rules, while L1 containment applies
/// everywhere.
const SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "tools/stormlint/src"];

/// Lint the whole repo tree rooted at `root`: every `.rs` file under
/// [`SCAN_DIRS`] plus the L4 mirror diff. Findings come back sorted by
/// path then line. I/O errors surface as findings too (rule
/// `wire-mirror-drift` for the two mirror files, since a missing mirror
/// *is* drift).
pub fn lint_tree(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();

    for dir in SCAN_DIRS {
        let mut files = Vec::new();
        collect_rs_files(&root.join(dir), &mut files);
        files.sort();
        for path in files {
            let rel = rel_path(root, &path);
            match fs::read_to_string(&path) {
                Ok(src) => findings.extend(lint_source(&rel, &src)),
                Err(e) => findings.push(Finding::new(
                    &rel,
                    1,
                    "io-error",
                    &format!("could not read file: {e}"),
                )),
            }
        }
    }

    // L4: both mirror files must exist and agree.
    let rust_wire = root.join(mirror::RUST_WIRE_PATH);
    let py_mirror = root.join(mirror::PY_MIRROR_PATH);
    match (fs::read_to_string(&rust_wire), fs::read_to_string(&py_mirror)) {
        (Ok(r), Ok(p)) => findings.extend(mirror::check_mirror(&r, &p)),
        (r, p) => {
            if let Err(e) = r {
                findings.push(Finding::new(
                    mirror::RUST_WIRE_PATH,
                    1,
                    rules::RULE_WIRE_MIRROR_DRIFT,
                    &format!("could not read the Rust wire codec: {e}"),
                ));
            }
            if let Err(e) = p {
                findings.push(Finding::new(
                    mirror::PY_MIRROR_PATH,
                    1,
                    rules::RULE_WIRE_MIRROR_DRIFT,
                    &format!("could not read the Python wire mirror: {e}"),
                ));
            }
        }
    }

    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    findings
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finding_renders_navigable() {
        let f = Finding::new("rust/src/lsh/query.rs", 48, rules::RULE_RANDOMIZED_HASHER, "msg");
        assert_eq!(
            f.to_string(),
            "rust/src/lsh/query.rs:48: error[randomized-hasher]: msg"
        );
    }
}
