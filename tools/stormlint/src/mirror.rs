//! L4: wire-constant mirror drift.
//!
//! The Python golden-fixture mirror (`python/tests/wire_mirror.py`)
//! re-implements the encoder so fixtures can be cross-checked outside
//! Rust. Its constant table must track `sketch/serialize.rs` exactly;
//! this module extracts both tables (evaluating the small const
//! expressions each side uses) and diffs them against each other *and*
//! against an embedded snapshot, so a change to either file without a
//! matching update to the other — or to the snapshot here — fails lint.

use std::collections::BTreeMap;

use crate::lexer::FileView;
use crate::rules::RULE_WIRE_MIRROR_DRIFT;
use crate::Finding;

pub const RUST_WIRE_PATH: &str = "rust/src/sketch/serialize.rs";
pub const PY_MIRROR_PATH: &str = "python/tests/wire_mirror.py";

/// The agreed wire-constant table. Extending the wire format means
/// updating serialize.rs, wire_mirror.py *and* this snapshot in one PR —
/// which is exactly the point.
pub const EXPECTED: &[(&str, u64)] = &[
    ("MAGIC", 0x53544F52),
    ("VERSION_DENSE", 1),
    ("VERSION_DELTA", 2),
    ("VERSION_WIDTH", 3),
    ("FLAG_DENSE", 0),
    ("FLAG_SPARSE", 1),
    ("FLAG_TASK_CLASSIFICATION", 2),
    ("FLAG_PRIVATE", 16),
    ("FAMILY_SHIFT", 2),
    ("FAMILY_MASK", 12),
    ("FAMILY_DENSE", 0),
    ("FAMILY_SPARSE", 1),
    ("FAMILY_HADAMARD", 2),
    ("HEADER", 32),
    ("HEADER_V2", 41),
    ("HEADER_V3", 42),
    ("MAX_CELLS", 67_108_864),
];

/// A constant with the 1-based line it was defined on.
pub type ConstTable = BTreeMap<String, (u64, usize)>;

/// Extract `const NAME: ty = expr;` items plus the `family_to_code`
/// match arms (as `FAMILY_<VARIANT>`) from Rust source.
pub fn extract_rust_constants(source: &str) -> ConstTable {
    let view = FileView::parse(source);
    let mut table = ConstTable::new();

    for (idx, l) in view.lines.iter().enumerate() {
        let line_no = idx + 1;
        let code = l.code.trim();
        let rest = code
            .strip_prefix("pub const ")
            .or_else(|| code.strip_prefix("const "));
        let Some(rest) = rest else { continue };
        let Some((name, tail)) = rest.split_once(':') else { continue };
        let Some((_ty, expr)) = tail.split_once('=') else { continue };
        let expr = expr.trim().trim_end_matches(';').trim();
        let env: BTreeMap<String, u64> =
            table.iter().map(|(k, &(v, _))| (k.clone(), v)).collect();
        if let Some(v) = eval_expr(expr, &env) {
            table.insert(name.trim().to_string(), (v, line_no));
        }
    }

    // family_to_code match arms: `HashFamily::Dense => 0,` etc.
    let mut in_family_fn = false;
    for (idx, l) in view.lines.iter().enumerate() {
        let code = l.code.as_str();
        if code.contains("fn family_to_code") {
            in_family_fn = true;
        }
        if !in_family_fn {
            continue;
        }
        if let Some(pos) = code.find("HashFamily::") {
            let after = &code[pos + "HashFamily::".len()..];
            let variant: String = after
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if let Some(arrow) = after.find("=>") {
                let value = after[arrow + 2..]
                    .trim()
                    .trim_end_matches(',')
                    .trim();
                if let Some(v) = eval_expr(value, &BTreeMap::new()) {
                    table.insert(format!("FAMILY_{}", variant.to_uppercase()), (v, idx + 1));
                }
            }
        }
        // The match fits in one fn; stop at its closing brace.
        if code.trim() == "}" && code.starts_with('}') {
            in_family_fn = false;
        }
    }

    table
}

/// Extract top-level `NAME = expr` assignments (ALL_CAPS names) from the
/// Python mirror.
pub fn extract_python_constants(source: &str) -> ConstTable {
    let mut table = ConstTable::new();
    for (idx, raw) in source.lines().enumerate() {
        // Top level only — the encoders indent their code.
        if raw.starts_with(|c: char| c.is_whitespace()) {
            continue;
        }
        let line = raw.split('#').next().unwrap_or("");
        let Some((name, expr)) = line.split_once('=') else { continue };
        let name = name.trim();
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
            || !name.starts_with(|c: char| c.is_ascii_uppercase())
        {
            continue;
        }
        // `==` comparisons are not assignments.
        if expr.starts_with('=') {
            continue;
        }
        let env: BTreeMap<String, u64> =
            table.iter().map(|(k, &(v, _))| (k.clone(), v)).collect();
        if let Some(v) = eval_expr(expr.trim(), &env) {
            table.insert(name.to_string(), (v, idx + 1));
        }
    }
    table
}

/// Diff both extracted tables against [`EXPECTED`].
pub fn check_mirror(rust_src: &str, py_src: &str) -> Vec<Finding> {
    let rust = extract_rust_constants(rust_src);
    let py = extract_python_constants(py_src);
    let mut out = Vec::new();

    for &(name, want) in EXPECTED {
        match rust.get(name) {
            None => out.push(Finding::new(
                RUST_WIRE_PATH,
                1,
                RULE_WIRE_MIRROR_DRIFT,
                &format!("wire constant {name} not found in the Rust codec"),
            )),
            Some(&(got, line)) if got != want => out.push(Finding::new(
                RUST_WIRE_PATH,
                line,
                RULE_WIRE_MIRROR_DRIFT,
                &format!(
                    "wire constant {name} = {got} in the Rust codec, but the agreed \
                     table says {want}; update wire_mirror.py and the stormlint \
                     snapshot together if the format really changed"
                ),
            )),
            Some(_) => {}
        }
        match py.get(name) {
            None => out.push(Finding::new(
                PY_MIRROR_PATH,
                1,
                RULE_WIRE_MIRROR_DRIFT,
                &format!("wire constant {name} not found in the Python mirror"),
            )),
            Some(&(got, line)) if got != want => out.push(Finding::new(
                PY_MIRROR_PATH,
                line,
                RULE_WIRE_MIRROR_DRIFT,
                &format!(
                    "wire constant {name} = {got} in the Python mirror, but the Rust \
                     codec says {want}"
                ),
            )),
            Some(_) => {}
        }
    }
    out
}

// ---- tiny const-expression evaluator ----
//
// Handles exactly what the two constant tables use: integer literals
// (decimal / 0x / 0b, `_` separators, Rust type suffixes), previously
// defined names, `+`, `-`, `<<`, and parentheses. Rust precedence:
// additive binds tighter than shifts.

fn eval_expr(expr: &str, env: &BTreeMap<String, u64>) -> Option<u64> {
    let tokens = tokenize(expr)?;
    let mut pos = 0usize;
    let v = parse_shift(&tokens, &mut pos, env)?;
    if pos == tokens.len() {
        Some(v)
    } else {
        None
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Num(u64),
    Ident(String),
    Plus,
    Minus,
    Shl,
    LParen,
    RParen,
}

fn tokenize(expr: &str) -> Option<Vec<Tok>> {
    let b = expr.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        match b[i] {
            b' ' | b'\t' => i += 1,
            b'+' => {
                out.push(Tok::Plus);
                i += 1;
            }
            b'-' => {
                out.push(Tok::Minus);
                i += 1;
            }
            b'<' if i + 1 < b.len() && b[i + 1] == b'<' => {
                out.push(Tok::Shl);
                i += 2;
            }
            b'(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            b')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            b'0'..=b'9' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                out.push(Tok::Num(parse_int(&expr[start..i])?));
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                out.push(Tok::Ident(expr[start..i].to_string()));
            }
            _ => return None,
        }
    }
    Some(out)
}

fn parse_int(lit: &str) -> Option<u64> {
    let clean: String = lit.chars().filter(|&c| c != '_').collect();
    // Strip a Rust type suffix (u8/u16/u32/u64/usize/i32/...).
    let strip = |s: &str| -> String {
        for suf in ["usize", "isize", "u64", "i64", "u32", "i32", "u16", "i16", "u8", "i8"] {
            if let Some(head) = s.strip_suffix(suf) {
                if !head.is_empty() {
                    return head.to_string();
                }
            }
        }
        s.to_string()
    };
    let clean = strip(&clean);
    if let Some(hex) = clean.strip_prefix("0x").or_else(|| clean.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else if let Some(bin) = clean.strip_prefix("0b").or_else(|| clean.strip_prefix("0B")) {
        u64::from_str_radix(bin, 2).ok()
    } else {
        clean.parse().ok()
    }
}

fn parse_shift(tokens: &[Tok], pos: &mut usize, env: &BTreeMap<String, u64>) -> Option<u64> {
    let mut v = parse_add(tokens, pos, env)?;
    while *pos < tokens.len() && tokens[*pos] == Tok::Shl {
        *pos += 1;
        let rhs = parse_add(tokens, pos, env)?;
        v = v.checked_shl(u32::try_from(rhs).ok()?)?;
    }
    Some(v)
}

fn parse_add(tokens: &[Tok], pos: &mut usize, env: &BTreeMap<String, u64>) -> Option<u64> {
    let mut v = parse_atom(tokens, pos, env)?;
    while *pos < tokens.len() {
        match tokens[*pos] {
            Tok::Plus => {
                *pos += 1;
                v = v.checked_add(parse_atom(tokens, pos, env)?)?;
            }
            Tok::Minus => {
                *pos += 1;
                v = v.checked_sub(parse_atom(tokens, pos, env)?)?;
            }
            _ => break,
        }
    }
    Some(v)
}

fn parse_atom(tokens: &[Tok], pos: &mut usize, env: &BTreeMap<String, u64>) -> Option<u64> {
    match tokens.get(*pos)? {
        Tok::Num(n) => {
            *pos += 1;
            Some(*n)
        }
        Tok::Ident(name) => {
            *pos += 1;
            env.get(name).copied()
        }
        Tok::LParen => {
            *pos += 1;
            let v = parse_shift(tokens, pos, env)?;
            if tokens.get(*pos)? != &Tok::RParen {
                return None;
            }
            *pos += 1;
            Some(v)
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluator_matches_rust_semantics() {
        let env = BTreeMap::from([("HEADER".to_string(), 32u64), ("FAMILY_SHIFT".to_string(), 2u64)]);
        assert_eq!(eval_expr("0x53544F52", &env), Some(0x53544F52));
        assert_eq!(eval_expr("4 + 2 + 2 + 4 + 4 + 8 + 8", &env), Some(32));
        assert_eq!(eval_expr("HEADER + 8 + 1", &env), Some(41));
        assert_eq!(eval_expr("1 << 26", &env), Some(67_108_864));
        assert_eq!(eval_expr("0b11 << FAMILY_SHIFT", &env), Some(12));
        // `+` binds tighter than `<<` in Rust: 1 << 2 + 1 == 8.
        assert_eq!(eval_expr("1 << 2 + 1", &env), Some(8));
        assert_eq!(eval_expr("(1 << 2) + 1", &env), Some(5));
        assert_eq!(eval_expr("67_108_864usize", &env), Some(67_108_864));
        assert_eq!(eval_expr("nope", &env), None);
    }

    #[test]
    fn rust_extraction_handles_the_codec_shapes() {
        let src = "\
const MAGIC: u32 = 0x53544F52;
const FAMILY_SHIFT: u8 = 2;
const FAMILY_MASK: u8 = 0b11 << FAMILY_SHIFT;
/// Shared header.
const HEADER: usize = 4 + 2 + 2 + 4 + 4 + 8 + 8;
const HEADER_V2: usize = HEADER + 8 + 1;

fn family_to_code(f: HashFamily) -> u8 {
    match f {
        HashFamily::Dense => 0,
        HashFamily::Sparse { .. } => 1,
        HashFamily::Hadamard => 2,
    }
}
";
        let t = extract_rust_constants(src);
        assert_eq!(t.get("MAGIC").map(|v| v.0), Some(0x53544F52));
        assert_eq!(t.get("FAMILY_MASK").map(|v| v.0), Some(12));
        assert_eq!(t.get("HEADER_V2").map(|v| v.0), Some(41));
        assert_eq!(t.get("FAMILY_DENSE").map(|v| v.0), Some(0));
        assert_eq!(t.get("FAMILY_SPARSE").map(|v| v.0), Some(1));
        assert_eq!(t.get("FAMILY_HADAMARD").map(|v| v.0), Some(2));
    }

    #[test]
    fn python_extraction_skips_indented_and_comments() {
        let src = "\
MAGIC = 0x53544F52  # frame magic
HEADER = 4 + 2 + 2 + 4 + 4 + 8 + 8
MAX_CELLS = 1 << 26
def header():
    local = 1
";
        let t = extract_python_constants(src);
        assert_eq!(t.get("MAGIC").map(|v| v.0), Some(0x53544F52));
        assert_eq!(t.get("HEADER").map(|v| v.0), Some(32));
        assert_eq!(t.get("MAX_CELLS").map(|v| v.0), Some(67_108_864));
        assert!(t.get("local").is_none());
    }

    #[test]
    fn drift_is_detected_in_either_direction() {
        let rust_ok = "const MAGIC: u32 = 0x53544F52;";
        let py_drifted = "MAGIC = 0x53544F53\n";
        let findings = check_mirror(rust_ok, py_drifted);
        assert!(findings
            .iter()
            .any(|f| f.file == PY_MIRROR_PATH && f.message.contains("MAGIC")));
        // The truncated sources above are missing most constants too.
        assert!(findings.iter().all(|f| f.rule == RULE_WIRE_MIRROR_DRIFT));
    }
}
