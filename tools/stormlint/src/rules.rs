//! The STORM-specific lint rules (L1–L3).
//!
//! Each rule is token-level over the blanked code view from
//! [`crate::lexer`], scoped by path allowlists, by `#[cfg(test)] mod`
//! regions (tests may index, unwrap and sleep), and by the
//! `stormlint::allow(rule)` comment escape hatch. The L4 mirror-drift
//! rule lives in [`crate::mirror`] because it compares two files rather
//! than scanning one.

use crate::lexer::{word_offsets, FileView};
use crate::Finding;

/// Rule identifiers, as printed in diagnostics and named in
/// `stormlint::allow(...)` comments.
pub const RULE_UNSAFE_OUTSIDE_SIMD: &str = "unsafe-outside-simd";
pub const RULE_MISSING_SAFETY_COMMENT: &str = "missing-safety-comment";
pub const RULE_RANDOMIZED_HASHER: &str = "randomized-hasher";
pub const RULE_WALL_CLOCK: &str = "wall-clock";
pub const RULE_RAW_THREAD_SPAWN: &str = "raw-thread-spawn";
pub const RULE_FMA_CONTRACTION: &str = "fma-contraction";
pub const RULE_WIRE_PANIC: &str = "wire-panic";
pub const RULE_WIRE_INDEX: &str = "wire-index";
pub const RULE_WIRE_ARITH: &str = "wire-arith";
pub const RULE_WIRE_MIRROR_DRIFT: &str = "wire-mirror-drift";

/// The one file allowed to contain `unsafe`.
const UNSAFE_ALLOWLIST: &[&str] = &["lsh/simd.rs"];
/// Files allowed to read the wall clock (plus anything under benches/).
const WALL_CLOCK_ALLOWLIST: &[&str] = &["util/timer.rs", "util/bench.rs"];
/// Files allowed to spawn raw threads (scoped `thread::scope` workers
/// elsewhere don't match the `thread::spawn` token and stay legal).
const THREAD_SPAWN_ALLOWLIST: &[&str] = &["edge/executor.rs", "edge/fleet.rs"];
/// Module prefixes whose float reductions must stay scalar-ordered:
/// `mul_add` (FMA contraction) would change results across targets.
const FMA_SCOPES: &[&str] = &["lsh/", "sketch/", "edge/"];
/// The wire codec file, home of the L3 rules.
const WIRE_FILE: &str = "sketch/serialize.rs";

fn path_ends_with(rel_path: &str, suffix: &str) -> bool {
    let p = rel_path.replace('\\', "/");
    p.ends_with(suffix)
}

fn in_allowlist(rel_path: &str, list: &[&str]) -> bool {
    list.iter().any(|s| path_ends_with(rel_path, s))
}

fn in_benches(rel_path: &str) -> bool {
    rel_path.replace('\\', "/").contains("benches/")
}

fn in_fma_scope(rel_path: &str) -> bool {
    let p = rel_path.replace('\\', "/");
    FMA_SCOPES.iter().any(|s| p.contains(&format!("src/{s}")))
}

/// Does a comment on `line` (1-based) or the line above carry a
/// `stormlint::allow(rule)` escape hatch naming `rule`?
fn allowed_by_comment(view: &FileView, line: usize, rule: &str) -> bool {
    let check = |idx: usize| -> bool {
        view.lines
            .get(idx)
            .map(|l| comment_allows(&l.comment, rule))
            .unwrap_or(false)
    };
    // Own line (trailing comment), or the previous line (standalone).
    check(line.wrapping_sub(1)) || (line >= 2 && check(line - 2))
}

fn comment_allows(comment: &str, rule: &str) -> bool {
    let mut rest = comment;
    while let Some(pos) = rest.find("stormlint::allow(") {
        let inner = &rest[pos + "stormlint::allow(".len()..];
        if let Some(end) = inner.find(')') {
            if inner[..end]
                .split(',')
                .any(|r| r.trim() == rule)
            {
                return true;
            }
            rest = &inner[end..];
        } else {
            return false;
        }
    }
    false
}

/// Walk upward from `line` looking for a `// SAFETY:` comment, skipping
/// lines that are blank or carry only attributes/other comments. This
/// accepts the idiomatic shape
/// ```text
/// // SAFETY: AVX2 confirmed by the dispatcher.
/// #[target_feature(enable = "avx2")]
/// unsafe fn kernel(...) { ... }
/// ```
fn has_safety_comment(view: &FileView, line: usize) -> bool {
    // Trailing comment on the same line counts too.
    let mut idx = line; // 1-based; view.lines[idx - 1] is `line`.
    loop {
        let Some(l) = view.lines.get(idx - 1) else { return false };
        if l.comment.contains("SAFETY:") {
            return true;
        }
        if idx != line {
            let code = l.code.trim();
            // Comment-only and blank lines have empty code after
            // blanking; attributes may sit between the comment and the
            // unsafe fn (`#[target_feature(...)]`).
            let skippable = code.is_empty()
                || code.starts_with("#[")
                || code.starts_with("#!")
                || code.ends_with(")]");
            if !skippable {
                return false;
            }
        }
        if idx == 1 {
            return false;
        }
        idx -= 1;
    }
}

fn line_of_offset(code: &str, offset: usize) -> usize {
    code.as_bytes()[..offset].iter().filter(|&&c| c == b'\n').count() + 1
}

fn joined_code(view: &FileView) -> String {
    view.lines
        .iter()
        .map(|l| l.code.as_str())
        .collect::<Vec<_>>()
        .join("\n")
}

/// Run every single-file rule against one source file.
pub fn check_file(rel_path: &str, view: &FileView) -> Vec<Finding> {
    let mut out = Vec::new();
    let code = joined_code(view);

    check_unsafe(rel_path, view, &code, &mut out);
    check_determinism(rel_path, view, &code, &mut out);
    check_wire_safety(rel_path, view, &code, &mut out);

    out.sort_by_key(|f| (f.line, f.rule));
    out
}

/// L1: `unsafe` containment and SAFETY comments.
fn check_unsafe(rel_path: &str, view: &FileView, code: &str, out: &mut Vec<Finding>) {
    for at in word_offsets(code, "unsafe") {
        let line = line_of_offset(code, at);
        if !in_allowlist(rel_path, UNSAFE_ALLOWLIST) {
            if allowed_by_comment(view, line, RULE_UNSAFE_OUTSIDE_SIMD) {
                continue;
            }
            out.push(Finding::new(
                rel_path,
                line,
                RULE_UNSAFE_OUTSIDE_SIMD,
                "`unsafe` is confined to lsh/simd.rs; move the code or route it \
                 through the audited SIMD module",
            ));
        } else {
            if allowed_by_comment(view, line, RULE_MISSING_SAFETY_COMMENT) {
                continue;
            }
            if !has_safety_comment(view, line) {
                out.push(Finding::new(
                    rel_path,
                    line,
                    RULE_MISSING_SAFETY_COMMENT,
                    "every `unsafe` block/fn needs a `// SAFETY:` comment stating \
                     the invariant that makes it sound",
                ));
            }
        }
    }
}

/// L2: determinism — no randomized hashers, wall clocks, raw thread
/// spawns, or FMA contraction in bit-identity-critical modules.
fn check_determinism(rel_path: &str, view: &FileView, code: &str, out: &mut Vec<Finding>) {
    // Test modules may use all of these freely.
    let flag = |out: &mut Vec<Finding>, view: &FileView, line: usize, rule: &'static str, msg: &str| {
        if view.in_test_region(line) || allowed_by_comment(view, line, rule) {
            return;
        }
        out.push(Finding::new(rel_path, line, rule, msg));
    };

    for word in ["HashMap", "HashSet"] {
        for at in word_offsets(code, word) {
            let line = line_of_offset(code, at);
            flag(
                out,
                view,
                line,
                RULE_RANDOMIZED_HASHER,
                "std HashMap/HashSet iterate in randomized-hasher order; use \
                 BTreeMap/BTreeSet (or a seeded hasher) so folds stay bit-identical",
            );
        }
    }

    if !in_allowlist(rel_path, WALL_CLOCK_ALLOWLIST) && !in_benches(rel_path) {
        for pat in ["SystemTime::now", "Instant::now"] {
            let mut from = 0usize;
            while let Some(pos) = code[from..].find(pat) {
                let at = from + pos;
                from = at + 1;
                let line = line_of_offset(code, at);
                flag(
                    out,
                    view,
                    line,
                    RULE_WALL_CLOCK,
                    "wall-clock reads live in util/timer.rs and benches only; take a \
                     Timer (or a caller-supplied timestamp) instead",
                );
            }
        }
    }

    if !in_allowlist(rel_path, THREAD_SPAWN_ALLOWLIST) {
        let mut from = 0usize;
        while let Some(pos) = code[from..].find("thread::spawn") {
            let at = from + pos;
            from = at + 1;
            let line = line_of_offset(code, at);
            flag(
                out,
                view,
                line,
                RULE_RAW_THREAD_SPAWN,
                "raw thread::spawn is confined to edge/executor.rs and edge/fleet.rs; \
                 route concurrency through the worker-pool executor",
            );
        }
    }

    if in_fma_scope(rel_path) {
        for at in word_offsets(code, "mul_add") {
            let line = line_of_offset(code, at);
            flag(
                out,
                view,
                line,
                RULE_FMA_CONTRACTION,
                "mul_add fuses with different rounding than mul-then-add; the \
                 bit-identity-critical modules must keep scalar-ordered float ops",
            );
        }
    }
}

/// Is `line` inside a decode-path region of the wire codec: a fn whose
/// name starts with `decode`, the varint/width helpers, fuzz entry
/// points, or any fn inside an `impl` block mentioning `WireReader`?
fn in_decode_path(view: &FileView, line: usize) -> bool {
    let decode_fn = view.fns.iter().any(|f| {
        line >= f.body_start
            && line <= f.body_end
            && (f.name.starts_with("decode")
                || f.name == "width_from_byte"
                || f.name.starts_with("fuzz_varint")
                || f.name == "get_varint")
    });
    let reader_impl = view
        .impls
        .iter()
        .any(|i| i.header.contains("WireReader") && line >= i.body_start && line <= i.body_end);
    decode_fn || reader_impl
}

/// L3: wire safety — decode paths in sketch/serialize.rs must be
/// panic-free: no slice indexing, no unwrap/expect, no unchecked
/// arithmetic. Untrusted bytes must only ever surface as `WireError`.
fn check_wire_safety(rel_path: &str, view: &FileView, code: &str, out: &mut Vec<Finding>) {
    if !path_ends_with(rel_path, WIRE_FILE) {
        return;
    }
    let b = code.as_bytes();

    let relevant = |view: &FileView, line: usize, rule: &str| -> bool {
        in_decode_path(view, line) && !view.in_test_region(line) && !allowed_by_comment(view, line, rule)
    };

    // Panicking constructs.
    const PANIC_TOKENS: &[&str] = &[
        ".unwrap()",
        ".expect(",
        "panic!",
        "unreachable!",
        "todo!",
        "unimplemented!",
        "assert!(",
        "assert_eq!(",
        "assert_ne!(",
        "debug_assert",
    ];
    for tok in PANIC_TOKENS {
        let mut from = 0usize;
        while let Some(pos) = code[from..].find(tok) {
            let at = from + pos;
            from = at + 1;
            let line = line_of_offset(code, at);
            if !relevant(view, line, RULE_WIRE_PANIC) {
                continue;
            }
            out.push(Finding::new(
                rel_path,
                line,
                RULE_WIRE_PANIC,
                "decode paths must not panic on untrusted bytes; return a WireError",
            ));
        }
    }

    // Slice indexing: `[` whose previous non-space char ends an
    // expression (identifier, `)`, `]`). `#[...]` attributes and array
    // type syntax `[u8; 4]` start after non-expression chars and don't
    // match.
    for (at, &c) in b.iter().enumerate() {
        if c != b'[' {
            continue;
        }
        let mut j = at;
        let mut prev = 0u8;
        while j > 0 {
            j -= 1;
            if b[j] != b' ' {
                prev = b[j];
                break;
            }
        }
        let indexing = prev.is_ascii_alphanumeric() || prev == b'_' || prev == b')' || prev == b']';
        if !indexing {
            continue;
        }
        let line = line_of_offset(code, at);
        if !relevant(view, line, RULE_WIRE_INDEX) {
            continue;
        }
        out.push(Finding::new(
            rel_path,
            line,
            RULE_WIRE_INDEX,
            "slice indexing panics on short frames; use .get(..) and map the \
             miss to WireError::Truncated",
        ));
    }

    // Unchecked arithmetic: binary `+`, `-`, `*` (and their `=` forms)
    // following an expression. Shifts stay legal — decode guards their
    // operands with explicit range checks before shifting.
    for (at, &c) in b.iter().enumerate() {
        if c != b'+' && c != b'-' && c != b'*' {
            continue;
        }
        // `->` return arrow, `+=`-style second char, `**`-like doubles.
        if c == b'-' && at + 1 < b.len() && b[at + 1] == b'>' {
            continue;
        }
        if at > 0 && (b[at - 1] == b'+' || b[at - 1] == b'-' || b[at - 1] == b'*') {
            continue;
        }
        let mut j = at;
        let mut prev = 0u8;
        while j > 0 {
            j -= 1;
            if b[j] != b' ' {
                prev = b[j];
                break;
            }
        }
        let binary = prev.is_ascii_alphanumeric() || prev == b'_' || prev == b')' || prev == b']';
        if !binary {
            continue;
        }
        let line = line_of_offset(code, at);
        if !relevant(view, line, RULE_WIRE_ARITH) {
            continue;
        }
        out.push(Finding::new(
            rel_path,
            line,
            RULE_WIRE_ARITH,
            "unchecked arithmetic can overflow on adversarial headers; use \
             checked_add/checked_mul (or saturating ops) and surface WireError",
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::FileView;

    fn lint(path: &str, src: &str) -> Vec<Finding> {
        check_file(path, &FileView::parse(src))
    }

    fn rules_of(findings: &[Finding]) -> Vec<&str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ---- L1 ----

    #[test]
    fn unsafe_outside_simd_is_flagged() {
        let f = lint("rust/src/sketch/race.rs", "fn f() { unsafe { std::hint::unreachable_unchecked() } }\n");
        assert!(rules_of(&f).contains(&RULE_UNSAFE_OUTSIDE_SIMD));
    }

    #[test]
    fn unsafe_in_simd_with_safety_comment_passes() {
        let src = "\
// SAFETY: caller checked AVX2 via the dispatcher.
#[target_feature(enable = \"avx2\")]
unsafe fn kernel(x: &[f32]) {}
";
        let f = lint("rust/src/lsh/simd.rs", src);
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }

    #[test]
    fn unsafe_in_simd_without_safety_comment_fails() {
        let f = lint("rust/src/lsh/simd.rs", "unsafe fn kernel(x: &[f32]) {}\n");
        assert_eq!(rules_of(&f), vec![RULE_MISSING_SAFETY_COMMENT]);
    }

    #[test]
    fn trailing_safety_comment_counts() {
        let f = lint(
            "rust/src/lsh/simd.rs",
            "let v = unsafe { load(ptr) }; // SAFETY: ptr is in-bounds by the loop guard.\n",
        );
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }

    #[test]
    fn unsafe_in_comment_or_string_is_ignored() {
        let f = lint(
            "rust/src/sketch/race.rs",
            "// this code is never unsafe\nlet s = \"unsafe\";\n",
        );
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }

    // ---- L2 ----

    #[test]
    fn hashmap_is_flagged_outside_tests() {
        let f = lint("rust/src/lsh/query.rs", "use std::collections::HashMap;\n");
        assert_eq!(rules_of(&f), vec![RULE_RANDOMIZED_HASHER]);
    }

    #[test]
    fn hashmap_in_test_mod_passes() {
        let src = "\
#[cfg(test)]
mod tests {
    use std::collections::HashMap;
}
";
        let f = lint("rust/src/lsh/query.rs", src);
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }

    #[test]
    fn wall_clock_flagged_outside_timer() {
        let f = lint("rust/src/edge/network.rs", "let t = std::time::Instant::now();\n");
        assert_eq!(rules_of(&f), vec![RULE_WALL_CLOCK]);
    }

    #[test]
    fn wall_clock_allowed_in_timer_and_benches() {
        assert!(lint("rust/src/util/timer.rs", "let t = Instant::now();\n").is_empty());
        assert!(lint("rust/src/util/bench.rs", "let t = Instant::now();\n").is_empty());
        assert!(lint("rust/benches/bench_fleet.rs", "let t = Instant::now();\n").is_empty());
    }

    #[test]
    fn thread_spawn_confined_to_executor_and_fleet() {
        let f = lint("rust/src/sketch/storm.rs", "std::thread::spawn(|| {});\n");
        assert_eq!(rules_of(&f), vec![RULE_RAW_THREAD_SPAWN]);
        assert!(lint("rust/src/edge/executor.rs", "std::thread::spawn(|| {});\n").is_empty());
        assert!(lint("rust/src/edge/fleet.rs", "std::thread::spawn(|| {});\n").is_empty());
    }

    #[test]
    fn scoped_threads_stay_legal() {
        let f = lint("rust/src/sketch/storm.rs", "std::thread::scope(|s| { s.spawn(|| {}); });\n");
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }

    #[test]
    fn mul_add_flagged_in_bit_identity_scopes_only() {
        let f = lint("rust/src/lsh/srp.rs", "let y = a.mul_add(b, c);\n");
        assert_eq!(rules_of(&f), vec![RULE_FMA_CONTRACTION]);
        assert!(lint("rust/src/linalg/matrix.rs", "let y = a.mul_add(b, c);\n").is_empty());
    }

    #[test]
    fn escape_hatch_comment_suppresses() {
        let src = "\
// stormlint::allow(randomized-hasher) -- keyed by opaque ids, order never observed
use std::collections::HashMap;
";
        assert!(lint("rust/src/lsh/query.rs", src).is_empty());
        let trailing = "use std::collections::HashMap; // stormlint::allow(randomized-hasher)\n";
        assert!(lint("rust/src/lsh/query.rs", trailing).is_empty());
    }

    #[test]
    fn escape_hatch_names_must_match() {
        let src = "\
// stormlint::allow(wall-clock)
use std::collections::HashMap;
";
        let f = lint("rust/src/lsh/query.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_RANDOMIZED_HASHER]);
    }

    // ---- L3 ----

    #[test]
    fn wire_unwrap_in_decode_fn_fails() {
        let src = "\
pub fn decode(bytes: &[u8]) -> Result<Frame, WireError> {
    let x = bytes.get(0).unwrap();
    Ok(Frame { x: *x })
}
";
        let f = lint("rust/src/sketch/serialize.rs", src);
        assert!(rules_of(&f).contains(&RULE_WIRE_PANIC));
    }

    #[test]
    fn wire_indexing_in_decode_fn_fails() {
        let src = "\
pub fn decode_delta(bytes: &[u8]) -> u8 {
    bytes[0]
}
";
        let f = lint("rust/src/sketch/serialize.rs", src);
        assert!(rules_of(&f).contains(&RULE_WIRE_INDEX));
    }

    #[test]
    fn wire_unchecked_add_in_reader_impl_fails() {
        let src = "\
struct WireReader<'a> { buf: &'a [u8], pos: usize }
impl<'a> WireReader<'a> {
    fn take(&mut self, n: usize) -> usize {
        self.pos + n
    }
}
";
        let f = lint("rust/src/sketch/serialize.rs", src);
        assert!(rules_of(&f).contains(&RULE_WIRE_ARITH));
    }

    #[test]
    fn encode_paths_are_out_of_scope() {
        let src = "\
pub fn encode(counts: &[u64]) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(counts[0] as u8 + 1);
    out
}
";
        let f = lint("rust/src/sketch/serialize.rs", src);
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }

    #[test]
    fn checked_ops_and_get_pass_in_decode() {
        let src = "\
pub fn decode(bytes: &[u8]) -> Result<u32, WireError> {
    let end = 4usize.checked_add(bytes.len()).ok_or(WireError::Truncated(0))?;
    let head = bytes.get(..4).ok_or(WireError::Truncated(end))?;
    head.try_into().map(u32::from_le_bytes).map_err(|_| WireError::Truncated(end))
}
";
        let f = lint("rust/src/sketch/serialize.rs", src);
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }

    #[test]
    fn wire_rules_skip_test_mods() {
        let src = "\
#[cfg(test)]
mod tests {
    fn decode_helper(bytes: &[u8]) -> u8 {
        bytes[0]
    }
}
";
        let f = lint("rust/src/sketch/serialize.rs", src);
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }
}
