//! CLI entry point: `cargo run -p stormlint [repo-root]`.
//!
//! Lints the repo tree and prints one `file:line: error[rule]: message`
//! line per violation (one-click navigable in CI logs and editors).
//! Exits 1 if anything was found, 0 on a clean tree.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    // Default to the workspace root: this crate lives at
    // <root>/tools/stormlint, so the manifest dir's grandparent is the
    // repo root whether invoked via `cargo run -p stormlint` from
    // anywhere in the workspace or as a bare binary.
    let root = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..")
    });

    let findings = stormlint::lint_tree(&root);
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("stormlint: clean (rules L1-L4, tree {})", root.display());
        ExitCode::SUCCESS
    } else {
        eprintln!("stormlint: {} violation(s)", findings.len());
        ExitCode::FAILURE
    }
}
