//! Fuzz target: full-sketch wire decoding must never panic.
//!
//! `decode` is the v1-compatible full-sketch entry point: it rebuilds a
//! live [`storm::sketch::storm::StormSketch`] (hash family and all) from
//! the embedded seed. Arbitrary bytes must yield either a sketch or a
//! structured `WireError` — any panic, unbounded allocation, or
//! arithmetic overflow is a wire-safety bug. Dense-family frames that
//! decode successfully must re-encode to a frame that decodes to the
//! same counters (the v1 wire only speaks the dense family, so the
//! round-trip leg is gated on it).

#![no_main]

use libfuzzer_sys::fuzz_target;
use storm::config::HashFamily;
use storm::sketch::serialize::{decode, encode};

fuzz_target!(|data: &[u8]| {
    if let Ok(sketch) = decode(data) {
        if sketch.config().hash_family == HashFamily::Dense {
            let bytes = encode(&sketch);
            let again = decode(&bytes).expect("re-encoded frame must decode");
            assert_eq!(again.grid().counts_u32(), sketch.grid().counts_u32());
            assert_eq!(again.count(), sketch.count());
            assert_eq!(again.seed(), sketch.seed());
            assert_eq!(again.dim(), sketch.dim());
        }
    }
});
