//! Fuzz target: LEB128 varint stream decoding must never panic.
//!
//! `fuzz_varint_stream` drains a byte slice through the same
//! `WireReader::varint` path the sparse decoder uses. Any decoded value
//! must re-encode to a canonical byte string that decodes back to the
//! same value (varints are canonical on this wire — no overlong forms
//! are ever produced by the encoder).

#![no_main]

use libfuzzer_sys::fuzz_target;
use storm::sketch::serialize::{fuzz_varint_stream, varint_to_bytes};

fuzz_target!(|data: &[u8]| {
    if let Ok(values) = fuzz_varint_stream(data) {
        for v in values {
            let bytes = varint_to_bytes(v);
            let back = fuzz_varint_stream(&bytes).expect("canonical varint must decode");
            assert_eq!(back, vec![v], "varint round-trip drifted");
        }
    }
});
