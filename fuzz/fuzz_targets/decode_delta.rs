//! Fuzz target: delta wire decoding must never panic.
//!
//! `decode_delta` handles the v1/v2/v3 frames — the largest attack
//! surface on the wire (varint gap coding, width negotiation, the v3
//! task/family/privacy flag bits). Arbitrary bytes must yield either a
//! delta or a structured `WireError`. Frames that decode successfully
//! must survive a v3 re-encode/re-decode round trip as an identical
//! [`storm::sketch::delta::SketchDelta`] (the width tag rides the
//! struct, so equality covers it).

#![no_main]

use libfuzzer_sys::fuzz_target;
use storm::sketch::serialize::{decode_delta, encode_delta_v3};

fuzz_target!(|data: &[u8]| {
    if let Ok(delta) = decode_delta(data) {
        let bytes = encode_delta_v3(&delta);
        let again = decode_delta(&bytes).expect("re-encoded delta must decode");
        assert_eq!(delta, again, "delta decode/encode round-trip drifted");
    }
});
