//! Step-size schedules for the derivative-free loop.

/// A step-size schedule: iteration -> eta.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    /// Constant eta.
    Constant(f64),
    /// `eta0 / (1 + iter / decay_iters)`.
    InverseTime { eta0: f64, decay_iters: f64 },
    /// `eta0 / sqrt(1 + iter)` — the classic robust choice for noisy
    /// gradient estimates.
    InverseSqrt { eta0: f64 },
    /// Piecewise: eta0 until `warm` iters, then eta0 * factor.
    StepDecay { eta0: f64, warm: usize, factor: f64 },
}

impl Schedule {
    pub fn at(&self, iter: usize) -> f64 {
        match *self {
            Schedule::Constant(e) => e,
            Schedule::InverseTime { eta0, decay_iters } => {
                eta0 / (1.0 + iter as f64 / decay_iters)
            }
            Schedule::InverseSqrt { eta0 } => eta0 / (1.0 + iter as f64).sqrt(),
            Schedule::StepDecay { eta0, warm, factor } => {
                if iter < warm {
                    eta0
                } else {
                    eta0 * factor
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant(0.5);
        assert_eq!(s.at(0), 0.5);
        assert_eq!(s.at(10_000), 0.5);
    }

    #[test]
    fn inverse_time_halves_at_decay() {
        let s = Schedule::InverseTime { eta0: 1.0, decay_iters: 100.0 };
        assert_close(s.at(0), 1.0, 1e-12);
        assert_close(s.at(100), 0.5, 1e-12);
    }

    #[test]
    fn inverse_sqrt_decays_monotonically() {
        let s = Schedule::InverseSqrt { eta0: 1.0 };
        let mut prev = f64::INFINITY;
        for it in 0..100 {
            let e = s.at(it);
            assert!(e < prev);
            prev = e;
        }
        assert_close(s.at(3), 0.5, 1e-12);
    }

    #[test]
    fn step_decay_switches_once() {
        let s = Schedule::StepDecay { eta0: 1.0, warm: 10, factor: 0.1 };
        assert_eq!(s.at(9), 1.0);
        assert_close(s.at(10), 0.1, 1e-12);
        assert_close(s.at(99), 0.1, 1e-12);
    }
}
