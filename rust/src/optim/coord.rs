//! Derivative-free coordinate descent over sketch queries.
//!
//! The sphere-sampling estimator of Algorithm 2 degrades in higher
//! dimensions (the gradient signal spreads over d directions while the
//! sketch noise per query is constant). Coordinate descent restructures
//! the same query budget into a sequence of *one-dimensional* line
//! searches — each coordinate's section search is robust to query noise
//! because it only needs ordering information along one axis, and the
//! surrogate is convex along every line through the constraint plane.
//!
//! Each sweep refines every coordinate by golden-section search on the
//! sketch estimate, with the bracket radius shrinking geometrically
//! across sweeps. All evaluations go through the same [`RiskOracle`] the
//! DFO path uses — as [`Probe::Axis`] candidates against the constant
//! sweep iterate, which is the incremental query engine's best case:
//! the base projection is cached once per coordinate and every bracket,
//! section, and center probe costs `O(R * p)` — so this optimizer works
//! against the pure-rust sketch, composite sketches, private releases,
//! and the XLA query executable.

use super::{CandidateSet, Probe, RiskOracle};

/// Coordinate-descent configuration.
#[derive(Clone, Copy, Debug)]
pub struct CoordConfig {
    /// Full sweeps over all coordinates.
    pub sweeps: usize,
    /// Initial half-width of each coordinate bracket.
    pub radius: f64,
    /// Bracket shrink factor per sweep.
    pub shrink: f64,
    /// Golden-section iterations per coordinate (each costs 1 query after
    /// the initial bracket probes).
    pub section_iters: usize,
}

impl Default for CoordConfig {
    fn default() -> Self {
        CoordConfig { sweeps: 6, radius: 0.8, shrink: 0.6, section_iters: 10 }
    }
}

/// Result of a coordinate-descent run.
pub struct CoordResult {
    pub theta: Vec<f64>,
    /// Risk estimate trace, one point per coordinate refinement.
    pub trace: Vec<f64>,
    pub evals: u64,
}

/// Minimize the oracle over `theta` (length d), last coordinate fixed at
/// -1 exactly like Algorithm 2.
pub fn coordinate_descent(oracle: &dyn RiskOracle, cfg: CoordConfig) -> CoordResult {
    let d = oracle.dim();
    let mut theta_tilde = vec![0.0; d + 1];
    theta_tilde[d] = -1.0;
    let mut trace = Vec::new();
    let mut evals = 0u64;
    let mut radius = cfg.radius;
    let phi = (5f64.sqrt() - 1.0) / 2.0; // 0.618...
    // Persistent scratch for the probe list and risks. Every evaluation
    // of a coordinate search is an axis probe against the SAME base
    // iterate (the old in-place slot mutation, expressed declaratively),
    // so the incremental engine's base cache stays valid for the whole
    // bracket + section + center sequence of a coordinate.
    let mut probes: Vec<Probe> = Vec::with_capacity(2);
    let mut probe_risks: Vec<f64> = Vec::with_capacity(2);
    for _ in 0..cfg.sweeps {
        for j in 0..d {
            // Golden-section search on coordinate j in
            // [theta_j - radius, theta_j + radius].
            let center = theta_tilde[j];
            let mut lo = center - radius;
            let mut hi = center + radius;
            let mut x1 = hi - phi * (hi - lo);
            let mut x2 = lo + phi * (hi - lo);
            probes.clear();
            probes.push(Probe::Axis { k: j, value: x1 });
            probes.push(Probe::Axis { k: j, value: x2 });
            oracle.risk_candidates(
                &CandidateSet { base: &theta_tilde, dirs: &[], probes: &probes },
                &mut probe_risks,
            );
            let (mut f1, mut f2) = (probe_risks[0], probe_risks[1]);
            evals += 2;
            let mut eval_at = |v: f64| -> f64 {
                probes.clear();
                probes.push(Probe::Axis { k: j, value: v });
                oracle.risk_candidates(
                    &CandidateSet { base: &theta_tilde, dirs: &[], probes: &probes },
                    &mut probe_risks,
                );
                probe_risks[0]
            };
            for _ in 0..cfg.section_iters {
                if f1 <= f2 {
                    hi = x2;
                    x2 = x1;
                    f2 = f1;
                    x1 = hi - phi * (hi - lo);
                    f1 = eval_at(x1);
                } else {
                    lo = x1;
                    x1 = x2;
                    f1 = f2;
                    x2 = lo + phi * (hi - lo);
                    f2 = eval_at(x2);
                }
                evals += 1;
            }
            let best = if f1 <= f2 { x1 } else { x2 };
            let best_f = f1.min(f2);
            // Keep the move only if it does not degrade the estimate at
            // the center (noise guard). `value == center` folds to the
            // cached base on the incremental path — a free re-read.
            let center_f = eval_at(center);
            evals += 1;
            if best_f < center_f {
                theta_tilde[j] = best;
                trace.push(best_f);
            } else {
                trace.push(center_f);
            }
        }
        radius *= cfg.shrink;
    }
    CoordResult { theta: theta_tilde[..d].to_vec(), trace, evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::FnOracle;

    #[test]
    fn solves_smooth_quadratic() {
        let target = vec![0.3, -0.5, 0.1, 0.7];
        let d = target.len();
        let tgt = target.clone();
        let oracle = FnOracle::new(d, move |tt: &[f64]| {
            tt[..d].iter().zip(&tgt).map(|(a, b)| (a - b) * (a - b)).sum()
        });
        let r = coordinate_descent(&oracle, CoordConfig::default());
        for (a, b) in r.theta.iter().zip(&target) {
            assert!((a - b).abs() < 0.02, "theta={:?}", r.theta);
        }
        assert!(r.evals > 0);
    }

    #[test]
    fn respects_constraint_plane() {
        // Oracle that punishes any deviation of the last coordinate from
        // -1; coordinate descent never touches it.
        let oracle = FnOracle::new(2, |tt: &[f64]| {
            assert_eq!(*tt.last().unwrap(), -1.0);
            tt[0] * tt[0] + tt[1] * tt[1]
        });
        let r = coordinate_descent(&oracle, CoordConfig::default());
        assert_eq!(r.theta.len(), 2);
    }

    #[test]
    fn noise_guard_keeps_center_when_no_improvement() {
        // Flat oracle: theta must stay at zero.
        let oracle = FnOracle::new(3, |_tt: &[f64]| 1.0);
        let r = coordinate_descent(&oracle, CoordConfig::default());
        assert_eq!(r.theta, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn reduces_sketch_surrogate_on_planted_regression() {
        // Well-conditioned case (moderate d, data spread through the
        // ball, generous R): coordinate descent must reduce the *exact*
        // surrogate, not just the noisy sketch estimate it optimizes.
        use crate::config::StormConfig;
        use crate::sketch::storm::StormSketch;
        use crate::util::rng::{Rng, Xoshiro256};
        let mut rng = Xoshiro256::new(3);
        let d = 3;
        let theta_star: Vec<f64> = (0..d).map(|_| rng.uniform_range(-0.4, 0.4)).collect();
        let cfg = StormConfig { rows: 3000, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, d + 1, 5);
        let mut examples = Vec::new();
        for _ in 0..2000 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform_range(-0.5, 0.5)).collect();
            let y = crate::util::mathx::dot(&x, &theta_star) + 0.005 * rng.gaussian();
            let mut z = x;
            z.push(y);
            examples.push(z);
        }
        // Scale into the ball.
        let max_norm = examples
            .iter()
            .map(|z| crate::util::mathx::norm2(z))
            .fold(0.0f64, f64::max);
        for z in &mut examples {
            for v in z.iter_mut() {
                *v *= 0.9 / max_norm;
            }
        }
        for z in &examples {
            sk.insert(z);
        }
        let r = coordinate_descent(&sk, CoordConfig::default());
        // Evaluate via the exact surrogate at the found vs zero model.
        let exact = |theta: &[f64]| {
            let mut tt = theta.to_vec();
            tt.push(-1.0);
            let n = crate::util::mathx::norm2(&tt);
            let radius = crate::data::scale::query_radius();
            let q: Vec<f64> = if n > radius {
                tt.iter().map(|v| v * radius / n).collect()
            } else {
                tt
            };
            crate::loss::prp_loss::exact_surrogate_risk(&q, &examples, 4)
        };
        let risk_found = exact(&r.theta);
        let risk_zero = exact(&vec![0.0; d]);
        assert!(
            risk_found < risk_zero,
            "coordinate descent failed to reduce exact surrogate: {risk_found} vs {risk_zero}"
        );
    }
}
