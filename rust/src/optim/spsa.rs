//! SPSA — simultaneous perturbation stochastic approximation (Spall).
//!
//! An alternative derivative-free estimator to Algorithm 2's sphere
//! sampling: ONE Rademacher perturbation direction per iteration and a
//! central difference along it, giving a gradient estimate from exactly
//! two oracle queries regardless of dimension. Cheaper per iteration than
//! DFO's k probes; noisier per step. Included as the ablation point the
//! paper's "we employ a simple optimization algorithm" invites — the
//! `bench_ablate` target compares the two at matched query budgets.

use super::{CandidateSet, Probe, RiskOracle};
use crate::util::rng::{Rng, Xoshiro256};

/// SPSA settings.
#[derive(Clone, Copy, Debug)]
pub struct SpsaConfig {
    /// Perturbation half-width c.
    pub c: f64,
    /// Step size a.
    pub a: f64,
    pub iters: usize,
    pub seed: u64,
}

impl Default for SpsaConfig {
    fn default() -> Self {
        SpsaConfig { c: 0.3, a: 0.4, iters: 800, seed: 0 }
    }
}

/// Run SPSA with the Algorithm-2 constraint (last coordinate pinned to
/// -1) and Polyak tail averaging. Returns theta (length d).
pub fn spsa(oracle: &dyn RiskOracle, cfg: SpsaConfig) -> Vec<f64> {
    let d = oracle.dim();
    let dim = d + 1;
    let mut theta_tilde = vec![0.0; dim];
    theta_tilde[dim - 1] = -1.0;
    let mut rng = Xoshiro256::new(cfg.seed);
    let tail_start = cfg.iters.saturating_sub((cfg.iters / 3).max(1));
    let mut tail_sum = vec![0.0; d];
    let mut tail_n = 0u64;
    // The central-difference pair is the whole per-iteration candidate
    // set — submit it as one CandidateSet through the oracle's candidate
    // entry point: the incremental engine projects the perturbation
    // direction once and serves both arms as O(R * p) updates; dense
    // backends materialize vectors bit-identical to the old explicit
    // clone-and-axpy construction. Buffers reused across iterations.
    let probes = [Probe::Dir { dir: 0, step: cfg.c }, Probe::Dir { dir: 0, step: -cfg.c }];
    let mut dirs: Vec<Vec<f64>> = vec![Vec::new()];
    let mut risks: Vec<f64> = Vec::with_capacity(2);
    for it in 0..cfg.iters {
        // Rademacher direction over the free coordinates.
        let mut delta = vec![0.0; dim];
        for v in delta.iter_mut().take(d) {
            *v = if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 };
        }
        dirs[0] = delta;
        oracle.risk_candidates(
            &CandidateSet { base: &theta_tilde, dirs: &dirs, probes: &probes },
            &mut risks,
        );
        let g = (risks[0] - risks[1]) / (2.0 * cfg.c);
        // SPSA update: divide by the perturbation elementwise (delta_i =
        // +-1, so this is multiplication).
        for i in 0..d {
            theta_tilde[i] -= cfg.a * g * dirs[0][i];
        }
        theta_tilde[dim - 1] = -1.0;
        if it >= tail_start {
            for (s, v) in tail_sum.iter_mut().zip(&theta_tilde[..d]) {
                *s += v;
            }
            tail_n += 1;
        }
    }
    if tail_n > 0 {
        tail_sum.iter().map(|s| s / tail_n as f64).collect()
    } else {
        theta_tilde[..d].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::FnOracle;

    #[test]
    fn converges_on_quadratic() {
        let target = vec![0.25, -0.4, 0.1];
        let d = target.len();
        let tgt = target.clone();
        let oracle = FnOracle::new(d, move |tt: &[f64]| {
            tt[..d].iter().zip(&tgt).map(|(a, b)| (a - b) * (a - b)).sum()
        });
        let theta = spsa(&oracle, SpsaConfig { c: 0.1, a: 0.05, iters: 2000, seed: 1 });
        for (a, b) in theta.iter().zip(&target) {
            assert!((a - b).abs() < 0.05, "{theta:?}");
        }
    }

    #[test]
    fn two_queries_per_iteration() {
        let oracle = FnOracle::new(2, |tt: &[f64]| tt[0] * tt[0] + tt[1] * tt[1]);
        let _ = spsa(&oracle, SpsaConfig { c: 0.1, a: 0.05, iters: 10, seed: 2 });
        assert_eq!(oracle.evals(), 20);
    }

    #[test]
    fn deterministic_given_seed() {
        let oracle = FnOracle::new(2, |tt: &[f64]| (tt[0] - 0.3).powi(2) + tt[1].powi(2));
        let a = spsa(&oracle, SpsaConfig { c: 0.1, a: 0.05, iters: 100, seed: 7 });
        let oracle2 = FnOracle::new(2, |tt: &[f64]| (tt[0] - 0.3).powi(2) + tt[1].powi(2));
        let b = spsa(&oracle2, SpsaConfig { c: 0.1, a: 0.05, iters: 100, seed: 7 });
        assert_eq!(a, b);
    }

    #[test]
    fn works_against_a_sketch() {
        use crate::config::StormConfig;
        use crate::sketch::storm::StormSketch;
        use crate::testing::gen_ball_point;
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(9);
        let cfg = StormConfig { rows: 200, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, 3, 4);
        for _ in 0..500 {
            sk.insert(&gen_ball_point(&mut rng, 3, 0.9));
        }
        let theta = spsa(&sk, SpsaConfig { c: 0.2, a: 0.2, iters: 200, seed: 3 });
        assert_eq!(theta.len(), 2);
        assert!(theta.iter().all(|v| v.is_finite()));
    }
}
