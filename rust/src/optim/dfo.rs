//! Derivative-free optimization — Algorithm 2 of the paper.
//!
//! Each iteration queries the sketch at `k` random points on a
//! `sigma`-sphere centered at the current `theta~`, spent as `k/2`
//! antithetic pairs, and forms the smoothed central-difference gradient
//! estimate
//!
//! ```text
//! g_hat = (d+1)/(k/2 * sigma) * sum_j 0.5 * (risk(theta~ + sigma u_j)
//!                                          - risk(theta~ - sigma u_j)) u_j
//! ```
//!
//! (the standard two-point sphere estimator; the antithetic difference
//! makes it unbiased for the smoothed objective and variance-bounded
//! without ever evaluating the incumbent itself), steps
//! `theta~ -= eta * g_hat`, and re-projects the last coordinate onto
//! the `-1` constraint — exactly the loop of Algorithm 2 with the gradient
//! estimator made explicit. Candidates go to the oracle as a
//! [`CandidateSet`] (base + direction probes), so the incremental engine
//! serves each probe in `O(R * p)` with one shared projection per
//! direction pair.

use super::{CandidateSet, Probe, RiskOracle};
use crate::config::OptimizerConfig;
use crate::util::mathx::axpy;
use crate::util::rng::{Rng, Xoshiro256};

/// Re-export so callers can `use storm::optim::dfo::DfoConfig`.
pub type DfoConfig = OptimizerConfig;

/// One optimization trace point.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub iter: usize,
    pub risk: f64,
}

/// Derivative-free optimizer state.
pub struct DfoOptimizer {
    cfg: DfoConfig,
    /// Current augmented parameter `[theta, -1]`.
    theta_tilde: Vec<f64>,
    rng: Xoshiro256,
    trace: Vec<TracePoint>,
    /// Per-step scratch, reused across iterations: the probe list and
    /// the risks returned by the oracle's candidate entry point. The
    /// probe directions are fresh allocations per step — they come
    /// straight from the RNG's `sphere_vec`.
    probes: Vec<Probe>,
    dirs: Vec<Vec<f64>>,
    risks: Vec<f64>,
}

impl DfoOptimizer {
    /// Initialize at `theta = 0` in `d` feature dimensions (Algorithm 2's
    /// `theta~_0 = 0^{d+1}` followed by the constraint projection).
    pub fn new(cfg: DfoConfig, d: usize) -> Self {
        let mut theta_tilde = vec![0.0; d + 1];
        theta_tilde[d] = -1.0;
        DfoOptimizer {
            rng: Xoshiro256::new(cfg.seed),
            cfg,
            theta_tilde,
            trace: Vec::new(),
            probes: Vec::new(),
            dirs: Vec::new(),
            risks: Vec::new(),
        }
    }

    /// Warm-start from an existing theta (length d).
    pub fn with_init(mut self, theta: &[f64]) -> Self {
        let d = self.theta_tilde.len() - 1;
        assert_eq!(theta.len(), d, "init theta must have length d");
        self.theta_tilde[..d].copy_from_slice(theta);
        self
    }

    /// Current feature-space parameter (length d, the last coordinate is
    /// the constant -1 constraint).
    pub fn theta(&self) -> &[f64] {
        &self.theta_tilde[..self.theta_tilde.len() - 1]
    }

    /// Full augmented parameter.
    pub fn theta_tilde(&self) -> &[f64] {
        &self.theta_tilde
    }

    /// Risk trace recorded during `run`.
    pub fn trace(&self) -> &[TracePoint] {
        &self.trace
    }

    /// Override the step size mid-run (custom schedules).
    pub fn set_step(&mut self, step: f64) {
        self.cfg.step = step;
    }

    /// One Algorithm-2 iteration against the oracle. Returns the mean
    /// probe risk — the Monte-Carlo estimate of the `sigma`-smoothed
    /// risk at the *pre-step* iterate (telemetry; the gradient uses only
    /// antithetic differences, so the incumbent `theta~` itself is never
    /// re-evaluated and a step costs exactly `k` oracle queries, one
    /// fewer than the seed's baseline-probing loop).
    ///
    /// The k queries are spent as k/2 *antithetic pairs* `theta +- sigma u`
    /// (central differences): sketch-estimate noise is correlated between
    /// the two sides, so the pairwise difference cancels most of it —
    /// markedly lower-variance than one-sided probing at the same query
    /// budget.
    pub fn step(&mut self, oracle: &dyn RiskOracle) -> f64 {
        let dim = self.theta_tilde.len();
        let pairs = (self.cfg.queries / 2).max(1);
        // Assemble the whole step as a CandidateSet — [+u_1, -u_1, ...]
        // relative to the shared base — and evaluate it through ONE
        // oracle.risk_candidates call: the incremental engine serves each
        // probe in O(R * p) with one projection per direction shared by
        // its antithetic pair; dense backends materialize vectors
        // bit-identical to the seed's explicit construction and run their
        // fused batch kernels.
        self.dirs.clear();
        self.probes.clear();
        for k in 0..pairs {
            let mut u = self.rng.sphere_vec(dim, 1.0);
            // Keep probes on the constraint surface: the last coordinate is
            // not a free parameter (Algorithm 2 projects it back), so
            // sampling it only injects variance.
            u[dim - 1] = 0.0;
            self.dirs.push(u);
            self.probes.push(Probe::Dir { dir: k, step: self.cfg.sigma });
            self.probes.push(Probe::Dir { dir: k, step: -self.cfg.sigma });
        }
        let set =
            CandidateSet { base: &self.theta_tilde, dirs: &self.dirs, probes: &self.probes };
        oracle.risk_candidates(&set, &mut self.risks);
        let mut grad = vec![0.0; dim];
        for (j, u) in self.dirs.iter().enumerate() {
            let delta = 0.5 * (self.risks[2 * j] - self.risks[2 * j + 1]);
            axpy(&mut grad, delta, u);
        }
        let scale = dim as f64 / (pairs as f64 * self.cfg.sigma);
        for g in &mut grad {
            *g *= scale;
        }
        let smoothed = self.risks.iter().sum::<f64>() / self.risks.len() as f64;
        // Gradient step + constraint projection.
        axpy(&mut self.theta_tilde, -self.cfg.step, &grad);
        self.theta_tilde[dim - 1] = -1.0;
        smoothed
    }

    /// Run `iters` iterations, then return the *tail average*
    /// (Polyak–Ruppert) of the last third of iterates — the standard
    /// variance-killer for stochastic convex optimization, which matters
    /// here because every risk readout carries sketch noise. (Selecting
    /// the minimum-risk iterate instead is badly biased: the minimum of
    /// hundreds of noisy readouts is dominated by noise, not progress —
    /// constant step + tail averaging empirically beats both best-iterate
    /// selection and `1/sqrt(t)` decay on the flat surrogate landscape;
    /// see EXPERIMENTS.md §Perf.)
    pub fn run(&mut self, oracle: &dyn RiskOracle, iters: usize) -> Vec<f64> {
        let d = self.theta_tilde.len() - 1;
        let tail_start = iters.saturating_sub((iters / 3).max(1));
        let mut tail_sum = vec![0.0; d];
        let mut tail_n = 0u64;
        for it in 0..iters {
            let risk = self.step(oracle);
            self.trace.push(TracePoint { iter: it, risk });
            if it >= tail_start {
                for (s, v) in tail_sum.iter_mut().zip(self.theta()) {
                    *s += v;
                }
                tail_n += 1;
            }
        }
        if tail_n > 0 {
            tail_sum.iter().map(|s| s / tail_n as f64).collect()
        } else {
            self.theta().to_vec()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::FnOracle;
    use crate::util::mathx::dot;

    /// Smooth convex quadratic with known minimum — checks the estimator
    /// and loop mechanics independent of sketches.
    fn quadratic_oracle(target: Vec<f64>) -> FnOracle<impl Fn(&[f64]) -> f64> {
        let d = target.len();
        FnOracle::new(d, move |tt: &[f64]| {
            tt[..d]
                .iter()
                .zip(&target)
                .map(|(a, b)| (a - b) * (a - b))
                .sum()
        })
    }

    #[test]
    fn converges_on_smooth_quadratic() {
        let target = vec![0.3, -0.2, 0.5];
        let oracle = quadratic_oracle(target.clone());
        let cfg = DfoConfig {
            queries: 8,
            sigma: 0.1,
            step: 0.05,
            iters: 400,
            seed: 1,
        };
        let mut opt = DfoOptimizer::new(cfg, 3);
        let theta = opt.run(&oracle, 400);
        for (a, b) in theta.iter().zip(&target) {
            assert!((a - b).abs() < 0.05, "theta={theta:?}");
        }
    }

    #[test]
    fn constraint_coordinate_stays_minus_one() {
        let oracle = quadratic_oracle(vec![0.1, 0.1]);
        let cfg = DfoConfig { queries: 4, sigma: 0.2, step: 0.1, iters: 10, seed: 2 };
        let mut opt = DfoOptimizer::new(cfg, 2);
        for _ in 0..10 {
            opt.step(&oracle);
            assert_eq!(*opt.theta_tilde().last().unwrap(), -1.0);
        }
    }

    #[test]
    fn trace_is_recorded_and_decreasing_overall() {
        let oracle = quadratic_oracle(vec![0.4, 0.4, -0.4, 0.2]);
        let cfg = DfoConfig { queries: 8, sigma: 0.1, step: 0.05, iters: 200, seed: 3 };
        let mut opt = DfoOptimizer::new(cfg, 4);
        let _ = opt.run(&oracle, 200);
        let trace = opt.trace();
        assert_eq!(trace.len(), 200);
        let early: f64 = trace[..20].iter().map(|t| t.risk).sum::<f64>() / 20.0;
        let late: f64 = trace[trace.len() - 20..].iter().map(|t| t.risk).sum::<f64>() / 20.0;
        assert!(late < early * 0.5, "early={early} late={late}");
    }

    #[test]
    fn deterministic_given_seed() {
        let oracle = quadratic_oracle(vec![0.2, -0.3]);
        let cfg = DfoConfig { queries: 4, sigma: 0.2, step: 0.1, iters: 50, seed: 9 };
        let t1 = DfoOptimizer::new(cfg, 2).run(&oracle, 50);
        let t2 = DfoOptimizer::new(cfg, 2).run(&oracle, 50);
        assert_eq!(t1, t2);
    }

    #[test]
    fn warm_start_respected() {
        let cfg = DfoConfig { queries: 4, sigma: 0.2, step: 0.1, iters: 1, seed: 4 };
        let opt = DfoOptimizer::new(cfg, 3).with_init(&[0.5, 0.6, 0.7]);
        assert_eq!(opt.theta(), &[0.5, 0.6, 0.7]);
        assert_eq!(*opt.theta_tilde().last().unwrap(), -1.0);
    }

    #[test]
    fn oracle_eval_budget_per_step() {
        let oracle = quadratic_oracle(vec![0.0, 0.0]);
        let cfg = DfoConfig { queries: 8, sigma: 0.2, step: 0.1, iters: 1, seed: 5 };
        let mut opt = DfoOptimizer::new(cfg, 2);
        opt.step(&oracle);
        // Exactly k probes (k/2 antithetic pairs) — the incumbent is
        // never re-evaluated, so there is no baseline query.
        assert_eq!(oracle.evals(), 8);
    }

    #[test]
    fn minimizes_prp_surrogate_toward_ls_solution() {
        // End-to-end on the *exact* surrogate (no sketch noise): the
        // minimizer should align with the planted regression model.
        use crate::loss::prp_loss::exact_surrogate_risk;
        use crate::util::rng::{Rng, Xoshiro256};
        let mut rng = Xoshiro256::new(6);
        let d = 3;
        let theta_star = vec![0.4, -0.3, 0.2];
        let examples: Vec<Vec<f64>> = (0..200)
            .map(|_| {
                let x: Vec<f64> = (0..d).map(|_| rng.uniform_range(-0.4, 0.4)).collect();
                let y = dot(&x, &theta_star);
                let mut z = x;
                z.push(y);
                z
            })
            .collect();
        let oracle = FnOracle::new(d, move |tt: &[f64]| exact_surrogate_risk(tt, &examples, 4));
        let cfg = DfoConfig { queries: 10, sigma: 0.1, step: 1.5, iters: 600, seed: 7 };
        let mut opt = DfoOptimizer::new(cfg, d);
        let theta = opt.run(&oracle, 600);
        // Direction should align strongly with theta_star (the surrogate
        // loss is scale-sensitive through the query normalization, so we
        // check the fit through predictions):
        for (a, b) in theta.iter().zip(&theta_star) {
            assert!((a - b).abs() < 0.12, "theta={theta:?} vs {theta_star:?}");
        }
    }
}
