//! Linear optimization over hash partitions (paper §3).
//!
//! "Such methods attempt to place theta into the optimal set of hash
//! partitions. Linear optimization is possible when the hash function is
//! a projection-based LSH in R^d."
//!
//! Concretely: every STORM row partitions the augmented space with p
//! hyperplanes. For each row we identify the *lowest-count* bucket (the
//! PRP count is monotone in the surrogate loss, so low count = low loss)
//! and extract the sign pattern it corresponds to. Each (hyperplane, sign)
//! pair is a linear constraint `s * <w, aug(theta~)> >= 0`; we run a
//! count-weighted perceptron over all constraints to find a `theta` deep
//! inside the intersection of the most promising partitions. The result is
//! a strong initializer that DFO then refines — matching the paper's use
//! of linear optimization as an "improvement over standard derivative-free
//! methods".

use crate::lsh::asym::{augment, Side};
use crate::sketch::storm::StormSketch;
use crate::util::mathx::{dot, norm2};

/// One linear constraint in the *raw* augmented query space: we want
/// `sign * <plane, aug_query(theta~)> >= margin`, weighted by how much
/// better the target bucket is than the row average.
#[derive(Clone, Debug)]
struct Constraint {
    plane: Vec<f64>,
    sign: f64,
    weight: f64,
}

/// Configuration for the partition perceptron.
#[derive(Clone, Copy, Debug)]
pub struct LinOptConfig {
    /// Perceptron epochs over the constraint set.
    pub epochs: usize,
    /// Step size for constraint-violation updates.
    pub step: f64,
    /// Target query-ball radius (theta~ is renormalized to this).
    pub radius: f64,
}

impl Default for LinOptConfig {
    fn default() -> Self {
        LinOptConfig { epochs: 40, step: 0.1, radius: 0.7 }
    }
}

/// Extract constraints and run the perceptron. Returns `theta` (length d).
pub fn linear_partition_init(sketch: &StormSketch, cfg: LinOptConfig) -> Vec<f64> {
    let aug_dim = sketch.dim(); // d + 1
    let mut constraints: Vec<Constraint> = Vec::new();
    let grid = sketch.grid();
    for (r, h) in sketch.hashes().iter().enumerate() {
        let row = grid.row(r);
        let (best_bucket, best_count) = row
            .iter()
            .enumerate()
            .min_by_key(|(_, &c)| c)
            .map(|(b, &c)| (b, c))
            .unwrap();
        let mean_count = row.iter().map(|&c| c as f64).sum::<f64>() / row.len() as f64;
        let weight = (mean_count - best_count as f64).max(0.0);
        if weight == 0.0 {
            continue; // uninformative row
        }
        // The asymmetric SRP hashes aug(query) in R^{aug_dim + 2}; bit j of
        // the bucket is sign(<w_j, aug(q)>).
        for (j, plane) in h.asym().srp().planes().iter().enumerate() {
            let sign = if (best_bucket >> j) & 1 == 1 { 1.0 } else { -1.0 };
            constraints.push(Constraint { plane: plane.clone(), sign, weight });
        }
    }
    // Start from the constraint-respecting zero model [0...0, -1].
    let mut theta_tilde = vec![0.0; aug_dim];
    theta_tilde[aug_dim - 1] = -1.0;
    for _ in 0..cfg.epochs {
        let mut violated = 0usize;
        for c in &constraints {
            // Renormalize into the query ball before augmenting.
            let n = norm2(&theta_tilde);
            let scaled: Vec<f64> = if n > cfg.radius {
                theta_tilde.iter().map(|v| v * cfg.radius / n).collect()
            } else {
                theta_tilde.clone()
            };
            let aq = augment(&scaled, Side::Query);
            if c.sign * dot(&c.plane, &aq) < 0.0 {
                violated += 1;
                // Nudge the free coordinates toward satisfying the plane.
                for i in 0..aug_dim - 1 {
                    theta_tilde[i] += cfg.step * c.weight.min(4.0) * c.sign * c.plane[i];
                }
                theta_tilde[aug_dim - 1] = -1.0;
            }
        }
        if violated == 0 {
            break;
        }
    }
    // Normalize the perceptron output: only the *direction* of theta~ is
    // identified by partition constraints (the query is rescaled into the
    // unit ball anyway), and a large-norm init strands the downstream DFO
    // in the direction-only regime where magnitude is unidentifiable.
    let norm: f64 = theta_tilde[..aug_dim - 1]
        .iter()
        .map(|v| v * v)
        .sum::<f64>()
        .sqrt();
    if norm > 1.0 {
        for v in &mut theta_tilde[..aug_dim - 1] {
            *v /= norm;
        }
    }
    // Guarded init: the perceptron is a heuristic — keep its output only
    // if the sketch scores it *clearly* better than the zero model (the
    // margin guards against accepting pure estimator noise).
    let candidate = theta_tilde[..aug_dim - 1].to_vec();
    let mut zero_tilde = vec![0.0; aug_dim];
    zero_tilde[aug_dim - 1] = -1.0;
    let risk_candidate = sketch.estimate_risk_scaled(&theta_tilde);
    let risk_zero = sketch.estimate_risk_scaled(&zero_tilde);
    let noise_margin = 0.5 / (sketch.config().rows as f64).sqrt();
    if risk_candidate + noise_margin <= risk_zero {
        candidate
    } else {
        vec![0.0; aug_dim - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StormConfig;
    use crate::optim::dfo::{DfoConfig, DfoOptimizer};
    use crate::optim::RiskOracle;
    use crate::util::rng::{Rng, Xoshiro256};

    fn planted_sketch(seed: u64) -> (StormSketch, Vec<f64>) {
        let mut rng = Xoshiro256::new(seed);
        let d = 3;
        let theta_star = vec![0.3, -0.2, 0.25];
        let cfg = StormConfig { rows: 150, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, d + 1, seed);
        for _ in 0..1500 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform_range(-0.4, 0.4)).collect();
            let y = crate::util::mathx::dot(&x, &theta_star) + 0.01 * rng.gaussian();
            sk.insert_example(&x, y);
        }
        (sk, theta_star)
    }

    #[test]
    fn init_is_finite_and_right_length() {
        let (sk, _) = planted_sketch(1);
        let t = linear_partition_init(&sk, LinOptConfig::default());
        assert_eq!(t.len(), 3);
        assert!(t.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn init_lowers_risk_vs_zero() {
        let (sk, _) = planted_sketch(2);
        let d = 3;
        let mut zero_tilde = vec![0.0; d + 1];
        zero_tilde[d] = -1.0;
        let risk_zero = sk.risk(&zero_tilde);
        let init = linear_partition_init(&sk, LinOptConfig::default());
        let mut init_tilde = init.clone();
        init_tilde.push(-1.0);
        let risk_init = sk.risk(&init_tilde);
        assert!(
            risk_init <= risk_zero + 1e-9,
            "init risk {risk_init} > zero risk {risk_zero}"
        );
    }

    #[test]
    fn warm_started_dfo_at_least_as_good() {
        let (sk, _) = planted_sketch(3);
        let cfg = DfoConfig { queries: 8, sigma: 0.3, step: 0.4, iters: 60, seed: 5 };
        // Cold start.
        let mut cold = DfoOptimizer::new(cfg, 3);
        let t_cold = cold.run(&sk, 60);
        // Warm start from the partition perceptron.
        let init = linear_partition_init(&sk, LinOptConfig::default());
        let mut warm = DfoOptimizer::new(cfg, 3).with_init(&init);
        let t_warm = warm.run(&sk, 60);
        let risk_of = |t: &[f64]| {
            let mut tt = t.to_vec();
            tt.push(-1.0);
            sk.risk(&tt)
        };
        // Warm should not be dramatically worse; usually better. Allow
        // small slack since both are stochastic.
        assert!(risk_of(&t_warm) <= risk_of(&t_cold) * 1.25 + 1e-6);
    }
}
