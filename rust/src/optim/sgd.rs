//! Exact-gradient baselines: projected gradient descent on the exact PRP
//! surrogate risk and on the exact L2 risk. These are the "full data"
//! references the sketch-trained models are compared against (Figure 4's
//! "converges to the optimal theta under least-squares ERM" claim).

use crate::loss::prp_loss::exact_surrogate_grad;
use crate::util::mathx::axpy;

/// Configuration for the exact-gradient descent baselines.
#[derive(Clone, Copy, Debug)]
pub struct GdConfig {
    pub step: f64,
    pub iters: usize,
}

impl Default for GdConfig {
    fn default() -> Self {
        GdConfig { step: 1.0, iters: 500 }
    }
}

/// Projected GD on the exact PRP surrogate over augmented examples
/// (`z = [x, y]`, all inside the unit ball). Maintains the `theta~_{d+1} =
/// -1` constraint exactly like Algorithm 2, but with the true gradient.
pub fn gd_prp_surrogate(examples: &[Vec<f64>], p: u32, cfg: GdConfig) -> Vec<f64> {
    assert!(!examples.is_empty());
    let dim = examples[0].len();
    let mut theta_tilde = vec![0.0; dim];
    theta_tilde[dim - 1] = -1.0;
    for _ in 0..cfg.iters {
        // Rescale the query into the unit ball the same way the sketch
        // estimator does, so the two optimize the same landscape.
        let norm = crate::util::mathx::norm2(&theta_tilde);
        let radius = crate::data::scale::query_radius();
        let query: Vec<f64> = if norm > radius {
            theta_tilde.iter().map(|v| v * radius / norm).collect()
        } else {
            theta_tilde.clone()
        };
        let grad = exact_surrogate_grad(&query, examples, p);
        axpy(&mut theta_tilde, -cfg.step, &grad);
        theta_tilde[dim - 1] = -1.0;
    }
    theta_tilde[..dim - 1].to_vec()
}

/// Plain GD on the exact (unnormalized-by-scale) L2 risk over augmented
/// examples: gradient of `mean <theta~, z>^2` w.r.t. the free coords.
pub fn gd_l2(examples: &[Vec<f64>], cfg: GdConfig) -> Vec<f64> {
    assert!(!examples.is_empty());
    let dim = examples[0].len();
    let mut theta_tilde = vec![0.0; dim];
    theta_tilde[dim - 1] = -1.0;
    let n = examples.len() as f64;
    for _ in 0..cfg.iters {
        let mut grad = vec![0.0; dim];
        for z in examples {
            let t = crate::util::mathx::dot(&theta_tilde, z);
            axpy(&mut grad, 2.0 * t / n, z);
        }
        axpy(&mut theta_tilde, -cfg.step, &grad);
        theta_tilde[dim - 1] = -1.0;
    }
    theta_tilde[..dim - 1].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::linalg::solve::{lstsq, LstsqMethod};
    use crate::testing::assert_allclose;
    use crate::util::rng::{Rng, Xoshiro256};

    fn planted(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Xoshiro256::new(seed);
        let theta: Vec<f64> = (0..d).map(|_| rng.uniform_range(-0.3, 0.3)).collect();
        let examples: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let x: Vec<f64> = (0..d).map(|_| rng.uniform_range(-0.4, 0.4)).collect();
                let y = crate::util::mathx::dot(&x, &theta);
                let mut z = x;
                z.push(y);
                z
            })
            .collect();
        (examples, theta)
    }

    #[test]
    fn l2_gd_matches_closed_form() {
        let (examples, _) = planted(100, 3, 1);
        let x = Matrix::from_rows(
            &examples.iter().map(|z| z[..3].to_vec()).collect::<Vec<_>>(),
        );
        let y: Vec<f64> = examples.iter().map(|z| z[3]).collect();
        let closed = lstsq(&x, &y, 0.0, LstsqMethod::Qr);
        let gd = gd_l2(&examples, GdConfig { step: 0.5, iters: 3000 });
        assert_allclose(&gd, &closed, 1e-4);
    }

    #[test]
    fn surrogate_gd_recovers_planted_model() {
        let (examples, theta_star) = planted(300, 3, 2);
        let got = gd_prp_surrogate(&examples, 4, GdConfig { step: 2.0, iters: 2000 });
        for (a, b) in got.iter().zip(&theta_star) {
            assert!((a - b).abs() < 0.05, "{got:?} vs {theta_star:?}");
        }
    }

    #[test]
    fn surrogate_and_l2_minimizers_agree() {
        // Theorem 2: same minimizer (noise-free planted data).
        let (examples, _) = planted(300, 4, 3);
        let a = gd_prp_surrogate(&examples, 4, GdConfig { step: 2.0, iters: 2000 });
        let b = gd_l2(&examples, GdConfig { step: 0.5, iters: 3000 });
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 0.05, "{a:?} vs {b:?}");
        }
    }
}
