//! Optimization over sketches.
//!
//! STORM exposes the empirical risk only through *pointwise queries* of an
//! integer counter array — there is no analytic gradient. The paper
//! therefore trains with derivative-free optimization (Algorithm 2), which
//! this module implements, plus the linear-optimization refinement of §3
//! and exact-gradient baselines for comparison.
//!
//! Everything optimizes a [`RiskOracle`] — the sketch, a composite of
//! sketches, an exact loss, or the AOT-compiled XLA query path all
//! implement it, so the optimizer code is shared across all backends.
//!
//! **The `CandidateSet` contract.** Optimizer steps submit whole
//! candidate sets as a [`CandidateSet`]: a shared base iterate plus
//! [`Probe`]s describing each candidate relative to it (the base itself,
//! one coordinate set to a value, or `base + c * u` along a direction).
//! [`RiskOracle::risk_candidates`] is the single entry point: the
//! default materializes the dense candidates (bit-identical to the
//! vectors the optimizers used to build) and calls
//! [`RiskOracle::risk_batch`], while [`IncrementalOracle`] routes the
//! set through the rank-1 incremental query engine
//! ([`crate::lsh::query`]) — `O(R * p)` per probe instead of
//! `O(R * p * d)` — falling back to dense materialization when
//! `STORM_QUERY_INCREMENTAL=off`. The incremental path is exact up to
//! measure-zero floating-point bucket ties (see the `lsh::query` module
//! docs for when it is bit-identical).

pub mod dfo;
pub mod coord;
pub mod spsa;
pub mod sgd;
pub mod linopt;
pub mod schedule;

use std::cell::{Cell, RefCell};

pub use crate::lsh::query::{CandidateSet, Probe};
use crate::lsh::query::{incremental_enabled, QueryEngine};
use crate::sketch::model::StormModel;
use crate::sketch::storm::{StormClassifierSketch, StormSketch};
use crate::sketch::RiskSketch;

/// Black-box access to an empirical-risk estimate at `theta~ = [theta, -1]`.
pub trait RiskOracle {
    /// Estimated risk at the *augmented* parameter vector (length `d + 1`,
    /// last coordinate fixed to -1 by convention; implementations rescale
    /// into the unit ball internally as needed).
    fn risk(&self, theta_tilde: &[f64]) -> f64;

    /// Feature dimension `d` (so `theta~` has length `d + 1`).
    fn dim(&self) -> usize;

    /// Number of oracle evaluations so far, if tracked (telemetry).
    fn evals(&self) -> u64 {
        0
    }

    /// Batched risk evaluation: one estimate per candidate, in order,
    /// written into `out` (cleared first). The default is a scalar loop;
    /// backends with a fused batch path (the sketch's projection bank,
    /// the XLA query executable) override it, so optimizers that submit
    /// whole candidate sets get the batched hot path on every backend.
    fn risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>) {
        out.clear();
        out.extend(candidates.iter().map(|q| self.risk(q)));
    }

    /// Evaluate a whole optimizer step's candidate set, one risk per
    /// probe in order, written into `out` (cleared first). The default
    /// materializes the dense candidates — reproducing exactly the
    /// vectors the optimizers built before the incremental engine — and
    /// submits them through [`Self::risk_batch`];
    /// [`IncrementalOracle`] overrides this with the rank-1 path.
    fn risk_candidates(&self, set: &CandidateSet, out: &mut Vec<f64>) {
        let mut dense = Vec::new();
        set.materialize(&mut dense);
        self.risk_batch(&dense, out);
    }
}

impl RiskOracle for StormSketch {
    fn risk(&self, theta_tilde: &[f64]) -> f64 {
        self.estimate_risk_scaled(theta_tilde)
    }

    fn dim(&self) -> usize {
        // Sketch dim is d + 1 (augmented).
        StormSketch::dim(self) - 1
    }

    /// Candidate sets go through the fused hash-bank query kernel:
    /// scratch-buffer reuse, no per-candidate allocation, bit-identical
    /// estimates to the scalar path.
    fn risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>) {
        self.estimate_risk_batch(candidates, out);
    }
}

/// The classifier sketch is a first-class risk oracle (Theorem 3's margin
/// loss): the same DFO / coordinate-descent / SPSA loops that train
/// regression models drive it, over `theta~ = [theta, -1]` whose trailing
/// constraint coordinate the margin estimate simply ignores (the
/// classifier's hyperplane passes through the origin).
impl RiskOracle for StormClassifierSketch {
    fn risk(&self, theta_tilde: &[f64]) -> f64 {
        RiskSketch::estimate_risk_scaled(self, theta_tilde)
    }

    fn dim(&self) -> usize {
        self.feature_dim()
    }

    /// Candidate sets go through the fused single-arm bank query kernel
    /// with scratch reuse — bit-identical to scalar estimates.
    fn risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>) {
        RiskSketch::estimate_risk_batch(self, candidates, out);
    }
}

/// Task-generic oracle: whatever task a [`StormModel`] was built for, the
/// optimizers see one uniform risk surface.
impl RiskOracle for StormModel {
    fn risk(&self, theta_tilde: &[f64]) -> f64 {
        RiskSketch::estimate_risk_scaled(self, theta_tilde)
    }

    fn dim(&self) -> usize {
        self.example_dim() - 1
    }

    fn risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>) {
        RiskSketch::estimate_risk_batch(self, candidates, out);
    }
}

/// A [`RiskSketch`] wrapped with the rank-1 incremental query engine
/// ([`crate::lsh::query::QueryEngine`]): candidate sets are served as
/// `O(R * p)` per-probe updates of the cached base projections instead
/// of dense `O(R * p * d)` re-projections. Scalar and batched queries
/// delegate to the model unchanged, so wrapping is free for everything
/// except [`RiskOracle::risk_candidates`]. The engine needs interior
/// mutability (`&self` oracle calls), which is why this lives in a
/// wrapper instead of inside the sketch — the sketch itself stays `Sync`
/// for the fleet executors' scoped threads.
///
/// With `STORM_QUERY_INCREMENTAL=off` the wrapper materializes densely
/// (into a reused scratch) and is bit-identical to the unwrapped model.
pub struct IncrementalOracle<'a, M: RiskSketch> {
    model: &'a M,
    engine: RefCell<QueryEngine>,
    dense: RefCell<Vec<Vec<f64>>>,
    evals: Cell<u64>,
}

impl<'a, M: RiskSketch> IncrementalOracle<'a, M> {
    /// Wrap `model`, binding an engine to its hash bank.
    pub fn new(model: &'a M) -> Self {
        IncrementalOracle {
            engine: RefCell::new(QueryEngine::new(model.bank())),
            model,
            dense: RefCell::new(Vec::new()),
            evals: Cell::new(0),
        }
    }

    /// The wrapped model.
    pub fn model(&self) -> &M {
        self.model
    }
}

impl<M: RiskSketch> RiskOracle for IncrementalOracle<'_, M> {
    fn risk(&self, theta_tilde: &[f64]) -> f64 {
        self.evals.set(self.evals.get() + 1);
        self.model.estimate_risk_scaled(theta_tilde)
    }

    fn dim(&self) -> usize {
        self.model.example_dim() - 1
    }

    fn evals(&self) -> u64 {
        self.evals.get()
    }

    fn risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>) {
        self.evals.set(self.evals.get() + candidates.len() as u64);
        self.model.estimate_risk_batch(candidates, out);
    }

    fn risk_candidates(&self, set: &CandidateSet, out: &mut Vec<f64>) {
        self.evals.set(self.evals.get() + set.len() as u64);
        if incremental_enabled() {
            let mut engine = self.engine.borrow_mut();
            self.model.estimate_risk_candidates(&mut engine, set, out);
        } else {
            let mut dense = self.dense.borrow_mut();
            set.materialize(&mut dense);
            self.model.estimate_risk_batch(&dense, out);
        }
    }
}

/// Adapter: any closure `Fn(&[f64]) -> f64` as a risk oracle (used for
/// composite sketches, exact losses, and the XLA runtime query path).
pub struct FnOracle<F: Fn(&[f64]) -> f64> {
    f: F,
    d: usize,
    evals: std::cell::Cell<u64>,
}

impl<F: Fn(&[f64]) -> f64> FnOracle<F> {
    /// `d` is the feature dimension (oracle receives `d + 1` vectors).
    pub fn new(d: usize, f: F) -> Self {
        FnOracle { f, d, evals: std::cell::Cell::new(0) }
    }
}

impl<F: Fn(&[f64]) -> f64> RiskOracle for FnOracle<F> {
    fn risk(&self, theta_tilde: &[f64]) -> f64 {
        self.evals.set(self.evals.get() + 1);
        (self.f)(theta_tilde)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn evals(&self) -> u64 {
        self.evals.get()
    }
}
