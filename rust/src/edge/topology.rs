//! Aggregation topologies: how device sketches flow to the leader.
//!
//! The paper imagines devices "propagating their sketches along the edges
//! of a communication network". Because merge is associative and
//! commutative, *any* aggregation tree yields identical counters — the
//! topologies differ only in link traffic and stall profile, which is
//! exactly what the fleet benchmarks measure.

/// Supported aggregation shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Every device sends directly to the leader.
    Star,
    /// Balanced aggregation tree with the given fanout; internal
    /// aggregator nodes merge children before forwarding upstream.
    Tree { fanout: usize },
    /// Devices form a chain; each forwards its merged prefix downstream
    /// (the paper's "propagate along the edges" picture).
    Chain,
    /// Multi-level aggregation tree whose *every* node — including the
    /// leader — has at most `max_fan_in` children, so no node ever
    /// buffers more than `max_fan_in` in-flight deltas regardless of
    /// fleet size. This is the million-device shape: depth grows as
    /// log_{fan_in}(n) while per-node memory stays constant.
    Deep { max_fan_in: usize },
}

/// One aggregation stage: the devices/aggregators at `children` feed the
/// node `parent`. Leader is node index `usize::MAX`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Stage {
    pub parent: usize,
    pub children: Vec<usize>,
}

/// The leader's pseudo-node id.
pub const LEADER: usize = usize::MAX;

/// Build the aggregation plan for `n` devices. Returns stages in
/// evaluation order (children of a later stage may be aggregator outputs
/// of earlier stages, identified by ids >= n).
pub fn plan(topology: Topology, n: usize) -> Vec<Stage> {
    assert!(n > 0);
    match topology {
        Topology::Star => vec![Stage { parent: LEADER, children: (0..n).collect() }],
        Topology::Chain => {
            // device 0 -> 1 -> ... -> n-1 -> leader; stage i merges node
            // (i-1)'s running aggregate with device i. We model it as each
            // consecutive pair producing an aggregator node.
            let mut stages = Vec::new();
            let mut upstream = 0usize; // running aggregate starts at device 0
            let mut next_agg = n;
            for dev in 1..n {
                stages.push(Stage { parent: next_agg, children: vec![upstream, dev] });
                upstream = next_agg;
                next_agg += 1;
            }
            stages.push(Stage { parent: LEADER, children: vec![upstream] });
            stages
        }
        // A deep tree is a balanced tree whose cap applies to every
        // node including the leader; the chunk planner below already
        // guarantees that (the final stage has at most `fanout`
        // children), so the two shapes share one implementation and
        // `Deep` exists as the named million-device spelling.
        Topology::Tree { fanout } | Topology::Deep { max_fan_in: fanout } => {
            assert!(fanout >= 2, "tree fan-in must be >= 2");
            let mut level: Vec<usize> = (0..n).collect();
            let mut next_agg = n;
            let mut stages = Vec::new();
            while level.len() > fanout {
                let mut next_level = Vec::new();
                for chunk in level.chunks(fanout) {
                    if chunk.len() == 1 {
                        next_level.push(chunk[0]);
                    } else {
                        stages.push(Stage { parent: next_agg, children: chunk.to_vec() });
                        next_level.push(next_agg);
                        next_agg += 1;
                    }
                }
                level = next_level;
            }
            stages.push(Stage { parent: LEADER, children: level });
            stages
        }
    }
}

/// Total number of aggregator (non-device, non-leader) nodes in a plan.
pub fn aggregator_count(stages: &[Stage]) -> usize {
    stages.iter().filter(|s| s.parent != LEADER).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    fn devices_covered(stages: &[Stage], n: usize) -> bool {
        // Every device id < n appears exactly once as a child across all
        // stages; every aggregator output feeds exactly one parent.
        let mut seen = BTreeSet::new();
        for s in stages {
            for &c in &s.children {
                assert!(seen.insert(c), "node {c} consumed twice");
            }
        }
        (0..n).all(|d| seen.contains(&d))
    }

    #[test]
    fn star_single_stage() {
        let p = plan(Topology::Star, 5);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].parent, LEADER);
        assert!(devices_covered(&p, 5));
        assert_eq!(aggregator_count(&p), 0);
    }

    #[test]
    fn chain_has_n_minus_1_aggregators() {
        let p = plan(Topology::Chain, 4);
        assert!(devices_covered(&p, 4));
        assert_eq!(aggregator_count(&p), 3);
        assert_eq!(p.last().unwrap().parent, LEADER);
    }

    #[test]
    fn tree_reduces_to_leader() {
        let p = plan(Topology::Tree { fanout: 2 }, 8);
        assert!(devices_covered(&p, 8));
        // 8 leaves, fanout 2: 4 + 2 internal aggregators, final stage of 2.
        assert_eq!(aggregator_count(&p), 6);
        assert_eq!(p.last().unwrap().parent, LEADER);
        assert!(p.last().unwrap().children.len() <= 2);
    }

    #[test]
    fn tree_with_small_n_is_single_stage() {
        let p = plan(Topology::Tree { fanout: 4 }, 3);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].parent, LEADER);
    }

    #[test]
    fn deep_tree_bounds_every_node_including_leader() {
        for (n, cap) in [(1usize, 2usize), (7, 2), (64, 4), (1000, 8), (4097, 16)] {
            let p = plan(Topology::Deep { max_fan_in: cap }, n);
            assert!(devices_covered(&p, n), "n={n} cap={cap}");
            for s in &p {
                assert!(
                    s.children.len() <= cap,
                    "node {} has {} children (cap {cap}, n={n})",
                    s.parent,
                    s.children.len()
                );
                assert!(s.children.len() >= 1);
            }
            assert_eq!(p.last().unwrap().parent, LEADER);
        }
    }

    #[test]
    fn deep_tree_matches_tree_of_same_fan_in() {
        for n in [1usize, 5, 33, 260] {
            assert_eq!(
                plan(Topology::Deep { max_fan_in: 4 }, n),
                plan(Topology::Tree { fanout: 4 }, n)
            );
        }
    }

    #[test]
    fn single_device_plans() {
        for t in [
            Topology::Star,
            Topology::Chain,
            Topology::Tree { fanout: 2 },
            Topology::Deep { max_fan_in: 2 },
        ] {
            let p = plan(t, 1);
            assert_eq!(p.last().unwrap().parent, LEADER);
            assert!(devices_covered(&p, 1));
        }
    }
}
