//! Energy accounting — the paper's motivating claim is that shipping a
//! tiny sketch beats shipping raw data on transmit energy ("data transfer
//! is an energy intensive procedure"; Broader Impacts). This model uses
//! standard first-order constants for wireless edge hardware and exposes
//! the sketch-vs-raw comparison the `energy` experiment reports.

/// Energy model constants (first-order, typical LTE-class radio + MCU).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// Joules per byte transmitted (LTE cat-M1 class: ~1-5 uJ/bit).
    pub tx_j_per_byte: f64,
    /// Joules per sketch insert (a few hundred flops on an MCU).
    pub insert_j: f64,
    /// Joules per derivative-free query evaluation.
    pub query_j: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            tx_j_per_byte: 20e-6,  // 2.5 uJ/bit
            insert_j: 2e-6,
            query_j: 2e-6,
        }
    }
}

/// Energy breakdown for one strategy.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyReport {
    pub tx_joules: f64,
    pub compute_joules: f64,
}

impl EnergyReport {
    pub fn total(&self) -> f64 {
        self.tx_joules + self.compute_joules
    }
}

impl EnergyModel {
    /// Energy for the STORM strategy: sketch locally (`inserts`), transmit
    /// `sketch_bytes` total over the network.
    pub fn storm_energy(&self, inserts: u64, sketch_bytes: u64) -> EnergyReport {
        EnergyReport {
            tx_joules: sketch_bytes as f64 * self.tx_j_per_byte,
            compute_joules: inserts as f64 * self.insert_j,
        }
    }

    /// Energy for the cloud strategy: transmit every raw example.
    pub fn raw_energy(&self, raw_bytes: u64) -> EnergyReport {
        EnergyReport {
            tx_joules: raw_bytes as f64 * self.tx_j_per_byte,
            compute_joules: 0.0,
        }
    }

    /// Ratio raw/storm (>1 means STORM wins).
    pub fn savings_ratio(&self, inserts: u64, sketch_bytes: u64, raw_bytes: u64) -> f64 {
        let s = self.storm_energy(inserts, sketch_bytes).total();
        if s == 0.0 {
            return f64::INFINITY;
        }
        self.raw_energy(raw_bytes).total() / s
    }

    /// Transmit energy for `flushes` dense delta frames of `cfg` at its
    /// *native* counter width (width-true wire accounting — a `u8` tier
    /// frame is ~a quarter of the `u32` frame, see
    /// [`crate::sketch::serialize::delta_wire_bytes`]).
    pub fn flush_tx_energy(&self, cfg: &crate::config::StormConfig, flushes: u64) -> f64 {
        let frame = crate::sketch::serialize::delta_wire_bytes(cfg) as u64;
        (flushes * frame) as f64 * self.tx_j_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sketch_beats_raw_for_large_streams() {
        let m = EnergyModel::default();
        // 1M examples x 22 dims x 8B raw vs one 6.4KB sketch shipped 100x.
        let raw_bytes = 1_000_000u64 * 22 * 8;
        let sketch_bytes = 6_400u64 * 100;
        let ratio = m.savings_ratio(1_000_000, sketch_bytes, raw_bytes);
        assert!(ratio > 10.0, "ratio={ratio}");
    }

    #[test]
    fn tiny_streams_may_not_benefit() {
        let m = EnergyModel::default();
        // 10 examples: shipping raw is cheaper than one sketch flush.
        let raw_bytes = 10u64 * 22 * 8;
        let sketch_bytes = 6_400u64;
        assert!(m.savings_ratio(10, sketch_bytes, raw_bytes) < 1.0);
    }

    #[test]
    fn flush_energy_is_width_true() {
        use crate::config::{CounterWidth, StormConfig};
        let m = EnergyModel::default();
        let at = |w: CounterWidth| {
            m.flush_tx_energy(
                &StormConfig {
                    rows: 100,
                    power: 4,
                    saturating: true,
                    counter_width: w,
                    ..Default::default()
                },
                100,
            )
        };
        // 1600 cells: the payload scales 1:2:4 with the width; only the
        // fixed per-frame framing keeps the ratios from being exact.
        assert!(at(CounterWidth::U8) < at(CounterWidth::U16));
        assert!(at(CounterWidth::U16) < at(CounterWidth::U32));
        let (u8_e, u32_e) = (at(CounterWidth::U8), at(CounterWidth::U32));
        assert!(u8_e < 0.3 * u32_e, "u8 {u8_e} vs u32 {u32_e}");
    }

    #[test]
    fn totals_add_up() {
        let m = EnergyModel::default();
        let r = m.storm_energy(1000, 5000);
        assert!((r.total() - (r.tx_joules + r.compute_joules)).abs() < 1e-18);
        assert!(r.tx_joules > 0.0 && r.compute_joules > 0.0);
    }
}
