//! Simulated network links: bounded channels (backpressure) with explicit
//! latency/bandwidth cost models and transfer accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Duration;

/// Messages devices send upstream.
#[derive(Debug)]
pub enum Message {
    /// A serialized sketch delta (wire format of `sketch::serialize`).
    Delta(Vec<u8>),
    /// Device finished its stream after ingesting `examples`.
    Done { device_id: usize, examples: u64 },
}

impl Message {
    /// Bytes this message occupies on the wire (header-free model: deltas
    /// dominate; Done is a 16-byte control frame).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Message::Delta(b) => b.len(),
            Message::Done { .. } => 16,
        }
    }
}

/// Shared transfer statistics for one link.
#[derive(Debug, Default)]
pub struct LinkStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
    /// Nanoseconds spent blocked on a full channel (backpressure stalls).
    pub blocked_ns: AtomicU64,
    /// Sends that found the channel full at first attempt.
    pub backpressure_events: AtomicU64,
}

impl LinkStats {
    pub fn snapshot(&self) -> LinkSnapshot {
        LinkSnapshot {
            messages: self.messages.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            blocked_ns: self.blocked_ns.load(Ordering::Relaxed),
            backpressure_events: self.backpressure_events.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of link stats.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkSnapshot {
    pub messages: u64,
    pub bytes: u64,
    pub blocked_ns: u64,
    pub backpressure_events: u64,
}

impl LinkSnapshot {
    pub fn merge(&mut self, other: &LinkSnapshot) {
        self.messages += other.messages;
        self.bytes += other.bytes;
        self.blocked_ns += other.blocked_ns;
        self.backpressure_events += other.backpressure_events;
    }
}

/// Sending half of a simulated link.
pub struct Link {
    tx: SyncSender<Message>,
    stats: Arc<LinkStats>,
    latency: Duration,
    /// Bytes per second; 0 = infinite.
    bandwidth_bps: u64,
}

impl Link {
    /// Create a link with the given bounded capacity. Returns the sender
    /// (with cost model) and the raw receiver for the aggregator side.
    pub fn new(
        capacity: usize,
        latency_us: u64,
        bandwidth_bps: u64,
    ) -> (Link, Receiver<Message>, Arc<LinkStats>) {
        let (tx, rx) = std::sync::mpsc::sync_channel(capacity);
        let stats = Arc::new(LinkStats::default());
        (
            Link {
                tx,
                stats: stats.clone(),
                latency: Duration::from_micros(latency_us),
                bandwidth_bps,
            },
            rx,
            stats,
        )
    }

    /// Send with simulated transfer cost. Blocks when the receiver is
    /// backed up (bounded channel) — that block *is* the backpressure the
    /// fleet config's `channel_capacity` controls.
    pub fn send(&self, msg: Message) -> Result<(), ()> {
        let bytes = msg.wire_bytes();
        // Pay the wire cost.
        let mut cost = self.latency;
        if self.bandwidth_bps > 0 {
            cost += Duration::from_secs_f64(bytes as f64 / self.bandwidth_bps as f64);
        }
        if !cost.is_zero() {
            std::thread::sleep(cost);
        }
        // Try fast path, fall back to blocking and time the stall.
        let msg = match self.tx.try_send(msg) {
            Ok(()) => {
                self.account(bytes);
                return Ok(());
            }
            Err(TrySendError::Full(m)) => {
                self.stats
                    .backpressure_events
                    .fetch_add(1, Ordering::Relaxed);
                m
            }
            Err(TrySendError::Disconnected(_)) => return Err(()),
        };
        let t = std::time::Instant::now();
        let result = self.tx.send(msg).map_err(|_| ());
        self.stats
            .blocked_ns
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if result.is_ok() {
            self.account(bytes);
        }
        result
    }

    fn account(&self, bytes: usize) {
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn stats(&self) -> Arc<LinkStats> {
        self.stats.clone()
    }
}

impl Clone for Link {
    fn clone(&self) -> Self {
        Link {
            tx: self.tx.clone(),
            stats: self.stats.clone(),
            latency: self.latency,
            bandwidth_bps: self.bandwidth_bps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_accounts_bytes_and_messages() {
        let (link, rx, stats) = Link::new(4, 0, 0);
        link.send(Message::Delta(vec![0u8; 100])).unwrap();
        link.send(Message::Done { device_id: 0, examples: 5 }).unwrap();
        let snap = stats.snapshot();
        assert_eq!(snap.messages, 2);
        assert_eq!(snap.bytes, 116);
        drop(link);
        assert_eq!(rx.iter().count(), 2);
    }

    #[test]
    fn disconnected_receiver_errors() {
        let (link, rx, _) = Link::new(1, 0, 0);
        drop(rx);
        assert!(link.send(Message::Delta(vec![1])).is_err());
    }

    #[test]
    fn backpressure_blocks_until_drained() {
        let (link, rx, stats) = Link::new(1, 0, 0);
        link.send(Message::Delta(vec![0u8; 10])).unwrap();
        // Next send must block until the consumer drains; do it from a
        // thread and drain after a delay.
        let handle = std::thread::spawn(move || {
            link.send(Message::Delta(vec![0u8; 10])).unwrap();
        });
        std::thread::sleep(Duration::from_millis(20));
        let _ = rx.recv().unwrap();
        handle.join().unwrap();
        let snap = stats.snapshot();
        assert_eq!(snap.backpressure_events, 1);
        assert!(snap.blocked_ns > 5_000_000, "blocked {}ns", snap.blocked_ns);
        let _ = rx.recv().unwrap();
    }

    #[test]
    fn latency_model_delays_send() {
        let (link, _rx, _) = Link::new(8, 20_000, 0); // 20ms
        let t = std::time::Instant::now();
        link.send(Message::Delta(vec![0u8; 1])).unwrap();
        assert!(t.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn bandwidth_model_scales_with_bytes() {
        let (link, _rx, _) = Link::new(8, 0, 1_000_000); // 1 MB/s
        let t = std::time::Instant::now();
        link.send(Message::Delta(vec![0u8; 50_000])).unwrap(); // 50ms
        assert!(t.elapsed() >= Duration::from_millis(45));
    }
}
