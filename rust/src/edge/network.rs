//! Simulated network links: bounded channels (backpressure) with explicit
//! latency/bandwidth cost models and transfer accounting — total and
//! broken down per sync round (epoch), which is what the communication-
//! vs-rounds experiments read.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Messages devices and aggregators send upstream.
#[derive(Clone, Debug)]
pub enum Message {
    /// A serialized sketch delta for one sync round (wire format v2 of
    /// `sketch::serialize`, v1 accepted for backward compatibility).
    /// `from` identifies the sending node (device or aggregator id) so
    /// receivers can deduplicate replayed frames: the exactly-once fold
    /// key is `(from, epoch)` — a sender never reuses an epoch tag for
    /// two different delta payloads (see `edge::faults` module docs).
    /// The payload is reference-counted so chaos duplicates, multi-child
    /// fan-out, and retry-until-confirmed re-sends share one frame
    /// allocation instead of cloning the bytes per copy.
    Delta { from: usize, epoch: u64, payload: Arc<[u8]> },
    /// Sender finished sync round `epoch` after ingesting `examples`
    /// within that round. One per round per child — the upstream barrier
    /// counts these.
    EndRound { device_id: usize, epoch: u64, examples: u64 },
    /// Sender finished its stream for good after ingesting `examples`.
    Done { device_id: usize, examples: u64 },
}

impl Message {
    /// Bytes this message occupies on the wire (header-free model: deltas
    /// dominate; EndRound is a 24-byte and Done a 16-byte control frame).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Message::Delta { payload, .. } => payload.len(),
            Message::EndRound { .. } => 24,
            Message::Done { .. } => 16,
        }
    }

    /// The sync round this message belongs to (None for stream-final
    /// control frames).
    pub fn epoch(&self) -> Option<u64> {
        match self {
            Message::Delta { epoch, .. } | Message::EndRound { epoch, .. } => Some(*epoch),
            Message::Done { .. } => None,
        }
    }
}

/// Traffic attributed to one sync round on one link.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RoundTraffic {
    pub messages: u64,
    pub bytes: u64,
    /// Bytes of catch-up traffic within this round: delta frames that
    /// carry increments from *earlier* epochs (retransmission after a
    /// drop, a straggler's deferred round, or a crash-recovery
    /// multi-epoch delta). Always `<= bytes` for the round.
    pub retransmit_bytes: u64,
}

/// Shared transfer statistics for one link.
#[derive(Debug, Default)]
pub struct LinkStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
    /// Nanoseconds spent blocked on a full channel (backpressure stalls).
    pub blocked_ns: AtomicU64,
    /// Sends that found the channel full at first attempt.
    pub backpressure_events: AtomicU64,
    /// Per-epoch traffic (epoch-tagged messages only; Done frames carry
    /// no epoch and land in the totals alone).
    rounds: Mutex<BTreeMap<u64, RoundTraffic>>,
}

impl LinkStats {
    pub fn snapshot(&self) -> LinkSnapshot {
        LinkSnapshot {
            messages: self.messages.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            blocked_ns: self.blocked_ns.load(Ordering::Relaxed),
            backpressure_events: self.backpressure_events.load(Ordering::Relaxed),
            rounds: self.rounds.lock().expect("link stats lock").clone(),
        }
    }
}

/// Plain-data copy of link stats.
#[derive(Clone, Debug, Default)]
pub struct LinkSnapshot {
    pub messages: u64,
    pub bytes: u64,
    pub blocked_ns: u64,
    pub backpressure_events: u64,
    /// Traffic per sync round, keyed by epoch.
    pub rounds: BTreeMap<u64, RoundTraffic>,
}

impl LinkSnapshot {
    pub fn merge(&mut self, other: &LinkSnapshot) {
        self.messages += other.messages;
        self.bytes += other.bytes;
        self.blocked_ns += other.blocked_ns;
        self.backpressure_events += other.backpressure_events;
        for (&epoch, t) in &other.rounds {
            let e = self.rounds.entry(epoch).or_default();
            e.messages += t.messages;
            e.bytes += t.bytes;
            e.retransmit_bytes += t.retransmit_bytes;
        }
    }

    /// Bytes attributed to one sync round across this snapshot.
    pub fn round_bytes(&self, epoch: u64) -> u64 {
        self.rounds.get(&epoch).map_or(0, |t| t.bytes)
    }

    /// Catch-up (retransmission) bytes attributed to one sync round.
    pub fn round_retransmit_bytes(&self, epoch: u64) -> u64 {
        self.rounds.get(&epoch).map_or(0, |t| t.retransmit_bytes)
    }

    /// Total catch-up bytes across every round.
    pub fn retransmit_bytes(&self) -> u64 {
        self.rounds.values().map(|t| t.retransmit_bytes).sum()
    }
}

/// Where a link's frames land: a bounded channel (the thread-per-node
/// runtime, with real backpressure) or a caller-drained outbox queue
/// (the worker-pool executor — unbounded, drained deterministically at
/// every scheduling step, so a send never blocks).
#[derive(Clone)]
enum Sink {
    Channel(SyncSender<Message>),
    Queue(Outbox),
}

/// A caller-drained message queue: the receiving half of a queue-backed
/// [`Link`] (see [`Link::queue`]).
pub type Outbox = Arc<Mutex<Vec<Message>>>;

/// Sending half of a simulated link.
pub struct Link {
    sink: Sink,
    stats: Arc<LinkStats>,
    latency: Duration,
    /// Bytes per second; 0 = infinite.
    bandwidth_bps: u64,
}

impl Link {
    /// Create a link with the given bounded capacity. Returns the sender
    /// (with cost model) and the raw receiver for the aggregator side.
    pub fn new(
        capacity: usize,
        latency_us: u64,
        bandwidth_bps: u64,
    ) -> (Link, Receiver<Message>, Arc<LinkStats>) {
        let (tx, rx) = std::sync::mpsc::sync_channel(capacity);
        let stats = Arc::new(LinkStats::default());
        (
            Link {
                sink: Sink::Channel(tx),
                stats: stats.clone(),
                latency: Duration::from_micros(latency_us),
                bandwidth_bps,
            },
            rx,
            stats,
        )
    }

    /// Create a queue-backed link for the cooperative executor: sends
    /// append to the returned outbox (drained by the scheduler between
    /// phases) under the same cost model and byte accounting as a
    /// channel link. `stats` is shared so every child of one aggregation
    /// stage accounts into that stage's single [`LinkStats`], exactly as
    /// the channel runtime's per-stage links do.
    pub fn queue(latency_us: u64, bandwidth_bps: u64, stats: Arc<LinkStats>) -> (Link, Outbox) {
        let outbox: Outbox = Arc::new(Mutex::new(Vec::new()));
        (
            Link {
                sink: Sink::Queue(outbox.clone()),
                stats,
                latency: Duration::from_micros(latency_us),
                bandwidth_bps,
            },
            outbox,
        )
    }

    /// Send with simulated transfer cost. Blocks when the receiver is
    /// backed up (bounded channel) — that block *is* the backpressure the
    /// fleet config's `channel_capacity` controls.
    pub fn send(&self, msg: Message) -> Result<(), ()> {
        self.send_class(msg, false)
    }

    /// [`Self::send`] with a traffic class: `retransmit = true` frames
    /// are additionally accounted into the round's `retransmit_bytes`
    /// (the fault-recovery catch-up traffic the resilience experiments
    /// measure; see `RoundTraffic`).
    pub fn send_class(&self, msg: Message, retransmit: bool) -> Result<(), ()> {
        let bytes = msg.wire_bytes();
        let epoch = msg.epoch();
        // Pay the wire cost.
        let mut cost = self.latency;
        if self.bandwidth_bps > 0 {
            cost += Duration::from_secs_f64(bytes as f64 / self.bandwidth_bps as f64);
        }
        if !cost.is_zero() {
            std::thread::sleep(cost);
        }
        let tx = match &self.sink {
            Sink::Queue(outbox) => {
                // Executor outbox: unbounded, caller-drained — a send
                // always lands, so only the byte accounting applies.
                outbox.lock().expect("outbox lock").push(msg);
                self.account(bytes, epoch, retransmit);
                return Ok(());
            }
            Sink::Channel(tx) => tx,
        };
        // Try fast path, fall back to blocking and time the stall.
        let msg = match tx.try_send(msg) {
            Ok(()) => {
                self.account(bytes, epoch, retransmit);
                return Ok(());
            }
            Err(TrySendError::Full(m)) => {
                self.stats
                    .backpressure_events
                    .fetch_add(1, Ordering::Relaxed);
                m
            }
            Err(TrySendError::Disconnected(_)) => return Err(()),
        };
        let t = crate::util::timer::Timer::start();
        let result = tx.send(msg).map_err(|_| ());
        self.stats
            .blocked_ns
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if result.is_ok() {
            self.account(bytes, epoch, retransmit);
        }
        result
    }

    fn account(&self, bytes: usize, epoch: Option<u64>, retransmit: bool) {
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        if let Some(epoch) = epoch {
            let mut rounds = self.stats.rounds.lock().expect("link stats lock");
            let t = rounds.entry(epoch).or_default();
            t.messages += 1;
            t.bytes += bytes as u64;
            if retransmit {
                t.retransmit_bytes += bytes as u64;
            }
        }
    }

    pub fn stats(&self) -> Arc<LinkStats> {
        self.stats.clone()
    }
}

impl Clone for Link {
    fn clone(&self) -> Self {
        Link {
            sink: self.sink.clone(),
            stats: self.stats.clone(),
            latency: self.latency,
            bandwidth_bps: self.bandwidth_bps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(epoch: u64, len: usize) -> Message {
        Message::Delta { from: 0, epoch, payload: vec![0u8; len].into() }
    }

    #[test]
    fn send_accounts_bytes_and_messages() {
        let (link, rx, stats) = Link::new(4, 0, 0);
        link.send(delta(0, 100)).unwrap();
        link.send(Message::Done { device_id: 0, examples: 5 }).unwrap();
        let snap = stats.snapshot();
        assert_eq!(snap.messages, 2);
        assert_eq!(snap.bytes, 116);
        drop(link);
        assert_eq!(rx.iter().count(), 2);
    }

    #[test]
    fn per_round_accounting_splits_by_epoch() {
        let (link, _rx, stats) = Link::new(8, 0, 0);
        link.send(delta(0, 50)).unwrap();
        link.send(Message::EndRound { device_id: 0, epoch: 0, examples: 9 }).unwrap();
        link.send(delta(1, 30)).unwrap();
        link.send(Message::EndRound { device_id: 0, epoch: 1, examples: 4 }).unwrap();
        link.send(Message::Done { device_id: 0, examples: 13 }).unwrap();
        let snap = stats.snapshot();
        assert_eq!(snap.round_bytes(0), 74);
        assert_eq!(snap.round_bytes(1), 54);
        assert_eq!(snap.rounds[&0].messages, 2);
        // Done is not attributed to any round; totals still include it.
        let round_total: u64 = snap.rounds.values().map(|t| t.bytes).sum();
        assert_eq!(snap.bytes, round_total + 16);
    }

    #[test]
    fn retransmit_class_accounts_into_round_bucket() {
        let (link, _rx, stats) = Link::new(8, 0, 0);
        link.send(delta(0, 40)).unwrap();
        link.send_class(delta(0, 25), true).unwrap();
        link.send_class(delta(1, 30), true).unwrap();
        let snap = stats.snapshot();
        // Retransmit frames count in BOTH the round total and the
        // retransmit bucket; plain frames only in the total.
        assert_eq!(snap.round_bytes(0), 65);
        assert_eq!(snap.round_retransmit_bytes(0), 25);
        assert_eq!(snap.round_retransmit_bytes(1), 30);
        assert_eq!(snap.retransmit_bytes(), 55);
        // Merge propagates the retransmit bucket.
        let mut merged = LinkSnapshot::default();
        merged.merge(&snap);
        merged.merge(&snap);
        assert_eq!(merged.round_retransmit_bytes(0), 50);
    }

    #[test]
    fn snapshot_merge_merges_round_maps() {
        let (a, _rxa, sa) = Link::new(4, 0, 0);
        let (b, _rxb, sb) = Link::new(4, 0, 0);
        a.send(delta(0, 10)).unwrap();
        b.send(delta(0, 20)).unwrap();
        b.send(delta(2, 5)).unwrap();
        let mut merged = LinkSnapshot::default();
        merged.merge(&sa.snapshot());
        merged.merge(&sb.snapshot());
        assert_eq!(merged.round_bytes(0), 30);
        assert_eq!(merged.round_bytes(2), 5);
        assert_eq!(merged.messages, 3);
    }

    #[test]
    fn queue_sink_accounts_and_enqueues() {
        let stats = Arc::new(LinkStats::default());
        let (link, outbox) = Link::queue(0, 0, stats.clone());
        link.send(delta(0, 100)).unwrap();
        link.send_class(delta(1, 30), true).unwrap();
        link.send(Message::Done { device_id: 0, examples: 1 }).unwrap();
        let snap = stats.snapshot();
        assert_eq!(snap.messages, 3);
        assert_eq!(snap.bytes, 146);
        assert_eq!(snap.round_retransmit_bytes(1), 30);
        assert_eq!(snap.backpressure_events, 0, "queue sends never block");
        let drained = std::mem::take(&mut *outbox.lock().unwrap());
        assert_eq!(drained.len(), 3);
        assert!(matches!(drained.last().unwrap(), Message::Done { .. }));
    }

    #[test]
    fn cloned_delta_shares_one_payload_allocation() {
        let m = delta(0, 64);
        let c = m.clone();
        match (&m, &c) {
            (Message::Delta { payload: a, .. }, Message::Delta { payload: b, .. }) => {
                assert!(Arc::ptr_eq(a, b), "clones must share the frame bytes");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn disconnected_receiver_errors() {
        let (link, rx, _) = Link::new(1, 0, 0);
        drop(rx);
        assert!(link.send(delta(0, 1)).is_err());
    }

    #[test]
    fn backpressure_blocks_until_drained() {
        let (link, rx, stats) = Link::new(1, 0, 0);
        link.send(delta(0, 10)).unwrap();
        // Next send must block until the consumer drains; do it from a
        // thread and drain after a delay.
        let handle = std::thread::spawn(move || {
            link.send(delta(0, 10)).unwrap();
        });
        std::thread::sleep(Duration::from_millis(20));
        let _ = rx.recv().unwrap();
        handle.join().unwrap();
        let snap = stats.snapshot();
        assert_eq!(snap.backpressure_events, 1);
        assert!(snap.blocked_ns > 5_000_000, "blocked {}ns", snap.blocked_ns);
        let _ = rx.recv().unwrap();
    }

    #[test]
    fn latency_model_delays_send() {
        let (link, _rx, _) = Link::new(8, 20_000, 0); // 20ms
        let t = std::time::Instant::now();
        link.send(delta(0, 1)).unwrap();
        assert!(t.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn bandwidth_model_scales_with_bytes() {
        let (link, _rx, _) = Link::new(8, 0, 1_000_000); // 1 MB/s
        let t = std::time::Instant::now();
        link.send(delta(0, 50_000)).unwrap(); // 50ms
        assert!(t.elapsed() >= Duration::from_millis(45));
    }
}
