//! The worker-pool fleet executor: million-device rounds on a bounded
//! thread pool with arena device state.
//!
//! The thread-per-node scheduler (`run_fleet_model_threaded`) costs an
//! OS thread — stack, scheduler state, wakeups — per device, which caps
//! a simulated fleet in the low tens of thousands. This executor keeps
//! the *protocol* (the same `DeviceMachine` / `AggMachine` /
//! `LeaderMachine` state machines) and replaces the *scheduler*:
//!
//! * **Arena device state.** Every device's counters live in two
//!   contiguous byte arenas at the device counter width — the
//!   cumulative grid and the last-confirmed snapshot — plus flat `u64`
//!   count vectors. Both grids are mandatory: under saturating narrow
//!   widths a round delta is `cumulative - snapshot` at native width,
//!   which a fresh-zeroed grid cannot reproduce. Per device that is
//!   `2 x rows x buckets x width.bytes() + O(1)` — sketch bytes, not
//!   thread stacks.
//! * **Scratch-model paging.** Each worker owns one real sketch (the
//!   hash bank — the expensive, seed-deterministic part — is identical
//!   for every device) and pages device counters in and out of the
//!   arena around each protocol step (`RiskSketch::load_state` /
//!   `store_state`).
//! * **Deterministic cooperative rounds.** Each epoch runs one device
//!   phase — devices sharded contiguously across the pool, each worker
//!   stepping its slice in id order — then one propagation pass that
//!   drains every child's outbox in stage order into its parent's
//!   machine. Messages travel per-child queue links
//!   ([`Link::queue`]), so per-link FIFO order is exactly the
//!   thread-per-node order and the cross-child interleaving is *one
//!   fixed legal schedule* instead of an OS-dependent one. Counter
//!   merges commute and folds deduplicate on `(from, epoch)`, so the
//!   final counters are bit-identical to the threaded path at every
//!   worker count — that is a tested invariant, not an aspiration.
//! * **Sharded leader folds.** The leader's per-round fold is split
//!   across the pool by counter range (`absorb_all_sharded`), which is
//!   bit-identical because per-cell addition is associative and
//!   commutative.
//!
//! The leader (and the caller's `on_round` hook) runs on the calling
//! thread, between phases — exactly where the coordinator interleaves
//! training.

use super::device::{DeviceConfig, DeviceMachine, DeviceReport};
use super::faults::{ChaosLink, FaultPlan, FaultStats};
use super::fleet::{
    fallback_round_examples, quorum_of, AggMachine, FleetResult, LeaderMachine,
};
use super::network::{Link, LinkSnapshot, LinkStats, Message, Outbox};
use super::topology::{plan, Stage, Topology, LEADER};
use crate::config::{CounterWidth, FleetConfig, StormConfig};
use crate::data::stream::StreamSource;
use crate::sketch::counters::GridSnapshot;
use crate::sketch::delta::SketchSnapshot;
use crate::sketch::RiskSketch;
use std::sync::Arc;

/// Resolve `[fleet] workers`: 0 means auto (the machine's available
/// parallelism), anything else is taken literally.
pub(crate) fn resolve_workers(cfg_workers: usize) -> usize {
    if cfg_workers == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        cfg_workers
    }
}

/// What a device phase does with each device this pass.
#[derive(Clone, Copy)]
enum Phase {
    /// Run one sync round at this epoch.
    Step(u64),
    /// Run the recovery epilogue and emit the device report.
    Finish,
}

/// Step every device in one contiguous chunk, paging counters through
/// the worker's scratch sketch. Devices run in id order within the
/// chunk, so a fixed chunking gives a fixed per-link message order.
#[allow(clippy::too_many_arguments)]
fn run_chunk<M: RiskSketch>(
    phase: Phase,
    sb: usize,
    (rows, buckets, width): (usize, usize, CounterWidth),
    machines: &mut [DeviceMachine],
    streams: &mut [Box<dyn StreamSource>],
    links: &[ChaosLink],
    cum: &mut [u8],
    snapb: &mut [u8],
    counts: &mut [u64],
    snap_counts: &mut [u64],
    reports: &mut [DeviceReport],
    sk: &mut M,
) {
    let cap = machines.first().map_or(0, |m| m.buf_capacity());
    let mut buf: Vec<crate::data::stream::Example> = Vec::with_capacity(cap);
    for i in 0..machines.len() {
        let span = i * sb..(i + 1) * sb;
        sk.load_state(&cum[span.clone()], counts[i]);
        let mut snap = SketchSnapshot {
            grid: GridSnapshot::from_native(rows, buckets, width, &snapb[span.clone()]),
            count: snap_counts[i],
        };
        match phase {
            Phase::Step(epoch) => machines[i].step_round(
                epoch,
                sk,
                &mut snap,
                streams[i].as_mut(),
                &mut buf,
                &links[i],
            ),
            Phase::Finish => {
                reports[i] =
                    machines[i].finish(sk, &mut snap, streams[i].as_mut(), &mut buf, &links[i]);
            }
        }
        sk.store_state(&mut cum[span.clone()]);
        counts[i] = sk.count();
        snap.grid.store_native(&mut snapb[span]);
        snap_counts[i] = snap.count;
    }
}

/// One parallel device phase: shard the fleet contiguously across the
/// pool and run every shard's chunk concurrently. Shards touch disjoint
/// arena slices, machines, streams and links, so this is plain
/// `chunks_mut` sharing — no locks on the hot path.
#[allow(clippy::too_many_arguments)]
fn device_phase<M: RiskSketch>(
    phase: Phase,
    workers: usize,
    sb: usize,
    geometry: (usize, usize, CounterWidth),
    machines: &mut [DeviceMachine],
    streams: &mut [Box<dyn StreamSource>],
    links: &mut [ChaosLink],
    cum: &mut [u8],
    snapb: &mut [u8],
    counts: &mut [u64],
    snap_counts: &mut [u64],
    reports: &mut [DeviceReport],
    scratch: &mut [M],
) {
    let n = machines.len();
    if n == 0 {
        return;
    }
    let chunk = n.div_ceil(workers.max(1));
    std::thread::scope(|s| {
        let iter = machines
            .chunks_mut(chunk)
            .zip(streams.chunks_mut(chunk))
            .zip(links.chunks_mut(chunk))
            .zip(cum.chunks_mut(chunk * sb))
            .zip(snapb.chunks_mut(chunk * sb))
            .zip(counts.chunks_mut(chunk))
            .zip(snap_counts.chunks_mut(chunk))
            .zip(reports.chunks_mut(chunk))
            .zip(scratch.iter_mut());
        for ((((((((ms, sts), lks), cumc), snapc), cts), scts), reps), sk) in iter {
            s.spawn(move || {
                run_chunk(phase, sb, geometry, ms, sts, lks, cumc, snapc, cts, scts, reps, sk);
            });
        }
    });
}

/// One propagation pass: drain every child's outbox, in stage order and
/// child order, into the parent's machine. Stage order is topological
/// (children stages precede their parents), so a round's deltas flow
/// leaf-to-leader within a single pass. With `finish_aggs` the pass is
/// the shutdown cascade: after an aggregator's children are drained it
/// must be done (every child Done arrived), so it exit-flushes and
/// cascades Done — which the next stage in the same pass then drains.
fn propagate<M: RiskSketch>(
    stages: &[Stage],
    outboxes: &[Option<Outbox>],
    aggs: &mut [Option<AggMachine>],
    agg_uplinks: &[Option<ChaosLink>],
    leader: &mut LeaderMachine<M>,
    on_round: &mut impl FnMut(u64, &M),
    finish_aggs: bool,
) {
    for stage in stages {
        let is_leader = stage.parent == LEADER;
        for &c in &stage.children {
            let msgs: Vec<Message> = {
                let mut q =
                    outboxes[c].as_ref().expect("child outbox").lock().expect("outbox lock");
                std::mem::take(&mut *q)
            };
            if is_leader {
                for m in msgs {
                    leader.on_message(m, on_round);
                }
            } else {
                let agg = aggs[stage.parent].as_mut().expect("aggregator machine");
                let up = agg_uplinks[stage.parent].as_ref().expect("aggregator uplink");
                for m in msgs {
                    agg.on_message(m, up);
                }
            }
        }
        if finish_aggs && !is_leader {
            let agg = aggs[stage.parent].as_mut().expect("aggregator machine");
            let up = agg_uplinks[stage.parent].as_ref().expect("aggregator uplink");
            debug_assert!(agg.is_done(), "every child finished before the final pass");
            agg.finish(up);
        }
    }
}

/// Run a fleet on the worker-pool arena executor — the default scheduler
/// behind `run_fleet_model_chaos`. Same protocol, same results, roughly
/// sketch-bytes of state per device instead of an OS thread.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_fleet_pooled<M: RiskSketch + 'static, F: FnMut(u64, &M)>(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
    fault_plan: Option<FaultPlan>,
    mut on_round: F,
) -> FleetResult<M> {
    assert_eq!(streams.len(), fleet.devices, "one stream per device");
    let mut streams = streams;
    let n = fleet.devices;
    let rounds = fleet.sync_rounds.max(1);
    let workers = resolve_workers(fleet.workers).min(n.max(1));
    // Per-tier widths, exactly as the threaded path resolves them.
    let device_storm = StormConfig {
        counter_width: fleet.device_counter_width.unwrap_or(storm.counter_width),
        ..storm
    };
    let stages = plan(topology, n);
    let timer = crate::util::timer::Timer::start();
    let crash = fault_plan.and_then(|p| p.crash_schedule(n, rounds as u64));
    // One stats block for every fault-wrapped link: at a million devices
    // a per-link block is a million allocations merged at exit for the
    // same four totals.
    let fault_stats = Arc::new(FaultStats::default());

    // One scratch sketch per worker; the hash bank inside is identical
    // for every device (same config, same seed), which is what makes
    // arena paging sound.
    let mut scratch: Vec<M> =
        (0..workers).map(|_| M::build(device_storm, dim, family_seed)).collect();
    let sb = scratch[0].grid().bytes();
    let geometry =
        (scratch[0].grid().rows(), scratch[0].grid().buckets(), scratch[0].grid().width());

    // Per-child queue links: each child (device or aggregator) sends
    // into its own outbox, drained by its parent in deterministic child
    // order. Byte accounting aggregates per stage, mirroring the
    // threaded path's one-link-per-stage stats.
    let max_node = stages
        .iter()
        .flat_map(|s| {
            s.children.iter().copied().chain((s.parent != LEADER).then_some(s.parent))
        })
        .max()
        .unwrap_or(0);
    let mut stage_stats: Vec<Arc<LinkStats>> = Vec::with_capacity(stages.len());
    let mut outboxes: Vec<Option<Outbox>> = (0..=max_node).map(|_| None).collect();
    let mut chaos_links: Vec<Option<ChaosLink>> = (0..=max_node).map(|_| None).collect();
    for stage in &stages {
        let st = Arc::new(LinkStats::default());
        stage_stats.push(st.clone());
        for &c in &stage.children {
            let (link, outbox) =
                Link::queue(fleet.link_latency_us, fleet.link_bandwidth_bps, st.clone());
            let chaos = ChaosLink::with_stats(link, c as u64, fault_plan, fault_stats.clone());
            outboxes[c] = Some(outbox);
            chaos_links[c] = Some(chaos);
        }
    }
    // Devices own the first n links; aggregator uplinks stay put.
    let mut dev_links: Vec<ChaosLink> =
        (0..n).map(|i| chaos_links[i].take().expect("device uplink")).collect();

    // Arena device state + one machine per device.
    let fallback = fallback_round_examples(&storm, dim, fleet.batch);
    let mut machines: Vec<DeviceMachine> = Vec::with_capacity(n);
    for (id, stream) in streams.iter_mut().enumerate() {
        let cfg = DeviceConfig {
            id,
            batch: fleet.batch,
            rounds,
            fallback_round_examples: fallback,
            storm: device_storm,
            family_seed,
            dim,
            epsilon: fleet.epsilon_per_round,
            plan: fault_plan,
            crash: crash.and_then(|(dev, at, down)| (dev == id).then_some((at, down))),
        };
        machines.push(DeviceMachine::new(cfg, stream.remaining_hint()));
    }
    let mut cum = vec![0u8; n * sb];
    let mut snapb = vec![0u8; n * sb];
    let mut counts = vec![0u64; n];
    let mut snap_counts = vec![0u64; n];
    let mut reports = vec![DeviceReport::default(); n];

    // Merge-tier machines.
    let mut aggs: Vec<Option<AggMachine>> = (0..=max_node).map(|_| None).collect();
    for stage in &stages {
        if stage.parent == LEADER {
            continue;
        }
        let quorum = quorum_of(fleet.min_quorum, stage.children.len());
        aggs[stage.parent] =
            Some(AggMachine::new(stage.parent, &stage.children, quorum, rounds as u64));
    }
    let leader_stage = stages.iter().find(|s| s.parent == LEADER).expect("leader stage");
    let quorum = quorum_of(fleet.min_quorum, leader_stage.children.len());
    let mut leader = LeaderMachine::new(
        M::build(storm, dim, family_seed),
        &leader_stage.children,
        quorum,
        rounds as u64,
        workers,
        fleet.decay_keep_permille,
    );

    // The cooperative round loop: device phase, then one leaf-to-leader
    // propagation pass. Round barriers close inside the pass, on this
    // thread — which is where `on_round` interleaves training.
    for epoch in 0..rounds as u64 {
        device_phase(
            Phase::Step(epoch),
            workers,
            sb,
            geometry,
            &mut machines,
            &mut streams,
            &mut dev_links,
            &mut cum,
            &mut snapb,
            &mut counts,
            &mut snap_counts,
            &mut reports,
            &mut scratch,
        );
        propagate(&stages, &outboxes, &mut aggs, &chaos_links, &mut leader, &mut on_round, false);
    }
    // Shutdown: device recovery epilogues (final deltas, back-filled
    // barriers, Done), then one finishing pass that exit-flushes each
    // aggregator and cascades Done up to the leader.
    device_phase(
        Phase::Finish,
        workers,
        sb,
        geometry,
        &mut machines,
        &mut streams,
        &mut dev_links,
        &mut cum,
        &mut snapb,
        &mut counts,
        &mut snap_counts,
        &mut reports,
        &mut scratch,
    );
    propagate(&stages, &outboxes, &mut aggs, &chaos_links, &mut leader, &mut on_round, true);
    debug_assert!(leader.is_done(), "every node cascaded Done");
    let (sketch, round_stats, examples) = leader.finish();

    let mut network = LinkSnapshot::default();
    for s in &stage_stats {
        network.merge(&s.snapshot());
    }
    FleetResult {
        sketch,
        devices: reports,
        network,
        wall_secs: timer.elapsed_secs(),
        examples,
        rounds: round_stats,
        faults: fault_stats.snapshot(),
    }
}
