//! A simulated edge device: owns a local stream and a *long-lived* local
//! STORM sketch, ingests between sync barriers, and at each sync round
//! ships only the counters that changed since the last round (an
//! epoch-tagged [`crate::sketch::delta::SketchDelta`] on wire format v2).
//!
//! Shipping deltas rather than cumulative sketches keeps upstream
//! aggregation idempotent-free simple addition, and the sparse v2 wire
//! encoding makes a quiet round cost bytes proportional to what actually
//! changed — the mergeable-summary property doing real work, per round.
//!
//! **Fault tolerance.** The device's recovery invariant is simple: the
//! counter snapshot (`snap`) advances **only when a delta is confirmed
//! delivered**. Anything that goes wrong — a dropped frame, a straggled
//! round, a crash — leaves the snapshot behind, and the next cut delta
//! automatically covers every epoch since (`delta_since` is cumulative):
//! the multi-epoch catch-up frame of the protocol, accounted as
//! retransmit bytes on the link. A crash/restart costs nothing extra
//! because the sketch *is* the checkpoint (a few KB of counters); the
//! device is silent for the downtime, then back-fills the missed
//! barrier acks and ships one catch-up delta. The epilogue after the
//! round loop guarantees the device never exits owing data or
//! barriers, retrying the final delta until the link confirms it
//! (bounded by the fault plan's drop-burst cap).

use super::faults::{drain_due, ChaosLink, Delivery, FaultPlan};
use super::network::Message;
use crate::config::StormConfig;
use crate::data::stream::StreamSource;
use crate::sketch::delta::{SketchDelta, SketchSnapshot};
use crate::sketch::privacy::noise_delta;
use crate::sketch::serialize::encode_delta;
use crate::sketch::RiskSketch;

/// Device runtime parameters.
#[derive(Clone, Copy, Debug)]
pub struct DeviceConfig {
    pub id: usize,
    /// Ingest batch size.
    pub batch: usize,
    /// Number of sync rounds the fleet runs; the device emits (at most)
    /// one delta per round and always one `EndRound` per round.
    pub rounds: usize,
    /// Per-round example budget when the stream cannot report its length
    /// (`StreamSource::remaining_hint` returns `None`); hinted streams
    /// split their remaining length evenly across rounds instead.
    pub fallback_round_examples: usize,
    /// Sketch configuration — including the learning *task* — (must
    /// match fleet-wide; merging enforces it).
    pub storm: StormConfig,
    /// Shared hash-family seed (fleet-wide).
    pub family_seed: u64,
    /// Streamed example dimension (d + 1): `[x, y]` for both tasks.
    pub dim: usize,
    /// Fault schedule (None = ideal network, the PR-2 path bit-for-bit).
    pub plan: Option<FaultPlan>,
    /// Crash window for THIS device: `(round, downtime)` — silent for
    /// `downtime` rounds starting at `round` (resolved fleet-wide from
    /// the plan's single crash/restart).
    pub crash: Option<(u64, u64)>,
    /// Per-round differential-privacy budget. > 0 adds two-sided
    /// geometric noise to every shipped delta's counters before encoding
    /// (the wire copy only — the device's own sketch stays exact). The
    /// noise is seeded from `(family_seed, id, epoch)`, so a retried or
    /// catch-up frame for the same epoch re-ships byte-identical noise
    /// and a retransmit never spends extra privacy budget. 0 = off,
    /// bit-identical to the non-private pipeline.
    pub epsilon: f64,
}

/// Deterministic per-(device, epoch) noise seed — see
/// [`DeviceConfig::epsilon`].
fn noise_seed(family_seed: u64, device: usize, epoch: u64) -> u64 {
    family_seed
        ^ (device as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ epoch.wrapping_mul(0xD1B5_4A32_D192_ED03)
}

/// Summary the device thread returns.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeviceReport {
    pub id: usize,
    pub examples: u64,
    pub batches: u64,
    /// Sync rounds the device actively ran (quiet rounds past stream end
    /// included; rounds spent down in a crash window are counted in
    /// `crashed_rounds` instead — `rounds + crashed_rounds == cfg.rounds`,
    /// and every round is still eventually acked upstream).
    pub rounds: u64,
    /// Non-empty deltas actually shipped (confirmed delivered) upstream.
    pub deltas: u64,
    /// Rounds spent down in the crash window.
    pub crashed_rounds: u64,
    /// Rounds whose barrier ack was deferred (straggler rounds).
    pub straggled: u64,
    /// Delivered delta frames that were catch-up traffic (covered more
    /// than the round they were sent in, or were retries).
    pub retransmits: u64,
    /// Width-true memory footprint of this device's counter array
    /// (`R x B x counter_width.bytes()` — a u8 device pays a quarter of
    /// the u32 footprint).
    pub sketch_bytes: usize,
    pub ingest_secs: f64,
}

/// Send every held barrier ack due at or before round `through`.
fn flush_ends(
    link: &ChaosLink,
    device_id: usize,
    held: &mut Vec<(u64, (u64, u64))>,
    through: u64,
) {
    drain_due(held, through, |(epoch, examples)| {
        let _ = link.send(Message::EndRound { device_id, epoch, examples });
    });
}

/// The device protocol as a small resumable state machine: everything
/// `run_device` used to keep on its thread's stack, minus the sketch,
/// snapshot, stream and batch buffer — those are passed into each call
/// so the arena executor can page per-device counters through one
/// scratch model per worker while the threaded wrapper keeps them
/// local. Driving `step_round` for every epoch and then `finish` is
/// *the* protocol; both schedulers share this one implementation.
pub(crate) struct DeviceMachine {
    cfg: DeviceConfig,
    /// Per-round example budget (from the stream hint or the fallback).
    budget: usize,
    buf_capacity: usize,
    exhausted: bool,
    /// Barrier acks deferred by straggler rounds.
    held_ends: Vec<(u64, (u64, u64))>,
    /// Barriers missed while crashed.
    missed: Vec<u64>,
    /// First epoch whose increments have not been confirmed delivered
    /// (a delta covering more than its own round is catch-up traffic).
    unshipped_from: u64,
    report: DeviceReport,
}

impl DeviceMachine {
    /// `hint` is the stream's length hint, which sizes both the
    /// per-round budget and the reusable batch buffer.
    pub(crate) fn new(cfg: DeviceConfig, hint: Option<usize>) -> Self {
        let rounds = cfg.rounds.max(1);
        let budget = match hint {
            Some(n) => n.div_ceil(rounds).max(1),
            None => cfg.fallback_round_examples.max(1),
        };
        DeviceMachine {
            cfg,
            budget,
            buf_capacity: cfg.batch.min(hint.unwrap_or(cfg.batch)).max(1),
            exhausted: false,
            held_ends: Vec::new(),
            missed: Vec::new(),
            unshipped_from: 0,
            report: DeviceReport { id: cfg.id, ..Default::default() },
        }
    }

    /// Capacity for the reusable batch buffer (no per-batch allocation).
    pub(crate) fn buf_capacity(&self) -> usize {
        self.buf_capacity
    }

    /// Encode a delta for the wire, noising a copy first when delta-level
    /// DP is on. Deterministic in `(family_seed, id, epoch)`.
    fn ship_bytes(&self, delta: &SketchDelta, epoch: u64) -> Vec<u8> {
        if self.cfg.epsilon > 0.0 {
            let mut noised = delta.clone();
            noise_delta(
                &mut noised,
                self.cfg.epsilon,
                noise_seed(self.cfg.family_seed, self.cfg.id, epoch),
            );
            encode_delta(&noised)
        } else {
            encode_delta(delta)
        }
    }

    fn last_epoch(&self) -> u64 {
        self.cfg.rounds.max(1) as u64 - 1
    }

    /// Run one sync round: ingest up to the round budget, cut and ship
    /// the delta, ack the barrier (deferred or coalesced under faults).
    pub(crate) fn step_round<M: RiskSketch>(
        &mut self,
        epoch: u64,
        sketch: &mut M,
        snap: &mut SketchSnapshot,
        stream: &mut dyn StreamSource,
        buf: &mut Vec<crate::data::stream::Example>,
        link: &ChaosLink,
    ) {
        let cfg = self.cfg;
        if cfg.crash.is_some_and(|(at, down)| epoch >= at && epoch < at + down) {
            // Down: no ingest, no sends. The sketch persists (it is the
            // checkpoint); the stream backlog waits at the source.
            self.missed.push(epoch);
            self.report.crashed_rounds += 1;
            return;
        }
        // Reconnect: back-fill the barrier acks missed while down so
        // full-quorum barriers can close.
        for &e in &self.missed {
            let _ = link.send(Message::EndRound { device_id: cfg.id, epoch: e, examples: 0 });
        }
        self.missed.clear();
        // Release straggled acks that are due this round.
        flush_ends(link, cfg.id, &mut self.held_ends, epoch);
        // The final round drains the stream completely so a stale or
        // missing hint never strands examples.
        let last = epoch == self.last_epoch();
        let mut ingested = 0usize;
        while !self.exhausted && (last || ingested < self.budget) {
            let want = if last { cfg.batch } else { cfg.batch.min(self.budget - ingested) };
            stream.next_batch_into(want, buf);
            if buf.is_empty() {
                self.exhausted = true;
                break;
            }
            // Fused batch sketching: one pass over the projection bank per
            // batch, bit-identical counters to per-example inserts.
            sketch.insert_batch(buf);
            ingested += buf.len();
            self.report.batches += 1;
        }
        self.report.examples += ingested as u64;
        self.report.rounds += 1;
        let straggle = cfg.plan.map_or(0, |p| p.straggle_rounds(cfg.id, epoch));
        if straggle > 0 && !last {
            // Straggler round: defer the barrier ack; the round's
            // increments simply ride in the next cut delta (the
            // snapshot stays behind — same recovery path as a drop).
            self.held_ends.push((epoch + straggle, (epoch, ingested as u64)));
            self.report.straggled += 1;
            return;
        }
        let delta = sketch.delta_since(snap, epoch);
        if !delta.is_empty() {
            let catchup = self.unshipped_from < epoch;
            match link.send_class(
                Message::Delta { from: cfg.id, epoch, payload: self.ship_bytes(&delta, epoch).into() },
                catchup,
            ) {
                Ok(Delivery::Delivered) => {
                    *snap = sketch.snapshot();
                    self.unshipped_from = epoch + 1;
                    self.report.deltas += 1;
                    self.report.retransmits += u64::from(catchup);
                }
                // Dropped: snapshot stays behind; the increments ride
                // in a later round's catch-up delta.
                Ok(Delivery::Dropped) => {}
                // A dead link (aggregator gone) stops shipping but the
                // device keeps sketching and counting.
                Err(()) => {}
            }
        } else {
            self.unshipped_from = epoch + 1; // quiet round: nothing owed
        }
        let _ = link.send(Message::EndRound {
            device_id: cfg.id,
            epoch,
            examples: ingested as u64,
        });
    }

    /// Recovery epilogue after the last round: a crash window that
    /// reached the end, straggled acks still held, or a dropped final
    /// delta all resolve here — the device never exits owing data or
    /// barriers. Sends `Done` and returns the device's report.
    pub(crate) fn finish<M: RiskSketch>(
        &mut self,
        sketch: &mut M,
        snap: &mut SketchSnapshot,
        stream: &mut dyn StreamSource,
        buf: &mut Vec<crate::data::stream::Example>,
        link: &ChaosLink,
    ) -> DeviceReport {
        let cfg = self.cfg;
        let last_epoch = self.last_epoch();
        for &e in &self.missed {
            let _ = link.send(Message::EndRound { device_id: cfg.id, epoch: e, examples: 0 });
        }
        self.missed.clear();
        flush_ends(link, cfg.id, &mut self.held_ends, u64::MAX);
        if !self.exhausted {
            // The crash swallowed the draining round: this is a one-pass
            // stream, so drain the backlog now or never.
            loop {
                stream.next_batch_into(cfg.batch, buf);
                if buf.is_empty() {
                    break;
                }
                sketch.insert_batch(buf);
                self.report.examples += buf.len() as u64;
                self.report.batches += 1;
            }
        }
        // Final-delta loop: retry until the link confirms delivery (the
        // plan's drop-burst cap bounds this) or the receiver is gone. Any
        // non-empty delta here means the in-loop path failed to deliver it
        // (a drop, or a crash covering the final round) — recovery traffic
        // by definition, so it is always retransmit-classed.
        let retrying = self.unshipped_from <= last_epoch;
        loop {
            let delta = sketch.delta_since(snap, last_epoch);
            if delta.is_empty() {
                break;
            }
            match link.send_class(
                Message::Delta {
                    from: cfg.id,
                    epoch: last_epoch,
                    payload: self.ship_bytes(&delta, last_epoch).into(),
                },
                retrying,
            ) {
                Ok(Delivery::Delivered) => {
                    *snap = sketch.snapshot();
                    self.report.deltas += 1;
                    self.report.retransmits += u64::from(retrying);
                    break;
                }
                Ok(Delivery::Dropped) => continue,
                Err(()) => break,
            }
        }
        self.report.sketch_bytes = sketch.grid().bytes();
        let _ = link.send(Message::Done { device_id: cfg.id, examples: self.report.examples });
        self.report
    }
}

/// Run one device through all sync rounds: sketch into the long-lived
/// local model, emit one delta + `EndRound` per round (deferred or
/// coalesced under faults), then `Done`. This is the body of each fleet
/// thread — a thin loop over [`DeviceMachine`], generic over the sketch
/// model, so regression and classification devices run the identical
/// protocol (same deltas, same barriers, same recovery paths), and the
/// arena executor drives the very same machine.
pub fn run_device<M: RiskSketch>(
    cfg: DeviceConfig,
    mut stream: Box<dyn StreamSource>,
    link: ChaosLink,
) -> DeviceReport {
    let rounds = cfg.rounds.max(1);
    let mut sketch = M::build(cfg.storm, cfg.dim, cfg.family_seed);
    let mut snap = sketch.snapshot();
    let timer = crate::util::timer::Timer::start();
    let hint = stream.remaining_hint();
    let mut machine = DeviceMachine::new(cfg, hint);
    let mut buf: Vec<crate::data::stream::Example> =
        Vec::with_capacity(machine.buf_capacity());
    for epoch in 0..rounds as u64 {
        machine.step_round(epoch, &mut sketch, &mut snap, stream.as_mut(), &mut buf, &link);
    }
    let mut report = machine.finish(&mut sketch, &mut snap, stream.as_mut(), &mut buf, &link);
    report.ingest_secs = timer.elapsed_secs();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::data::stream::ReplayStream;
    use crate::edge::network::Link;
    use crate::linalg::matrix::Matrix;
    use crate::sketch::model::StormModel;
    use crate::sketch::serialize::decode_delta;
    use crate::sketch::storm::StormSketch;

    fn toy_dataset(n: usize) -> Dataset {
        let x = Matrix::from_fn(n, 2, |r, c| ((r + c) % 5) as f64 * 0.1);
        let y = (0..n).map(|i| (i % 3) as f64 * 0.1).collect();
        Dataset::new("dev", x, y)
    }

    fn dev_cfg(id: usize, rounds: usize) -> DeviceConfig {
        DeviceConfig {
            id,
            batch: 8,
            rounds,
            fallback_round_examples: 16,
            storm: StormConfig { rows: 10, power: 3, saturating: true, ..Default::default() },
            family_seed: 42,
            dim: 3,
            plan: None,
            crash: None,
            epsilon: 0.0,
        }
    }

    fn plain(link: Link) -> ChaosLink {
        ChaosLink::passthrough(link)
    }

    /// Reassemble every delta a device shipped into one sketch,
    /// deduplicating on `(from, epoch)` exactly as a merge node does.
    fn reassemble(msgs: &[Message]) -> (StormSketch, u64, Vec<u64>) {
        let mut merged = StormSketch::new(dev_cfg(0, 1).storm, 3, 42);
        let mut done_examples = 0;
        let mut epochs = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for msg in msgs {
            match msg {
                Message::Delta { from, epoch, payload } => {
                    if !seen.insert((*from, *epoch)) {
                        continue; // duplicate frame: exactly-once fold
                    }
                    let d = decode_delta(payload).unwrap();
                    assert_eq!(d.epoch, *epoch, "frame epoch must match message epoch");
                    merged.apply_delta(&d);
                    epochs.push(*epoch);
                }
                Message::Done { examples, .. } => done_examples = *examples,
                Message::EndRound { .. } => {}
            }
        }
        (merged, done_examples, epochs)
    }

    fn reference_sketch(ds: &Dataset) -> StormSketch {
        let mut reference = StormSketch::new(dev_cfg(0, 1).storm, 3, 42);
        for i in 0..ds.len() {
            reference.insert(&ds.augmented(i));
        }
        reference
    }

    #[test]
    fn device_sketches_whole_stream_across_rounds() {
        let ds = toy_dataset(50);
        let (link, rx, _) = Link::new(64, 0, 0);
        let report = run_device::<StormSketch>(
            dev_cfg(0, 4),
            Box::new(ReplayStream::new(ds.clone())),
            plain(link),
        );
        assert_eq!(report.examples, 50);
        assert_eq!(report.rounds, 4);
        let msgs: Vec<Message> = rx.iter().collect();
        let ends = msgs.iter().filter(|m| matches!(m, Message::EndRound { .. })).count();
        assert_eq!(ends, 4, "one EndRound per round");
        let (merged, done_examples, epochs) = reassemble(&msgs);
        assert_eq!(done_examples, 50);
        // Deltas tagged with consecutive epochs, applied in order equal a
        // locally-built one-shot sketch.
        assert!(epochs.windows(2).all(|w| w[0] < w[1]), "{epochs:?}");
        let reference = reference_sketch(&ds);
        assert_eq!(merged.grid().counts_u32(), reference.grid().counts_u32());
        assert_eq!(merged.count(), 50);
    }

    #[test]
    fn hinted_stream_splits_examples_evenly_across_rounds() {
        let ds = toy_dataset(64);
        let (link, rx, _) = Link::new(64, 0, 0);
        let report =
            run_device::<StormSketch>(dev_cfg(1, 4), Box::new(ReplayStream::new(ds)), plain(link));
        assert_eq!(report.examples, 64);
        assert_eq!(report.deltas, 4);
        // 64 hinted examples over 4 rounds -> 16 per round.
        let per_round: Vec<u64> = rx
            .iter()
            .filter_map(|m| match m {
                Message::EndRound { examples, .. } => Some(examples),
                _ => None,
            })
            .collect();
        assert_eq!(per_round, vec![16, 16, 16, 16]);
    }

    /// Strips the length hint off a stream — the unknown-length regime
    /// (an open-ended sensor), which is what forces the fallback budget
    /// and the mid-run exhaustion path.
    struct NoHint(ReplayStream);

    impl crate::data::stream::StreamSource for NoHint {
        fn next_example(&mut self) -> Option<crate::data::stream::Example> {
            self.0.next_example()
        }
    }

    #[test]
    fn exhausted_stream_still_answers_every_round() {
        // Hintless stream of 10 examples, 5 rounds of fallback budget 3
        // (batch 2): rounds 0..3 ingest 3+3+3+1, the stream dries up
        // mid-round-3, and round 4 must still send EndRound with zero
        // examples — quiet rounds answer the barrier.
        let ds = toy_dataset(10);
        let (link, rx, _) = Link::new(64, 0, 0);
        let mut cfg = dev_cfg(2, 5);
        cfg.batch = 2;
        cfg.fallback_round_examples = 3;
        let report =
            run_device::<StormSketch>(cfg, Box::new(NoHint(ReplayStream::new(ds))), plain(link));
        assert_eq!(report.examples, 10);
        assert_eq!(report.rounds, 5);
        let ends: Vec<(u64, u64)> = rx
            .iter()
            .filter_map(|m| match m {
                Message::EndRound { epoch, examples, .. } => Some((epoch, examples)),
                _ => None,
            })
            .collect();
        assert_eq!(
            ends,
            vec![(0, 3), (1, 3), (2, 3), (3, 1), (4, 0)],
            "fallback budget + mid-run exhaustion + quiet final round"
        );
    }

    #[test]
    fn empty_stream_sends_endrounds_and_done_only() {
        let ds = toy_dataset(0);
        let (link, rx, _) = Link::new(16, 0, 0);
        let report =
            run_device::<StormSketch>(dev_cfg(3, 3), Box::new(ReplayStream::new(ds)), plain(link));
        assert_eq!(report.examples, 0);
        assert_eq!(report.deltas, 0);
        let msgs: Vec<Message> = rx.iter().collect();
        assert_eq!(msgs.len(), 4); // 3 EndRound + Done
        assert!(msgs.iter().all(|m| !matches!(m, Message::Delta { .. })));
        assert!(matches!(msgs.last().unwrap(), Message::Done { .. }));
    }

    #[test]
    fn dead_link_does_not_panic() {
        let ds = toy_dataset(30);
        let (link, rx, _) = Link::new(8, 0, 0);
        drop(rx);
        let report =
            run_device::<StormSketch>(dev_cfg(4, 3), Box::new(ReplayStream::new(ds)), plain(link));
        assert_eq!(report.examples, 30);
        assert_eq!(report.deltas, 0);
        assert_eq!(report.rounds, 3);
    }

    #[test]
    fn single_round_device_ships_one_delta() {
        let ds = toy_dataset(40);
        let (link, rx, _) = Link::new(64, 0, 0);
        let report =
            run_device::<StormSketch>(dev_cfg(5, 1), Box::new(ReplayStream::new(ds)), plain(link));
        assert_eq!(report.deltas, 1);
        let deltas = rx.iter().filter(|m| matches!(m, Message::Delta { .. })).count();
        assert_eq!(deltas, 1);
    }

    #[test]
    fn narrow_width_device_ships_v3_deltas_that_widen_exactly() {
        // A u8 device's rounds reassemble into a u32 merge node
        // counter-for-counter equal to the u32 reference (no cell in
        // this stream comes near 255, so widening is exact), at a
        // quarter of the device-side memory.
        let ds = toy_dataset(50);
        let (link, rx, _) = Link::new(64, 0, 0);
        let mut cfg = dev_cfg(0, 4);
        cfg.storm.counter_width = crate::config::CounterWidth::U8;
        let report =
            run_device::<StormSketch>(cfg, Box::new(ReplayStream::new(ds.clone())), plain(link));
        assert_eq!(report.examples, 50);
        assert_eq!(report.sketch_bytes, 10 * 8, "u8 cells: R x B x 1 byte");
        let msgs: Vec<Message> = rx.iter().collect();
        for m in &msgs {
            if let Message::Delta { payload, .. } = m {
                let d = decode_delta(payload).unwrap();
                assert_eq!(d.width, crate::config::CounterWidth::U8);
                assert_eq!(
                    u16::from_le_bytes(payload[4..6].try_into().unwrap()),
                    3,
                    "narrow deltas ship the width-tagged v3 wire"
                );
            }
        }
        let (merged, done, _) = reassemble(&msgs);
        assert_eq!(done, 50);
        assert_eq!(merged.grid().counts_u32(), reference_sketch(&ds).grid().counts_u32());
        assert_eq!(merged.grid().width(), crate::config::CounterWidth::U32);
    }

    #[test]
    fn dropped_deltas_ride_in_catchup_frames_and_lose_nothing() {
        // Total loss: every delta is dropped until the burst cap forces
        // one through. The reassembled sketch must still be complete,
        // and the delivered catch-up frames must be retransmit-classed.
        let ds = toy_dataset(48);
        let (link, rx, stats) = Link::new(256, 0, 0);
        let mut cfg = dev_cfg(6, 6);
        cfg.plan = Some(FaultPlan::drop_only(1, 1000));
        let chaos = ChaosLink::new(link, cfg.id as u64, cfg.plan);
        let fault_stats = chaos.stats();
        let report =
            run_device::<StormSketch>(cfg, Box::new(ReplayStream::new(ds.clone())), chaos);
        assert_eq!(report.examples, 48);
        assert_eq!(report.rounds, 6);
        let faults = fault_stats.snapshot();
        assert!(faults.drops > 0, "plan must actually drop: {faults:?}");
        let msgs: Vec<Message> = rx.iter().collect();
        let (merged, done, _) = reassemble(&msgs);
        assert_eq!(done, 48);
        let reference = reference_sketch(&ds);
        assert_eq!(merged.grid().counts_u32(), reference.grid().counts_u32());
        assert_eq!(merged.count(), 48);
        // Catch-up frames were delivered and accounted as retransmit
        // bytes on the link.
        assert!(report.retransmits > 0, "{report:?}");
        assert!(stats.snapshot().retransmit_bytes() > 0);
    }

    #[test]
    fn crashed_device_backfills_barriers_and_ships_everything() {
        let ds = toy_dataset(60);
        let (link, rx, _) = Link::new(256, 0, 0);
        let mut cfg = dev_cfg(7, 6);
        cfg.crash = Some((2, 2)); // silent for rounds 2 and 3
        let report =
            run_device::<StormSketch>(cfg, Box::new(ReplayStream::new(ds.clone())), plain(link));
        assert_eq!(report.crashed_rounds, 2);
        assert_eq!(report.examples, 60, "backlog drained after restart");
        let msgs: Vec<Message> = rx.iter().collect();
        // Every round is eventually acked exactly once, crashed rounds
        // with zero examples.
        let mut acked: Vec<(u64, u64)> = msgs
            .iter()
            .filter_map(|m| match m {
                Message::EndRound { epoch, examples, .. } => Some((*epoch, *examples)),
                _ => None,
            })
            .collect();
        acked.sort_unstable();
        assert_eq!(acked.len(), 6);
        assert_eq!(acked.iter().map(|&(e, _)| e).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(acked[2].1, 0);
        assert_eq!(acked[3].1, 0);
        let (merged, done, _) = reassemble(&msgs);
        assert_eq!(done, 60);
        assert_eq!(merged.grid().counts_u32(), reference_sketch(&ds).grid().counts_u32());
    }

    #[test]
    fn crash_covering_final_round_drains_in_epilogue() {
        let ds = toy_dataset(40);
        let (link, rx, _) = Link::new(256, 0, 0);
        let mut cfg = dev_cfg(8, 4);
        cfg.crash = Some((2, 2)); // rounds 2 and 3 (the final round) down
        let report =
            run_device::<StormSketch>(cfg, Box::new(ReplayStream::new(ds.clone())), plain(link));
        assert_eq!(report.examples, 40);
        let msgs: Vec<Message> = rx.iter().collect();
        let (merged, done, _) = reassemble(&msgs);
        assert_eq!(done, 40);
        assert_eq!(merged.grid().counts_u32(), reference_sketch(&ds).grid().counts_u32());
        assert_eq!(merged.count(), 40);
    }

    #[test]
    fn straggler_rounds_defer_acks_but_preserve_the_sketch() {
        let ds = toy_dataset(50);
        let (link, rx, _) = Link::new(256, 0, 0);
        let mut cfg = dev_cfg(9, 5);
        // Every non-final round straggles; drops/dups/delays off so the
        // effect is isolated.
        cfg.plan = Some(FaultPlan {
            straggle_per_mille: 1000,
            max_straggle: 2,
            ..FaultPlan::quiet(13)
        });
        let chaos = ChaosLink::new(link, cfg.id as u64, cfg.plan);
        let report =
            run_device::<StormSketch>(cfg, Box::new(ReplayStream::new(ds.clone())), chaos);
        assert!(report.straggled > 0, "{report:?}");
        assert_eq!(report.examples, 50);
        let msgs: Vec<Message> = rx.iter().collect();
        let mut acked: Vec<u64> = msgs
            .iter()
            .filter_map(|m| match m {
                Message::EndRound { epoch, .. } => Some(*epoch),
                _ => None,
            })
            .collect();
        acked.sort_unstable();
        assert_eq!(acked, vec![0, 1, 2, 3, 4], "every round acked exactly once");
        let (merged, done, _) = reassemble(&msgs);
        assert_eq!(done, 50);
        assert_eq!(merged.grid().counts_u32(), reference_sketch(&ds).grid().counts_u32());
    }

    #[test]
    fn private_device_ships_deterministic_noised_v3_frames() {
        // epsilon > 0: every shipped frame carries the privacy bit on the
        // v3 wire, two identical runs ship byte-identical frames (the
        // no-double-spend property rests on this determinism), and the
        // device's own report still accounts every example exactly.
        let run = || {
            let ds = toy_dataset(50);
            let (link, rx, _) = Link::new(64, 0, 0);
            let mut cfg = dev_cfg(11, 4);
            cfg.epsilon = 0.8;
            let report = run_device::<StormSketch>(
                cfg,
                Box::new(ReplayStream::new(ds)),
                plain(link),
            );
            (report, rx.iter().collect::<Vec<Message>>())
        };
        let (report, msgs) = run();
        assert_eq!(report.examples, 50);
        let mut frames = 0;
        for m in &msgs {
            if let Message::Delta { payload, .. } = m {
                frames += 1;
                assert_eq!(
                    u16::from_le_bytes(payload[4..6].try_into().unwrap()),
                    3,
                    "private deltas must ship the v3 wire"
                );
                let d = decode_delta(payload).unwrap();
                assert!(d.private, "privacy bit must ride the wire");
            }
        }
        assert!(frames > 0, "the device shipped nothing");
        let (_, msgs_again) = run();
        let payloads = |ms: &[Message]| {
            ms.iter()
                .filter_map(|m| match m {
                    Message::Delta { payload, .. } => Some(payload.to_vec()),
                    _ => None,
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(payloads(&msgs), payloads(&msgs_again), "noise must be seed-deterministic");
    }

    /// Labelled toy dataset: same features, ±1 labels.
    fn toy_labelled_dataset(n: usize) -> Dataset {
        let x = Matrix::from_fn(n, 2, |r, c| ((r + c) % 5) as f64 * 0.1);
        let y = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        Dataset::new("dev-clf", x, y)
    }

    #[test]
    fn classification_device_ships_task_tagged_deltas_that_reassemble() {
        // A classifier device runs the identical round protocol — under
        // drops — and its task-tagged deltas reassemble into a classifier
        // model equal to a one-shot local build.
        use crate::config::Task;
        let ds = toy_labelled_dataset(48);
        let (link, rx, _) = Link::new(256, 0, 0);
        let mut cfg = dev_cfg(10, 5);
        cfg.storm.task = Task::Classification;
        cfg.plan = Some(FaultPlan::drop_only(1, 1000));
        let chaos = ChaosLink::new(link, cfg.id as u64, cfg.plan);
        let report = run_device::<StormModel>(cfg, Box::new(ReplayStream::new(ds.clone())), chaos);
        assert_eq!(report.examples, 48);
        assert_eq!(report.rounds, 5);
        let msgs: Vec<Message> = rx.iter().collect();
        let mut merged = StormModel::new(cfg.storm, 3, 42);
        let mut seen = std::collections::BTreeSet::new();
        let mut done = 0;
        for msg in &msgs {
            match msg {
                Message::Delta { from, epoch, payload } => {
                    if !seen.insert((*from, *epoch)) {
                        continue;
                    }
                    let d = decode_delta(payload).unwrap();
                    assert_eq!(d.cfg.task, Task::Classification, "task bit must ride the wire");
                    merged.apply_delta(&d);
                }
                Message::Done { examples, .. } => done = *examples,
                Message::EndRound { .. } => {}
            }
        }
        assert_eq!(done, 48);
        let mut reference = StormModel::new(cfg.storm, 3, 42);
        for i in 0..ds.len() {
            reference.insert(&ds.augmented(i));
        }
        assert_eq!(merged.grid().counts_u32(), reference.grid().counts_u32());
        assert_eq!(merged.count(), 48);
    }
}
