//! A simulated edge device: owns a local stream and a local STORM sketch,
//! ingests in batches, and periodically flushes sketch *deltas* upstream.
//!
//! Flushing deltas (the counts accumulated since the last flush) rather
//! than cumulative sketches makes upstream aggregation idempotent-free
//! simple addition and keeps every wire message the same size — the
//! mergeable-summary property doing real work.

use super::network::{Link, Message};
use crate::config::StormConfig;
use crate::data::stream::StreamSource;
use crate::sketch::serialize::encode;
use crate::sketch::storm::StormSketch;
use crate::sketch::Sketch;

/// Device runtime parameters.
#[derive(Clone, Copy, Debug)]
pub struct DeviceConfig {
    pub id: usize,
    /// Ingest batch size.
    pub batch: usize,
    /// Flush the delta sketch upstream every `flush_batches` batches.
    pub flush_batches: usize,
    /// Sketch configuration (must match fleet-wide; merging enforces it).
    pub storm: StormConfig,
    /// Shared hash-family seed (fleet-wide).
    pub family_seed: u64,
    /// Augmented example dimension (d + 1).
    pub dim: usize,
}

/// Summary the device thread returns.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeviceReport {
    pub id: usize,
    pub examples: u64,
    pub batches: u64,
    pub flushes: u64,
    pub ingest_secs: f64,
}

/// Run one device to stream exhaustion: sketch locally, flush deltas over
/// the link, then send `Done`. This is the body of each fleet thread.
pub fn run_device(
    cfg: DeviceConfig,
    mut stream: Box<dyn StreamSource>,
    link: Link,
) -> DeviceReport {
    let mut delta = StormSketch::new(cfg.storm, cfg.dim, cfg.family_seed);
    let mut report = DeviceReport { id: cfg.id, ..Default::default() };
    let timer = crate::util::timer::Timer::start();
    let mut batches_since_flush = 0usize;
    loop {
        let batch = stream.next_batch(cfg.batch);
        if batch.is_empty() {
            break;
        }
        // Fused batch sketching: one pass over the projection bank per
        // batch, bit-identical counters to per-example inserts.
        delta.insert_batch(&batch);
        report.examples += batch.len() as u64;
        report.batches += 1;
        batches_since_flush += 1;
        if batches_since_flush >= cfg.flush_batches && delta.count() > 0 {
            if flush(&mut delta, &cfg, &link) {
                report.flushes += 1;
            }
            batches_since_flush = 0;
        }
    }
    if delta.count() > 0 && flush(&mut delta, &cfg, &link) {
        report.flushes += 1;
    }
    report.ingest_secs = timer.elapsed_secs();
    let _ = link.send(Message::Done { device_id: cfg.id, examples: report.examples });
    report
}

/// Serialize + ship the delta, then reset it. Returns false if the link is
/// down (aggregator gone) — the device stops flushing but keeps counting.
fn flush(delta: &mut StormSketch, cfg: &DeviceConfig, link: &Link) -> bool {
    let bytes = encode(delta);
    let ok = link.send(Message::Delta(bytes)).is_ok();
    *delta = StormSketch::new(cfg.storm, cfg.dim, cfg.family_seed);
    ok
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::data::stream::ReplayStream;
    use crate::edge::network::Link;
    use crate::linalg::matrix::Matrix;
    use crate::sketch::serialize::decode;

    fn toy_dataset(n: usize) -> Dataset {
        let x = Matrix::from_fn(n, 2, |r, c| ((r + c) % 5) as f64 * 0.1);
        let y = (0..n).map(|i| (i % 3) as f64 * 0.1).collect();
        Dataset::new("dev", x, y)
    }

    fn dev_cfg(id: usize) -> DeviceConfig {
        DeviceConfig {
            id,
            batch: 8,
            flush_batches: 2,
            storm: StormConfig { rows: 10, power: 3, saturating: true },
            family_seed: 42,
            dim: 3,
        }
    }

    #[test]
    fn device_sketches_whole_stream() {
        let ds = toy_dataset(50);
        let (link, rx, _) = Link::new(64, 0, 0);
        let report = run_device(dev_cfg(0), Box::new(ReplayStream::new(ds.clone())), link);
        assert_eq!(report.examples, 50);
        assert_eq!(report.batches, 7); // ceil(50/8)
        // Reassemble: merged deltas equal a locally-built sketch.
        let mut merged = StormSketch::new(dev_cfg(0).storm, 3, 42);
        let mut done = false;
        for msg in rx.iter() {
            match msg {
                Message::Delta(b) => merged.merge_from(&decode(&b).unwrap()),
                Message::Done { examples, .. } => {
                    assert_eq!(examples, 50);
                    done = true;
                }
            }
        }
        assert!(done);
        let mut reference = StormSketch::new(dev_cfg(0).storm, 3, 42);
        for i in 0..ds.len() {
            reference.insert(&ds.augmented(i));
        }
        assert_eq!(merged.grid().data(), reference.grid().data());
        assert_eq!(merged.count(), 50);
    }

    #[test]
    fn flush_cadence_respected() {
        let ds = toy_dataset(64); // 8 batches of 8 -> flush every 2 -> 4 flushes
        let (link, rx, _) = Link::new(64, 0, 0);
        let report = run_device(dev_cfg(1), Box::new(ReplayStream::new(ds)), link);
        assert_eq!(report.flushes, 4);
        let deltas = rx.iter().filter(|m| matches!(m, Message::Delta(_))).count();
        assert_eq!(deltas, 4);
    }

    #[test]
    fn empty_stream_sends_only_done() {
        let ds = toy_dataset(0);
        let (link, rx, _) = Link::new(8, 0, 0);
        let report = run_device(dev_cfg(2), Box::new(ReplayStream::new(ds)), link);
        assert_eq!(report.examples, 0);
        assert_eq!(report.flushes, 0);
        let msgs: Vec<Message> = rx.iter().collect();
        assert_eq!(msgs.len(), 1);
        assert!(matches!(msgs[0], Message::Done { .. }));
    }

    #[test]
    fn dead_link_does_not_panic() {
        let ds = toy_dataset(30);
        let (link, rx, _) = Link::new(8, 0, 0);
        drop(rx);
        let report = run_device(dev_cfg(3), Box::new(ReplayStream::new(ds)), link);
        assert_eq!(report.examples, 30);
        assert_eq!(report.flushes, 0);
    }
}
