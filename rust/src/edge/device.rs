//! A simulated edge device: owns a local stream and a *long-lived* local
//! STORM sketch, ingests between sync barriers, and at each sync round
//! ships only the counters that changed since the last round (an
//! epoch-tagged [`crate::sketch::delta::SketchDelta`] on wire format v2).
//!
//! Shipping deltas rather than cumulative sketches keeps upstream
//! aggregation idempotent-free simple addition, and the sparse v2 wire
//! encoding makes a quiet round cost bytes proportional to what actually
//! changed — the mergeable-summary property doing real work, per round.

use super::network::{Link, Message};
use crate::config::StormConfig;
use crate::data::stream::StreamSource;
use crate::sketch::serialize::encode_delta;
use crate::sketch::storm::StormSketch;

/// Device runtime parameters.
#[derive(Clone, Copy, Debug)]
pub struct DeviceConfig {
    pub id: usize,
    /// Ingest batch size.
    pub batch: usize,
    /// Number of sync rounds the fleet runs; the device emits (at most)
    /// one delta per round and always one `EndRound` per round.
    pub rounds: usize,
    /// Per-round example budget when the stream cannot report its length
    /// (`StreamSource::remaining_hint` returns `None`); hinted streams
    /// split their remaining length evenly across rounds instead.
    pub fallback_round_examples: usize,
    /// Sketch configuration (must match fleet-wide; merging enforces it).
    pub storm: StormConfig,
    /// Shared hash-family seed (fleet-wide).
    pub family_seed: u64,
    /// Augmented example dimension (d + 1).
    pub dim: usize,
}

/// Summary the device thread returns.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeviceReport {
    pub id: usize,
    pub examples: u64,
    pub batches: u64,
    /// Sync rounds completed (always `cfg.rounds`, even past stream end —
    /// quiet rounds still answer the barrier).
    pub rounds: u64,
    /// Non-empty deltas actually shipped upstream.
    pub deltas: u64,
    pub ingest_secs: f64,
}

/// Run one device through all sync rounds: sketch into the long-lived
/// local sketch, emit one delta + `EndRound` per round, then `Done`.
/// This is the body of each fleet thread.
pub fn run_device(
    cfg: DeviceConfig,
    mut stream: Box<dyn StreamSource>,
    link: Link,
) -> DeviceReport {
    let rounds = cfg.rounds.max(1);
    let mut sketch = StormSketch::new(cfg.storm, cfg.dim, cfg.family_seed);
    let mut snap = sketch.snapshot();
    let mut report = DeviceReport { id: cfg.id, ..Default::default() };
    let timer = crate::util::timer::Timer::start();
    // The stream's own length hint sizes both the per-round budget and
    // the reusable batch buffer (no per-batch allocation).
    let hint = stream.remaining_hint();
    let budget = match hint {
        Some(n) => n.div_ceil(rounds).max(1),
        None => cfg.fallback_round_examples.max(1),
    };
    let mut buf: Vec<crate::data::stream::Example> =
        Vec::with_capacity(cfg.batch.min(hint.unwrap_or(cfg.batch)).max(1));
    let mut exhausted = false;
    for epoch in 0..rounds as u64 {
        // The final round drains the stream completely so a stale or
        // missing hint never strands examples.
        let last = epoch + 1 == rounds as u64;
        let mut ingested = 0usize;
        while !exhausted && (last || ingested < budget) {
            let want = if last { cfg.batch } else { cfg.batch.min(budget - ingested) };
            stream.next_batch_into(want, &mut buf);
            if buf.is_empty() {
                exhausted = true;
                break;
            }
            // Fused batch sketching: one pass over the projection bank per
            // batch, bit-identical counters to per-example inserts.
            sketch.insert_batch(&buf);
            ingested += buf.len();
            report.batches += 1;
        }
        report.examples += ingested as u64;
        let delta = sketch.delta_since(&snap, epoch);
        if !delta.is_empty() {
            // A dead link (aggregator gone) stops shipping but the device
            // keeps sketching and counting.
            if link
                .send(Message::Delta { epoch, payload: encode_delta(&delta) })
                .is_ok()
            {
                report.deltas += 1;
            }
            snap = sketch.snapshot();
        }
        report.rounds += 1;
        let _ = link.send(Message::EndRound {
            device_id: cfg.id,
            epoch,
            examples: ingested as u64,
        });
    }
    report.ingest_secs = timer.elapsed_secs();
    let _ = link.send(Message::Done { device_id: cfg.id, examples: report.examples });
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::data::stream::ReplayStream;
    use crate::edge::network::Link;
    use crate::linalg::matrix::Matrix;
    use crate::sketch::serialize::decode_delta;
    use crate::sketch::Sketch;

    fn toy_dataset(n: usize) -> Dataset {
        let x = Matrix::from_fn(n, 2, |r, c| ((r + c) % 5) as f64 * 0.1);
        let y = (0..n).map(|i| (i % 3) as f64 * 0.1).collect();
        Dataset::new("dev", x, y)
    }

    fn dev_cfg(id: usize, rounds: usize) -> DeviceConfig {
        DeviceConfig {
            id,
            batch: 8,
            rounds,
            fallback_round_examples: 16,
            storm: StormConfig { rows: 10, power: 3, saturating: true },
            family_seed: 42,
            dim: 3,
        }
    }

    /// Reassemble every delta a device shipped into one sketch.
    fn reassemble(msgs: &[Message]) -> (StormSketch, u64, Vec<u64>) {
        let mut merged = StormSketch::new(dev_cfg(0, 1).storm, 3, 42);
        let mut done_examples = 0;
        let mut epochs = Vec::new();
        for msg in msgs {
            match msg {
                Message::Delta { epoch, payload } => {
                    let d = decode_delta(payload).unwrap();
                    assert_eq!(d.epoch, *epoch, "frame epoch must match message epoch");
                    merged.apply_delta(&d);
                    epochs.push(*epoch);
                }
                Message::Done { examples, .. } => done_examples = *examples,
                Message::EndRound { .. } => {}
            }
        }
        (merged, done_examples, epochs)
    }

    #[test]
    fn device_sketches_whole_stream_across_rounds() {
        let ds = toy_dataset(50);
        let (link, rx, _) = Link::new(64, 0, 0);
        let report = run_device(dev_cfg(0, 4), Box::new(ReplayStream::new(ds.clone())), link);
        assert_eq!(report.examples, 50);
        assert_eq!(report.rounds, 4);
        let msgs: Vec<Message> = rx.iter().collect();
        let ends = msgs.iter().filter(|m| matches!(m, Message::EndRound { .. })).count();
        assert_eq!(ends, 4, "one EndRound per round");
        let (merged, done_examples, epochs) = reassemble(&msgs);
        assert_eq!(done_examples, 50);
        // Deltas tagged with consecutive epochs, applied in order equal a
        // locally-built one-shot sketch.
        assert!(epochs.windows(2).all(|w| w[0] < w[1]), "{epochs:?}");
        let mut reference = StormSketch::new(dev_cfg(0, 1).storm, 3, 42);
        for i in 0..ds.len() {
            reference.insert(&ds.augmented(i));
        }
        assert_eq!(merged.grid().data(), reference.grid().data());
        assert_eq!(merged.count(), 50);
    }

    #[test]
    fn hinted_stream_splits_examples_evenly_across_rounds() {
        let ds = toy_dataset(64);
        let (link, rx, _) = Link::new(64, 0, 0);
        let report = run_device(dev_cfg(1, 4), Box::new(ReplayStream::new(ds)), link);
        assert_eq!(report.examples, 64);
        assert_eq!(report.deltas, 4);
        // 64 hinted examples over 4 rounds -> 16 per round.
        let per_round: Vec<u64> = rx
            .iter()
            .filter_map(|m| match m {
                Message::EndRound { examples, .. } => Some(examples),
                _ => None,
            })
            .collect();
        assert_eq!(per_round, vec![16, 16, 16, 16]);
    }

    /// Strips the length hint off a stream — the unknown-length regime
    /// (an open-ended sensor), which is what forces the fallback budget
    /// and the mid-run exhaustion path.
    struct NoHint(ReplayStream);

    impl crate::data::stream::StreamSource for NoHint {
        fn next_example(&mut self) -> Option<crate::data::stream::Example> {
            self.0.next_example()
        }
    }

    #[test]
    fn exhausted_stream_still_answers_every_round() {
        // Hintless stream of 10 examples, 5 rounds of fallback budget 3
        // (batch 2): rounds 0..3 ingest 3+3+3+1, the stream dries up
        // mid-round-3, and round 4 must still send EndRound with zero
        // examples — quiet rounds answer the barrier.
        let ds = toy_dataset(10);
        let (link, rx, _) = Link::new(64, 0, 0);
        let mut cfg = dev_cfg(2, 5);
        cfg.batch = 2;
        cfg.fallback_round_examples = 3;
        let report = run_device(cfg, Box::new(NoHint(ReplayStream::new(ds))), link);
        assert_eq!(report.examples, 10);
        assert_eq!(report.rounds, 5);
        let ends: Vec<(u64, u64)> = rx
            .iter()
            .filter_map(|m| match m {
                Message::EndRound { epoch, examples, .. } => Some((epoch, examples)),
                _ => None,
            })
            .collect();
        assert_eq!(
            ends,
            vec![(0, 3), (1, 3), (2, 3), (3, 1), (4, 0)],
            "fallback budget + mid-run exhaustion + quiet final round"
        );
    }

    #[test]
    fn empty_stream_sends_endrounds_and_done_only() {
        let ds = toy_dataset(0);
        let (link, rx, _) = Link::new(16, 0, 0);
        let report = run_device(dev_cfg(3, 3), Box::new(ReplayStream::new(ds)), link);
        assert_eq!(report.examples, 0);
        assert_eq!(report.deltas, 0);
        let msgs: Vec<Message> = rx.iter().collect();
        assert_eq!(msgs.len(), 4); // 3 EndRound + Done
        assert!(msgs.iter().all(|m| !matches!(m, Message::Delta { .. })));
        assert!(matches!(msgs.last().unwrap(), Message::Done { .. }));
    }

    #[test]
    fn dead_link_does_not_panic() {
        let ds = toy_dataset(30);
        let (link, rx, _) = Link::new(8, 0, 0);
        drop(rx);
        let report = run_device(dev_cfg(4, 3), Box::new(ReplayStream::new(ds)), link);
        assert_eq!(report.examples, 30);
        assert_eq!(report.deltas, 0);
        assert_eq!(report.rounds, 3);
    }

    #[test]
    fn single_round_device_ships_one_delta() {
        let ds = toy_dataset(40);
        let (link, rx, _) = Link::new(64, 0, 0);
        let report = run_device(dev_cfg(5, 1), Box::new(ReplayStream::new(ds)), link);
        assert_eq!(report.deltas, 1);
        let deltas = rx.iter().filter(|m| matches!(m, Message::Delta { .. })).count();
        assert_eq!(deltas, 1);
    }
}
