//! Fleet orchestration: wire up the aggregation topology with simulated
//! links and run `sync_rounds` rounds of delta synchronization. Each
//! round, devices push the counters changed since the last barrier;
//! aggregators fold the round's deltas in place and forward one merged
//! delta upstream; the leader applies the round and hands its evolving
//! sketch to the `on_round` callback — which is where the coordinator
//! interleaves training (the anytime model).
//!
//! **Two schedulers, one protocol.** The protocol logic lives in
//! resumable state machines (`DeviceMachine`, `AggMachine`,
//! `LeaderMachine`) that two schedulers drive:
//!
//! * the **worker-pool executor** ([`super::executor`], the default):
//!   device state lives in a contiguous arena, a bounded pool of
//!   `[fleet] workers` threads steps devices in deterministic rounds,
//!   and messages flow through per-node outboxes drained in stage
//!   order. A million-device fleet costs roughly its sketch bytes, not
//!   a million OS threads.
//! * the **thread-per-node reference** ([`run_fleet_model_threaded`]):
//!   one OS thread per device and aggregator, bounded channels for
//!   backpressure. Kept as the oracle the executor is equivalence-
//!   tested against.
//!
//! **Task-generic.** The whole pipeline is generic over
//! [`crate::sketch::RiskSketch`] (`run_fleet_model*`): a regression
//! fleet and a classification fleet run the *same* protocol — deltas,
//! barriers, quorums, fault recovery — because everything above the
//! model is counter algebra. The aggregator tier never even constructs a
//! model; it folds task-tagged deltas. The `run_fleet*` wrappers keep
//! the seed's regression-typed signatures.
//!
//! Because counter merging is associative and commutative, R rounds of
//! delta merges produce a leader sketch bit-identical to the one-shot
//! full-sketch merge (property-tested in `proptest_invariants.rs`);
//! rounds change *when* information arrives and what it costs on the
//! wire, never what the final counters are.
//!
//! **Width tiers.** Device sketches may run at a narrower counter width
//! than the upstream accumulators (`[fleet] device_counter_width`
//! overriding `[storm] counter_width`): an MCU-class device holds `u8`
//! cells, its round deltas ship width-tagged v3 frames, and every merge
//! point folds them into wide counters *exactly* — widening merges are
//! lossless, so as long as no device cell saturates locally the fleet
//! result is counter-for-counter identical to an all-`u32` run
//! (property-tested: `prop_widening_merge_exact_without_saturation`).
//!
//! **Fault-tolerant sync.** The same invariant holds under a chaotic
//! network (`[fleet] faults_seed`, see [`super::faults`]): the protocol
//! guarantees every device increment reaches the leader *exactly once*
//! no matter how messily frames arrive.
//!
//! * **Exactly-once folds.** Every delta frame carries its sender id;
//!   merge nodes deduplicate on `(from, epoch)`, so replayed frames are
//!   no-ops. Senders never reuse an epoch tag for different payloads.
//! * **Quorum barriers.** A round closes once `min_quorum` of a node's
//!   direct children have acked it (`0` = all children, the default —
//!   which preserves seed behaviour bit-for-bit). Stragglers stop
//!   stalling the leader; their data arrives late and is still folded
//!   exactly once.
//! * **Catch-up.** Deltas that arrive after their round closed are
//!   applied directly (leader) or pooled and re-shipped under a fresh
//!   epoch tag (aggregators); deltas whose upstream send was dropped
//!   join the same pool. At stream end every node flushes its pool,
//!   retrying until the link confirms delivery — so the only way to
//!   lose data is to lose the node itself.

use super::device::{run_device, DeviceConfig, DeviceReport};
use super::faults::{ChaosLink, Delivery, FaultPlan, FaultStats, FaultSummary};
use super::network::{Link, LinkSnapshot, Message};
use super::topology::{plan, Stage, Topology, LEADER};
use crate::config::{FleetConfig, StormConfig};
use crate::data::stream::StreamSource;
use crate::sketch::delta::{absorb_all_sharded, pool_delta, SketchDelta};
use crate::sketch::serialize::{decode_delta, encode_delta};
use crate::sketch::storm::StormSketch;
use crate::sketch::RiskSketch;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// What one closed sync round looked like from the leader.
#[derive(Clone, Copy, Debug)]
pub struct RoundStat {
    pub round: u64,
    /// Examples acked by the quorum that closed this round.
    pub examples: u64,
    /// Cumulative examples in the leader sketch after the round closed.
    pub leader_count: u64,
    /// Delta messages the leader folded this round.
    pub deltas: u64,
}

/// Result of a fleet run, generic over the sketch model (defaults to the
/// regression sketch, the seed behaviour).
pub struct FleetResult<M = StormSketch> {
    /// The leader's merged model — the only artifact that leaves the
    /// fleet, and everything training needs.
    pub sketch: M,
    pub devices: Vec<DeviceReport>,
    /// Aggregate link statistics across every hop (with per-round
    /// breakdown in `network.rounds`).
    pub network: LinkSnapshot,
    pub wall_secs: f64,
    /// Total examples ingested fleet-wide.
    pub examples: u64,
    /// Per-round leader-side statistics, in round order.
    pub rounds: Vec<RoundStat>,
    /// Fault events the chaos layer actually injected (all-zero on the
    /// default ideal network).
    pub faults: FaultSummary,
}

/// Per-epoch accumulation at a merge point (aggregator or leader): the
/// folded delta, the round's example tally, and how many children have
/// closed the round. The leader additionally buffers incoming deltas
/// (`fold_batched`) so its round fold can be sharded across the worker
/// pool by counter-cell range.
#[derive(Default)]
struct RoundAccum {
    delta: Option<SketchDelta>,
    /// Deltas awaiting the next sharded flush (leader path only;
    /// aggregator fan-in is bounded, so aggregators fold on arrival).
    batch: Vec<SketchDelta>,
    examples: u64,
    ends: usize,
    deltas: u64,
}

/// Leader fold batch: enough deltas per flush to amortize the scoped
/// fan-out, few enough that the buffered frames stay a small bounded
/// multiple of one sketch.
const LEADER_FOLD_BATCH: usize = 64;

impl RoundAccum {
    fn fold(&mut self, d: SketchDelta) {
        self.deltas += 1;
        match &mut self.delta {
            Some(acc) => acc.merge_from(&d),
            None => self.delta = Some(d),
        }
    }

    /// Buffer `d` for the next sharded flush, flushing when the batch
    /// fills. With `workers = 1` this degenerates to the sequential
    /// arrival-order chain `fold` performs.
    fn fold_batched(&mut self, d: SketchDelta, workers: usize) {
        self.deltas += 1;
        self.batch.push(d);
        if self.batch.len() >= LEADER_FOLD_BATCH {
            self.flush(workers);
        }
    }

    /// Fold the buffered batch into the accumulator, sharded across
    /// `workers` by cell range — per-cell bit-identical to the
    /// sequential chain (see [`absorb_all_sharded`]).
    fn flush(&mut self, workers: usize) {
        if self.batch.is_empty() {
            return;
        }
        let batch = std::mem::take(&mut self.batch);
        match &mut self.delta {
            Some(acc) => absorb_all_sharded(acc, &batch, workers),
            None => {
                let mut it = batch.into_iter();
                let mut acc = it.next().expect("non-empty batch");
                let rest: Vec<SketchDelta> = it.collect();
                absorb_all_sharded(&mut acc, &rest, workers);
                self.delta = Some(acc);
            }
        }
    }
}

/// Exactly-once `(from, epoch)` filter for one merge node. For the known
/// child set and the run's bounded epoch range this is a dense bitset —
/// at a million-device star the `BTreeSet<(usize, u64)>` it replaces
/// costs hundreds of MB and a logarithmic probe per frame — with a
/// `BTreeSet` fallback for out-of-range senders or epochs (which the
/// protocol never produces, but correctness must not depend on that).
struct Dedup {
    /// Sorted direct-child node ids; a child's rank is its bit row.
    children: Vec<usize>,
    /// Bits per child: the protocol never tags an epoch past the round
    /// count (the exit flush uses `max(pool epoch, next)`, both bounded
    /// by `rounds`), +2 slack for the inclusive bound.
    bits_per: usize,
    bits: Vec<u64>,
    overflow: BTreeSet<(usize, u64)>,
}

impl Dedup {
    fn new(children: &[usize], rounds: u64) -> Dedup {
        let mut children = children.to_vec();
        children.sort_unstable();
        let bits_per = rounds as usize + 2;
        let words = (children.len() * bits_per).div_ceil(64);
        Dedup { children, bits_per, bits: vec![0; words], overflow: BTreeSet::new() }
    }

    /// True exactly the first time `(from, epoch)` is seen.
    fn insert(&mut self, from: usize, epoch: u64) -> bool {
        if let Ok(slot) = self.children.binary_search(&from) {
            if (epoch as usize) < self.bits_per {
                let idx = slot * self.bits_per + epoch as usize;
                let mask = 1u64 << (idx % 64);
                let word = &mut self.bits[idx / 64];
                let fresh = *word & mask == 0;
                *word |= mask;
                return fresh;
            }
        }
        self.overflow.insert((from, epoch))
    }
}

/// Record one `EndRound` from a child, then advance the in-order barrier:
/// close round `next` (and any directly following quorate rounds) as
/// soon as `quorum` children have ended it, handing each round's
/// accumulator to `close`. Shared by the leader loop and the aggregator
/// nodes — only the close action differs. Callers deduplicate acks and
/// discard acks for already-closed rounds before calling.
fn end_round_and_drain(
    pending: &mut BTreeMap<u64, RoundAccum>,
    next: &mut u64,
    quorum: usize,
    epoch: u64,
    examples: u64,
    mut close: impl FnMut(u64, RoundAccum),
) {
    let acc = pending.entry(epoch).or_default();
    acc.examples += examples;
    acc.ends += 1;
    // A round closes when a quorum of direct children has ended it; with
    // the default full quorum and FIFO links the round's deltas are
    // guaranteed to have arrived first, and anything later is handled
    // by the exactly-once catch-up path.
    while pending.get(next).is_some_and(|a| a.ends >= quorum) {
        let acc = pending.remove(next).expect("pending round");
        close(*next, acc);
        *next += 1;
    }
}

/// The per-node barrier quorum: `min_quorum = 0` (default) means all
/// direct children, anything else is clamped to `1..=children`.
pub(crate) fn quorum_of(min_quorum: usize, children: usize) -> usize {
    if min_quorum == 0 {
        children
    } else {
        min_quorum.clamp(1, children)
    }
}

/// Per-round ingestion budget for streams that cannot report their
/// length: sized so steady-state delta traffic stays well below what
/// shipping the raw bytes would cost (the whole point of sketches).
pub(crate) fn fallback_round_examples(storm: &StormConfig, dim: usize, batch: usize) -> usize {
    const FLUSH_RAW_MULTIPLE: usize = 8;
    let wire = crate::sketch::serialize::wire_bytes(storm);
    let raw_bytes_per_example = (dim * 8).max(1);
    (FLUSH_RAW_MULTIPLE * wire / raw_bytes_per_example).max(4 * batch)
}

/// Run a regression fleet over per-device streams. `dim` is the
/// augmented example dimension (d + 1); `family_seed` fixes the shared
/// hash family. Thin wrapper over [`run_fleet_model`] at the seed's
/// regression type — the task-generic entry points are the `*_model`
/// family.
pub fn run_fleet(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
) -> FleetResult {
    run_fleet_model::<StormSketch>(fleet, storm, topology, dim, family_seed, streams)
}

/// [`run_fleet`] with a per-round hook: `on_round(round, sketch)` runs on
/// the caller's thread right after the leader closes a round, while the
/// devices keep streaming the next round in the background — training
/// interleaves with ingestion instead of waiting for the whole fleet.
/// The fault plan, if any, comes from `fleet.faults_seed`.
pub fn run_fleet_with(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
    on_round: impl FnMut(u64, &StormSketch),
) -> FleetResult {
    run_fleet_model_with::<StormSketch, _>(
        fleet, storm, topology, dim, family_seed, streams, on_round,
    )
}

/// [`run_fleet_with`] under an explicit fault plan (tests and the
/// resilience benchmarks construct controlled plans directly; `None` is
/// the ideal network).
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_chaos(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
    fault_plan: Option<FaultPlan>,
    on_round: impl FnMut(u64, &StormSketch),
) -> FleetResult {
    run_fleet_model_chaos::<StormSketch, _>(
        fleet, storm, topology, dim, family_seed, streams, fault_plan, on_round,
    )
}

/// Task-generic fleet: run any [`RiskSketch`] model — the regression
/// sketch, the margin classifier, or the runtime-dispatched
/// [`crate::sketch::model::StormModel`] — through the identical round
/// protocol. `dim` is the streamed example dimension (d + 1) for every
/// task.
pub fn run_fleet_model<M: RiskSketch + 'static>(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
) -> FleetResult<M> {
    run_fleet_model_with::<M, _>(fleet, storm, topology, dim, family_seed, streams, |_, _| {})
}

/// [`run_fleet_model`] with a per-round hook (see [`run_fleet_with`]).
pub fn run_fleet_model_with<M: RiskSketch + 'static, F: FnMut(u64, &M)>(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
    on_round: F,
) -> FleetResult<M> {
    let plan = fleet.faults_seed.map(FaultPlan::from_seed);
    run_fleet_model_chaos::<M, F>(fleet, storm, topology, dim, family_seed, streams, plan, on_round)
}

/// [`run_fleet_model_with`] under an explicit fault plan — the generic
/// entry every other fleet entry point delegates to. Runs on the
/// worker-pool arena executor ([`super::executor`]); `fleet.workers`
/// sizes the pool (0 = auto). The schedule never changes the result:
/// counters are bit-identical at every worker count, and to the
/// [`run_fleet_model_threaded`] reference.
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_model_chaos<M: RiskSketch + 'static, F: FnMut(u64, &M)>(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
    fault_plan: Option<FaultPlan>,
    on_round: F,
) -> FleetResult<M> {
    super::executor::run_fleet_pooled::<M, F>(
        fleet, storm, topology, dim, family_seed, streams, fault_plan, on_round,
    )
}

/// The thread-per-node reference scheduler: one OS thread per device and
/// aggregator, bounded channels for backpressure. This was the only
/// scheduler before the arena executor; it is kept public as the oracle
/// for worker-count determinism tests (the executor must be bit-identical
/// to it at any pool size) and for A/B benchmarks. It does not scale past
/// tens of thousands of devices — use [`run_fleet_model_chaos`] for that.
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_model_threaded<M: RiskSketch + 'static, F: FnMut(u64, &M)>(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
    fault_plan: Option<FaultPlan>,
    mut on_round: F,
) -> FleetResult<M> {
    assert_eq!(streams.len(), fleet.devices, "one stream per device");
    let n = fleet.devices;
    let rounds = fleet.sync_rounds.max(1);
    // Per-tier widths: devices may sketch at a narrower counter width
    // than the aggregation tier; the leader always accumulates at the
    // `storm` width so narrow deltas widen exactly on merge.
    let device_storm = StormConfig {
        counter_width: fleet.device_counter_width.unwrap_or(storm.counter_width),
        ..storm
    };
    let stages = plan(topology, n);
    let timer = crate::util::timer::Timer::start();
    let crash = fault_plan.and_then(|p| p.crash_schedule(n, rounds as u64));
    let mut fault_stats: Vec<Arc<FaultStats>> = Vec::new();

    // One link per non-leaf node (aggregators + leader), keyed by parent.
    let mut rx_for: BTreeMap<usize, Receiver<Message>> = BTreeMap::new();
    let mut tx_for: BTreeMap<usize, Link> = BTreeMap::new();
    let mut stats = Vec::new();
    for stage in &stages {
        let (link, rx, st) = Link::new(
            fleet.channel_capacity,
            fleet.link_latency_us,
            fleet.link_bandwidth_bps,
        );
        rx_for.insert(stage.parent, rx);
        tx_for.insert(stage.parent, link);
        stats.push(st);
    }
    // Map each child node to a fault-wrapped clone of its parent stage's
    // link; the child's node id keys the plan's per-link decisions.
    let mut uplink: BTreeMap<usize, ChaosLink> = BTreeMap::new();
    for stage in &stages {
        for &c in &stage.children {
            let chaos = ChaosLink::new(tx_for[&stage.parent].clone(), c as u64, fault_plan);
            fault_stats.push(chaos.stats());
            uplink.insert(c, chaos);
        }
    }
    drop(tx_for); // aggregator/device ChaosLinks hold the remaining clones

    // Device threads. Hinted streams split their length evenly over the
    // rounds; hintless streams fall back to the shared budget.
    let fallback_round_examples = fallback_round_examples(&storm, dim, fleet.batch);
    let mut device_handles = Vec::new();
    for (id, stream) in streams.into_iter().enumerate() {
        let cfg = DeviceConfig {
            id,
            batch: fleet.batch,
            rounds,
            fallback_round_examples,
            storm: device_storm,
            family_seed,
            dim,
            plan: fault_plan,
            crash: crash.and_then(|(dev, at, down)| (dev == id).then_some((at, down))),
            epsilon: fleet.epsilon_per_round,
        };
        let link = uplink.remove(&id).expect("device uplink");
        device_handles.push(std::thread::spawn(move || run_device::<M>(cfg, stream, link)));
    }

    // Aggregator threads, in stage order. Each folds its children's
    // deltas per epoch exactly once and forwards ONE merged delta +
    // EndRound per quorate round upstream, then cascades Done.
    let mut agg_handles = Vec::new();
    for stage in &stages {
        if stage.parent == LEADER {
            continue;
        }
        let rx = rx_for.remove(&stage.parent).expect("aggregator rx");
        let up = uplink.remove(&stage.parent).expect("aggregator uplink");
        let quorum = quorum_of(fleet.min_quorum, stage.children.len());
        let agg_id = stage.parent;
        let children = stage.children.clone();
        let total_rounds = rounds as u64;
        agg_handles.push(std::thread::spawn(move || {
            run_aggregator(rx, up, agg_id, &children, quorum, total_rounds)
        }));
    }

    // Leader: close rounds in epoch order, applying each round's folded
    // delta and running the caller's hook at every barrier. Late deltas
    // (stragglers under a partial quorum, catch-up frames) merge the
    // moment they arrive — counter addition is epoch-agnostic.
    let leader_stage: &Stage = stages.iter().find(|s| s.parent == LEADER).expect("leader stage");
    let leader_rx = rx_for.remove(&LEADER).expect("leader rx");
    let quorum = quorum_of(fleet.min_quorum, leader_stage.children.len());
    let mut leader = LeaderMachine::new(
        M::build(storm, dim, family_seed),
        &leader_stage.children,
        quorum,
        rounds as u64,
        1, // sequential folds: this is the reference schedule
        fleet.decay_keep_permille,
    );
    while !leader.is_done() {
        match leader_rx.recv() {
            Ok(msg) => leader.on_message(msg, &mut on_round),
            Err(_) => break,
        }
    }
    let (sketch, round_stats, examples) = leader.finish();

    let devices: Vec<DeviceReport> = device_handles
        .into_iter()
        .map(|h| h.join().expect("device thread"))
        .collect();
    for h in agg_handles {
        h.join().expect("aggregator thread");
    }
    let mut network = LinkSnapshot::default();
    for s in &stats {
        network.merge(&s.snapshot());
    }
    let mut faults = FaultSummary::default();
    for s in &fault_stats {
        faults.merge(&s.snapshot());
    }
    FleetResult {
        sketch,
        devices,
        network,
        wall_secs: timer.elapsed_secs(),
        examples,
        rounds: round_stats,
        faults,
    }
}

/// Aggregator protocol as a resumable state machine: fold every child
/// delta of an epoch exactly once (deduplicating on `(from, epoch)`),
/// and once a quorum of children closed the epoch forward the single
/// merged delta (plus the round barrier) upstream — cascading Done with
/// the summed example count after the final round. Late or drop-returned
/// increments are pooled and re-shipped under a fresh epoch tag; the
/// exit flush retries until the uplink confirms, so an aggregator never
/// exits owing data.
///
/// [`run_aggregator`] drives one machine from a blocking channel (the
/// thread-per-node path); the arena executor drives many by draining
/// child outboxes in stage order. The machine is schedule-agnostic:
/// any per-link-FIFO delivery order yields the same final counters.
pub(crate) struct AggMachine {
    agg_id: usize,
    expect: usize,
    quorum: usize,
    pending: BTreeMap<u64, RoundAccum>,
    next: u64,
    done: usize,
    examples: u64,
    seen_delta: Dedup,
    seen_end: Dedup,
    /// Increments owed upstream that missed their round: late arrivals
    /// after a quorum close, plus our own frames the fault layer dropped.
    unshipped: Option<SketchDelta>,
}

impl AggMachine {
    pub(crate) fn new(agg_id: usize, children: &[usize], quorum: usize, rounds: u64) -> AggMachine {
        AggMachine {
            agg_id,
            expect: children.len(),
            quorum,
            pending: BTreeMap::new(),
            next: 0,
            done: 0,
            examples: 0,
            seen_delta: Dedup::new(children, rounds),
            seen_end: Dedup::new(children, rounds),
            unshipped: None,
        }
    }

    /// Every direct child has cascaded Done.
    pub(crate) fn is_done(&self) -> bool {
        self.done >= self.expect
    }

    pub(crate) fn on_message(&mut self, msg: Message, up: &ChaosLink) {
        match msg {
            Message::Delta { from, epoch, payload } => {
                if !self.seen_delta.insert(from, epoch) {
                    return; // duplicate frame: exactly-once fold
                }
                let Ok(delta) = decode_delta(&payload) else { return };
                if epoch < self.next {
                    pool_delta(&mut self.unshipped, delta);
                } else {
                    self.pending.entry(epoch).or_default().fold(delta);
                }
            }
            Message::EndRound { device_id, epoch, examples: e } => {
                if !self.seen_end.insert(device_id, epoch) || epoch < self.next {
                    return; // duplicate or late ack for a closed round
                }
                let agg_id = self.agg_id;
                let unshipped = &mut self.unshipped;
                end_round_and_drain(&mut self.pending, &mut self.next, self.quorum, epoch, e, |round, acc| {
                    let mut out = acc.delta;
                    let mut catchup = false;
                    if let Some(pooled) = unshipped.take() {
                        catchup = true;
                        match &mut out {
                            Some(d) => d.absorb(&pooled),
                            None => {
                                let mut p = pooled;
                                p.epoch = round; // fresh tag: this round is ours
                                out = Some(p);
                            }
                        }
                    }
                    if let Some(delta) = out {
                        if !delta.is_empty() {
                            let msg = Message::Delta {
                                from: agg_id,
                                epoch: round,
                                payload: encode_delta(&delta).into(),
                            };
                            match up.send_class(msg, catchup) {
                                // Dropped: pool and re-ship under a
                                // later (never-used) tag.
                                Ok(Delivery::Dropped) => pool_delta(unshipped, delta),
                                Ok(Delivery::Delivered) | Err(()) => {}
                            }
                        }
                    }
                    let _ = up.send(Message::EndRound {
                        device_id: agg_id,
                        epoch: round,
                        examples: acc.examples,
                    });
                });
            }
            Message::Done { examples: e, .. } => {
                self.done += 1;
                self.examples += e;
            }
        }
    }

    /// Exit flush: pool every never-closed round's accumulator, tag the
    /// pool with an epoch this node has never sent (round `next` never
    /// closed, so `max(next, pool.epoch)` is fresh), and retry until the
    /// link confirms — the fault plan's drop-burst cap bounds the loop.
    /// Ends by cascading Done upstream. Call exactly once, after the
    /// last child message.
    pub(crate) fn finish(&mut self, up: &ChaosLink) {
        let mut pool = self.unshipped.take();
        for (_, acc) in std::mem::take(&mut self.pending) {
            if let Some(d) = acc.delta {
                pool_delta(&mut pool, d);
            }
        }
        if let Some(mut d) = pool {
            if !d.is_empty() {
                d.epoch = d.epoch.max(self.next);
                loop {
                    let msg = Message::Delta {
                        from: self.agg_id,
                        epoch: d.epoch,
                        payload: encode_delta(&d).into(),
                    };
                    match up.send_class(msg, true) {
                        Ok(Delivery::Delivered) | Err(()) => break,
                        Ok(Delivery::Dropped) => continue,
                    }
                }
            }
        }
        let _ = up.send(Message::Done { device_id: self.agg_id, examples: self.examples });
    }
}

/// Drive one [`AggMachine`] from a blocking channel (thread-per-node
/// reference path).
fn run_aggregator(
    rx: Receiver<Message>,
    up: ChaosLink,
    agg_id: usize,
    children: &[usize],
    quorum: usize,
    rounds: u64,
) {
    let mut m = AggMachine::new(agg_id, children, quorum, rounds);
    while !m.is_done() {
        match rx.recv() {
            Ok(msg) => m.on_message(msg, &up),
            Err(_) => break,
        }
    }
    m.finish(&up);
}

/// Leader protocol as a resumable state machine: close rounds in epoch
/// order, applying each round's folded delta and running the caller's
/// hook at every barrier. Late deltas (stragglers under a partial
/// quorum, catch-up frames) merge the moment they arrive — counter
/// addition is epoch-agnostic.
///
/// `fold_workers` shards the round fold across that many threads by
/// counter range; because counter merges commute per cell the result is
/// bit-identical at every shard count (the thread-per-node reference
/// passes 1).
pub(crate) struct LeaderMachine<M> {
    expect: usize,
    quorum: usize,
    fold_workers: usize,
    /// Round-boundary exponential decay: every close first scales the
    /// leader's counters (and count) to `decay_keep_permille / 1000`, so
    /// the round's fresh increments enter at full weight while older
    /// rounds fade geometrically. 1000 (the default) is an exact no-op —
    /// the cumulative algebra and its bit-identity invariants hold only
    /// there.
    decay_keep_permille: u16,
    sketch: M,
    pending: BTreeMap<u64, RoundAccum>,
    round_stats: Vec<RoundStat>,
    next_round: u64,
    done: usize,
    examples: u64,
    seen_delta: Dedup,
    seen_end: Dedup,
}

impl<M: RiskSketch> LeaderMachine<M> {
    pub(crate) fn new(
        sketch: M,
        children: &[usize],
        quorum: usize,
        rounds: u64,
        fold_workers: usize,
        decay_keep_permille: u16,
    ) -> LeaderMachine<M> {
        LeaderMachine {
            expect: children.len(),
            quorum,
            fold_workers: fold_workers.max(1),
            decay_keep_permille,
            sketch,
            pending: BTreeMap::new(),
            round_stats: Vec::new(),
            next_round: 0,
            done: 0,
            examples: 0,
            seen_delta: Dedup::new(children, rounds),
            seen_end: Dedup::new(children, rounds),
        }
    }

    /// Every direct child has cascaded Done.
    pub(crate) fn is_done(&self) -> bool {
        self.done >= self.expect
    }

    pub(crate) fn on_message(&mut self, msg: Message, on_round: &mut impl FnMut(u64, &M)) {
        match msg {
            Message::Delta { from, epoch, payload } => {
                if !self.seen_delta.insert(from, epoch) {
                    return; // duplicate frame: exactly-once fold
                }
                let delta = decode_delta(&payload).expect("valid wire delta");
                if epoch < self.next_round {
                    self.sketch.apply_delta(&delta); // late for a closed round
                } else {
                    self.pending.entry(epoch).or_default().fold_batched(delta, self.fold_workers);
                }
            }
            Message::EndRound { device_id, epoch, examples: e } => {
                if !self.seen_end.insert(device_id, epoch) || epoch < self.next_round {
                    return; // duplicate or late ack for a closed round
                }
                let sketch = &mut self.sketch;
                let round_stats = &mut self.round_stats;
                let fold_workers = self.fold_workers;
                let decay_keep = self.decay_keep_permille;
                end_round_and_drain(
                    &mut self.pending,
                    &mut self.next_round,
                    self.quorum,
                    epoch,
                    e,
                    |round, mut acc| {
                        acc.flush(fold_workers);
                        // Round boundary: fade the past before folding the
                        // present (exact no-op at the default 1000).
                        if decay_keep < 1000 {
                            sketch.decay(decay_keep);
                        }
                        if let Some(delta) = &acc.delta {
                            sketch.apply_delta(delta);
                        }
                        round_stats.push(RoundStat {
                            round,
                            examples: acc.examples,
                            leader_count: sketch.count(),
                            deltas: acc.deltas,
                        });
                        on_round(round, sketch);
                    },
                );
            }
            Message::Done { examples: e, .. } => {
                self.done += 1;
                self.examples += e;
            }
        }
    }

    /// Fold whatever never made it into a closed round: rounds that
    /// never reached quorum, and catch-up frames tagged past the last
    /// round. Everything here was already deduplicated on arrival.
    /// Returns the final sketch, the per-round stats, and the fleet-wide
    /// example tally from the Done cascade.
    pub(crate) fn finish(mut self) -> (M, Vec<RoundStat>, u64) {
        for (_, mut acc) in std::mem::take(&mut self.pending) {
            acc.flush(self.fold_workers);
            if let Some(delta) = &acc.delta {
                self.sketch.apply_delta(delta);
            }
        }
        (self.sketch, self.round_stats, self.examples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::stream::partition_streams;
    use crate::data::synthetic;

    fn small_fleet_cfg(devices: usize, sync_rounds: usize) -> FleetConfig {
        FleetConfig {
            devices,
            batch: 16,
            channel_capacity: 4,
            link_latency_us: 0,
            link_bandwidth_bps: 0,
            sync_rounds,
            min_quorum: 0,
            faults_seed: None,
            device_counter_width: None,
            workers: 0,
            fan_in: 2,
            epsilon_per_round: 0.0,
            decay_keep_permille: 1000,
            seed: 0,
        }
    }

    fn scaled_ds() -> crate::data::dataset::Dataset {
        let mut ds = synthetic::synth2d_regression(300, 0.5, 0.0, 0.05, 7);
        crate::data::scale::scale_to_unit_ball(&mut ds, 0.9);
        ds
    }

    fn reference_sketch(storm: StormConfig, seed: u64) -> (StormSketch, u64) {
        let ds = scaled_ds();
        let mut sk = StormSketch::new(storm, ds.dim() + 1, seed);
        for i in 0..ds.len() {
            sk.insert(&ds.augmented(i));
        }
        (sk, ds.len() as u64)
    }

    fn run_with(topology: Topology, devices: usize, rounds: usize) -> FleetResult {
        let ds = scaled_ds();
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let streams = partition_streams(&ds, devices, None);
        run_fleet(
            small_fleet_cfg(devices, rounds),
            storm,
            topology,
            ds.dim() + 1,
            99,
            streams,
        )
    }

    #[test]
    fn star_fleet_equals_single_device_sketch() {
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let (reference, n) = reference_sketch(storm, 99);
        let result = run_with(Topology::Star, 4, 1);
        assert_eq!(result.examples, n);
        assert_eq!(result.sketch.count(), n);
        assert_eq!(result.sketch.grid().counts_u32(), reference.grid().counts_u32());
        assert_eq!(result.faults, super::FaultSummary::default());
    }

    #[test]
    fn multi_round_sync_is_bit_identical_to_one_shot() {
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let (reference, n) = reference_sketch(storm, 99);
        for rounds in [2usize, 3, 5] {
            let result = run_with(Topology::Star, 4, rounds);
            assert_eq!(result.examples, n, "rounds={rounds}");
            assert_eq!(
                result.sketch.grid().counts_u32(),
                reference.grid().counts_u32(),
                "rounds={rounds}"
            );
            assert_eq!(result.rounds.len(), rounds, "rounds={rounds}");
            // Leader counts grow monotonically and end at n.
            let counts: Vec<u64> = result.rounds.iter().map(|r| r.leader_count).collect();
            assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
            assert_eq!(*counts.last().unwrap(), n);
            let per_round: u64 = result.rounds.iter().map(|r| r.examples).sum();
            assert_eq!(per_round, n);
        }
    }

    #[test]
    fn tree_and_chain_agree_with_star_across_rounds() {
        for rounds in [1usize, 3] {
            let star = run_with(Topology::Star, 6, rounds);
            let tree = run_with(Topology::Tree { fanout: 2 }, 6, rounds);
            let chain = run_with(Topology::Chain, 6, rounds);
            assert_eq!(star.sketch.grid().counts_u32(), tree.sketch.grid().counts_u32());
            assert_eq!(star.sketch.grid().counts_u32(), chain.sketch.grid().counts_u32());
            assert_eq!(star.examples, tree.examples);
            assert_eq!(star.examples, chain.examples);
            // Per-round leader state is ALSO topology-invariant: the set
            // of device increments in round r does not depend on how they
            // were folded on the way up.
            let lc = |r: &FleetResult| r.rounds.iter().map(|s| s.leader_count).collect::<Vec<_>>();
            assert_eq!(lc(&star), lc(&tree));
            assert_eq!(lc(&star), lc(&chain));
        }
    }

    #[test]
    fn on_round_sees_evolving_sketch_at_every_barrier() {
        let ds = scaled_ds();
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let streams = partition_streams(&ds, 3, None);
        let mut seen: Vec<(u64, u64)> = Vec::new();
        let result = run_fleet_with(
            small_fleet_cfg(3, 4),
            storm,
            Topology::Star,
            ds.dim() + 1,
            7,
            streams,
            |round, sketch| seen.push((round, sketch.count())),
        );
        assert_eq!(seen.len(), 4);
        assert_eq!(seen.iter().map(|&(r, _)| r).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(seen.windows(2).all(|w| w[0].1 <= w[1].1), "{seen:?}");
        assert_eq!(seen.last().unwrap().1, result.sketch.count());
    }

    #[test]
    fn network_accounts_bytes_per_round() {
        let result = run_with(Topology::Star, 3, 3);
        assert!(result.network.bytes > 0);
        assert_eq!(result.network.rounds.len(), 3);
        // Every epoch-tagged byte lands in a round bucket; Done frames
        // (16 bytes each, one per device on a star) do not.
        let round_total: u64 = result.network.rounds.values().map(|t| t.bytes).sum();
        assert_eq!(result.network.bytes, round_total + 16 * 3);
        // Each round carries its barrier frames: 3 devices x 24 bytes.
        for (epoch, t) in &result.network.rounds {
            assert!(t.bytes >= 3 * 24, "round {epoch} too light: {t:?}");
        }
        // Ideal network: no catch-up traffic at all.
        assert_eq!(result.network.retransmit_bytes(), 0);
    }

    #[test]
    fn device_reports_cover_dataset() {
        let result = run_with(Topology::Star, 5, 2);
        let total: u64 = result.devices.iter().map(|d| d.examples).sum();
        assert_eq!(total, 300);
        assert!(result.devices.iter().all(|d| d.batches > 0));
        assert!(result.devices.iter().all(|d| d.rounds == 2));
    }

    #[test]
    fn single_device_fleet_works() {
        let result = run_with(Topology::Star, 1, 1);
        assert_eq!(result.examples, 300);
    }

    #[test]
    fn chaos_run_is_bit_identical_to_fault_free_reference() {
        // One fixed chaotic schedule across all three topologies: the
        // final counters must equal the fault-free one-shot merge, and
        // faults must actually have been injected (non-vacuous chaos).
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let (reference, n) = reference_sketch(storm, 99);
        let ds = scaled_ds();
        for topo in [Topology::Star, Topology::Tree { fanout: 2 }, Topology::Chain] {
            let mut cfg = small_fleet_cfg(5, 6);
            cfg.faults_seed = Some(0xC4A0);
            let streams = partition_streams(&ds, 5, None);
            let result = run_fleet(cfg, storm, topo, ds.dim() + 1, 99, streams);
            assert_eq!(result.examples, n, "{topo:?}");
            assert_eq!(
                result.sketch.grid().counts_u32(),
                reference.grid().counts_u32(),
                "{topo:?}: chaos changed the counters"
            );
            assert_eq!(result.sketch.count(), n, "{topo:?}");
            assert_eq!(result.rounds.len(), 6, "{topo:?}: every round must close");
            assert!(result.faults.total() > 0, "{topo:?}: chaos was vacuous");
        }
    }

    #[test]
    fn partial_quorum_closes_rounds_and_stays_exact() {
        // min_quorum = 2 of 5 devices: rounds may close before
        // stragglers report, but late deltas still fold exactly once.
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let (reference, n) = reference_sketch(storm, 99);
        let ds = scaled_ds();
        let mut cfg = small_fleet_cfg(5, 4);
        cfg.min_quorum = 2;
        cfg.faults_seed = Some(77);
        let streams = partition_streams(&ds, 5, None);
        let result = run_fleet(cfg, storm, Topology::Star, ds.dim() + 1, 99, streams);
        assert_eq!(result.examples, n);
        assert_eq!(result.sketch.grid().counts_u32(), reference.grid().counts_u32());
        assert_eq!(result.rounds.len(), 4);
        // The leader count trace is still monotone.
        let counts: Vec<u64> = result.rounds.iter().map(|r| r.leader_count).collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
    }

    #[test]
    fn narrow_device_tier_matches_u32_fleet_exactly() {
        // u8 devices + u32 leader: the widening merge reproduces the
        // all-u32 fleet counter-for-counter (the 300-example dataset over
        // 4 devices never pushes a device cell near 255), while each
        // device holds a quarter of the counter memory.
        use crate::config::CounterWidth;
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let (reference, n) = reference_sketch(storm, 99);
        let ds = scaled_ds();
        for width in [CounterWidth::U8, CounterWidth::U16] {
            let mut cfg = small_fleet_cfg(4, 3);
            cfg.device_counter_width = Some(width);
            let streams = partition_streams(&ds, 4, None);
            let result = run_fleet(cfg, storm, Topology::Star, ds.dim() + 1, 99, streams);
            assert_eq!(result.examples, n, "{width:?}");
            assert_eq!(result.sketch.grid().width(), CounterWidth::U32, "leader stays wide");
            assert_eq!(
                result.sketch.grid().counts_u32(),
                reference.grid().counts_u32(),
                "{width:?}: widening merge must be exact"
            );
            for d in &result.devices {
                assert_eq!(d.sketch_bytes, 12 * 8 * width.bytes(), "{width:?}");
            }
        }
    }

    #[test]
    fn private_fleet_keeps_exact_tally_and_is_deterministic() {
        // Delta-level DP: the leader's merged counters carry noise, but
        // the example tally is exact (delta counts are never noised) and
        // two identical runs agree bit-for-bit (seeded noise).
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let (reference, n) = reference_sketch(storm, 99);
        let ds = scaled_ds();
        let mut cfg = small_fleet_cfg(4, 3);
        cfg.epsilon_per_round = 0.5;
        let run = || {
            let streams = partition_streams(&ds, 4, None);
            run_fleet(cfg, storm, Topology::Star, ds.dim() + 1, 99, streams)
        };
        let result = run();
        assert_eq!(result.examples, n);
        assert_eq!(result.sketch.count(), n, "only counter cells are noised");
        assert_eq!(result.rounds.len(), 3, "privacy never stalls a barrier");
        assert_ne!(
            result.sketch.grid().counts_u32(),
            reference.grid().counts_u32(),
            "epsilon = 0.5 noise must actually perturb the counters"
        );
        let again = run();
        assert_eq!(result.sketch.grid().counts_u32(), again.sketch.grid().counts_u32());
    }

    #[test]
    fn decayed_leader_down_weights_early_rounds() {
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let (reference, n) = reference_sketch(storm, 99);
        let ds = scaled_ds();
        let mut cfg = small_fleet_cfg(4, 4);
        cfg.decay_keep_permille = 500;
        let streams = partition_streams(&ds, 4, None);
        let result = run_fleet(cfg, storm, Topology::Star, ds.dim() + 1, 99, streams);
        assert_eq!(result.examples, n, "ingest accounting is unaffected by decay");
        assert!(
            result.sketch.count() < n,
            "decay must shrink the effective example count ({} !< {n})",
            result.sketch.count()
        );
        let mass = |g: &crate::sketch::counters::CounterGrid| {
            g.counts_u32().iter().map(|&c| c as u64).sum::<u64>()
        };
        assert!(mass(result.sketch.grid()) < mass(reference.grid()));
        // keep = 1.0 is the exact cumulative run, bit for bit.
        cfg.decay_keep_permille = 1000;
        let streams = partition_streams(&ds, 4, None);
        let cumulative = run_fleet(cfg, storm, Topology::Star, ds.dim() + 1, 99, streams);
        assert_eq!(cumulative.sketch.grid().counts_u32(), reference.grid().counts_u32());
        assert_eq!(cumulative.sketch.count(), n);
    }

    #[test]
    fn quorum_of_clamps_sensibly() {
        assert_eq!(quorum_of(0, 5), 5);
        assert_eq!(quorum_of(3, 5), 3);
        assert_eq!(quorum_of(9, 5), 5);
        assert_eq!(quorum_of(1, 5), 1);
    }

    #[test]
    fn dedup_is_exactly_once_with_overflow_fallback() {
        let mut d = Dedup::new(&[3, 7, 100], 4);
        assert!(d.insert(7, 0));
        assert!(!d.insert(7, 0), "bitset path deduplicates");
        assert!(d.insert(7, 1));
        assert!(d.insert(3, 0));
        // Out-of-range epoch and unknown sender take the fallback set.
        assert!(d.insert(7, 99));
        assert!(!d.insert(7, 99));
        assert!(d.insert(42, 0));
        assert!(!d.insert(42, 0));
    }

    /// The executor must produce the same result as the thread-per-node
    /// reference — not just statistically, bit for bit — at every pool
    /// size, on the same seeds. This is the contract that lets
    /// `run_fleet_model_chaos` route everything through the arena
    /// executor by default.
    #[test]
    fn executor_matches_threaded_reference_at_every_worker_count() {
        use crate::config::CounterWidth;
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let ds = scaled_ds();
        for topo in [Topology::Star, Topology::Deep { max_fan_in: 3 }, Topology::Chain] {
            for width in [None, Some(CounterWidth::U8), Some(CounterWidth::U16)] {
                let mut cfg = small_fleet_cfg(6, 3);
                cfg.device_counter_width = width;
                cfg.faults_seed = Some(0xBEEF);
                let plan = cfg.faults_seed.map(FaultPlan::from_seed);
                let streams = partition_streams(&ds, 6, None);
                let reference = run_fleet_model_threaded::<StormSketch, _>(
                    cfg,
                    storm,
                    topo,
                    ds.dim() + 1,
                    99,
                    streams,
                    plan,
                    |_, _| {},
                );
                for workers in [1usize, 2, 8] {
                    let mut c = cfg;
                    c.workers = workers;
                    let streams = partition_streams(&ds, 6, None);
                    let result = run_fleet_model_chaos::<StormSketch, _>(
                        c,
                        storm,
                        topo,
                        ds.dim() + 1,
                        99,
                        streams,
                        plan,
                        |_, _| {},
                    );
                    let ctx = format!("workers={workers} topo={topo:?} width={width:?}");
                    assert_eq!(
                        result.sketch.grid().counts_u32(),
                        reference.sketch.grid().counts_u32(),
                        "{ctx}: executor counters diverged from the threaded reference"
                    );
                    assert_eq!(result.sketch.count(), reference.sketch.count(), "{ctx}");
                    assert_eq!(result.examples, reference.examples, "{ctx}");
                    assert_eq!(result.rounds.len(), reference.rounds.len(), "{ctx}");
                    // Device reports are schedule-independent too
                    // (ingest timing aside — the executor does not
                    // attribute wall time per device).
                    for (a, b) in result.devices.iter().zip(&reference.devices) {
                        assert_eq!(
                            (a.id, a.examples, a.batches, a.rounds, a.deltas),
                            (b.id, b.examples, b.batches, b.rounds, b.deltas),
                            "{ctx}"
                        );
                        assert_eq!(
                            (a.crashed_rounds, a.straggled, a.retransmits, a.sketch_bytes),
                            (b.crashed_rounds, b.straggled, b.retransmits, b.sketch_bytes),
                            "{ctx}"
                        );
                    }
                }
            }
        }
    }

    /// On an ideal network with full quorums the executor's per-round
    /// trace — and the per-stage byte accounting — is identical to the
    /// threaded reference, not just the final counters: both schedulers
    /// deliver the same frames on the same links in per-link FIFO order,
    /// and round closes depend only on the per-epoch ack sets.
    #[test]
    fn executor_round_traces_and_bytes_match_threaded_on_ideal_network() {
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let ds = scaled_ds();
        for topo in [Topology::Star, Topology::Tree { fanout: 2 }, Topology::Chain] {
            let cfg = small_fleet_cfg(5, 4);
            let streams = partition_streams(&ds, 5, None);
            let reference = run_fleet_model_threaded::<StormSketch, _>(
                cfg,
                storm,
                topo,
                ds.dim() + 1,
                42,
                streams,
                None,
                |_, _| {},
            );
            for workers in [1usize, 3] {
                let mut c = cfg;
                c.workers = workers;
                let streams = partition_streams(&ds, 5, None);
                let result = run_fleet_model_chaos::<StormSketch, _>(
                    c,
                    storm,
                    topo,
                    ds.dim() + 1,
                    42,
                    streams,
                    None,
                    |_, _| {},
                );
                let ctx = format!("workers={workers} topo={topo:?}");
                let trace = |r: &FleetResult| {
                    r.rounds
                        .iter()
                        .map(|s| (s.round, s.examples, s.leader_count, s.deltas))
                        .collect::<Vec<_>>()
                };
                assert_eq!(trace(&result), trace(&reference), "{ctx}");
                assert_eq!(result.network.bytes, reference.network.bytes, "{ctx}");
                assert_eq!(result.network.messages, reference.network.messages, "{ctx}");
                assert_eq!(result.network.rounds, reference.network.rounds, "{ctx}");
                assert_eq!(result.network.retransmit_bytes(), 0, "{ctx}");
            }
        }
    }

    /// A deep tree bounds every merge node's fan-in; the executor must
    /// still reproduce the one-shot reference through the multi-level
    /// fold, and classification fleets ride the same scheduler.
    #[test]
    fn deep_tree_fleet_is_exact_for_both_tasks() {
        let storm = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let (reference, n) = reference_sketch(storm, 99);
        let result = run_with(Topology::Deep { max_fan_in: 3 }, 9, 2);
        assert_eq!(result.examples, n);
        assert_eq!(result.sketch.grid().counts_u32(), reference.grid().counts_u32());

        let clf_storm = StormConfig { task: Task::Classification, ..storm };
        let ds = labelled_ds(240);
        let clf_reference = classifier_reference(clf_storm, &ds, 99);
        let mut cfg = small_fleet_cfg(9, 2);
        cfg.workers = 4;
        let streams = partition_streams(&ds, 9, None);
        let result = run_fleet_model::<StormModel>(
            cfg,
            clf_storm,
            Topology::Deep { max_fan_in: 3 },
            ds.dim() + 1,
            99,
            streams,
        );
        assert_eq!(result.sketch.grid().counts_u32(), clf_reference.grid().counts_u32());
        assert_eq!(result.sketch.count(), 240);
    }

    use crate::config::Task;
    use crate::sketch::model::StormModel;

    fn labelled_ds(n: usize) -> crate::data::dataset::Dataset {
        let mut ds = synthetic::synth2d_classification(n, 0.8, 0.25, 11);
        crate::data::scale::scale_features_to_unit_ball(&mut ds, 0.9);
        ds
    }

    fn classifier_reference(
        storm: StormConfig,
        ds: &crate::data::dataset::Dataset,
        seed: u64,
    ) -> StormModel {
        let mut m = StormModel::new(storm, ds.dim() + 1, seed);
        for i in 0..ds.len() {
            m.insert(&ds.augmented(i));
        }
        m
    }

    #[test]
    fn classification_fleet_equals_one_shot_across_topologies_and_rounds() {
        // The classifier merge-equals-concatenation invariant through the
        // real fleet: any topology, any round count, counters equal a
        // single local classifier over the whole labelled stream.
        let storm = StormConfig {
            rows: 12,
            power: 3,
            saturating: true,
            task: Task::Classification,
            ..Default::default()
        };
        let ds = labelled_ds(240);
        let reference = classifier_reference(storm, &ds, 99);
        for topo in [Topology::Star, Topology::Tree { fanout: 2 }, Topology::Chain] {
            for rounds in [1usize, 3] {
                let streams = partition_streams(&ds, 4, None);
                let result = run_fleet_model::<StormModel>(
                    small_fleet_cfg(4, rounds),
                    storm,
                    topo,
                    ds.dim() + 1,
                    99,
                    streams,
                );
                assert!(result.sketch.as_classifier().is_some(), "{topo:?}");
                assert_eq!(
                    result.sketch.grid().counts_u32(),
                    reference.grid().counts_u32(),
                    "{topo:?} rounds={rounds}"
                );
                assert_eq!(result.sketch.count(), 240, "{topo:?} rounds={rounds}");
                assert_eq!(result.examples, 240);
            }
        }
    }

    #[test]
    fn classification_chaos_run_is_bit_identical_to_fault_free_oneshot() {
        // The PR-3 headline invariant now holds for the classifier too:
        // a chaotic schedule (drops/dups/reorders/stragglers/crash) ends
        // with counters bit-identical to the fault-free one-shot merge.
        let storm = StormConfig {
            rows: 12,
            power: 3,
            saturating: true,
            task: Task::Classification,
            ..Default::default()
        };
        let ds = labelled_ds(240);
        let reference = classifier_reference(storm, &ds, 99);
        let mut cfg = small_fleet_cfg(5, 6);
        cfg.faults_seed = Some(0xC1A5_C4A0);
        let plan = cfg.faults_seed.map(FaultPlan::from_seed);
        let streams = partition_streams(&ds, 5, None);
        let result = run_fleet_model_chaos::<StormModel, _>(
            cfg,
            storm,
            Topology::Tree { fanout: 2 },
            ds.dim() + 1,
            99,
            streams,
            plan,
            |_, _| {},
        );
        assert_eq!(result.sketch.grid().counts_u32(), reference.grid().counts_u32());
        assert_eq!(result.sketch.count(), 240);
        assert_eq!(result.rounds.len(), 6, "every round must close");
        assert!(result.faults.total() > 0, "chaos was vacuous");
    }

    #[test]
    fn narrow_classification_devices_widen_exactly() {
        // u8 classifier devices + u32 leader: widening merges stay exact
        // for the margin-hash counters too (one increment per row per
        // example keeps every cell far below 255 here).
        use crate::config::CounterWidth;
        let storm = StormConfig {
            rows: 12,
            power: 3,
            saturating: true,
            task: Task::Classification,
            ..Default::default()
        };
        let ds = labelled_ds(240);
        let reference = classifier_reference(storm, &ds, 99);
        let mut cfg = small_fleet_cfg(4, 3);
        cfg.device_counter_width = Some(CounterWidth::U8);
        let streams = partition_streams(&ds, 4, None);
        let result =
            run_fleet_model::<StormModel>(cfg, storm, Topology::Star, ds.dim() + 1, 99, streams);
        assert_eq!(result.sketch.grid().width(), CounterWidth::U32, "leader stays wide");
        assert_eq!(result.sketch.grid().counts_u32(), reference.grid().counts_u32());
        for d in &result.devices {
            assert_eq!(d.sketch_bytes, 12 * 8, "u8 classifier devices: 1 byte/cell");
        }
    }
}
