//! Fleet orchestration: spawn device threads, wire up the aggregation
//! topology with simulated links, and run `sync_rounds` rounds of delta
//! synchronization. Each round, devices push the counters changed since
//! the last barrier; aggregators fold the round's deltas in place and
//! forward one merged delta upstream; the leader applies the round and
//! hands its evolving sketch to the `on_round` callback — which is where
//! the coordinator interleaves training (the anytime model).
//!
//! Because counter merging is associative and commutative, R rounds of
//! delta merges produce a leader sketch bit-identical to the one-shot
//! full-sketch merge (property-tested in `proptest_invariants.rs`);
//! rounds change *when* information arrives and what it costs on the
//! wire, never what the final counters are.

use super::device::{run_device, DeviceConfig, DeviceReport};
use super::network::{Link, LinkSnapshot, Message};
use super::topology::{plan, Stage, Topology, LEADER};
use crate::config::{FleetConfig, StormConfig};
use crate::data::stream::StreamSource;
use crate::sketch::delta::SketchDelta;
use crate::sketch::serialize::{decode_delta, encode_delta};
use crate::sketch::storm::StormSketch;
use crate::sketch::Sketch;
use std::collections::BTreeMap;
use std::sync::mpsc::Receiver;

/// What one closed sync round looked like from the leader.
#[derive(Clone, Copy, Debug)]
pub struct RoundStat {
    pub round: u64,
    /// Examples merged into the leader during this round.
    pub examples: u64,
    /// Cumulative examples in the leader sketch after the round closed.
    pub leader_count: u64,
    /// Delta messages the leader folded this round.
    pub deltas: u64,
}

/// Result of a fleet run.
pub struct FleetResult {
    /// The leader's merged sketch — the only artifact that leaves the
    /// fleet, and everything training needs.
    pub sketch: StormSketch,
    pub devices: Vec<DeviceReport>,
    /// Aggregate link statistics across every hop (with per-round
    /// breakdown in `network.rounds`).
    pub network: LinkSnapshot,
    pub wall_secs: f64,
    /// Total examples ingested fleet-wide.
    pub examples: u64,
    /// Per-round leader-side statistics, in round order.
    pub rounds: Vec<RoundStat>,
}

/// Per-epoch accumulation at a merge point (aggregator or leader): the
/// folded delta, the round's example tally, and how many children have
/// closed the round.
#[derive(Default)]
struct RoundAccum {
    delta: Option<SketchDelta>,
    examples: u64,
    ends: usize,
    deltas: u64,
}

impl RoundAccum {
    fn fold(&mut self, d: SketchDelta) {
        self.deltas += 1;
        match &mut self.delta {
            Some(acc) => acc.merge_from(&d),
            None => self.delta = Some(d),
        }
    }
}

/// Record one `EndRound` from a child, then advance the in-order barrier:
/// close round `next` (and any directly following complete rounds) as
/// soon as all `expect` children have ended it, handing each round's
/// accumulator to `close`. Shared by the leader loop and the aggregator
/// nodes — only the close action differs.
fn end_round_and_drain(
    pending: &mut BTreeMap<u64, RoundAccum>,
    next: &mut u64,
    expect: usize,
    epoch: u64,
    examples: u64,
    mut close: impl FnMut(u64, RoundAccum),
) {
    let acc = pending.entry(epoch).or_default();
    acc.examples += examples;
    acc.ends += 1;
    // A round closes when every direct child has ended it; FIFO links
    // guarantee the round's deltas arrived first.
    while pending.get(next).is_some_and(|a| a.ends == expect) {
        let acc = pending.remove(next).expect("pending round");
        close(*next, acc);
        *next += 1;
    }
}

/// Run a fleet over per-device streams. `dim` is the augmented example
/// dimension (d + 1); `family_seed` fixes the shared hash family.
pub fn run_fleet(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
) -> FleetResult {
    run_fleet_with(fleet, storm, topology, dim, family_seed, streams, |_, _| {})
}

/// [`run_fleet`] with a per-round hook: `on_round(round, sketch)` runs on
/// the caller's thread right after the leader closes a round, while the
/// devices keep streaming the next round in the background — training
/// interleaves with ingestion instead of waiting for the whole fleet.
pub fn run_fleet_with(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
    mut on_round: impl FnMut(u64, &StormSketch),
) -> FleetResult {
    assert_eq!(streams.len(), fleet.devices, "one stream per device");
    let n = fleet.devices;
    let rounds = fleet.sync_rounds.max(1);
    let stages = plan(topology, n);
    let timer = crate::util::timer::Timer::start();

    // One link per non-leaf node (aggregators + leader), keyed by parent.
    let mut rx_for: BTreeMap<usize, Receiver<Message>> = BTreeMap::new();
    let mut tx_for: BTreeMap<usize, Link> = BTreeMap::new();
    let mut stats = Vec::new();
    for stage in &stages {
        let (link, rx, st) = Link::new(
            fleet.channel_capacity,
            fleet.link_latency_us,
            fleet.link_bandwidth_bps,
        );
        rx_for.insert(stage.parent, rx);
        tx_for.insert(stage.parent, link);
        stats.push(st);
    }
    // Map each child node to the link of its parent stage.
    let mut uplink: BTreeMap<usize, Link> = BTreeMap::new();
    for stage in &stages {
        for &c in &stage.children {
            uplink.insert(c, tx_for[&stage.parent].clone());
        }
    }
    drop(tx_for); // aggregator threads hold the remaining clones

    // Device threads. Hinted streams split their length evenly over the
    // rounds; hintless streams fall back to a budget sized so steady-state
    // delta traffic stays well below shipping the raw bytes would cost
    // (the whole point of sketches).
    const FLUSH_RAW_MULTIPLE: usize = 8;
    let wire = crate::sketch::serialize::wire_bytes(&storm);
    let raw_bytes_per_example = (dim * 8).max(1);
    let fallback_round_examples =
        (FLUSH_RAW_MULTIPLE * wire / raw_bytes_per_example).max(4 * fleet.batch);
    let mut device_handles = Vec::new();
    for (id, stream) in streams.into_iter().enumerate() {
        let cfg = DeviceConfig {
            id,
            batch: fleet.batch,
            rounds,
            fallback_round_examples,
            storm,
            family_seed,
            dim,
        };
        let link = uplink.remove(&id).expect("device uplink");
        device_handles.push(std::thread::spawn(move || run_device(cfg, stream, link)));
    }

    // Aggregator threads, in stage order. Each folds its children's
    // deltas per epoch and forwards ONE merged delta + EndRound per round
    // upstream, then cascades Done.
    let mut agg_handles = Vec::new();
    for stage in &stages {
        if stage.parent == LEADER {
            continue;
        }
        let rx = rx_for.remove(&stage.parent).expect("aggregator rx");
        let up = uplink.remove(&stage.parent).expect("aggregator uplink");
        let expect = stage.children.len();
        let agg_id = stage.parent;
        agg_handles.push(std::thread::spawn(move || run_aggregator(rx, up, agg_id, expect)));
    }

    // Leader: close rounds in epoch order, applying each round's folded
    // delta and running the caller's hook at every barrier.
    let leader_stage: &Stage = stages.iter().find(|s| s.parent == LEADER).expect("leader stage");
    let leader_rx = rx_for.remove(&LEADER).expect("leader rx");
    let expect = leader_stage.children.len();
    let mut sketch = StormSketch::new(storm, dim, family_seed);
    let mut pending: BTreeMap<u64, RoundAccum> = BTreeMap::new();
    let mut round_stats: Vec<RoundStat> = Vec::new();
    let mut next_round: u64 = 0;
    let mut done = 0usize;
    let mut examples = 0u64;
    while done < expect {
        match leader_rx.recv() {
            Ok(Message::Delta { epoch, payload }) => {
                let delta = decode_delta(&payload).expect("valid wire delta");
                pending.entry(epoch).or_default().fold(delta);
            }
            Ok(Message::EndRound { epoch, examples: e, .. }) => {
                end_round_and_drain(&mut pending, &mut next_round, expect, epoch, e, |round, acc| {
                    if let Some(delta) = &acc.delta {
                        sketch.apply_delta(delta);
                    }
                    round_stats.push(RoundStat {
                        round,
                        examples: acc.examples,
                        leader_count: sketch.count(),
                        deltas: acc.deltas,
                    });
                    on_round(round, &sketch);
                });
            }
            Ok(Message::Done { examples: e, .. }) => {
                done += 1;
                examples += e;
            }
            Err(_) => break,
        }
    }
    // Defensive: if links died mid-round, fold whatever arrived so the
    // sketch loses as little as possible.
    for (_, acc) in pending {
        if let Some(delta) = &acc.delta {
            sketch.apply_delta(delta);
        }
    }

    let devices: Vec<DeviceReport> = device_handles
        .into_iter()
        .map(|h| h.join().expect("device thread"))
        .collect();
    for h in agg_handles {
        h.join().expect("aggregator thread");
    }
    let mut network = LinkSnapshot::default();
    for s in &stats {
        network.merge(&s.snapshot());
    }
    FleetResult {
        sketch,
        devices,
        network,
        wall_secs: timer.elapsed_secs(),
        examples,
        rounds: round_stats,
    }
}

/// Aggregator node: fold every child delta of an epoch in place, and once
/// all children closed the epoch forward the single merged delta (plus
/// the round barrier) upstream — cascading Done with the summed example
/// count after the final round.
fn run_aggregator(rx: Receiver<Message>, up: Link, agg_id: usize, expect: usize) {
    let mut pending: BTreeMap<u64, RoundAccum> = BTreeMap::new();
    let mut next: u64 = 0;
    let mut done = 0usize;
    let mut examples = 0u64;
    while done < expect {
        match rx.recv() {
            Ok(Message::Delta { epoch, payload }) => {
                if let Ok(delta) = decode_delta(&payload) {
                    pending.entry(epoch).or_default().fold(delta);
                }
            }
            Ok(Message::EndRound { epoch, examples: e, .. }) => {
                end_round_and_drain(&mut pending, &mut next, expect, epoch, e, |round, acc| {
                    if let Some(delta) = &acc.delta {
                        if !delta.is_empty() {
                            let _ = up.send(Message::Delta {
                                epoch: round,
                                payload: encode_delta(delta),
                            });
                        }
                    }
                    let _ = up.send(Message::EndRound {
                        device_id: agg_id,
                        epoch: round,
                        examples: acc.examples,
                    });
                });
            }
            Ok(Message::Done { examples: e, .. }) => {
                done += 1;
                examples += e;
            }
            Err(_) => break,
        }
    }
    let _ = up.send(Message::Done { device_id: agg_id, examples });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::stream::partition_streams;
    use crate::data::synthetic;

    fn small_fleet_cfg(devices: usize, sync_rounds: usize) -> FleetConfig {
        FleetConfig {
            devices,
            batch: 16,
            channel_capacity: 4,
            link_latency_us: 0,
            link_bandwidth_bps: 0,
            sync_rounds,
            seed: 0,
        }
    }

    fn scaled_ds() -> crate::data::dataset::Dataset {
        let mut ds = synthetic::synth2d_regression(300, 0.5, 0.0, 0.05, 7);
        crate::data::scale::scale_to_unit_ball(&mut ds, 0.9);
        ds
    }

    fn reference_sketch(storm: StormConfig, seed: u64) -> (StormSketch, u64) {
        let ds = scaled_ds();
        let mut sk = StormSketch::new(storm, ds.dim() + 1, seed);
        for i in 0..ds.len() {
            sk.insert(&ds.augmented(i));
        }
        (sk, ds.len() as u64)
    }

    fn run_with(topology: Topology, devices: usize, rounds: usize) -> FleetResult {
        let ds = scaled_ds();
        let storm = StormConfig { rows: 12, power: 3, saturating: true };
        let streams = partition_streams(&ds, devices, None);
        run_fleet(
            small_fleet_cfg(devices, rounds),
            storm,
            topology,
            ds.dim() + 1,
            99,
            streams,
        )
    }

    #[test]
    fn star_fleet_equals_single_device_sketch() {
        let storm = StormConfig { rows: 12, power: 3, saturating: true };
        let (reference, n) = reference_sketch(storm, 99);
        let result = run_with(Topology::Star, 4, 1);
        assert_eq!(result.examples, n);
        assert_eq!(result.sketch.count(), n);
        assert_eq!(result.sketch.grid().data(), reference.grid().data());
    }

    #[test]
    fn multi_round_sync_is_bit_identical_to_one_shot() {
        let storm = StormConfig { rows: 12, power: 3, saturating: true };
        let (reference, n) = reference_sketch(storm, 99);
        for rounds in [2usize, 3, 5] {
            let result = run_with(Topology::Star, 4, rounds);
            assert_eq!(result.examples, n, "rounds={rounds}");
            assert_eq!(result.sketch.grid().data(), reference.grid().data(), "rounds={rounds}");
            assert_eq!(result.rounds.len(), rounds, "rounds={rounds}");
            // Leader counts grow monotonically and end at n.
            let counts: Vec<u64> = result.rounds.iter().map(|r| r.leader_count).collect();
            assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
            assert_eq!(*counts.last().unwrap(), n);
            let per_round: u64 = result.rounds.iter().map(|r| r.examples).sum();
            assert_eq!(per_round, n);
        }
    }

    #[test]
    fn tree_and_chain_agree_with_star_across_rounds() {
        for rounds in [1usize, 3] {
            let star = run_with(Topology::Star, 6, rounds);
            let tree = run_with(Topology::Tree { fanout: 2 }, 6, rounds);
            let chain = run_with(Topology::Chain, 6, rounds);
            assert_eq!(star.sketch.grid().data(), tree.sketch.grid().data());
            assert_eq!(star.sketch.grid().data(), chain.sketch.grid().data());
            assert_eq!(star.examples, tree.examples);
            assert_eq!(star.examples, chain.examples);
            // Per-round leader state is ALSO topology-invariant: the set
            // of device increments in round r does not depend on how they
            // were folded on the way up.
            let lc = |r: &FleetResult| r.rounds.iter().map(|s| s.leader_count).collect::<Vec<_>>();
            assert_eq!(lc(&star), lc(&tree));
            assert_eq!(lc(&star), lc(&chain));
        }
    }

    #[test]
    fn on_round_sees_evolving_sketch_at_every_barrier() {
        let ds = scaled_ds();
        let storm = StormConfig { rows: 12, power: 3, saturating: true };
        let streams = partition_streams(&ds, 3, None);
        let mut seen: Vec<(u64, u64)> = Vec::new();
        let result = run_fleet_with(
            small_fleet_cfg(3, 4),
            storm,
            Topology::Star,
            ds.dim() + 1,
            7,
            streams,
            |round, sketch| seen.push((round, sketch.count())),
        );
        assert_eq!(seen.len(), 4);
        assert_eq!(seen.iter().map(|&(r, _)| r).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(seen.windows(2).all(|w| w[0].1 <= w[1].1), "{seen:?}");
        assert_eq!(seen.last().unwrap().1, result.sketch.count());
    }

    #[test]
    fn network_accounts_bytes_per_round() {
        let result = run_with(Topology::Star, 3, 3);
        assert!(result.network.bytes > 0);
        assert_eq!(result.network.rounds.len(), 3);
        // Every epoch-tagged byte lands in a round bucket; Done frames
        // (16 bytes each, one per device on a star) do not.
        let round_total: u64 = result.network.rounds.values().map(|t| t.bytes).sum();
        assert_eq!(result.network.bytes, round_total + 16 * 3);
        // Each round carries its barrier frames: 3 devices x 24 bytes.
        for (epoch, t) in &result.network.rounds {
            assert!(t.bytes >= 3 * 24, "round {epoch} too light: {t:?}");
        }
    }

    #[test]
    fn device_reports_cover_dataset() {
        let result = run_with(Topology::Star, 5, 2);
        let total: u64 = result.devices.iter().map(|d| d.examples).sum();
        assert_eq!(total, 300);
        assert!(result.devices.iter().all(|d| d.batches > 0));
        assert!(result.devices.iter().all(|d| d.rounds == 2));
    }

    #[test]
    fn single_device_fleet_works() {
        let result = run_with(Topology::Star, 1, 1);
        assert_eq!(result.examples, 300);
    }
}
