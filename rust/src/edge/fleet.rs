//! Fleet orchestration: spawn device threads, wire up the aggregation
//! topology with simulated links, merge everything into the leader's
//! sketch, and report transfer/energy statistics.

use super::device::{run_device, DeviceConfig, DeviceReport};
use super::network::{Link, LinkSnapshot, Message};
use super::topology::{plan, Stage, Topology, LEADER};
use crate::config::{FleetConfig, StormConfig};
use crate::data::stream::StreamSource;
use crate::sketch::serialize::{decode, encode};
use crate::sketch::storm::StormSketch;
use crate::sketch::Sketch;
use std::collections::BTreeMap;
use std::sync::mpsc::Receiver;

/// Result of a fleet run.
pub struct FleetResult {
    /// The leader's merged sketch — the only artifact that leaves the
    /// fleet, and everything training needs.
    pub sketch: StormSketch,
    pub devices: Vec<DeviceReport>,
    /// Aggregate link statistics across every hop.
    pub network: LinkSnapshot,
    pub wall_secs: f64,
    /// Total examples ingested fleet-wide.
    pub examples: u64,
}

/// Run a fleet over per-device streams. `dim` is the augmented example
/// dimension (d + 1); `family_seed` fixes the shared hash family.
pub fn run_fleet(
    fleet: FleetConfig,
    storm: StormConfig,
    topology: Topology,
    dim: usize,
    family_seed: u64,
    streams: Vec<Box<dyn StreamSource>>,
) -> FleetResult {
    assert_eq!(streams.len(), fleet.devices, "one stream per device");
    let n = fleet.devices;
    let stages = plan(topology, n);
    let timer = crate::util::timer::Timer::start();

    // One link per non-leaf node (aggregators + leader), keyed by parent.
    let mut rx_for: BTreeMap<usize, Receiver<Message>> = BTreeMap::new();
    let mut tx_for: BTreeMap<usize, Link> = BTreeMap::new();
    let mut stats = Vec::new();
    for stage in &stages {
        let (link, rx, st) = Link::new(
            fleet.channel_capacity,
            fleet.link_latency_us,
            fleet.link_bandwidth_bps,
        );
        rx_for.insert(stage.parent, rx);
        tx_for.insert(stage.parent, link);
        stats.push(st);
    }
    // Map each child node to the link of its parent stage.
    let mut uplink: BTreeMap<usize, Link> = BTreeMap::new();
    for stage in &stages {
        for &c in &stage.children {
            uplink.insert(c, tx_for[&stage.parent].clone());
        }
    }
    drop(tx_for); // aggregator threads hold the remaining clones

    // Device threads. Flush cadence adapts to the sketch size: a delta is
    // shipped once the device has ingested several wire-messages' worth
    // of raw bytes, so steady-state sketch traffic stays well below what
    // shipping the raw data would cost (the whole point of sketches). A
    // final flush at stream end bounds staleness.
    const FLUSH_RAW_MULTIPLE: usize = 8;
    let wire = crate::sketch::serialize::wire_bytes(&storm);
    let raw_bytes_per_batch = fleet.batch * dim * 8;
    let flush_batches = (FLUSH_RAW_MULTIPLE * wire / raw_bytes_per_batch.max(1)).max(4);
    let mut device_handles = Vec::new();
    for (id, stream) in streams.into_iter().enumerate() {
        let cfg = DeviceConfig {
            id,
            batch: fleet.batch,
            flush_batches,
            storm,
            family_seed,
            dim,
        };
        let link = uplink.remove(&id).expect("device uplink");
        device_handles.push(std::thread::spawn(move || run_device(cfg, stream, link)));
    }

    // Aggregator threads, in stage order. Each drains its receiver,
    // merges deltas, and forwards ONE merged delta + Done upstream.
    let mut agg_handles = Vec::new();
    for stage in &stages {
        if stage.parent == LEADER {
            continue;
        }
        let rx = rx_for.remove(&stage.parent).expect("aggregator rx");
        let up = uplink.remove(&stage.parent).expect("aggregator uplink");
        let expect_done = stage.children.len();
        agg_handles.push(std::thread::spawn(move || {
            run_aggregator(rx, up, expect_done, storm, dim, family_seed)
        }));
    }

    // Leader: drain the final stage.
    let leader_stage: &Stage = stages.iter().find(|s| s.parent == LEADER).expect("leader stage");
    let leader_rx = rx_for.remove(&LEADER).expect("leader rx");
    let mut sketch = StormSketch::new(storm, dim, family_seed);
    let mut done = 0usize;
    let mut examples = 0u64;
    while done < leader_stage.children.len() {
        match leader_rx.recv() {
            Ok(Message::Delta(bytes)) => {
                let delta = decode(&bytes).expect("valid wire delta");
                sketch.merge_from(&delta);
            }
            Ok(Message::Done { examples: e, .. }) => {
                done += 1;
                examples += e;
            }
            Err(_) => break,
        }
    }

    let devices: Vec<DeviceReport> = device_handles
        .into_iter()
        .map(|h| h.join().expect("device thread"))
        .collect();
    for h in agg_handles {
        h.join().expect("aggregator thread");
    }
    let mut network = LinkSnapshot::default();
    for s in &stats {
        network.merge(&s.snapshot());
    }
    FleetResult {
        sketch,
        devices,
        network,
        wall_secs: timer.elapsed_secs(),
        examples,
    }
}

/// Aggregator node: merge every delta from children, forward the merged
/// sketch once all children are done (cascading Done upstream with the
/// summed example count).
fn run_aggregator(
    rx: Receiver<Message>,
    up: Link,
    expect_done: usize,
    storm: StormConfig,
    dim: usize,
    family_seed: u64,
) {
    let mut acc = StormSketch::new(storm, dim, family_seed);
    let mut done = 0usize;
    let mut examples = 0u64;
    while done < expect_done {
        match rx.recv() {
            Ok(Message::Delta(bytes)) => {
                if let Ok(delta) = decode(&bytes) {
                    acc.merge_from(&delta);
                }
            }
            Ok(Message::Done { examples: e, .. }) => {
                done += 1;
                examples += e;
            }
            Err(_) => break,
        }
    }
    if acc.count() > 0 {
        let _ = up.send(Message::Delta(encode(&acc)));
    }
    let _ = up.send(Message::Done { device_id: usize::MAX - 1, examples });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::stream::partition_streams;
    use crate::data::synthetic;

    fn small_fleet_cfg(devices: usize) -> FleetConfig {
        FleetConfig {
            devices,
            batch: 16,
            channel_capacity: 4,
            link_latency_us: 0,
            link_bandwidth_bps: 0,
            seed: 0,
        }
    }

    fn scaled_ds() -> crate::data::dataset::Dataset {
        let mut ds = synthetic::synth2d_regression(300, 0.5, 0.0, 0.05, 7);
        crate::data::scale::scale_to_unit_ball(&mut ds, 0.9);
        ds
    }

    fn reference_sketch(storm: StormConfig, seed: u64) -> (StormSketch, u64) {
        let ds = scaled_ds();
        let mut sk = StormSketch::new(storm, ds.dim() + 1, seed);
        for i in 0..ds.len() {
            sk.insert(&ds.augmented(i));
        }
        (sk, ds.len() as u64)
    }

    fn run_with(topology: Topology, devices: usize) -> FleetResult {
        let ds = scaled_ds();
        let storm = StormConfig { rows: 12, power: 3, saturating: true };
        let streams = partition_streams(&ds, devices, None);
        run_fleet(small_fleet_cfg(devices), storm, topology, ds.dim() + 1, 99, streams)
    }

    #[test]
    fn star_fleet_equals_single_device_sketch() {
        let storm = StormConfig { rows: 12, power: 3, saturating: true };
        let (reference, n) = reference_sketch(storm, 99);
        let result = run_with(Topology::Star, 4);
        assert_eq!(result.examples, n);
        assert_eq!(result.sketch.count(), n);
        assert_eq!(result.sketch.grid().data(), reference.grid().data());
    }

    #[test]
    fn tree_and_chain_agree_with_star() {
        let star = run_with(Topology::Star, 6);
        let tree = run_with(Topology::Tree { fanout: 2 }, 6);
        let chain = run_with(Topology::Chain, 6);
        assert_eq!(star.sketch.grid().data(), tree.sketch.grid().data());
        assert_eq!(star.sketch.grid().data(), chain.sketch.grid().data());
        assert_eq!(star.examples, tree.examples);
        assert_eq!(star.examples, chain.examples);
    }

    #[test]
    fn network_bytes_scale_with_flushes() {
        let result = run_with(Topology::Star, 3);
        assert!(result.network.messages >= 3); // at least one delta + dones
        assert!(result.network.bytes > 0);
        let per_msg = crate::sketch::serialize::wire_bytes(&StormConfig {
            rows: 12,
            power: 3,
            saturating: true,
        });
        // Every delta message is exactly wire_bytes; total is a multiple
        // plus 16-byte Done frames.
        let deltas = (result.network.bytes
            - 16 * result.devices.len() as u64) / per_msg as u64;
        assert!(deltas >= 3, "deltas={deltas}");
    }

    #[test]
    fn device_reports_cover_dataset() {
        let result = run_with(Topology::Star, 5);
        let total: u64 = result.devices.iter().map(|d| d.examples).sum();
        assert_eq!(total, 300);
        assert!(result.devices.iter().all(|d| d.batches > 0));
    }

    #[test]
    fn single_device_fleet_works() {
        let result = run_with(Topology::Star, 1);
        assert_eq!(result.examples, 300);
    }
}
