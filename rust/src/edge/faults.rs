//! Deterministic fault injection for the fleet sync protocol.
//!
//! A [`FaultPlan`] is a pure function of one `u64` seed: every per-link
//! decision (drop / duplicate / delay-reorder of message `i` on link
//! `l`), every per-device straggler round, and the one crash/restart
//! window are derived by hashing `(seed, stream, index)` — no state, no
//! wall clock — so a chaotic run's *schedule* is replayable from the
//! seed alone. (Arrival interleaving across senders remains
//! OS-scheduled; the protocol's property test asserts the final
//! counters are invariant to exactly that.)
//!
//! [`ChaosLink`] wraps the PR-2 [`Link`] and applies the plan on the
//! sender side:
//!
//! * **drop** — data (`Delta`) frames only; the frame is discarded and
//!   the *sender is told* ([`Delivery::Dropped`]), modelling a timeout /
//!   missing ack. The sender recovers by not advancing its counter
//!   snapshot, so the lost increments ride in a later round's
//!   multi-epoch catch-up delta (single-pass streams cannot be re-read;
//!   the protocol, not the data layer, re-ships). A per-link
//!   consecutive-drop cap (`max_drop_burst`) forces delivery after a
//!   bounded burst — the structural "eventual delivery" guarantee that
//!   bounds every retry loop.
//! * **duplicate** — the frame is delivered twice. Receivers fold
//!   exactly-once by deduplicating on `(from, epoch)`; senders never
//!   reuse an epoch tag for two different payloads.
//! * **delay / reorder** — the frame is held and released only after
//!   `k` subsequent sends on the same link (k = 1 is an adjacent-pair
//!   reorder), violating per-link FIFO deterministically. Held frames
//!   are flushed before `Done` so nothing outlives the stream.
//!
//! Control frames (`EndRound`, `Done`) model a tiny reliable control
//! channel: they can be delayed, duplicated and reordered but never
//! dropped — dropping a 24-byte ack is cheap to prevent in practice
//! (retry forever) and exempting them keeps the liveness argument
//! local: every barrier eventually sees every child, so quorum
//! (`[fleet] min_quorum`) is a latency knob, not a correctness crutch.

use super::network::{Link, Message};
use crate::util::rng::splitmix64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// What the fault layer did with one message, from the sender's view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Delivery {
    /// The message was (or will be, for held frames) delivered.
    Delivered,
    /// The message was discarded; the sender must re-ship the content.
    Dropped,
}

/// Per-message fault decision on a link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkFault {
    Deliver,
    Drop,
    Duplicate,
    /// Hold the message until `k` more messages have been sent on this
    /// link (k = 1 swaps adjacent messages; larger k is a long delay).
    Hold(u64),
}

/// Seeded, replayable fault schedule. All probabilities are per-mille
/// (0 = never, 1000 = always); all decisions are pure functions of
/// `(seed, stream, index)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    /// P(drop) per data frame.
    pub drop_per_mille: u16,
    /// P(duplicate) per frame.
    pub dup_per_mille: u16,
    /// P(hold) per frame; held for `1..=max_delay` subsequent sends.
    pub delay_per_mille: u16,
    pub max_delay: u8,
    /// Consecutive data-frame drops per link before delivery is forced
    /// (the eventual-delivery bound; must be >= 1 for drops to fire).
    pub max_drop_burst: u8,
    /// P(straggle) per device round; the round's delta + barrier are
    /// deferred by `1..=max_straggle` rounds.
    pub straggle_per_mille: u16,
    pub max_straggle: u8,
    /// P(the run contains one device crash/restart at all).
    pub crash_per_mille: u16,
    /// Crash downtime in rounds (silent: no ingest, no sends), at most
    /// this many.
    pub max_crash_downtime: u8,
}

const STREAM_LINK: u64 = 0x4C49_4E4B; // "LINK"
const STREAM_STRAGGLE: u64 = 0x5354_5241; // "STRA"
const STREAM_CRASH: u64 = 0x4352_4153; // "CRAS"

impl FaultPlan {
    /// A chaotic plan whose intensities are themselves derived from the
    /// seed — one u64 names the entire fault schedule. Always includes
    /// a crash/restart when the run has at least two rounds.
    pub fn from_seed(seed: u64) -> Self {
        let mut s = seed ^ 0xC4A0_5FA0_0FA0_17ED;
        let mut r = |lo: u16, span: u16| lo + (splitmix64(&mut s) % span as u64) as u16;
        FaultPlan {
            seed,
            drop_per_mille: r(50, 250),
            dup_per_mille: r(30, 200),
            delay_per_mille: r(50, 250),
            max_delay: 3,
            max_drop_burst: 4,
            straggle_per_mille: r(100, 300),
            max_straggle: 2,
            crash_per_mille: 1000,
            max_crash_downtime: 2,
        }
    }

    /// A plan that injects nothing (useful as an explicit control arm).
    pub fn quiet(seed: u64) -> Self {
        FaultPlan {
            seed,
            drop_per_mille: 0,
            dup_per_mille: 0,
            delay_per_mille: 0,
            max_delay: 0,
            max_drop_burst: 0,
            straggle_per_mille: 0,
            max_straggle: 0,
            crash_per_mille: 0,
            max_crash_downtime: 0,
        }
    }

    /// Pure-loss plan at a controlled drop rate — the knob the
    /// catch-up-overhead-vs-drop-rate experiment sweeps
    /// (EXPERIMENTS.md §Resilience).
    pub fn drop_only(seed: u64, drop_per_mille: u16) -> Self {
        FaultPlan {
            drop_per_mille,
            max_drop_burst: 8,
            ..FaultPlan::quiet(seed)
        }
    }

    /// One hash evaluation shared by every decision: replayable,
    /// stateless, decorrelated across streams and indices.
    fn roll(&self, stream: u64, index: u64) -> u64 {
        let mut s = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ stream.wrapping_mul(0xBF58_476D_1CE4_E5B9)
            ^ index.wrapping_mul(0x94D0_49BB_1331_11EB);
        splitmix64(&mut s)
    }

    /// Decision for the `index`-th message sent on link `link`.
    pub fn link_action(&self, link: u64, index: u64) -> LinkFault {
        let r = self.roll(STREAM_LINK ^ link, index);
        let pick = (r % 1000) as u32;
        let d = self.drop_per_mille as u32;
        let dd = d + self.dup_per_mille as u32;
        let ddd = dd + self.delay_per_mille as u32;
        if pick < d {
            LinkFault::Drop
        } else if pick < dd {
            LinkFault::Duplicate
        } else if pick < ddd && self.max_delay > 0 {
            LinkFault::Hold(1 + (r >> 32) % self.max_delay as u64)
        } else {
            LinkFault::Deliver
        }
    }

    /// How many rounds device `device` defers round `round` (0 = on
    /// time).
    pub fn straggle_rounds(&self, device: usize, round: u64) -> u64 {
        if self.straggle_per_mille == 0 || self.max_straggle == 0 {
            return 0;
        }
        let r = self.roll(STREAM_STRAGGLE ^ device as u64, round);
        if (r % 1000) as u16 < self.straggle_per_mille {
            1 + (r >> 32) % self.max_straggle as u64
        } else {
            0
        }
    }

    /// The run's single crash/restart: `(device, round, downtime)` —
    /// the device is silent (no ingest, no sends) for `downtime` rounds
    /// starting at `round`, then restarts from its persisted sketch (a
    /// few KB — checkpointing it is free) and catches up. One-shot runs
    /// (`rounds < 2`) never crash.
    pub fn crash_schedule(&self, devices: usize, rounds: u64) -> Option<(usize, u64, u64)> {
        if self.crash_per_mille == 0 || self.max_crash_downtime == 0 || rounds < 2 || devices == 0 {
            return None;
        }
        let gate = self.roll(STREAM_CRASH, 0);
        if (gate % 1000) as u16 >= self.crash_per_mille {
            return None;
        }
        let r = self.roll(STREAM_CRASH, 1);
        let device = (r % devices as u64) as usize;
        let round = (r >> 16) % rounds;
        let downtime = 1 + (r >> 48) % self.max_crash_downtime as u64;
        Some((device, round, downtime))
    }
}

/// Counters of what the fault layer actually did on one link (shared
/// with the fleet driver for the run report).
#[derive(Debug, Default)]
pub struct FaultStats {
    pub drops: AtomicU64,
    pub duplicates: AtomicU64,
    pub delayed: AtomicU64,
    /// Drops suppressed by the `max_drop_burst` cap.
    pub forced_deliveries: AtomicU64,
}

/// Plain-data copy of [`FaultStats`], mergeable across links.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultSummary {
    pub drops: u64,
    pub duplicates: u64,
    pub delayed: u64,
    pub forced_deliveries: u64,
}

impl FaultStats {
    pub fn snapshot(&self) -> FaultSummary {
        FaultSummary {
            drops: self.drops.load(Ordering::Relaxed),
            duplicates: self.duplicates.load(Ordering::Relaxed),
            delayed: self.delayed.load(Ordering::Relaxed),
            forced_deliveries: self.forced_deliveries.load(Ordering::Relaxed),
        }
    }
}

impl FaultSummary {
    pub fn merge(&mut self, other: &FaultSummary) {
        self.drops += other.drops;
        self.duplicates += other.duplicates;
        self.delayed += other.delayed;
        self.forced_deliveries += other.forced_deliveries;
    }

    /// Total injected fault events.
    pub fn total(&self) -> u64 {
        self.drops + self.duplicates + self.delayed
    }
}

/// Drain every `(release_at, item)` entry due at or before `through`,
/// in release order (ties keep insertion order — stable sort), handing
/// each item to `send`. Shared by the link-level held-frame buffer and
/// the device's deferred barrier acks so the two release paths cannot
/// drift apart.
pub fn drain_due<T>(held: &mut Vec<(u64, T)>, through: u64, mut send: impl FnMut(T)) {
    if held.is_empty() {
        return;
    }
    held.sort_by_key(|entry| entry.0);
    let due = held.iter().take_while(|entry| entry.0 <= through).count();
    for (_, item) in held.drain(..due) {
        send(item);
    }
}

#[derive(Default)]
struct ChaosState {
    /// Messages offered to this link so far (indexes the plan).
    index: u64,
    /// Current consecutive data-frame drop run.
    drop_burst: u8,
    /// Held frames: `(release_after_index, (message, retransmit_class))`.
    held: Vec<(u64, (Message, bool))>,
}

/// A sender-side link that applies a [`FaultPlan`]. With no plan it is
/// a transparent pass-through of [`Link`] — the default fleet path is
/// bit-identical to PR-2. One `ChaosLink` per sending node; the link id
/// is the node id, which keys the plan's per-link decision stream.
pub struct ChaosLink {
    inner: Link,
    link_id: u64,
    plan: Option<FaultPlan>,
    state: Mutex<ChaosState>,
    stats: Arc<FaultStats>,
}

impl ChaosLink {
    pub fn new(inner: Link, link_id: u64, plan: Option<FaultPlan>) -> Self {
        Self::with_stats(inner, link_id, plan, Arc::new(FaultStats::default()))
    }

    /// Like [`Self::new`] but accounting into a shared [`FaultStats`].
    /// The arena executor gives every link of a 1M-device fleet one
    /// stats block instead of a million allocations to merge.
    pub fn with_stats(
        inner: Link,
        link_id: u64,
        plan: Option<FaultPlan>,
        stats: Arc<FaultStats>,
    ) -> Self {
        ChaosLink {
            inner,
            link_id,
            plan,
            state: Mutex::new(ChaosState::default()),
            stats,
        }
    }

    /// A link that injects nothing (unit tests, single-node paths).
    pub fn passthrough(inner: Link) -> Self {
        ChaosLink::new(inner, 0, None)
    }

    pub fn stats(&self) -> Arc<FaultStats> {
        self.stats.clone()
    }

    /// Send through the fault layer. `Ok(Delivered)` means the message
    /// was queued (possibly twice, possibly late); `Ok(Dropped)` means
    /// the plan discarded it and the sender must recover the content;
    /// `Err` means the receiver is gone.
    pub fn send(&self, msg: Message) -> Result<Delivery, ()> {
        self.send_class(msg, false)
    }

    /// [`Self::send`] with the retransmit traffic class (see
    /// [`Link::send_class`]).
    pub fn send_class(&self, msg: Message, retransmit: bool) -> Result<Delivery, ()> {
        let Some(plan) = self.plan else {
            return self.inner.send_class(msg, retransmit).map(|()| Delivery::Delivered);
        };
        let mut st = self.state.lock().expect("chaos link state");
        let i = st.index;
        st.index += 1;
        // Done terminates the stream: flush everything held, then pass
        // it through untouched (never dropped, duplicated or delayed).
        if matches!(msg, Message::Done { .. }) {
            Self::flush_held(&self.inner, &mut st.held, u64::MAX);
            return self.inner.send_class(msg, retransmit).map(|()| Delivery::Delivered);
        }
        let action = plan.link_action(self.link_id, i);
        let droppable = matches!(msg, Message::Delta { .. });
        let result = match action {
            LinkFault::Drop if droppable && st.drop_burst < plan.max_drop_burst => {
                st.drop_burst += 1;
                self.stats.drops.fetch_add(1, Ordering::Relaxed);
                Ok(Delivery::Dropped)
            }
            LinkFault::Drop if droppable => {
                // Burst cap reached: force the delivery (eventual
                // delivery is structural, not probabilistic).
                st.drop_burst = 0;
                self.stats.forced_deliveries.fetch_add(1, Ordering::Relaxed);
                self.inner.send_class(msg, retransmit).map(|()| Delivery::Delivered)
            }
            LinkFault::Duplicate => {
                if droppable {
                    st.drop_burst = 0;
                }
                self.stats.duplicates.fetch_add(1, Ordering::Relaxed);
                self.inner.send_class(msg.clone(), retransmit)?;
                self.inner.send_class(msg, retransmit).map(|()| Delivery::Delivered)
            }
            LinkFault::Hold(k) => {
                if droppable {
                    st.drop_burst = 0;
                }
                self.stats.delayed.fetch_add(1, Ordering::Relaxed);
                st.held.push((i + k, (msg, retransmit)));
                Ok(Delivery::Delivered)
            }
            LinkFault::Drop | LinkFault::Deliver => {
                if droppable {
                    st.drop_burst = 0;
                }
                self.inner.send_class(msg, retransmit).map(|()| Delivery::Delivered)
            }
        };
        // Release held frames whose delay has elapsed (in release
        // order, ties in insertion order — stable sort).
        Self::flush_held(&self.inner, &mut st.held, i);
        result
    }

    /// Send every held frame due at or before `through` (dead-link
    /// errors are ignored: the receiver side is gone, nothing to hold
    /// for).
    fn flush_held(inner: &Link, held: &mut Vec<(u64, (Message, bool))>, through: u64) {
        drain_due(held, through, |(msg, retransmit)| {
            let _ = inner.send_class(msg, retransmit);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edge::network::Link;

    fn delta(from: usize, epoch: u64, len: usize) -> Message {
        Message::Delta { from, epoch, payload: vec![0u8; len].into() }
    }

    #[test]
    fn plan_is_a_pure_function_of_the_seed() {
        let a = FaultPlan::from_seed(42);
        let b = FaultPlan::from_seed(42);
        assert_eq!(a, b);
        for link in 0..4u64 {
            for i in 0..200u64 {
                assert_eq!(a.link_action(link, i), b.link_action(link, i));
            }
        }
        for dev in 0..4usize {
            for r in 0..20u64 {
                assert_eq!(a.straggle_rounds(dev, r), b.straggle_rounds(dev, r));
            }
        }
        assert_eq!(a.crash_schedule(5, 8), b.crash_schedule(5, 8));
        // Different seeds give different schedules somewhere.
        let c = FaultPlan::from_seed(43);
        let differs = (0..200u64).any(|i| a.link_action(0, i) != c.link_action(0, i));
        assert!(differs);
    }

    #[test]
    fn chaotic_plan_injects_every_fault_kind() {
        let plan = FaultPlan::from_seed(7);
        let mut kinds = [false; 4];
        for i in 0..2000u64 {
            match plan.link_action(1, i) {
                LinkFault::Deliver => kinds[0] = true,
                LinkFault::Drop => kinds[1] = true,
                LinkFault::Duplicate => kinds[2] = true,
                LinkFault::Hold(k) => {
                    assert!(k >= 1 && k <= plan.max_delay as u64);
                    kinds[3] = true;
                }
            }
        }
        assert_eq!(kinds, [true; 4], "all four actions must occur");
        assert!(plan.crash_schedule(4, 6).is_some());
        let (dev, round, down) = plan.crash_schedule(4, 6).unwrap();
        assert!(dev < 4 && round < 6 && down >= 1);
        assert!((0..4).any(|d| (0..20).any(|r| plan.straggle_rounds(d, r) > 0)));
    }

    #[test]
    fn quiet_plan_and_no_plan_are_transparent() {
        assert!(FaultPlan::quiet(9).crash_schedule(8, 8).is_none());
        for i in 0..100 {
            assert_eq!(FaultPlan::quiet(9).link_action(0, i), LinkFault::Deliver);
        }
        let (link, rx, _) = Link::new(16, 0, 0);
        let chaos = ChaosLink::passthrough(link);
        for e in 0..5u64 {
            assert_eq!(chaos.send(delta(0, e, 10)).unwrap(), Delivery::Delivered);
        }
        chaos.send(Message::Done { device_id: 0, examples: 5 }).unwrap();
        drop(chaos);
        let msgs: Vec<Message> = rx.iter().collect();
        assert_eq!(msgs.len(), 6);
        let epochs: Vec<u64> = msgs.iter().filter_map(|m| m.epoch()).collect();
        assert_eq!(epochs, vec![0, 1, 2, 3, 4], "passthrough preserves FIFO");
    }

    #[test]
    fn drops_are_sender_visible_and_burst_capped() {
        let plan = FaultPlan { drop_per_mille: 1000, max_drop_burst: 2, ..FaultPlan::quiet(3) };
        let (link, rx, _) = Link::new(64, 0, 0);
        let chaos = ChaosLink::new(link, 5, Some(plan));
        let mut outcomes = Vec::new();
        for i in 0..9u64 {
            outcomes.push(chaos.send(delta(5, i, 8)).unwrap());
        }
        // Always-drop plan with burst cap 2: every third frame forced.
        assert_eq!(
            outcomes,
            vec![
                Delivery::Dropped,
                Delivery::Dropped,
                Delivery::Delivered,
                Delivery::Dropped,
                Delivery::Dropped,
                Delivery::Delivered,
                Delivery::Dropped,
                Delivery::Dropped,
                Delivery::Delivered,
            ]
        );
        let stats = chaos.stats().snapshot();
        assert_eq!(stats.drops, 6);
        assert_eq!(stats.forced_deliveries, 3);
        drop(chaos);
        assert_eq!(rx.iter().count(), 3);
    }

    #[test]
    fn control_frames_are_never_dropped() {
        let plan = FaultPlan { drop_per_mille: 1000, max_drop_burst: 255, ..FaultPlan::quiet(4) };
        let (link, rx, _) = Link::new(64, 0, 0);
        let chaos = ChaosLink::new(link, 1, Some(plan));
        for e in 0..6u64 {
            let out = chaos
                .send(Message::EndRound { device_id: 1, epoch: e, examples: 3 })
                .unwrap();
            assert_eq!(out, Delivery::Delivered);
        }
        drop(chaos);
        assert_eq!(rx.iter().count(), 6);
    }

    #[test]
    fn duplicates_deliver_two_copies() {
        let plan = FaultPlan { dup_per_mille: 1000, ..FaultPlan::quiet(5) };
        let (link, rx, _) = Link::new(64, 0, 0);
        let chaos = ChaosLink::new(link, 2, Some(plan));
        assert_eq!(chaos.send(delta(2, 0, 12)).unwrap(), Delivery::Delivered);
        assert_eq!(chaos.stats().snapshot().duplicates, 1);
        drop(chaos);
        let msgs: Vec<Message> = rx.iter().collect();
        assert_eq!(msgs.len(), 2);
        for m in &msgs {
            assert!(matches!(m, Message::Delta { from: 2, epoch: 0, payload } if payload.len() == 12));
        }
    }

    #[test]
    fn held_frames_release_late_and_flush_on_done() {
        let plan = FaultPlan { delay_per_mille: 1000, max_delay: 1, ..FaultPlan::quiet(6) };
        let (link, rx, _) = Link::new(64, 0, 0);
        let chaos = ChaosLink::new(link, 3, Some(plan));
        // Every frame is held one slot: frame i is released by frame
        // i+1's send, producing a deterministic adjacent reorder; the
        // last frame only escapes via the Done flush.
        for e in 0..3u64 {
            assert_eq!(chaos.send(delta(3, e, 4)).unwrap(), Delivery::Delivered);
        }
        chaos.send(Message::Done { device_id: 3, examples: 0 }).unwrap();
        drop(chaos);
        let msgs: Vec<Message> = rx.iter().collect();
        assert_eq!(msgs.len(), 4);
        assert!(matches!(msgs.last().unwrap(), Message::Done { .. }));
        let epochs: Vec<u64> = msgs.iter().filter_map(|m| m.epoch()).collect();
        assert_eq!(epochs, vec![0, 1, 2], "held frames keep relative order here");
        assert_eq!(chaos.stats().snapshot().delayed, 3);
    }

    #[test]
    fn eventual_delivery_no_data_frame_is_lost_forever() {
        // Under an arbitrary chaotic plan, every frame the sender was
        // told was Delivered must come out before Done, and the number
        // of Dropped outcomes must match the drop stat.
        for seed in 0..20u64 {
            let plan = FaultPlan::from_seed(seed);
            let (link, rx, _) = Link::new(1024, 0, 0);
            let chaos = ChaosLink::new(link, 11, Some(plan));
            let mut delivered = 0u64;
            let mut dropped = 0u64;
            for e in 0..200u64 {
                match chaos.send(delta(11, e, 16)).unwrap() {
                    Delivery::Delivered => delivered += 1,
                    Delivery::Dropped => dropped += 1,
                }
            }
            chaos.send(Message::Done { device_id: 11, examples: 0 }).unwrap();
            let stats = chaos.stats().snapshot();
            drop(chaos);
            let msgs: Vec<Message> = rx.iter().collect();
            let deltas = msgs.iter().filter(|m| matches!(m, Message::Delta { .. })).count() as u64;
            assert!(matches!(msgs.last().unwrap(), Message::Done { .. }));
            assert_eq!(stats.drops, dropped, "seed {seed}");
            // Delivered + one extra copy per duplicate.
            assert_eq!(deltas, delivered + stats.duplicates, "seed {seed}");
        }
    }
}
