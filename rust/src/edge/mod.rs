//! Edge-device fleet simulation.
//!
//! The paper's deployment story: data is born on edge devices; each device
//! sketches its local stream one-pass; sketches (a few KB) flow over a
//! communication network and merge by addition; a leader trains against
//! the merged sketch. No raw example ever leaves a device.
//!
//! This module simulates that system faithfully enough to measure the
//! claims: thread-per-device ingestion, bounded channels for backpressure,
//! explicit link models (latency, bandwidth, byte counters), aggregation
//! topologies (star / tree / chain), and an energy model comparing sketch
//! shipping against raw-data shipping.

pub mod device;
pub mod faults;
pub mod network;
pub mod topology;
pub mod fleet;
pub mod energy;
