//! Edge-device fleet simulation.
//!
//! The paper's deployment story: data is born on edge devices; each device
//! sketches its local stream one-pass; sketches (a few KB) flow over a
//! communication network and merge by addition; a leader trains against
//! the merged sketch. No raw example ever leaves a device.
//!
//! This module simulates that system faithfully enough to measure the
//! claims: a worker-pool executor with arena device state (the default,
//! scaling to million-device fleets) plus a thread-per-node reference
//! scheduler, explicit link models (latency, bandwidth, byte counters),
//! aggregation topologies (star / tree / deep tree / chain), and an
//! energy model comparing sketch shipping against raw-data shipping.

pub mod device;
pub mod executor;
pub mod faults;
pub mod network;
pub mod topology;
pub mod fleet;
pub mod energy;
