//! Config validation: reject configurations that would silently produce
//! meaningless experiments (zero rows, p outside the hashable range, empty
//! fleets, and so on).

use super::{HashFamily, RunConfig};

/// Validate a full run configuration; returns a human-readable error.
pub fn validate(cfg: &RunConfig) -> Result<(), String> {
    if cfg.storm.rows == 0 {
        return Err("storm.rows must be >= 1".to_string());
    }
    if cfg.storm.rows > 1_000_000 {
        return Err("storm.rows unreasonably large (> 1e6)".to_string());
    }
    if cfg.storm.power == 0 || cfg.storm.power > 24 {
        return Err("storm.power must be in 1..=24 (buckets = 2^power)".to_string());
    }
    if let HashFamily::Sparse { density_permille } = cfg.storm.hash_family {
        if density_permille == 0 || density_permille > 1000 {
            return Err(format!(
                "storm.sparse_density must be in (0, 1] — the expected nonzero fraction \
                 per hyperplane (got {}); use 0.1 for the default 10% density, or \
                 hash_family = \"dense\" if you want every coordinate",
                density_permille as f64 / 1000.0
            ));
        }
    }
    if cfg.storm.hash_family != HashFamily::Dense && cfg.artifacts_dir.is_some() {
        return Err(format!(
            "artifacts_dir (the AOT XLA backend) embeds dense Gaussian hyperplanes and \
             cannot serve hash_family = \"{}\"; drop artifacts_dir to use the pure-rust \
             path, or set hash_family = \"dense\"",
            cfg.storm.hash_family
        ));
    }
    if cfg.optimizer.queries == 0 {
        return Err("optimizer.queries must be >= 1".to_string());
    }
    if !(cfg.optimizer.sigma > 0.0) || cfg.optimizer.sigma > 2.0 {
        return Err("optimizer.sigma must be in (0, 2]".to_string());
    }
    if !(cfg.optimizer.step > 0.0) {
        return Err("optimizer.step must be > 0".to_string());
    }
    if cfg.fleet.devices == 0 {
        return Err("fleet.devices must be >= 1".to_string());
    }
    if cfg.fleet.batch == 0 {
        return Err("fleet.batch must be >= 1".to_string());
    }
    if cfg.fleet.channel_capacity == 0 {
        return Err("fleet.channel_capacity must be >= 1".to_string());
    }
    if cfg.fleet.sync_rounds == 0 {
        return Err("fleet.sync_rounds must be >= 1".to_string());
    }
    if cfg.fleet.sync_rounds > 1_000_000 {
        return Err("fleet.sync_rounds unreasonably large (> 1e6)".to_string());
    }
    if cfg.fleet.min_quorum > cfg.fleet.devices {
        return Err(format!(
            "fleet.min_quorum ({}) exceeds fleet.devices ({}); use 0 for \"all\"",
            cfg.fleet.min_quorum, cfg.fleet.devices
        ));
    }
    // workers = 0 is the documented "auto" spelling (resolve to
    // available_parallelism at run time), so every non-absurd value is
    // legal; the cap only catches typos like workers = 80000.
    if cfg.fleet.workers > 4096 {
        return Err(format!(
            "fleet.workers ({}) unreasonably large (> 4096); use 0 for auto",
            cfg.fleet.workers
        ));
    }
    if cfg.fleet.fan_in < 2 {
        return Err(format!(
            "fleet.fan_in must be >= 2 (got {}): an aggregation node with fewer than \
             two children cannot reduce anything",
            cfg.fleet.fan_in
        ));
    }
    if !cfg.fleet.epsilon_per_round.is_finite() || cfg.fleet.epsilon_per_round < 0.0 {
        return Err(format!(
            "privacy.epsilon_per_round must be finite and >= 0 (got {}); 0 disables \
             delta-level DP",
            cfg.fleet.epsilon_per_round
        ));
    }
    if cfg.fleet.decay_keep_permille == 0 || cfg.fleet.decay_keep_permille > 1000 {
        return Err(format!(
            "privacy.decay_keep must be in (0, 1] — the fraction of every leader \
             counter kept per round (got {}); use 1.0 to disable decay",
            cfg.fleet.decay_keep_permille as f64 / 1000.0
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;

    fn base() -> RunConfig {
        RunConfig {
            dataset: "airfoil".to_string(),
            ..Default::default()
        }
    }

    #[test]
    fn default_is_valid() {
        assert!(validate(&base()).is_ok());
    }

    #[test]
    fn catches_each_violation() {
        let mut c = base();
        c.storm.rows = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.storm.power = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.storm.power = 30;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.optimizer.queries = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.optimizer.sigma = 0.0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.optimizer.step = 0.0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.devices = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.batch = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.channel_capacity = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.sync_rounds = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.min_quorum = c.fleet.devices + 1;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.storm.hash_family = HashFamily::Sparse { density_permille: 0 };
        assert!(validate(&c).is_err());

        let mut c = base();
        c.storm.hash_family = HashFamily::Sparse { density_permille: 1001 };
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.workers = 5000;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.fan_in = 1;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.fan_in = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.epsilon_per_round = -1.0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.epsilon_per_round = f64::NAN;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.epsilon_per_round = f64::INFINITY;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.decay_keep_permille = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.decay_keep_permille = 1001;
        assert!(validate(&c).is_err());
    }

    #[test]
    fn privacy_knob_edges_are_valid() {
        let mut c = base();
        c.fleet.epsilon_per_round = 0.0;
        c.fleet.decay_keep_permille = 1000;
        assert!(validate(&c).is_ok(), "both knobs off is the seed default");
        c.fleet.epsilon_per_round = 1e9;
        c.fleet.decay_keep_permille = 1;
        assert!(validate(&c).is_ok(), "huge epsilon and aggressive decay are legal");
    }

    #[test]
    fn workers_zero_means_auto_and_is_valid() {
        let mut c = base();
        c.fleet.workers = 0;
        assert!(validate(&c).is_ok(), "0 is the documented auto spelling");
        c.fleet.workers = 1;
        assert!(validate(&c).is_ok());
        c.fleet.workers = 4096;
        assert!(validate(&c).is_ok(), "the cap itself is inclusive");
    }

    #[test]
    fn workers_and_fan_in_toml_spellings() {
        // The TOML front-end routes through the same validator, so the
        // file spelling and the programmatic (CLI-built) config must
        // agree on what is rejected.
        let cfg = RunConfig::from_toml_str("[fleet]\nworkers = 0\nfan_in = 2\n").unwrap();
        assert_eq!(cfg.fleet.workers, 0);
        assert_eq!(cfg.fleet.fan_in, 2);
        let cfg = RunConfig::from_toml_str("[fleet]\nworkers = 8\nfan_in = 16\n").unwrap();
        assert_eq!(cfg.fleet.workers, 8);
        assert_eq!(cfg.fleet.fan_in, 16);
        let err = RunConfig::from_toml_str("[fleet]\nfan_in = 1\n").unwrap_err();
        assert!(err.to_string().contains("fan_in"), "{err}");
        let err = RunConfig::from_toml_str("[fleet]\nworkers = 99999\n").unwrap_err();
        assert!(err.to_string().contains("workers"), "{err}");
    }

    #[test]
    fn sparse_density_errors_are_actionable() {
        let mut c = base();
        c.storm.hash_family = HashFamily::Sparse { density_permille: 1500 };
        let msg = validate(&c).unwrap_err();
        assert!(msg.contains("(0, 1]"), "error must name the valid range: {msg}");
        assert!(msg.contains("1.5"), "error must echo the offending value: {msg}");
    }

    #[test]
    fn sparse_density_edges_are_valid() {
        let mut c = base();
        c.storm.hash_family = HashFamily::Sparse { density_permille: 1 };
        assert!(validate(&c).is_ok());
        c.storm.hash_family = HashFamily::Sparse { density_permille: 1000 };
        assert!(validate(&c).is_ok());
    }

    #[test]
    fn structured_families_reject_the_xla_backend() {
        // The AOT artifacts embed dense Gaussian planes; a structured
        // family would silently hash into a different bucket space.
        for family in
            [HashFamily::Sparse { density_permille: 100 }, HashFamily::Hadamard]
        {
            let mut c = base();
            c.storm.hash_family = family;
            assert!(validate(&c).is_ok(), "pure-rust path must accept {family}");
            c.artifacts_dir = Some("artifacts".to_string());
            let msg = validate(&c).unwrap_err();
            assert!(msg.contains("artifacts_dir"), "{msg}");
            assert!(msg.contains(family.name()), "{msg}");
        }
        let mut c = base();
        c.artifacts_dir = Some("artifacts".to_string());
        assert!(validate(&c).is_ok(), "dense + XLA stays valid");
    }

    #[test]
    fn quorum_within_fleet_is_valid() {
        let mut c = base();
        c.fleet.min_quorum = c.fleet.devices;
        assert!(validate(&c).is_ok());
        c.fleet.min_quorum = 1;
        assert!(validate(&c).is_ok());
    }
}
