//! Config validation: reject configurations that would silently produce
//! meaningless experiments (zero rows, p outside the hashable range, empty
//! fleets, and so on).

use super::RunConfig;

/// Validate a full run configuration; returns a human-readable error.
pub fn validate(cfg: &RunConfig) -> Result<(), String> {
    if cfg.storm.rows == 0 {
        return Err("storm.rows must be >= 1".to_string());
    }
    if cfg.storm.rows > 1_000_000 {
        return Err("storm.rows unreasonably large (> 1e6)".to_string());
    }
    if cfg.storm.power == 0 || cfg.storm.power > 24 {
        return Err("storm.power must be in 1..=24 (buckets = 2^power)".to_string());
    }
    if cfg.optimizer.queries == 0 {
        return Err("optimizer.queries must be >= 1".to_string());
    }
    if !(cfg.optimizer.sigma > 0.0) || cfg.optimizer.sigma > 2.0 {
        return Err("optimizer.sigma must be in (0, 2]".to_string());
    }
    if !(cfg.optimizer.step > 0.0) {
        return Err("optimizer.step must be > 0".to_string());
    }
    if cfg.fleet.devices == 0 {
        return Err("fleet.devices must be >= 1".to_string());
    }
    if cfg.fleet.batch == 0 {
        return Err("fleet.batch must be >= 1".to_string());
    }
    if cfg.fleet.channel_capacity == 0 {
        return Err("fleet.channel_capacity must be >= 1".to_string());
    }
    if cfg.fleet.sync_rounds == 0 {
        return Err("fleet.sync_rounds must be >= 1".to_string());
    }
    if cfg.fleet.sync_rounds > 1_000_000 {
        return Err("fleet.sync_rounds unreasonably large (> 1e6)".to_string());
    }
    if cfg.fleet.min_quorum > cfg.fleet.devices {
        return Err(format!(
            "fleet.min_quorum ({}) exceeds fleet.devices ({}); use 0 for \"all\"",
            cfg.fleet.min_quorum, cfg.fleet.devices
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;

    fn base() -> RunConfig {
        RunConfig {
            dataset: "airfoil".to_string(),
            ..Default::default()
        }
    }

    #[test]
    fn default_is_valid() {
        assert!(validate(&base()).is_ok());
    }

    #[test]
    fn catches_each_violation() {
        let mut c = base();
        c.storm.rows = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.storm.power = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.storm.power = 30;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.optimizer.queries = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.optimizer.sigma = 0.0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.optimizer.step = 0.0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.devices = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.batch = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.channel_capacity = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.sync_rounds = 0;
        assert!(validate(&c).is_err());

        let mut c = base();
        c.fleet.min_quorum = c.fleet.devices + 1;
        assert!(validate(&c).is_err());
    }

    #[test]
    fn quorum_within_fleet_is_valid() {
        let mut c = base();
        c.fleet.min_quorum = c.fleet.devices;
        assert!(validate(&c).is_ok());
        c.fleet.min_quorum = 1;
        assert!(validate(&c).is_ok());
    }
}
