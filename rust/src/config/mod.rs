//! Configuration system: typed configs for every subsystem plus a minimal
//! TOML-subset parser (`toml.rs`) so runs are reproducible from checked-in
//! config files without a `serde` dependency.

pub mod toml;
pub mod validate;

use crate::config::toml::TomlDoc;
use std::path::Path;

/// Sketch hyperparameters (Section 3 / 4.1 of the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StormConfig {
    /// Number of independent repetitions R (rows of the sketch).
    pub rows: usize,
    /// Number of hyperplanes p per PRP hash; the row has `2^p` buckets.
    /// The paper finds p = 4 the sweet spot (Figure 3).
    pub power: u32,
    /// Counter width policy: saturate instead of wrapping.
    pub saturating: bool,
}

impl Default for StormConfig {
    fn default() -> Self {
        StormConfig { rows: 50, power: 4, saturating: true }
    }
}

impl StormConfig {
    /// Buckets per row, `B = 2^p`.
    pub fn buckets(&self) -> usize {
        1usize << self.power
    }

    /// Sketch memory in bytes with `u32` counters (the paper's "tiny array
    /// of integer counters"; reported on the Figure-4 memory axis).
    pub fn sketch_bytes(&self) -> usize {
        self.rows * self.buckets() * std::mem::size_of::<u32>()
    }
}

/// Derivative-free optimizer settings (Algorithm 2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OptimizerConfig {
    /// Queries per gradient estimate (paper: k = 8).
    pub queries: usize,
    /// Sphere radius sigma (paper: 0.5).
    pub sigma: f64,
    /// Step size eta.
    pub step: f64,
    /// Iteration budget.
    pub iters: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        OptimizerConfig { queries: 8, sigma: 0.5, step: 0.5, iters: 300, seed: 0 }
    }
}

/// Edge-fleet simulation settings.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetConfig {
    pub devices: usize,
    /// Per-device ingest batch size.
    pub batch: usize,
    /// Bounded channel capacity between devices and the aggregator
    /// (backpressure window, in sketch-delta messages).
    pub channel_capacity: usize,
    /// Simulated link latency per message, microseconds.
    pub link_latency_us: u64,
    /// Simulated link bandwidth, bytes/second (0 = infinite).
    pub link_bandwidth_bps: u64,
    /// Sync rounds: devices ship one epoch-tagged delta per round and the
    /// coordinator trains between rounds. 1 = the one-shot pipeline
    /// (sketch everything, then train once).
    pub sync_rounds: usize,
    /// Barrier quorum: how many direct children a merge node waits for
    /// before closing a round (clamped to the node's child count).
    /// 0 = all children — the default, which preserves the ideal-network
    /// behaviour bit-for-bit; smaller quorums let rounds close without
    /// stragglers, whose deltas then fold late (still exactly once).
    pub min_quorum: usize,
    /// Seed for the deterministic fault-injection plan
    /// (`edge::faults::FaultPlan::from_seed`): drops, duplicates,
    /// delays/reorders, straggler rounds and one device crash/restart,
    /// all replayable from this one value. None = ideal network.
    pub faults_seed: Option<u64>,
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            devices: 4,
            batch: 64,
            channel_capacity: 16,
            link_latency_us: 200,
            link_bandwidth_bps: 0,
            sync_rounds: 1,
            min_quorum: 0,
            faults_seed: None,
            seed: 0,
        }
    }
}

/// Top-level run configuration assembled from a TOML file or CLI flags.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunConfig {
    pub dataset: String,
    pub storm: StormConfig,
    pub optimizer: OptimizerConfig,
    pub fleet: FleetConfig,
    /// Path to the AOT artifact directory (None = pure-rust path).
    pub artifacts_dir: Option<String>,
}

/// Configuration errors.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("parse error: {0}")]
    Parse(String),
    #[error("invalid config: {0}")]
    Invalid(String),
}

impl RunConfig {
    /// Load from a TOML file (see `configs/` for examples). Unknown keys
    /// are rejected — configs are an interface, typos should not pass
    /// silently.
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<RunConfig, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml_str(text: &str) -> Result<RunConfig, ConfigError> {
        let doc = TomlDoc::parse(text).map_err(ConfigError::Parse)?;
        let mut cfg = RunConfig {
            dataset: "airfoil".to_string(),
            ..Default::default()
        };
        for (section, key, value) in doc.entries() {
            match (section.as_str(), key.as_str()) {
                ("", "dataset") => cfg.dataset = value.as_str().to_string(),
                ("", "artifacts_dir") => cfg.artifacts_dir = Some(value.as_str().to_string()),
                ("storm", "rows") => cfg.storm.rows = value.as_usize().map_err(ConfigError::Parse)?,
                ("storm", "power") => {
                    cfg.storm.power = value.as_usize().map_err(ConfigError::Parse)? as u32
                }
                ("storm", "saturating") => {
                    cfg.storm.saturating = value.as_bool().map_err(ConfigError::Parse)?
                }
                ("optimizer", "queries") => {
                    cfg.optimizer.queries = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("optimizer", "sigma") => {
                    cfg.optimizer.sigma = value.as_f64().map_err(ConfigError::Parse)?
                }
                ("optimizer", "step") => {
                    cfg.optimizer.step = value.as_f64().map_err(ConfigError::Parse)?
                }
                ("optimizer", "iters") => {
                    cfg.optimizer.iters = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("optimizer", "seed") => {
                    cfg.optimizer.seed = value.as_usize().map_err(ConfigError::Parse)? as u64
                }
                ("fleet", "devices") => {
                    cfg.fleet.devices = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("fleet", "batch") => cfg.fleet.batch = value.as_usize().map_err(ConfigError::Parse)?,
                ("fleet", "channel_capacity") => {
                    cfg.fleet.channel_capacity = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("fleet", "link_latency_us") => {
                    cfg.fleet.link_latency_us = value.as_usize().map_err(ConfigError::Parse)? as u64
                }
                ("fleet", "link_bandwidth_bps") => {
                    cfg.fleet.link_bandwidth_bps =
                        value.as_usize().map_err(ConfigError::Parse)? as u64
                }
                ("fleet", "sync_rounds") => {
                    cfg.fleet.sync_rounds = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("fleet", "min_quorum") => {
                    cfg.fleet.min_quorum = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("fleet", "faults_seed") => {
                    cfg.fleet.faults_seed =
                        Some(value.as_usize().map_err(ConfigError::Parse)? as u64)
                }
                ("fleet", "seed") => {
                    cfg.fleet.seed = value.as_usize().map_err(ConfigError::Parse)? as u64
                }
                (s, k) => {
                    return Err(ConfigError::Parse(format!("unknown config key [{s}] {k}")));
                }
            }
        }
        validate::validate(&cfg).map_err(ConfigError::Invalid)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_values() {
        let s = StormConfig::default();
        assert_eq!(s.power, 4);
        assert_eq!(s.buckets(), 16);
        let o = OptimizerConfig::default();
        assert_eq!(o.queries, 8);
        assert_eq!(o.sigma, 0.5);
    }

    #[test]
    fn sketch_bytes_formula() {
        let s = StormConfig { rows: 100, power: 4, saturating: true };
        assert_eq!(s.sketch_bytes(), 100 * 16 * 4);
    }

    #[test]
    fn parses_full_toml() {
        let cfg = RunConfig::from_toml_str(
            r#"
dataset = "autos"
artifacts_dir = "artifacts"

[storm]
rows = 100
power = 4

[optimizer]
queries = 8
sigma = 0.5
step = 0.25
iters = 500
seed = 3

[fleet]
devices = 8
batch = 32
channel_capacity = 4
link_latency_us = 100
link_bandwidth_bps = 1000000
sync_rounds = 6
min_quorum = 5
faults_seed = 1234
seed = 7
"#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, "autos");
        assert_eq!(cfg.storm.rows, 100);
        assert_eq!(cfg.optimizer.iters, 500);
        assert_eq!(cfg.fleet.devices, 8);
        assert_eq!(cfg.fleet.link_bandwidth_bps, 1_000_000);
        assert_eq!(cfg.fleet.sync_rounds, 6);
        assert_eq!(cfg.fleet.min_quorum, 5);
        assert_eq!(cfg.fleet.faults_seed, Some(1234));
        assert_eq!(cfg.artifacts_dir.as_deref(), Some("artifacts"));
    }

    #[test]
    fn fault_knobs_default_off() {
        let cfg = RunConfig::from_toml_str("[fleet]\ndevices = 4\n").unwrap();
        assert_eq!(cfg.fleet.min_quorum, 0, "default quorum is all children");
        assert_eq!(cfg.fleet.faults_seed, None, "default network is ideal");
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(RunConfig::from_toml_str("[storm]\nwat = 3\n").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(RunConfig::from_toml_str("[storm]\nrows = 0\n").is_err());
    }
}
