//! Configuration system: typed configs for every subsystem plus a minimal
//! TOML-subset parser (`toml.rs`) so runs are reproducible from checked-in
//! config files without a `serde` dependency.

pub mod toml;
pub mod validate;

use crate::config::toml::TomlDoc;
use std::path::Path;

/// Storage width of one sketch counter cell. Sketch *memory* is the
/// resource the paper trades against risk; an MCU-class device whose
/// per-round counts never exceed a few hundred can run the whole sketch
/// in `u8` cells at a quarter of the `u32` footprint, while upstream
/// aggregators keep wide accumulators. Narrow counters saturate at their
/// own maximum (graceful degradation, device-local); merges widen
/// narrow-into-wide exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CounterWidth {
    U8,
    U16,
    #[default]
    U32,
}

impl CounterWidth {
    /// Bytes per counter cell.
    pub fn bytes(self) -> usize {
        match self {
            CounterWidth::U8 => 1,
            CounterWidth::U16 => 2,
            CounterWidth::U32 => 4,
        }
    }

    /// Largest value a cell of this width can hold.
    pub fn max_value(self) -> u32 {
        match self {
            CounterWidth::U8 => u8::MAX as u32,
            CounterWidth::U16 => u16::MAX as u32,
            CounterWidth::U32 => u32::MAX,
        }
    }

    /// The narrowest width that can hold `v` without clipping.
    pub fn fitting(v: u32) -> CounterWidth {
        if v <= u8::MAX as u32 {
            CounterWidth::U8
        } else if v <= u16::MAX as u32 {
            CounterWidth::U16
        } else {
            CounterWidth::U32
        }
    }

    /// Config/CLI name (`u8` | `u16` | `u32`).
    pub fn name(self) -> &'static str {
        match self {
            CounterWidth::U8 => "u8",
            CounterWidth::U16 => "u16",
            CounterWidth::U32 => "u32",
        }
    }

    /// Parse a config/CLI name; `None` for anything but `u8`/`u16`/`u32`.
    pub fn parse(s: &str) -> Option<CounterWidth> {
        match s.trim() {
            "u8" => Some(CounterWidth::U8),
            "u16" => Some(CounterWidth::U16),
            "u32" => Some(CounterWidth::U32),
            _ => None,
        }
    }
}

impl std::fmt::Display for CounterWidth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The learning task a sketch model estimates risk for. The paper proves
/// both ends: Theorem 2 (regression via the paired PRP surrogate) and
/// Theorem 3 (max-margin classification via the single-arm margin hash).
/// The whole pipeline — device, fleet, wire, driver — dispatches on this
/// one knob (`[storm] task` / CLI `--task`); see
/// [`crate::sketch::model::StormModel`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Task {
    /// Least-squares regression over augmented `[x, y]` examples
    /// (Theorem 2). The seed behaviour, and the default.
    #[default]
    Regression,
    /// Max-margin binary classification over labelled `[x, y]` examples
    /// with `y` in {-1, +1} (Theorem 3): labels fold into the hash sign.
    Classification,
}

impl Task {
    /// Config/CLI name (`regression` | `classification`).
    pub fn name(self) -> &'static str {
        match self {
            Task::Regression => "regression",
            Task::Classification => "classification",
        }
    }

    /// Parse a config/CLI name; `None` for anything else.
    pub fn parse(s: &str) -> Option<Task> {
        match s.trim() {
            "regression" => Some(Task::Regression),
            "classification" => Some(Task::Classification),
            _ => None,
        }
    }
}

impl std::fmt::Display for Task {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Default nonzero fraction for [`HashFamily::Sparse`], in per-mille
/// (100 = 10% of augmented coordinates per hyperplane — Achlioptas-style
/// sparse projections stay within the SRP concentration regime well
/// below this).
pub const DEFAULT_SPARSE_DENSITY_PERMILLE: u16 = 100;

/// The hyperplane family the sketch's LSH rows draw from — the
/// projection-cost knob of the hash hot path. All three families feed
/// the same fused sign-fold ([`crate::lsh::bank::HashBank`]); they trade
/// per-example FLOPs against the Gaussian family's tightest collision
/// guarantees:
///
/// * `dense` — iid Gaussian hyperplanes (the seed family; `O(d)` mults
///   per plane). The default; the only family the wire goldens and the
///   XLA backend embed.
/// * `sparse` — sparse Rademacher hyperplanes: each augmented coordinate
///   enters a plane with probability `density` and sign ±1, so a
///   projection is a few *adds* per nonzero.
/// * `hadamard` — fast-Hadamard SRP (`HD₁HD₂HD₃`-style): three sign
///   diagonals interleaved with Walsh–Hadamard transforms give `p`
///   pseudo-Gaussian projections in `O(m log m)` per row over the
///   padded power-of-two dimension `m`.
///
/// Merging sketches of different families is meaningless (the bucket
/// index spaces differ), so [`StormConfig::merge_compatible`] requires
/// equality, density included.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum HashFamily {
    /// Dense iid Gaussian hyperplanes (seed behaviour, wire-pinned).
    #[default]
    Dense,
    /// Sparse Rademacher hyperplanes at `density_permille / 1000`
    /// expected nonzeros per coordinate (each plane keeps at least one).
    Sparse {
        /// Expected nonzero fraction per hyperplane, in per-mille
        /// (valid range 1..=1000; see `config::validate`).
        density_permille: u16,
    },
    /// Fast-Hadamard structured SRP over the padded power-of-two dim.
    Hadamard,
}

impl HashFamily {
    /// Config/CLI name (`dense` | `sparse` | `hadamard`).
    pub fn name(self) -> &'static str {
        match self {
            HashFamily::Dense => "dense",
            HashFamily::Sparse { .. } => "sparse",
            HashFamily::Hadamard => "hadamard",
        }
    }

    /// Parse a config/CLI name; `None` for anything else. `sparse`
    /// parses at the default density
    /// ([`DEFAULT_SPARSE_DENSITY_PERMILLE`]); override it with the
    /// `sparse_density` key / `--sparse-density` flag.
    pub fn parse(s: &str) -> Option<HashFamily> {
        match s.trim() {
            "dense" => Some(HashFamily::Dense),
            "sparse" => Some(HashFamily::Sparse {
                density_permille: DEFAULT_SPARSE_DENSITY_PERMILLE,
            }),
            "hadamard" => Some(HashFamily::Hadamard),
            _ => None,
        }
    }

    /// Sparse nonzero fraction as a float (`None` for other families).
    pub fn sparse_density(self) -> Option<f64> {
        match self {
            HashFamily::Sparse { density_permille } => Some(density_permille as f64 / 1000.0),
            _ => None,
        }
    }
}

impl std::fmt::Display for HashFamily {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Sketch hyperparameters (Section 3 / 4.1 of the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StormConfig {
    /// Number of independent repetitions R (rows of the sketch).
    pub rows: usize,
    /// Number of hyperplanes p per PRP hash; the row has `2^p` buckets.
    /// The paper finds p = 4 the sweet spot (Figure 3).
    pub power: u32,
    /// Counter overflow policy: saturate instead of wrapping.
    pub saturating: bool,
    /// Counter cell width (`u32` default — the seed representation).
    pub counter_width: CounterWidth,
    /// Which risk the sketch estimates (regression is the seed default).
    /// The concrete sketch constructors normalize this to their own task;
    /// [`crate::sketch::model::StormModel`] dispatches on it.
    pub task: Task,
    /// Hyperplane family for the LSH rows (`dense` default — the seed
    /// Gaussian family; `sparse` / `hadamard` are the structured
    /// low-FLOP families). Fleet-wide invariant like `task`.
    pub hash_family: HashFamily,
}

impl Default for StormConfig {
    fn default() -> Self {
        StormConfig {
            rows: 50,
            power: 4,
            saturating: true,
            counter_width: CounterWidth::U32,
            task: Task::Regression,
            hash_family: HashFamily::Dense,
        }
    }
}

impl StormConfig {
    /// Buckets per row, `B = 2^p`.
    pub fn buckets(&self) -> usize {
        1usize << self.power
    }

    /// Sketch memory in bytes at the configured counter width (the
    /// paper's "tiny array of integer counters"; reported on the
    /// Figure-4 memory axis).
    pub fn sketch_bytes(&self) -> usize {
        self.rows * self.buckets() * self.counter_width.bytes()
    }

    /// True when two sketches/deltas of these configs can be merged:
    /// identical geometry, overflow policy, *task* (a classification
    /// delta folded into a regression sketch would silently mix two
    /// different hash constructions) and *hyperplane family* (dense /
    /// sparse / Hadamard rows index incompatible bucket spaces even at
    /// the same seed; sparse density counts too). Counter *width* is
    /// allowed to differ — merges widen narrow-into-wide exactly (and
    /// clip wide-into-narrow at the destination's width, same as local
    /// saturation).
    pub fn merge_compatible(&self, other: &StormConfig) -> bool {
        self.rows == other.rows
            && self.power == other.power
            && self.saturating == other.saturating
            && self.task == other.task
            && self.hash_family == other.hash_family
    }
}

/// Derivative-free optimizer settings (Algorithm 2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OptimizerConfig {
    /// Queries per gradient estimate (paper: k = 8).
    pub queries: usize,
    /// Sphere radius sigma (paper: 0.5).
    pub sigma: f64,
    /// Step size eta.
    pub step: f64,
    /// Iteration budget.
    pub iters: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        OptimizerConfig { queries: 8, sigma: 0.5, step: 0.5, iters: 300, seed: 0 }
    }
}

/// Edge-fleet simulation settings.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetConfig {
    pub devices: usize,
    /// Per-device ingest batch size.
    pub batch: usize,
    /// Bounded channel capacity between devices and the aggregator
    /// (backpressure window, in sketch-delta messages).
    pub channel_capacity: usize,
    /// Simulated link latency per message, microseconds.
    pub link_latency_us: u64,
    /// Simulated link bandwidth, bytes/second (0 = infinite).
    pub link_bandwidth_bps: u64,
    /// Sync rounds: devices ship one epoch-tagged delta per round and the
    /// coordinator trains between rounds. 1 = the one-shot pipeline
    /// (sketch everything, then train once).
    pub sync_rounds: usize,
    /// Barrier quorum: how many direct children a merge node waits for
    /// before closing a round (clamped to the node's child count).
    /// 0 = all children — the default, which preserves the ideal-network
    /// behaviour bit-for-bit; smaller quorums let rounds close without
    /// stragglers, whose deltas then fold late (still exactly once).
    pub min_quorum: usize,
    /// Seed for the deterministic fault-injection plan
    /// (`edge::faults::FaultPlan::from_seed`): drops, duplicates,
    /// delays/reorders, straggler rounds and one device crash/restart,
    /// all replayable from this one value. None = ideal network.
    pub faults_seed: Option<u64>,
    /// Per-tier counter-width override for *device* sketches: devices run
    /// at this width while aggregators and the leader keep the
    /// `[storm] counter_width` accumulators. Merges widen narrow device
    /// deltas into the wide upstream counters exactly (saturation, if
    /// any, is device-local). None = devices use `[storm] counter_width`.
    pub device_counter_width: Option<CounterWidth>,
    /// Worker threads for the arena fleet executor. 0 = auto
    /// (`std::thread::available_parallelism`). The executor schedules
    /// every device and aggregator state machine cooperatively across
    /// this pool, so the knob bounds OS threads — not fleet size — and
    /// results are bit-identical at every worker count.
    pub workers: usize,
    /// Maximum children per aggregation node for `tree` / `deep`
    /// topologies (must be >= 2). Star and chain ignore it.
    pub fan_in: usize,
    /// Per-round differential-privacy budget for *delta-level* DP
    /// (`[privacy] epsilon_per_round` / `--epsilon`): each device adds
    /// two-sided geometric noise to its per-epoch delta counters before
    /// encoding, so the coordinator only ever sees noised integers.
    /// Spend composes linearly across sync rounds (sequential
    /// composition); the driver surfaces the running ledger. 0 = off —
    /// the shipped bytes are bit-identical to the non-private pipeline.
    pub epsilon_per_round: f64,
    /// Leader-side exponential counter decay at round boundaries, as the
    /// *kept* fraction in per-mille (`[privacy] decay_keep`, a float in
    /// (0, 1] in the TOML). 900 keeps 90% of every leader counter per
    /// round (half-life ≈ 6.6 rounds), down-weighting stale data under
    /// distribution shift. 1000 = off — the leader fold stays exactly
    /// cumulative, preserving the bit-identity invariants.
    pub decay_keep_permille: u16,
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            devices: 4,
            batch: 64,
            channel_capacity: 16,
            link_latency_us: 200,
            link_bandwidth_bps: 0,
            sync_rounds: 1,
            min_quorum: 0,
            faults_seed: None,
            device_counter_width: None,
            workers: 0,
            fan_in: 2,
            epsilon_per_round: 0.0,
            decay_keep_permille: 1000,
            seed: 0,
        }
    }
}

/// Top-level run configuration assembled from a TOML file or CLI flags.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunConfig {
    pub dataset: String,
    pub storm: StormConfig,
    pub optimizer: OptimizerConfig,
    pub fleet: FleetConfig,
    /// Path to the AOT artifact directory (None = pure-rust path).
    pub artifacts_dir: Option<String>,
}

/// Configuration errors.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("parse error: {0}")]
    Parse(String),
    #[error("invalid config: {0}")]
    Invalid(String),
}

impl RunConfig {
    /// Load from a TOML file (see `configs/` for examples). Unknown keys
    /// are rejected — configs are an interface, typos should not pass
    /// silently.
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<RunConfig, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml_str(text: &str) -> Result<RunConfig, ConfigError> {
        let doc = TomlDoc::parse(text).map_err(ConfigError::Parse)?;
        let mut cfg = RunConfig {
            dataset: "airfoil".to_string(),
            ..Default::default()
        };
        // `sparse_density` may appear before or after `hash_family` in the
        // file; hold it until both keys have been seen.
        let mut pending_sparse_density: Option<f64> = None;
        for (section, key, value) in doc.entries() {
            match (section.as_str(), key.as_str()) {
                ("", "dataset") => cfg.dataset = value.as_str().to_string(),
                ("", "artifacts_dir") => cfg.artifacts_dir = Some(value.as_str().to_string()),
                ("storm", "rows") => cfg.storm.rows = value.as_usize().map_err(ConfigError::Parse)?,
                ("storm", "power") => {
                    cfg.storm.power = value.as_usize().map_err(ConfigError::Parse)? as u32
                }
                ("storm", "saturating") => {
                    cfg.storm.saturating = value.as_bool().map_err(ConfigError::Parse)?
                }
                ("storm", "counter_width") => {
                    cfg.storm.counter_width = CounterWidth::parse(value.as_str()).ok_or_else(|| {
                        ConfigError::Parse(format!(
                            "storm.counter_width must be u8|u16|u32, got {:?}",
                            value.as_str()
                        ))
                    })?
                }
                ("storm", "task") => {
                    cfg.storm.task = Task::parse(value.as_str()).ok_or_else(|| {
                        ConfigError::Parse(format!(
                            "storm.task must be regression|classification, got {:?}",
                            value.as_str()
                        ))
                    })?
                }
                ("storm", "hash_family") => {
                    cfg.storm.hash_family =
                        HashFamily::parse(value.as_str()).ok_or_else(|| {
                            ConfigError::Parse(format!(
                                "storm.hash_family must be dense|sparse|hadamard, got {:?}",
                                value.as_str()
                            ))
                        })?
                }
                ("storm", "sparse_density") => {
                    pending_sparse_density = Some(value.as_f64().map_err(ConfigError::Parse)?)
                }
                ("optimizer", "queries") => {
                    cfg.optimizer.queries = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("optimizer", "sigma") => {
                    cfg.optimizer.sigma = value.as_f64().map_err(ConfigError::Parse)?
                }
                ("optimizer", "step") => {
                    cfg.optimizer.step = value.as_f64().map_err(ConfigError::Parse)?
                }
                ("optimizer", "iters") => {
                    cfg.optimizer.iters = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("optimizer", "seed") => {
                    cfg.optimizer.seed = value.as_usize().map_err(ConfigError::Parse)? as u64
                }
                ("fleet", "devices") => {
                    cfg.fleet.devices = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("fleet", "batch") => cfg.fleet.batch = value.as_usize().map_err(ConfigError::Parse)?,
                ("fleet", "channel_capacity") => {
                    cfg.fleet.channel_capacity = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("fleet", "link_latency_us") => {
                    cfg.fleet.link_latency_us = value.as_usize().map_err(ConfigError::Parse)? as u64
                }
                ("fleet", "link_bandwidth_bps") => {
                    cfg.fleet.link_bandwidth_bps =
                        value.as_usize().map_err(ConfigError::Parse)? as u64
                }
                ("fleet", "sync_rounds") => {
                    cfg.fleet.sync_rounds = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("fleet", "min_quorum") => {
                    cfg.fleet.min_quorum = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("fleet", "faults_seed") => {
                    cfg.fleet.faults_seed =
                        Some(value.as_usize().map_err(ConfigError::Parse)? as u64)
                }
                ("fleet", "device_counter_width") => {
                    cfg.fleet.device_counter_width =
                        Some(CounterWidth::parse(value.as_str()).ok_or_else(|| {
                            ConfigError::Parse(format!(
                                "fleet.device_counter_width must be u8|u16|u32, got {:?}",
                                value.as_str()
                            ))
                        })?)
                }
                ("fleet", "workers") => {
                    cfg.fleet.workers = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("fleet", "fan_in") => {
                    cfg.fleet.fan_in = value.as_usize().map_err(ConfigError::Parse)?
                }
                ("fleet", "seed") => {
                    cfg.fleet.seed = value.as_usize().map_err(ConfigError::Parse)? as u64
                }
                ("privacy", "epsilon_per_round") => {
                    cfg.fleet.epsilon_per_round =
                        value.as_f64().map_err(ConfigError::Parse)?
                }
                ("privacy", "decay_keep") => {
                    // Stored in per-mille like sparse_density; out-of-range
                    // values survive the conversion so `validate` can
                    // report them against (0, 1].
                    let keep = value.as_f64().map_err(ConfigError::Parse)?;
                    let permille = (keep * 1000.0).round().clamp(0.0, u16::MAX as f64);
                    cfg.fleet.decay_keep_permille = permille as u16;
                }
                (s, k) => {
                    return Err(ConfigError::Parse(format!("unknown config key [{s}] {k}")));
                }
            }
        }
        if let Some(density) = pending_sparse_density {
            match cfg.storm.hash_family {
                HashFamily::Sparse { .. } => {
                    // Out-of-range values survive the conversion so
                    // `validate` can report them against (0, 1].
                    let permille = (density * 1000.0).round().clamp(0.0, u16::MAX as f64);
                    cfg.storm.hash_family =
                        HashFamily::Sparse { density_permille: permille as u16 };
                }
                other => {
                    return Err(ConfigError::Parse(format!(
                        "storm.sparse_density only applies to hash_family = \"sparse\" \
                         (got hash_family = {:?})",
                        other.name()
                    )));
                }
            }
        }
        validate::validate(&cfg).map_err(ConfigError::Invalid)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_values() {
        let s = StormConfig::default();
        assert_eq!(s.power, 4);
        assert_eq!(s.buckets(), 16);
        let o = OptimizerConfig::default();
        assert_eq!(o.queries, 8);
        assert_eq!(o.sigma, 0.5);
    }

    #[test]
    fn sketch_bytes_formula_is_width_true() {
        let mut s = StormConfig { rows: 100, power: 4, saturating: true, ..Default::default() };
        assert_eq!(s.sketch_bytes(), 100 * 16 * 4);
        s.counter_width = CounterWidth::U8;
        assert_eq!(s.sketch_bytes(), 100 * 16);
        s.counter_width = CounterWidth::U16;
        assert_eq!(s.sketch_bytes(), 100 * 16 * 2);
    }

    #[test]
    fn counter_width_parse_and_fit() {
        assert_eq!(CounterWidth::parse("u8"), Some(CounterWidth::U8));
        assert_eq!(CounterWidth::parse(" u16 "), Some(CounterWidth::U16));
        assert_eq!(CounterWidth::parse("u32"), Some(CounterWidth::U32));
        assert_eq!(CounterWidth::parse("u64"), None);
        assert_eq!(CounterWidth::fitting(0), CounterWidth::U8);
        assert_eq!(CounterWidth::fitting(255), CounterWidth::U8);
        assert_eq!(CounterWidth::fitting(256), CounterWidth::U16);
        assert_eq!(CounterWidth::fitting(65_536), CounterWidth::U32);
        assert!(CounterWidth::U8 < CounterWidth::U16 && CounterWidth::U16 < CounterWidth::U32);
        assert_eq!(CounterWidth::default(), CounterWidth::U32);
        assert_eq!(CounterWidth::U8.to_string(), "u8");
    }

    #[test]
    fn merge_compatible_ignores_width_only() {
        let base = StormConfig::default();
        let narrow = StormConfig { counter_width: CounterWidth::U8, ..base };
        assert!(base.merge_compatible(&narrow));
        assert!(!base.merge_compatible(&StormConfig { rows: base.rows + 1, ..base }));
        assert!(!base.merge_compatible(&StormConfig { power: 3, ..base }));
        assert!(!base.merge_compatible(&StormConfig { saturating: false, ..base }));
        assert!(
            !base.merge_compatible(&StormConfig { task: Task::Classification, ..base }),
            "cross-task merges must be rejected: the hash families differ"
        );
        assert!(
            !base.merge_compatible(&StormConfig {
                hash_family: HashFamily::Sparse { density_permille: 100 },
                ..base
            }),
            "cross-hash-family merges must be rejected: incompatible bucket spaces"
        );
        assert!(
            !base.merge_compatible(&StormConfig { hash_family: HashFamily::Hadamard, ..base }),
        );
        let sparse_a = StormConfig {
            hash_family: HashFamily::Sparse { density_permille: 100 },
            ..base
        };
        let sparse_b = StormConfig {
            hash_family: HashFamily::Sparse { density_permille: 200 },
            ..base
        };
        assert!(
            !sparse_a.merge_compatible(&sparse_b),
            "same family at different densities draws different planes"
        );
        assert!(sparse_a.merge_compatible(&sparse_a));
    }

    #[test]
    fn hash_family_parse_display_and_default() {
        assert_eq!(HashFamily::parse("dense"), Some(HashFamily::Dense));
        assert_eq!(
            HashFamily::parse(" sparse "),
            Some(HashFamily::Sparse { density_permille: DEFAULT_SPARSE_DENSITY_PERMILLE })
        );
        assert_eq!(HashFamily::parse("hadamard"), Some(HashFamily::Hadamard));
        assert_eq!(HashFamily::parse("fourier"), None);
        assert_eq!(HashFamily::default(), HashFamily::Dense);
        assert_eq!(HashFamily::Sparse { density_permille: 50 }.to_string(), "sparse");
        assert_eq!(HashFamily::Sparse { density_permille: 250 }.sparse_density(), Some(0.25));
        assert_eq!(HashFamily::Dense.sparse_density(), None);
    }

    #[test]
    fn hash_family_key_parses_and_rejects_bad_values() {
        let cfg = RunConfig::from_toml_str("[storm]\nhash_family = \"hadamard\"\n").unwrap();
        assert_eq!(cfg.storm.hash_family, HashFamily::Hadamard);
        let cfg = RunConfig::from_toml_str("[storm]\nrows = 10\n").unwrap();
        assert_eq!(cfg.storm.hash_family, HashFamily::Dense, "seed default is dense");
        assert!(RunConfig::from_toml_str("[storm]\nhash_family = \"circulant\"\n").is_err());
    }

    #[test]
    fn sparse_density_key_applies_in_either_order() {
        let cfg = RunConfig::from_toml_str(
            "[storm]\nhash_family = \"sparse\"\nsparse_density = 0.25\n",
        )
        .unwrap();
        assert_eq!(cfg.storm.hash_family, HashFamily::Sparse { density_permille: 250 });
        let cfg = RunConfig::from_toml_str(
            "[storm]\nsparse_density = 0.05\nhash_family = \"sparse\"\n",
        )
        .unwrap();
        assert_eq!(cfg.storm.hash_family, HashFamily::Sparse { density_permille: 50 });
        // Without an explicit density the default applies.
        let cfg = RunConfig::from_toml_str("[storm]\nhash_family = \"sparse\"\n").unwrap();
        assert_eq!(
            cfg.storm.hash_family,
            HashFamily::Sparse { density_permille: DEFAULT_SPARSE_DENSITY_PERMILLE }
        );
    }

    #[test]
    fn sparse_density_rejected_without_sparse_family() {
        assert!(RunConfig::from_toml_str("[storm]\nsparse_density = 0.1\n").is_err());
        assert!(RunConfig::from_toml_str(
            "[storm]\nhash_family = \"hadamard\"\nsparse_density = 0.1\n"
        )
        .is_err());
    }

    #[test]
    fn sparse_density_out_of_range_rejected() {
        for bad in ["0.0", "-0.5", "1.5", "2000.0"] {
            let text =
                format!("[storm]\nhash_family = \"sparse\"\nsparse_density = {bad}\n");
            assert!(RunConfig::from_toml_str(&text).is_err(), "density {bad} accepted");
        }
        // 1.0 (every coordinate) is the inclusive upper edge.
        let cfg = RunConfig::from_toml_str(
            "[storm]\nhash_family = \"sparse\"\nsparse_density = 1.0\n",
        )
        .unwrap();
        assert_eq!(cfg.storm.hash_family, HashFamily::Sparse { density_permille: 1000 });
    }

    #[test]
    fn task_parse_display_and_default() {
        assert_eq!(Task::parse("regression"), Some(Task::Regression));
        assert_eq!(Task::parse(" classification "), Some(Task::Classification));
        assert_eq!(Task::parse("clustering"), None);
        assert_eq!(Task::default(), Task::Regression);
        assert_eq!(Task::Classification.to_string(), "classification");
    }

    #[test]
    fn task_key_parses_and_rejects_bad_values() {
        let cfg = RunConfig::from_toml_str("[storm]\ntask = \"classification\"\n").unwrap();
        assert_eq!(cfg.storm.task, Task::Classification);
        let cfg = RunConfig::from_toml_str("[storm]\nrows = 10\n").unwrap();
        assert_eq!(cfg.storm.task, Task::Regression, "seed default is regression");
        assert!(RunConfig::from_toml_str("[storm]\ntask = \"ranking\"\n").is_err());
    }

    #[test]
    fn parses_full_toml() {
        let cfg = RunConfig::from_toml_str(
            r#"
dataset = "autos"
artifacts_dir = "artifacts"

[storm]
rows = 100
power = 4
counter_width = "u16"

[optimizer]
queries = 8
sigma = 0.5
step = 0.25
iters = 500
seed = 3

[fleet]
devices = 8
batch = 32
channel_capacity = 4
link_latency_us = 100
link_bandwidth_bps = 1000000
sync_rounds = 6
min_quorum = 5
faults_seed = 1234
device_counter_width = "u8"
workers = 4
fan_in = 8
seed = 7

[privacy]
epsilon_per_round = 0.5
decay_keep = 0.9
"#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, "autos");
        assert_eq!(cfg.storm.rows, 100);
        assert_eq!(cfg.storm.counter_width, CounterWidth::U16);
        assert_eq!(cfg.fleet.device_counter_width, Some(CounterWidth::U8));
        assert_eq!(cfg.optimizer.iters, 500);
        assert_eq!(cfg.fleet.devices, 8);
        assert_eq!(cfg.fleet.link_bandwidth_bps, 1_000_000);
        assert_eq!(cfg.fleet.sync_rounds, 6);
        assert_eq!(cfg.fleet.min_quorum, 5);
        assert_eq!(cfg.fleet.faults_seed, Some(1234));
        assert_eq!(cfg.fleet.workers, 4);
        assert_eq!(cfg.fleet.fan_in, 8);
        assert_eq!(cfg.fleet.epsilon_per_round, 0.5);
        assert_eq!(cfg.fleet.decay_keep_permille, 900);
        assert_eq!(cfg.artifacts_dir.as_deref(), Some("artifacts"));
    }

    #[test]
    fn fault_knobs_default_off() {
        let cfg = RunConfig::from_toml_str("[fleet]\ndevices = 4\n").unwrap();
        assert_eq!(cfg.fleet.min_quorum, 0, "default quorum is all children");
        assert_eq!(cfg.fleet.faults_seed, None, "default network is ideal");
        assert_eq!(cfg.storm.counter_width, CounterWidth::U32, "default width is the seed u32");
        assert_eq!(cfg.fleet.device_counter_width, None, "devices follow [storm] by default");
        assert_eq!(cfg.fleet.workers, 0, "default worker count is auto");
        assert_eq!(cfg.fleet.fan_in, 2, "default fan-in matches the seed tree fanout");
        assert_eq!(cfg.fleet.epsilon_per_round, 0.0, "privacy defaults off");
        assert_eq!(cfg.fleet.decay_keep_permille, 1000, "decay defaults off");
    }

    #[test]
    fn privacy_knobs_parse_and_reject_bad_values() {
        let cfg =
            RunConfig::from_toml_str("[privacy]\nepsilon_per_round = 1.25\n").unwrap();
        assert_eq!(cfg.fleet.epsilon_per_round, 1.25);
        assert_eq!(cfg.fleet.decay_keep_permille, 1000, "decay stays off");
        let cfg = RunConfig::from_toml_str("[privacy]\ndecay_keep = 0.5\n").unwrap();
        assert_eq!(cfg.fleet.decay_keep_permille, 500);
        // decay_keep = 1.0 (no decay) is the inclusive upper edge.
        let cfg = RunConfig::from_toml_str("[privacy]\ndecay_keep = 1.0\n").unwrap();
        assert_eq!(cfg.fleet.decay_keep_permille, 1000);
        for bad in [
            "epsilon_per_round = -0.5",
            "decay_keep = 0.0",
            "decay_keep = -0.1",
            "decay_keep = 1.5",
            "budget = 3",
        ] {
            let text = format!("[privacy]\n{bad}\n");
            assert!(RunConfig::from_toml_str(&text).is_err(), "{bad} accepted");
        }
    }

    #[test]
    fn bad_counter_width_rejected() {
        assert!(RunConfig::from_toml_str("[storm]\ncounter_width = \"u64\"\n").is_err());
        assert!(RunConfig::from_toml_str("[fleet]\ndevice_counter_width = \"wide\"\n").is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(RunConfig::from_toml_str("[storm]\nwat = 3\n").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(RunConfig::from_toml_str("[storm]\nrows = 0\n").is_err());
    }
}
