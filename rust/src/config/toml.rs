//! Minimal TOML-subset parser: `[section]` headers, `key = value` pairs,
//! `#` comments; values are strings ("..."), booleans, integers, and
//! floats. That covers the crate's config files without a serde stack.

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
}

impl TomlValue {
    pub fn as_str(&self) -> &str {
        match self {
            TomlValue::Str(s) => s,
            _ => "",
        }
    }

    pub fn as_bool(&self) -> Result<bool, String> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(format!("expected bool, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize, String> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            other => Err(format!("expected non-negative integer, got {other:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64, String> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => Err(format!("expected number, got {other:?}")),
        }
    }
}

/// A parsed document: ordered `(section, key, value)` triples. The root
/// section is the empty string.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: Vec<(String, String, TomlValue)>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = parse_value(value.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.entries.push((section.clone(), key.to_string(), value));
        }
        Ok(doc)
    }

    pub fn entries(&self) -> impl Iterator<Item = &(String, String, TomlValue)> {
        self.entries.iter()
    }

    /// Look up a single key.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries
            .iter()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string is preserved.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".to_string());
    }
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".to_string());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            "top = \"root\"\n[a]\nx = 1\ny = 2.5\nz = true\n[b]\ns = \"hi\" # comment\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_str(), "root");
        assert_eq!(doc.get("a", "x").unwrap().as_usize().unwrap(), 1);
        assert_eq!(doc.get("a", "y").unwrap().as_f64().unwrap(), 2.5);
        assert!(doc.get("a", "z").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("b", "s").unwrap().as_str(), "hi");
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let doc = TomlDoc::parse("# header\n\nx = 3 # trailing\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn hash_inside_string_preserved() {
        let doc = TomlDoc::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str(), "a#b");
    }

    #[test]
    fn negative_int_not_usize() {
        let doc = TomlDoc::parse("x = -3\n").unwrap();
        assert!(doc.get("", "x").unwrap().as_usize().is_err());
        assert_eq!(doc.get("", "x").unwrap().as_f64().unwrap(), -3.0);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("x = 1\noops\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = TomlDoc::parse("[bad\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn unterminated_string_rejected() {
        assert!(TomlDoc::parse("s = \"abc\n").is_err());
    }
}
