//! `storm` — the coordinator CLI.
//!
//! Subcommands:
//!
//! * `train`      — end-to-end: fleet -> merged sketch -> DFO -> report
//! * `experiment` — regenerate a paper table/figure (see `--list`)
//! * `sketch`     — build a sketch of a dataset and print its stats
//! * `info`       — registry, artifact manifest and version info

#![deny(unsafe_code)]
#![deny(clippy::dbg_macro, clippy::todo, clippy::unimplemented, clippy::mem_forget)]

use storm::config::{RunConfig, StormConfig};
use storm::coordinator::driver::{train, QueryBackend};
use storm::data::registry;
use storm::edge::topology::Topology;
use storm::experiments::{self, Effort};
use storm::util::argparse::{ArgError, ArgParser};

fn main() {
    storm::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..]),
        Some("experiment") => cmd_experiment(&args[1..]),
        Some("sketch") => cmd_sketch(&args[1..]),
        Some("info") => cmd_info(),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "storm {} — sketches toward online risk minimization

USAGE:
  storm <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
  train         train a model end-to-end on the edge-fleet simulator
  experiment    regenerate a paper table/figure (try: storm experiment --list)
  sketch        build a sketch of a dataset and print stats
  info          registry + artifact info

Run `storm <SUBCOMMAND> --help` for options.",
        storm::VERSION
    );
}

fn parse_width(s: &str) -> anyhow::Result<storm::config::CounterWidth> {
    storm::config::CounterWidth::parse(s)
        .ok_or_else(|| anyhow::anyhow!("counter width must be u8|u16|u32, got {s:?}"))
}

/// Resolve `--hash-family` (+ optional `--sparse-density`) into a
/// [`storm::config::HashFamily`], with the same per-mille conversion and
/// bounds the TOML loader applies.
fn parse_hash_family(
    family: &str,
    density: Option<f64>,
) -> anyhow::Result<storm::config::HashFamily> {
    use storm::config::HashFamily;
    let mut fam = HashFamily::parse(family).ok_or_else(|| {
        anyhow::anyhow!("--hash-family must be dense|sparse|hadamard, got {family:?}")
    })?;
    if let Some(d) = density {
        anyhow::ensure!(
            matches!(fam, HashFamily::Sparse { .. }),
            "--sparse-density only applies to --hash-family sparse (got {family:?})"
        );
        anyhow::ensure!(
            d > 0.0 && d <= 1.0,
            "--sparse-density must be a fraction in (0, 1], got {d}"
        );
        fam = HashFamily::Sparse {
            density_permille: (d * 1000.0).round().clamp(1.0, 1000.0) as u16,
        };
    }
    Ok(fam)
}

fn handle_help(parser: &ArgParser, err: ArgError) -> i32 {
    match err {
        ArgError::HelpRequested => {
            print!("{}", parser.usage());
            0
        }
        other => {
            eprintln!("error: {other}");
            2
        }
    }
}

fn cmd_train(args: &[String]) -> i32 {
    let parser = ArgParser::new("storm train", "end-to-end edge training")
        .opt("dataset", Some("airfoil"), "registry dataset name")
        .opt("task", Some("regression"), "learning task: regression | classification")
        .opt("rows", Some("100"), "sketch rows R")
        .opt("power", Some("4"), "hyperplanes per row p (buckets = 2^p)")
        .opt("counter-width", Some("u32"), "counter cell width: u8 | u16 | u32")
        .opt(
            "device-counter-width",
            None,
            "narrower width for DEVICE sketches only (u8 | u16 | u32); merges widen exactly",
        )
        .opt(
            "hash-family",
            Some("dense"),
            "hyperplane family: dense | sparse | hadamard (structured = cheaper projections)",
        )
        .opt(
            "sparse-density",
            None,
            "nonzero fraction in (0, 1] for --hash-family sparse (default 0.1)",
        )
        .opt("devices", Some("4"), "simulated edge devices")
        .opt("workers", Some("0"), "executor worker threads (0 = one per hardware core)")
        .opt("fan-in", Some("2"), "children per merge node for tree/deep topologies (>= 2)")
        .opt("sync-rounds", Some("1"), "delta sync rounds (training interleaves between rounds)")
        .opt("min-quorum", Some("0"), "children a barrier waits for (0 = all; stragglers fold late)")
        .opt("faults-seed", None, "seeded chaos schedule: drops/dups/reorders + straggler rounds + one crash")
        .opt(
            "epsilon",
            Some("0"),
            "per-round differential-privacy budget per device (0 = off, bit-identical wire)",
        )
        .opt(
            "decay-keep",
            Some("1.0"),
            "fraction of every leader counter kept per round in (0, 1] (1.0 = no decay)",
        )
        .opt("iters", Some("400"), "DFO iterations (split across sync rounds)")
        .opt("queries", Some("8"), "DFO probes per iteration")
        .opt("sigma", Some("0.3"), "DFO sphere radius")
        .opt("step", Some("0.6"), "DFO step size")
        .opt("seed", Some("0"), "run seed")
        .opt("topology", Some("star"), "star | tree | deep | chain (tree/deep use --fan-in)")
        .opt("backend", Some("rust"), "query backend: rust | xla")
        .opt("artifacts", Some("artifacts"), "artifact dir for the xla backend")
        .opt("checkpoint", None, "write final state to this path");
    let parsed = match parser.parse(args.iter().cloned()) {
        Ok(p) => p,
        Err(e) => return handle_help(&parser, e),
    };
    let run = || -> anyhow::Result<i32> {
        let mut cfg = RunConfig {
            dataset: parsed.get_string("dataset"),
            ..Default::default()
        };
        cfg.storm.rows = parsed.get_usize("rows")?;
        cfg.storm.power = parsed.get_usize("power")? as u32;
        let task_name = parsed.get_string("task");
        cfg.storm.task = storm::config::Task::parse(&task_name).ok_or_else(|| {
            anyhow::anyhow!("--task must be regression|classification, got {task_name:?}")
        })?;
        cfg.storm.counter_width = parse_width(&parsed.get_string("counter-width"))?;
        if let Some(w) = parsed.get("device-counter-width") {
            cfg.fleet.device_counter_width = Some(parse_width(w)?);
        }
        let density = match parsed.get("sparse-density") {
            Some(_) => Some(parsed.get_f64("sparse-density")?),
            None => None,
        };
        cfg.storm.hash_family = parse_hash_family(&parsed.get_string("hash-family"), density)?;
        cfg.fleet.devices = parsed.get_usize("devices")?;
        cfg.fleet.workers = parsed.get_usize("workers")?;
        cfg.fleet.fan_in = parsed.get_usize("fan-in")?;
        anyhow::ensure!(cfg.fleet.fan_in >= 2, "--fan-in must be >= 2");
        cfg.fleet.sync_rounds = parsed.get_usize("sync-rounds")?;
        anyhow::ensure!(cfg.fleet.sync_rounds >= 1, "--sync-rounds must be >= 1");
        cfg.fleet.min_quorum = parsed.get_usize("min-quorum")?;
        anyhow::ensure!(
            cfg.fleet.min_quorum <= cfg.fleet.devices,
            "--min-quorum must be <= --devices (0 = all)"
        );
        if parsed.get("faults-seed").is_some() {
            cfg.fleet.faults_seed = Some(parsed.get_u64("faults-seed")?);
        }
        cfg.fleet.epsilon_per_round = parsed.get_f64("epsilon")?;
        anyhow::ensure!(
            cfg.fleet.epsilon_per_round.is_finite() && cfg.fleet.epsilon_per_round >= 0.0,
            "--epsilon must be finite and >= 0 (0 disables delta-level DP)"
        );
        let decay_keep = parsed.get_f64("decay-keep")?;
        anyhow::ensure!(
            decay_keep > 0.0 && decay_keep <= 1.0,
            "--decay-keep must be a fraction in (0, 1], got {decay_keep}"
        );
        cfg.fleet.decay_keep_permille = (decay_keep * 1000.0).round() as u16;
        cfg.optimizer.iters = parsed.get_usize("iters")?;
        cfg.optimizer.queries = parsed.get_usize("queries")?;
        cfg.optimizer.sigma = parsed.get_f64("sigma")?;
        cfg.optimizer.step = parsed.get_f64("step")?;
        cfg.optimizer.seed = parsed.get_u64("seed")?;
        // The artifacts dir only feeds the XLA backend, which embeds dense
        // Gaussian hyperplanes; structured families never use it, and
        // leaving it set would trip validate()'s family/artifacts check.
        cfg.artifacts_dir = if cfg.storm.hash_family == storm::config::HashFamily::Dense {
            Some(parsed.get_string("artifacts"))
        } else {
            None
        };
        let topology = match parsed.get_string("topology").as_str() {
            "star" => Topology::Star,
            "tree" => Topology::Tree { fanout: cfg.fleet.fan_in },
            "deep" => Topology::Deep { max_fan_in: cfg.fleet.fan_in },
            "chain" => Topology::Chain,
            other => anyhow::bail!("unknown topology {other:?}"),
        };
        let backend = match parsed.get_string("backend").as_str() {
            "rust" => QueryBackend::Rust,
            "xla" => QueryBackend::Xla,
            other => anyhow::bail!("unknown backend {other:?}"),
        };
        let ds = registry::load(&cfg.dataset, cfg.optimizer.seed)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {:?}", cfg.dataset))?;
        let report = train(&cfg, ds, topology, backend)?;
        println!("{}", report.summary());
        if let Some(acc) = report.accuracy {
            println!(
                "classification: training accuracy {:.1}% (margin power p = {})",
                acc * 100.0,
                cfg.storm.power,
            );
        }
        println!(
            "fleet: {} examples over {} devices in {:.2}s; train: {:.2}s ({} iters over {} rounds)",
            report.examples,
            cfg.fleet.devices,
            report.fleet_wall_secs,
            report.train_wall_secs,
            cfg.optimizer.iters,
            cfg.fleet.sync_rounds,
        );
        println!(
            "memory: leader sketch {} B ({}), per-device sketch {} B ({})",
            report.sketch_bytes,
            cfg.storm.counter_width,
            report.device_sketch_bytes,
            cfg.fleet.device_counter_width.unwrap_or(cfg.storm.counter_width),
        );
        if report.fault_events > 0 {
            println!(
                "chaos: {} fault events injected (seed {:?}); {} catch-up bytes recovered the stream",
                report.fault_events, cfg.fleet.faults_seed, report.retransmit_bytes,
            );
        }
        if report.epsilon_total > 0.0 {
            println!(
                "privacy: epsilon {} per round x {} rounds = {:.3} total (geometric noise on shipped deltas)",
                cfg.fleet.epsilon_per_round,
                report.rounds.len().max(1),
                report.epsilon_total,
            );
        }
        if cfg.fleet.sync_rounds > 1 {
            // The eps_spent column appears only under privacy so the
            // default table stays byte-stable for existing consumers.
            let eps_col = report.epsilon_total > 0.0;
            println!(
                "round  examples  net_bytes  resend_bytes  est_risk{}",
                if eps_col { "  eps_spent" } else { "" },
            );
            for r in &report.rounds {
                let eps =
                    if eps_col { format!("  {:>9.3}", r.epsilon_spent) } else { String::new() };
                println!(
                    "{:>5}  {:>8}  {:>9}  {:>12}  {:.5}{}",
                    r.round, r.examples, r.bytes, r.retransmit_bytes, r.risk, eps
                );
            }
        }
        if let Some(path) = parsed.get("checkpoint") {
            let state = storm::coordinator::state::TrainingState {
                dataset: report.dataset.clone(),
                iter: cfg.optimizer.iters,
                theta: report.theta.clone(),
                trace: report.trace.clone(),
                rounds: report.rounds.iter().map(|r| (r.round, r.risk, r.bytes)).collect(),
            };
            state.save(path)?;
            println!("checkpoint written to {path}");
        }
        Ok(0)
    };
    match run() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_experiment(args: &[String]) -> i32 {
    let parser = ArgParser::new("storm experiment", "regenerate a paper table/figure")
        .positional("id", "experiment id (see --list)")
        .opt("seed", Some("0"), "experiment seed")
        .opt("out-dir", None, "also write TSVs under this directory")
        .switch("full", "paper-grade effort (10 runs) instead of fast")
        .switch("list", "list experiment ids");
    let parsed = match parser.parse(args.iter().cloned()) {
        Ok(p) => p,
        Err(e) => return handle_help(&parser, e),
    };
    if parsed.get_bool("list") {
        for id in experiments::ALL {
            println!("{id}");
        }
        return 0;
    }
    let Some(id) = parsed.positionals().first() else {
        eprintln!("error: missing experiment id (try --list)");
        return 2;
    };
    let effort = if parsed.get_bool("full") { Effort::Full } else { Effort::Fast };
    let seed = parsed.get_u64("seed").unwrap_or(0);
    let Some(tables) = experiments::run(id, effort, seed) else {
        eprintln!("error: unknown experiment {id:?} (try --list)");
        return 2;
    };
    for (i, t) in tables.iter().enumerate() {
        t.print();
        if let Some(dir) = parsed.get("out-dir") {
            let path = format!("{dir}/{id}_{i}.tsv");
            if let Err(e) = t.write_file(&path) {
                eprintln!("warning: could not write {path}: {e}");
            } else {
                println!("# wrote {path}");
            }
        }
        println!();
    }
    0
}

fn cmd_sketch(args: &[String]) -> i32 {
    let parser = ArgParser::new("storm sketch", "build a sketch and print stats")
        .opt("dataset", Some("airfoil"), "registry dataset name")
        .opt("rows", Some("100"), "sketch rows R")
        .opt("power", Some("4"), "hyperplanes per row")
        .opt("counter-width", Some("u32"), "counter cell width: u8 | u16 | u32")
        .opt("hash-family", Some("dense"), "hyperplane family: dense | sparse | hadamard")
        .opt("sparse-density", None, "nonzero fraction in (0, 1] for --hash-family sparse (default 0.1)")
        .opt("seed", Some("0"), "hash family seed");
    let parsed = match parser.parse(args.iter().cloned()) {
        Ok(p) => p,
        Err(e) => return handle_help(&parser, e),
    };
    let run = || -> anyhow::Result<i32> {
        let name = parsed.get_string("dataset");
        let seed = parsed.get_u64("seed")?;
        let mut ds = registry::load(&name, seed)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}"))?;
        storm::data::scale::scale_to_unit_ball(&mut ds, storm::data::scale::DEFAULT_RADIUS);
        let density = match parsed.get("sparse-density") {
            Some(_) => Some(parsed.get_f64("sparse-density")?),
            None => None,
        };
        let cfg = StormConfig {
            rows: parsed.get_usize("rows")?,
            power: parsed.get_usize("power")? as u32,
            saturating: true,
            counter_width: parse_width(&parsed.get_string("counter-width"))?,
            hash_family: parse_hash_family(&parsed.get_string("hash-family"), density)?,
            ..Default::default()
        };
        let mut sk = storm::sketch::storm::StormSketch::new(cfg, ds.dim() + 1, seed);
        let (_, secs) = storm::util::timer::time_it(|| {
            for i in 0..ds.len() {
                sk.insert(&ds.augmented(i));
            }
        });
        println!(
            "dataset={name} n={} d={} | sketch R={} B={} @{} {} -> {} bytes ({}x compression) | insert {:.1} ex/s",
            ds.len(),
            ds.dim(),
            cfg.rows,
            cfg.buckets(),
            cfg.counter_width,
            cfg.hash_family,
            sk.bytes(),
            ds.raw_bytes() / sk.bytes().max(1),
            ds.len() as f64 / secs.max(1e-12),
        );
        println!(
            "wire bytes per delta flush: {} (dense ceiling at {}: {})",
            storm::sketch::serialize::wire_bytes(&cfg),
            cfg.counter_width,
            storm::sketch::serialize::delta_wire_bytes(&cfg),
        );
        Ok(0)
    };
    match run() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    println!("storm {}", storm::VERSION);
    println!("\ndatasets:");
    for info in registry::REGISTRY {
        println!(
            "  {:<12} n={:<6} d={:<3} substitute={} {}",
            info.name, info.n, info.d, info.synthetic_substitute, info.description
        );
    }
    match storm::runtime::Manifest::load("artifacts") {
        Ok(m) => {
            println!("\nartifacts ({}):", m.len());
            for a in m.iter() {
                println!(
                    "  {:<26} kind={:?} dim={} rows={} power={} batch={} queries={}",
                    a.name, a.kind, a.dim, a.rows, a.power, a.batch, a.queries
                );
            }
        }
        Err(_) => println!("\nartifacts: none (run `make artifacts`)"),
    }
    0
}
