//! PJRT runtime: load AOT-compiled XLA artifacts (HLO text emitted by
//! `python/compile/aot.py`) and execute them from the rust hot path.
//!
//! Python runs once, at build time (`make artifacts`); this module is the
//! only thing that touches the resulting `artifacts/` directory. The
//! interchange format is HLO **text** — the image's xla_extension 0.5.1
//! rejects jax >= 0.5 serialized protos (64-bit instruction ids), while
//! the text parser reassigns ids cleanly.
//!
//! Two entry points per compiled configuration:
//!
//! * `insert` — batch of augmented examples -> `[R, 2^p]` count histogram
//!   (the Pallas PRP kernel: projection on the MXU, one-hot histogram);
//! * `query`  — counts + K query vectors -> K surrogate-risk estimates.
//!
//! The *hyperplanes are runtime inputs*, not baked constants: the rust
//! sketch and the XLA path share the exact same hash family, so their
//! counters agree bit-for-bit (verified by `rust/tests/integration_runtime`).

pub mod manifest;
pub mod executor;

pub use executor::XlaStorm;
pub use manifest::{ArtifactInfo, ArtifactKind, Manifest};
