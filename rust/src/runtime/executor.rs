//! The XLA executor: compiles manifest artifacts on the PJRT CPU client
//! and exposes typed insert/query calls to the coordinator.
//!
//! PJRT client state is not `Sync`; the coordinator therefore drives the
//! executor from a single thread (the leader), while device threads use
//! the pure-rust insert path. This matches the deployment model — the
//! accelerator lives with the leader, the edge devices are scalar CPUs.

use super::manifest::{ArtifactInfo, ArtifactKind, Manifest};
use crate::lsh::prp::PairedRandomProjection;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// A loaded STORM executor pair (insert + query) for one configuration.
pub struct XlaStorm {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    insert_exe: xla::PjRtLoadedExecutable,
    query_exe: xla::PjRtLoadedExecutable,
    insert_info: ArtifactInfo,
    query_info: ArtifactInfo,
    /// Flattened hyperplanes `[R, P, D+2]` as an XLA literal, shared by
    /// both entry points (kept resident across calls).
    planes: xla::Literal,
    calls: std::cell::Cell<u64>,
}

impl XlaStorm {
    /// Load the artifact pair matching `(dim, rows, power)` from `dir`.
    pub fn load(dir: impl AsRef<Path>, dim: usize, rows: usize, power: u32, hashes: &[PairedRandomProjection]) -> Result<XlaStorm> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let (insert_info, query_info) = manifest
            .find_pair(dim, rows, power)
            .ok_or_else(|| anyhow!("no artifact pair for dim={dim} rows={rows} power={power} in {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let insert_exe = compile(&client, &insert_info.file)?;
        let query_exe = compile(&client, &query_info.file)?;
        let planes = planes_literal(hashes, dim, power)?;
        Ok(XlaStorm {
            client,
            insert_exe,
            query_exe,
            insert_info: insert_info.clone(),
            query_info: query_info.clone(),
            planes,
            calls: std::cell::Cell::new(0),
        })
    }

    /// Static batch size of the insert entry point.
    pub fn batch_size(&self) -> usize {
        self.insert_info.batch
    }

    /// Static query-vector count of the query entry point.
    pub fn query_size(&self) -> usize {
        self.query_info.queries
    }

    /// Number of executions so far (telemetry).
    pub fn calls(&self) -> u64 {
        self.calls.get()
    }

    /// Run the insert kernel on up to `batch_size` augmented examples
    /// (row-major `examples[i]` of length D). Returns the `[R, 2^p]` count
    /// delta. Short batches are padded and masked out.
    pub fn insert_counts(&self, examples: &[Vec<f64>]) -> Result<Vec<u32>> {
        let b = self.insert_info.batch;
        let d = self.insert_info.dim;
        if examples.len() > b {
            bail!("batch {} exceeds compiled size {b}", examples.len());
        }
        let mut z = vec![0f32; b * d];
        let mut mask = vec![0f32; b];
        for (i, ex) in examples.iter().enumerate() {
            if ex.len() != d {
                bail!("example dim {} != compiled dim {d}", ex.len());
            }
            for (j, &v) in ex.iter().enumerate() {
                z[i * d + j] = v as f32;
            }
            mask[i] = 1.0;
        }
        let z_lit = xla::Literal::vec1(&z)
            .reshape(&[b as i64, d as i64])
            .map_err(|e| anyhow!("reshape z: {e:?}"))?;
        let mask_lit = xla::Literal::vec1(&mask);
        let out = self
            .insert_exe
            .execute::<xla::Literal>(&[z_lit, mask_lit, self.planes.clone()])
            .map_err(|e| anyhow!("insert execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("insert fetch: {e:?}"))?;
        self.calls.set(self.calls.get() + 1);
        let flat = out
            .to_tuple1()
            .map_err(|e| anyhow!("insert untuple: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("insert to_vec: {e:?}"))?;
        Ok(flat.iter().map(|&v| v.round().max(0.0) as u32).collect())
    }

    /// Run the query kernel: estimate the normalized count at each of up
    /// to `query_size` query vectors against the given counters. Returns
    /// the paper-normalized surrogate risks (count / (R * n * SCALE)).
    pub fn query_risks(&self, counts: &[u32], n: u64, queries: &[Vec<f64>]) -> Result<Vec<f64>> {
        let k = self.query_info.queries;
        let d = self.query_info.dim;
        let r = self.query_info.rows;
        let buckets = self.query_info.buckets();
        if counts.len() != r * buckets {
            bail!("counts len {} != R*B = {}", counts.len(), r * buckets);
        }
        if queries.len() > k {
            bail!("query count {} exceeds compiled size {k}", queries.len());
        }
        let counts_f: Vec<f32> = counts.iter().map(|&c| c as f32).collect();
        let counts_lit = xla::Literal::vec1(&counts_f)
            .reshape(&[r as i64, buckets as i64])
            .map_err(|e| anyhow!("reshape counts: {e:?}"))?;
        let mut q = vec![0f32; k * d];
        for (i, qu) in queries.iter().enumerate() {
            if qu.len() != d {
                bail!("query dim {} != compiled dim {d}", qu.len());
            }
            for (j, &v) in qu.iter().enumerate() {
                q[i * d + j] = v as f32;
            }
        }
        let q_lit = xla::Literal::vec1(&q)
            .reshape(&[k as i64, d as i64])
            .map_err(|e| anyhow!("reshape queries: {e:?}"))?;
        let n_lit = xla::Literal::vec1(&[n as f32]);
        let out = self
            .query_exe
            .execute::<xla::Literal>(&[counts_lit, q_lit, self.planes.clone(), n_lit])
            .map_err(|e| anyhow!("query execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("query fetch: {e:?}"))?;
        self.calls.set(self.calls.get() + 1);
        let flat = out
            .to_tuple1()
            .map_err(|e| anyhow!("query untuple: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("query to_vec: {e:?}"))?;
        Ok(flat[..queries.len()].iter().map(|&v| v as f64).collect())
    }
}

/// Compile one HLO-text artifact.
fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
}

/// Pack the shared hash family into a `[R, P, D+2]` f32 literal. The
/// augmented-space planes come straight from the rust sketch so both
/// paths hash identically.
fn planes_literal(hashes: &[PairedRandomProjection], dim: usize, power: u32) -> Result<xla::Literal> {
    let r = hashes.len();
    let p = power as usize;
    let aug = dim + 2;
    let mut flat = Vec::with_capacity(r * p * aug);
    for h in hashes {
        let planes = h.asym().srp().planes();
        if planes.len() != p {
            bail!("hash has {} planes, expected {p}", planes.len());
        }
        for plane in planes {
            if plane.len() != aug {
                bail!("plane has dim {}, expected {aug}", plane.len());
            }
            flat.extend(plane.iter().map(|&v| v as f32));
        }
    }
    xla::Literal::vec1(&flat)
        .reshape(&[r as i64, p as i64, aug as i64])
        .map_err(|e| anyhow!("reshape planes: {e:?}"))
}
