//! The artifact manifest: `artifacts/manifest.toml`, written by
//! `python/compile/aot.py` and read here. It records, per compiled
//! executable, the entry-point kind and every static shape the rust side
//! must respect when building input literals.

use crate::config::toml::TomlDoc;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// What an artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Batch insert: `(z [B,D], mask [B], planes [R,P,D+2]) -> [R, 2^p]`.
    Insert,
    /// Risk query: `(counts [R,B'], queries [K,D], planes, n) -> [K]`.
    Query,
}

/// One artifact's metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    /// Augmented example dimension D = d + 1.
    pub dim: usize,
    /// Sketch rows R.
    pub rows: usize,
    /// Hyperplanes per row p (buckets = 2^p).
    pub power: u32,
    /// Static batch size (insert) — callers pad + mask.
    pub batch: usize,
    /// Static query count (query) — callers pad.
    pub queries: usize,
}

impl ArtifactInfo {
    pub fn buckets(&self) -> usize {
        1usize << self.power
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    artifacts: BTreeMap<String, ArtifactInfo>,
}

/// Manifest errors.
#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("parse error: {0}")]
    Parse(String),
    #[error("artifact {0}: missing key {1}")]
    MissingKey(String, &'static str),
    #[error("artifact {0}: bad kind {1:?}")]
    BadKind(String, String),
}

impl Manifest {
    /// Load `<dir>/manifest.toml`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ManifestError> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.toml"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` anchors relative artifact files.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, ManifestError> {
        let doc = TomlDoc::parse(text).map_err(ManifestError::Parse)?;
        // Group keys by section "artifact.<name>".
        let mut sections: BTreeMap<String, BTreeMap<String, crate::config::toml::TomlValue>> =
            BTreeMap::new();
        for (section, key, value) in doc.entries() {
            if let Some(name) = section.strip_prefix("artifact.") {
                sections
                    .entry(name.to_string())
                    .or_default()
                    .insert(key.clone(), value.clone());
            }
        }
        let mut artifacts = BTreeMap::new();
        for (name, keys) in sections {
            let get_str = |k: &'static str| -> Result<String, ManifestError> {
                keys.get(k)
                    .map(|v| v.as_str().to_string())
                    .filter(|s| !s.is_empty())
                    .ok_or(ManifestError::MissingKey(name.clone(), k))
            };
            let get_usize = |k: &'static str| -> Result<usize, ManifestError> {
                keys.get(k)
                    .ok_or(ManifestError::MissingKey(name.clone(), k))?
                    .as_usize()
                    .map_err(ManifestError::Parse)
            };
            let kind = match get_str("kind")?.as_str() {
                "insert" => ArtifactKind::Insert,
                "query" => ArtifactKind::Query,
                other => return Err(ManifestError::BadKind(name.clone(), other.to_string())),
            };
            let info = ArtifactInfo {
                name: name.clone(),
                file: dir.join(get_str("file")?),
                kind,
                dim: get_usize("dim")?,
                rows: get_usize("rows")?,
                power: get_usize("power")? as u32,
                batch: get_usize("batch").unwrap_or(0),
                queries: get_usize("queries").unwrap_or(0),
            };
            artifacts.insert(name, info);
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.get(name)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactInfo> {
        self.artifacts.values()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// Find the insert/query pair compiled for a given (dim, rows, power)
    /// configuration.
    pub fn find_pair(&self, dim: usize, rows: usize, power: u32) -> Option<(&ArtifactInfo, &ArtifactInfo)> {
        let insert = self.artifacts.values().find(|a| {
            a.kind == ArtifactKind::Insert && a.dim == dim && a.rows == rows && a.power == power
        })?;
        let query = self.artifacts.values().find(|a| {
            a.kind == ArtifactKind::Query && a.dim == dim && a.rows == rows && a.power == power
        })?;
        Some((insert, query))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[artifact.prp_insert_airfoil]
file = "prp_insert_airfoil.hlo.txt"
kind = "insert"
dim = 10
rows = 50
power = 4
batch = 256

[artifact.storm_query_airfoil]
file = "storm_query_airfoil.hlo.txt"
kind = "query"
dim = 10
rows = 50
power = 4
queries = 16
"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.len(), 2);
        let ins = m.get("prp_insert_airfoil").unwrap();
        assert_eq!(ins.kind, ArtifactKind::Insert);
        assert_eq!(ins.dim, 10);
        assert_eq!(ins.batch, 256);
        assert_eq!(ins.buckets(), 16);
        assert_eq!(ins.file, Path::new("/tmp/a/prp_insert_airfoil.hlo.txt"));
    }

    #[test]
    fn find_pair_matches_config() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        let (i, q) = m.find_pair(10, 50, 4).unwrap();
        assert_eq!(i.kind, ArtifactKind::Insert);
        assert_eq!(q.kind, ArtifactKind::Query);
        assert!(m.find_pair(11, 50, 4).is_none());
    }

    #[test]
    fn missing_key_rejected() {
        let bad = "[artifact.x]\nfile = \"x.hlo\"\nkind = \"insert\"\n";
        assert!(matches!(
            Manifest::parse(bad, Path::new(".")),
            Err(ManifestError::MissingKey(..))
        ));
    }

    #[test]
    fn bad_kind_rejected() {
        let bad = "[artifact.x]\nfile = \"x\"\nkind = \"wat\"\ndim = 1\nrows = 1\npower = 1\n";
        assert!(matches!(
            Manifest::parse(bad, Path::new(".")),
            Err(ManifestError::BadKind(..))
        ));
    }

    #[test]
    fn empty_manifest_ok() {
        let m = Manifest::parse("", Path::new(".")).unwrap();
        assert!(m.is_empty());
    }
}
