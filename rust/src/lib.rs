//! # STORM — Sketches Toward Online Risk Minimization
//!
//! A production-grade reproduction of *"STORM: Foundations of End-to-End
//! Empirical Risk Minimization on the Edge"* (Coleman, Gupta, Chen,
//! Shrivastava, 2020).
//!
//! STORM compresses a data stream into a tiny array of integer counters
//! indexed by locality-sensitive hash (LSH) functions. Querying the sketch
//! at a parameter vector returns an unbiased estimate of a *surrogate
//! empirical risk* whose minimizer coincides with the least-squares (or
//! max-margin) minimizer — so regression and classification models can be
//! trained directly from the sketch, on the edge, without retaining the
//! data.
//!
//! ## Architecture
//!
//! This crate is layer 3 of a three-layer stack:
//!
//! * **L3 (this crate)** — the coordinator: edge-device fleet simulation,
//!   sketch merging over network topologies, backpressure, the
//!   derivative-free optimization (DFO) outer loop, metrics and CLI.
//! * **L2 (python/compile/model.py)** — JAX compute graphs for bulk sketch
//!   insertion, query, and fused DFO steps, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — the Pallas hot-spot kernel:
//!   batched paired-random-projection hashing + one-hot histogram
//!   accumulation.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT and executes
//! them from the hot path; Python never runs at request time.
//!
//! ## Lint posture
//!
//! `unsafe` is denied crate-wide; the one audited exception is
//! [`lsh::simd`], which scopes its own `#![allow(unsafe_code)]` and
//! denies `unsafe_op_in_unsafe_fn`. The repo-specific invariants the
//! compiler can't see (seeded determinism, panic-free wire decoding,
//! scalar-ordered float reductions) are enforced by `tools/stormlint`
//! — `cargo run -p stormlint`.

#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::dbg_macro, clippy::todo, clippy::unimplemented, clippy::mem_forget)]

pub mod util;
pub mod testing;
pub mod config;
pub mod linalg;
pub mod data;
pub mod lsh;
pub mod sketch;
pub mod loss;
pub mod optim;
pub mod baselines;
pub mod metrics;
pub mod edge;
pub mod coordinator;
pub mod runtime;
pub mod experiments;

/// Convenient re-exports of the types most users need.
pub mod prelude {
    pub use crate::config::{StormConfig, Task};
    pub use crate::data::dataset::Dataset;
    pub use crate::linalg::matrix::Matrix;
    pub use crate::lsh::srp::SignedRandomProjection;
    pub use crate::optim::dfo::{DfoConfig, DfoOptimizer};
    pub use crate::sketch::model::StormModel;
    pub use crate::sketch::storm::{StormClassifierSketch, StormSketch};
    pub use crate::sketch::RiskSketch;
    pub use crate::util::rng::{Rng, Xoshiro256};
}

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
