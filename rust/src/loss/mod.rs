//! Surrogate and reference loss functions.
//!
//! * [`prp_loss`] — the paper's PRP regression surrogate `g` (Theorem 2):
//!   closed form, gradient, curvature factor, plus the exact dataset-level
//!   surrogate risk used to validate the sketch estimator;
//! * [`margin`] — the classification-calibrated margin loss (Theorem 3);
//! * [`reference`] — classical losses (L2, hinge, logistic, squared hinge)
//!   for the Figure-6 comparison and exact-ERM baselines.

pub mod prp_loss;
pub mod margin;
pub mod reference;
