//! The PRP surrogate loss for linear regression (paper §4.1, Theorem 2):
//!
//! ```text
//! g_p(t) = 1/2 (1 - acos(t)/pi)^p + 1/2 (1 - acos(-t)/pi)^p,
//! t = <[theta, -1], [x, y]>
//! ```
//!
//! Properties proved in the paper and verified by the tests here:
//! symmetric in `t`, convex for p >= 2, minimized exactly at `t = 0` (the
//! least-squares surface), with local curvature maximized near p = 4
//! (Figure 3).

use crate::util::mathx::{dot, srp_collision, srp_collision_deriv};

/// Single-sided collision term `f(t) = (1 - acos(t)/pi)^p`.
#[inline]
pub fn collision_power(t: f64, p: u32) -> f64 {
    srp_collision(t).powi(p as i32)
}

/// The PRP surrogate loss `g_p(t)`.
#[inline]
pub fn prp_surrogate(t: f64, p: u32) -> f64 {
    0.5 * collision_power(t, p) + 0.5 * collision_power(-t, p)
}

/// d/dt of the surrogate: `p/2 (f(t)^{p-1} - f(-t)^{p-1}) f'(t)` with
/// `f'(t) = 1/(pi sqrt(1-t^2))` shared by both terms (paper, proof of
/// Thm 2).
#[inline]
pub fn prp_surrogate_deriv(t: f64, p: u32) -> f64 {
    let fp = srp_collision(t);
    let fm = srp_collision(-t);
    0.5 * p as f64
        * (fp.powi(p as i32 - 1) - fm.powi(p as i32 - 1))
        * srp_collision_deriv(t)
}

/// Loss "sharpness" at offset `t` — the paper's Figure 3(b) quantity:
/// the slope magnitude of the surrogate at `<theta, y[x,-1]> = t`.
#[inline]
pub fn prp_slope_at(t: f64, p: u32) -> f64 {
    prp_surrogate_deriv(t, p).abs()
}

/// Exact surrogate empirical risk over a dataset:
/// `mean_i g_p(<theta~, z_i>)`. This is the quantity the STORM sketch
/// estimates; the tests cross-check the two.
pub fn exact_surrogate_risk(theta_tilde: &[f64], examples: &[Vec<f64>], p: u32) -> f64 {
    assert!(!examples.is_empty());
    examples
        .iter()
        .map(|z| prp_surrogate(dot(theta_tilde, z), p))
        .sum::<f64>()
        / examples.len() as f64
}

/// Gradient of the exact surrogate risk w.r.t. `theta~` (used by the
/// exact-gradient baseline; the gradient w.r.t. the *last* coordinate is
/// discarded by the optimizer's projection step):
/// `mean_i g'(t_i) z_i`.
pub fn exact_surrogate_grad(theta_tilde: &[f64], examples: &[Vec<f64>], p: u32) -> Vec<f64> {
    let mut grad = vec![0.0; theta_tilde.len()];
    for z in examples {
        let t = dot(theta_tilde, z);
        let gp = prp_surrogate_deriv(t, p);
        for (gi, zi) in grad.iter_mut().zip(z) {
            *gi += gp * zi;
        }
    }
    for gi in &mut grad {
        *gi /= examples.len() as f64;
    }
    grad
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, cases};
    use crate::util::rng::Rng;

    #[test]
    fn symmetric_in_t() {
        for p in [1, 2, 4, 8, 16] {
            for i in 0..20 {
                let t = i as f64 * 0.05;
                assert_close(prp_surrogate(t, p), prp_surrogate(-t, p), 1e-12);
            }
        }
    }

    #[test]
    fn minimized_at_zero_for_p_ge_2() {
        for p in [2, 3, 4, 8, 16] {
            let g0 = prp_surrogate(0.0, p);
            for i in 1..20 {
                let t = i as f64 * 0.05;
                assert!(
                    prp_surrogate(t, p) > g0,
                    "p={p} t={t}: {} !> {}",
                    prp_surrogate(t, p),
                    g0
                );
            }
        }
    }

    #[test]
    fn p_equals_1_is_flat() {
        // Theorem 2: gradient is identically zero when p = 1
        // (f(t) + f(-t) = 1 for the single-bit SRP).
        for i in 0..20 {
            let t = -0.95 + i as f64 * 0.1;
            assert_close(prp_surrogate(t, 1), 0.5, 1e-12);
            assert_close(prp_surrogate_deriv(t, 1), 0.0, 1e-12);
        }
    }

    #[test]
    fn convex_for_p_ge_2() {
        // Discrete second difference >= 0 across the domain.
        for p in [2, 4, 8] {
            let h = 0.01;
            let mut t = -0.97;
            while t <= 0.97 {
                let second =
                    prp_surrogate(t - h, p) - 2.0 * prp_surrogate(t, p) + prp_surrogate(t + h, p);
                assert!(second >= -1e-10, "p={p} t={t} second={second}");
                t += 0.02;
            }
        }
    }

    #[test]
    fn deriv_matches_finite_difference() {
        cases(100, 5, |rng, _| {
            let p = 2 + (rng.next_u64() % 14) as u32;
            let t = rng.uniform_range(-0.9, 0.9);
            let h = 1e-6;
            let fd = (prp_surrogate(t + h, p) - prp_surrogate(t - h, p)) / (2.0 * h);
            assert_close(prp_surrogate_deriv(t, p), fd, 1e-4);
        });
    }

    #[test]
    fn deriv_gradcheck_across_powers_and_domain_boundary() {
        // Central-difference check on a fixed grid across p in
        // {2, 3, 4, 6}, including points near the ±1 domain boundary
        // where f'(t) = 1/(pi sqrt(1 - t^2)) grows fast — relative
        // tolerance, and a step small enough to stay inside [-1, 1].
        let grid = [-0.999, -0.99, -0.9, -0.5, -0.1, 0.0, 0.1, 0.5, 0.9, 0.99, 0.999];
        for p in [2u32, 3, 4, 6] {
            for &t in &grid {
                let h = 1e-7;
                let fd = (prp_surrogate(t + h, p) - prp_surrogate(t - h, p)) / (2.0 * h);
                let an = prp_surrogate_deriv(t, p);
                assert!(an.is_finite(), "p={p} t={t}: non-finite derivative {an}");
                let tol = 1e-5 * (1.0 + an.abs());
                assert!(
                    (an - fd).abs() <= tol,
                    "p={p} t={t}: analytic {an} vs central-difference {fd} (tol {tol})"
                );
            }
        }
    }

    #[test]
    fn p4_has_steepest_slope_near_optimum() {
        // Figure 3(b): at t = 0.1 the slope peaks at p = 4 among powers of 2.
        let slopes: Vec<(u32, f64)> = [1u32, 2, 4, 8, 16]
            .iter()
            .map(|&p| (p, prp_slope_at(0.1, p)))
            .collect();
        let best = slopes
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert_eq!(best.0, 4, "slopes: {slopes:?}");
    }

    #[test]
    fn exact_risk_and_grad_consistent() {
        cases(30, 6, |rng, _| {
            let d = crate::testing::gen_dim(rng, 2, 6);
            let examples: Vec<Vec<f64>> = (0..20)
                .map(|_| crate::testing::gen_ball_point(rng, d, 0.9))
                .collect();
            let theta = crate::testing::gen_ball_point(rng, d, 0.5);
            let g = exact_surrogate_grad(&theta, &examples, 4);
            // Directional finite difference.
            let dir = rng.sphere_vec(d, 1.0);
            let h = 1e-6;
            let tp: Vec<f64> = theta.iter().zip(&dir).map(|(a, b)| a + h * b).collect();
            let tm: Vec<f64> = theta.iter().zip(&dir).map(|(a, b)| a - h * b).collect();
            let fd = (exact_surrogate_risk(&tp, &examples, 4)
                - exact_surrogate_risk(&tm, &examples, 4))
                / (2.0 * h);
            assert_close(dot(&g, &dir), fd, 1e-4);
        });
    }

    #[test]
    fn surrogate_bounded_in_unit_interval() {
        for p in [1, 2, 4, 8] {
            for i in 0..=40 {
                let t = -1.0 + i as f64 * 0.05;
                let g = prp_surrogate(t, p);
                assert!((0.0..=1.0).contains(&g), "p={p} t={t} g={g}");
            }
        }
    }
}
