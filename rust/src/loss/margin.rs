//! The STORM classification margin loss (paper §4.2, Theorem 3):
//!
//! ```text
//! phi_p(t) = 2^p (1 - acos(-t)/pi)^p,   t = y <theta, x>  in [-1, 1]
//! ```
//!
//! Classification-calibrated: convex for p >= 2 with `phi'(0) = -1/pi *
//! 2^p * p * (1/2)^{p-1} < 0` — misclassified points (t < 0) are penalized
//! more than correctly classified ones.

use crate::util::mathx::{srp_collision, srp_collision_deriv};

/// The margin loss `phi_p(t)` with the paper's `2^p` normalization.
#[inline]
pub fn margin_loss(t: f64, p: u32) -> f64 {
    (1u64 << p) as f64 * srp_collision(-t).powi(p as i32)
}

/// d/dt of the margin loss.
#[inline]
pub fn margin_loss_deriv(t: f64, p: u32) -> f64 {
    // d/dt f(-t)^p = -p f(-t)^{p-1} f'(-t)
    -((1u64 << p) as f64)
        * p as f64
        * srp_collision(-t).powi(p as i32 - 1)
        * srp_collision_deriv(-t)
}

/// Exact margin empirical risk `mean_i phi_p(y_i <theta, x_i>)`.
pub fn exact_margin_risk(theta: &[f64], xs: &[Vec<f64>], ys: &[f64], p: u32) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    xs.iter()
        .zip(ys)
        .map(|(x, y)| margin_loss(y * crate::util::mathx::dot(theta, x), p))
        .sum::<f64>()
        / xs.len() as f64
}

/// 0-1 classification accuracy of a hyperplane model.
pub fn accuracy(theta: &[f64], xs: &[Vec<f64>], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return 0.0;
    }
    let correct = xs
        .iter()
        .zip(ys)
        .filter(|(x, y)| crate::util::mathx::dot(theta, x) * **y > 0.0)
        .count();
    correct as f64 / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, cases};
    use crate::util::rng::Rng;

    #[test]
    fn calibrated_negative_slope_at_origin() {
        // Necessary & sufficient condition for classification calibration
        // of a convex margin loss: phi'(0) < 0.
        for p in [1, 2, 4, 8] {
            assert!(margin_loss_deriv(0.0, p) < 0.0, "p={p}");
        }
        // Paper's appendix computes the p-scaled value at the origin; for
        // phi(t) = 2^p f(-t)^p it is -2^p p (1/2)^{p-1} / pi.
        let p = 4u32;
        let want = -(16.0) * 4.0 * 0.125 / std::f64::consts::PI;
        assert_close(margin_loss_deriv(0.0, p), want, 1e-9);
    }

    #[test]
    fn monotone_decreasing_in_margin() {
        for p in [1, 2, 4] {
            let mut prev = f64::INFINITY;
            for i in 0..=20 {
                let t = -1.0 + 0.1 * i as f64;
                let v = margin_loss(t, p);
                assert!(v <= prev + 1e-12, "p={p} t={t}");
                prev = v;
            }
        }
    }

    #[test]
    fn convex_for_p_ge_2() {
        for p in [2, 4, 8] {
            let h = 0.01;
            let mut t = -0.97;
            while t <= 0.97 {
                let second = margin_loss(t - h, p) - 2.0 * margin_loss(t, p) + margin_loss(t + h, p);
                assert!(second >= -1e-8, "p={p} t={t} second={second}");
                t += 0.02;
            }
        }
    }

    #[test]
    fn endpoint_values() {
        // t = -1 (worst): f(1)^p = 1 -> 2^p. t = 1 (best): f(-1)^p = 0.
        for p in [1, 2, 4] {
            assert_close(margin_loss(-1.0, p), (1u64 << p) as f64, 1e-9);
            assert_close(margin_loss(1.0, p), 0.0, 1e-9);
        }
    }

    #[test]
    fn deriv_matches_finite_difference() {
        cases(50, 3, |rng, _| {
            let p = 2 + (rng.next_u64() % 6) as u32;
            let t = rng.uniform_range(-0.9, 0.9);
            let h = 1e-6;
            let fd = (margin_loss(t + h, p) - margin_loss(t - h, p)) / (2.0 * h);
            assert_close(margin_loss_deriv(t, p), fd, 1e-3);
        });
    }

    #[test]
    fn deriv_gradcheck_across_powers_and_domain_boundary() {
        // Central-difference check on a fixed grid across p in
        // {2, 3, 4, 6}, including the ±1 boundary region where
        // f'(-t) = 1/(pi sqrt(1 - t^2)) grows fast. The 2^p scaling
        // makes absolute errors large at p = 6, so tolerance is
        // relative to the analytic value.
        let grid = [-0.999, -0.99, -0.9, -0.5, -0.1, 0.0, 0.1, 0.5, 0.9, 0.99, 0.999];
        for p in [2u32, 3, 4, 6] {
            for &t in &grid {
                let h = 1e-7;
                let fd = (margin_loss(t + h, p) - margin_loss(t - h, p)) / (2.0 * h);
                let an = margin_loss_deriv(t, p);
                assert!(an.is_finite(), "p={p} t={t}: non-finite derivative {an}");
                assert!(an <= 0.0, "p={p} t={t}: margin loss must be non-increasing, got {an}");
                let tol = 1e-5 * (1.0 + an.abs());
                assert!(
                    (an - fd).abs() <= tol,
                    "p={p} t={t}: analytic {an} vs central-difference {fd} (tol {tol})"
                );
            }
        }
    }

    #[test]
    fn accuracy_counts_correct_side() {
        let xs = vec![vec![1.0, 0.0], vec![-1.0, 0.0], vec![0.5, 0.0]];
        let ys = vec![1.0, -1.0, -1.0];
        assert_close(accuracy(&[1.0, 0.0], &xs, &ys), 2.0 / 3.0, 1e-12);
    }

    #[test]
    fn exact_risk_separable_data_prefers_separator() {
        // Risk of the true separator should be below a random direction.
        let xs = vec![
            vec![0.5, 0.1],
            vec![0.6, -0.1],
            vec![-0.5, 0.05],
            vec![-0.55, -0.03],
        ];
        let ys = vec![1.0, 1.0, -1.0, -1.0];
        let good = exact_margin_risk(&[0.9, 0.0], &xs, &ys, 2);
        let bad = exact_margin_risk(&[0.0, 0.9], &xs, &ys, 2);
        assert!(good < bad);
    }
}
