//! Classical reference losses, for the Figure-6 comparison and for the
//! exact-ERM baselines the paper measures STORM against.

/// Squared (L2) loss on the residual `r = h(x) - y`.
#[inline]
pub fn l2(r: f64) -> f64 {
    r * r
}

/// Hinge loss on the margin `t = y h(x)`.
#[inline]
pub fn hinge(t: f64) -> f64 {
    (1.0 - t).max(0.0)
}

/// Squared hinge loss.
#[inline]
pub fn squared_hinge(t: f64) -> f64 {
    hinge(t).powi(2)
}

/// Logistic loss `log(1 + e^{-t})`, numerically stabilized.
#[inline]
pub fn logistic(t: f64) -> f64 {
    if t > 0.0 {
        (-t).exp().ln_1p()
    } else {
        -t + t.exp().ln_1p()
    }
}

/// Zero-one loss on the margin.
#[inline]
pub fn zero_one(t: f64) -> f64 {
    if t > 0.0 {
        0.0
    } else {
        1.0
    }
}

/// Mean L2 empirical risk of a linear model over augmented examples
/// `z = [x, y]`: `mean_i <theta~, z_i>^2` with `theta~ = [theta, -1]`.
pub fn exact_l2_risk(theta_tilde: &[f64], examples: &[Vec<f64>]) -> f64 {
    assert!(!examples.is_empty());
    examples
        .iter()
        .map(|z| l2(crate::util::mathx::dot(theta_tilde, z)))
        .sum::<f64>()
        / examples.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn l2_parabola() {
        assert_eq!(l2(0.0), 0.0);
        assert_eq!(l2(2.0), 4.0);
        assert_eq!(l2(-2.0), 4.0);
    }

    #[test]
    fn hinge_piecewise() {
        assert_eq!(hinge(2.0), 0.0);
        assert_eq!(hinge(1.0), 0.0);
        assert_close(hinge(0.0), 1.0, 1e-12);
        assert_close(hinge(-1.0), 2.0, 1e-12);
        assert_close(squared_hinge(-1.0), 4.0, 1e-12);
    }

    #[test]
    fn logistic_stable_both_tails() {
        assert!(logistic(100.0) < 1e-10);
        assert_close(logistic(-100.0), 100.0, 1e-6);
        assert_close(logistic(0.0), std::f64::consts::LN_2, 1e-12);
    }

    #[test]
    fn zero_one_threshold() {
        assert_eq!(zero_one(0.5), 0.0);
        assert_eq!(zero_one(0.0), 1.0);
        assert_eq!(zero_one(-0.5), 1.0);
    }

    #[test]
    fn margin_losses_upper_bound_zero_one() {
        // Calibration sanity: hinge and logistic dominate 0-1 (scaled).
        for i in 0..40 {
            let t = -2.0 + 0.1 * i as f64;
            assert!(hinge(t) + 1e-12 >= zero_one(t));
            assert!(logistic(t) / std::f64::consts::LN_2 + 1e-12 >= zero_one(t));
        }
    }

    #[test]
    fn exact_l2_risk_matches_mse_formulation() {
        // <[theta,-1],[x,y]>^2 = (pred - y)^2.
        let examples = vec![vec![1.0, 2.0, 3.0], vec![0.5, -1.0, 0.0]];
        let theta_tilde = vec![1.0, 1.0, -1.0];
        let want = ((1.0 + 2.0 - 3.0f64).powi(2) + (0.5 - 1.0 - 0.0f64).powi(2)) / 2.0;
        assert_close(exact_l2_risk(&theta_tilde, &examples), want, 1e-12);
    }
}
