//! Scoped wall-clock timing helpers used by the coordinator's metrics and
//! the experiment harness.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Clone, Copy, Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e6
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Pretty-print a duration in adaptive units.
pub fn human_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, s) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn human_units() {
        assert!(human_duration(2.5e-9).ends_with("ns"));
        assert!(human_duration(2.5e-6).ends_with("us"));
        assert!(human_duration(2.5e-3).ends_with("ms"));
        assert!(human_duration(2.5).ends_with('s'));
    }
}
