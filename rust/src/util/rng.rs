//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set has no `rand` crate, so we implement the small
//! set of generators the paper's algorithms need: `splitmix64` for seeding,
//! `xoshiro256++` as the workhorse stream generator, Box–Muller gaussians
//! (used by signed random projections and DFO sphere sampling), and the
//! Laplace / exponential draws used by the differentially-private sketch.
//!
//! Everything is deterministic given a seed, which the experiment harness
//! relies on to average over *independently seeded* sketch constructions
//! exactly as the paper does (10 runs per configuration).

/// SplitMix64 step: the standard seeding PRNG (Steele et al.).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Minimal RNG interface used across the crate.
pub trait Rng {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift reduction.
    #[inline]
    fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (un-cached variant; two uniforms per
    /// draw keeps the trait object-safe and stateless).
    fn gaussian(&mut self) -> f64 {
        // Avoid u == 0 so ln is finite.
        let u = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u = if u <= 0.0 { f64::MIN_POSITIVE } else { u };
        let v = self.uniform();
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }

    /// N(mu, sigma^2) draw.
    #[inline]
    fn gaussian_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.gaussian()
    }

    /// Laplace(0, b) draw (used for epsilon-DP count noise).
    fn laplace(&mut self, b: f64) -> f64 {
        let u = self.uniform() - 0.5;
        // `uniform()` is `[0, 1)`, so `u = -0.5` is reachable and the raw
        // inverse CDF would take `ln(0) = -inf`; clamp like `exponential`.
        -b * u.signum() * (1.0 - 2.0 * u.abs()).max(f64::MIN_POSITIVE).ln()
    }

    /// Two-sided geometric (discrete Laplace) draw: `P(Z = k) ∝ alpha^|k|`
    /// for `alpha in [0, 1)`. The difference of two iid geometric variables
    /// has exactly this law, which keeps the noise in integers — the
    /// discrete analogue of [`Rng::laplace`] used for counter-level DP
    /// (`alpha = exp(-epsilon / sensitivity)`).
    fn two_sided_geometric(&mut self, alpha: f64) -> i64 {
        assert!((0.0..1.0).contains(&alpha), "alpha must be in [0, 1)");
        if alpha == 0.0 {
            return 0;
        }
        // G = floor(ln(U) / ln(alpha)) is Geometric(1 - alpha) counting
        // failures: P(G >= k) = alpha^k. Clamp U away from 0 as above.
        let ln_a = alpha.ln();
        let g1 = (self.uniform().max(f64::MIN_POSITIVE).ln() / ln_a).floor() as i64;
        let g2 = (self.uniform().max(f64::MIN_POSITIVE).ln() / ln_a).floor() as i64;
        g1 - g2
    }

    /// Exponential(rate) draw.
    fn exponential(&mut self, rate: f64) -> f64 {
        let u = self.uniform().max(f64::MIN_POSITIVE);
        -u.ln() / rate
    }

    /// Vector of iid standard gaussians.
    fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gaussian()).collect()
    }

    /// Uniform point on the sphere of radius `sigma` centered at the
    /// origin in `n` dimensions (gaussian direction, normalized).
    /// This is the sampling primitive of Algorithm 2 in the paper.
    fn sphere_vec(&mut self, n: usize, sigma: f64) -> Vec<f64> {
        loop {
            let g = self.gaussian_vec(n);
            let norm: f64 = g.iter().map(|v| v * v).sum::<f64>().sqrt();
            if norm > 1e-12 {
                return g.into_iter().map(|v| v * sigma / norm).collect();
            }
        }
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed from a single u64 via splitmix64 (the recommended procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Xoshiro256 { s }
    }

    /// Derive an independent child stream (used to give each edge device,
    /// LSH row, and experiment repetition its own generator).
    pub fn fork(&mut self, tag: u64) -> Xoshiro256 {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Xoshiro256 { s }
    }
}

impl Rng for Xoshiro256 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Xoshiro256::new(7);
        let mut b = Xoshiro256::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Xoshiro256::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Xoshiro256::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn laplace_moments() {
        let mut r = Xoshiro256::new(6);
        let b = 2.0;
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.laplace(b)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        // Var(Laplace(b)) = 2 b^2 = 8
        assert!((var - 8.0).abs() < 0.4, "var={var}");
    }

    /// Replays a fixed word stream — lets the tests force the exact
    /// `uniform() == 0` draw that used to send `laplace` to infinity.
    struct ReplayRng {
        words: Vec<u64>,
        at: usize,
    }

    impl Rng for ReplayRng {
        fn next_u64(&mut self) -> u64 {
            let w = self.words[self.at % self.words.len()];
            self.at += 1;
            w
        }
    }

    #[test]
    fn laplace_is_finite_even_at_the_uniform_edges() {
        // next_u64 = 0 gives uniform() = 0, i.e. u = -0.5 — the draw that
        // used to produce -inf; u64::MAX probes the other edge.
        for words in [vec![0u64], vec![u64::MAX], vec![0, u64::MAX]] {
            let mut r = ReplayRng { words, at: 0 };
            for _ in 0..8 {
                for b in [1e-3, 1.0, 1e6] {
                    let x = r.laplace(b);
                    assert!(x.is_finite(), "laplace({b}) = {x}");
                }
            }
        }
    }

    #[test]
    fn laplace_stream_has_no_non_finite_draws() {
        let mut r = Xoshiro256::new(13);
        for _ in 0..200_000 {
            assert!(r.laplace(3.0).is_finite());
        }
    }

    #[test]
    fn two_sided_geometric_moments() {
        let mut r = Xoshiro256::new(14);
        let alpha: f64 = 0.6;
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.two_sided_geometric(alpha) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        // Var = 2 alpha / (1 - alpha)^2 = 7.5 at alpha = 0.6.
        let want = 2.0 * alpha / ((1.0 - alpha) * (1.0 - alpha));
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - want).abs() < 0.4, "var={var} want={want}");
    }

    #[test]
    fn two_sided_geometric_edges() {
        let mut r = Xoshiro256::new(15);
        for _ in 0..100 {
            assert_eq!(r.two_sided_geometric(0.0), 0, "alpha = 0 is the no-noise spelling");
        }
        // The forced uniform() = 0 edge stays finite (i64, no panic).
        let mut edge = ReplayRng { words: vec![0u64], at: 0 };
        let z = edge.two_sided_geometric(0.9);
        assert!(z.abs() < 1 << 40, "clamped edge draw stays bounded: {z}");
    }

    #[test]
    fn sphere_vec_has_requested_radius() {
        let mut r = Xoshiro256::new(8);
        for _ in 0..100 {
            let v = r.sphere_vec(12, 0.5);
            let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((norm - 0.5).abs() < 1e-9);
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(10);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::new(11);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut root = Xoshiro256::new(12);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
