//! Foundational utilities built from scratch for the offline environment:
//! PRNGs, math helpers, CLI argument parsing, logging, timing and the
//! micro-benchmark framework used by `rust/benches/`.

pub mod rng;
pub mod mathx;
pub mod argparse;
pub mod logging;
pub mod timer;
pub mod bench;
