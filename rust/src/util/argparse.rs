//! Minimal, dependency-free command-line parsing.
//!
//! The offline vendor set has no `clap`, so the CLI is built on this small
//! spec-driven parser: long flags (`--key value` / `--key=value`), boolean
//! switches, positional arguments, per-command help text, and typed
//! accessors with defaults.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Kind of an option.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgKind {
    /// `--key <value>` — takes a value.
    Value,
    /// `--key` — boolean switch.
    Switch,
}

/// One declared option.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub kind: ArgKind,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// A declarative argument parser for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct ArgParser {
    command: String,
    about: String,
    specs: Vec<ArgSpec>,
    positionals: Vec<(&'static str, &'static str)>,
}

/// Parsed result: values by flag name + leftover positionals.
#[derive(Clone, Debug, Default)]
pub struct ParsedArgs {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

/// Error raised on malformed command lines.
#[derive(Debug, thiserror::Error)]
pub enum ArgError {
    #[error("unknown flag --{0}")]
    Unknown(String),
    #[error("flag --{0} expects a value")]
    MissingValue(String),
    #[error("could not parse --{flag} value {value:?} as {ty}")]
    BadValue {
        flag: String,
        value: String,
        ty: &'static str,
    },
    #[error("help requested")]
    HelpRequested,
}

impl ArgParser {
    pub fn new(command: &str, about: &str) -> Self {
        ArgParser {
            command: command.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare a `--key <value>` option with an optional default.
    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, kind: ArgKind::Value, default, help });
        self
    }

    /// Declare a boolean `--flag` switch (defaults to false).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, kind: ArgKind::Switch, default: None, help });
        self
    }

    /// Declare a positional argument (for help text only; extras are kept).
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Render `--help` output.
    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.command, self.about);
        let _ = writeln!(s, "\nUSAGE:\n  {} [OPTIONS] {}", self.command,
            self.positionals.iter().map(|(n, _)| format!("<{n}>")).collect::<Vec<_>>().join(" "));
        if !self.positionals.is_empty() {
            let _ = writeln!(s, "\nARGS:");
            for (n, h) in &self.positionals {
                let _ = writeln!(s, "  <{n:<14}> {h}");
            }
        }
        let _ = writeln!(s, "\nOPTIONS:");
        for spec in &self.specs {
            let tail = match (spec.kind, spec.default) {
                (ArgKind::Value, Some(d)) => format!("{} [default: {}]", spec.help, d),
                _ => spec.help.to_string(),
            };
            let flag = match spec.kind {
                ArgKind::Value => format!("--{} <v>", spec.name),
                ArgKind::Switch => format!("--{}", spec.name),
            };
            let _ = writeln!(s, "  {flag:<22} {tail}");
        }
        let _ = writeln!(s, "  {:<22} print this help", "--help");
        s
    }

    /// Parse a token stream (not including argv[0] / the subcommand name).
    pub fn parse<I, S>(&self, args: I) -> Result<ParsedArgs, ArgError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = ParsedArgs::default();
        for spec in &self.specs {
            if let (ArgKind::Value, Some(d)) = (spec.kind, spec.default) {
                out.values.insert(spec.name.to_string(), d.to_string());
            }
            if spec.kind == ArgKind::Switch {
                out.switches.insert(spec.name.to_string(), false);
            }
        }
        let mut it = args.into_iter().map(Into::into).peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(ArgError::HelpRequested);
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| ArgError::Unknown(name.clone()))?;
                match spec.kind {
                    ArgKind::Switch => {
                        out.switches.insert(name, true);
                    }
                    ArgKind::Value => {
                        let v = match inline {
                            Some(v) => v,
                            None => it.next().ok_or_else(|| ArgError::MissingValue(name.clone()))?,
                        };
                        out.values.insert(name, v);
                    }
                }
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }
}

impl ParsedArgs {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_string(&self, name: &str) -> String {
        self.get(name).unwrap_or_default().to_string()
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, ArgError> {
        self.typed(name, "usize", |v| v.parse::<usize>().ok())
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, ArgError> {
        self.typed(name, "u64", |v| v.parse::<u64>().ok())
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, ArgError> {
        self.typed(name, "f64", |v| v.parse::<f64>().ok())
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    fn typed<T>(
        &self,
        name: &str,
        ty: &'static str,
        parse: impl Fn(&str) -> Option<T>,
    ) -> Result<T, ArgError> {
        let raw = self.get(name).ok_or_else(|| ArgError::MissingValue(name.to_string()))?;
        parse(raw).ok_or_else(|| ArgError::BadValue {
            flag: name.to_string(),
            value: raw.to_string(),
            ty,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parser() -> ArgParser {
        ArgParser::new("demo", "test parser")
            .opt("rows", Some("50"), "sketch rows")
            .opt("sigma", Some("0.5"), "sphere radius")
            .opt("name", None, "dataset name")
            .switch("verbose", "chatty output")
            .positional("input", "input file")
    }

    #[test]
    fn defaults_apply() {
        let p = parser().parse(Vec::<String>::new()).unwrap();
        assert_eq!(p.get_usize("rows").unwrap(), 50);
        assert_eq!(p.get_f64("sigma").unwrap(), 0.5);
        assert!(!p.get_bool("verbose"));
        assert!(p.get("name").is_none());
    }

    #[test]
    fn space_and_equals_forms() {
        let p = parser().parse(["--rows", "7", "--sigma=0.25"]).unwrap();
        assert_eq!(p.get_usize("rows").unwrap(), 7);
        assert_eq!(p.get_f64("sigma").unwrap(), 0.25);
    }

    #[test]
    fn switches_and_positionals() {
        let p = parser().parse(["--verbose", "a.csv", "b.csv"]).unwrap();
        assert!(p.get_bool("verbose"));
        assert_eq!(p.positionals(), &["a.csv".to_string(), "b.csv".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(parser().parse(["--nope"]), Err(ArgError::Unknown(_))));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(parser().parse(["--rows"]), Err(ArgError::MissingValue(_))));
    }

    #[test]
    fn bad_value_rejected() {
        assert!(matches!(
            parser().parse(["--rows", "xyz"]).unwrap().get_usize("rows"),
            Err(ArgError::BadValue { .. })
        ));
    }

    #[test]
    fn help_requested() {
        assert!(matches!(parser().parse(["--help"]), Err(ArgError::HelpRequested)));
        let usage = parser().usage();
        assert!(usage.contains("--rows"));
        assert!(usage.contains("demo"));
    }
}
