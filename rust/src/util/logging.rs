//! Tiny `log`-facade backend: leveled, timestamped stderr logging with a
//! `STORM_LOG` environment filter (error|warn|info|debug|trace).

use crate::util::timer::Timer;
use log::{Level, LevelFilter, Metadata, Record};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Process start reference for the relative timestamps, captured lazily
/// on the first log line through the repo's one wall-clock home
/// ([`crate::util::timer::Timer`] — stormlint's `wall-clock` rule keeps
/// `Instant::now` out of everywhere else).
static START: OnceLock<Timer> = OnceLock::new();
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get_or_init(Timer::start).elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>8.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent). Level comes from `STORM_LOG`, default
/// `info`.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("STORM_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
