//! Scalar math helpers shared by the LSH collision-probability formulas,
//! surrogate losses and metrics.

/// Numerically-guarded arccos: clamps the argument into `[-1, 1]` before
/// calling `acos`. The asymmetric inner-product hash guarantees
/// `|<a, b>| <= 1` analytically, but floating-point dot products can
/// overshoot by a few ulps which would yield NaN.
#[inline]
pub fn acos_clamped(t: f64) -> f64 {
    t.clamp(-1.0, 1.0).acos()
}

/// SRP single-hyperplane collision probability for *angle*:
/// `1 - acos(t)/pi` where `t` is the (possibly unnormalized) inner product
/// fed through the asymmetric transform. This is the building block `f` in
/// the paper's Theorem 2.
#[inline]
pub fn srp_collision(t: f64) -> f64 {
    1.0 - acos_clamped(t) / std::f64::consts::PI
}

/// Derivative of [`srp_collision`] w.r.t. `t`: `1 / (pi * sqrt(1 - t^2))`.
/// Guarded away from the endpoints.
#[inline]
pub fn srp_collision_deriv(t: f64) -> f64 {
    let t = t.clamp(-1.0 + 1e-12, 1.0 - 1e-12);
    1.0 / (std::f64::consts::PI * (1.0 - t * t).sqrt())
}

/// Error function via the Abramowitz–Stegun 7.1.26 rational approximation
/// (|err| < 1.5e-7), enough for the gaussian-CDF uses in tests/metrics.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
#[inline]
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Dot product of equal-length slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `a += scale * b` in place.
#[inline]
pub fn axpy(a: &mut [f64], scale: f64, b: &[f64]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] += scale * b[i];
    }
}

/// Mean of a slice (0 for empty).
#[inline]
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance of a slice (0 for len < 2).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Percentile (nearest-rank) of an unsorted slice; `q` in `[0, 100]`.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Next power of two at or above `n` (n >= 1).
#[inline]
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acos_clamped_handles_overshoot() {
        assert!(acos_clamped(1.0 + 1e-12).is_finite());
        assert!(acos_clamped(-1.0 - 1e-12).is_finite());
        assert!((acos_clamped(0.0) - std::f64::consts::FRAC_PI_2).abs() < 1e-12);
    }

    #[test]
    fn srp_collision_endpoints() {
        assert!((srp_collision(1.0) - 1.0).abs() < 1e-12);
        assert!(srp_collision(-1.0).abs() < 1e-12);
        assert!((srp_collision(0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn srp_collision_monotone_increasing() {
        let mut prev = srp_collision(-1.0);
        let mut t = -1.0 + 0.01;
        while t <= 1.0 {
            let cur = srp_collision(t);
            assert!(cur >= prev);
            prev = cur;
            t += 0.01;
        }
    }

    #[test]
    fn srp_deriv_matches_finite_difference() {
        for &t in &[-0.9, -0.5, 0.0, 0.3, 0.8] {
            let h = 1e-6;
            let fd = (srp_collision(t + h) - srp_collision(t - h)) / (2.0 * h);
            let an = srp_collision_deriv(t);
            assert!((fd - an).abs() < 1e-5, "t={t} fd={fd} an={an}");
        }
    }

    #[test]
    fn erf_known_values() {
        // The A&S 7.1.26 coefficients sum to 1 - 1e-9, not exactly 1.
        assert!(erf(0.0).abs() < 1e-8);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779095).abs() < 1e-6);
    }

    #[test]
    fn normal_cdf_symmetry() {
        for &x in &[0.1, 0.7, 1.5, 2.5] {
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn dot_norm_axpy() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert!((dot(&a, &b) - 32.0).abs() < 1e-12);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        let mut c = [1.0, 1.0, 1.0];
        axpy(&mut c, 2.0, &a);
        assert_eq!(c, [3.0, 5.0, 7.0]);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stats_helpers() {
        let xs = [2.0, 4.0, 6.0];
        assert!((mean(&xs) - 4.0).abs() < 1e-12);
        assert!((variance(&xs) - 8.0 / 3.0).abs() < 1e-12);
    }
}
