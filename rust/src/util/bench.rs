//! Micro-benchmark framework (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets in `rust/benches/` are `harness = false` binaries
//! that drive this module: per-benchmark warmup, adaptive iteration count
//! targeting a fixed measurement window, and mean / stddev / p50 / p99 /
//! throughput reporting on stdout in a stable, grep-friendly format.
//!
//! Every suite is a plain standalone binary — regenerate any
//! `BENCH_<name>.json` with:
//!
//! ```text
//! cargo bench --bench bench_<name>            # full measurement window
//! STORM_BENCH_FAST=1 cargo bench --bench bench_<name>   # CI-speed pass
//! ```
//!
//! Each suite ends by calling [`JsonReporter::record_peak_rss`] so the
//! JSON also carries the run's peak resident set size.

use crate::util::mathx::{mean, percentile, variance};
use std::time::Instant;

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Seconds of warmup before measuring.
    pub warmup_secs: f64,
    /// Target seconds of measurement.
    pub measure_secs: f64,
    /// Minimum number of timed samples.
    pub min_samples: usize,
    /// Maximum number of timed samples.
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_secs: 0.2,
            measure_secs: 1.0,
            min_samples: 10,
            max_samples: 10_000,
        }
    }
}

/// Fast settings for CI / quick runs (`STORM_BENCH_FAST=1`).
pub fn config_from_env() -> BenchConfig {
    if std::env::var("STORM_BENCH_FAST").is_ok() {
        BenchConfig {
            warmup_secs: 0.02,
            measure_secs: 0.1,
            min_samples: 3,
            max_samples: 200,
        }
    } else {
        BenchConfig::default()
    }
}

/// Summary statistics for one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<u64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items.map(|n| n as f64 / self.mean_s)
    }

    /// Stable single-line report, e.g.
    /// `bench storm_insert       mean=1.23ms p50=1.20ms p99=1.50ms n=812 thrpt=81300.0/s`
    pub fn report(&self) -> String {
        let base = format!(
            "bench {:<36} mean={} p50={} p99={} sd={} n={}",
            self.name,
            crate::util::timer::human_duration(self.mean_s),
            crate::util::timer::human_duration(self.p50_s),
            crate::util::timer::human_duration(self.p99_s),
            crate::util::timer::human_duration(self.std_s),
            self.samples,
        );
        match self.throughput() {
            Some(t) => format!("{base} thrpt={t:.1}/s"),
            None => base,
        }
    }
}

/// Run one benchmark: `f` is invoked repeatedly and timed per call.
pub fn bench<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    bench_with_items(name, cfg, None, &mut f)
}

/// Like [`bench`] but records `items` work units per call for throughput.
pub fn bench_items<F: FnMut()>(name: &str, cfg: BenchConfig, items: u64, mut f: F) -> BenchResult {
    bench_with_items(name, cfg, Some(items), &mut f)
}

fn bench_with_items(
    name: &str,
    cfg: BenchConfig,
    items: Option<u64>,
    f: &mut dyn FnMut(),
) -> BenchResult {
    // Warmup.
    let warm_start = Instant::now();
    while warm_start.elapsed().as_secs_f64() < cfg.warmup_secs {
        f();
    }
    // Measure.
    let mut samples = Vec::new();
    let run_start = Instant::now();
    while (samples.len() < cfg.min_samples
        || run_start.elapsed().as_secs_f64() < cfg.measure_secs)
        && samples.len() < cfg.max_samples
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let result = BenchResult {
        name: name.to_string(),
        samples: samples.len(),
        mean_s: mean(&samples),
        std_s: variance(&samples).sqrt(),
        p50_s: percentile(&samples, 50.0),
        p99_s: percentile(&samples, 99.0),
        items,
    };
    println!("{}", result.report());
    result
}

/// Print a section header so bench output groups visibly per figure/table.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`), or 0 where the procfs field is unavailable
/// (non-Linux). A high-water mark, not a current reading: call it at
/// the end of a bench run to capture the run's worst-case footprint.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    parse_vm_hwm(&status).unwrap_or(0)
}

/// Parse the `VmHWM:` line of a `/proc/<pid>/status` dump into bytes.
fn parse_vm_hwm(status: &str) -> Option<u64> {
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    // Format: `VmHWM:	   12345 kB`.
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Collects the results of one bench suite and emits a machine-readable
/// `BENCH_<suite>.json` alongside the human stdout report, so the perf
/// trajectory is tracked across PRs (EXPERIMENTS.md §Perf and
/// §Communication vs. rounds read these).
///
/// Output is a JSON array of objects: timing entries carry `name`,
/// `ns_per_item`, `items_per_sec` (both `null` when the bench has no item
/// count) plus the raw timing stats; scalar entries (recorded with
/// [`JsonReporter::record_scalar`] — e.g. wire bytes per round) carry
/// `name` and `value`. Written to `$STORM_BENCH_JSON_DIR` if set,
/// otherwise the current directory.
pub struct JsonReporter {
    suite: String,
    entries: Vec<Entry>,
}

enum Entry {
    Bench(BenchResult),
    Scalar { name: String, value: f64 },
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

impl JsonReporter {
    pub fn new(suite: &str) -> Self {
        JsonReporter { suite: suite.to_string(), entries: Vec::new() }
    }

    /// Record one benchmark result (typically the return value of
    /// [`bench`] / [`bench_items`]).
    pub fn record(&mut self, result: BenchResult) {
        self.entries.push(Entry::Bench(result));
    }

    /// Record a free-form scalar metric alongside the timings — sizes,
    /// ratios, byte counts (e.g. sparse-vs-dense wire bytes per round).
    pub fn record_scalar(&mut self, name: &str, value: f64) {
        println!("metric {name:<35} value={value:.3}");
        self.entries.push(Entry::Scalar { name: name.to_string(), value });
    }

    /// Record the process peak RSS (see [`peak_rss_bytes`]) as a
    /// `peak_rss_bytes` scalar. Every bench main calls this just before
    /// [`JsonReporter::write`] so each `BENCH_<suite>.json` carries the
    /// suite's memory high-water mark alongside its timings; 0 on
    /// platforms without `/proc/self/status`.
    pub fn record_peak_rss(&mut self) {
        self.record_scalar("peak_rss_bytes", peak_rss_bytes() as f64);
    }

    /// Render all recorded results as a JSON array.
    pub fn to_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, entry) in self.entries.iter().enumerate() {
            match entry {
                Entry::Bench(r) => {
                    let ns_per_item = match r.items {
                        Some(n) if n > 0 => json_num(r.mean_s * 1e9 / n as f64),
                        _ => "null".to_string(),
                    };
                    let items_per_sec = match r.throughput() {
                        Some(t) => json_num(t),
                        None => "null".to_string(),
                    };
                    out.push_str(&format!(
                        concat!(
                            "  {{\"name\": \"{}\", \"ns_per_item\": {}, ",
                            "\"items_per_sec\": {}, \"mean_ns\": {}, ",
                            "\"p50_ns\": {}, \"p99_ns\": {}, \"sd_ns\": {}, ",
                            "\"samples\": {}, \"items\": {}}}"
                        ),
                        json_escape(&r.name),
                        ns_per_item,
                        items_per_sec,
                        json_num(r.mean_s * 1e9),
                        json_num(r.p50_s * 1e9),
                        json_num(r.p99_s * 1e9),
                        json_num(r.std_s * 1e9),
                        r.samples,
                        r.items.map_or("null".to_string(), |n| n.to_string()),
                    ));
                }
                Entry::Scalar { name, value } => {
                    out.push_str(&format!(
                        "  {{\"name\": \"{}\", \"value\": {}}}",
                        json_escape(name),
                        json_num(*value),
                    ));
                }
            }
            out.push_str(if i + 1 < self.entries.len() { ",\n" } else { "\n" });
        }
        out.push_str("]\n");
        out
    }

    /// Write `BENCH_<suite>.json` and return the path written.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("STORM_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
        let path = std::path::Path::new(&dir).join(format!("BENCH_{}.json", self.suite));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let cfg = BenchConfig {
            warmup_secs: 0.0,
            measure_secs: 0.01,
            min_samples: 3,
            max_samples: 50,
        };
        let r = bench("unit_test_noop", cfg, || {
            black_box(1 + 1);
        });
        assert!(r.samples >= 3);
        assert!(r.mean_s >= 0.0);
        assert!(r.report().contains("unit_test_noop"));
    }

    #[test]
    fn json_reporter_renders_valid_shape() {
        let mut rep = JsonReporter::new("unit");
        rep.record(BenchResult {
            name: "a_bench".to_string(),
            samples: 5,
            mean_s: 1e-6,
            std_s: 1e-8,
            p50_s: 1e-6,
            p99_s: 2e-6,
            items: Some(100),
        });
        rep.record(BenchResult {
            name: "no_items".to_string(),
            samples: 3,
            mean_s: 2e-6,
            std_s: 0.0,
            p50_s: 2e-6,
            p99_s: 2e-6,
            items: None,
        });
        let json = rep.to_json();
        assert!(json.starts_with("[\n"));
        assert!(json.trim_end().ends_with(']'));
        assert!(json.contains("\"name\": \"a_bench\""));
        // 1e-6 s / 100 items = 10 ns/item.
        assert!(json.contains("\"ns_per_item\": 10.000"));
        assert!(json.contains("\"items_per_sec\": 100000000.000"));
        assert!(json.contains("\"ns_per_item\": null"));
        // Exactly one comma-separated boundary between the two objects.
        assert_eq!(json.matches("},\n").count(), 1);
    }

    #[test]
    fn json_reporter_mixes_scalars_and_timings() {
        let mut rep = JsonReporter::new("unit");
        rep.record_scalar("wire_bytes_sparse", 512.0);
        rep.record(BenchResult {
            name: "timed".to_string(),
            samples: 3,
            mean_s: 1e-6,
            std_s: 0.0,
            p50_s: 1e-6,
            p99_s: 1e-6,
            items: None,
        });
        let json = rep.to_json();
        assert!(json.contains("\"name\": \"wire_bytes_sparse\", \"value\": 512.000"));
        assert!(json.contains("\"name\": \"timed\""));
        assert_eq!(json.matches("},\n").count(), 1);
    }

    #[test]
    fn parses_vm_hwm_and_tolerates_absence() {
        let status = "Name:\tstorm\nVmPeak:\t  999 kB\nVmHWM:\t   12345 kB\nThreads:\t4\n";
        assert_eq!(parse_vm_hwm(status), Some(12345 * 1024));
        assert_eq!(parse_vm_hwm("Name:\tstorm\n"), None);
        assert_eq!(parse_vm_hwm("VmHWM:\tgarbage kB\n"), None);
        #[cfg(target_os = "linux")]
        assert!(peak_rss_bytes() > 0, "procfs should report a high-water mark on Linux");
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("tab\there"), "tab\\u0009here");
    }

    #[test]
    fn throughput_reported() {
        let cfg = BenchConfig {
            warmup_secs: 0.0,
            measure_secs: 0.01,
            min_samples: 3,
            max_samples: 50,
        };
        let r = bench_items("unit_test_items", cfg, 100, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.throughput().unwrap() > 0.0);
    }
}
