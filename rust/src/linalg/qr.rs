//! Householder QR — used for (a) numerically robust least squares and
//! (b) exact leverage scores (row norms of the thin Q factor), which the
//! leverage-score sampling baseline needs.

use super::matrix::Matrix;

/// Thin QR factorization of an `n x d` matrix with `n >= d`:
/// `A = Q R` with `Q` `n x d` orthonormal columns and `R` `d x d` upper
/// triangular.
#[derive(Clone, Debug)]
pub struct ThinQr {
    pub q: Matrix,
    pub r: Matrix,
}

/// Factor via Householder reflections accumulated into an explicit thin Q.
pub fn thin_qr(a: &Matrix) -> ThinQr {
    let (n, d) = a.shape();
    assert!(n >= d, "thin_qr requires n >= d (got {n} x {d})");
    // Work on a copy; collect Householder vectors.
    let mut r_work = a.clone();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(d);
    for k in 0..d {
        // Build the Householder vector for column k below the diagonal.
        let mut v = vec![0.0; n - k];
        let mut norm_x = 0.0;
        for i in k..n {
            let x = r_work[(i, k)];
            v[i - k] = x;
            norm_x += x * x;
        }
        let norm_x = norm_x.sqrt();
        if norm_x > 0.0 {
            let alpha = if v[0] >= 0.0 { -norm_x } else { norm_x };
            v[0] -= alpha;
            let vnorm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if vnorm > 1e-300 {
                for x in &mut v {
                    *x /= vnorm;
                }
                // Apply H = I - 2 v v^T to the trailing submatrix.
                for j in k..d {
                    let mut dotp = 0.0;
                    for i in k..n {
                        dotp += v[i - k] * r_work[(i, j)];
                    }
                    for i in k..n {
                        r_work[(i, j)] -= 2.0 * v[i - k] * dotp;
                    }
                }
            } else {
                v.iter_mut().for_each(|x| *x = 0.0);
            }
        }
        vs.push(v);
    }
    // R = top d x d of the transformed matrix.
    let mut r = Matrix::zeros(d, d);
    for i in 0..d {
        for j in i..d {
            r[(i, j)] = r_work[(i, j)];
        }
    }
    // Q = H_0 H_1 ... H_{d-1} * [I_d; 0] — apply reflections in reverse to
    // the first d columns of the identity.
    let mut q = Matrix::zeros(n, d);
    for i in 0..d {
        q[(i, i)] = 1.0;
    }
    for k in (0..d).rev() {
        let v = &vs[k];
        for j in 0..d {
            let mut dotp = 0.0;
            for i in k..n {
                dotp += v[i - k] * q[(i, j)];
            }
            for i in k..n {
                q[(i, j)] -= 2.0 * v[i - k] * dotp;
            }
        }
    }
    ThinQr { q, r }
}

impl ThinQr {
    /// Least-squares solve `min ||A x - b||` via `R x = Q^T b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let d = self.r.rows();
        let qtb = self.q.matvec_t(b);
        let mut x = vec![0.0; d];
        for i in (0..d).rev() {
            let mut sum = qtb[i];
            for k in i + 1..d {
                sum -= self.r[(i, k)] * x[k];
            }
            let rii = self.r[(i, i)];
            x[i] = if rii.abs() > 1e-300 { sum / rii } else { 0.0 };
        }
        x
    }

    /// Statistical leverage scores: `l_i = ||Q_{i,:}||^2`. They sum to d
    /// (the column rank) and are the sampling probabilities (after
    /// normalization) used by the leverage-sampling baseline.
    pub fn leverage_scores(&self) -> Vec<f64> {
        (0..self.q.rows())
            .map(|i| self.q.row(i).iter().map(|v| v * v).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, assert_close, cases};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn qr_reconstructs_a() {
        let mut rng = Xoshiro256::new(31);
        let a = Matrix::gaussian(8, 4, &mut rng);
        let f = thin_qr(&a);
        let recon = f.q.matmul(&f.r);
        assert_allclose(recon.data(), a.data(), 1e-9);
    }

    #[test]
    fn q_has_orthonormal_columns() {
        let mut rng = Xoshiro256::new(32);
        let a = Matrix::gaussian(10, 5, &mut rng);
        let f = thin_qr(&a);
        let qtq = f.q.gram();
        assert_allclose(qtq.data(), Matrix::eye(5).data(), 1e-9);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Xoshiro256::new(33);
        let a = Matrix::gaussian(7, 4, &mut rng);
        let f = thin_qr(&a);
        for i in 0..4 {
            for j in 0..i {
                assert!(f.r[(i, j)].abs() < 1e-10);
            }
        }
    }

    #[test]
    fn solve_recovers_planted_model() {
        cases(15, 34, |rng, _| {
            let d = crate::testing::gen_dim(rng, 1, 8);
            let n = d + 5 + crate::testing::gen_dim(rng, 0, 20);
            let a = Matrix::gaussian(n, d, rng);
            let x_true: Vec<f64> = (0..d).map(|i| (i % 3) as f64 - 1.0).collect();
            let b = a.matvec(&x_true);
            let x = thin_qr(&a).solve(&b);
            assert_allclose(&x, &x_true, 1e-7);
        });
    }

    #[test]
    fn leverage_scores_sum_to_rank() {
        let mut rng = Xoshiro256::new(35);
        let a = Matrix::gaussian(20, 6, &mut rng);
        let scores = thin_qr(&a).leverage_scores();
        assert_eq!(scores.len(), 20);
        assert_close(scores.iter().sum::<f64>(), 6.0, 1e-9);
        for &s in &scores {
            assert!((0.0..=1.0 + 1e-9).contains(&s), "score={s}");
        }
    }

    #[test]
    fn leverage_of_identity_rows_is_one() {
        // A = I (n = d): every row has leverage exactly 1.
        let a = Matrix::eye(5);
        let scores = thin_qr(&a).leverage_scores();
        assert_allclose(&scores, &[1.0; 5], 1e-10);
    }
}
