//! Row-major dense matrix with the operations the rest of the crate needs.

use crate::util::rng::Rng;
use std::fmt;

/// Dense row-major `rows x cols` matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(6) {
            writeln!(f, "  {:?}", &self.row(r)[..self.cols.min(8)])?;
        }
        if self.rows > 6 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure over `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer does not match shape");
        Matrix { rows, cols, data }
    }

    /// Build from row slices.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Matrix { rows: rows.len(), cols, data }
    }

    /// iid standard gaussian entries.
    pub fn gaussian(rows: usize, cols: usize, rng: &mut impl Rng) -> Self {
        Matrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.gaussian()).collect(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c`.
    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// Matrix product `self * other` (ikj loop order — cache friendly for
    /// row-major operands; the sizes in this crate are small enough that a
    /// full blocked GEMM is unnecessary).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let dst = out.row_mut(i);
                for j in 0..orow.len() {
                    dst[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// Matrix-vector product `self * v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|r| crate::util::mathx::dot(self.row(r), v))
            .collect()
    }

    /// `self^T * v` without materializing the transpose.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "matvec_t shape mismatch");
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            let s = v[r];
            if s == 0.0 {
                continue;
            }
            for c in 0..self.cols {
                out[c] += s * row[c];
            }
        }
        out
    }

    /// Gram matrix `self^T * self` (symmetric; used by normal equations).
    pub fn gram(&self) -> Matrix {
        let d = self.cols;
        let mut g = Matrix::zeros(d, d);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..d {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let grow = g.row_mut(i);
                for j in i..d {
                    grow[j] += ri * row[j];
                }
            }
        }
        // Mirror upper triangle down.
        for i in 0..d {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Elementwise scale in place.
    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_allclose;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.shape(), (2, 2));
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.col(0), vec![1.0, 3.0]);
    }

    #[test]
    fn eye_matmul_is_identity_map() {
        let mut rng = Xoshiro256::new(1);
        let a = Matrix::gaussian(4, 4, &mut rng);
        let i = Matrix::eye(4);
        assert_allclose(a.matmul(&i).data(), a.data(), 1e-12);
        assert_allclose(i.matmul(&a).data(), a.data(), 1e-12);
    }

    #[test]
    fn matmul_known_product() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_allclose(c.data(), &[19.0, 22.0, 43.0, 50.0], 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256::new(2);
        let a = Matrix::gaussian(3, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        let mut rng = Xoshiro256::new(3);
        let a = Matrix::gaussian(4, 3, &mut rng);
        let v = vec![1.0, -2.0, 0.5];
        let via_mm = a.matmul(&Matrix::from_vec(3, 1, v.clone()));
        assert_allclose(&a.matvec(&v), via_mm.data(), 1e-12);
    }

    #[test]
    fn matvec_t_agrees_with_transpose() {
        let mut rng = Xoshiro256::new(4);
        let a = Matrix::gaussian(5, 3, &mut rng);
        let v: Vec<f64> = (0..5).map(|i| i as f64 - 2.0).collect();
        assert_allclose(&a.matvec_t(&v), &a.transpose().matvec(&v), 1e-12);
    }

    #[test]
    fn gram_matches_explicit_product() {
        let mut rng = Xoshiro256::new(5);
        let a = Matrix::gaussian(6, 4, &mut rng);
        let g = a.gram();
        let explicit = a.transpose().matmul(&a);
        assert_allclose(g.data(), explicit.data(), 1e-10);
    }

    #[test]
    fn select_rows_picks_rows() {
        let a = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]);
        let s = a.select_rows(&[2, 0]);
        assert_eq!(s.data(), &[3.0, 1.0]);
    }

    #[test]
    fn frobenius_and_max_abs() {
        let a = Matrix::from_rows(&[vec![3.0, 0.0], vec![0.0, -4.0]]);
        assert!((a.frobenius() - 5.0).abs() < 1e-12);
        assert_eq!(a.max_abs(), 4.0);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
