//! Dense linear algebra substrate, written from scratch.
//!
//! Everything the baselines and solvers need: a row-major matrix type,
//! blocked matrix products, Cholesky factorization (exact least squares /
//! ridge via normal equations), Householder QR (leverage scores and a
//! numerically robust least-squares path), and triangular solves.
//!
//! This is the "dependency" layer the paper assumes exists — the
//! comparison baselines (exact LS, leverage-score sampling, the
//! Clarkson–Woodruff sketch-and-solve) all sit on top of it.

pub mod matrix;
pub mod cholesky;
pub mod qr;
pub mod solve;

pub use matrix::Matrix;
