//! High-level least-squares solvers used throughout the baselines and the
//! experiment harness.

use super::cholesky::Cholesky;
use super::matrix::Matrix;
use super::qr::thin_qr;

/// How to solve the least-squares problem.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LstsqMethod {
    /// Normal equations + Cholesky (fast, squares the condition number).
    NormalEquations,
    /// Householder QR (slower, numerically robust).
    Qr,
}

/// Solve `min_theta ||X theta - y||_2^2 + ridge * ||theta||^2`.
///
/// `ridge = 0` gives ordinary least squares; the normal-equation path adds
/// a tiny jitter retry if the Gram matrix is numerically singular (e.g. in
/// the undersampled n < d regime the sampling baselines hit around the
/// double-descent peak).
pub fn lstsq(x: &Matrix, y: &[f64], ridge: f64, method: LstsqMethod) -> Vec<f64> {
    assert_eq!(x.rows(), y.len(), "row/label mismatch");
    match method {
        LstsqMethod::Qr if x.rows() >= x.cols() && ridge == 0.0 => thin_qr(x).solve(y),
        _ => {
            let d = x.cols();
            let mut gram = x.gram();
            let xty = x.matvec_t(y);
            let mut jitter = ridge;
            for attempt in 0..6 {
                let mut a = gram.clone();
                if jitter > 0.0 {
                    for i in 0..d {
                        a[(i, i)] += jitter;
                    }
                }
                match Cholesky::factor(&a) {
                    Ok(ch) => return ch.solve(&xty),
                    Err(_) => {
                        // Escalate jitter: scale with the Gram diagonal so the
                        // regularization is dimensionally sensible.
                        let diag_mean = (0..d).map(|i| gram[(i, i)]).sum::<f64>() / d.max(1) as f64;
                        jitter = (diag_mean.max(1e-12)) * 1e-10 * 10f64.powi(attempt);
                    }
                }
            }
            // Degenerate fallback: heavy ridge.
            for i in 0..d {
                gram[(i, i)] += 1e-3;
            }
            Cholesky::factor(&gram)
                .expect("heavily ridged Gram must be SPD")
                .solve(&xty)
        }
    }
}

/// Mean squared error of a linear model `theta` on `(X, y)`.
pub fn mse(x: &Matrix, y: &[f64], theta: &[f64]) -> f64 {
    assert_eq!(x.rows(), y.len());
    let pred = x.matvec(theta);
    let n = y.len().max(1) as f64;
    pred.iter()
        .zip(y)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, cases};
    use crate::util::rng::{Rng, Xoshiro256};

    #[test]
    fn both_methods_recover_planted_model() {
        cases(10, 41, |rng, _| {
            let d = crate::testing::gen_dim(rng, 2, 8);
            let n = d * 5 + 10;
            let x = Matrix::gaussian(n, d, rng);
            let theta: Vec<f64> = (0..d).map(|i| i as f64 * 0.5 - 1.0).collect();
            let y = x.matvec(&theta);
            let t1 = lstsq(&x, &y, 0.0, LstsqMethod::NormalEquations);
            let t2 = lstsq(&x, &y, 0.0, LstsqMethod::Qr);
            assert_allclose(&t1, &theta, 1e-6);
            assert_allclose(&t2, &theta, 1e-6);
        });
    }

    #[test]
    fn ridge_shrinks_solution() {
        let mut rng = Xoshiro256::new(42);
        let x = Matrix::gaussian(50, 4, &mut rng);
        let theta: Vec<f64> = vec![2.0, -1.0, 0.5, 3.0];
        let y: Vec<f64> = x
            .matvec(&theta)
            .iter()
            .map(|v| v + 0.01 * rng.gaussian())
            .collect();
        let t0 = lstsq(&x, &y, 0.0, LstsqMethod::NormalEquations);
        let t_big = lstsq(&x, &y, 1e4, LstsqMethod::NormalEquations);
        let n0: f64 = t0.iter().map(|v| v * v).sum();
        let nb: f64 = t_big.iter().map(|v| v * v).sum();
        assert!(nb < n0 * 0.1, "ridge failed to shrink: {nb} vs {n0}");
    }

    #[test]
    fn singular_gram_does_not_panic() {
        // n < d: Gram is rank deficient; jitter path must kick in.
        let mut rng = Xoshiro256::new(43);
        let x = Matrix::gaussian(3, 8, &mut rng);
        let y = vec![1.0, 2.0, 3.0];
        let t = lstsq(&x, &y, 0.0, LstsqMethod::NormalEquations);
        assert_eq!(t.len(), 8);
        assert!(t.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn mse_zero_for_exact_fit() {
        let mut rng = Xoshiro256::new(44);
        let x = Matrix::gaussian(20, 3, &mut rng);
        let theta = vec![1.0, 2.0, 3.0];
        let y = x.matvec(&theta);
        assert!(mse(&x, &y, &theta) < 1e-18);
    }

    #[test]
    fn mse_positive_for_wrong_model() {
        let x = Matrix::eye(3);
        let y = vec![1.0, 1.0, 1.0];
        assert!(mse(&x, &y, &[0.0, 0.0, 0.0]) > 0.9);
    }
}
