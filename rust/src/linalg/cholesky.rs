//! Cholesky factorization and SPD solves — the workhorse behind the exact
//! least-squares baseline (normal equations) and ridge regularization.

use super::matrix::Matrix;

/// Errors from factorization.
#[derive(Debug, thiserror::Error)]
pub enum CholeskyError {
    #[error("matrix is not square ({0}x{1})")]
    NotSquare(usize, usize),
    #[error("matrix is not positive definite (pivot {0} = {1:.3e})")]
    NotPositiveDefinite(usize, f64),
}

/// Lower-triangular Cholesky factor `L` with `A = L L^T`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Matrix,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix.
    pub fn factor(a: &Matrix) -> Result<Self, CholeskyError> {
        let (n, m) = a.shape();
        if n != m {
            return Err(CholeskyError::NotSquare(n, m));
        }
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return Err(CholeskyError::NotPositiveDefinite(i, sum));
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// The lower factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Solve `A x = b` via forward + back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        // Forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * y[k];
            }
            y[i] = sum / self.l[(i, i)];
        }
        // Back: L^T x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
        x
    }

    /// log-determinant of `A` (2 * sum log diag L).
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, cases};
    use crate::util::rng::Xoshiro256;

    fn random_spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
        let a = Matrix::gaussian(n + 2, n, rng);
        let mut g = a.gram();
        for i in 0..n {
            g[(i, i)] += 0.5; // well away from singular
        }
        g
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Xoshiro256::new(11);
        let a = random_spd(5, &mut rng);
        let ch = Cholesky::factor(&a).unwrap();
        let recon = ch.l().matmul(&ch.l().transpose());
        assert_allclose(recon.data(), a.data(), 1e-9);
    }

    #[test]
    fn solve_recovers_known_x() {
        cases(20, 21, |rng, _| {
            let n = crate::testing::gen_dim(rng, 1, 12);
            let a = random_spd(n, rng);
            let x_true: Vec<f64> = (0..n).map(|i| (i as f64) - 1.5).collect();
            let b = a.matvec(&x_true);
            let x = Cholesky::factor(&a).unwrap().solve(&b);
            assert_allclose(&x, &x_true, 1e-6);
        });
    }

    #[test]
    fn rejects_non_square() {
        let a = Matrix::zeros(2, 3);
        assert!(matches!(Cholesky::factor(&a), Err(CholeskyError::NotSquare(2, 3))));
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(matches!(
            Cholesky::factor(&a),
            Err(CholeskyError::NotPositiveDefinite(..))
        ));
    }

    #[test]
    fn log_det_identity_is_zero() {
        let ch = Cholesky::factor(&Matrix::eye(4)).unwrap();
        assert!(ch.log_det().abs() < 1e-12);
    }
}
