//! Datasets and streaming sources.
//!
//! The paper evaluates on three UCI regression datasets (Table 1) and on
//! 2-D synthetic data (Figure 5). This offline environment cannot fetch
//! UCI, so `synthetic` provides deterministic generators matched to each
//! dataset's (N, d) and conditioning profile — see DESIGN.md §5 for the
//! substitution argument. A CSV loader is included so real UCI files drop
//! in unchanged when available.

pub mod dataset;
pub mod scale;
pub mod synthetic;
pub mod csv;
pub mod stream;
pub mod registry;

pub use dataset::Dataset;
