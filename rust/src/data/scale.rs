//! Unit-ball scaling.
//!
//! The asymmetric inner-product LSH (Section 2.2 of the paper) requires
//! the hashed vectors to lie inside the unit sphere; "we often scale the
//! dataset when using this inner product hash in practice." We scale the
//! *augmented* examples `[x, y]` so that the largest norm is `radius < 1`,
//! and remember the factor so losses can be mapped back to original units.

use super::dataset::Dataset;

/// Default target radius, kept strictly below 1 so the appended
/// asymmetric-LSH coordinate `sqrt(1 - ||z||^2)` stays real with margin.
pub const DEFAULT_RADIUS: f64 = 0.9;

/// Scale a dataset in place so every augmented example `[x, y]` has norm
/// at most `radius`. Returns the scale factor applied (multiplied into the
/// dataset's running `scale_factor`).
pub fn scale_to_unit_ball(ds: &mut Dataset, radius: f64) -> f64 {
    assert!((0.0..1.0).contains(&radius) && radius > 0.0);
    let mut max_norm: f64 = 0.0;
    for i in 0..ds.len() {
        let mut sq: f64 = ds.x.row(i).iter().map(|v| v * v).sum();
        sq += ds.y[i] * ds.y[i];
        max_norm = max_norm.max(sq.sqrt());
    }
    if max_norm == 0.0 {
        return 1.0;
    }
    let s = radius / max_norm;
    ds.x.scale(s);
    for y in &mut ds.y {
        *y *= s;
    }
    ds.scale_factor *= s;
    s
}

/// Quantile unit-ball scaling: scale so the `quantile`-th norm equals
/// `radius`, then *clip* the remaining tail onto the sphere of radius
/// `clip_radius` (norm capped, direction preserved).
///
/// Max-norm scaling (the naive reading of "scale the dataset") lets a few
/// outliers crush every typical example deep into the ball — mean norms
/// of 0.15–0.35 on the Table-1 sets — which flattens the surrogate loss
/// (the inner products `<theta~, z>` that carry the signal are all tiny)
/// until sketch noise dominates. Scaling to a high quantile instead keeps
/// typical examples at informative radii; the clipped tail (a few
/// percent) keeps its direction, perturbing the surrogate minimizer far
/// less than the SNR it buys. Returns the scale factor.
pub fn scale_to_unit_ball_quantile(ds: &mut Dataset, radius: f64, quantile: f64) -> f64 {
    assert!((0.0..1.0).contains(&radius) && radius > 0.0);
    assert!((0.0..=1.0).contains(&quantile) && quantile > 0.0);
    let mut norms: Vec<f64> = (0..ds.len())
        .map(|i| {
            let sq: f64 = ds.x.row(i).iter().map(|v| v * v).sum::<f64>() + ds.y[i] * ds.y[i];
            sq.sqrt()
        })
        .collect();
    if norms.is_empty() {
        return 1.0;
    }
    let mut sorted = norms.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (((sorted.len() as f64) * quantile).ceil() as usize)
        .clamp(1, sorted.len())
        - 1;
    let q_norm = sorted[rank];
    if q_norm == 0.0 {
        return scale_to_unit_ball(ds, radius);
    }
    let s = radius / q_norm;
    ds.x.scale(s);
    for y in &mut ds.y {
        *y *= s;
    }
    ds.scale_factor *= s;
    // Clip the tail onto the sphere just inside the unit ball.
    let clip_radius = 0.999;
    for n in &mut norms {
        *n *= s;
    }
    for i in 0..ds.len() {
        if norms[i] > clip_radius {
            let f = clip_radius / norms[i];
            for v in ds.x.row_mut(i) {
                *v *= f;
            }
            ds.y[i] *= f;
        }
    }
    s
}

/// Scale only the *features* into the unit ball, leaving labels
/// untouched — the classification-task scaler. The margin hash folds the
/// ±1 label into the hash *sign* (`-y * x`), so the hashed vector's norm
/// is `||x||` and labels must stay exactly ±1; scaling them (as the
/// regression scalers do) would corrupt the task. Returns the applied
/// factor.
pub fn scale_features_to_unit_ball(ds: &mut Dataset, radius: f64) -> f64 {
    assert!((0.0..1.0).contains(&radius) && radius > 0.0);
    let max_norm = (0..ds.len())
        .map(|i| ds.x.row(i).iter().map(|v| v * v).sum::<f64>().sqrt())
        .fold(0.0f64, f64::max);
    if max_norm == 0.0 {
        return 1.0;
    }
    let s = radius / max_norm;
    ds.x.scale(s);
    ds.scale_factor *= s;
    s
}

/// Maximum augmented-example norm (diagnostic + test helper).
pub fn max_augmented_norm(ds: &Dataset) -> f64 {
    (0..ds.len())
        .map(|i| {
            let sq: f64 = ds.x.row(i).iter().map(|v| v * v).sum::<f64>() + ds.y[i] * ds.y[i];
            sq.sqrt()
        })
        .fold(0.0, f64::max)
}

/// Bound the norm a *query* vector `[theta, -1]` may have so that the
/// asymmetric transform stays valid; callers clip theta into this ball.
pub fn query_radius() -> f64 {
    DEFAULT_RADIUS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;

    fn ds() -> Dataset {
        let x = Matrix::from_rows(&[vec![3.0, 0.0], vec![0.0, 4.0]]);
        Dataset::new("s", x, vec![4.0, 3.0])
    }

    #[test]
    fn feature_scaler_leaves_labels_exact() {
        let x = Matrix::from_rows(&[vec![3.0, 0.0], vec![0.0, 4.0]]);
        let mut d = Dataset::new("clf", x, vec![1.0, -1.0]);
        let s = scale_features_to_unit_ball(&mut d, 0.9);
        assert!((s - 0.225).abs() < 1e-12, "max feature norm 4 -> 0.9");
        assert_eq!(d.y, vec![1.0, -1.0], "labels must stay exactly ±1");
        let max_feat = (0..d.len())
            .map(|i| d.x.row(i).iter().map(|v| v * v).sum::<f64>().sqrt())
            .fold(0.0f64, f64::max);
        assert!((max_feat - 0.9).abs() < 1e-12);
    }

    #[test]
    fn scales_max_norm_to_radius() {
        let mut d = ds();
        // max augmented norm = ||[3,0,4]|| = 5
        let s = scale_to_unit_ball(&mut d, 0.9);
        assert!((s - 0.18).abs() < 1e-12);
        assert!((max_augmented_norm(&d) - 0.9).abs() < 1e-12);
        assert!((d.scale_factor - 0.18).abs() < 1e-12);
    }

    #[test]
    fn scaling_preserves_regression_solution() {
        // lstsq(X*s, y*s) == lstsq(X, y): uniform scaling of [X|y] keeps theta*.
        use crate::linalg::solve::{lstsq, LstsqMethod};
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(77);
        let x = Matrix::gaussian(40, 3, &mut rng);
        let theta = vec![1.0, -2.0, 0.5];
        let y = x.matvec(&theta);
        let mut d = Dataset::new("p", x, y);
        let t0 = lstsq(&d.x, &d.y, 0.0, LstsqMethod::Qr);
        scale_to_unit_ball(&mut d, 0.9);
        let t1 = lstsq(&d.x, &d.y, 0.0, LstsqMethod::Qr);
        crate::testing::assert_allclose(&t0, &t1, 1e-8);
    }

    #[test]
    fn zero_dataset_noop() {
        let x = Matrix::zeros(2, 2);
        let mut d = Dataset::new("z", x, vec![0.0, 0.0]);
        assert_eq!(scale_to_unit_ball(&mut d, 0.9), 1.0);
    }

    #[test]
    #[should_panic]
    fn invalid_radius_panics() {
        let mut d = ds();
        scale_to_unit_ball(&mut d, 1.5);
    }
}
