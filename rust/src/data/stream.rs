//! Streaming data sources.
//!
//! STORM is an *online* sketch: devices see examples one at a time (or in
//! small batches) and may not retain them. These adapters turn in-memory
//! datasets into streams for the edge-device simulator — replayed in
//! order, shuffled, or partitioned round-robin / contiguously across a
//! fleet.

use super::dataset::Dataset;
use crate::util::rng::{Rng, Xoshiro256};

/// One streamed example: the augmented vector `[x, y]`.
pub type Example = Vec<f64>;

/// A pull-based stream of augmented examples.
pub trait StreamSource: Send {
    /// Next example, or `None` when exhausted.
    fn next_example(&mut self) -> Option<Example>;

    /// Pull up to `n` examples into a batch, pre-sized from
    /// [`Self::remaining_hint`] so a short tail batch never over-allocates.
    fn next_batch(&mut self, n: usize) -> Vec<Example> {
        let mut out = Vec::new();
        self.next_batch_into(n, &mut out);
        out
    }

    /// Pull up to `n` examples into a caller-owned buffer (cleared first)
    /// — the allocation-free ingest path: devices reuse one buffer for
    /// every batch of a long-running stream.
    fn next_batch_into(&mut self, n: usize, out: &mut Vec<Example>) {
        out.clear();
        let cap = self.remaining_hint().map_or(n, |r| n.min(r));
        // reserve() is relative to len (0 after clear), so this ensures
        // capacity >= cap up front and is a no-op on a warm buffer.
        out.reserve(cap);
        for _ in 0..n {
            match self.next_example() {
                Some(e) => out.push(e),
                None => break,
            }
        }
    }

    /// Total examples this source will yield, if known.
    fn remaining_hint(&self) -> Option<usize> {
        None
    }
}

/// Forward through boxes so `Box<dyn StreamSource>` satisfies
/// `impl StreamSource` bounds WITHOUT falling back to the trait's default
/// methods — in particular `remaining_hint` must reach the concrete
/// stream (the default `None` would silently discard the length hints
/// every stream in this module knows).
impl<S: StreamSource + ?Sized> StreamSource for Box<S> {
    fn next_example(&mut self) -> Option<Example> {
        (**self).next_example()
    }

    fn next_batch(&mut self, n: usize) -> Vec<Example> {
        (**self).next_batch(n)
    }

    fn next_batch_into(&mut self, n: usize, out: &mut Vec<Example>) {
        (**self).next_batch_into(n, out)
    }

    fn remaining_hint(&self) -> Option<usize> {
        (**self).remaining_hint()
    }
}

/// Replays a dataset in index order.
pub struct ReplayStream {
    ds: Dataset,
    pos: usize,
}

impl ReplayStream {
    pub fn new(ds: Dataset) -> Self {
        ReplayStream { ds, pos: 0 }
    }
}

impl StreamSource for ReplayStream {
    fn next_example(&mut self) -> Option<Example> {
        if self.pos >= self.ds.len() {
            return None;
        }
        let e = self.ds.augmented(self.pos);
        self.pos += 1;
        Some(e)
    }

    fn remaining_hint(&self) -> Option<usize> {
        Some(self.ds.len() - self.pos)
    }
}

/// Replays a dataset in a seeded random order (one-pass shuffle).
pub struct ShuffledStream {
    ds: Dataset,
    order: Vec<usize>,
    pos: usize,
}

impl ShuffledStream {
    pub fn new(ds: Dataset, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let mut order: Vec<usize> = (0..ds.len()).collect();
        rng.shuffle(&mut order);
        ShuffledStream { ds, order, pos: 0 }
    }
}

impl StreamSource for ShuffledStream {
    fn next_example(&mut self) -> Option<Example> {
        if self.pos >= self.order.len() {
            return None;
        }
        let e = self.ds.augmented(self.order[self.pos]);
        self.pos += 1;
        Some(e)
    }

    fn remaining_hint(&self) -> Option<usize> {
        Some(self.order.len() - self.pos)
    }
}

/// An infinite stream that re-draws from the dataset with replacement —
/// models a long-running sensor that keeps emitting from the same
/// distribution. `take_limit` bounds it for tests/experiments.
pub struct ResampleStream {
    ds: Dataset,
    rng: Xoshiro256,
    emitted: usize,
    take_limit: usize,
}

impl ResampleStream {
    pub fn new(ds: Dataset, seed: u64, take_limit: usize) -> Self {
        ResampleStream { ds, rng: Xoshiro256::new(seed), emitted: 0, take_limit }
    }
}

impl StreamSource for ResampleStream {
    fn next_example(&mut self) -> Option<Example> {
        if self.emitted >= self.take_limit || self.ds.is_empty() {
            return None;
        }
        self.emitted += 1;
        let i = self.rng.below(self.ds.len() as u64) as usize;
        Some(self.ds.augmented(i))
    }

    fn remaining_hint(&self) -> Option<usize> {
        Some(self.take_limit - self.emitted)
    }
}

/// Partition a dataset into per-device streams (contiguous shards), the
/// topology the paper's distributed setting implies: each device sees its
/// own locally-collected slice of the global dataset. The boxed streams
/// keep reporting `remaining_hint` (each shard knows its length), which
/// devices use to pre-size ingest buffers and split sync-round budgets.
pub fn partition_streams(ds: &Dataset, devices: usize, shuffled_seed: Option<u64>) -> Vec<Box<dyn StreamSource>> {
    ds.shards(devices)
        .into_iter()
        .enumerate()
        .map(|(i, shard)| -> Box<dyn StreamSource> {
            match shuffled_seed {
                Some(s) => Box::new(ShuffledStream::new(shard, s.wrapping_add(i as u64))),
                None => Box::new(ReplayStream::new(shard)),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;

    fn ds(n: usize) -> Dataset {
        let x = Matrix::from_fn(n, 2, |r, c| (r * 2 + c) as f64);
        let y = (0..n).map(|i| i as f64).collect();
        Dataset::new("s", x, y)
    }

    #[test]
    fn replay_yields_in_order_and_exhausts() {
        let mut s = ReplayStream::new(ds(3));
        assert_eq!(s.remaining_hint(), Some(3));
        assert_eq!(s.next_example().unwrap(), vec![0.0, 1.0, 0.0]);
        assert_eq!(s.next_example().unwrap(), vec![2.0, 3.0, 1.0]);
        assert_eq!(s.next_example().unwrap(), vec![4.0, 5.0, 2.0]);
        assert!(s.next_example().is_none());
    }

    #[test]
    fn shuffled_is_a_permutation() {
        let mut s = ShuffledStream::new(ds(10), 4);
        let mut ys: Vec<f64> = std::iter::from_fn(|| s.next_example())
            .map(|e| e[2])
            .collect();
        ys.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ys, (0..10).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn batch_pull_respects_size() {
        let mut s = ReplayStream::new(ds(5));
        assert_eq!(s.next_batch(2).len(), 2);
        assert_eq!(s.next_batch(10).len(), 3);
        assert!(s.next_batch(1).is_empty());
    }

    #[test]
    fn resample_bounded_and_from_support() {
        let mut s = ResampleStream::new(ds(4), 9, 100);
        let mut count = 0;
        while let Some(e) = s.next_example() {
            assert!(e[2] < 4.0);
            count += 1;
        }
        assert_eq!(count, 100);
    }

    #[test]
    fn partition_streams_preserve_remaining_hints() {
        // The hint must survive the Box<dyn StreamSource> indirection —
        // a forwarding gap here would silently return the default None.
        let d = ds(10);
        let mut streams = partition_streams(&d, 3, None);
        let hints: Vec<usize> = streams.iter().map(|s| s.remaining_hint().unwrap()).collect();
        assert_eq!(hints.iter().sum::<usize>(), 10);
        assert!(hints.iter().all(|&h| h >= 3));
        // And it ticks down as the stream drains.
        streams[0].next_example().unwrap();
        assert_eq!(streams[0].remaining_hint().unwrap(), hints[0] - 1);
        // Shuffled partitions report hints too.
        let shuffled = partition_streams(&d, 2, Some(9));
        assert!(shuffled.iter().all(|s| s.remaining_hint().is_some()));
    }

    #[test]
    fn next_batch_into_reuses_buffer_and_respects_hint() {
        let mut s = ReplayStream::new(ds(5));
        let mut buf = Vec::new();
        s.next_batch_into(2, &mut buf);
        assert_eq!(buf.len(), 2);
        let cap = buf.capacity();
        s.next_batch_into(2, &mut buf);
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.capacity(), cap, "no reallocation on reuse");
        // Asking for more than remains pulls only the tail.
        s.next_batch_into(10, &mut buf);
        assert_eq!(buf.len(), 1);
        s.next_batch_into(10, &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn boxed_stream_forwards_all_methods() {
        let mut b: Box<dyn StreamSource> = Box::new(ReplayStream::new(ds(4)));
        assert_eq!(b.remaining_hint(), Some(4));
        assert_eq!(b.next_batch(3).len(), 3);
        assert_eq!(b.remaining_hint(), Some(1));
        assert!(b.next_example().is_some());
        assert_eq!(b.remaining_hint(), Some(0));
    }

    #[test]
    fn partition_covers_dataset() {
        let d = ds(10);
        let mut streams = partition_streams(&d, 3, None);
        let total: usize = streams
            .iter_mut()
            .map(|s| {
                let mut c = 0;
                while s.next_example().is_some() {
                    c += 1;
                }
                c
            })
            .sum();
        assert_eq!(total, 10);
    }
}
