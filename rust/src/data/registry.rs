//! Dataset registry — the programmatic form of the paper's Table 1, plus
//! the synthetic 2-D sets of Figure 5. The experiment harness and CLI look
//! datasets up by name here.

use super::dataset::Dataset;
use super::synthetic;

/// A registry entry mirroring one row of Table 1.
#[derive(Clone, Debug)]
pub struct DatasetInfo {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    pub description: &'static str,
    /// True when this offline build substitutes a synthetic generator for
    /// the original UCI source (see DESIGN.md §5).
    pub synthetic_substitute: bool,
}

/// Table 1 of the paper (+ the Figure-5 synthetic sets).
pub const REGISTRY: &[DatasetInfo] = &[
    DatasetInfo {
        name: "airfoil",
        n: 1400,
        d: 9,
        description: "Airfoil parameters to predict sound level",
        synthetic_substitute: true,
    },
    DatasetInfo {
        name: "autos",
        n: 159,
        d: 26,
        description: "Automobile prices and information to predict acquisition risk",
        synthetic_substitute: true,
    },
    DatasetInfo {
        name: "parkinsons",
        n: 5800,
        d: 21,
        description: "Telemonitoring data from parkinsons patients, with disease progression",
        synthetic_substitute: true,
    },
    DatasetInfo {
        name: "synth2d-reg",
        n: 1000,
        d: 2,
        description: "2-D synthetic regression (Figure 5)",
        synthetic_substitute: false,
    },
    DatasetInfo {
        name: "synth2d-clf",
        n: 1000,
        d: 2,
        description: "2-D synthetic classification (Figure 5)",
        synthetic_substitute: false,
    },
];

/// Look up registry metadata by name.
pub fn info(name: &str) -> Option<&'static DatasetInfo> {
    REGISTRY.iter().find(|i| i.name == name)
}

/// Instantiate a dataset by registry name. Unknown names return `None`.
pub fn load(name: &str, seed: u64) -> Option<Dataset> {
    match name {
        "airfoil" => Some(synthetic::airfoil(seed)),
        "autos" => Some(synthetic::autos(seed)),
        "parkinsons" => Some(synthetic::parkinsons(seed)),
        "synth2d-reg" => Some(synthetic::synth2d_regression(1000, 0.8, 0.1, 0.05, seed)),
        "synth2d-clf" => Some(synthetic::synth2d_classification(1000, 0.8, 0.25, seed)),
        _ => None,
    }
}

/// Names of the three Table-1 regression datasets used by Figure 4.
pub const TABLE1_NAMES: &[&str] = &["airfoil", "autos", "parkinsons"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_generators() {
        for name in TABLE1_NAMES {
            let meta = info(name).unwrap();
            let ds = load(name, 1).unwrap();
            assert_eq!(ds.len(), meta.n, "{name} n");
            assert_eq!(ds.dim(), meta.d, "{name} d");
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(info("nope").is_none());
        assert!(load("nope", 1).is_none());
    }

    #[test]
    fn synthetic_sets_load() {
        assert_eq!(load("synth2d-reg", 2).unwrap().dim(), 2);
        assert_eq!(load("synth2d-clf", 2).unwrap().dim(), 2);
    }
}
