//! Minimal CSV loading so real UCI files can be dropped in for the
//! experiments when available (the synthetic generators are the default in
//! this offline environment).

use super::dataset::Dataset;
use crate::linalg::matrix::Matrix;
use std::io::BufRead;
use std::path::Path;

/// CSV parse errors.
#[derive(Debug, thiserror::Error)]
pub enum CsvError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("empty file")]
    Empty,
    #[error("row {row} has {got} fields, expected {want}")]
    Ragged { row: usize, got: usize, want: usize },
    #[error("row {row}, column {col}: cannot parse {value:?} as f64")]
    BadNumber { row: usize, col: usize, value: String },
    #[error("need at least 2 columns (features + target), got {0}")]
    TooNarrow(usize),
}

/// Parse CSV text into a dataset. The **last column** is the target; all
/// preceding columns are features. A non-numeric first line is treated as
/// a header and skipped. Blank lines are ignored.
pub fn parse_csv(name: &str, text: &str) -> Result<Dataset, CsvError> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut width: Option<usize> = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let parsed: Result<Vec<f64>, usize> = fields
            .iter()
            .enumerate()
            .map(|(i, f)| f.parse::<f64>().map_err(|_| i))
            .collect();
        match parsed {
            Ok(vals) => {
                if let Some(w) = width {
                    if vals.len() != w {
                        return Err(CsvError::Ragged { row: lineno, got: vals.len(), want: w });
                    }
                } else {
                    width = Some(vals.len());
                }
                rows.push(vals);
            }
            Err(col) => {
                // Header line is only tolerated before any data rows.
                if rows.is_empty() && width.is_none() {
                    continue;
                }
                return Err(CsvError::BadNumber {
                    row: lineno,
                    col,
                    value: fields.get(col).unwrap_or(&"").to_string(),
                });
            }
        }
    }
    let w = width.ok_or(CsvError::Empty)?;
    if w < 2 {
        return Err(CsvError::TooNarrow(w));
    }
    let n = rows.len();
    let d = w - 1;
    let mut x = Matrix::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for (r, vals) in rows.into_iter().enumerate() {
        x.row_mut(r).copy_from_slice(&vals[..d]);
        y.push(vals[d]);
    }
    Ok(Dataset::new(name, x, y))
}

/// Load a CSV file from disk.
pub fn load_csv(path: impl AsRef<Path>) -> Result<Dataset, CsvError> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)?;
    let mut text = String::new();
    for line in std::io::BufReader::new(file).lines() {
        text.push_str(&line?);
        text.push('\n');
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "csv".to_string());
    parse_csv(&name, &text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_plain_csv() {
        let ds = parse_csv("t", "1,2,3\n4,5,6\n").unwrap();
        assert_eq!(ds.x.shape(), (2, 2));
        assert_eq!(ds.y, vec![3.0, 6.0]);
    }

    #[test]
    fn skips_header_and_blank_lines() {
        let ds = parse_csv("t", "a,b,target\n\n1,2,3\n").unwrap();
        assert_eq!(ds.len(), 1);
        assert_eq!(ds.y, vec![3.0]);
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(matches!(
            parse_csv("t", "1,2,3\n4,5\n"),
            Err(CsvError::Ragged { row: 1, got: 2, want: 3 })
        ));
    }

    #[test]
    fn rejects_mid_file_text() {
        assert!(matches!(
            parse_csv("t", "1,2,3\nx,5,6\n"),
            Err(CsvError::BadNumber { row: 1, col: 0, .. })
        ));
    }

    #[test]
    fn rejects_empty_and_narrow() {
        assert!(matches!(parse_csv("t", ""), Err(CsvError::Empty)));
        assert!(matches!(parse_csv("t", "1\n2\n"), Err(CsvError::TooNarrow(1))));
    }

    #[test]
    fn loads_from_disk() {
        let dir = std::env::temp_dir().join("storm_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("toy.csv");
        std::fs::write(&p, "f1,f2,y\n1,0,2\n0,1,3\n").unwrap();
        let ds = load_csv(&p).unwrap();
        assert_eq!(ds.name, "toy");
        assert_eq!(ds.len(), 2);
    }
}
