//! In-memory regression/classification dataset representation.

use crate::linalg::matrix::Matrix;
use crate::util::rng::Rng;

/// A supervised dataset: feature matrix `X` (`n x d`) and targets `y`.
///
/// For STORM, examples are sketched as the concatenated vector `[x, y]`
/// ([`Dataset::augmented`]), following the paper's formulation of the
/// least-squares loss through `<[theta, -1], [x, y]>`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Matrix,
    pub y: Vec<f64>,
    /// Scale factor applied by unit-ball normalization (see `scale.rs`);
    /// 1.0 when unscaled. Kept so losses can be reported in original units.
    pub scale_factor: f64,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: Matrix, y: Vec<f64>) -> Self {
        assert_eq!(x.rows(), y.len(), "X rows must match y length");
        Dataset { name: name.into(), x, y, scale_factor: 1.0 }
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature dimensionality `d`.
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Iterate `(x_i, y_i)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (&[f64], f64)> + '_ {
        (0..self.len()).map(move |i| (self.x.row(i), self.y[i]))
    }

    /// The augmented example `z_i = [x_i, y_i]` the sketch ingests.
    pub fn augmented(&self, i: usize) -> Vec<f64> {
        let mut z = self.x.row(i).to_vec();
        z.push(self.y[i]);
        z
    }

    /// Full augmented matrix `[X | y]` (`n x (d+1)`).
    pub fn augmented_matrix(&self) -> Matrix {
        let (n, d) = self.x.shape();
        Matrix::from_fn(n, d + 1, |r, c| {
            if c < d {
                self.x[(r, c)]
            } else {
                self.y[r]
            }
        })
    }

    /// Random train/test split: `frac` of rows go to train.
    pub fn split(&self, frac: f64, rng: &mut impl Rng) -> (Dataset, Dataset) {
        let n = self.len();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let k = ((n as f64) * frac).round() as usize;
        let (tr, te) = idx.split_at(k.min(n));
        (self.subset(tr, "train"), self.subset(te, "test"))
    }

    /// Extract a row subset.
    pub fn subset(&self, idx: &[usize], suffix: &str) -> Dataset {
        Dataset {
            name: format!("{}/{}", self.name, suffix),
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            scale_factor: self.scale_factor,
        }
    }

    /// Split the dataset into `k` contiguous shards (for distributing over
    /// edge devices). Shard sizes differ by at most one.
    pub fn shards(&self, k: usize) -> Vec<Dataset> {
        assert!(k > 0);
        let n = self.len();
        let base = n / k;
        let extra = n % k;
        let mut out = Vec::with_capacity(k);
        let mut start = 0;
        for s in 0..k {
            let len = base + usize::from(s < extra);
            let idx: Vec<usize> = (start..start + len).collect();
            out.push(self.subset(&idx, &format!("shard{s}")));
            start += len;
        }
        out
    }

    /// In-memory size of the raw data in bytes (f64 storage), used as the
    /// "full dataset" reference point on the Figure 4 memory axis.
    pub fn raw_bytes(&self) -> usize {
        (self.x.rows() * self.x.cols() + self.y.len()) * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn toy() -> Dataset {
        let x = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        Dataset::new("toy", x, vec![10.0, 20.0, 30.0])
    }

    #[test]
    fn basic_accessors() {
        let d = toy();
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.augmented(1), vec![3.0, 4.0, 20.0]);
    }

    #[test]
    fn augmented_matrix_layout() {
        let d = toy();
        let a = d.augmented_matrix();
        assert_eq!(a.shape(), (3, 3));
        assert_eq!(a.row(0), &[1.0, 2.0, 10.0]);
        assert_eq!(a.row(2), &[5.0, 6.0, 30.0]);
    }

    #[test]
    fn split_partitions_rows() {
        let d = toy();
        let mut rng = Xoshiro256::new(5);
        let (tr, te) = d.split(2.0 / 3.0, &mut rng);
        assert_eq!(tr.len(), 2);
        assert_eq!(te.len(), 1);
    }

    #[test]
    fn shards_cover_everything() {
        let d = toy();
        let shards = d.shards(2);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 3);
        assert_eq!(shards[0].len(), 2); // extra row goes to shard 0
    }

    #[test]
    fn raw_bytes_counts_f64s() {
        let d = toy();
        assert_eq!(d.raw_bytes(), (3 * 2 + 3) * 8);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        let x = Matrix::zeros(2, 2);
        let _ = Dataset::new("bad", x, vec![1.0]);
    }
}
