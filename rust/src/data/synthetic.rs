//! Deterministic synthetic dataset generators.
//!
//! The three UCI datasets of Table 1 are substituted with generators that
//! match each dataset's size, dimensionality, and a qualitative
//! conditioning profile (see DESIGN.md §5). Each is a planted linear model
//! `y = <x, theta*> + eps` over correlated, anisotropic features, so the
//! least-squares optimum is known up to noise and the paper's claims
//! (convergence of the STORM minimizer to the LS minimizer, double descent
//! of sampling baselines at n ~ d) are exercised faithfully.

use super::dataset::Dataset;
use crate::linalg::matrix::Matrix;
use crate::util::rng::{Rng, Xoshiro256};

/// Specification of a planted regression problem.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    /// Feature covariance decay: eigenvalue_i ∝ decay^i. 1.0 = isotropic,
    /// smaller = more anisotropic (worse conditioning), mimicking the
    /// correlated physical measurements of the UCI sets.
    pub spectrum_decay: f64,
    /// Fraction of heavy-tailed (Laplace) feature directions, mimicking
    /// skewed sensor channels.
    pub heavy_frac: f64,
    /// Label noise standard deviation relative to signal.
    pub noise: f64,
}

/// Table-1 substitute: airfoil self-noise (1.4k x 9 after one-hot-ish
/// expansion in the paper's setup; modest conditioning, low noise).
pub const AIRFOIL: SyntheticSpec = SyntheticSpec {
    name: "airfoil",
    n: 1400,
    d: 9,
    spectrum_decay: 0.7,
    heavy_frac: 0.2,
    noise: 0.05,
};

/// Table-1 substitute: automobile acquisition risk (159 x 26 — the small-N,
/// relatively high-d set that puts the sampling baselines in the
/// double-descent danger zone).
pub const AUTOS: SyntheticSpec = SyntheticSpec {
    name: "autos",
    n: 159,
    d: 26,
    spectrum_decay: 0.8,
    heavy_frac: 0.35,
    noise: 0.1,
};

/// Table-1 substitute: parkinsons telemonitoring (5.8k x 21; larger N,
/// correlated biomedical channels).
pub const PARKINSONS: SyntheticSpec = SyntheticSpec {
    name: "parkinsons",
    n: 5800,
    d: 21,
    spectrum_decay: 0.65,
    heavy_frac: 0.25,
    noise: 0.08,
};

/// Generate a dataset from a spec, deterministically per seed.
pub fn generate(spec: &SyntheticSpec, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed ^ fnv(spec.name));
    let d = spec.d;
    // Random orthogonal-ish mixing matrix (gaussian, then QR would be
    // ideal; a scaled gaussian mix suffices for conditioning control).
    let mix = Matrix::gaussian(d, d, &mut rng);
    // Anisotropic spectrum.
    let scales: Vec<f64> = (0..d).map(|i| spec.spectrum_decay.powi(i as i32)).collect();
    let n_heavy = ((d as f64) * spec.heavy_frac).round() as usize;

    let mut x = Matrix::zeros(spec.n, d);
    let mut latent = vec![0.0; d];
    for r in 0..spec.n {
        for (j, l) in latent.iter_mut().enumerate() {
            let raw = if j < n_heavy { rng.laplace(std::f64::consts::FRAC_1_SQRT_2) } else { rng.gaussian() };
            *l = raw * scales[j];
        }
        let row = mix.matvec(&latent);
        x.row_mut(r).copy_from_slice(&row);
    }
    // Planted model with entries in [-1, 1].
    let theta: Vec<f64> = (0..d).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
    let signal = x.matvec(&theta);
    let sig_std = crate::util::mathx::variance(&signal).sqrt().max(1e-9);
    let y: Vec<f64> = signal
        .iter()
        .map(|s| s + rng.gaussian() * spec.noise * sig_std)
        .collect();
    Dataset::new(spec.name, x, y)
}

/// Convenience constructors for the Table-1 trio.
pub fn airfoil(seed: u64) -> Dataset {
    generate(&AIRFOIL, seed)
}
pub fn autos(seed: u64) -> Dataset {
    generate(&AUTOS, seed)
}
pub fn parkinsons(seed: u64) -> Dataset {
    generate(&PARKINSONS, seed)
}

/// 2-D synthetic regression data for Figure 5: points spread along a line
/// with gaussian perpendicular jitter.
pub fn synth2d_regression(n: usize, slope: f64, intercept: f64, noise: f64, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed);
    let mut x = Matrix::zeros(n, 2);
    let mut y = Vec::with_capacity(n);
    for r in 0..n {
        let t = rng.uniform_range(-1.0, 1.0);
        x[(r, 0)] = t;
        x[(r, 1)] = 1.0; // bias column so the model learns the intercept
        y.push(slope * t + intercept + rng.gaussian() * noise);
    }
    Dataset::new("synth2d-reg", x, y)
}

/// Non-stationary 2-D regression stream: the planted slope jumps from
/// `slope_a` to `slope_b` at example `shift_at`, in stream order — the
/// drift benchmark for exponentially-decayed leader counters. Rows are
/// emitted in time order, so round r of an R-round sync covers the
/// stream slice `[r*n/R, (r+1)*n/R)` and the shift lands mid-run.
pub fn synth2d_drift(
    n: usize,
    slope_a: f64,
    slope_b: f64,
    shift_at: usize,
    noise: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Xoshiro256::new(seed ^ 0xD81F);
    let mut x = Matrix::zeros(n, 2);
    let mut y = Vec::with_capacity(n);
    for r in 0..n {
        let slope = if r < shift_at { slope_a } else { slope_b };
        let t = rng.uniform_range(-1.0, 1.0);
        x[(r, 0)] = t;
        x[(r, 1)] = 1.0;
        y.push(slope * t + rng.gaussian() * noise);
    }
    Dataset::new("synth2d-drift", x, y)
}

/// 2-D synthetic binary classification for Figure 5: two gaussian blobs
/// with labels in {-1, +1}, separated along a random direction.
pub fn synth2d_classification(n: usize, margin: f64, noise: f64, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed);
    let angle = rng.uniform_range(0.0, std::f64::consts::PI);
    let dir = [angle.cos(), angle.sin()];
    let mut x = Matrix::zeros(n, 2);
    let mut y = Vec::with_capacity(n);
    for r in 0..n {
        let label = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        let c = [dir[0] * margin * label, dir[1] * margin * label];
        x[(r, 0)] = c[0] + rng.gaussian() * noise;
        x[(r, 1)] = c[1] + rng.gaussian() * noise;
        y.push(label);
    }
    Dataset::new("synth2d-clf", x, y)
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solve::{lstsq, mse, LstsqMethod};

    #[test]
    fn table1_shapes_match_paper() {
        assert_eq!(airfoil(1).x.shape(), (1400, 9));
        assert_eq!(autos(1).x.shape(), (159, 26));
        assert_eq!(parkinsons(1).x.shape(), (5800, 21));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = airfoil(7);
        let b = airfoil(7);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y, b.y);
        let c = airfoil(8);
        assert_ne!(a.x.data(), c.x.data());
    }

    #[test]
    fn planted_model_is_learnable() {
        // The least-squares fit should explain most variance (low noise).
        for ds in [airfoil(3), autos(3), parkinsons(3)] {
            let theta = lstsq(&ds.x, &ds.y, 0.0, LstsqMethod::Qr);
            let fit_mse = mse(&ds.x, &ds.y, &theta);
            let var_y = crate::util::mathx::variance(&ds.y);
            assert!(
                fit_mse < 0.1 * var_y,
                "{}: mse {fit_mse} not << var {var_y}",
                ds.name
            );
        }
    }

    #[test]
    fn synth2d_regression_recovers_line() {
        let ds = synth2d_regression(500, 0.8, 0.1, 0.01, 9);
        let theta = lstsq(&ds.x, &ds.y, 0.0, LstsqMethod::Qr);
        assert!((theta[0] - 0.8).abs() < 0.02, "slope={}", theta[0]);
        assert!((theta[1] - 0.1).abs() < 0.02, "intercept={}", theta[1]);
    }

    #[test]
    fn synth2d_drift_plants_two_regimes() {
        let n = 800;
        let ds = synth2d_drift(n, 0.8, -0.8, n / 2, 0.01, 9);
        assert_eq!(ds.x.shape(), (n, 2));
        // LS on each half recovers its own slope; the halves disagree.
        let half = |lo: usize, hi: usize| {
            let sub = ds.subset(&(lo..hi).collect::<Vec<_>>(), "drift-half");
            lstsq(&sub.x, &sub.y, 0.0, LstsqMethod::Qr)
        };
        let pre = half(0, n / 2);
        let post = half(n / 2, n);
        assert!((pre[0] - 0.8).abs() < 0.05, "pre slope {}", pre[0]);
        assert!((post[0] + 0.8).abs() < 0.05, "post slope {}", post[0]);
        // Deterministic per seed.
        let again = synth2d_drift(n, 0.8, -0.8, n / 2, 0.01, 9);
        assert_eq!(ds.y, again.y);
    }

    #[test]
    fn synth2d_classification_is_separable() {
        let ds = synth2d_classification(400, 1.0, 0.2, 10);
        // Labels balanced-ish and in {-1, 1}.
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count();
        assert!(pos > 100 && pos < 300);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
        // A linear probe (LS on labels) should classify well.
        let theta = lstsq(&ds.x, &ds.y, 1e-6, LstsqMethod::NormalEquations);
        let correct = ds
            .iter()
            .filter(|(x, y)| (crate::util::mathx::dot(x, &theta) * y) > 0.0)
            .count();
        assert!(correct as f64 > 0.95 * ds.len() as f64, "acc={}", correct);
    }

    #[test]
    fn heavy_tail_fraction_changes_distribution() {
        // Sanity: autos (heavy 0.35) should have larger kurtosis in raw
        // latent mix than a pure gaussian set of the same size would.
        let ds = autos(5);
        let flat: Vec<f64> = ds.x.data().to_vec();
        let m = crate::util::mathx::mean(&flat);
        let var = crate::util::mathx::variance(&flat);
        let kurt = flat.iter().map(|v| (v - m).powi(4)).sum::<f64>() / (flat.len() as f64 * var * var);
        assert!(kurt > 2.5, "kurtosis={kurt}");
    }
}
