//! Non-stationary stream experiment: when the data distribution shifts
//! mid-run, the cumulative leader sketch keeps estimating risk against a
//! mixture of the old and new regimes, while a leader that exponentially
//! decays its counters at each round boundary (`[privacy] decay_keep`)
//! tracks the current regime. The benchmark plants a theta flip halfway
//! through a `synth2d_drift` stream and compares post-shift risk of the
//! model trained from each sketch — the decayed sketch must win.

use super::Effort;
use crate::config::{OptimizerConfig, StormConfig};
use crate::data::scale::scale_to_unit_ball;
use crate::data::synthetic;
use crate::linalg::solve::mse;
use crate::metrics::export::Table;
use crate::optim::dfo::DfoOptimizer;
use crate::sketch::storm::StormSketch;

/// Keep fractions (per-mille) the sweep compares against the cumulative
/// (keep = 1000) leader.
const KEEPS: [u16; 2] = [700, 400];

pub fn run(effort: Effort, seed: u64) -> Table {
    let (n, rounds) = match effort {
        Effort::Fast => (1200usize, 6usize),
        Effort::Full => (4000, 10),
    };
    let storm = StormConfig { rows: 400, power: 4, saturating: true, ..Default::default() };
    let mut table = Table::new(
        "drift: post-shift MSE, decayed vs cumulative leader counters (theta flips mid-stream)",
        &["run", "keep_permille", "mse_cumulative", "mse_decayed", "decayed_wins"],
    );
    for run in 0..effort.runs() {
        let run_seed = seed.wrapping_add(run as u64);
        let mut ds = synthetic::synth2d_drift(n, 0.8, -0.8, n / 2, 0.02, run_seed);
        scale_to_unit_ball(&mut ds, 0.9);
        // Post-shift slice in scaled space: the regime the anytime model
        // should be tracking when the run ends.
        let post = ds.subset(&(n / 2..n).collect::<Vec<_>>(), "drift-post");
        let family_seed = run_seed ^ 0xD81F7;
        let per_round = n / rounds;
        let train_theta = |sk: &StormSketch, opt_seed: u64| {
            let ocfg = OptimizerConfig {
                queries: 8,
                sigma: 0.3,
                step: 0.6,
                iters: effort.dfo_iters(),
                seed: opt_seed,
            };
            DfoOptimizer::new(ocfg, ds.dim()).run(sk, effort.dfo_iters())
        };
        // Cumulative leader: every round folds, nothing fades.
        let mut cumulative = StormSketch::new(storm, ds.dim() + 1, family_seed);
        for i in 0..n {
            cumulative.insert(&ds.augmented(i));
        }
        let mse_cum = mse(&post.x, &post.y, &train_theta(&cumulative, run_seed ^ 1));
        for &keep in &KEEPS {
            // Decayed leader: fade the past, then fold the round's delta
            // — exactly the LeaderMachine round-close semantics. Round r
            // covers the time-ordered stream slice [r*n/R, (r+1)*n/R).
            let mut decayed = StormSketch::new(storm, ds.dim() + 1, family_seed);
            for r in 0..rounds {
                decayed.decay(keep);
                let lo = r * per_round;
                let hi = if r + 1 == rounds { n } else { lo + per_round };
                for i in lo..hi {
                    decayed.insert(&ds.augmented(i));
                }
            }
            let mse_dec = mse(&post.x, &post.y, &train_theta(&decayed, run_seed ^ 2));
            table.push(vec![
                run as f64,
                keep as f64,
                mse_cum,
                mse_dec,
                f64::from(u8::from(mse_dec < mse_cum)),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    #[test]
    fn decayed_sketch_beats_cumulative_after_the_shift() {
        let t = super::run(super::Effort::Fast, 11);
        assert!(!t.rows.is_empty());
        // Averaged over runs, every keep level must beat the cumulative
        // sketch on post-shift risk — the headline drift claim.
        for keep in super::KEEPS {
            let rows: Vec<_> = t.rows.iter().filter(|r| r[1] == keep as f64).collect();
            assert!(!rows.is_empty(), "keep={keep} missing from the sweep");
            let cum: f64 = rows.iter().map(|r| r[2]).sum::<f64>() / rows.len() as f64;
            let dec: f64 = rows.iter().map(|r| r[3]).sum::<f64>() / rows.len() as f64;
            assert!(dec < cum, "keep={keep}: decayed {dec} not better than cumulative {cum}");
        }
    }
}
