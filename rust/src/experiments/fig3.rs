//! Figure 3: (a) the PRP surrogate loss for different p, with a
//! sketch-estimated overlay; (b) the slope at `t = 0.1` as a function of p
//! — the paper's argument that p = 4 maximizes local curvature.

use crate::config::StormConfig;
use crate::loss::prp_loss::{prp_slope_at, prp_surrogate};
use crate::metrics::export::Table;
use crate::sketch::storm::StormSketch;

pub const POWERS: &[u32] = &[1, 2, 4, 8, 16];

/// Figure 3a: loss curves over `t` in (-1, 1), closed form for each p,
/// plus a STORM-estimated curve at p = 4 (R = 500) demonstrating that the
/// sketch reproduces the analytic surrogate.
pub fn run_fig3a(seed: u64) -> Table {
    let mut cols: Vec<String> = vec!["t".to_string()];
    for p in POWERS {
        cols.push(format!("g_p{p}"));
    }
    cols.push("sketch_p4".to_string());
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("fig3a: PRP surrogate loss vs t", &col_refs);

    // One data point z on the first axis: then <theta~, z> = t is swept by
    // moving the query along the same axis. (The surrogate is a function
    // of t only, so a single example suffices and makes the sketch overlay
    // exact in expectation.)
    let dim = 2;
    let cfg = StormConfig { rows: 500, power: 4, saturating: true, ..Default::default() };
    let mut sk = StormSketch::new(cfg, dim, seed);
    let z = vec![0.95, 0.0];
    sk.insert(&z);

    // Sweep |t| <= 0.9 so the matching query q = t/z0 stays inside the
    // unit ball the asymmetric hash requires.
    let steps = 81;
    for i in 0..steps {
        let t = -0.9 + 1.8 * i as f64 / (steps - 1) as f64;
        let mut row = vec![t];
        for &p in POWERS {
            row.push(prp_surrogate(t, p));
        }
        // Query whose inner product with z is exactly t.
        let q = vec![t / z[0], 0.0];
        row.push(sk.estimate_risk(&q));
        table.push(row);
    }
    table
}

/// Figure 3b: |dg/dt| at t = 0.1 for p = 1..16.
pub fn run_fig3b() -> Table {
    let mut table = Table::new("fig3b: surrogate slope at t=0.1 vs p", &["p", "slope"]);
    for p in 1..=16u32 {
        table.push(vec![p as f64, prp_slope_at(0.1, p)]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3a_sketch_tracks_closed_form() {
        let t = run_fig3a(3);
        assert_eq!(t.rows.len(), 81);
        // Column 3 is g_p4 (t, p1, p2, p4, ...), column 6 the sketch
        // estimate; they must agree within sketch noise (R = 500 -> ~5%).
        let mut max_err: f64 = 0.0;
        for row in &t.rows {
            max_err = max_err.max((row[3] - row[6]).abs());
        }
        assert!(max_err < 0.08, "max_err={max_err}");
    }

    #[test]
    fn fig3a_curves_ordered_at_large_t() {
        // At t -> 1, larger p has larger g? No: all approach 1/2 f^p ->
        // 0.5. At moderate t, smaller p is larger. Check p1 >= p16 at 0.5.
        let t = run_fig3a(5);
        let row = t
            .rows
            .iter()
            .min_by(|a, b| ((a[0] - 0.5).abs()).partial_cmp(&(b[0] - 0.5).abs()).unwrap())
            .unwrap();
        assert!(row[1] >= row[5], "p1 {} vs p16 {}", row[1], row[5]);
    }

    #[test]
    fn fig3b_peaks_at_p4() {
        let t = run_fig3b();
        let best = t
            .rows
            .iter()
            .max_by(|a, b| a[1].partial_cmp(&b[1]).unwrap())
            .unwrap();
        assert_eq!(best[0], 4.0, "slope table: {:?}", t.rows);
    }
}
