//! Energy experiment (Broader Impacts): transmit-energy comparison of
//! shipping STORM sketches vs shipping raw examples, across stream sizes
//! — at every counter width. Narrow tiers shrink the dense flush frame
//! (width-true wire accounting via `serialize::delta_wire_bytes`), so a
//! `u8` device pays ~a quarter of the `u32` transmit energy per busy
//! flush on top of the raw-vs-sketch win.

use crate::config::{CounterWidth, StormConfig};
use crate::edge::energy::EnergyModel;
use crate::metrics::export::Table;
use crate::sketch::serialize::delta_wire_bytes;

pub fn run() -> Table {
    let model = EnergyModel::default();
    let d = 21usize; // parkinsons-like feature width
    let flush_every = 256u64; // examples per delta flush
    let mut table = Table::new(
        "energy: raw-vs-sketch transmit energy (J) vs stream size, per counter width",
        &["examples", "width_bytes", "raw_joules", "storm_joules", "savings_ratio"],
    );
    for width in [CounterWidth::U8, CounterWidth::U16, CounterWidth::U32] {
        let cfg = StormConfig {
            rows: 100,
            power: 4,
            saturating: true,
            counter_width: width,
            ..Default::default()
        };
        for exp in [3u32, 4, 5, 6, 7] {
            let n = 10u64.pow(exp);
            let raw_bytes = n * (d as u64 + 1) * 8;
            let flushes = n.div_ceil(flush_every);
            let sketch_bytes = flushes * delta_wire_bytes(&cfg) as u64;
            let raw = model.raw_energy(raw_bytes).total();
            let storm = model.storm_energy(n, sketch_bytes).total();
            table.push(vec![n as f64, width.bytes() as f64, raw, storm, raw / storm]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    #[test]
    fn savings_grow_with_stream_size_at_every_width() {
        let t = super::run();
        for width_bytes in [1.0, 2.0, 4.0] {
            let ratios: Vec<f64> = t
                .rows
                .iter()
                .filter(|r| r[1] == width_bytes)
                .map(|r| r[4])
                .collect();
            assert_eq!(ratios.len(), 5);
            assert!(ratios.windows(2).all(|w| w[1] >= w[0] * 0.99), "{ratios:?}");
            assert!(
                *ratios.last().unwrap() > 5.0,
                "large streams should favor sketching: {ratios:?}"
            );
        }
    }

    #[test]
    fn narrow_widths_cost_less_transmit_energy() {
        // Same stream size, same flush cadence: the u8 tier's flush frame
        // is ~a quarter of the u32 frame, so its total energy is lower.
        let t = super::run();
        let storm_at = |wb: f64| -> f64 {
            t.rows
                .iter()
                .find(|r| r[1] == wb && r[0] == 1e6)
                .map(|r| r[3])
                .unwrap()
        };
        assert!(storm_at(1.0) < storm_at(2.0));
        assert!(storm_at(2.0) < storm_at(4.0));
    }
}
