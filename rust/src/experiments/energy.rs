//! Energy experiment (Broader Impacts): transmit-energy comparison of
//! shipping STORM sketches vs shipping raw examples, across stream sizes.

use crate::config::StormConfig;
use crate::edge::energy::EnergyModel;
use crate::metrics::export::Table;
use crate::sketch::serialize::wire_bytes;

pub fn run() -> Table {
    let model = EnergyModel::default();
    let cfg = StormConfig { rows: 100, power: 4, saturating: true };
    let d = 21usize; // parkinsons-like feature width
    let flush_every = 256u64; // examples per delta flush
    let mut table = Table::new(
        "energy: raw-vs-sketch transmit energy (J) vs stream size",
        &["examples", "raw_joules", "storm_joules", "savings_ratio"],
    );
    for exp in [3u32, 4, 5, 6, 7] {
        let n = 10u64.pow(exp);
        let raw_bytes = n * (d as u64 + 1) * 8;
        let flushes = n.div_ceil(flush_every);
        let sketch_bytes = flushes * wire_bytes(&cfg) as u64;
        let raw = model.raw_energy(raw_bytes).total();
        let storm = model.storm_energy(n, sketch_bytes).total();
        table.push(vec![n as f64, raw, storm, raw / storm]);
    }
    table
}

#[cfg(test)]
mod tests {
    #[test]
    fn savings_grow_with_stream_size() {
        let t = super::run();
        let ratios: Vec<f64> = t.rows.iter().map(|r| r[3]).collect();
        assert!(ratios.windows(2).all(|w| w[1] >= w[0] * 0.99), "{ratios:?}");
        assert!(
            *ratios.last().unwrap() > 5.0,
            "large streams should favor sketching: {ratios:?}"
        );
    }
}
