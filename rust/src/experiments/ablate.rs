//! Ablations over the design choices DESIGN.md calls out:
//!
//! * probe strategy: antithetic pairs (ours) vs SPSA at matched budget;
//! * iterate selection: tail averaging (ours) vs final iterate;
//! * data scaling: quantile-0.9 (ours) vs max-norm;
//! * hash power p: 2 / 4 / 8 at fixed memory (paper fixes p = 4).
//!
//! Each row reports mean training MSE over `Effort::runs()` independent
//! sketches on the airfoil substitute — the same protocol as Figure 4.

use super::Effort;
use crate::config::{OptimizerConfig, StormConfig};
use crate::data::registry;
use crate::data::scale::{scale_to_unit_ball, scale_to_unit_ball_quantile};
use crate::linalg::solve::mse;
use crate::metrics::export::Table;
use crate::optim::dfo::DfoOptimizer;
use crate::optim::spsa::{spsa, SpsaConfig};
use crate::sketch::storm::StormSketch;

fn build_sketch(ds: &crate::data::dataset::Dataset, rows: usize, power: u32, seed: u64) -> StormSketch {
    let cfg = StormConfig { rows, power, saturating: true, ..Default::default() };
    let mut sk = StormSketch::new(cfg, ds.dim() + 1, seed);
    for i in 0..ds.len() {
        sk.insert(&ds.augmented(i));
    }
    sk
}

pub fn run(effort: Effort, seed: u64) -> Table {
    let runs = effort.runs();
    let iters = effort.dfo_iters();
    let mut table = Table::new(
        format!("ablate: design choices on airfoil (mean MSE of {runs} runs; lower is better)"),
        &["variant", "mse"],
    );
    // Variant ids: 0 = ours (antithetic DFO + tail avg + quantile scale,
    // p=4); 1 = SPSA; 2 = final iterate; 3 = max-norm scaling; 4 = p=2;
    // 5 = p=8 (memory-matched: rows scaled to keep bytes constant).
    let mut acc = [0.0f64; 6];
    for r in 0..runs {
        let s = seed + r as u64 * 101;
        let mut ds_q = registry::load("airfoil", s).unwrap();
        scale_to_unit_ball_quantile(&mut ds_q, 0.9, 0.9);
        let mut ds_m = registry::load("airfoil", s).unwrap();
        scale_to_unit_ball(&mut ds_m, 0.9);
        let d = ds_q.dim();
        let ocfg = OptimizerConfig { queries: 8, sigma: 0.3, step: 0.6, iters, seed: s ^ 7 };

        // 0: ours.
        let sk = build_sketch(&ds_q, 1000, 4, s);
        let theta = DfoOptimizer::new(ocfg, d).run(&sk, iters);
        acc[0] += mse(&ds_q.x, &ds_q.y, &theta).min(1e6);

        // 1: SPSA at the same total query budget (iters * 9 queries / 2).
        let spsa_iters = iters * 9 / 2;
        let theta = spsa(&sk, SpsaConfig { c: 0.3, a: 0.3, iters: spsa_iters, seed: s ^ 7 });
        acc[1] += mse(&ds_q.x, &ds_q.y, &theta).min(1e6);

        // 2: final iterate instead of tail average.
        let mut opt = DfoOptimizer::new(ocfg, d);
        for _ in 0..iters {
            opt.step(&sk);
        }
        acc[2] += mse(&ds_q.x, &ds_q.y, opt.theta()).min(1e6);

        // 3: max-norm scaling.
        let sk_m = build_sketch(&ds_m, 1000, 4, s);
        let theta = DfoOptimizer::new(ocfg, d).run(&sk_m, iters);
        acc[3] += mse(&ds_m.x, &ds_m.y, &theta).min(1e6);

        // 4/5: p = 2 (rows x4 for equal bytes), p = 8 (rows / 16).
        let sk2 = build_sketch(&ds_q, 4000, 2, s);
        let theta = DfoOptimizer::new(ocfg, d).run(&sk2, iters);
        acc[4] += mse(&ds_q.x, &ds_q.y, &theta).min(1e6);
        let sk8 = build_sketch(&ds_q, 63, 8, s);
        let theta = DfoOptimizer::new(ocfg, d).run(&sk8, iters);
        acc[5] += mse(&ds_q.x, &ds_q.y, &theta).min(1e6);
    }
    for (i, a) in acc.iter().enumerate() {
        table.push(vec![i as f64, a / runs as f64]);
    }
    table
}

#[cfg(test)]
mod tests {
    #[test]
    fn ablation_runs_and_ours_is_competitive() {
        let t = super::run(super::Effort::Fast, 3);
        assert_eq!(t.rows.len(), 6);
        let ours = t.rows[0][1];
        assert!(ours.is_finite() && ours > 0.0);
        // Ours should not be the worst variant.
        let worst = t.rows.iter().map(|r| r[1]).fold(0.0f64, f64::max);
        assert!(ours < worst, "ours={ours} worst={worst}");
    }
}
