//! Figure 6 (appendix): the STORM margin loss next to the classical
//! classification losses — hinge, squared hinge, logistic, zero-one —
//! over the margin t in [-1, 1].

use crate::loss::margin::margin_loss;
use crate::loss::reference;
use crate::metrics::export::Table;

pub fn run() -> Table {
    let mut table = Table::new(
        "fig6: classification losses vs margin t",
        &["t", "storm_p1", "storm_p2", "storm_p4", "hinge", "sq_hinge", "logistic", "zero_one"],
    );
    let steps = 81;
    for i in 0..steps {
        let t = -1.0 + 2.0 * i as f64 / (steps - 1) as f64;
        table.push(vec![
            t,
            margin_loss(t, 1),
            margin_loss(t, 2),
            margin_loss(t, 4),
            reference::hinge(t),
            reference::squared_hinge(t),
            reference::logistic(t),
            reference::zero_one(t),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_losses_penalize_misclassification_more() {
        let t = run();
        let first = &t.rows[0]; // t = -1
        let last = t.rows.last().unwrap(); // t = +1
        for c in 1..=7 {
            assert!(first[c] >= last[c], "column {c} not decreasing overall");
        }
    }

    #[test]
    fn storm_losses_are_classification_calibrated_shape() {
        // Strictly positive at t=0 and decreasing through it.
        let t = run();
        let mid = t.rows.iter().find(|r| r[0].abs() < 0.02).unwrap();
        for c in 1..=3 {
            assert!(mid[c] > 0.0);
        }
    }
}
