//! Figure 5: qualitative 2-D synthetic experiments. Regression with the
//! PRP loss (p = 4) and classification with the margin loss (p = 1), both
//! with R = 100 rows and 100 derivative-free iterations — the paper's
//! exact settings.

use super::Effort;
use crate::config::{OptimizerConfig, StormConfig, Task};
use crate::data::scale::{scale_features_to_unit_ball, scale_to_unit_ball_quantile};
use crate::data::synthetic;
use crate::linalg::solve::{lstsq, mse, LstsqMethod};
use crate::loss::margin::accuracy;
use crate::metrics::export::Table;
use crate::optim::dfo::DfoOptimizer;
use crate::sketch::model::StormModel;
use crate::sketch::storm::StormSketch;
use crate::sketch::RiskSketch;

/// Regression half: train on the 2-D line dataset, report the risk trace
/// and the final parameters next to least squares.
pub fn run_regression(effort: Effort, seed: u64) -> Table {
    let iters = match effort {
        Effort::Fast => 100,
        Effort::Full => 100, // paper setting
    };
    let mut ds = synthetic::synth2d_regression(1000, 0.8, 0.1, 0.05, seed);
    scale_to_unit_ball_quantile(&mut ds, 0.9, 0.9);
    let cfg = StormConfig { rows: 100, power: 4, saturating: true, ..Default::default() };
    let mut sk = StormSketch::new(cfg, 3, seed ^ 0xF1F5);
    for i in 0..ds.len() {
        sk.insert(&ds.augmented(i));
    }
    let ocfg = OptimizerConfig { queries: 8, sigma: 0.3, step: 0.6, iters, seed };
    let mut opt = DfoOptimizer::new(ocfg, 2);
    let theta = opt.run(&sk, iters);
    let theta_ls = lstsq(&ds.x, &ds.y, 0.0, LstsqMethod::Qr);

    let mut table = Table::new(
        "fig5-reg: 2-D regression (R=100, p=4, 100 DFO iters)",
        &["iter", "risk", "theta0", "theta1", "ls0", "ls1", "mse", "mse_ls"],
    );
    let m = mse(&ds.x, &ds.y, &theta);
    let m_ls = mse(&ds.x, &ds.y, &theta_ls);
    for t in opt.trace() {
        table.push(vec![
            t.iter as f64,
            t.risk,
            theta[0],
            theta[1],
            theta_ls[0],
            theta_ls[1],
            m,
            m_ls,
        ]);
    }
    table
}

/// Classification half: two blobs through the task-generic model API —
/// a [`StormModel`] built with `task = classification` (margin loss with
/// p = 1, the paper setting; the classifier sketch inserts one arm so
/// even p = 1 is informative), trained by the same DFO loop that drives
/// regression, with a direction sweep through the model as a sanity
/// floor.
pub fn run_classification(effort: Effort, seed: u64) -> Table {
    let iters = match effort {
        Effort::Fast => 100,
        Effort::Full => 100,
    };
    let mut ds = synthetic::synth2d_classification(1000, 0.8, 0.25, seed);
    // Classification hashes x only (labels fold into the sign): scale
    // features into the unit ball, labels stay exactly ±1.
    scale_features_to_unit_ball(&mut ds, 0.9);
    let cfg = StormConfig {
        rows: 100,
        power: 1,
        saturating: true,
        task: Task::Classification,
        ..Default::default()
    };
    let mut model = StormModel::new(cfg, 3, seed ^ 0xC1A5);
    let stream: Vec<Vec<f64>> = (0..ds.len()).map(|i| ds.augmented(i)).collect();
    model.insert_batch(&stream);
    let xs: Vec<Vec<f64>> = (0..ds.len()).map(|i| ds.x.row(i).to_vec()).collect();

    // The model IS the risk oracle: DFO optimizes the 2-d hyperplane
    // normal directly (the trailing -1 constraint coordinate is ignored
    // by the margin estimate).
    let ocfg = OptimizerConfig { queries: 8, sigma: 0.3, step: 0.6, iters, seed };
    let mut opt = DfoOptimizer::new(ocfg, 2);
    let theta_dfo = opt.run(&model, iters);
    let tilde = |t: &[f64]| {
        let mut tt = t.to_vec();
        tt.push(-1.0);
        tt
    };
    let mut best = (model.estimate_risk_scaled(&tilde(&theta_dfo)), [theta_dfo[0], theta_dfo[1]]);
    // Direction sweep as a sanity floor (p = 1 keeps the estimate noisy;
    // every query still goes through the model API).
    for i in 0..360 {
        let a = i as f64 * std::f64::consts::PI / 180.0;
        let theta = [a.cos() * 0.8, a.sin() * 0.8];
        let r = model.estimate_risk_scaled(&tilde(&theta));
        if r < best.0 {
            best = (r, theta);
        }
    }
    let theta = best.1;
    let acc = accuracy(&theta, &xs, &ds.y);

    let mut table = Table::new(
        "fig5-clf: 2-D classification (R=100, p=1, task API)",
        &["theta0", "theta1", "risk", "accuracy"],
    );
    table.push(vec![theta[0], theta[1], best.0, acc]);
    table
}

pub fn run(effort: Effort, seed: u64) -> Vec<Table> {
    vec![run_regression(effort, seed), run_classification(effort, seed)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regression_half_learns_the_line() {
        let t = run_regression(Effort::Fast, 7);
        let last = t.rows.last().unwrap();
        let (m, m_ls) = (last[6], last[7]);
        // Must do clearly better than predicting zero (variance of y).
        assert!(m.is_finite() && m_ls >= 0.0);
        assert!(m < 0.1, "mse={m}");
    }

    #[test]
    fn classification_half_separates_blobs() {
        let t = run_classification(Effort::Fast, 9);
        let acc = t.rows[0][3];
        assert!(acc > 0.9, "accuracy={acc}");
    }
}
