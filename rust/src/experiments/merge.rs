//! Mergeability experiment: the §1/§6 claim that STORM is a mergeable
//! summary. Sweeps fleet sizes, topologies and device counter widths,
//! asserting the merged counters are *identical* to a single-device
//! sketch while measuring the network traffic and stall profile each
//! configuration costs. The width sweep exercises the widening-merge
//! path: narrow (`u8`/`u16`) device tiers folding into `u32`
//! accumulators, stream sizes capped so no device cell can saturate
//! (`2 x examples-per-device <= width max`), which makes exactness a
//! theorem rather than a coincidence.

use super::Effort;
use crate::config::{CounterWidth, FleetConfig, StormConfig};
use crate::data::dataset::Dataset;
use crate::data::scale::scale_to_unit_ball;
use crate::data::stream::partition_streams;
use crate::data::synthetic;
use crate::edge::fleet::run_fleet;
use crate::edge::topology::Topology;
use crate::metrics::export::Table;
use crate::sketch::storm::StormSketch;

const TOPOLOGIES: [Topology; 3] = [Topology::Star, Topology::Tree { fanout: 2 }, Topology::Chain];

fn reference_for(ds: &Dataset, storm: StormConfig, family_seed: u64) -> StormSketch {
    let mut reference = StormSketch::new(storm, ds.dim() + 1, family_seed);
    for i in 0..ds.len() {
        reference.insert(&ds.augmented(i));
    }
    reference
}

pub fn run(effort: Effort, seed: u64) -> Table {
    let device_sweep: &[usize] = match effort {
        Effort::Fast => &[1, 2, 4, 8],
        Effort::Full => &[1, 2, 4, 8, 16, 32],
    };
    let mut ds = synthetic::parkinsons(seed);
    scale_to_unit_ball(&mut ds, 0.9);
    let storm = StormConfig { rows: 100, power: 4, saturating: true, ..Default::default() };
    let family_seed = seed ^ 0x4D45;
    let reference = reference_for(&ds, storm, family_seed);

    let mut table = Table::new(
        "merge: fleet sketch == single-device sketch (0/1), traffic per topology/width",
        &[
            "devices",
            "topology",
            "device_width_bytes",
            "identical",
            "net_bytes",
            "messages",
            "stall_ms",
            "wall_ms",
        ],
    );
    let push_run = |ds: &Dataset,
                    reference: &StormSketch,
                    devices: usize,
                    tid: usize,
                    topo: Topology,
                    width: Option<CounterWidth>,
                    table: &mut Table| {
        let fleet = FleetConfig {
            devices,
            batch: 64,
            channel_capacity: 4,
            link_latency_us: 0,
            link_bandwidth_bps: 0,
            sync_rounds: 1,
            min_quorum: 0,
            faults_seed: None,
            device_counter_width: width,
            workers: 0,
            fan_in: 2,
            epsilon_per_round: 0.0,
            decay_keep_permille: 1000,
            seed,
        };
        let streams = partition_streams(ds, devices, None);
        let result = run_fleet(fleet, storm, topo, ds.dim() + 1, family_seed, streams);
        let identical = result.sketch.grid().counts_u32() == reference.grid().counts_u32()
            && result.sketch.count() == reference.count();
        table.push(vec![
            devices as f64,
            tid as f64,
            width.unwrap_or(storm.counter_width).bytes() as f64,
            f64::from(u8::from(identical)),
            result.network.bytes as f64,
            result.network.messages as f64,
            result.network.blocked_ns as f64 / 1e6,
            result.wall_secs * 1e3,
        ]);
    };

    // Device-count sweep at the default (u32) width.
    for &devices in device_sweep {
        for (tid, topo) in TOPOLOGIES.into_iter().enumerate() {
            push_run(&ds, &reference, devices, tid, topo, None, &mut table);
        }
    }

    // Width sweep: narrow device tiers vs the same u32 accumulator, the
    // stream capped so a device cell provably cannot saturate (each
    // insert adds 2 increments per row, so `examples-per-device <=
    // width_max / 2` bounds every cell below the clip). The u32 leg is
    // already covered by the device-count sweep above.
    let devices = 4usize;
    for width in [CounterWidth::U8, CounterWidth::U16] {
        let cap = (width.max_value() as usize / 2).saturating_mul(devices).min(ds.len());
        let sub = ds.subset(&(0..cap).collect::<Vec<_>>(), "merge-width");
        let sub_reference = reference_for(&sub, storm, family_seed);
        for (tid, topo) in TOPOLOGIES.into_iter().enumerate() {
            push_run(&sub, &sub_reference, devices, tid, topo, Some(width), &mut table);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    #[test]
    fn all_configurations_merge_exactly() {
        let t = super::run(super::Effort::Fast, 5);
        for row in &t.rows {
            assert_eq!(
                row[3], 1.0,
                "devices={} topo={} width={} not identical",
                row[0], row[1], row[2]
            );
        }
        // More devices -> at least as much traffic in star topology (the
        // u32 device-count sweep: the first 12 rows).
        let star_rows: Vec<&Vec<f64>> = t.rows.iter().take(12).filter(|r| r[1] == 0.0).collect();
        assert!(star_rows.last().unwrap()[4] >= star_rows[0][4]);
        // The width sweep actually ran at all three widths.
        for wb in [1.0, 2.0, 4.0] {
            assert!(t.rows.iter().any(|r| r[2] == wb), "missing width {wb}");
        }
    }
}
