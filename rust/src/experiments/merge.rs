//! Mergeability experiment: the §1/§6 claim that STORM is a mergeable
//! summary. Sweeps fleet sizes and topologies, asserting the merged
//! counters are *identical* to a single-device sketch while measuring the
//! network traffic and stall profile each topology costs.

use super::Effort;
use crate::config::{FleetConfig, StormConfig};
use crate::data::scale::scale_to_unit_ball;
use crate::data::stream::partition_streams;
use crate::data::synthetic;
use crate::edge::fleet::run_fleet;
use crate::edge::topology::Topology;
use crate::metrics::export::Table;
use crate::sketch::storm::StormSketch;
use crate::sketch::Sketch;

pub fn run(effort: Effort, seed: u64) -> Table {
    let device_sweep: &[usize] = match effort {
        Effort::Fast => &[1, 2, 4, 8],
        Effort::Full => &[1, 2, 4, 8, 16, 32],
    };
    let mut ds = synthetic::parkinsons(seed);
    scale_to_unit_ball(&mut ds, 0.9);
    let storm = StormConfig { rows: 100, power: 4, saturating: true };
    let family_seed = seed ^ 0x4D45;

    // Single-device reference.
    let mut reference = StormSketch::new(storm, ds.dim() + 1, family_seed);
    for i in 0..ds.len() {
        reference.insert(&ds.augmented(i));
    }

    let mut table = Table::new(
        "merge: fleet sketch == single-device sketch (0/1), traffic per topology",
        &["devices", "topology", "identical", "net_bytes", "messages", "stall_ms", "wall_ms"],
    );
    for &devices in device_sweep {
        for (tid, topo) in [
            Topology::Star,
            Topology::Tree { fanout: 2 },
            Topology::Chain,
        ]
        .into_iter()
        .enumerate()
        {
            let fleet = FleetConfig {
                devices,
                batch: 64,
                channel_capacity: 4,
                link_latency_us: 0,
                link_bandwidth_bps: 0,
                sync_rounds: 1,
                min_quorum: 0,
                faults_seed: None,
                seed,
            };
            let streams = partition_streams(&ds, devices, None);
            let result = run_fleet(fleet, storm, topo, ds.dim() + 1, family_seed, streams);
            let identical = result.sketch.grid().data() == reference.grid().data()
                && result.sketch.count() == reference.count();
            table.push(vec![
                devices as f64,
                tid as f64,
                f64::from(u8::from(identical)),
                result.network.bytes as f64,
                result.network.messages as f64,
                result.network.blocked_ns as f64 / 1e6,
                result.wall_secs * 1e3,
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    #[test]
    fn all_configurations_merge_exactly() {
        let t = super::run(super::Effort::Fast, 5);
        for row in &t.rows {
            assert_eq!(row[2], 1.0, "devices={} topo={} not identical", row[0], row[1]);
        }
        // More devices -> at least as much traffic in star topology.
        let star_rows: Vec<&Vec<f64>> = t.rows.iter().filter(|r| r[1] == 0.0).collect();
        assert!(star_rows.last().unwrap()[3] >= star_rows[0][3]);
    }
}
