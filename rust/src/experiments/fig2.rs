//! Figure 2 (intuition): random SRP partitions over 2-D data — the
//! occupancy histogram shows dense vs sparse cells, the information a
//! regression line's partition memberships expose.

use crate::lsh::srp::SignedRandomProjection;
use crate::lsh::LshFunction;
use crate::metrics::export::Table;
use crate::util::rng::{Rng, Xoshiro256};

pub fn run(seed: u64) -> Table {
    let mut rng = Xoshiro256::new(seed);
    // Correlated 2-D cloud (the kind of structure a regression line fits).
    let data: Vec<Vec<f64>> = (0..2000)
        .map(|_| {
            let t = rng.uniform_range(-1.0, 1.0);
            vec![t, 0.8 * t + 0.15 * rng.gaussian()]
        })
        .collect();
    let p = 4u32;
    let hash = SignedRandomProjection::new(2, p, seed);
    let mut counts = vec![0usize; hash.range()];
    for z in &data {
        counts[hash.hash(z)] += 1;
    }
    let mut table = Table::new(
        "fig2: SRP partition occupancy on correlated 2-D data (p=4)",
        &["bucket", "count", "fraction"],
    );
    let n = data.len() as f64;
    for (b, &c) in counts.iter().enumerate() {
        table.push(vec![b as f64, c as f64, c as f64 / n]);
    }
    table
}

#[cfg(test)]
mod tests {
    #[test]
    fn occupancy_is_concentrated() {
        // Correlated data occupies few partitions densely: the top-4 of 16
        // buckets should hold most of the mass (that is the figure's point).
        let t = super::run(4);
        let mut fracs: Vec<f64> = t.rows.iter().map(|r| r[2]).collect();
        fracs.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let top4: f64 = fracs[..4].iter().sum();
        assert!(top4 > 0.6, "top4={top4}");
        let total: f64 = fracs.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
