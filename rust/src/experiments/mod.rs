//! Experiment harnesses — one per table/figure of the paper's evaluation
//! (see DESIGN.md §4 for the index). Every harness returns
//! [`crate::metrics::export::Table`]s with stable column schemas and can
//! be invoked via `storm experiment <id>` or the corresponding
//! `cargo bench` target.
//!
//! | id       | paper artifact                     |
//! |----------|------------------------------------|
//! | table1   | Table 1 (datasets)                 |
//! | fig2     | Figure 2 (partition intuition)     |
//! | fig3a    | Figure 3a (surrogate loss vs p)    |
//! | fig3b    | Figure 3b (slope at 0.1 vs p)      |
//! | fig4     | Figure 4 (MSE vs memory, 3 sets)   |
//! | fig5     | Figure 5 (2-D reg + clf)           |
//! | fig6     | Figure 6 (margin-loss comparison)  |
//! | merge    | mergeability / fleet equivalence   |
//! | privacy  | DP release epsilon sweep           |
//! | energy   | sketch-vs-raw transmit energy      |
//! | drift    | decayed vs cumulative under shift  |

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table1;
pub mod merge;
pub mod ablate;
pub mod privacy;
pub mod energy;
pub mod drift;

use crate::metrics::export::Table;

/// Effort level: `Fast` for CI / benches, `Full` for paper-grade runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Effort {
    Fast,
    Full,
}

impl Effort {
    pub fn from_env() -> Effort {
        if std::env::var("STORM_BENCH_FULL").is_ok() {
            Effort::Full
        } else {
            Effort::Fast
        }
    }

    /// Paper protocol: 10 averaged runs. Fast mode: 3.
    pub fn runs(self) -> usize {
        match self {
            Effort::Fast => 3,
            Effort::Full => 10,
        }
    }

    pub fn dfo_iters(self) -> usize {
        match self {
            Effort::Fast => 200,
            Effort::Full => 400,
        }
    }
}

/// Run an experiment by id; returns its tables. Unknown ids return None.
pub fn run(id: &str, effort: Effort, seed: u64) -> Option<Vec<Table>> {
    let tables = match id {
        "table1" => vec![table1::run()],
        "fig2" => vec![fig2::run(seed)],
        "fig3a" => vec![fig3::run_fig3a(seed)],
        "fig3b" => vec![fig3::run_fig3b()],
        "fig4" => fig4::run(effort, seed),
        "fig5" => fig5::run(effort, seed),
        "fig6" => vec![fig6::run()],
        "merge" => vec![merge::run(effort, seed)],
        "privacy" => vec![privacy::run(effort, seed)],
        "energy" => vec![energy::run()],
        "ablate" => vec![ablate::run(effort, seed)],
        "drift" => vec![drift::run(effort, seed)],
        _ => return None,
    };
    Some(tables)
}

/// All known experiment ids.
pub const ALL: &[&str] = &[
    "table1", "fig2", "fig3a", "fig3b", "fig4", "fig5", "fig6", "merge", "privacy", "energy",
    "ablate", "drift",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_is_none() {
        assert!(run("nope", Effort::Fast, 0).is_none());
    }

    #[test]
    fn all_ids_resolve() {
        // Only run the cheap ones here; the expensive ones have their own
        // integration tests / bench targets.
        for id in ["table1", "fig3a", "fig3b", "fig6", "energy"] {
            let tables = run(id, Effort::Fast, 1).unwrap();
            assert!(!tables.is_empty(), "{id} produced no tables");
            for t in &tables {
                assert!(!t.rows.is_empty(), "{id} table {} empty", t.title);
            }
        }
    }
}
