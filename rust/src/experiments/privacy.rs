//! Differential-privacy ablation (paper §2.2): train against
//! Laplace-noised sketch releases across an epsilon sweep and measure the
//! accuracy cost of privacy.

use super::Effort;
use crate::config::{OptimizerConfig, StormConfig};
use crate::data::scale::scale_to_unit_ball_quantile;
use crate::data::synthetic;
use crate::linalg::solve::mse;
use crate::metrics::export::Table;
use crate::optim::dfo::DfoOptimizer;
use crate::optim::FnOracle;
use crate::sketch::privacy::PrivateStormRelease;
use crate::sketch::storm::StormSketch;
use crate::util::mathx::norm2;

const EPSILONS: &[f64] = &[0.1, 0.5, 1.0, 5.0, 10.0];

pub fn run(effort: Effort, seed: u64) -> Table {
    let iters = effort.dfo_iters();
    let runs = effort.runs();
    let mut ds = synthetic::synth2d_regression(1000, 0.8, 0.1, 0.03, seed);
    scale_to_unit_ball_quantile(&mut ds, 0.9, 0.9);
    let d = ds.dim();
    let cfg = StormConfig { rows: 200, power: 4, saturating: true, ..Default::default() };

    let mut table = Table::new(
        format!("privacy: epsilon vs training MSE (mean of {runs} runs; inf = exact sketch)"),
        &["epsilon", "mse"],
    );
    let train_on = |risk: &dyn Fn(&[f64]) -> f64, run_seed: u64| -> Vec<f64> {
        let oracle = FnOracle::new(d, risk);
        let ocfg = OptimizerConfig { queries: 8, sigma: 0.3, step: 0.6, iters, seed: run_seed };
        DfoOptimizer::new(ocfg, d).run(&oracle, iters)
    };
    let rescale = |q: &[f64]| -> Vec<f64> {
        let n = norm2(q);
        let r = crate::data::scale::query_radius();
        if n <= r {
            q.to_vec()
        } else {
            q.iter().map(|v| v * r / n).collect()
        }
    };

    for &eps in EPSILONS {
        let mut acc = 0.0;
        for r in 0..runs {
            let fam = seed ^ (r as u64 * 31 + 7);
            let mut sk = StormSketch::new(cfg, d + 1, fam);
            for i in 0..ds.len() {
                sk.insert(&ds.augmented(i));
            }
            let rel = PrivateStormRelease::release(&sk, eps, fam ^ 0xD0);
            let theta = train_on(&|q: &[f64]| rel.estimate_risk(&rescale(q)), fam);
            acc += mse(&ds.x, &ds.y, &theta).min(1e6);
        }
        table.push(vec![eps, acc / runs as f64]);
    }
    // Non-private reference (epsilon = inf encoded as 0 in the table tail).
    let mut acc = 0.0;
    for r in 0..runs {
        let fam = seed ^ (r as u64 * 31 + 7);
        let mut sk = StormSketch::new(cfg, d + 1, fam);
        for i in 0..ds.len() {
            sk.insert(&ds.augmented(i));
        }
        let theta = train_on(&|q: &[f64]| sk.estimate_risk_scaled(q), fam);
        acc += mse(&ds.x, &ds.y, &theta).min(1e6);
    }
    table.push(vec![f64::INFINITY, acc / runs as f64]);
    table
}

#[cfg(test)]
mod tests {
    #[test]
    fn privacy_costs_accuracy_at_tight_epsilon() {
        let t = super::run(super::Effort::Fast, 11);
        let mse_tight = t.rows[0][1]; // eps = 0.1
        let mse_exact = t.rows.last().unwrap()[1]; // eps = inf
        assert!(
            mse_tight >= mse_exact * 0.8,
            "tight epsilon should not beat exact: {mse_tight} vs {mse_exact}"
        );
        assert!(t.rows.iter().all(|r| r[1].is_finite()));
    }
}
