//! Figure 4 — the paper's headline evaluation: training MSE as a function
//! of memory for STORM vs random sampling, leverage-score sampling and the
//! Clarkson–Woodruff sketch, on the three Table-1 datasets.
//!
//! Protocol (paper §5): each point averages `Effort::runs()` runs with
//! independently-constructed sketches/samples. Memory budgets are chosen
//! as sample counts spanning well below d to well above d, so the sampling
//! baselines sweep straight through the sample-wise double-descent peak at
//! n ~ d; STORM, which always uses the full dataset, does not exhibit the
//! peak. Exact least squares is reported as the floor.

use super::Effort;
use crate::baselines::cw::ClarksonWoodruff;
use crate::baselines::exact::ExactLeastSquares;
use crate::baselines::leverage::LeverageSampling;
use crate::baselines::random_sampling::RandomSampling;
use crate::baselines::{sample_bytes, CompressedRegression};
use crate::config::{OptimizerConfig, StormConfig};
use crate::data::registry;
use crate::data::scale::scale_to_unit_ball_quantile;
use crate::linalg::solve::mse;
use crate::metrics::export::Table;
use crate::optim::dfo::DfoOptimizer;
use crate::sketch::storm::StormSketch;

/// Sample-count multipliers of d defining the memory sweep.
const SWEEP: &[f64] = &[0.25, 0.5, 1.0, 2.0, 4.0, 16.0, 64.0];

/// Train STORM at a given byte budget and return the training MSE.
fn storm_point(
    ds: &crate::data::dataset::Dataset,
    budget_bytes: usize,
    iters: usize,
    seed: u64,
) -> (f64, usize) {
    let buckets_bytes = 16 * 4; // p = 4, u32 counters
    let rows = (budget_bytes / buckets_bytes).max(4);
    let cfg = StormConfig { rows, power: 4, saturating: true, ..Default::default() };
    let mut sk = StormSketch::new(cfg, ds.dim() + 1, seed);
    for i in 0..ds.len() {
        sk.insert(&ds.augmented(i));
    }
    let ocfg = OptimizerConfig {
        queries: 8,
        sigma: 0.3,
        step: 0.6,
        iters,
        seed: seed ^ 0x5117,
    };
    let mut opt = DfoOptimizer::new(ocfg, ds.dim());
    let theta = opt.run(&sk, iters);
    (mse(&ds.x, &ds.y, &theta), sk.bytes())
}

/// Run the full Figure-4 sweep; one table per dataset.
pub fn run(effort: Effort, seed: u64) -> Vec<Table> {
    let runs = effort.runs();
    let iters = effort.dfo_iters();
    let mut tables = Vec::new();
    for name in registry::TABLE1_NAMES {
        let mut table = Table::new(
            format!("fig4: {name} — training MSE vs memory (mean of {runs} runs)"),
            &[
                "bytes",
                "sample_rows",
                "mse_random",
                "mse_leverage",
                "mse_cw",
                "mse_storm",
                "mse_exact",
            ],
        );
        let mut ds = registry::load(name, seed).expect("registry dataset");
        scale_to_unit_ball_quantile(&mut ds, crate::data::scale::DEFAULT_RADIUS, 0.9);
        let d = ds.dim();
        let (theta_exact, _) = ExactLeastSquares.fit(&ds, 0, 0);
        let mse_exact = mse(&ds.x, &ds.y, &theta_exact);

        for &mult in SWEEP {
            let rows = ((d as f64 * mult).round() as usize).max(1);
            let budget = sample_bytes(rows, d);
            let mut acc = [0.0f64; 4]; // random, leverage, cw, storm
            for run in 0..runs {
                let rs = run as u64 * 7919 + seed;
                let (t, _) = RandomSampling.fit(&ds, budget, rs);
                acc[0] += mse(&ds.x, &ds.y, &t).min(1e6);
                let (t, _) = LeverageSampling.fit(&ds, budget, rs);
                acc[1] += mse(&ds.x, &ds.y, &t).min(1e6);
                let (t, _) = ClarksonWoodruff.fit(&ds, budget, rs);
                acc[2] += mse(&ds.x, &ds.y, &t).min(1e6);
                let (m, _) = storm_point(&ds, budget, iters, rs);
                acc[3] += m.min(1e6);
            }
            let n = runs as f64;
            table.push(vec![
                budget as f64,
                rows as f64,
                acc[0] / n,
                acc[1] / n,
                acc[2] / n,
                acc[3] / n,
                mse_exact,
            ]);
        }
        tables.push(table);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One reduced fig4 run on the smallest dataset — the structural
    /// claims: sampling shows a double-descent bump near d, STORM does
    /// not, and everything improves toward exact LS at large memory.
    #[test]
    fn fig4_shape_holds_on_autos() {
        let mut ds = registry::load("autos", 3).unwrap();
        scale_to_unit_ball_quantile(&mut ds, 0.9, 0.9);
        let d = ds.dim();
        let runs = 6;
        let col = |mult: f64, method: &str| -> f64 {
            let rows = ((d as f64 * mult) as usize).max(1);
            let budget = sample_bytes(rows, d);
            let mut acc = 0.0;
            for r in 0..runs {
                let t = match method {
                    "random" => RandomSampling.fit(&ds, budget, r as u64).0,
                    "storm" => {
                        let (m, _) = storm_point(&ds, budget, 150, r as u64);
                        acc += m.min(1e6);
                        continue;
                    }
                    _ => unreachable!(),
                };
                acc += mse(&ds.x, &ds.y, &t).min(1e6);
            }
            acc / runs as f64
        };
        // Sampling: peak near d vs large-sample regime.
        let rand_at_d = col(1.0, "random");
        let rand_large = col(4.0, "random");
        assert!(
            rand_at_d > rand_large,
            "no double-descent bump: at_d={rand_at_d} large={rand_large}"
        );
        // STORM at the same two budgets must NOT spike at n ~ d.
        let storm_at_d = col(1.0, "storm");
        let storm_large = col(4.0, "storm");
        assert!(
            storm_at_d < rand_at_d,
            "STORM ({storm_at_d}) should beat sampling ({rand_at_d}) in the danger zone"
        );
        assert!(
            storm_at_d < storm_large * 10.0 + 1e-3,
            "STORM spiked at d: {storm_at_d} vs {storm_large}"
        );
    }
}
