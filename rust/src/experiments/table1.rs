//! Table 1: the dataset registry, regenerated programmatically (with the
//! synthetic-substitute flag made explicit — DESIGN.md §5).

use crate::data::registry::{load, REGISTRY};
use crate::metrics::export::Table;

pub fn run() -> Table {
    let mut table = Table::new(
        "table1: datasets (n, d, raw bytes, synthetic substitute)",
        &["n", "d", "raw_bytes", "substitute"],
    );
    for info in REGISTRY {
        // Verify the generator agrees with the registry row.
        let ds = load(info.name, 0).expect("registry generator");
        assert_eq!(ds.len(), info.n, "{}", info.name);
        assert_eq!(ds.dim(), info.d, "{}", info.name);
        table.push(vec![
            info.n as f64,
            info.d as f64,
            ds.raw_bytes() as f64,
            f64::from(u8::from(info.synthetic_substitute)),
        ]);
        println!("{:<12} n={:<6} d={:<3} {}", info.name, info.n, info.d, info.description);
    }
    table
}

#[cfg(test)]
mod tests {
    #[test]
    fn table_has_all_registry_rows() {
        let t = super::run();
        assert_eq!(t.rows.len(), super::REGISTRY.len());
    }
}
