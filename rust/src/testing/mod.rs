//! Property-testing-lite: `proptest` is not in the offline vendor set, so
//! invariant tests use this small seeded case-sweep framework. It provides
//! deterministic generators over the crate's own RNG and a `cases` driver
//! that reports the failing seed/case for reproduction.

use crate::util::rng::{Rng, Xoshiro256};

/// Run `n` generated cases. On panic the failing case index and derived
/// seed are printed so the case can be replayed exactly.
pub fn cases(n: usize, seed: u64, mut body: impl FnMut(&mut Xoshiro256, usize)) {
    let mut root = Xoshiro256::new(seed);
    for case in 0..n {
        let mut rng = root.fork(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng, case);
        }));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (root seed {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Uniform f64 vector with entries in `[lo, hi)`.
pub fn gen_vec(rng: &mut Xoshiro256, len: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..len).map(|_| rng.uniform_range(lo, hi)).collect()
}

/// Point drawn uniformly in the ball of the given radius (rejection-free:
/// gaussian direction + radius transform).
pub fn gen_ball_point(rng: &mut Xoshiro256, dim: usize, radius: f64) -> Vec<f64> {
    let dir = rng.sphere_vec(dim, 1.0);
    let r = radius * rng.uniform().powf(1.0 / dim as f64);
    dir.into_iter().map(|v| v * r).collect()
}

/// Random dimension in `[lo, hi]`.
pub fn gen_dim(rng: &mut Xoshiro256, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// Assert two floats agree to a tolerance, with a useful message.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    assert!(
        (a - b).abs() <= tol,
        "assert_close failed: {a} vs {b} (|diff|={} > tol={tol})",
        (a - b).abs()
    );
}

/// Assert two slices agree elementwise to a tolerance.
#[track_caller]
pub fn assert_allclose(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for i in 0..a.len() {
        assert!(
            (a[i] - b[i]).abs() <= tol,
            "assert_allclose failed at index {i}: {} vs {} (tol={tol})",
            a[i],
            b[i]
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_run_deterministically() {
        let mut log1 = Vec::new();
        cases(5, 99, |rng, _| log1.push(rng.next_u64()));
        let mut log2 = Vec::new();
        cases(5, 99, |rng, _| log2.push(rng.next_u64()));
        assert_eq!(log1, log2);
    }

    #[test]
    fn ball_points_inside_radius() {
        cases(50, 7, |rng, _| {
            let dim = gen_dim(rng, 1, 20);
            let p = gen_ball_point(rng, dim, 0.9);
            let norm: f64 = p.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!(norm <= 0.9 + 1e-9, "norm={norm}");
        });
    }

    #[test]
    #[should_panic]
    fn assert_close_fires() {
        assert_close(1.0, 2.0, 0.5);
    }

    #[test]
    fn allclose_passes_within_tol() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-9, 2.0 - 1e-9], 1e-6);
    }
}
