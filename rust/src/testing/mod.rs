//! Property-testing-lite: `proptest` is not in the offline vendor set, so
//! invariant tests use this small seeded case-sweep framework. It provides
//! deterministic generators over the crate's own RNG and a `cases` driver
//! that reports the failing seed/case for reproduction.
//!
//! Two environment knobs control every sweep (read per [`cases`] call):
//!
//! * `STORM_TEST_CASES=<m>` multiplies each property's case budget by
//!   the integer `m` (the scheduled deep-property CI job runs with
//!   `STORM_TEST_CASES=10`).
//! * `STORM_TEST_REPLAY=<seed>:<case>` re-runs exactly one case: the
//!   property whose root seed is `<seed>` executes only case `<case>`
//!   (with its exact RNG stream); every other property runs zero cases.
//!   A failing sweep prints the ready-to-paste value.

use crate::util::rng::{Rng, Xoshiro256};

/// How a [`cases`] sweep should run, normally parsed from the
/// environment (see the module docs); separated out so the parsing and
/// selection logic is unit-testable without touching process state.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CaseOptions {
    /// Multiplier on every property's case budget (None = 1).
    pub multiplier: Option<usize>,
    /// `(root_seed, case)` — run only this case of this property.
    pub replay: Option<(u64, usize)>,
}

impl CaseOptions {
    /// Parse from the raw env-var values. Malformed values panic loudly:
    /// a typo'd knob silently running the defaults would defeat the deep
    /// CI job.
    pub fn parse(cases_var: Option<&str>, replay_var: Option<&str>) -> CaseOptions {
        let multiplier = cases_var.map(|v| {
            v.trim()
                .parse::<usize>()
                .unwrap_or_else(|_| panic!("STORM_TEST_CASES must be an integer multiplier, got {v:?}"))
        });
        let replay = replay_var.map(|v| {
            let err = || panic!("STORM_TEST_REPLAY must be <seed>:<case>, got {v:?}");
            let (seed, case) = v.trim().split_once(':').unwrap_or_else(err);
            match (seed.parse::<u64>(), case.parse::<usize>()) {
                (Ok(s), Ok(c)) => (s, c),
                _ => err(),
            }
        });
        CaseOptions { multiplier, replay }
    }

    /// Read `STORM_TEST_CASES` / `STORM_TEST_REPLAY` from the process
    /// environment.
    pub fn from_env() -> CaseOptions {
        CaseOptions::parse(
            std::env::var("STORM_TEST_CASES").ok().as_deref(),
            std::env::var("STORM_TEST_REPLAY").ok().as_deref(),
        )
    }
}

/// Run `n` generated cases (scaled and filtered by the environment —
/// see the module docs). On panic the failing case index and root seed
/// are printed with a ready-to-paste `STORM_TEST_REPLAY` value so the
/// case can be replayed exactly. Returns the number of cases executed
/// (0 when a replay targets a different property).
pub fn cases(n: usize, seed: u64, body: impl FnMut(&mut Xoshiro256, usize)) -> usize {
    cases_with(CaseOptions::from_env(), n, seed, body)
}

/// [`cases`] with explicit options (the env-free core).
pub fn cases_with(
    opts: CaseOptions,
    n: usize,
    seed: u64,
    mut body: impl FnMut(&mut Xoshiro256, usize),
) -> usize {
    let n = n * opts.multiplier.unwrap_or(1).max(1);
    // Miri interprets MIR ~100-1000x slower than native code: shrink
    // every property to a smoke-level budget so `cargo miri test`
    // finishes, while keeping the generators and seeds identical.
    let n = if cfg!(miri) { n.min(2) } else { n };
    let mut root = Xoshiro256::new(seed);
    if let Some((replay_seed, replay_case)) = opts.replay {
        if replay_seed != seed {
            return 0; // replay targets another property: skip fast
        }
        // `fork` advances the root stream, so case k's generator depends
        // on the k forks before it — replay must burn through them.
        for case in 0..replay_case {
            let _ = root.fork(case as u64);
        }
        let mut rng = root.fork(replay_case as u64);
        body(&mut rng, replay_case);
        return 1;
    }
    for case in 0..n {
        let mut rng = root.fork(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng, case);
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case} (root seed {seed}); \
                 replay with STORM_TEST_REPLAY={seed}:{case}"
            );
            std::panic::resume_unwind(e);
        }
    }
    n
}

/// Counter width the invariant sweeps should build sketches at:
/// `STORM_TEST_WIDTH=u8|u16|u32` (default `u32`). The CI matrix runs the
/// suite once at `u8` so the narrow counter path stays exercised; the
/// bit-exactness properties are saturation-robust at a *uniform* width
/// (clipping commutes with merging for non-negative increments), so the
/// same assertions hold at every width. A malformed value panics loudly
/// — a typo'd knob silently running the default would defeat that CI leg.
pub fn test_counter_width() -> crate::config::CounterWidth {
    match std::env::var("STORM_TEST_WIDTH") {
        Err(_) => crate::config::CounterWidth::U32,
        Ok(v) => crate::config::CounterWidth::parse(&v)
            .unwrap_or_else(|| panic!("STORM_TEST_WIDTH must be u8|u16|u32, got {v:?}")),
    }
}

/// Learning task the task-generic invariant sweeps should build models
/// at: `STORM_TEST_TASK=regression|classification` (default `regression`,
/// the seed behaviour). The CI matrix runs the suite once at
/// `classification` so the classifier rides every fleet/chaos/width
/// invariant, not just the tests that name it explicitly. Malformed
/// values panic loudly — a typo'd knob silently running the default
/// would defeat that CI leg.
pub fn test_task() -> crate::config::Task {
    match std::env::var("STORM_TEST_TASK") {
        Err(_) => crate::config::Task::Regression,
        Ok(v) => crate::config::Task::parse(&v)
            .unwrap_or_else(|| panic!("STORM_TEST_TASK must be regression|classification, got {v:?}")),
    }
}

/// Hyperplane family the family-generic invariant sweeps should build
/// sketches at: `STORM_TEST_HASH_FAMILY=dense|sparse|hadamard` (default
/// `dense`, the seed behaviour — sparse runs at the default density). The
/// CI matrix runs the suite once at `sparse` so the structured-projection
/// path rides every fleet/merge/wire invariant. Malformed values panic
/// loudly — a typo'd knob silently running the default would defeat that
/// CI leg.
pub fn test_hash_family() -> crate::config::HashFamily {
    match std::env::var("STORM_TEST_HASH_FAMILY") {
        Err(_) => crate::config::HashFamily::Dense,
        Ok(v) => crate::config::HashFamily::parse(&v).unwrap_or_else(|| {
            panic!("STORM_TEST_HASH_FAMILY must be dense|sparse|hadamard, got {v:?}")
        }),
    }
}

/// Per-round privacy budget the privacy-injectable sweeps should noise
/// device deltas at: `STORM_TEST_PRIVACY=<epsilon>` (default `0.0`, the
/// seed behaviour — privacy off, byte-identical wire). The CI matrix
/// runs the suite once at a positive epsilon so the noised v3 wire path
/// and the deterministic per-`(device, epoch)` noise ride the privacy
/// invariants on every push. Malformed values panic loudly — a typo'd
/// knob silently running the default would defeat that CI leg.
pub fn test_privacy_epsilon() -> f64 {
    match std::env::var("STORM_TEST_PRIVACY") {
        Err(_) => 0.0,
        Ok(v) => {
            let eps = v.trim().parse::<f64>().unwrap_or_else(|_| {
                panic!("STORM_TEST_PRIVACY must be an epsilon >= 0, got {v:?}")
            });
            assert!(
                eps.is_finite() && eps >= 0.0,
                "STORM_TEST_PRIVACY must be finite and >= 0, got {v:?}"
            );
            eps
        }
    }
}

/// Uniform f64 vector with entries in `[lo, hi)`.
pub fn gen_vec(rng: &mut Xoshiro256, len: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..len).map(|_| rng.uniform_range(lo, hi)).collect()
}

/// Point drawn uniformly in the ball of the given radius (rejection-free:
/// gaussian direction + radius transform).
pub fn gen_ball_point(rng: &mut Xoshiro256, dim: usize, radius: f64) -> Vec<f64> {
    let dir = rng.sphere_vec(dim, 1.0);
    let r = radius * rng.uniform().powf(1.0 / dim as f64);
    dir.into_iter().map(|v| v * r).collect()
}

/// Random dimension in `[lo, hi]`.
pub fn gen_dim(rng: &mut Xoshiro256, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// Assert two floats agree to a tolerance, with a useful message.
/// Exactly equal values — including equal infinities — always pass; any
/// other non-finite operand (NaN, or mismatched infinities) fails with
/// an explicit non-finite message instead of a misleading `|diff|=NaN`.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    if a == b {
        return; // covers equal infinities; NaN never compares equal
    }
    assert!(
        a.is_finite() && b.is_finite(),
        "assert_close failed: non-finite operand ({a} vs {b})"
    );
    assert!(
        (a - b).abs() <= tol,
        "assert_close failed: {a} vs {b} (|diff|={} > tol={tol})",
        (a - b).abs()
    );
}

/// Assert two slices agree elementwise to a tolerance (same non-finite
/// contract as [`assert_close`], with the failing index reported).
#[track_caller]
pub fn assert_allclose(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for i in 0..a.len() {
        if a[i] == b[i] {
            continue;
        }
        assert!(
            a[i].is_finite() && b[i].is_finite(),
            "assert_allclose failed at index {i}: non-finite operand ({} vs {})",
            a[i],
            b[i]
        );
        assert!(
            (a[i] - b[i]).abs() <= tol,
            "assert_allclose failed at index {i}: {} vs {} (tol={tol})",
            a[i],
            b[i]
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_run_deterministically() {
        let mut log1 = Vec::new();
        cases(5, 99, |rng, _| log1.push(rng.next_u64()));
        let mut log2 = Vec::new();
        cases(5, 99, |rng, _| log2.push(rng.next_u64()));
        assert_eq!(log1, log2);
    }

    #[test]
    fn ball_points_inside_radius() {
        cases(50, 7, |rng, _| {
            let dim = gen_dim(rng, 1, 20);
            let p = gen_ball_point(rng, dim, 0.9);
            let norm: f64 = p.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!(norm <= 0.9 + 1e-9, "norm={norm}");
        });
    }

    #[test]
    fn multiplier_scales_the_sweep() {
        let opts = CaseOptions { multiplier: Some(3), ..Default::default() };
        let mut ran = 0usize;
        let n = cases_with(opts, 4, 11, |_, _| ran += 1);
        assert_eq!(n, 12);
        assert_eq!(ran, 12);
        // Multiplier 0 is treated as 1 (never silently run nothing).
        let opts = CaseOptions { multiplier: Some(0), ..Default::default() };
        assert_eq!(cases_with(opts, 4, 11, |_, _| {}), 4);
    }

    #[test]
    fn replay_reruns_exactly_the_targeted_case_with_its_stream() {
        // Record case 3's stream from a full sweep...
        let mut full: Vec<(usize, u64)> = Vec::new();
        cases_with(CaseOptions::default(), 6, 42, |rng, case| {
            full.push((case, rng.next_u64()));
        });
        // ...then replay only case 3 and demand the identical draw.
        let opts = CaseOptions { replay: Some((42, 3)), ..Default::default() };
        let mut replayed: Vec<(usize, u64)> = Vec::new();
        let n = cases_with(opts, 6, 42, |rng, case| {
            replayed.push((case, rng.next_u64()));
        });
        assert_eq!(n, 1);
        assert_eq!(replayed, vec![full[3]]);
        // A replay for a different property's seed runs nothing.
        let other = CaseOptions { replay: Some((43, 3)), ..Default::default() };
        assert_eq!(cases_with(other, 6, 42, |_, _| panic!("must not run")), 0);
    }

    #[test]
    fn case_options_parse_both_knobs() {
        assert_eq!(CaseOptions::parse(None, None), CaseOptions::default());
        assert_eq!(
            CaseOptions::parse(Some("10"), None),
            CaseOptions { multiplier: Some(10), replay: None }
        );
        assert_eq!(
            CaseOptions::parse(None, Some("118:7")),
            CaseOptions { multiplier: None, replay: Some((118, 7)) }
        );
        assert_eq!(
            CaseOptions::parse(Some(" 2 "), Some(" 5:0 ")),
            CaseOptions { multiplier: Some(2), replay: Some((5, 0)) }
        );
    }

    #[test]
    #[should_panic(expected = "STORM_TEST_REPLAY")]
    fn malformed_replay_panics_loudly() {
        let _ = CaseOptions::parse(None, Some("notaseed"));
    }

    #[test]
    #[should_panic(expected = "STORM_TEST_CASES")]
    fn malformed_multiplier_panics_loudly() {
        let _ = CaseOptions::parse(Some("ten"), None);
    }

    #[test]
    #[should_panic]
    fn assert_close_fires() {
        assert_close(1.0, 2.0, 0.5);
    }

    #[test]
    fn allclose_passes_within_tol() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-9, 2.0 - 1e-9], 1e-6);
    }

    #[test]
    fn equal_infinities_compare_close() {
        assert_close(f64::INFINITY, f64::INFINITY, 0.0);
        assert_close(f64::NEG_INFINITY, f64::NEG_INFINITY, 1e-9);
        assert_allclose(&[f64::INFINITY, 1.0], &[f64::INFINITY, 1.0], 1e-9);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn nan_fails_with_explicit_message() {
        assert_close(f64::NAN, f64::NAN, 1e9);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn mismatched_infinities_fail_as_non_finite() {
        assert_close(f64::INFINITY, f64::NEG_INFINITY, 1e9);
    }

    #[test]
    #[should_panic(expected = "non-finite operand")]
    fn allclose_reports_nan_index() {
        assert_allclose(&[1.0, f64::NAN], &[1.0, 2.0], 1e9);
    }
}
