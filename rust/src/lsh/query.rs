//! Rank-1 incremental query engine for the optimizer hot path.
//!
//! Every candidate an optimizer step evaluates differs from a shared
//! base iterate `theta~` by a single coordinate (coordinate descent) or
//! a single direction (DFO / SPSA antithetic pairs). SRP projections are
//! linear, so instead of re-projecting each dense `d`-dim candidate
//! through all `R * p` hyperplanes (`O(R * p * d)` per candidate), the
//! engine caches the base iterate's per-plane head projections
//! `P[r, j] = <w_head(r, j), theta~>` and squared norm once, then serves
//! each candidate as a rank-1 update:
//!
//! * **axis probe** `q = theta~ with q[k] = value`:
//!   `proj = P + (value - theta~[k]) * W[:, k]` and
//!   `||q||^2 = ||theta~||^2 - theta~[k]^2 + value^2`;
//! * **direction probe** `q = theta~ + c * u`:
//!   `proj = P + c * U` (with `U = <w_head, u>` shared by the antithetic
//!   `+-c` pair) and
//!   `||q||^2 = ||theta~||^2 + 2c <theta~, u> + c^2 ||u||^2`.
//!
//! Both are `O(1)` per plane — `O(R * p)` per candidate — and exact by
//! linearity for all three hash families: dense gathers a cached
//! plane-transposed column, sparse gathers CSR columns, and Hadamard
//! uses `H(e_k)`, a signed ±1 column of the effective projection matrix
//! ([`crate::lsh::bank::HashBank::head_column`]). The unit-ball rescale
//! of the dense query path (`s = radius / ||q||` when the candidate
//! leaves the ball) distributes over the projection, so the decision per
//! plane is `s * proj[j] + w_q[j] * tail >= 0` with
//! `tail = sqrt(1 - s^2 ||q||^2)` — no dense vector is ever formed.
//!
//! **When is the incremental path exact?** Bucket decisions are sign
//! tests of the same real-valued projection the dense path computes, so
//! the two paths agree except when floating-point rounding (the scale
//! `s` is applied to the accumulated projection instead of elementwise,
//! and the squared norm is updated instead of recomputed) straddles an
//! exact zero — a measure-zero set of ties. On continuous random inputs
//! the buckets are identical with probability 1 (property-tested across
//! families, widths, and tasks), and when every intermediate product and
//! sum is exactly representable (dyadic-rational coordinates, in-ball
//! candidates so `s = 1`) the paths are bit-identical; an axis probe
//! whose `value` equals the base coordinate reuses the cached base
//! projection and norm outright and is bit-identical unconditionally.
//!
//! Set `STORM_QUERY_INCREMENTAL=off` to force the dense-materialize
//! fallback everywhere ([`incremental_enabled`]); the CI `query-dense`
//! leg runs the whole suite that way, and the dense path stays behind as
//! the bit-level regression oracle.

use std::collections::BTreeMap;
use std::sync::OnceLock;

use crate::lsh::bank::HashBank;
use crate::lsh::simd::{self, Kernel};
use crate::util::mathx::{axpy, dot};

/// One candidate of an optimizer step, described relative to
/// [`CandidateSet::base`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Probe {
    /// The base iterate itself.
    Base,
    /// `base` with coordinate `k` *set* to `value` (coordinate descent's
    /// golden-section probes; set — not added — so the materialized
    /// vector reproduces the old slot-assignment bitwise).
    Axis {
        /// Coordinate index into the base vector.
        k: usize,
        /// New value of that coordinate.
        value: f64,
    },
    /// `base + step * dirs[dir]` (DFO / SPSA antithetic probes; the two
    /// arms of a pair share one direction projection).
    Dir {
        /// Index into [`CandidateSet::dirs`].
        dir: usize,
        /// Signed step length `c`.
        step: f64,
    },
}

/// A whole optimizer step's worth of risk queries: a shared base
/// iterate, the direction vectors the probes reference, and the probes
/// themselves. This is the contract between `optim` and the sketch query
/// paths — [`crate::optim::RiskOracle::risk_candidates`] either serves
/// it incrementally ([`QueryEngine`]) or materializes the dense
/// candidates ([`CandidateSet::materialize`]) and calls the batched
/// dense oracle.
#[derive(Clone, Copy, Debug)]
pub struct CandidateSet<'a> {
    /// The base iterate `theta~` (full augmented length; the classifier
    /// reads only the leading `d` coordinates, exactly like its dense
    /// path).
    pub base: &'a [f64],
    /// Direction vectors referenced by [`Probe::Dir`] (same length as
    /// `base`).
    pub dirs: &'a [Vec<f64>],
    /// The candidates, in evaluation order.
    pub probes: &'a [Probe],
}

impl CandidateSet<'_> {
    /// Number of candidates.
    pub fn len(&self) -> usize {
        self.probes.len()
    }

    /// True when the set has no probes.
    pub fn is_empty(&self) -> bool {
        self.probes.is_empty()
    }

    /// Materialize the dense candidate vectors into `out` (cleared
    /// first), reproducing exactly — bit for bit — the vectors the
    /// optimizers built before the incremental engine existed: clone the
    /// base, then assign the axis slot or `axpy` the direction.
    pub fn materialize(&self, out: &mut Vec<Vec<f64>>) {
        out.clear();
        out.reserve(self.probes.len());
        for probe in self.probes {
            let mut v = self.base.to_vec();
            match *probe {
                Probe::Base => {}
                Probe::Axis { k, value } => v[k] = value,
                Probe::Dir { dir, step } => axpy(&mut v, step, &self.dirs[dir]),
            }
            out.push(v);
        }
    }
}

static INCREMENTAL: OnceLock<bool> = OnceLock::new();

/// Whether the incremental query path is enabled, resolved once per
/// process: honours `STORM_QUERY_INCREMENTAL` (`off`/`0`/`false` force
/// the dense-materialize fallback, `on`/`1`/`auto` re-enable it,
/// anything else panics loudly rather than silently running the wrong
/// path — same contract as `STORM_SIMD`).
pub fn incremental_enabled() -> bool {
    *INCREMENTAL.get_or_init(|| match std::env::var("STORM_QUERY_INCREMENTAL") {
        Err(_) => true,
        Ok(v) => match v.trim() {
            "off" | "0" | "false" => false,
            "" | "on" | "1" | "auto" | "true" => true,
            other => panic!("STORM_QUERY_INCREMENTAL must be off|0|false|on|1|auto, got {other:?}"),
        },
    })
}

/// The incremental query engine: caches per-bank plane data (query-tail
/// coefficients, axis columns) and per-step base state (projections,
/// squared norm), and turns a [`CandidateSet`] into one query bucket per
/// `(probe, row)` pair. One engine serves one bank; the base cache
/// invalidates itself whenever the base slice changes, so optimizers
/// just call [`Self::probe_buckets`] every step.
#[derive(Debug)]
pub struct QueryEngine {
    rows: usize,
    p: usize,
    /// Head dimension the engine slices candidates to (`bank.dim()` —
    /// the classifier's feature dim, the regression sketch's full
    /// augmented dim).
    dim: usize,
    kernel: Kernel,
    radius: f64,
    /// Query-side tail coefficient per plane, `[R * p]`.
    tail_q: Vec<f64>,
    /// Cached base head (validates the per-step cache).
    base: Vec<f64>,
    base_valid: bool,
    /// Cached base head projections, `[R * p]`.
    base_proj: Vec<f64>,
    base_norm_sq: f64,
    /// Axis columns `W[:, k]`, cached across steps (coordinate descent
    /// revisits every coordinate each sweep). A BTreeMap keeps the
    /// cache's iteration order deterministic (stormlint:
    /// `randomized-hasher`) — lookups here are O(log sweeps), dwarfed by
    /// the column fills they cache.
    axis_cols: BTreeMap<usize, Vec<f64>>,
    /// Per-set direction state (projection, `<base, u>`, `||u||^2`).
    dir_proj: Vec<Vec<f64>>,
    dir_dot: Vec<f64>,
    dir_norm_sq: Vec<f64>,
    /// Per-probe projection scratch, `[R * p]`.
    proj: Vec<f64>,
    /// Output buckets, probe-major `[probes * R]`.
    buckets: Vec<usize>,
}

impl QueryEngine {
    /// Build an engine for `bank`, caching its query-tail coefficients.
    pub fn new(bank: &HashBank) -> Self {
        let mut tail_q = Vec::new();
        bank.query_tail_coeffs(&mut tail_q);
        QueryEngine {
            rows: bank.rows(),
            p: bank.bits() as usize,
            dim: bank.dim(),
            kernel: simd::kernel(),
            radius: crate::data::scale::query_radius(),
            tail_q,
            base: Vec::new(),
            base_valid: false,
            base_proj: Vec::new(),
            base_norm_sq: 0.0,
            axis_cols: BTreeMap::new(),
            dir_proj: Vec::new(),
            dir_dot: Vec::new(),
            dir_norm_sq: Vec::new(),
            proj: Vec::new(),
            buckets: Vec::new(),
        }
    }

    /// Query buckets for every probe of `set` against `bank`, returned
    /// probe-major: bucket of probe `i` in row `r` at `[i * rows + r]`.
    /// The base pass (`O(R * p * d)`) runs only when the base slice
    /// changed since the last call; every probe after that costs
    /// `O(R * p)` (plus one `O(R * p * d)` projection per *direction*,
    /// shared by its antithetic pair).
    pub fn probe_buckets(&mut self, bank: &HashBank, set: &CandidateSet) -> &[usize] {
        assert_eq!(bank.rows(), self.rows, "engine bound to a different bank geometry");
        assert_eq!(bank.bits() as usize, self.p, "engine bound to a different bank geometry");
        assert_eq!(bank.dim(), self.dim, "engine bound to a different bank geometry");
        assert!(set.base.len() >= self.dim, "candidate base shorter than bank dim");
        let base = &set.base[..self.dim];
        if !self.base_valid || self.base != base {
            bank.project_all(base, &mut self.base_proj);
            self.base_norm_sq = dot(base, base);
            self.base.clear();
            self.base.extend_from_slice(base);
            self.base_valid = true;
        }
        // Per-set direction state: one head projection per direction,
        // shared by every probe that references it.
        self.dir_proj.resize(set.dirs.len(), Vec::new());
        self.dir_dot.clear();
        self.dir_norm_sq.clear();
        for (i, u) in set.dirs.iter().enumerate() {
            assert!(u.len() >= self.dim, "direction shorter than bank dim");
            let head = &u[..self.dim];
            let mut proj = std::mem::take(&mut self.dir_proj[i]);
            bank.project_all(head, &mut proj);
            self.dir_proj[i] = proj;
            self.dir_dot.push(dot(base, head));
            self.dir_norm_sq.push(dot(head, head));
        }
        self.buckets.clear();
        self.buckets.resize(set.probes.len() * self.rows, 0);
        let mut out = std::mem::take(&mut self.buckets);
        for (i, probe) in set.probes.iter().enumerate() {
            let slot = &mut out[i * self.rows..(i + 1) * self.rows];
            match *probe {
                Probe::Base => self.fold_base(slot),
                // An axis probe outside the engine's head (the
                // classifier's label slot) or one that re-states the
                // base value leaves the head — and so the buckets —
                // exactly equal to the base's.
                Probe::Axis { k, value } if k >= self.dim || value == self.base[k] => {
                    self.fold_base(slot)
                }
                Probe::Axis { k, value } => {
                    let col = match self.axis_cols.entry(k) {
                        std::collections::btree_map::Entry::Occupied(e) => e.into_mut(),
                        std::collections::btree_map::Entry::Vacant(e) => {
                            let mut col = Vec::new();
                            bank.head_column(k, &mut col);
                            e.insert(col)
                        }
                    };
                    self.proj.clear();
                    self.proj.extend_from_slice(&self.base_proj);
                    simd::axpy(self.kernel, &mut self.proj, value - self.base[k], col);
                    let norm_sq = (self.base_norm_sq - self.base[k] * self.base[k]
                        + value * value)
                        .max(0.0);
                    fold_rows(
                        self.rows, self.p, self.radius, &self.tail_q, &self.proj, norm_sq, slot,
                    );
                }
                Probe::Dir { dir, step } => {
                    self.proj.clear();
                    self.proj.extend_from_slice(&self.base_proj);
                    simd::axpy(self.kernel, &mut self.proj, step, &self.dir_proj[dir]);
                    let norm_sq = (self.base_norm_sq
                        + 2.0 * step * self.dir_dot[dir]
                        + step * step * self.dir_norm_sq[dir])
                        .max(0.0);
                    fold_rows(
                        self.rows, self.p, self.radius, &self.tail_q, &self.proj, norm_sq, slot,
                    );
                }
            }
        }
        self.buckets = out;
        &self.buckets
    }

    /// Fold the cached base projections into `slot` (base-probe path,
    /// reusing the cached squared norm exactly).
    fn fold_base(&self, slot: &mut [usize]) {
        fold_rows(
            self.rows,
            self.p,
            self.radius,
            &self.tail_q,
            &self.base_proj,
            self.base_norm_sq,
            slot,
        );
    }
}

/// Sign-fold one candidate's per-plane projections into per-row query
/// buckets: resolve the unit-ball rescale `s` and MIPS tail from the
/// squared norm, then `bit j = [s * proj[r * p + j] + w_q * tail >= 0]`
/// — the same decision [`HashBank::query_bucket`] makes on the dense
/// vector, with the scale applied to the accumulated projection instead
/// of elementwise.
fn fold_rows(
    rows: usize,
    p: usize,
    radius: f64,
    tail_q: &[f64],
    proj: &[f64],
    norm_sq: f64,
    slot: &mut [usize],
) {
    let n = norm_sq.sqrt();
    let (s, tail) = if n <= radius {
        (1.0, (1.0 - norm_sq).max(0.0).sqrt())
    } else {
        let s = radius / n;
        (s, (1.0 - s * s * norm_sq).max(0.0).sqrt())
    };
    for (r, h) in slot.iter_mut().enumerate().take(rows) {
        let mut bits = 0usize;
        for j in 0..p {
            if s * proj[r * p + j] + tail_q[r * p + j] * tail >= 0.0 {
                bits |= 1 << j;
            }
        }
        *h = bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::prp::PairedRandomProjection;
    use crate::testing::{cases, gen_ball_point, gen_dim};
    use crate::util::mathx::norm2;

    fn mk_bank(family: usize, dim: usize, p: u32, rows: usize, seed: u64) -> HashBank {
        let seeds: Vec<u64> = (0..rows as u64)
            .map(|r| seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(r))
            .collect();
        match family {
            0 => {
                let hashes: Vec<PairedRandomProjection> =
                    seeds.iter().map(|&s| PairedRandomProjection::new(dim, p, s)).collect();
                HashBank::from_rows(&hashes)
            }
            1 => HashBank::sparse_from_seeds(dim, p, &seeds, 300),
            _ => HashBank::hadamard_from_seeds(dim, p, &seeds),
        }
    }

    /// The dense oracle: materialize, rescale elementwise, hash each row.
    fn dense_buckets(bank: &HashBank, set: &CandidateSet) -> Vec<usize> {
        let mut cands = Vec::new();
        set.materialize(&mut cands);
        let radius = crate::data::scale::query_radius();
        let mut out = Vec::new();
        for q in &cands {
            let head = &q[..bank.dim()];
            let n = norm2(head);
            let scaled: Vec<f64> = if n <= radius {
                head.to_vec()
            } else {
                head.iter().map(|v| v * radius / n).collect()
            };
            let tail = HashBank::mips_tail(&scaled);
            for r in 0..bank.rows() {
                out.push(bank.query_bucket(r, &scaled, tail));
            }
        }
        out
    }

    #[test]
    fn materialize_reproduces_manual_construction_bitwise() {
        cases(30, 71, |rng, case| {
            let dim = gen_dim(rng, 2, 10);
            let base = gen_ball_point(rng, dim, 0.8);
            let dir = gen_ball_point(rng, dim, 1.0);
            let probes = [
                Probe::Base,
                Probe::Axis { k: case % dim, value: 0.25 },
                Probe::Dir { dir: 0, step: 0.1 },
                Probe::Dir { dir: 0, step: -0.1 },
            ];
            let dirs = [dir.clone()];
            let set = CandidateSet { base: &base, dirs: &dirs, probes: &probes };
            let mut got = Vec::new();
            set.materialize(&mut got);
            let mut ax = base.clone();
            ax[case % dim] = 0.25;
            let mut plus = base.clone();
            axpy(&mut plus, 0.1, &dir);
            let mut minus = base.clone();
            axpy(&mut minus, -0.1, &dir);
            let want = vec![base.clone(), ax, plus, minus];
            assert_eq!(got, want);
        });
    }

    #[test]
    fn incremental_buckets_match_dense_oracle_every_family() {
        // Random continuous inputs: fp ties are measure-zero, so the
        // rank-1 path must reproduce the dense-materialized buckets
        // exactly — in and out of the unit ball, axis and direction
        // probes, base re-evaluation included.
        cases(40, 72, |rng, case| {
            let dim = gen_dim(rng, 2, 16);
            let p = 1 + (case % 8) as u32;
            let family = case % 3;
            let bank = mk_bank(family, dim, p, 4, case as u64 ^ 0xA11CE);
            let mut base = gen_ball_point(rng, dim, 0.8);
            if case % 4 == 0 {
                // Out-of-ball base: the rescale path on every probe.
                for v in &mut base {
                    *v *= 5.0;
                }
            }
            let dirs = vec![gen_ball_point(rng, dim, 1.0), gen_ball_point(rng, dim, 1.0)];
            let probes = [
                Probe::Base,
                Probe::Axis { k: case % dim, value: 0.5 },
                Probe::Axis { k: (case + 1) % dim, value: base[(case + 1) % dim] },
                Probe::Dir { dir: 0, step: 0.2 },
                Probe::Dir { dir: 0, step: -0.2 },
                Probe::Dir { dir: 1, step: 1.5 },
            ];
            let set = CandidateSet { base: &base, dirs: &dirs, probes: &probes };
            let mut engine = QueryEngine::new(&bank);
            let got = engine.probe_buckets(&bank, &set).to_vec();
            let want = dense_buckets(&bank, &set);
            assert_eq!(got, want, "family {} dim {dim} p {p}", bank.family());
            // Second call with the same base hits the cache — identical.
            assert_eq!(engine.probe_buckets(&bank, &set), &want[..]);
        });
    }

    #[test]
    fn engine_revalidates_when_the_base_moves() {
        let bank = mk_bank(0, 6, 4, 3, 99);
        let mut engine = QueryEngine::new(&bank);
        let mut rng = crate::util::rng::Xoshiro256::new(5);
        let mut base = gen_ball_point(&mut rng, 6, 0.7);
        let probes = [Probe::Base, Probe::Axis { k: 2, value: 0.3 }];
        for step in 0..4 {
            let set = CandidateSet { base: &base, dirs: &[], probes: &probes };
            let got = engine.probe_buckets(&bank, &set).to_vec();
            assert_eq!(got, dense_buckets(&bank, &set), "step {step}");
            // Move the base like an optimizer accepting a probe.
            base[step % 6] += 0.05;
        }
    }

    #[test]
    fn dyadic_inputs_are_bit_identical_to_the_dense_path() {
        // Coarse dyadic-rational coordinates, ±1 sparse planes, in-ball
        // candidates: every product and sum in both paths is exactly
        // representable, so this is bit-identity, not just tie-free
        // agreement. (The general-case guarantee is exactness up to
        // measure-zero fp ties; here the ties cannot happen at all.)
        let dim = 8;
        let bank = mk_bank(1, dim, 6, 5, 0xD7AD1C);
        let base: Vec<f64> = (0..dim).map(|i| (i as f64 - 3.0) / 16.0).collect();
        let dirs: Vec<Vec<f64>> =
            vec![(0..dim).map(|i| if i % 2 == 0 { 0.25 } else { -0.125 }).collect()];
        let probes = [
            Probe::Base,
            Probe::Axis { k: 1, value: 0.375 },
            Probe::Axis { k: 5, value: -0.5 },
            Probe::Dir { dir: 0, step: 0.25 },
            Probe::Dir { dir: 0, step: -0.25 },
        ];
        let set = CandidateSet { base: &base, dirs: &dirs, probes: &probes };
        assert!(norm2(&base) <= crate::data::scale::query_radius(), "test must stay in-ball");
        let mut engine = QueryEngine::new(&bank);
        assert_eq!(engine.probe_buckets(&bank, &set), dense_buckets(&bank, &set));
    }

    #[test]
    fn axis_probe_beyond_head_dim_folds_to_base() {
        // The classifier's label slot: an axis probe at k >= bank.dim()
        // cannot change the head, so its buckets equal the base's.
        let bank = mk_bank(0, 4, 3, 3, 7);
        let mut rng = crate::util::rng::Xoshiro256::new(9);
        let mut base = gen_ball_point(&mut rng, 4, 0.6);
        base.push(-1.0); // augmented label slot past the bank head
        let probes = [Probe::Base, Probe::Axis { k: 4, value: 2.5 }];
        let set = CandidateSet { base: &base, dirs: &[], probes: &probes };
        let mut engine = QueryEngine::new(&bank);
        let got = engine.probe_buckets(&bank, &set);
        assert_eq!(&got[..3], &got[3..6]);
    }
}
