//! Signed random projections (SRP): the angular LSH family of
//! Goemans–Williamson / Charikar. A p-bit SRP draws p gaussian hyperplanes
//! `w_j ~ N(0, I_d)` and maps `x` to the integer whose j-th bit is
//! `sign(<w_j, x>)`. Two vectors collide on one bit with probability
//! `1 - angle(x, y)/pi`; the p-bit collision probability is that raised to
//! the p-th power.

use super::{CollisionProbability, LshFunction};
use crate::util::mathx::{acos_clamped, dot, norm2};
use crate::util::rng::{Rng, Xoshiro256};

/// A p-bit signed random projection hash over `R^d`.
///
/// Hyperplanes are stored *flat* (row-major `[p, d]` in one contiguous
/// buffer): the hash inner loop is `p` back-to-back dot products, and a
/// contiguous layout lets the compiler keep them vectorized instead of
/// chasing per-plane allocations (§Perf).
#[derive(Clone, Debug)]
pub struct SignedRandomProjection {
    /// Hyperplane normals, row-major `[p, d]`, flattened.
    flat: Vec<f64>,
    p: u32,
    dim: usize,
}

impl SignedRandomProjection {
    /// Draw a fresh p-bit SRP for dimension `d` from `seed`.
    pub fn new(dim: usize, p: u32, seed: u64) -> Self {
        assert!(p >= 1 && p <= 24, "p must be in 1..=24");
        assert!(dim >= 1);
        let mut rng = Xoshiro256::new(seed);
        let flat = rng.gaussian_vec(dim * p as usize);
        SignedRandomProjection { flat, p, dim }
    }

    /// Number of hyperplanes p.
    pub fn bits(&self) -> u32 {
        self.p
    }

    /// Hyperplane `j` as a slice.
    #[inline]
    pub fn plane(&self, j: usize) -> &[f64] {
        &self.flat[j * self.dim..(j + 1) * self.dim]
    }

    /// The raw projection values `<w_j, x>` (used by the linear-optimization
    /// training mode, which needs more than the sign).
    pub fn project(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.dim);
        (0..self.p as usize).map(|j| dot(self.plane(j), x)).collect()
    }

    /// Access to the hyperplanes (the AOT compile path serializes them so
    /// the XLA artifacts hash identically to the rust path).
    pub fn planes(&self) -> Vec<Vec<f64>> {
        (0..self.p as usize).map(|j| self.plane(j).to_vec()).collect()
    }

    /// The bucket of the antipode `-x`: all sign bits flip, so this is the
    /// bitwise complement within the p-bit range. PRP exploits this to get
    /// the second insert location for free.
    pub fn antipodal_bucket(&self, bucket: usize) -> usize {
        !bucket & (self.range() - 1)
    }
}

impl LshFunction for SignedRandomProjection {
    fn hash(&self, x: &[f64]) -> usize {
        assert_eq!(x.len(), self.dim, "SRP dim mismatch");
        let mut h = 0usize;
        for j in 0..self.p as usize {
            // Tie-break sign(0) as 1 so the bucket map is total.
            if dot(self.plane(j), x) >= 0.0 {
                h |= 1 << j;
            }
        }
        h
    }

    fn range(&self) -> usize {
        1usize << self.p
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

impl CollisionProbability for SignedRandomProjection {
    /// `(1 - angle(x,y)/pi)^p` — the *normalized* (angular) collision
    /// probability. For the unnormalized inner-product version see
    /// [`crate::lsh::asym`].
    fn collision_probability(&self, x: &[f64], y: &[f64]) -> f64 {
        let nx = norm2(x);
        let ny = norm2(y);
        if nx == 0.0 || ny == 0.0 {
            // Degenerate: the zero vector collides with everything under
            // our sign(0)=1 tie-break.
            return 1.0;
        }
        let cos = (dot(x, y) / (nx * ny)).clamp(-1.0, 1.0);
        let single = 1.0 - acos_clamped(cos) / std::f64::consts::PI;
        single.powi(self.bits() as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::empirical_collision;
    use crate::testing::{assert_close, cases};

    #[test]
    fn hash_in_range_and_deterministic() {
        let l = SignedRandomProjection::new(5, 4, 42);
        let x = vec![0.3, -0.1, 0.7, 0.0, -0.5];
        let h = l.hash(&x);
        assert!(h < l.range());
        assert_eq!(h, l.hash(&x));
        assert_eq!(l.range(), 16);
    }

    #[test]
    fn scale_invariance() {
        // SRP depends only on direction.
        let l = SignedRandomProjection::new(4, 6, 1);
        cases(50, 2, |rng, _| {
            let x = crate::testing::gen_ball_point(rng, 4, 1.0);
            if crate::util::mathx::norm2(&x) < 1e-6 {
                return;
            }
            let scaled: Vec<f64> = x.iter().map(|v| v * 7.5).collect();
            assert_eq!(l.hash(&x), l.hash(&scaled));
        });
    }

    #[test]
    fn antipodal_bucket_is_hash_of_negation() {
        cases(50, 3, |rng, case| {
            let l = SignedRandomProjection::new(6, 5, case as u64);
            let x = crate::testing::gen_ball_point(rng, 6, 1.0);
            let neg: Vec<f64> = x.iter().map(|v| -v).collect();
            // Ties (exact zeros) break the complement identity; gaussian
            // projections of continuous points are a.s. nonzero.
            assert_eq!(l.antipodal_bucket(l.hash(&x)), l.hash(&neg));
        });
    }

    #[test]
    fn collision_probability_matches_empirical() {
        let x = vec![1.0, 0.0, 0.0];
        let y = vec![0.6, 0.8, 0.0]; // angle = acos(0.6)
        let probe = SignedRandomProjection::new(3, 2, 0);
        let analytic = probe.collision_probability(&x, &y);
        let emp = empirical_collision(
            |seed| SignedRandomProjection::new(3, 2, seed),
            &x,
            &y,
            20_000,
        );
        assert_close(emp, analytic, 0.015);
    }

    #[test]
    fn identical_points_always_collide() {
        let l = SignedRandomProjection::new(4, 8, 9);
        let x = vec![0.2, 0.4, -0.1, 0.9];
        assert_eq!(l.hash(&x), l.hash(&x.clone()));
        assert_close(l.collision_probability(&x, &x), 1.0, 1e-12);
    }

    #[test]
    fn orthogonal_points_collide_at_half_per_bit() {
        let l = SignedRandomProjection::new(2, 1, 0);
        let x = vec![1.0, 0.0];
        let y = vec![0.0, 1.0];
        assert_close(l.collision_probability(&x, &y), 0.5, 1e-12);
    }

    #[test]
    fn projection_values_match_sign_bits() {
        let l = SignedRandomProjection::new(3, 4, 5);
        let x = vec![0.1, -0.7, 0.4];
        let proj = l.project(&x);
        let h = l.hash(&x);
        for (j, p) in proj.iter().enumerate() {
            assert_eq!((h >> j) & 1 == 1, *p >= 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let l = SignedRandomProjection::new(3, 2, 0);
        l.hash(&[1.0, 2.0]);
    }
}
