//! Locality-sensitive hashing.
//!
//! The sketch is only as good as its hash family: STORM's loss estimators
//! *are* LSH collision probabilities (Theorem 1). This module provides:
//!
//! * [`srp`] — signed random projections (p-bit angular LSH), the paper's
//!   workhorse family;
//! * [`asym`] — the asymmetric inner-product transform (Shrivastava & Li
//!   MIPS hashing) that lets a query `theta` collide with data `[x, y]`
//!   according to their raw inner product;
//! * [`prp`] — paired random projections: the paper's regression
//!   construction that inserts `z` and `-z` so the combined collision
//!   probability is symmetric in `|<theta, z>|`;
//! * [`pstable`] — p-stable (Euclidean) LSH, used by the general RACE
//!   sketch for KDE-style estimates and in composition tests;
//! * [`compose`] — injective composition of two LSH functions whose
//!   collision probability is the *product* of the constituents
//!   (Theorem 1's multiplication closure);
//! * [`bank`] — the fused hash-bank kernel: all `R` rows' hyperplanes
//!   behind one family-dispatched engine, hashing both PRP arms from a
//!   single shared-projection pass (the batch insert/query hot path);
//! * [`simd`] — runtime-dispatched AVX2/SSE2/NEON projection kernels for
//!   the dense bank, vectorized across planes so they stay bit-identical
//!   to the scalar oracle;
//! * [`structured`] — structured hyperplane families (sparse Rademacher
//!   and fast-Hadamard SRP) that cut dense O(d)-per-plane projection cost
//!   to a few adds per nonzero / one O(d log d) transform per row;
//! * [`query`] — the rank-1 incremental query engine: caches the base
//!   iterate's per-plane projections and squared norm once per optimizer
//!   step and serves each candidate `theta~ + c * u` (or a single
//!   coordinate set to a value) as an O(R * p) update instead of an
//!   O(R * p * d) re-projection. Exact by linearity for every family;
//!   see the module docs for the floating-point tie discussion and the
//!   `STORM_QUERY_INCREMENTAL=off` escape hatch.
//!
//! **Hash families.** The sketch selects its hyperplane family through
//! `[storm] hash_family` (`dense` default — the paper's Gaussian SRP,
//! wire-golden-pinned; `sparse` — Achlioptas/Li-style sparse Rademacher;
//! `hadamard` — subsampled randomized Hadamard). All families draw from
//! the same per-row seed streams, so two sketches agree bucket-for-bucket
//! iff they share `(seed, hash_family)` — which is why
//! `StormConfig::merge_compatible` requires equal families, exactly like
//! equal tasks. The bank ([`bank::HashBank`]) is the single dispatch
//! point: constructors pick the family, and `data_pair` / `data_bucket` /
//! `query_bucket` serve every family behind one API.

pub mod srp;
pub mod asym;
pub mod prp;
pub mod pstable;
pub mod compose;
pub mod bank;
pub mod simd;
pub mod structured;
pub mod query;

/// A locality-sensitive hash function mapping vectors to bucket indices in
/// `[0, range)`.
pub trait LshFunction: Send + Sync {
    /// Hash one vector.
    fn hash(&self, x: &[f64]) -> usize;

    /// Number of distinct hash values.
    fn range(&self) -> usize;

    /// Input dimensionality this function expects.
    fn dim(&self) -> usize;
}

/// A family with a closed-form collision probability `k(x, y)` — the
/// quantity STORM sketches estimate sums of.
pub trait CollisionProbability {
    /// `Pr[l(x) = l(y)]` under a random draw of `l` from the family.
    fn collision_probability(&self, x: &[f64], y: &[f64]) -> f64;
}

/// Empirically estimate a collision probability by drawing `trials`
/// functions from a family constructor (test helper, exposed because the
/// python oracle cross-checks use it too).
pub fn empirical_collision<F, L>(mut make: F, x: &[f64], y: &[f64], trials: usize) -> f64
where
    F: FnMut(u64) -> L,
    L: LshFunction,
{
    let mut hits = 0usize;
    for t in 0..trials {
        let l = make(t as u64);
        if l.hash(x) == l.hash(y) {
            hits += 1;
        }
    }
    hits as f64 / trials as f64
}
