//! Runtime-dispatched SIMD kernels for the fused hash bank's projection
//! + sign-fold hot path.
//!
//! The bank evaluates, per sketch row, `p` hyperplane projections of the
//! same example followed by a `>= 0` sign fold into a `p`-bit bucket.
//! These kernels vectorize **across planes**: lane `j` of a SIMD vector
//! owns plane `j`'s accumulator, the coordinate loop walks `i = 0..d`
//! sequentially broadcasting `z[i]`, and each lane performs exactly the
//! scalar sequence `acc += w_j[i] * z[i]` (separate multiply and add —
//! no FMA contraction, which would round once instead of twice).
//!
//! **Bit-identity contract.** Every per-plane sum reproduces the scalar
//! accumulation order term-for-term, so the SIMD path is bit-identical
//! to the scalar oracle, not merely close:
//!
//! * lane arithmetic (`mul`/`add`/`sub` on f64 lanes) is the same
//!   IEEE-754 operation as the scalar `*`/`+`/`-`;
//! * the accumulation *order* over `i` is identical per plane because
//!   lanes never mix coordinates — vectorization re-associates across
//!   planes (independent sums), never within one;
//! * the sign fold uses ordered greater-equal compares
//!   (`_CMP_GE_OQ` / `cmpge` / `vcgeq_f64`), matching the scalar
//!   `>= 0.0` decision on every input including `-0.0` (true) and NaN
//!   (false);
//! * movemask maps lane `j` to bit `j`, matching the scalar
//!   `bucket |= 1 << j` fold.
//!
//! The kernels read a **transposed** per-row plane layout
//! `t[i * p + j] = w_j[i]` (coordinate-major) so the per-coordinate load
//! of 2/4 adjacent planes is one unaligned vector load. Remainder lanes
//! (`p % lane_width`) fall through to a scalar loop over the same
//! transposed array.
//!
//! Kernel selection happens once per process ([`kernel`]): AVX2 when the
//! CPU reports it, else SSE2 (the x86-64 baseline); NEON on aarch64 (the
//! baseline there); scalar elsewhere — and always scalar under Miri,
//! which interprets no vendor intrinsics. Set `STORM_SIMD=off` (or
//! `scalar`) to force the scalar fallback — the CI `simd-off` leg runs
//! the whole suite this way to pin the fallback against the oracle.
//!
//! This module is the crate's **only** home for `unsafe`
//! (`#![deny(unsafe_code)]` at the crate root, stormlint's
//! `unsafe-outside-simd` rule): every site below carries a `// SAFETY:`
//! comment and `unsafe_op_in_unsafe_fn` is denied, so even inside
//! `unsafe fn` each operation sits in an audited `unsafe {}` block.

#![allow(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

use std::sync::OnceLock;

/// Which projection kernel the process resolved to (one of these per
/// process; see [`kernel`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar loop over the transposed layout (always
    /// available; forced by `STORM_SIMD=off|scalar`).
    Scalar,
    /// SSE2, 2 f64 lanes (the x86-64 baseline — no runtime detection
    /// needed).
    #[cfg(target_arch = "x86_64")]
    Sse2,
    /// AVX2, 4 f64 lanes (runtime-detected).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// NEON, 2 f64 lanes (the aarch64 baseline).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Kernel {
    /// Diagnostic name (`scalar` | `sse2` | `avx2` | `neon`).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Kernel::Sse2 => "sse2",
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => "neon",
        }
    }
}

fn detect() -> Kernel {
    // Miri interprets MIR, not vendor intrinsics: route dispatch to the
    // scalar oracle so `cargo miri test` runs the whole suite.
    #[cfg(miri)]
    {
        Kernel::Scalar
    }
    #[cfg(all(not(miri), target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Kernel::Avx2;
        }
        Kernel::Sse2
    }
    #[cfg(all(not(miri), target_arch = "aarch64"))]
    {
        Kernel::Neon
    }
    #[cfg(not(any(miri, target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Kernel::Scalar
    }
}

static KERNEL: OnceLock<Kernel> = OnceLock::new();

/// The process-wide kernel, resolved once: honours `STORM_SIMD`
/// (`off`/`scalar` force the scalar path, `auto`/`on` re-enable
/// detection, anything else panics loudly rather than silently running
/// the wrong kernel), then falls back to CPU feature detection.
pub fn kernel() -> Kernel {
    *KERNEL.get_or_init(|| match std::env::var("STORM_SIMD") {
        Err(_) => detect(),
        Ok(v) => match v.trim() {
            "off" | "scalar" => Kernel::Scalar,
            "" | "auto" | "on" => detect(),
            other => panic!("STORM_SIMD must be off|scalar|auto|on, got {other:?}"),
        },
    })
}

/// Scalar reference over the transposed layout, from plane `start` to
/// `p` — both the `Kernel::Scalar` body and the remainder-lane handler
/// for the vector kernels.
#[inline]
fn data_pair_tail_scalar(
    trow: &[f64],
    p: usize,
    z: &[f64],
    tail: f64,
    start: usize,
) -> (usize, usize) {
    let d = z.len();
    let mut pos = 0usize;
    let mut neg = 0usize;
    for j in start..p {
        let mut s = 0.0;
        for (i, &zi) in z.iter().enumerate() {
            s += trow[i * p + j] * zi;
        }
        let t = trow[(d + 1) * p + j] * tail;
        if s + t >= 0.0 {
            pos |= 1 << j;
        }
        if t - s >= 0.0 {
            neg |= 1 << j;
        }
    }
    (pos, neg)
}

/// Scalar single-side fold (tail coefficient row `tail_row`: `d` for the
/// query side, `d + 1` for the data side), planes `start..p`.
#[inline]
fn side_bucket_tail_scalar(
    trow: &[f64],
    p: usize,
    v: &[f64],
    tail: f64,
    tail_row: usize,
    start: usize,
) -> usize {
    let mut h = 0usize;
    for j in start..p {
        let mut s = 0.0;
        for (i, &vi) in v.iter().enumerate() {
            s += trow[i * p + j] * vi;
        }
        if s + trow[tail_row * p + j] * tail >= 0.0 {
            h |= 1 << j;
        }
    }
    h
}

/// Scalar axpy over elements `start..`, shared by the `Kernel::Scalar`
/// body and the vector kernels' remainder lanes.
#[inline]
fn axpy_scalar(y: &mut [f64], a: f64, x: &[f64], start: usize) {
    for (yj, &xj) in y[start..].iter_mut().zip(&x[start..]) {
        *yj += a * xj;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    // SAFETY: `#[target_feature(enable = "avx2")]` makes this fn unsafe;
    // the only callers are the `data_pair_t` dispatch arms, which reach
    // it solely when `detect()` saw `is_x86_feature_detected!("avx2")`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn data_pair_avx2(trow: &[f64], p: usize, z: &[f64], tail: f64) -> (usize, usize) {
        // SAFETY: the dispatcher asserts trow.len() == (d + 2) * p. The
        // deepest 4-wide unaligned load starts at (d + 1) * p + j with
        // j + 4 <= p, i.e. ends at (d + 2) * p - 1 — in bounds; AVX2 is
        // available per the fn's contract above.
        unsafe {
            let d = z.len();
            let base = trow.as_ptr();
            let zero = _mm256_setzero_pd();
            let tailv = _mm256_set1_pd(tail);
            let mut pos = 0usize;
            let mut neg = 0usize;
            let mut j = 0usize;
            while j + 4 <= p {
                let mut acc = zero;
                for (i, &zi) in z.iter().enumerate() {
                    let w = _mm256_loadu_pd(base.add(i * p + j));
                    acc = _mm256_add_pd(acc, _mm256_mul_pd(w, _mm256_set1_pd(zi)));
                }
                let t = _mm256_mul_pd(_mm256_loadu_pd(base.add((d + 1) * p + j)), tailv);
                let pm =
                    _mm256_movemask_pd(_mm256_cmp_pd::<_CMP_GE_OQ>(_mm256_add_pd(acc, t), zero));
                let nm =
                    _mm256_movemask_pd(_mm256_cmp_pd::<_CMP_GE_OQ>(_mm256_sub_pd(t, acc), zero));
                pos |= (pm as usize) << j;
                neg |= (nm as usize) << j;
                j += 4;
            }
            let (rp, rn) = super::data_pair_tail_scalar(trow, p, z, tail, j);
            (pos | rp, neg | rn)
        }
    }

    // SAFETY: `#[target_feature(enable = "avx2")]` makes this fn unsafe;
    // only the dispatch arms call it, after runtime AVX2 detection.
    #[target_feature(enable = "avx2")]
    pub unsafe fn side_bucket_avx2(
        trow: &[f64],
        p: usize,
        v: &[f64],
        tail: f64,
        tail_row: usize,
    ) -> usize {
        // SAFETY: the dispatcher asserts trow.len() == (v.len() + 2) * p
        // and tail_row <= v.len() + 1, so the deepest 4-wide load ends at
        // (v.len() + 2) * p - 1 — in bounds; AVX2 is available per the
        // fn's contract above.
        unsafe {
            let base = trow.as_ptr();
            let zero = _mm256_setzero_pd();
            let tailv = _mm256_set1_pd(tail);
            let mut h = 0usize;
            let mut j = 0usize;
            while j + 4 <= p {
                let mut acc = zero;
                for (i, &vi) in v.iter().enumerate() {
                    let w = _mm256_loadu_pd(base.add(i * p + j));
                    acc = _mm256_add_pd(acc, _mm256_mul_pd(w, _mm256_set1_pd(vi)));
                }
                let t = _mm256_mul_pd(_mm256_loadu_pd(base.add(tail_row * p + j)), tailv);
                let m =
                    _mm256_movemask_pd(_mm256_cmp_pd::<_CMP_GE_OQ>(_mm256_add_pd(acc, t), zero));
                h |= (m as usize) << j;
                j += 4;
            }
            h | super::side_bucket_tail_scalar(trow, p, v, tail, tail_row, j)
        }
    }

    // SAFETY: `#[target_feature(enable = "sse2")]` makes this fn unsafe
    // even though SSE2 is the x86-64 baseline — every x86-64 CPU may
    // call it; the dispatch arms are the only callers.
    #[target_feature(enable = "sse2")]
    pub unsafe fn data_pair_sse2(trow: &[f64], p: usize, z: &[f64], tail: f64) -> (usize, usize) {
        // SAFETY: same bounds argument as the AVX2 twin with 2-wide
        // loads: the deepest load ends at (d + 2) * p - 1, within the
        // dispatcher-asserted trow length; SSE2 is baseline on x86-64.
        unsafe {
            let d = z.len();
            let base = trow.as_ptr();
            let zero = _mm_setzero_pd();
            let tailv = _mm_set1_pd(tail);
            let mut pos = 0usize;
            let mut neg = 0usize;
            let mut j = 0usize;
            while j + 2 <= p {
                let mut acc = zero;
                for (i, &zi) in z.iter().enumerate() {
                    let w = _mm_loadu_pd(base.add(i * p + j));
                    acc = _mm_add_pd(acc, _mm_mul_pd(w, _mm_set1_pd(zi)));
                }
                let t = _mm_mul_pd(_mm_loadu_pd(base.add((d + 1) * p + j)), tailv);
                let pm = _mm_movemask_pd(_mm_cmpge_pd(_mm_add_pd(acc, t), zero));
                let nm = _mm_movemask_pd(_mm_cmpge_pd(_mm_sub_pd(t, acc), zero));
                pos |= (pm as usize) << j;
                neg |= (nm as usize) << j;
                j += 2;
            }
            let (rp, rn) = super::data_pair_tail_scalar(trow, p, z, tail, j);
            (pos | rp, neg | rn)
        }
    }

    // SAFETY: `#[target_feature(enable = "sse2")]` — baseline on x86-64;
    // only the dispatch arms call it.
    #[target_feature(enable = "sse2")]
    pub unsafe fn side_bucket_sse2(
        trow: &[f64],
        p: usize,
        v: &[f64],
        tail: f64,
        tail_row: usize,
    ) -> usize {
        // SAFETY: same bounds argument as the AVX2 twin with 2-wide
        // loads over the dispatcher-asserted trow length; SSE2 is
        // baseline on x86-64.
        unsafe {
            let base = trow.as_ptr();
            let zero = _mm_setzero_pd();
            let tailv = _mm_set1_pd(tail);
            let mut h = 0usize;
            let mut j = 0usize;
            while j + 2 <= p {
                let mut acc = zero;
                for (i, &vi) in v.iter().enumerate() {
                    let w = _mm_loadu_pd(base.add(i * p + j));
                    acc = _mm_add_pd(acc, _mm_mul_pd(w, _mm_set1_pd(vi)));
                }
                let t = _mm_mul_pd(_mm_loadu_pd(base.add(tail_row * p + j)), tailv);
                let m = _mm_movemask_pd(_mm_cmpge_pd(_mm_add_pd(acc, t), zero));
                h |= (m as usize) << j;
                j += 2;
            }
            h | super::side_bucket_tail_scalar(trow, p, v, tail, tail_row, j)
        }
    }

    // SAFETY: `#[target_feature(enable = "avx2")]` — only the `axpy`
    // dispatch arm calls it, after runtime AVX2 detection.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(y: &mut [f64], a: f64, x: &[f64]) {
        // SAFETY: the dispatcher asserts y.len() == x.len(); every
        // 4-wide load/store covers j..j + 4 with j + 4 <= n, so both
        // pointers stay inside their slices. y and x are distinct
        // borrows (&mut vs &), so the store never aliases the loads.
        unsafe {
            let n = y.len();
            let yp = y.as_mut_ptr();
            let xp = x.as_ptr();
            let av = _mm256_set1_pd(a);
            let mut j = 0usize;
            while j + 4 <= n {
                let acc = _mm256_add_pd(
                    _mm256_loadu_pd(yp.add(j)),
                    _mm256_mul_pd(av, _mm256_loadu_pd(xp.add(j))),
                );
                _mm256_storeu_pd(yp.add(j), acc);
                j += 4;
            }
            super::axpy_scalar(y, a, x, j);
        }
    }

    // SAFETY: `#[target_feature(enable = "sse2")]` — baseline on x86-64;
    // only the `axpy` dispatch arm calls it.
    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy_sse2(y: &mut [f64], a: f64, x: &[f64]) {
        // SAFETY: same argument as the AVX2 twin with 2-wide loads and
        // stores bounded by j + 2 <= n over non-aliasing slices.
        unsafe {
            let n = y.len();
            let yp = y.as_mut_ptr();
            let xp = x.as_ptr();
            let av = _mm_set1_pd(a);
            let mut j = 0usize;
            while j + 2 <= n {
                let acc =
                    _mm_add_pd(_mm_loadu_pd(yp.add(j)), _mm_mul_pd(av, _mm_loadu_pd(xp.add(j))));
                _mm_storeu_pd(yp.add(j), acc);
                j += 2;
            }
            super::axpy_scalar(y, a, x, j);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    // SAFETY: NEON intrinsics are unsafe fns; NEON is the aarch64
    // baseline, so this is callable from any aarch64 context. The input
    // is a plain SIMD value — no memory access at all.
    #[inline]
    unsafe fn ge_zero_mask(v: float64x2_t) -> usize {
        // SAFETY: pure lane compare and extract on an owned vector
        // value; no pointers involved.
        unsafe {
            let m = vcgeq_f64(v, vdupq_n_f64(0.0));
            ((vgetq_lane_u64::<0>(m) & 1) | ((vgetq_lane_u64::<1>(m) & 1) << 1)) as usize
        }
    }

    // SAFETY: `#[target_feature(enable = "neon")]` — baseline on
    // aarch64; only the dispatch arms call it.
    #[target_feature(enable = "neon")]
    pub unsafe fn data_pair_neon(trow: &[f64], p: usize, z: &[f64], tail: f64) -> (usize, usize) {
        // SAFETY: the dispatcher asserts trow.len() == (d + 2) * p; the
        // deepest 2-wide load starts at (d + 1) * p + j with j + 2 <= p,
        // ending at (d + 2) * p - 1 — in bounds. NEON is baseline.
        unsafe {
            let d = z.len();
            let base = trow.as_ptr();
            let mut pos = 0usize;
            let mut neg = 0usize;
            let mut j = 0usize;
            while j + 2 <= p {
                let mut acc = vdupq_n_f64(0.0);
                for (i, &zi) in z.iter().enumerate() {
                    let w = vld1q_f64(base.add(i * p + j));
                    acc = vaddq_f64(acc, vmulq_n_f64(w, zi));
                }
                let t = vmulq_n_f64(vld1q_f64(base.add((d + 1) * p + j)), tail);
                pos |= ge_zero_mask(vaddq_f64(acc, t)) << j;
                neg |= ge_zero_mask(vsubq_f64(t, acc)) << j;
                j += 2;
            }
            let (rp, rn) = super::data_pair_tail_scalar(trow, p, z, tail, j);
            (pos | rp, neg | rn)
        }
    }

    // SAFETY: `#[target_feature(enable = "neon")]` — baseline on
    // aarch64; only the dispatch arms call it.
    #[target_feature(enable = "neon")]
    pub unsafe fn side_bucket_neon(
        trow: &[f64],
        p: usize,
        v: &[f64],
        tail: f64,
        tail_row: usize,
    ) -> usize {
        // SAFETY: the dispatcher asserts trow.len() == (v.len() + 2) * p
        // and tail_row <= v.len() + 1, bounding every 2-wide load by
        // (v.len() + 2) * p - 1. NEON is baseline.
        unsafe {
            let base = trow.as_ptr();
            let mut h = 0usize;
            let mut j = 0usize;
            while j + 2 <= p {
                let mut acc = vdupq_n_f64(0.0);
                for (i, &vi) in v.iter().enumerate() {
                    let w = vld1q_f64(base.add(i * p + j));
                    acc = vaddq_f64(acc, vmulq_n_f64(w, vi));
                }
                let t = vmulq_n_f64(vld1q_f64(base.add(tail_row * p + j)), tail);
                h |= ge_zero_mask(vaddq_f64(acc, t)) << j;
                j += 2;
            }
            h | super::side_bucket_tail_scalar(trow, p, v, tail, tail_row, j)
        }
    }

    // SAFETY: `#[target_feature(enable = "neon")]` — baseline on
    // aarch64; only the `axpy` dispatch arm calls it.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_neon(y: &mut [f64], a: f64, x: &[f64]) {
        // SAFETY: the dispatcher asserts y.len() == x.len(); loads and
        // stores cover j..j + 2 with j + 2 <= n over non-aliasing
        // slices (&mut vs &).
        unsafe {
            let n = y.len();
            let yp = y.as_mut_ptr();
            let xp = x.as_ptr();
            let mut j = 0usize;
            while j + 2 <= n {
                let acc = vaddq_f64(vld1q_f64(yp.add(j)), vmulq_n_f64(vld1q_f64(xp.add(j)), a));
                vst1q_f64(yp.add(j), acc);
                j += 2;
            }
            super::axpy_scalar(y, a, x, j);
        }
    }
}

/// Both PRP data buckets (`sign(s + t)`, `sign(t - s)` folds) for one
/// sketch row from its transposed plane block `trow`
/// (`trow[i * p + j] = w_j[i]`, length `(z.len() + 2) * p`), with the
/// precomputed MIPS `tail`. Dispatches on `k`; every kernel is
/// bit-identical to the scalar path (module docs).
#[inline]
pub fn data_pair_t(k: Kernel, trow: &[f64], p: usize, z: &[f64], tail: f64) -> (usize, usize) {
    debug_assert_eq!(trow.len(), (z.len() + 2) * p);
    match k {
        Kernel::Scalar => data_pair_tail_scalar(trow, p, z, tail, 0),
        // SAFETY: SSE2 is the x86-64 baseline; the slice-length contract
        // is the debug_assert above (and every caller builds trow that
        // way via the bank's transposed layout).
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse2 => unsafe { x86::data_pair_sse2(trow, p, z, tail) },
        // SAFETY: `Kernel::Avx2` exists only after
        // `is_x86_feature_detected!("avx2")` succeeded in `detect()`.
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { x86::data_pair_avx2(trow, p, z, tail) },
        // SAFETY: NEON is the aarch64 baseline.
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { arm::data_pair_neon(trow, p, z, tail) },
    }
}

/// One-side bucket (`sign(s + t)` fold) for one sketch row: `tail_row`
/// selects the augmented slot carrying the tail coefficient —
/// `v.len() + 1` for the data side, `v.len()` for the query side.
#[inline]
pub fn side_bucket_t(
    k: Kernel,
    trow: &[f64],
    p: usize,
    v: &[f64],
    tail: f64,
    tail_row: usize,
) -> usize {
    debug_assert_eq!(trow.len(), (v.len() + 2) * p);
    debug_assert!(tail_row == v.len() || tail_row == v.len() + 1);
    match k {
        Kernel::Scalar => side_bucket_tail_scalar(trow, p, v, tail, tail_row, 0),
        // SAFETY: SSE2 is the x86-64 baseline; slice-length contract per
        // the debug_asserts above.
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse2 => unsafe { x86::side_bucket_sse2(trow, p, v, tail, tail_row) },
        // SAFETY: `Kernel::Avx2` exists only after runtime AVX2
        // detection in `detect()`.
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { x86::side_bucket_avx2(trow, p, v, tail, tail_row) },
        // SAFETY: NEON is the aarch64 baseline.
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { arm::side_bucket_neon(trow, p, v, tail, tail_row) },
    }
}

/// In-place rank-1 update `y[j] += a * x[j]` over equal-length slices —
/// the per-plane axpy of the incremental query engine
/// ([`crate::lsh::query::QueryEngine`]). Lane arithmetic is a separate
/// multiply and add (no FMA contraction), and lanes never mix elements,
/// so every element is **bit-identical** to the scalar statement under
/// any kernel.
#[inline]
pub fn axpy(k: Kernel, y: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len(), "axpy length mismatch");
    match k {
        Kernel::Scalar => axpy_scalar(y, a, x, 0),
        // SAFETY: SSE2 is the x86-64 baseline; equal lengths per the
        // debug_assert above.
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse2 => unsafe { x86::axpy_sse2(y, a, x) },
        // SAFETY: `Kernel::Avx2` exists only after runtime AVX2
        // detection in `detect()`.
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { x86::axpy_avx2(y, a, x) },
        // SAFETY: NEON is the aarch64 baseline.
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { arm::axpy_neon(y, a, x) },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{cases, gen_ball_point, gen_dim};
    use crate::util::rng::Rng;

    /// Random transposed plane block for `p` planes over `d + 2` coords.
    fn gen_trow(rng: &mut crate::util::rng::Xoshiro256, d: usize, p: usize) -> Vec<f64> {
        (0..(d + 2) * p).map(|_| rng.gaussian()).collect()
    }

    #[test]
    fn detected_kernel_matches_scalar_bitwise_all_remainders() {
        // Sweep p across 1..=24 so the vector main loop AND every
        // remainder-lane count (p mod 2, p mod 4) are exercised, at
        // small and SIMD-friendly-large dims.
        let k = kernel();
        cases(40, 25, |rng, case| {
            let d = if case % 2 == 0 { gen_dim(rng, 1, 12) } else { 64 + (case % 200) };
            let p = 1 + (case % 24);
            let trow = gen_trow(rng, d, p);
            let z = gen_ball_point(rng, d, 0.95);
            let tail = rng.uniform();
            assert_eq!(
                data_pair_t(k, &trow, p, &z, tail),
                data_pair_t(Kernel::Scalar, &trow, p, &z, tail),
                "kernel {} diverged from scalar (d={d} p={p})",
                k.name()
            );
            for tail_row in [d, d + 1] {
                assert_eq!(
                    side_bucket_t(k, &trow, p, &z, tail, tail_row),
                    side_bucket_t(Kernel::Scalar, &trow, p, &z, tail, tail_row),
                    "kernel {} side fold diverged (d={d} p={p} tail_row={tail_row})",
                    k.name()
                );
            }
        });
    }

    #[test]
    fn scalar_fold_tie_breaks_zero_as_one() {
        // A plane whose projection is exactly 0.0 must set its bit
        // (sign(0) = 1), and -0.0 compares >= 0.0 too.
        let p = 3;
        let d = 1;
        // Planes: w_0 = [0, 0, 0] (s + t = 0.0), w_1 = [-1, 0, 0] with
        // z = [0.0] (s = -0.0), w_2 = [1, 0, -1] (t negative).
        let mut trow = vec![0.0; (d + 2) * p];
        trow[0 * p + 1] = -1.0;
        trow[0 * p + 2] = 1.0;
        trow[(d + 1) * p + 2] = -1.0;
        let z = [0.0];
        let (pos, neg) = data_pair_t(Kernel::Scalar, &trow, p, &z, 1.0);
        assert_eq!(pos & 1, 1, "exact zero must hash as positive");
        assert_eq!(pos & 2, 2, "-0.0 head must still compare >= 0");
        assert_eq!(pos & 4, 0, "negative tail term must clear the bit");
        assert_eq!(neg & 1, 1);
    }

    #[test]
    fn kernel_name_is_stable() {
        assert_eq!(Kernel::Scalar.name(), "scalar");
        assert!(!kernel().name().is_empty());
    }

    #[test]
    fn miri_and_simd_off_route_to_scalar() {
        // Under Miri the dispatch must resolve scalar (no vendor
        // intrinsics in the interpreter); elsewhere this just pins the
        // STORM_SIMD=scalar contract used by the simd-off CI leg.
        if cfg!(miri) {
            assert_eq!(kernel(), Kernel::Scalar);
        }
    }

    #[test]
    fn axpy_matches_scalar_bitwise_all_remainders() {
        // Lengths 0..=19 cover the vector main loop and every remainder
        // count for 2- and 4-lane kernels.
        let k = kernel();
        cases(40, 29, |rng, case| {
            let n = case % 20;
            let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let y0: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let a = rng.gaussian();
            let mut y_k = y0.clone();
            axpy(k, &mut y_k, a, &x);
            let mut y_s = y0.clone();
            axpy_scalar(&mut y_s, a, x.as_slice(), 0);
            for j in 0..n {
                assert_eq!(
                    y_k[j].to_bits(),
                    y_s[j].to_bits(),
                    "kernel {} axpy diverged at {j} (n={n})",
                    k.name()
                );
            }
        });
    }
}
