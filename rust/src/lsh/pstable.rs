//! p-stable (Euclidean) LSH of Datar–Immorlica–Indyk–Mirrokni: hash by a
//! quantized gaussian projection, `l(x) = floor((<w, x> + b) / r) mod B`.
//! Collision probability is a monotone decreasing function of the L2
//! distance. This is the family the general-purpose RACE sketch (KDE mode)
//! uses, and a second distinct family for Theorem-1 composition tests.

use super::{CollisionProbability, LshFunction};
use crate::util::mathx::{dot, normal_cdf};
use crate::util::rng::{Rng, Xoshiro256};

/// One Euclidean LSH function.
#[derive(Clone, Debug)]
pub struct PStableHash {
    w: Vec<f64>,
    b: f64,
    /// Quantization width.
    r: f64,
    /// Buckets are folded into `[0, range)` to bound sketch width.
    range: usize,
    dim: usize,
}

impl PStableHash {
    pub fn new(dim: usize, r: f64, range: usize, seed: u64) -> Self {
        assert!(r > 0.0 && range >= 2 && dim >= 1);
        let mut rng = Xoshiro256::new(seed);
        PStableHash {
            w: rng.gaussian_vec(dim),
            b: rng.uniform_range(0.0, r),
            r,
            range,
            dim,
        }
    }

    /// Analytic single-function collision probability as a function of the
    /// Euclidean distance `c` (DIIM'04, eq. for the gaussian kernel):
    /// `P(c) = 1 - 2 Phi(-r/c) - (2c / (sqrt(2 pi) r)) (1 - exp(-r^2/(2 c^2)))`
    pub fn collision_probability_at_distance(&self, c: f64) -> f64 {
        if c <= 1e-12 {
            return 1.0;
        }
        let ratio = self.r / c;
        let term1 = 1.0 - 2.0 * normal_cdf(-ratio);
        let term2 = (2.0 * c / ((2.0 * std::f64::consts::PI).sqrt() * self.r))
            * (1.0 - (-ratio * ratio / 2.0).exp());
        (term1 - term2).clamp(0.0, 1.0)
    }
}

impl LshFunction for PStableHash {
    fn hash(&self, x: &[f64]) -> usize {
        assert_eq!(x.len(), self.dim, "pstable dim mismatch");
        let v = (dot(&self.w, x) + self.b) / self.r;
        let cell = v.floor() as i64;
        (cell.rem_euclid(self.range as i64)) as usize
    }

    fn range(&self) -> usize {
        self.range
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

impl CollisionProbability for PStableHash {
    fn collision_probability(&self, x: &[f64], y: &[f64]) -> f64 {
        let c: f64 = x
            .iter()
            .zip(y)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        self.collision_probability_at_distance(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::empirical_collision;
    use crate::testing::assert_close;

    #[test]
    fn hash_in_range() {
        let l = PStableHash::new(3, 1.0, 8, 0);
        for i in 0..50 {
            let x = vec![i as f64 * 0.37, -(i as f64) * 0.11, 0.5];
            assert!(l.hash(&x) < 8);
        }
    }

    #[test]
    fn nearby_points_collide_more() {
        let probe = PStableHash::new(2, 4.0, 64, 0);
        let x = vec![0.0, 0.0];
        let near = vec![0.1, 0.0];
        let far = vec![3.0, 0.0];
        let p_near = empirical_collision(|s| PStableHash::new(2, 4.0, 64, s), &x, &near, 5_000);
        let p_far = empirical_collision(|s| PStableHash::new(2, 4.0, 64, s), &x, &far, 5_000);
        assert!(p_near > p_far + 0.1, "near={p_near} far={p_far}");
        // Analytic agreement (folding makes the empirical slightly larger;
        // with range 64 the wrap collision chance is negligible at r=4).
        assert_close(
            p_near,
            probe.collision_probability(&x, &near),
            0.03,
        );
    }

    #[test]
    fn analytic_probability_monotone_decreasing_in_distance() {
        let l = PStableHash::new(2, 2.0, 16, 1);
        let mut prev = 1.0;
        for i in 1..30 {
            let p = l.collision_probability_at_distance(i as f64 * 0.2);
            assert!(p <= prev + 1e-12);
            prev = p;
        }
    }

    #[test]
    fn zero_distance_always_collides() {
        let l = PStableHash::new(4, 1.5, 32, 2);
        let x = vec![0.3, 0.1, -0.2, 0.9];
        assert_eq!(l.hash(&x), l.hash(&x.clone()));
        assert_close(l.collision_probability(&x, &x), 1.0, 1e-12);
    }
}
