//! Structured hyperplane families: cheap substitutes for dense Gaussian
//! projections in the sketch hot path.
//!
//! Dense SRP pays O(d) multiply-adds per plane. Two classical structured
//! families cut that cost while preserving the angular-LSH behaviour the
//! STORM estimators rest on:
//!
//! * [`SparseRademacherPlanes`] — each plane keeps only an expected
//!   `density` fraction of coordinates, each with a ±1 sign (Achlioptas /
//!   Li-style very sparse random projections). A projection is a few
//!   signed adds per nonzero; storage is index/sign runs instead of a
//!   dense matrix.
//! * [`FastHadamardPlanes`] — the HD₁HD₂HD₃ subsampled randomized
//!   Hadamard transform: three rounds of (random ±1 diagonal, then
//!   fast Walsh–Hadamard transform) over the next-power-of-two padding
//!   of the input, with `p` distinct output coordinates per row selected
//!   as the plane projections. One O(m log m) transform serves all `p`
//!   planes of a row at once.
//!
//! Both families are generated from the same per-row seed streams as the
//! dense planes, so fleet-wide merge compatibility reduces to equal
//! `(seed, hash_family)` exactly as for dense. The fused bank
//! (`lsh/bank.rs`) consumes these families in decomposed form — head
//! nonzeros plus the two augmented tail coefficients — which *defines*
//! the family's hashing semantics; the [`LshFunction`] impls here hash
//! whole (already augmented) vectors and are used by the generic RACE
//! sketch and as test oracles.

use super::LshFunction;
use crate::util::rng::{Rng, Xoshiro256};

/// Draw a ±1 sign from the stream (one raw bit).
#[inline]
fn rademacher(rng: &mut Xoshiro256) -> f64 {
    if rng.next_u64() & 1 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// `p` sparse Rademacher hyperplanes over `n`-dimensional inputs, stored
/// as per-plane index/sign runs in ascending coordinate order.
#[derive(Clone, Debug)]
pub struct SparseRademacherPlanes {
    n: usize,
    p: u32,
    /// CSR-style run boundaries: plane `j`'s nonzeros live at
    /// `offsets[j]..offsets[j + 1]` in `idx`/`sign`.
    offsets: Vec<u32>,
    idx: Vec<u32>,
    sign: Vec<f64>,
}

impl SparseRademacherPlanes {
    /// Generate `p` planes over `n` coordinates from `seed`, keeping each
    /// coordinate with probability `density_permille / 1000`. Every plane
    /// is forced to have at least one nonzero so it stays a genuine
    /// hyperplane (an all-zero plane would hash everything to 1).
    pub fn new(n: usize, p: u32, seed: u64, density_permille: u16) -> Self {
        assert!(n >= 1, "sparse planes need dim >= 1");
        assert!((1..=24).contains(&p), "p must be in 1..=24, got {p}");
        assert!(
            (1..=1000).contains(&density_permille),
            "sparse density must be in (0, 1] (permille 1..=1000), got {density_permille}"
        );
        let density = density_permille as f64 / 1000.0;
        let mut rng = Xoshiro256::new(seed);
        let mut offsets = Vec::with_capacity(p as usize + 1);
        offsets.push(0u32);
        let mut idx: Vec<u32> = Vec::new();
        let mut sign: Vec<f64> = Vec::new();
        for _ in 0..p {
            let start = idx.len();
            for i in 0..n {
                if rng.uniform() < density {
                    idx.push(i as u32);
                    sign.push(rademacher(&mut rng));
                }
            }
            if idx.len() == start {
                idx.push(rng.below(n as u64) as u32);
                sign.push(rademacher(&mut rng));
            }
            offsets.push(idx.len() as u32);
        }
        SparseRademacherPlanes { n, p, offsets, idx, sign }
    }

    /// Number of planes.
    pub fn planes(&self) -> u32 {
        self.p
    }

    /// Total nonzeros across all planes.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Plane `j`'s nonzeros as `(coordinate, sign)` pairs in ascending
    /// coordinate order (the canonical accumulation order).
    pub fn nonzeros(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.offsets[j] as usize;
        let hi = self.offsets[j + 1] as usize;
        self.idx[lo..hi]
            .iter()
            .zip(&self.sign[lo..hi])
            .map(|(&i, &s)| (i as usize, s))
    }

    /// Project `x` onto plane `j`: signed sum over the plane's nonzeros,
    /// accumulated in ascending coordinate order.
    pub fn project(&self, j: usize, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.n);
        let mut s = 0.0;
        for (i, sg) in self.nonzeros(j) {
            s += sg * x[i];
        }
        s
    }
}

impl LshFunction for SparseRademacherPlanes {
    fn hash(&self, x: &[f64]) -> usize {
        assert_eq!(x.len(), self.n, "dimension mismatch");
        let mut h = 0usize;
        for j in 0..self.p as usize {
            if self.project(j, x) >= 0.0 {
                h |= 1 << j;
            }
        }
        h
    }

    fn range(&self) -> usize {
        1 << self.p
    }

    fn dim(&self) -> usize {
        self.n
    }
}

/// In-place unnormalized fast Walsh–Hadamard transform; `v.len()` must be
/// a power of two.
pub fn fwht(v: &mut [f64]) {
    let n = v.len();
    debug_assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for k in i..i + h {
                let a = v[k];
                let b = v[k + h];
                v[k] = a + b;
                v[k + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// `p` fast-Hadamard SRP planes over `n`-dimensional inputs: inputs are
/// zero-padded to `m = next_pow2(n)`, pushed through
/// `H·D₃·H·D₂·H·D₁` (three sign-diagonal + FWHT rounds), and plane `j`
/// reads output coordinate `sel[j]`.
#[derive(Clone, Debug)]
pub struct FastHadamardPlanes {
    n: usize,
    m: usize,
    p: u32,
    d1: Vec<f64>,
    d2: Vec<f64>,
    d3: Vec<f64>,
    sel: Vec<usize>,
}

impl FastHadamardPlanes {
    /// Generate from `seed`. Requires `p <= next_pow2(n)` — with fewer
    /// padded coordinates than planes the `p` selected outputs could not
    /// be distinct.
    pub fn new(n: usize, p: u32, seed: u64) -> Self {
        assert!(n >= 1, "hadamard planes need dim >= 1");
        assert!((1..=24).contains(&p), "p must be in 1..=24, got {p}");
        let m = crate::util::mathx::next_pow2(n);
        assert!(
            (p as usize) <= m,
            "hadamard family needs p <= next_pow2(dim) distinct output rows; \
             got p = {p}, next_pow2({n}) = {m} — lower storm.power or use \
             hash_family = \"dense\"|\"sparse\""
        );
        let mut rng = Xoshiro256::new(seed);
        let sign_vec = |rng: &mut Xoshiro256| (0..m).map(|_| rademacher(rng)).collect::<Vec<f64>>();
        let d1 = sign_vec(&mut rng);
        let d2 = sign_vec(&mut rng);
        let d3 = sign_vec(&mut rng);
        let sel = rng.sample_indices(m, p as usize);
        FastHadamardPlanes { n, m, p, d1, d2, d3, sel }
    }

    /// Number of planes.
    pub fn planes(&self) -> u32 {
        self.p
    }

    /// Padded transform length (`next_pow2(dim)`).
    pub fn padded_len(&self) -> usize {
        self.m
    }

    /// Output coordinate plane `j` reads.
    pub fn selected_index(&self, j: usize) -> usize {
        self.sel[j]
    }

    /// Full transform of `x` (zero-padded to `m`) into `out` — `out` is
    /// cleared and resized, so a reused buffer never reallocates after
    /// warmup. `x` may be shorter than `dim`; missing trailing
    /// coordinates are treated as zero (the bank exploits this to
    /// transform bare heads of augmented vectors).
    pub fn transform(&self, x: &[f64], out: &mut Vec<f64>) {
        assert!(x.len() <= self.n, "input longer than family dim");
        out.clear();
        out.extend_from_slice(x);
        out.resize(self.m, 0.0);
        for (v, s) in out.iter_mut().zip(&self.d1) {
            *v *= s;
        }
        fwht(out);
        for (v, s) in out.iter_mut().zip(&self.d2) {
            *v *= s;
        }
        fwht(out);
        for (v, s) in out.iter_mut().zip(&self.d3) {
            *v *= s;
        }
        fwht(out);
    }

    /// Column of the effective projection matrix restricted to the
    /// selected rows: `T(e_coord)[sel[j]]` for `j = 0..p`. The bank uses
    /// this to peel the two augmented tail slots out of the transform so
    /// the per-example pass only transforms the head.
    pub fn basis_column(&self, coord: usize) -> Vec<f64> {
        assert!(coord < self.n);
        let mut basis = vec![0.0; self.n];
        basis[coord] = 1.0;
        let mut out = Vec::new();
        self.transform(&basis, &mut out);
        self.sel.iter().map(|&s| out[s]).collect()
    }
}

impl LshFunction for FastHadamardPlanes {
    fn hash(&self, x: &[f64]) -> usize {
        assert_eq!(x.len(), self.n, "dimension mismatch");
        let mut out = Vec::new();
        self.transform(x, &mut out);
        let mut h = 0usize;
        for j in 0..self.p as usize {
            if out[self.sel[j]] >= 0.0 {
                h |= 1 << j;
            }
        }
        h
    }

    fn range(&self) -> usize {
        1 << self.p
    }

    fn dim(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, cases, gen_ball_point, gen_dim};

    #[test]
    fn sparse_planes_respect_density_and_min_nonzero() {
        let n = 200;
        let p = 8;
        let sp = SparseRademacherPlanes::new(n, p, 42, 100);
        for j in 0..p as usize {
            let nnz = sp.nonzeros(j).count();
            assert!(nnz >= 1, "plane {j} must have at least one nonzero");
            // 10% of 200 = 20 expected; allow a wide deterministic band.
            assert!(nnz <= 60, "plane {j} far denser than requested: {nnz}");
            let mut prev = None;
            for (i, s) in sp.nonzeros(j) {
                assert!(i < n);
                assert!(s == 1.0 || s == -1.0);
                if let Some(pv) = prev {
                    assert!(i > pv, "indices must be strictly ascending");
                }
                prev = Some(i);
            }
        }
        // Degenerate density still yields hyperplanes.
        let tiny = SparseRademacherPlanes::new(3, 4, 7, 1);
        for j in 0..4 {
            assert!(tiny.nonzeros(j).count() >= 1);
        }
    }

    #[test]
    fn sparse_projection_matches_dense_equivalent() {
        cases(30, 31, |rng, case| {
            let n = gen_dim(rng, 2, 40);
            let p = 1 + (case % 8) as u32;
            let sp = SparseRademacherPlanes::new(n, p, 1000 + case as u64, 300);
            let x = gen_ball_point(rng, n, 1.0);
            for j in 0..p as usize {
                // Densify the plane and dot it the slow way.
                let mut w = vec![0.0; n];
                for (i, s) in sp.nonzeros(j) {
                    w[i] = s;
                }
                let dense: f64 = w.iter().zip(&x).map(|(a, b)| a * b).sum();
                assert_close(sp.project(j, &x), dense, 1e-12);
            }
            // hash() folds the same signs.
            let mut h = 0usize;
            for j in 0..p as usize {
                if sp.project(j, &x) >= 0.0 {
                    h |= 1 << j;
                }
            }
            assert_eq!(sp.hash(&x), h);
        });
    }

    #[test]
    fn sparse_is_deterministic_and_seed_sensitive() {
        let a = SparseRademacherPlanes::new(50, 6, 9, 150);
        let b = SparseRademacherPlanes::new(50, 6, 9, 150);
        let c = SparseRademacherPlanes::new(50, 6, 10, 150);
        let x: Vec<f64> = (0..50).map(|i| ((i * 7 + 3) % 11) as f64 - 5.0).collect();
        assert_eq!(a.hash(&x), b.hash(&x));
        let mut diff = false;
        for j in 0..6 {
            if a.nonzeros(j).collect::<Vec<_>>() != c.nonzeros(j).collect::<Vec<_>>() {
                diff = true;
            }
        }
        assert!(diff, "different seeds should draw different planes");
    }

    #[test]
    fn fwht_matches_naive_hadamard() {
        // H_2 ⊗ H_2 on length 4: H[i][j] = (-1)^{popcount(i & j)}.
        let x = [1.0, -2.0, 3.0, 0.5];
        let mut v = x.to_vec();
        fwht(&mut v);
        for (i, &got) in v.iter().enumerate() {
            let want: f64 = x
                .iter()
                .enumerate()
                .map(|(j, &xj)| {
                    let sign = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
                    sign * xj
                })
                .sum();
            assert_close(got, want, 1e-12);
        }
    }

    #[test]
    fn fwht_is_self_inverse_up_to_scale() {
        let mut v: Vec<f64> = (0..16).map(|i| (i as f64 - 7.5) * 0.3).collect();
        let orig = v.clone();
        fwht(&mut v);
        fwht(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            assert_close(*a, b * 16.0, 1e-9);
        }
    }

    #[test]
    fn hadamard_transform_is_linear_and_antipodal() {
        cases(20, 32, |rng, case| {
            let n = gen_dim(rng, 3, 33);
            let p = (1 + case % 4).min(crate::util::mathx::next_pow2(n)) as u32;
            let hp = FastHadamardPlanes::new(n, p, 77 + case as u64);
            let x = gen_ball_point(rng, n, 1.0);
            let neg: Vec<f64> = x.iter().map(|v| -v).collect();
            let mut tx = Vec::new();
            let mut tn = Vec::new();
            hp.transform(&x, &mut tx);
            hp.transform(&neg, &mut tn);
            // Negation commutes with the transform *bitwise*: every step
            // is multiplication and add/sub of f64, and IEEE-754 negation
            // distributes exactly over both.
            for (a, b) in tx.iter().zip(&tn) {
                assert_eq!(a.to_bits(), (-b).to_bits());
            }
        });
    }

    #[test]
    fn hadamard_matches_explicit_matrix() {
        // Reconstruct the effective matrix column-by-column and check a
        // full transform against the matrix-vector product.
        let n = 6;
        let p = 4;
        let hp = FastHadamardPlanes::new(n, p, 5);
        let cols: Vec<Vec<f64>> = (0..n).map(|c| hp.basis_column(c)).collect();
        let x = [0.3, -1.2, 0.7, 2.0, -0.4, 0.05];
        let mut out = Vec::new();
        hp.transform(&x, &mut out);
        for j in 0..p as usize {
            let want: f64 = (0..n).map(|c| cols[c][j] * x[c]).sum();
            assert_close(out[hp.selected_index(j)], want, 1e-9);
        }
    }

    #[test]
    fn hadamard_selected_rows_are_distinct() {
        let hp = FastHadamardPlanes::new(10, 8, 3);
        let mut sel: Vec<usize> = (0..8).map(|j| hp.selected_index(j)).collect();
        sel.sort_unstable();
        sel.dedup();
        assert_eq!(sel.len(), 8);
        assert!(sel.iter().all(|&s| s < hp.padded_len()));
    }

    #[test]
    #[should_panic(expected = "p <= next_pow2(dim)")]
    fn hadamard_rejects_more_planes_than_padded_rows() {
        FastHadamardPlanes::new(3, 8, 1);
    }

    #[test]
    fn structured_families_balance_hash_bits() {
        // Sanity: over random inputs each plane's sign should be roughly
        // balanced — a catastrophically broken family collapses to one
        // bucket.
        let n = 64;
        let p = 6u32;
        let sp = SparseRademacherPlanes::new(n, p, 21, 200);
        let hp = FastHadamardPlanes::new(n, p, 22);
        let mut rng = crate::util::rng::Xoshiro256::new(99);
        let mut sp_ones = vec![0usize; p as usize];
        let mut hp_ones = vec![0usize; p as usize];
        let trials = 400;
        for _ in 0..trials {
            let x = crate::util::rng::Rng::gaussian_vec(&mut rng, n);
            let (hs, hh) = (sp.hash(&x), hp.hash(&x));
            for j in 0..p as usize {
                sp_ones[j] += (hs >> j) & 1;
                hp_ones[j] += (hh >> j) & 1;
            }
        }
        for j in 0..p as usize {
            let fs = sp_ones[j] as f64 / trials as f64;
            let fh = hp_ones[j] as f64 / trials as f64;
            assert!((0.2..=0.8).contains(&fs), "sparse plane {j} unbalanced: {fs}");
            assert!((0.2..=0.8).contains(&fh), "hadamard plane {j} unbalanced: {fh}");
        }
    }
}
