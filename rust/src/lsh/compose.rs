//! Injective LSH composition — the multiplication closure of Theorem 1.
//!
//! Given independent LSH functions `l1, l2` with collision probabilities
//! `k1, k2`, the composed function `l(x) = pi(l1(x), l2(x))` with `pi`
//! injective collides iff *both* constituents collide, so its collision
//! probability is the product `k1 * k2`. The paper suggests
//! `pi(a, b) = p1^a p2^b`; we use the equivalent (and overflow-free)
//! row-major pairing `a * range2 + b`, which is injective on
//! `[0, range1) x [0, range2)`.

use super::{CollisionProbability, LshFunction};

/// Composition of two LSH functions via an injective pairing.
pub struct ComposedHash<A, B> {
    a: A,
    b: B,
}

impl<A: LshFunction, B: LshFunction> ComposedHash<A, B> {
    pub fn new(a: A, b: B) -> Self {
        assert_eq!(a.dim(), b.dim(), "composed hashes must share input dim");
        ComposedHash { a, b }
    }
}

impl<A: LshFunction, B: LshFunction> LshFunction for ComposedHash<A, B> {
    fn hash(&self, x: &[f64]) -> usize {
        self.a.hash(x) * self.b.range() + self.b.hash(x)
    }

    fn range(&self) -> usize {
        self.a.range() * self.b.range()
    }

    fn dim(&self) -> usize {
        self.a.dim()
    }
}

impl<A, B> CollisionProbability for ComposedHash<A, B>
where
    A: LshFunction + CollisionProbability,
    B: LshFunction + CollisionProbability,
{
    fn collision_probability(&self, x: &[f64], y: &[f64]) -> f64 {
        self.a.collision_probability(x, y) * self.b.collision_probability(x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::empirical_collision;
    use crate::lsh::pstable::PStableHash;
    use crate::lsh::srp::SignedRandomProjection;
    use crate::testing::assert_close;

    #[test]
    fn pairing_is_injective() {
        let a = SignedRandomProjection::new(3, 2, 0);
        let b = SignedRandomProjection::new(3, 3, 1);
        let c = ComposedHash::new(a, b);
        assert_eq!(c.range(), 4 * 8);
        // Exhaustively: distinct (ha, hb) pairs map to distinct outputs.
        let mut seen = std::collections::BTreeSet::new();
        for ha in 0..4 {
            for hb in 0..8 {
                assert!(seen.insert(ha * 8 + hb));
            }
        }
        assert_eq!(seen.len(), 32);
    }

    #[test]
    fn composed_collision_is_product_srp_x_srp() {
        let x = vec![1.0, 0.0, 0.0];
        let y = vec![0.7, 0.7141428, 0.0];
        let make = |seed: u64| {
            ComposedHash::new(
                SignedRandomProjection::new(3, 1, seed.wrapping_mul(2).wrapping_add(1)),
                SignedRandomProjection::new(3, 1, seed.wrapping_mul(2).wrapping_add(2)),
            )
        };
        let probe = make(0);
        let analytic = probe.collision_probability(&x, &y);
        let emp = empirical_collision(make, &x, &y, 30_000);
        assert_close(emp, analytic, 0.015);
    }

    #[test]
    fn composed_collision_is_product_srp_x_pstable() {
        // Mixed families — Theorem 1 allows any independent pair.
        let x = vec![0.2, -0.4];
        let y = vec![0.5, 0.3];
        let make = |seed: u64| {
            ComposedHash::new(
                SignedRandomProjection::new(2, 1, seed.wrapping_mul(2).wrapping_add(100)),
                PStableHash::new(2, 2.0, 64, seed.wrapping_mul(2).wrapping_add(200)),
            )
        };
        let probe = make(0);
        let analytic = probe.collision_probability(&x, &y);
        let emp = empirical_collision(make, &x, &y, 30_000);
        // p-stable folding adds a small positive bias; loose tolerance.
        assert_close(emp, analytic, 0.02);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_rejected() {
        let a = SignedRandomProjection::new(2, 1, 0);
        let b = SignedRandomProjection::new(3, 1, 1);
        let _ = ComposedHash::new(a, b);
    }
}
