//! Asymmetric inner-product LSH (Shrivastava & Li, 2014).
//!
//! SRP collision probability is monotone in the *angle*; for ERM we need
//! it monotone in the raw inner product `<theta, z>`. The trick (paper
//! §2.2): append coordinates so both vectors land on the unit sphere
//! without changing their inner product —
//!
//! * data    `z -> [z, 0, sqrt(1 - ||z||^2)]`
//! * query   `q -> [q, sqrt(1 - ||q||^2), 0]`
//!
//! Then `<T_q(q), T_d(z)> = <q, z>` and both transformed vectors are unit
//! norm, so the SRP collision probability becomes
//! `(1 - acos(<q, z>)/pi)^p` — exactly the `f(a, b)` of Theorem 2. Both
//! inputs must lie inside the unit ball (the dataset scaler guarantees
//! this for data; the optimizer clips queries).

use super::{LshFunction};
use crate::util::mathx::{dot, srp_collision};
use super::srp::SignedRandomProjection;

/// Which side of the asymmetric pair a vector is on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// Stream data (gets the `[z, 0, tail]` transform).
    Data,
    /// Query / parameter vector (gets the `[q, tail, 0]` transform).
    Query,
}

/// Apply the MIPS augmentation. Panics if `||v|| > 1 + eps` (callers must
/// scale first); tiny overshoots from rounding are clamped.
pub fn augment(v: &[f64], side: Side) -> Vec<f64> {
    let sq: f64 = v.iter().map(|x| x * x).sum();
    assert!(
        sq <= 1.0 + 1e-9,
        "asymmetric LSH input must lie in the unit ball (||v||^2 = {sq})"
    );
    let tail = (1.0 - sq).max(0.0).sqrt();
    let mut out = Vec::with_capacity(v.len() + 2);
    out.extend_from_slice(v);
    match side {
        Side::Data => {
            out.push(0.0);
            out.push(tail);
        }
        Side::Query => {
            out.push(tail);
            out.push(0.0);
        }
    }
    out
}

/// An asymmetric inner-product hash: a p-bit SRP over the augmented space
/// `R^{d+2}`, with side-specific preprocessing.
#[derive(Clone, Debug)]
pub struct AsymmetricInnerProductHash {
    srp: SignedRandomProjection,
    dim: usize,
}

impl AsymmetricInnerProductHash {
    pub fn new(dim: usize, p: u32, seed: u64) -> Self {
        AsymmetricInnerProductHash {
            srp: SignedRandomProjection::new(dim + 2, p, seed),
            dim,
        }
    }

    /// Hash a vector on the given side.
    pub fn hash_side(&self, v: &[f64], side: Side) -> usize {
        assert_eq!(v.len(), self.dim, "asym hash dim mismatch");
        self.srp.hash(&augment(v, side))
    }

    /// Hash a vector that has already been augmented (hot path: the
    /// augmentation is shared across every row of a sketch, so callers
    /// compute it once per insert/query instead of once per row).
    #[inline]
    pub fn hash_augmented(&self, aug: &[f64]) -> usize {
        debug_assert_eq!(aug.len(), self.dim + 2);
        self.srp.hash(aug)
    }

    /// Bucket of the *negated* data vector (used by PRP): the augmented
    /// tail coordinate is unchanged under `z -> -z` **only in the leading
    /// d coordinates**, so this is NOT the plain bitwise complement — we
    /// hash explicitly.
    pub fn hash_data_negated(&self, v: &[f64]) -> usize {
        let neg: Vec<f64> = v.iter().map(|x| -x).collect();
        self.hash_side(&neg, Side::Data)
    }

    pub fn bits(&self) -> u32 {
        self.srp.bits()
    }

    pub fn range(&self) -> usize {
        self.srp.range()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Underlying SRP (exposed for the AOT compile path, which must embed
    /// identical hyperplanes into the XLA artifact).
    pub fn srp(&self) -> &SignedRandomProjection {
        &self.srp
    }

    /// Collision probability between a query `q` and data `z`, both inside
    /// the unit ball: `(1 - acos(<q, z>)/pi)^p` — monotone *increasing* in
    /// the inner product, unnormalized.
    pub fn collision_probability_qd(&self, q: &[f64], z: &[f64]) -> f64 {
        let t = dot(q, z).clamp(-1.0, 1.0);
        srp_collision(t).powi(self.bits() as i32)
    }
}

/// Adapter so an asymmetric hash can be used where a plain (data-side)
/// `LshFunction` is expected — e.g. when feeding the generic RACE sketch.
pub struct DataSideHash<'a>(pub &'a AsymmetricInnerProductHash);

impl LshFunction for DataSideHash<'_> {
    fn hash(&self, x: &[f64]) -> usize {
        self.0.hash_side(x, Side::Data)
    }

    fn range(&self) -> usize {
        self.0.range()
    }

    fn dim(&self) -> usize {
        self.0.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, cases, gen_ball_point};
    use crate::util::mathx::norm2;

    #[test]
    fn augmentation_preserves_inner_product_and_normalizes() {
        cases(50, 1, |rng, _| {
            let d = crate::testing::gen_dim(rng, 1, 10);
            let q = gen_ball_point(rng, d, 0.95);
            let z = gen_ball_point(rng, d, 0.95);
            let aq = augment(&q, Side::Query);
            let az = augment(&z, Side::Data);
            assert_close(dot(&aq, &az), dot(&q, &z), 1e-9);
            assert_close(norm2(&aq), 1.0, 1e-9);
            assert_close(norm2(&az), 1.0, 1e-9);
        });
    }

    #[test]
    fn collision_matches_empirical() {
        let q = vec![0.5, 0.2];
        let z = vec![-0.3, 0.6];
        let probe = AsymmetricInnerProductHash::new(2, 2, 0);
        let analytic = probe.collision_probability_qd(&q, &z);
        let trials = 20_000;
        let mut hits = 0;
        for s in 0..trials {
            let h = AsymmetricInnerProductHash::new(2, 2, s as u64);
            if h.hash_side(&q, Side::Query) == h.hash_side(&z, Side::Data) {
                hits += 1;
            }
        }
        assert_close(hits as f64 / trials as f64, analytic, 0.015);
    }

    #[test]
    fn collision_monotone_in_inner_product() {
        let h = AsymmetricInnerProductHash::new(1, 4, 3);
        let q = vec![0.9];
        let mut prev = -1.0;
        for i in 0..19 {
            let z = vec![-0.9 + 0.1 * i as f64];
            let k = h.collision_probability_qd(&q, &z);
            assert!(k >= prev, "not monotone at i={i}");
            prev = k;
        }
    }

    #[test]
    #[should_panic]
    fn outside_unit_ball_rejected() {
        augment(&[1.5, 0.0], Side::Data);
    }

    #[test]
    fn data_side_adapter_consistent() {
        let h = AsymmetricInnerProductHash::new(3, 4, 7);
        let z = vec![0.1, -0.2, 0.3];
        let adapter = DataSideHash(&h);
        assert_eq!(adapter.hash(&z), h.hash_side(&z, Side::Data));
        assert_eq!(adapter.range(), 16);
        assert_eq!(adapter.dim(), 3);
    }

    #[test]
    fn negated_hash_matches_explicit_negation() {
        cases(30, 8, |rng, case| {
            let h = AsymmetricInnerProductHash::new(4, 3, case as u64);
            let z = gen_ball_point(rng, 4, 0.9);
            let neg: Vec<f64> = z.iter().map(|v| -v).collect();
            assert_eq!(h.hash_data_negated(&z), h.hash_side(&neg, Side::Data));
        });
    }
}
