//! Fused hash-bank kernel: all `R` rows' SRP hyperplanes in one
//! contiguous projection matrix, evaluated in a single pass per example.
//!
//! The seed scalar path stores each row's hyperplanes inside an
//! independently-allocated [`PairedRandomProjection`] and hashes the two
//! PRP arms separately: `2 * R * p` scattered `(d+2)`-wide dot products
//! per insert. This module concatenates every plane into one row-major
//! `[R * p, d + 2]` matrix and exploits the structure of the MIPS
//! augmentation to serve **both** arms from one projection:
//!
//! * data arms:  `aug(+z) = [ z, 0, tail]`, `aug(-z) = [-z, 0, tail]`
//!   with the *same* tail `sqrt(1 - ||z||^2)` (norms are sign-invariant);
//! * plane `w = [w_head, w_q, w_d]` therefore projects as
//!   `<w, aug(+z)> = s + t` and `<w, aug(-z)> = t - s` where
//!   `s = <w_head, z>` is the head term and `t = w_d * tail` the tail
//!   term — one head dot product instead of two, halving insert FLOPs.
//!
//! **Bit-equivalence.** The grids must stay bit-identical to the seed
//! scalar path for a fixed seed (property-tested in
//! `tests/proptest_invariants.rs`). This holds because [`dot`] is a plain
//! sequential accumulate: the head term `s` reproduces the scalar
//! partial sum exactly; IEEE-754 negation and addition are sign-symmetric
//! so the negated arm's prefix is exactly `-s`; and the two terms the
//! fused path skips (`w_q * 0.0` on the data side, `w_d * 0.0` on the
//! query side) never change the numeric value of the accumulator, so
//! every `>= 0.0` sign bit matches the scalar decision.
//!
//! The bank is a *derived* structure: it copies (never replaces) the
//! per-row hashes, so `StormSketch::hashes()` / `srp()` stay intact and
//! the Python AOT path keeps embedding identical hyperplanes.

use crate::lsh::asym::AsymmetricInnerProductHash;
use crate::lsh::prp::PairedRandomProjection;
use crate::util::mathx::dot;

/// A contiguous bank of `R * p` SRP hyperplanes over the augmented space
/// `R^{d+2}`, serving fused PRP insert/query hashing for a whole sketch.
#[derive(Clone, Debug)]
pub struct HashBank {
    /// All hyperplanes, row-major `[R * p, d + 2]`: row `r`'s plane `j`
    /// lives at flat index `r * p + j`.
    planes: Vec<f64>,
    rows: usize,
    p: u32,
    /// Raw (unaugmented) dimension `d`; each plane has `d + 2` coords.
    dim: usize,
}

impl HashBank {
    /// Build a bank by concatenating the hyperplanes of per-row PRP
    /// hashes (the seed representation). The copy preserves the exact
    /// coefficients, so fused and scalar hashing agree bit-for-bit.
    pub fn from_rows(hashes: &[PairedRandomProjection]) -> Self {
        assert!(!hashes.is_empty(), "hash bank needs at least one row");
        let dim = hashes[0].dim();
        let p = hashes[0].bits();
        let aug = dim + 2;
        let mut planes = Vec::with_capacity(hashes.len() * p as usize * aug);
        for h in hashes {
            assert_eq!(h.dim(), dim, "bank rows must share dim");
            assert_eq!(h.bits(), p, "bank rows must share p");
            let srp = h.asym().srp();
            for j in 0..p as usize {
                planes.extend_from_slice(srp.plane(j));
            }
        }
        HashBank { planes, rows: hashes.len(), p, dim }
    }

    /// Build a bank from per-row *single-arm* asymmetric hashes — the
    /// classifier sketch's hash family (Theorem 3 inserts one arm, no PRP
    /// pairing). Same contiguous `[R * p, d + 2]` layout and the same
    /// exact-coefficient copy, so [`Self::data_bucket`] /
    /// [`Self::query_bucket`] agree bit-for-bit with the per-row scalar
    /// hashes.
    pub fn from_asym_rows(hashes: &[AsymmetricInnerProductHash]) -> Self {
        assert!(!hashes.is_empty(), "hash bank needs at least one row");
        let dim = hashes[0].dim();
        let p = hashes[0].bits();
        let aug = dim + 2;
        let mut planes = Vec::with_capacity(hashes.len() * p as usize * aug);
        for h in hashes {
            assert_eq!(h.dim(), dim, "bank rows must share dim");
            assert_eq!(h.bits(), p, "bank rows must share p");
            for j in 0..p as usize {
                planes.extend_from_slice(h.srp().plane(j));
            }
        }
        HashBank { planes, rows: hashes.len(), p, dim }
    }

    /// Number of sketch rows R.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Hyperplanes per row p.
    pub fn bits(&self) -> u32 {
        self.p
    }

    /// Raw (unaugmented) input dimension d.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Buckets per row, `2^p`.
    pub fn range(&self) -> usize {
        1usize << self.p
    }

    /// Bank memory in bytes (diagnostics).
    pub fn bytes(&self) -> usize {
        self.planes.len() * std::mem::size_of::<f64>()
    }

    /// Plane `j` of row `r` as a `(d + 2)`-slice.
    #[inline]
    pub fn plane(&self, r: usize, j: usize) -> &[f64] {
        let aug = self.dim + 2;
        let idx = r * self.p as usize + j;
        &self.planes[idx * aug..(idx + 1) * aug]
    }

    /// The MIPS tail coordinate `sqrt(1 - ||v||^2)` — the same magnitude
    /// on both sides of the asymmetric pair (only its *position* in the
    /// augmented vector differs). Computed exactly like
    /// [`crate::lsh::asym::augment`], including its unit-ball assertion.
    #[inline]
    pub fn mips_tail(z: &[f64]) -> f64 {
        let sq: f64 = z.iter().map(|x| x * x).sum();
        assert!(
            sq <= 1.0 + 1e-9,
            "asymmetric LSH input must lie in the unit ball (||v||^2 = {sq})"
        );
        (1.0 - sq).max(0.0).sqrt()
    }

    /// Both PRP insert buckets of row `r` for data vector `z` with
    /// precomputed `tail`, from a single pass over the row's planes.
    /// Equals `hashes[r].insert_buckets(z)` bit-for-bit.
    #[inline]
    pub fn data_pair(&self, r: usize, z: &[f64], tail: f64) -> (usize, usize) {
        debug_assert_eq!(z.len(), self.dim, "bank data dim mismatch");
        let d = self.dim;
        let mut pos = 0usize;
        let mut neg = 0usize;
        for j in 0..self.p as usize {
            let w = self.plane(r, j);
            let s = dot(&w[..d], z);
            let t = w[d + 1] * tail;
            // Tie-break sign(0) as 1, matching the scalar SRP.
            if s + t >= 0.0 {
                pos |= 1 << j;
            }
            if t - s >= 0.0 {
                neg |= 1 << j;
            }
        }
        (pos, neg)
    }

    /// Single-arm data bucket of row `r` for data vector `z` with
    /// precomputed tail — the positive arm of [`Self::data_pair`], which
    /// is all the classifier sketch inserts (Theorem 3, no PRP pairing).
    /// Equals `asym.hash_side(z, Side::Data)` bit-for-bit: the skipped
    /// query-slot term `w[d] * 0.0` never changes the accumulator value.
    #[inline]
    pub fn data_bucket(&self, r: usize, z: &[f64], tail: f64) -> usize {
        debug_assert_eq!(z.len(), self.dim, "bank data dim mismatch");
        let d = self.dim;
        let mut h = 0usize;
        for j in 0..self.p as usize {
            let w = self.plane(r, j);
            if dot(&w[..d], z) + w[d + 1] * tail >= 0.0 {
                h |= 1 << j;
            }
        }
        h
    }

    /// Query bucket of row `r` for query vector `q` with precomputed
    /// query-side tail. Equals `hashes[r].query_bucket(q)` bit-for-bit.
    #[inline]
    pub fn query_bucket(&self, r: usize, q: &[f64], tail: f64) -> usize {
        debug_assert_eq!(q.len(), self.dim, "bank query dim mismatch");
        let d = self.dim;
        let mut h = 0usize;
        for j in 0..self.p as usize {
            let w = self.plane(r, j);
            if dot(&w[..d], q) + w[d] * tail >= 0.0 {
                h |= 1 << j;
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{cases, gen_ball_point, gen_dim};

    fn mk_rows(dim: usize, p: u32, rows: usize, seed: u64) -> Vec<PairedRandomProjection> {
        (0..rows)
            .map(|r| {
                PairedRandomProjection::new(
                    dim,
                    p,
                    seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(r as u64),
                )
            })
            .collect()
    }

    #[test]
    fn data_pair_matches_scalar_prp_bitwise() {
        cases(60, 21, |rng, case| {
            let dim = gen_dim(rng, 1, 12);
            let p = 1 + (case % 8) as u32;
            let hashes = mk_rows(dim, p, 5, case as u64);
            let bank = HashBank::from_rows(&hashes);
            let z = gen_ball_point(rng, dim, 0.95);
            let tail = HashBank::mips_tail(&z);
            for (r, h) in hashes.iter().enumerate() {
                assert_eq!(bank.data_pair(r, &z, tail), h.insert_buckets(&z));
            }
        });
    }

    #[test]
    fn query_bucket_matches_scalar_prp_bitwise() {
        cases(60, 22, |rng, case| {
            let dim = gen_dim(rng, 1, 12);
            let p = 1 + (case % 8) as u32;
            let hashes = mk_rows(dim, p, 4, case as u64 ^ 0xBEEF);
            let bank = HashBank::from_rows(&hashes);
            let q = gen_ball_point(rng, dim, 0.95);
            let sq: f64 = q.iter().map(|x| x * x).sum();
            let tail = (1.0 - sq).max(0.0).sqrt();
            for (r, h) in hashes.iter().enumerate() {
                assert_eq!(bank.query_bucket(r, &q, tail), h.query_bucket(&q));
            }
        });
    }

    #[test]
    fn bank_shape_and_plane_access() {
        let hashes = mk_rows(3, 4, 7, 11);
        let bank = HashBank::from_rows(&hashes);
        assert_eq!(bank.rows(), 7);
        assert_eq!(bank.bits(), 4);
        assert_eq!(bank.dim(), 3);
        assert_eq!(bank.range(), 16);
        assert_eq!(bank.bytes(), 7 * 4 * 5 * 8);
        for (r, h) in hashes.iter().enumerate() {
            for j in 0..4 {
                assert_eq!(bank.plane(r, j), h.asym().srp().plane(j));
            }
        }
    }

    #[test]
    #[should_panic]
    fn mips_tail_rejects_outside_ball() {
        HashBank::mips_tail(&[1.5, 0.0]);
    }

    fn mk_asym_rows(dim: usize, p: u32, rows: usize, seed: u64) -> Vec<AsymmetricInnerProductHash> {
        (0..rows)
            .map(|r| {
                AsymmetricInnerProductHash::new(
                    dim,
                    p,
                    seed.wrapping_mul(0x51afd6ed558ccd65).wrapping_add(r as u64),
                )
            })
            .collect()
    }

    #[test]
    fn asym_bank_data_bucket_matches_scalar_hash_bitwise() {
        use crate::lsh::asym::Side;
        cases(60, 23, |rng, case| {
            let dim = gen_dim(rng, 1, 12);
            let p = 1 + (case % 8) as u32;
            let hashes = mk_asym_rows(dim, p, 5, case as u64);
            let bank = HashBank::from_asym_rows(&hashes);
            let z = gen_ball_point(rng, dim, 0.95);
            let tail = HashBank::mips_tail(&z);
            for (r, h) in hashes.iter().enumerate() {
                assert_eq!(bank.data_bucket(r, &z, tail), h.hash_side(&z, Side::Data));
            }
        });
    }

    #[test]
    fn asym_bank_query_bucket_matches_scalar_hash_bitwise() {
        use crate::lsh::asym::Side;
        cases(60, 24, |rng, case| {
            let dim = gen_dim(rng, 1, 12);
            let p = 1 + (case % 8) as u32;
            let hashes = mk_asym_rows(dim, p, 4, case as u64 ^ 0xC1A5);
            let bank = HashBank::from_asym_rows(&hashes);
            let q = gen_ball_point(rng, dim, 0.95);
            let tail = HashBank::mips_tail(&q);
            for (r, h) in hashes.iter().enumerate() {
                assert_eq!(bank.query_bucket(r, &q, tail), h.hash_side(&q, Side::Query));
            }
        });
    }
}
