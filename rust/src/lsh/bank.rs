//! Fused hash-bank kernel: all `R` rows' hyperplanes behind one
//! family-dispatched projection engine, evaluated in a single pass per
//! example.
//!
//! The seed scalar path stores each row's hyperplanes inside an
//! independently-allocated [`PairedRandomProjection`] and hashes the two
//! PRP arms separately: `2 * R * p` scattered `(d+2)`-wide dot products
//! per insert. The bank concatenates every plane into one row-major
//! `[R * p, d + 2]` matrix and exploits the structure of the MIPS
//! augmentation to serve **both** arms from one projection:
//!
//! * data arms:  `aug(+z) = [ z, 0, tail]`, `aug(-z) = [-z, 0, tail]`
//!   with the *same* tail `sqrt(1 - ||z||^2)` (norms are sign-invariant);
//! * plane `w = [w_head, w_q, w_d]` therefore projects as
//!   `<w, aug(+z)> = s + t` and `<w, aug(-z)> = t - s` where
//!   `s = <w_head, z>` is the head term and `t = w_d * tail` the tail
//!   term — one head dot product instead of two, halving insert FLOPs.
//!
//! **SIMD.** For the dense family the bank additionally keeps a
//! *transposed* per-row copy of the planes (`t[i * p + j] = w_j[i]`,
//! coordinate-major) and evaluates all `p` projections of a row through
//! the runtime-dispatched kernels in [`crate::lsh::simd`] — lane `j`
//! owns plane `j`, so vectorization re-associates across independent
//! sums, never within one, and the SIMD path stays **bit-identical** to
//! the scalar oracle (see the `simd` module docs for the full argument;
//! the equivalence proptests pin it at every width, both tasks, and up
//! to the config-validated maxima of `p`).
//!
//! **Structured families.** [`Self::sparse_from_seeds`] /
//! [`Self::hadamard_from_seeds`] build the bank from
//! [`crate::lsh::structured`] families instead of dense Gaussian planes.
//! Their hashing semantics are *defined* by the bank's decomposed
//! evaluation: plane `j`'s projection of an augmented vector is
//! `head_term + w_q * aug[d] + w_d * aug[d+1]`, with the head term
//! evaluated by the family (signed adds for sparse, one shared
//! `O(m log m)` transform per row for fast-Hadamard) and the two tail
//! coefficients peeled out at construction. Both arms still come from
//! one head evaluation, and the antipodal identity `pos(-z) = neg(z)`
//! holds bitwise because IEEE-754 negation distributes exactly over
//! every add/sub in the head evaluation.
//!
//! **Bit-equivalence (dense).** The grids must stay bit-identical to the
//! seed scalar path for a fixed seed (property-tested in
//! `tests/proptest_invariants.rs`). This holds because [`dot`] is a plain
//! sequential accumulate: the head term `s` reproduces the scalar
//! partial sum exactly; IEEE-754 negation and addition are sign-symmetric
//! so the negated arm's prefix is exactly `-s`; and the two terms the
//! fused path skips (`w_q * 0.0` on the data side, `w_d * 0.0` on the
//! query side) never change the numeric value of the accumulator, so
//! every `>= 0.0` sign bit matches the scalar decision.
//!
//! The dense bank is a *derived* structure: it copies (never replaces)
//! the per-row hashes, so `StormSketch::hashes()` / `srp()` stay intact
//! and the Python AOT path keeps embedding identical hyperplanes.

use std::cell::RefCell;

use crate::lsh::asym::AsymmetricInnerProductHash;
use crate::lsh::prp::PairedRandomProjection;
use crate::lsh::simd::{self, Kernel};
use crate::lsh::structured::{FastHadamardPlanes, SparseRademacherPlanes};
use crate::util::mathx::dot;

thread_local! {
    /// Reused fast-Hadamard transform buffer (per thread so the bank
    /// stays `Sync` for the parallel batch-insert path).
    static HADAMARD_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// One sketch row of the sparse family, split into head runs and the two
/// augmented tail coefficients.
#[derive(Clone, Debug)]
struct SparseBankRow {
    /// Plane `j`'s head nonzeros live at `offsets[j]..offsets[j+1]`.
    offsets: Vec<u32>,
    idx: Vec<u32>,
    sign: Vec<f64>,
    /// Coefficient at augmented slot `d` (query tail), per plane.
    c_q: Vec<f64>,
    /// Coefficient at augmented slot `d + 1` (data tail), per plane.
    c_d: Vec<f64>,
}

/// One sketch row of the fast-Hadamard family: the transform plus the
/// two augmented-slot columns of its effective projection matrix.
#[derive(Clone, Debug)]
struct HadamardBankRow {
    planes: FastHadamardPlanes,
    col_q: Vec<f64>,
    col_d: Vec<f64>,
}

/// Family-specific storage behind the bank's uniform hashing API.
#[derive(Clone, Debug)]
enum BankKind {
    Dense {
        /// All hyperplanes, row-major `[R * p, d + 2]`: row `r`'s plane
        /// `j` lives at flat index `r * p + j`.
        planes: Vec<f64>,
        /// Per-row transposed copy for the SIMD kernels:
        /// `transposed[r * (d+2) * p + i * p + j] = planes[(r*p+j)*(d+2) + i]`.
        transposed: Vec<f64>,
        /// Projection kernel resolved once at construction.
        kernel: Kernel,
    },
    Sparse { bank_rows: Vec<SparseBankRow> },
    Hadamard { bank_rows: Vec<HadamardBankRow> },
}

/// A contiguous bank of `R * p` hyperplanes over the augmented space
/// `R^{d+2}`, serving fused PRP insert/query hashing for a whole sketch.
#[derive(Clone, Debug)]
pub struct HashBank {
    kind: BankKind,
    rows: usize,
    p: u32,
    /// Raw (unaugmented) dimension `d`; each plane has `d + 2` coords.
    dim: usize,
}

impl HashBank {
    fn dense(planes: Vec<f64>, rows: usize, p: u32, dim: usize) -> Self {
        let aug = dim + 2;
        let pu = p as usize;
        debug_assert_eq!(planes.len(), rows * pu * aug);
        let mut transposed = vec![0.0; planes.len()];
        for r in 0..rows {
            let base = r * pu * aug;
            for j in 0..pu {
                for i in 0..aug {
                    transposed[base + i * pu + j] = planes[base + j * aug + i];
                }
            }
        }
        let kernel = simd::kernel();
        HashBank { kind: BankKind::Dense { planes, transposed, kernel }, rows, p, dim }
    }

    /// Build a dense bank by concatenating the hyperplanes of per-row PRP
    /// hashes (the seed representation). The copy preserves the exact
    /// coefficients, so fused and scalar hashing agree bit-for-bit.
    pub fn from_rows(hashes: &[PairedRandomProjection]) -> Self {
        assert!(!hashes.is_empty(), "hash bank needs at least one row");
        let dim = hashes[0].dim();
        let p = hashes[0].bits();
        let aug = dim + 2;
        let mut planes = Vec::with_capacity(hashes.len() * p as usize * aug);
        for h in hashes {
            assert_eq!(h.dim(), dim, "bank rows must share dim");
            assert_eq!(h.bits(), p, "bank rows must share p");
            let srp = h.asym().srp();
            for j in 0..p as usize {
                planes.extend_from_slice(srp.plane(j));
            }
        }
        HashBank::dense(planes, hashes.len(), p, dim)
    }

    /// Build a dense bank from per-row *single-arm* asymmetric hashes —
    /// the classifier sketch's hash family (Theorem 3 inserts one arm, no
    /// PRP pairing). Same contiguous `[R * p, d + 2]` layout and the same
    /// exact-coefficient copy, so [`Self::data_bucket`] /
    /// [`Self::query_bucket`] agree bit-for-bit with the per-row scalar
    /// hashes.
    pub fn from_asym_rows(hashes: &[AsymmetricInnerProductHash]) -> Self {
        assert!(!hashes.is_empty(), "hash bank needs at least one row");
        let dim = hashes[0].dim();
        let p = hashes[0].bits();
        let aug = dim + 2;
        let mut planes = Vec::with_capacity(hashes.len() * p as usize * aug);
        for h in hashes {
            assert_eq!(h.dim(), dim, "bank rows must share dim");
            assert_eq!(h.bits(), p, "bank rows must share p");
            for j in 0..p as usize {
                planes.extend_from_slice(h.srp().plane(j));
            }
        }
        HashBank::dense(planes, hashes.len(), p, dim)
    }

    /// Build a sparse-Rademacher bank: one
    /// [`SparseRademacherPlanes`] draw per row seed, over the augmented
    /// `d + 2` coordinates, split into head runs + tail coefficients.
    pub fn sparse_from_seeds(dim: usize, p: u32, seeds: &[u64], density_permille: u16) -> Self {
        assert!(!seeds.is_empty(), "hash bank needs at least one row");
        let n = dim + 2;
        let bank_rows = seeds
            .iter()
            .map(|&seed| {
                let sp = SparseRademacherPlanes::new(n, p, seed, density_permille);
                let pu = p as usize;
                let mut offsets = vec![0u32];
                let mut idx = Vec::new();
                let mut sign = Vec::new();
                let mut c_q = vec![0.0; pu];
                let mut c_d = vec![0.0; pu];
                for j in 0..pu {
                    for (i, s) in sp.nonzeros(j) {
                        if i < dim {
                            idx.push(i as u32);
                            sign.push(s);
                        } else if i == dim {
                            c_q[j] = s;
                        } else {
                            c_d[j] = s;
                        }
                    }
                    offsets.push(idx.len() as u32);
                }
                SparseBankRow { offsets, idx, sign, c_q, c_d }
            })
            .collect();
        HashBank { kind: BankKind::Sparse { bank_rows }, rows: seeds.len(), p, dim }
    }

    /// Build a fast-Hadamard bank: one [`FastHadamardPlanes`] draw per
    /// row seed over the augmented `d + 2` coordinates, with the two
    /// augmented-slot columns peeled out so the per-example pass only
    /// transforms the head.
    pub fn hadamard_from_seeds(dim: usize, p: u32, seeds: &[u64]) -> Self {
        assert!(!seeds.is_empty(), "hash bank needs at least one row");
        let n = dim + 2;
        let bank_rows = seeds
            .iter()
            .map(|&seed| {
                let planes = FastHadamardPlanes::new(n, p, seed);
                let col_q = planes.basis_column(dim);
                let col_d = planes.basis_column(dim + 1);
                HadamardBankRow { planes, col_q, col_d }
            })
            .collect();
        HashBank { kind: BankKind::Hadamard { bank_rows }, rows: seeds.len(), p, dim }
    }

    /// Number of sketch rows R.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Hyperplanes per row p.
    pub fn bits(&self) -> u32 {
        self.p
    }

    /// Raw (unaugmented) input dimension d.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Buckets per row, `2^p`.
    pub fn range(&self) -> usize {
        1usize << self.p
    }

    /// Hash-family name (`dense` | `sparse` | `hadamard`), diagnostics.
    pub fn family(&self) -> &'static str {
        match &self.kind {
            BankKind::Dense { .. } => "dense",
            BankKind::Sparse { .. } => "sparse",
            BankKind::Hadamard { .. } => "hadamard",
        }
    }

    /// Projection kernel name the dense family resolved to (`scalar` for
    /// structured families, whose evaluation is not plane-parallel).
    pub fn kernel_name(&self) -> &'static str {
        match &self.kind {
            BankKind::Dense { kernel, .. } => kernel.name(),
            _ => "scalar",
        }
    }

    /// Bank memory in bytes (diagnostics).
    pub fn bytes(&self) -> usize {
        let f = std::mem::size_of::<f64>();
        let u = std::mem::size_of::<u32>();
        match &self.kind {
            BankKind::Dense { planes, transposed, .. } => (planes.len() + transposed.len()) * f,
            BankKind::Sparse { bank_rows } => bank_rows
                .iter()
                .map(|row| {
                    (row.offsets.len() + row.idx.len()) * u
                        + (row.sign.len() + row.c_q.len() + row.c_d.len()) * f
                })
                .sum(),
            BankKind::Hadamard { bank_rows } => bank_rows
                .iter()
                .map(|row| {
                    (3 * row.planes.padded_len() + row.col_q.len() + row.col_d.len()) * f
                        + self.p as usize * std::mem::size_of::<usize>()
                })
                .sum(),
        }
    }

    /// Plane `j` of row `r` as a `(d + 2)`-slice. Dense family only —
    /// structured families have no materialized planes.
    #[inline]
    pub fn plane(&self, r: usize, j: usize) -> &[f64] {
        let BankKind::Dense { planes, .. } = &self.kind else {
            panic!("plane access requires the dense family (bank is {})", self.family())
        };
        let aug = self.dim + 2;
        let idx = r * self.p as usize + j;
        &planes[idx * aug..(idx + 1) * aug]
    }

    /// The MIPS tail coordinate `sqrt(1 - ||v||^2)` — the same magnitude
    /// on both sides of the asymmetric pair (only its *position* in the
    /// augmented vector differs). Computed exactly like
    /// [`crate::lsh::asym::augment`], including its unit-ball assertion.
    #[inline]
    pub fn mips_tail(z: &[f64]) -> f64 {
        let sq: f64 = z.iter().map(|x| x * x).sum();
        assert!(
            sq <= 1.0 + 1e-9,
            "asymmetric LSH input must lie in the unit ball (||v||^2 = {sq})"
        );
        (1.0 - sq).max(0.0).sqrt()
    }

    #[inline]
    fn trow<'a>(transposed: &'a [f64], r: usize, aug: usize, pu: usize) -> &'a [f64] {
        &transposed[r * aug * pu..(r + 1) * aug * pu]
    }

    /// Both PRP insert buckets of row `r` for data vector `z` with
    /// precomputed `tail`, from a single pass over the row's planes.
    /// Dense: equals `hashes[r].insert_buckets(z)` bit-for-bit (SIMD or
    /// scalar — the kernels are bit-identical).
    #[inline]
    pub fn data_pair(&self, r: usize, z: &[f64], tail: f64) -> (usize, usize) {
        debug_assert_eq!(z.len(), self.dim, "bank data dim mismatch");
        let pu = self.p as usize;
        match &self.kind {
            BankKind::Dense { transposed, kernel, .. } => {
                let trow = Self::trow(transposed, r, self.dim + 2, pu);
                simd::data_pair_t(*kernel, trow, pu, z, tail)
            }
            BankKind::Sparse { bank_rows } => {
                let row = &bank_rows[r];
                let mut pos = 0usize;
                let mut neg = 0usize;
                for j in 0..pu {
                    let lo = row.offsets[j] as usize;
                    let hi = row.offsets[j + 1] as usize;
                    let mut s = 0.0;
                    for k in lo..hi {
                        s += row.sign[k] * z[row.idx[k] as usize];
                    }
                    let t = row.c_d[j] * tail;
                    // Tie-break sign(0) as 1, matching the scalar SRP.
                    if s + t >= 0.0 {
                        pos |= 1 << j;
                    }
                    if t - s >= 0.0 {
                        neg |= 1 << j;
                    }
                }
                (pos, neg)
            }
            BankKind::Hadamard { bank_rows } => {
                let row = &bank_rows[r];
                HADAMARD_SCRATCH.with(|c| {
                    let out = &mut *c.borrow_mut();
                    row.planes.transform(z, out);
                    let mut pos = 0usize;
                    let mut neg = 0usize;
                    for j in 0..pu {
                        let s = out[row.planes.selected_index(j)];
                        let t = row.col_d[j] * tail;
                        if s + t >= 0.0 {
                            pos |= 1 << j;
                        }
                        if t - s >= 0.0 {
                            neg |= 1 << j;
                        }
                    }
                    (pos, neg)
                })
            }
        }
    }

    /// Single-arm data bucket of row `r` for data vector `z` with
    /// precomputed tail — the positive arm of [`Self::data_pair`], which
    /// is all the classifier sketch inserts (Theorem 3, no PRP pairing).
    /// Dense: equals `asym.hash_side(z, Side::Data)` bit-for-bit — the
    /// skipped query-slot term `w[d] * 0.0` never changes the
    /// accumulator value.
    #[inline]
    pub fn data_bucket(&self, r: usize, z: &[f64], tail: f64) -> usize {
        debug_assert_eq!(z.len(), self.dim, "bank data dim mismatch");
        self.side_bucket(r, z, tail, false)
    }

    /// Query bucket of row `r` for query vector `q` with precomputed
    /// query-side tail. Dense: equals `hashes[r].query_bucket(q)`
    /// bit-for-bit.
    #[inline]
    pub fn query_bucket(&self, r: usize, q: &[f64], tail: f64) -> usize {
        debug_assert_eq!(q.len(), self.dim, "bank query dim mismatch");
        self.side_bucket(r, q, tail, true)
    }

    #[inline]
    fn side_bucket(&self, r: usize, v: &[f64], tail: f64, query_side: bool) -> usize {
        let pu = self.p as usize;
        match &self.kind {
            BankKind::Dense { transposed, kernel, .. } => {
                let trow = Self::trow(transposed, r, self.dim + 2, pu);
                let tail_row = if query_side { self.dim } else { self.dim + 1 };
                simd::side_bucket_t(*kernel, trow, pu, v, tail, tail_row)
            }
            BankKind::Sparse { bank_rows } => {
                let row = &bank_rows[r];
                let tail_c = if query_side { &row.c_q } else { &row.c_d };
                let mut h = 0usize;
                for j in 0..pu {
                    let lo = row.offsets[j] as usize;
                    let hi = row.offsets[j + 1] as usize;
                    let mut s = 0.0;
                    for k in lo..hi {
                        s += row.sign[k] * v[row.idx[k] as usize];
                    }
                    if s + tail_c[j] * tail >= 0.0 {
                        h |= 1 << j;
                    }
                }
                h
            }
            BankKind::Hadamard { bank_rows } => {
                let row = &bank_rows[r];
                let tail_c = if query_side { &row.col_q } else { &row.col_d };
                HADAMARD_SCRATCH.with(|c| {
                    let out = &mut *c.borrow_mut();
                    row.planes.transform(v, out);
                    let mut h = 0usize;
                    for j in 0..pu {
                        if out[row.planes.selected_index(j)] + tail_c[j] * tail >= 0.0 {
                            h |= 1 << j;
                        }
                    }
                    h
                })
            }
        }
    }

    /// Scalar-oracle version of [`Self::data_pair`]: the original
    /// plain-`dot` loop over the row-major planes, kept verbatim as the
    /// reference the SIMD kernels are property-tested against (and as the
    /// `bank_scalar_*` bench baseline). Dense family only.
    pub fn data_pair_scalar(&self, r: usize, z: &[f64], tail: f64) -> (usize, usize) {
        debug_assert_eq!(z.len(), self.dim, "bank data dim mismatch");
        let d = self.dim;
        let mut pos = 0usize;
        let mut neg = 0usize;
        for j in 0..self.p as usize {
            let w = self.plane(r, j);
            let s = dot(&w[..d], z);
            let t = w[d + 1] * tail;
            // Tie-break sign(0) as 1, matching the scalar SRP.
            if s + t >= 0.0 {
                pos |= 1 << j;
            }
            if t - s >= 0.0 {
                neg |= 1 << j;
            }
        }
        (pos, neg)
    }

    /// Scalar-oracle version of [`Self::data_bucket`]. Dense family only.
    pub fn data_bucket_scalar(&self, r: usize, z: &[f64], tail: f64) -> usize {
        debug_assert_eq!(z.len(), self.dim, "bank data dim mismatch");
        let d = self.dim;
        let mut h = 0usize;
        for j in 0..self.p as usize {
            let w = self.plane(r, j);
            if dot(&w[..d], z) + w[d + 1] * tail >= 0.0 {
                h |= 1 << j;
            }
        }
        h
    }

    /// Scalar-oracle version of [`Self::query_bucket`]. Dense family only.
    pub fn query_bucket_scalar(&self, r: usize, q: &[f64], tail: f64) -> usize {
        debug_assert_eq!(q.len(), self.dim, "bank query dim mismatch");
        let d = self.dim;
        let mut h = 0usize;
        for j in 0..self.p as usize {
            let w = self.plane(r, j);
            if dot(&w[..d], q) + w[d] * tail >= 0.0 {
                h |= 1 << j;
            }
        }
        h
    }

    /// Head projections of every plane in the bank: fills `out` with
    /// `R * p` values, `out[r * p + j] = <w_head(r, j), v>`, the per-plane
    /// head term before any tail contribution. This is the once-per-step
    /// base pass of the incremental query engine
    /// ([`crate::lsh::query::QueryEngine`]).
    ///
    /// Each plane's partial sum accumulates in ascending coordinate
    /// order, so dense values are **bit-identical** to
    /// `dot(&plane(r, j)[..d], v)` — the head term of the scalar query
    /// oracle. Sparse reproduces the CSR run order and Hadamard the
    /// shared row transform, again exactly the decisions' head terms.
    pub fn project_all(&self, v: &[f64], out: &mut Vec<f64>) {
        debug_assert_eq!(v.len(), self.dim, "bank projection dim mismatch");
        let pu = self.p as usize;
        out.clear();
        out.resize(self.rows * pu, 0.0);
        match &self.kind {
            BankKind::Dense { transposed, kernel, .. } => {
                for r in 0..self.rows {
                    let trow = Self::trow(transposed, r, self.dim + 2, pu);
                    let acc = &mut out[r * pu..(r + 1) * pu];
                    for (i, &x) in v.iter().enumerate() {
                        simd::axpy(*kernel, acc, x, &trow[i * pu..(i + 1) * pu]);
                    }
                }
            }
            BankKind::Sparse { bank_rows } => {
                for (r, row) in bank_rows.iter().enumerate() {
                    let acc = &mut out[r * pu..(r + 1) * pu];
                    for (j, a) in acc.iter_mut().enumerate() {
                        let lo = row.offsets[j] as usize;
                        let hi = row.offsets[j + 1] as usize;
                        let mut s = 0.0;
                        for k in lo..hi {
                            s += row.sign[k] * v[row.idx[k] as usize];
                        }
                        *a = s;
                    }
                }
            }
            BankKind::Hadamard { bank_rows } => {
                for (r, row) in bank_rows.iter().enumerate() {
                    let acc = &mut out[r * pu..(r + 1) * pu];
                    HADAMARD_SCRATCH.with(|c| {
                        let scratch = &mut *c.borrow_mut();
                        row.planes.transform(v, scratch);
                        for (j, a) in acc.iter_mut().enumerate() {
                            *a = scratch[row.planes.selected_index(j)];
                        }
                    });
                }
            }
        }
    }

    /// Column `k` of every plane's head: fills `out` with `R * p` values,
    /// `out[r * p + j] = w_head(r, j)[k]` — the rank-1 update direction
    /// for an axis perturbation of coordinate `k`. Dense gathers the
    /// contiguous transposed column, sparse scans each plane's CSR run,
    /// Hadamard evaluates `H(e_k)` per row (a signed ±1 column of the
    /// effective projection matrix).
    pub fn head_column(&self, k: usize, out: &mut Vec<f64>) {
        assert!(k < self.dim, "head column {k} out of range (dim {})", self.dim);
        let pu = self.p as usize;
        out.clear();
        out.resize(self.rows * pu, 0.0);
        match &self.kind {
            BankKind::Dense { transposed, .. } => {
                for r in 0..self.rows {
                    let trow = Self::trow(transposed, r, self.dim + 2, pu);
                    out[r * pu..(r + 1) * pu].copy_from_slice(&trow[k * pu..(k + 1) * pu]);
                }
            }
            BankKind::Sparse { bank_rows } => {
                for (r, row) in bank_rows.iter().enumerate() {
                    let dst = &mut out[r * pu..(r + 1) * pu];
                    for (j, d) in dst.iter_mut().enumerate() {
                        let lo = row.offsets[j] as usize;
                        let hi = row.offsets[j + 1] as usize;
                        for t in lo..hi {
                            // Head indices ascend within a plane's run.
                            match (row.idx[t] as usize).cmp(&k) {
                                std::cmp::Ordering::Less => continue,
                                std::cmp::Ordering::Equal => {
                                    *d = row.sign[t];
                                    break;
                                }
                                std::cmp::Ordering::Greater => break,
                            }
                        }
                    }
                }
            }
            BankKind::Hadamard { bank_rows } => {
                for (r, row) in bank_rows.iter().enumerate() {
                    let col = row.planes.basis_column(k);
                    out[r * pu..(r + 1) * pu].copy_from_slice(&col);
                }
            }
        }
    }

    /// Query-side tail coefficient of every plane: fills `out` with
    /// `R * p` values, `out[r * p + j] = w(r, j)[d]` — the coefficient
    /// multiplying the MIPS query tail in [`Self::query_bucket`]'s
    /// decision. Cached once by the incremental query engine.
    pub fn query_tail_coeffs(&self, out: &mut Vec<f64>) {
        let pu = self.p as usize;
        out.clear();
        out.resize(self.rows * pu, 0.0);
        match &self.kind {
            BankKind::Dense { transposed, .. } => {
                for r in 0..self.rows {
                    let trow = Self::trow(transposed, r, self.dim + 2, pu);
                    out[r * pu..(r + 1) * pu]
                        .copy_from_slice(&trow[self.dim * pu..(self.dim + 1) * pu]);
                }
            }
            BankKind::Sparse { bank_rows } => {
                for (r, row) in bank_rows.iter().enumerate() {
                    out[r * pu..(r + 1) * pu].copy_from_slice(&row.c_q);
                }
            }
            BankKind::Hadamard { bank_rows } => {
                for (r, row) in bank_rows.iter().enumerate() {
                    out[r * pu..(r + 1) * pu].copy_from_slice(&row.col_q);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::LshFunction;
    use crate::testing::{cases, gen_ball_point, gen_dim};

    fn mk_rows(dim: usize, p: u32, rows: usize, seed: u64) -> Vec<PairedRandomProjection> {
        (0..rows)
            .map(|r| {
                PairedRandomProjection::new(
                    dim,
                    p,
                    seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(r as u64),
                )
            })
            .collect()
    }

    fn row_seeds(rows: usize, seed: u64) -> Vec<u64> {
        (0..rows as u64)
            .map(|r| seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(r))
            .collect()
    }

    #[test]
    fn data_pair_matches_scalar_prp_bitwise() {
        cases(60, 21, |rng, case| {
            let dim = gen_dim(rng, 1, 12);
            let p = 1 + (case % 8) as u32;
            let hashes = mk_rows(dim, p, 5, case as u64);
            let bank = HashBank::from_rows(&hashes);
            let z = gen_ball_point(rng, dim, 0.95);
            let tail = HashBank::mips_tail(&z);
            for (r, h) in hashes.iter().enumerate() {
                assert_eq!(bank.data_pair(r, &z, tail), h.insert_buckets(&z));
            }
        });
    }

    #[test]
    fn query_bucket_matches_scalar_prp_bitwise() {
        cases(60, 22, |rng, case| {
            let dim = gen_dim(rng, 1, 12);
            let p = 1 + (case % 8) as u32;
            let hashes = mk_rows(dim, p, 4, case as u64 ^ 0xBEEF);
            let bank = HashBank::from_rows(&hashes);
            let q = gen_ball_point(rng, dim, 0.95);
            let sq: f64 = q.iter().map(|x| x * x).sum();
            let tail = (1.0 - sq).max(0.0).sqrt();
            for (r, h) in hashes.iter().enumerate() {
                assert_eq!(bank.query_bucket(r, &q, tail), h.query_bucket(&q));
            }
        });
    }

    #[test]
    fn simd_path_matches_scalar_oracle_at_p_max_and_large_d() {
        // Bank-only sweep at the config-validated maximum p = 24 and d in
        // the hundreds: pins the SIMD main loop *and* remainder lanes
        // against the verbatim scalar oracle without allocating grids.
        cases(20, 26, |rng, case| {
            let dim = 100 + (case * 37) % 300;
            // Descend from p = 24 so the maximum is pinned at any case budget.
            let p = 24 - (case % 24) as u32;
            let hashes = mk_rows(dim, p, 3, case as u64 ^ 0x51D);
            let bank = HashBank::from_rows(&hashes);
            let z = gen_ball_point(rng, dim, 0.95);
            let tail = HashBank::mips_tail(&z);
            for r in 0..bank.rows() {
                assert_eq!(
                    bank.data_pair(r, &z, tail),
                    bank.data_pair_scalar(r, &z, tail),
                    "kernel {} diverged (dim={dim} p={p} row={r})",
                    bank.kernel_name()
                );
                assert_eq!(bank.data_bucket(r, &z, tail), bank.data_bucket_scalar(r, &z, tail));
                assert_eq!(bank.query_bucket(r, &z, tail), bank.query_bucket_scalar(r, &z, tail));
            }
        });
    }

    #[test]
    fn bank_shape_and_plane_access() {
        let hashes = mk_rows(3, 4, 7, 11);
        let bank = HashBank::from_rows(&hashes);
        assert_eq!(bank.rows(), 7);
        assert_eq!(bank.bits(), 4);
        assert_eq!(bank.dim(), 3);
        assert_eq!(bank.range(), 16);
        assert_eq!(bank.family(), "dense");
        // Row-major planes + the transposed SIMD copy.
        assert_eq!(bank.bytes(), 2 * 7 * 4 * 5 * 8);
        for (r, h) in hashes.iter().enumerate() {
            for j in 0..4 {
                assert_eq!(bank.plane(r, j), h.asym().srp().plane(j));
            }
        }
    }

    #[test]
    #[should_panic]
    fn mips_tail_rejects_outside_ball() {
        HashBank::mips_tail(&[1.5, 0.0]);
    }

    fn mk_asym_rows(dim: usize, p: u32, rows: usize, seed: u64) -> Vec<AsymmetricInnerProductHash> {
        (0..rows)
            .map(|r| {
                AsymmetricInnerProductHash::new(
                    dim,
                    p,
                    seed.wrapping_mul(0x51afd6ed558ccd65).wrapping_add(r as u64),
                )
            })
            .collect()
    }

    #[test]
    fn asym_bank_data_bucket_matches_scalar_hash_bitwise() {
        use crate::lsh::asym::Side;
        cases(60, 23, |rng, case| {
            let dim = gen_dim(rng, 1, 12);
            let p = 1 + (case % 8) as u32;
            let hashes = mk_asym_rows(dim, p, 5, case as u64);
            let bank = HashBank::from_asym_rows(&hashes);
            let z = gen_ball_point(rng, dim, 0.95);
            let tail = HashBank::mips_tail(&z);
            for (r, h) in hashes.iter().enumerate() {
                assert_eq!(bank.data_bucket(r, &z, tail), h.hash_side(&z, Side::Data));
            }
        });
    }

    #[test]
    fn asym_bank_query_bucket_matches_scalar_hash_bitwise() {
        use crate::lsh::asym::Side;
        cases(60, 24, |rng, case| {
            let dim = gen_dim(rng, 1, 12);
            let p = 1 + (case % 8) as u32;
            let hashes = mk_asym_rows(dim, p, 4, case as u64 ^ 0xC1A5);
            let bank = HashBank::from_asym_rows(&hashes);
            let q = gen_ball_point(rng, dim, 0.95);
            let tail = HashBank::mips_tail(&q);
            for (r, h) in hashes.iter().enumerate() {
                assert_eq!(bank.query_bucket(r, &q, tail), h.hash_side(&q, Side::Query));
            }
        });
    }

    #[test]
    fn sparse_bank_matches_augmented_lsh_oracle() {
        // The sparse family's semantics decompose into head + tail
        // terms; the whole-vector LshFunction oracle accumulates the
        // zero query-slot term in between, which never flips a `>= 0`
        // decision — so buckets agree exactly.
        cases(40, 27, |rng, case| {
            let dim = gen_dim(rng, 1, 20);
            let p = 1 + (case % 8) as u32;
            let seeds = row_seeds(4, case as u64 ^ 0x5AA5);
            let bank = HashBank::sparse_from_seeds(dim, p, &seeds, 300);
            assert_eq!(bank.family(), "sparse");
            let z = gen_ball_point(rng, dim, 0.95);
            let tail = HashBank::mips_tail(&z);
            for (r, &seed) in seeds.iter().enumerate() {
                let oracle = SparseRademacherPlanes::new(dim + 2, p, seed, 300);
                let mut aug_data: Vec<f64> = z.clone();
                aug_data.push(0.0);
                aug_data.push(tail);
                let mut aug_neg: Vec<f64> = z.iter().map(|v| -v).collect();
                aug_neg.push(0.0);
                aug_neg.push(tail);
                let mut aug_query: Vec<f64> = z.clone();
                aug_query.push(tail);
                aug_query.push(0.0);
                let (pos, neg) = bank.data_pair(r, &z, tail);
                assert_eq!(pos, oracle.hash(&aug_data));
                assert_eq!(neg, oracle.hash(&aug_neg));
                assert_eq!(bank.data_bucket(r, &z, tail), pos);
                assert_eq!(bank.query_bucket(r, &z, tail), oracle.hash(&aug_query));
            }
        });
    }

    #[test]
    fn structured_banks_hash_antipodal_arms_consistently() {
        // pos(-z) must equal neg(z) bitwise for every family: IEEE-754
        // negation distributes exactly over the head evaluation.
        cases(30, 28, |rng, case| {
            let dim = gen_dim(rng, 3, 40);
            let p = (1 + (case % 8) as u32).min(crate::util::mathx::next_pow2(dim + 2) as u32);
            let seeds = row_seeds(3, case as u64 ^ 0x7E57);
            let banks = [
                HashBank::sparse_from_seeds(dim, p, &seeds, 250),
                HashBank::hadamard_from_seeds(dim, p, &seeds),
            ];
            let z = gen_ball_point(rng, dim, 0.95);
            let neg_z: Vec<f64> = z.iter().map(|v| -v).collect();
            let tail = HashBank::mips_tail(&z);
            for bank in &banks {
                for r in 0..bank.rows() {
                    let (pos, neg) = bank.data_pair(r, &z, tail);
                    let (pos2, neg2) = bank.data_pair(r, &neg_z, tail);
                    assert_eq!(pos2, neg, "family {}", bank.family());
                    assert_eq!(neg2, pos, "family {}", bank.family());
                }
            }
        });
    }

    #[test]
    fn hadamard_bank_matches_explicit_projection() {
        // Cross-check the decomposed head-transform + tail-column path
        // against an explicit matrix-vector product built from basis
        // columns (closeness, not bit-identity: butterfly order differs).
        let dim = 6;
        let p = 5u32;
        let seeds = row_seeds(2, 99);
        let bank = HashBank::hadamard_from_seeds(dim, p, &seeds);
        assert_eq!(bank.family(), "hadamard");
        let mut rng = crate::util::rng::Xoshiro256::new(7);
        let z = gen_ball_point(&mut rng, dim, 0.9);
        let tail = HashBank::mips_tail(&z);
        for (r, &seed) in seeds.iter().enumerate() {
            let planes = FastHadamardPlanes::new(dim + 2, p, seed);
            let cols: Vec<Vec<f64>> = (0..dim + 2).map(|c| planes.basis_column(c)).collect();
            let mut expect_pos = 0usize;
            let mut expect_query = 0usize;
            for j in 0..p as usize {
                let head: f64 = (0..dim).map(|c| cols[c][j] * z[c]).sum();
                if head + cols[dim + 1][j] * tail >= 0.0 {
                    expect_pos |= 1 << j;
                }
                if head + cols[dim][j] * tail >= 0.0 {
                    expect_query |= 1 << j;
                }
            }
            // Projections are well away from zero with prob. 1, so the
            // closeness of the two evaluation orders implies equal signs.
            assert_eq!(bank.data_pair(r, &z, tail).0, expect_pos);
            assert_eq!(bank.query_bucket(r, &z, tail), expect_query);
        }
    }

    #[test]
    fn structured_bank_shapes_and_determinism() {
        let seeds = row_seeds(5, 13);
        let sp = HashBank::sparse_from_seeds(4, 6, &seeds, 200);
        let hd = HashBank::hadamard_from_seeds(4, 6, &seeds);
        for bank in [&sp, &hd] {
            assert_eq!(bank.rows(), 5);
            assert_eq!(bank.bits(), 6);
            assert_eq!(bank.dim(), 4);
            assert_eq!(bank.range(), 64);
            assert!(bank.bytes() > 0);
        }
        // Same seeds → same buckets (fleet merge compatibility rests on
        // this).
        let sp2 = HashBank::sparse_from_seeds(4, 6, &seeds, 200);
        let hd2 = HashBank::hadamard_from_seeds(4, 6, &seeds);
        let z = [0.1, -0.2, 0.3, 0.05];
        let tail = HashBank::mips_tail(&z);
        for r in 0..5 {
            assert_eq!(sp.data_pair(r, &z, tail), sp2.data_pair(r, &z, tail));
            assert_eq!(hd.data_pair(r, &z, tail), hd2.data_pair(r, &z, tail));
        }
    }

    #[test]
    #[should_panic(expected = "dense family")]
    fn structured_bank_rejects_plane_access() {
        let bank = HashBank::sparse_from_seeds(3, 4, &[1, 2], 500);
        let _ = bank.plane(0, 0);
    }
}
