//! Paired random projections (PRP) — the paper's construction for a
//! regression surrogate loss (Section 4.1).
//!
//! A PRP hash is an asymmetric inner-product hash where each *data* point
//! `z = [x, y]` is inserted **twice**: once as `z` and once as `-z`. The
//! query `theta~ = [theta, -1]` is hashed once. The expected (normalized)
//! count at the queried bucket is then
//!
//! ```text
//! E[count]/n = k(theta~, z) + k(theta~, -z)
//!            = (1 - acos(+t)/pi)^p + (1 - acos(-t)/pi)^p,  t = <theta~, z>
//! ```
//!
//! which is `2 * g(theta~, z)` — twice the paper's surrogate loss (the
//! paper's definition carries the 1/2 normalization; we keep it in the
//! estimator). It is symmetric in `t` and, for p >= 2, convex with its
//! minimum exactly where `<theta~, z> = 0`, i.e. on the least-squares
//! regression surface (Theorem 2).

use super::asym::{AsymmetricInnerProductHash, Side};

/// A PRP hash function over `R^dim` (dim includes the appended label
/// coordinate, i.e. `dim = d + 1` for a d-feature regression problem).
#[derive(Clone, Debug)]
pub struct PairedRandomProjection {
    inner: AsymmetricInnerProductHash,
}

impl PairedRandomProjection {
    pub fn new(dim: usize, p: u32, seed: u64) -> Self {
        PairedRandomProjection {
            inner: AsymmetricInnerProductHash::new(dim, p, seed),
        }
    }

    /// The two buckets a data point updates: `hash(z)` and `hash(-z)`.
    pub fn insert_buckets(&self, z: &[f64]) -> (usize, usize) {
        (
            self.inner.hash_side(z, Side::Data),
            self.inner.hash_data_negated(z),
        )
    }

    /// Hot-path variant of [`Self::insert_buckets`]: takes the two
    /// augmented arms (`augment(z)`, `augment(-z)`) precomputed once per
    /// insert and shared across all sketch rows — the augmentation (a
    /// norm + sqrt + two allocations) dominates the per-row cost
    /// otherwise.
    #[inline]
    pub fn insert_buckets_aug(&self, aug_pos: &[f64], aug_neg: &[f64]) -> (usize, usize) {
        (
            self.inner.hash_augmented(aug_pos),
            self.inner.hash_augmented(aug_neg),
        )
    }

    /// The single bucket a query probes.
    pub fn query_bucket(&self, theta_tilde: &[f64]) -> usize {
        self.inner.hash_side(theta_tilde, Side::Query)
    }

    /// Hot-path variant of [`Self::query_bucket`] over a precomputed
    /// query-side augmentation.
    #[inline]
    pub fn query_bucket_aug(&self, aug_query: &[f64]) -> usize {
        self.inner.hash_augmented(aug_query)
    }

    /// Number of hyperplanes p.
    pub fn bits(&self) -> u32 {
        self.inner.bits()
    }

    /// Bucket count `2^p`.
    pub fn range(&self) -> usize {
        self.inner.range()
    }

    pub fn dim(&self) -> usize {
        self.inner.dim()
    }

    /// Expected normalized count for a single example — the quantity the
    /// sketch estimates, equal to `2 g(theta~, z)` with `g` the paper's
    /// surrogate loss.
    pub fn expected_count(&self, theta_tilde: &[f64], z: &[f64]) -> f64 {
        let kp = self.inner.collision_probability_qd(theta_tilde, z);
        let neg: Vec<f64> = z.iter().map(|v| -v).collect();
        let km = self.inner.collision_probability_qd(theta_tilde, &neg);
        kp + km
    }

    /// Access to the underlying asymmetric hash (AOT path).
    pub fn asym(&self) -> &AsymmetricInnerProductHash {
        &self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::prp_loss::prp_surrogate;
    use crate::testing::{assert_close, cases, gen_ball_point};
    use crate::util::mathx::dot;

    #[test]
    fn insert_buckets_in_range_and_distinct_in_general() {
        cases(40, 1, |rng, case| {
            let h = PairedRandomProjection::new(5, 4, case as u64);
            let z = gen_ball_point(rng, 5, 0.9);
            let (b1, b2) = h.insert_buckets(&z);
            assert!(b1 < h.range() && b2 < h.range());
        });
    }

    #[test]
    fn expected_count_is_twice_surrogate_loss() {
        cases(60, 2, |rng, case| {
            let d = crate::testing::gen_dim(rng, 1, 8);
            let p = 1 + (case % 6) as u32;
            let h = PairedRandomProjection::new(d, p, case as u64);
            let z = gen_ball_point(rng, d, 0.7);
            let q = gen_ball_point(rng, d, 0.7);
            let t = dot(&q, &z);
            assert_close(h.expected_count(&q, &z), 2.0 * prp_surrogate(t, p), 1e-12);
        });
    }

    #[test]
    fn empirical_pair_count_matches_expectation() {
        // Monte Carlo over hash draws: average of [query hits z-bucket] +
        // [query hits (-z)-bucket] should match expected_count.
        let z = vec![0.4, -0.3];
        let q = vec![0.2, 0.5];
        let probe = PairedRandomProjection::new(2, 2, 0);
        let want = probe.expected_count(&q, &z);
        let trials = 20_000;
        let mut acc = 0.0;
        for s in 0..trials {
            let h = PairedRandomProjection::new(2, 2, s as u64);
            let (b1, b2) = h.insert_buckets(&z);
            let qb = h.query_bucket(&q);
            acc += f64::from(qb == b1) + f64::from(qb == b2);
        }
        assert_close(acc / trials as f64, want, 0.02);
    }

    #[test]
    fn expected_count_symmetric_in_t() {
        let h = PairedRandomProjection::new(1, 4, 5);
        for i in 0..10 {
            let t = 0.08 * i as f64;
            let a = h.expected_count(&[0.9], &[t / 0.9]);
            let b = h.expected_count(&[0.9], &[-t / 0.9]);
            assert_close(a, b, 1e-12);
        }
    }

    #[test]
    fn expected_count_minimized_at_orthogonality() {
        let h = PairedRandomProjection::new(1, 4, 6);
        let at_zero = h.expected_count(&[0.9], &[0.0]);
        for &t in &[0.2, 0.5, 0.8] {
            assert!(h.expected_count(&[0.9], &[t]) > at_zero);
        }
    }
}
