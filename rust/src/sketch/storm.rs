//! The STORM sketch — the paper's central data structure.
//!
//! `R` rows of `B = 2^p` integer counters. Each row `r` owns an
//! independent PRP hash (asymmetric inner-product LSH over the augmented
//! example space `R^{d+1}` — see [`crate::lsh::prp`]).
//!
//! **Insert** (`z = [x, y]`): increment both `l_r(z)` and `l_r(-z)` in
//! every row — two counter updates per row (Algorithm 1 / Figure 1).
//!
//! **Query** (`theta~ = [theta, -1]`, rescaled into the unit ball): read
//! the count at `[r, l_r(theta~)]`, average over rows, divide by `n`. The
//! expectation is `2 * (1/n) sum_i g(theta~, z_i)` — the paper's surrogate
//! empirical risk up to the constant 2 (kept in [`StormSketch::SCALE`]).
//!
//! **Classification mode**: insert `[x * (-y)]` once per row (labels in
//! {-1, +1}); the expected normalized count is the margin loss of
//! Theorem 3 up to the `2^p` constant.

use super::counters::CounterGrid;
use super::Sketch;
use crate::config::StormConfig;
use crate::lsh::prp::PairedRandomProjection;
use crate::util::mathx::norm2;

/// Scale relating raw normalized counts to the paper's surrogate loss `g`:
/// `E[query] = SCALE * mean_i g(theta~, z_i)`.
pub const SCALE: f64 = 2.0;

/// The STORM sketch for regression surrogate-loss estimation.
pub struct StormSketch {
    cfg: StormConfig,
    grid: CounterGrid,
    hashes: Vec<PairedRandomProjection>,
    count: u64,
    dim: usize,
    seed: u64,
}

impl StormSketch {
    /// `dim` is the *augmented* dimension `d + 1` ( features + label ).
    pub fn new(cfg: StormConfig, dim: usize, seed: u64) -> Self {
        assert!(dim >= 1);
        let hashes: Vec<PairedRandomProjection> = (0..cfg.rows)
            .map(|r| {
                PairedRandomProjection::new(
                    dim,
                    cfg.power,
                    seed.wrapping_mul(0x9E3779B97F4A7C15)
                        .wrapping_add(r as u64),
                )
            })
            .collect();
        StormSketch {
            grid: CounterGrid::new(cfg.rows, cfg.buckets(), cfg.saturating),
            hashes,
            count: 0,
            dim,
            cfg,
            seed,
        }
    }

    /// Insert a `(x, y)` example (regression mode): augments to `[x, y]`.
    pub fn insert_example(&mut self, x: &[f64], y: f64) {
        let mut z = Vec::with_capacity(x.len() + 1);
        z.extend_from_slice(x);
        z.push(y);
        self.insert(&z);
    }

    /// Estimated surrogate empirical risk `mean_i g(theta~, z_i)` at a
    /// query `theta~` already inside the unit ball.
    pub fn estimate_risk(&self, theta_tilde: &[f64]) -> f64 {
        self.query(theta_tilde) / SCALE
    }

    /// Query with automatic rescaling: `[theta, -1]` generally has norm
    /// above 1; the asymmetric hash needs it inside the unit ball. Scaling
    /// the query by a positive constant does not move the surrogate
    /// minimizer (the loss is monotone in |<q, z>| and all candidates are
    /// scaled alike within one optimization step).
    pub fn estimate_risk_scaled(&self, theta_tilde: &[f64]) -> f64 {
        let n = norm2(theta_tilde);
        let radius = crate::data::scale::query_radius();
        if n <= radius {
            return self.estimate_risk(theta_tilde);
        }
        let scaled: Vec<f64> = theta_tilde.iter().map(|v| v * radius / n).collect();
        self.estimate_risk(&scaled)
    }

    pub fn config(&self) -> StormConfig {
        self.cfg
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn grid(&self) -> &CounterGrid {
        &self.grid
    }

    /// Per-row hash functions (AOT compile path reads the hyperplanes).
    pub fn hashes(&self) -> &[PairedRandomProjection] {
        &self.hashes
    }

    /// Bulk-add a `[R, B]` histogram delta produced by the XLA insert
    /// kernel for a batch of `batch_n` examples.
    pub fn add_batch_counts(&mut self, delta: &[u32], batch_n: u64) {
        self.grid.add_counts(delta);
        self.count += batch_n;
    }

    /// Replace-free accessor used by the serializer.
    pub(crate) fn parts(&self) -> (&CounterGrid, u64) {
        (&self.grid, self.count)
    }

    pub(crate) fn parts_mut(&mut self) -> (&mut CounterGrid, &mut u64) {
        (&mut self.grid, &mut self.count)
    }
}

impl Sketch for StormSketch {
    fn insert(&mut self, z: &[f64]) {
        assert_eq!(z.len(), self.dim, "insert dim mismatch");
        // Hot path: augment both PRP arms ONCE — the augmentation (norm +
        // sqrt + allocation) is identical for every row, so hoisting it
        // out of the row loop is a ~3x insert-throughput win (see
        // EXPERIMENTS.md §Perf).
        let aug_pos = crate::lsh::asym::augment(z, crate::lsh::asym::Side::Data);
        let neg: Vec<f64> = z.iter().map(|v| -v).collect();
        let aug_neg = crate::lsh::asym::augment(&neg, crate::lsh::asym::Side::Data);
        for (r, h) in self.hashes.iter().enumerate() {
            let (b1, b2) = h.insert_buckets_aug(&aug_pos, &aug_neg);
            self.grid.increment(r, b1);
            self.grid.increment(r, b2);
        }
        self.count += 1;
    }

    fn count(&self) -> u64 {
        self.count
    }

    /// Raw normalized count estimate: `(1/n) * mean_r count[r, l_r(q)]`.
    fn query(&self, q: &[f64]) -> f64 {
        assert_eq!(q.len(), self.dim, "query dim mismatch");
        if self.count == 0 {
            return 0.0;
        }
        let aug_q = crate::lsh::asym::augment(q, crate::lsh::asym::Side::Query);
        let mut acc = 0.0;
        for (r, h) in self.hashes.iter().enumerate() {
            acc += self.grid.get(r, h.query_bucket_aug(&aug_q)) as f64;
        }
        acc / (self.hashes.len() as f64 * self.count as f64)
    }

    fn merge_from(&mut self, other: &Self) {
        assert_eq!(self.cfg, other.cfg, "merge: config mismatch");
        assert_eq!(self.seed, other.seed, "merge: seed (hash family) mismatch");
        assert_eq!(self.dim, other.dim, "merge: dim mismatch");
        self.grid.merge_from(&other.grid);
        self.count += other.count;
    }

    fn bytes(&self) -> usize {
        self.grid.bytes()
    }
}

/// Classification-mode STORM sketch (Theorem 3): inserts `-y * x` with a
/// *single* asymmetric hash per row (no pairing); the expected normalized
/// count at query `theta` is `(1 - acos(-y <theta, x>)/pi)^p =
//  g(theta, [x,y]) / 2^p`.
pub struct StormClassifierSketch {
    cfg: StormConfig,
    grid: CounterGrid,
    hashes: Vec<crate::lsh::asym::AsymmetricInnerProductHash>,
    count: u64,
    dim: usize,
    seed: u64,
}

impl StormClassifierSketch {
    /// `dim` is the raw feature dimension d (labels fold into the sign).
    pub fn new(cfg: StormConfig, dim: usize, seed: u64) -> Self {
        let hashes = (0..cfg.rows)
            .map(|r| {
                crate::lsh::asym::AsymmetricInnerProductHash::new(
                    dim,
                    cfg.power,
                    seed.wrapping_mul(0x51afd6ed558ccd65).wrapping_add(r as u64),
                )
            })
            .collect();
        StormClassifierSketch {
            grid: CounterGrid::new(cfg.rows, cfg.buckets(), cfg.saturating),
            hashes,
            count: 0,
            dim,
            cfg,
            seed,
        }
    }

    /// Insert a labelled example, `y` in {-1, +1}.
    pub fn insert_labelled(&mut self, x: &[f64], y: f64) {
        assert_eq!(x.len(), self.dim);
        assert!(y == 1.0 || y == -1.0, "labels must be +-1");
        let v: Vec<f64> = x.iter().map(|xi| -y * xi).collect();
        for (r, h) in self.hashes.iter().enumerate() {
            let b = h.hash_side(&v, crate::lsh::asym::Side::Data);
            self.grid.increment(r, b);
        }
        self.count += 1;
    }

    /// Estimated mean margin loss `mean_i g(theta, [x_i, y_i])` (with the
    /// `2^p` constant of Theorem 3 restored).
    pub fn estimate_risk(&self, theta: &[f64]) -> f64 {
        assert_eq!(theta.len(), self.dim);
        if self.count == 0 {
            return 0.0;
        }
        let mut acc = 0.0;
        for (r, h) in self.hashes.iter().enumerate() {
            acc += self.grid.get(r, h.hash_side(theta, crate::lsh::asym::Side::Query)) as f64;
        }
        let norm_count = acc / (self.hashes.len() as f64 * self.count as f64);
        norm_count * (self.cfg.buckets() as f64)
    }

    /// Query with unit-ball rescaling (same argument as the regression
    /// variant).
    pub fn estimate_risk_scaled(&self, theta: &[f64]) -> f64 {
        let n = norm2(theta);
        let radius = crate::data::scale::query_radius();
        if n <= radius {
            return self.estimate_risk(theta);
        }
        let scaled: Vec<f64> = theta.iter().map(|v| v * radius / n).collect();
        self.estimate_risk(&scaled)
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bytes(&self) -> usize {
        self.grid.bytes()
    }

    pub fn merge_from(&mut self, other: &Self) {
        assert_eq!(self.cfg, other.cfg);
        assert_eq!(self.seed, other.seed);
        assert_eq!(self.dim, other.dim);
        self.grid.merge_from(&other.grid);
        self.count += other.count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::prp_loss::prp_surrogate;
    use crate::testing::{assert_close, gen_ball_point};
    use crate::util::mathx::dot;
    use crate::util::rng::Xoshiro256;

    fn exact_surrogate(theta_tilde: &[f64], data: &[Vec<f64>], p: u32) -> f64 {
        data.iter()
            .map(|z| prp_surrogate(dot(theta_tilde, z), p))
            .sum::<f64>()
            / data.len() as f64
    }

    #[test]
    fn estimates_surrogate_risk_unbiasedly() {
        let mut rng = Xoshiro256::new(3);
        let dim = 5;
        let data: Vec<Vec<f64>> = (0..300)
            .map(|_| gen_ball_point(&mut rng, dim, 0.9))
            .collect();
        let q = gen_ball_point(&mut rng, dim, 0.8);
        let cfg = StormConfig { rows: 2000, power: 4, saturating: true };
        let mut sk = StormSketch::new(cfg, dim, 17);
        for z in &data {
            sk.insert(z);
        }
        let est = sk.estimate_risk(&q);
        let want = exact_surrogate(&q, &data, 4);
        assert_close(est, want, 0.02);
    }

    #[test]
    fn insert_example_augments() {
        let cfg = StormConfig { rows: 3, power: 2, saturating: true };
        let mut a = StormSketch::new(cfg, 3, 5);
        let mut b = StormSketch::new(cfg, 3, 5);
        a.insert_example(&[0.1, 0.2], 0.3);
        b.insert(&[0.1, 0.2, 0.3]);
        assert_eq!(a.grid().data(), b.grid().data());
    }

    #[test]
    fn two_increments_per_row_per_insert() {
        let cfg = StormConfig { rows: 6, power: 4, saturating: true };
        let mut sk = StormSketch::new(cfg, 4, 2);
        let mut rng = Xoshiro256::new(1);
        for _ in 0..25 {
            let z = gen_ball_point(&mut rng, 4, 0.9);
            sk.insert(&z);
        }
        for r in 0..6 {
            let row_total: u64 = sk.grid().row(r).iter().map(|&c| c as u64).sum();
            assert_eq!(row_total, 50, "row {r}");
        }
    }

    #[test]
    fn merge_equals_union() {
        let cfg = StormConfig { rows: 15, power: 3, saturating: true };
        let mut rng = Xoshiro256::new(4);
        let d1: Vec<Vec<f64>> = (0..40).map(|_| gen_ball_point(&mut rng, 3, 0.9)).collect();
        let d2: Vec<Vec<f64>> = (0..60).map(|_| gen_ball_point(&mut rng, 3, 0.9)).collect();
        let mut s1 = StormSketch::new(cfg, 3, 9);
        let mut s2 = StormSketch::new(cfg, 3, 9);
        let mut su = StormSketch::new(cfg, 3, 9);
        for z in &d1 {
            s1.insert(z);
            su.insert(z);
        }
        for z in &d2 {
            s2.insert(z);
            su.insert(z);
        }
        s1.merge_from(&s2);
        assert_eq!(s1.grid().data(), su.grid().data());
        assert_eq!(s1.count(), 100);
        // And the estimates agree exactly.
        let q = gen_ball_point(&mut rng, 3, 0.8);
        assert_close(s1.estimate_risk(&q), su.estimate_risk(&q), 1e-12);
    }

    #[test]
    #[should_panic]
    fn merge_different_seeds_panics() {
        let cfg = StormConfig::default();
        let mut a = StormSketch::new(cfg, 3, 1);
        let b = StormSketch::new(cfg, 3, 2);
        a.merge_from(&b);
    }

    #[test]
    fn risk_scaled_handles_large_theta() {
        let cfg = StormConfig { rows: 50, power: 4, saturating: true };
        let mut sk = StormSketch::new(cfg, 3, 8);
        let mut rng = Xoshiro256::new(6);
        for _ in 0..100 {
            let z = gen_ball_point(&mut rng, 3, 0.9);
            sk.insert(&z);
        }
        // Norm ~ 3.7 > 1: must not panic, must be finite.
        let big = vec![2.0, 2.0, -2.0];
        let r = sk.estimate_risk_scaled(&big);
        assert!(r.is_finite() && r >= 0.0);
    }

    #[test]
    fn classifier_sketch_estimates_margin_loss() {
        let mut rng = Xoshiro256::new(12);
        let dim = 3;
        let p = 2u32;
        let cfg = StormConfig { rows: 3000, power: p, saturating: true };
        let mut sk = StormClassifierSketch::new(cfg, dim, 31);
        let data: Vec<(Vec<f64>, f64)> = (0..200)
            .map(|i| {
                (
                    gen_ball_point(&mut rng, dim, 0.7),
                    if i % 2 == 0 { 1.0 } else { -1.0 },
                )
            })
            .collect();
        for (x, y) in &data {
            sk.insert_labelled(x, *y);
        }
        let theta = gen_ball_point(&mut rng, dim, 0.8);
        let est = sk.estimate_risk(&theta);
        let want: f64 = data
            .iter()
            .map(|(x, y)| crate::loss::margin::margin_loss(dot(&theta, x) * y, p))
            .sum::<f64>()
            / data.len() as f64;
        assert_close(est, want, 0.15 * want.max(0.5));
    }

    #[test]
    fn classifier_rejects_bad_labels() {
        let cfg = StormConfig::default();
        let mut sk = StormClassifierSketch::new(cfg, 2, 0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sk.insert_labelled(&[0.1, 0.1], 0.5);
        }));
        assert!(result.is_err());
    }
}
