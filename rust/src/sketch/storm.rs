//! The STORM sketch — the paper's central data structure.
//!
//! `R` rows of `B = 2^p` integer counters. Each row `r` owns an
//! independent PRP hash (asymmetric inner-product LSH over the augmented
//! example space `R^{d+1}` — see [`crate::lsh::prp`]).
//!
//! **Insert** (`z = [x, y]`): increment both `l_r(z)` and `l_r(-z)` in
//! every row — two counter updates per row (Algorithm 1 / Figure 1).
//!
//! **Query** (`theta~ = [theta, -1]`, rescaled into the unit ball): read
//! the count at `[r, l_r(theta~)]`, average over rows, divide by `n`. The
//! expectation is `2 * (1/n) sum_i g(theta~, z_i)` — the paper's surrogate
//! empirical risk up to the constant 2 (kept in [`StormSketch::SCALE`]).
//!
//! **Classification mode**: insert `[x * (-y)]` once per row (labels in
//! {-1, +1}); the expected normalized count is the margin loss of
//! Theorem 3 up to the `2^p` constant.

use super::counters::{CounterCell, CounterGrid, CounterStore};
use crate::config::{HashFamily, StormConfig, Task};
use crate::lsh::bank::HashBank;
use crate::lsh::prp::PairedRandomProjection;
use crate::lsh::query::{CandidateSet, QueryEngine};
use crate::util::mathx::norm2;

/// Per-row seed stream for the regression PRP rows (and every structured
/// family riding the same stream): row `r` of a sketch seeded `s` draws
/// from `s * GOLDEN + r`.
pub(crate) const REGRESSION_ROW_SEED_MULT: u64 = 0x9E3779B97F4A7C15;

/// Per-row seed stream multiplier for the classifier's single-arm rows.
const CLASSIFIER_ROW_SEED_MULT: u64 = 0x51afd6ed558ccd65;

/// The per-row seeds a sketch's hash rows draw from.
pub(crate) fn row_seeds(seed: u64, mult: u64, rows: usize) -> Vec<u64> {
    (0..rows as u64).map(|r| seed.wrapping_mul(mult).wrapping_add(r)).collect()
}

/// Build the family-dispatched bank for a sketch. Dense banks are
/// derived from the per-row hashes elsewhere (so the scalar oracle and
/// AOT paths keep their exact planes); this constructor serves the
/// structured families, which exist *only* in bank form.
pub(crate) fn structured_bank(family: HashFamily, dim: usize, p: u32, seeds: &[u64]) -> HashBank {
    match family {
        HashFamily::Dense => unreachable!("dense banks are built from per-row hashes"),
        HashFamily::Sparse { density_permille } => {
            HashBank::sparse_from_seeds(dim, p, seeds, density_permille)
        }
        HashFamily::Hadamard => HashBank::hadamard_from_seeds(dim, p, seeds),
    }
}

/// Scale relating raw normalized counts to the paper's surrogate loss `g`:
/// `E[query] = SCALE * mean_i g(theta~, z_i)`.
pub const SCALE: f64 = 2.0;

/// The STORM sketch for regression surrogate-loss estimation.
pub struct StormSketch {
    cfg: StormConfig,
    grid: CounterGrid,
    /// Per-row scalar hashes. Dense family only — structured families
    /// exist purely in bank form, so this is empty for them.
    hashes: Vec<PairedRandomProjection>,
    /// Fused projection bank (batch hot path; for dense, the exact same
    /// hyperplanes as `hashes`).
    bank: HashBank,
    count: u64,
    dim: usize,
    seed: u64,
    /// Per-example MIPS tails scratch for batch inserts (reused across
    /// batches — zero steady-state allocation).
    batch_tails: Vec<f64>,
}

impl StormSketch {
    /// `dim` is the *augmented* dimension `d + 1` ( features + label ).
    pub fn new(mut cfg: StormConfig, dim: usize, seed: u64) -> Self {
        assert!(dim >= 1);
        // The concrete type IS the task: normalize so deltas and wire
        // frames from this sketch always carry the regression tag.
        cfg.task = Task::Regression;
        let hashes: Vec<PairedRandomProjection> = match cfg.hash_family {
            HashFamily::Dense => (0..cfg.rows)
                .map(|r| {
                    PairedRandomProjection::new(
                        dim,
                        cfg.power,
                        seed.wrapping_mul(REGRESSION_ROW_SEED_MULT).wrapping_add(r as u64),
                    )
                })
                .collect(),
            _ => Vec::new(),
        };
        let bank = if cfg.hash_family == HashFamily::Dense {
            HashBank::from_rows(&hashes)
        } else {
            let seeds = row_seeds(seed, REGRESSION_ROW_SEED_MULT, cfg.rows);
            structured_bank(cfg.hash_family, dim, cfg.power, &seeds)
        };
        StormSketch {
            grid: CounterGrid::with_width(
                cfg.rows,
                cfg.buckets(),
                cfg.saturating,
                cfg.counter_width,
            ),
            hashes,
            bank,
            count: 0,
            dim,
            cfg,
            seed,
            batch_tails: Vec::new(),
        }
    }

    /// Insert a `(x, y)` example (regression mode): augments to `[x, y]`.
    pub fn insert_example(&mut self, x: &[f64], y: f64) {
        let mut z = Vec::with_capacity(x.len() + 1);
        z.extend_from_slice(x);
        z.push(y);
        self.insert(&z);
    }

    /// Estimated surrogate empirical risk `mean_i g(theta~, z_i)` at a
    /// query `theta~` already inside the unit ball.
    pub fn estimate_risk(&self, theta_tilde: &[f64]) -> f64 {
        self.query(theta_tilde) / SCALE
    }

    /// Query with automatic rescaling: `[theta, -1]` generally has norm
    /// above 1; the asymmetric hash needs it inside the unit ball. Scaling
    /// the query by a positive constant does not move the surrogate
    /// minimizer (the loss is monotone in |<q, z>| and all candidates are
    /// scaled alike within one optimization step).
    pub fn estimate_risk_scaled(&self, theta_tilde: &[f64]) -> f64 {
        let n = norm2(theta_tilde);
        let radius = crate::data::scale::query_radius();
        if n <= radius {
            return self.estimate_risk(theta_tilde);
        }
        let scaled: Vec<f64> = theta_tilde.iter().map(|v| v * radius / n).collect();
        self.estimate_risk(&scaled)
    }

    pub fn config(&self) -> StormConfig {
        self.cfg
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn grid(&self) -> &CounterGrid {
        &self.grid
    }

    /// Per-row hash functions (AOT compile path reads the hyperplanes;
    /// the equivalence proptests use them as the scalar oracle). Empty
    /// for structured hash families, which exist only in bank form.
    pub fn hashes(&self) -> &[PairedRandomProjection] {
        &self.hashes
    }

    /// The fused projection bank (for dense, the same hyperplanes as
    /// [`Self::hashes`], concatenated into one contiguous matrix).
    pub fn bank(&self) -> &HashBank {
        &self.bank
    }

    /// Fused batch insert: hash every example against the contiguous
    /// projection bank with row-block tiling (a block of planes stays
    /// cache-resident while the whole batch streams past) and both PRP
    /// arms served by one shared projection per plane. Produces a counter
    /// grid bit-identical to sequential [`Self::insert`] calls
    /// (property-tested). Row chunks run on scoped threads when the
    /// `R x batch` work grid is large enough to amortize spawning.
    pub fn insert_batch(&mut self, batch: &[Vec<f64>]) {
        let threads = auto_insert_threads(self.cfg.rows, batch.len());
        self.insert_batch_with_threads(batch, threads);
    }

    /// [`Self::insert_batch`] with an explicit row-chunk thread count
    /// (1 = fully sequential). Any thread count yields the same grid:
    /// rows are partitioned disjointly, so there is no write contention
    /// and no ordering effect.
    pub fn insert_batch_with_threads(&mut self, batch: &[Vec<f64>], threads: usize) {
        if batch.is_empty() {
            return;
        }
        for z in batch {
            assert_eq!(z.len(), self.dim, "insert dim mismatch");
        }
        // The MIPS tail is shared by both arms and by every row: compute
        // it once per example for the whole batch, into a scratch buffer
        // reused across batches (taken out of `self` so the grid can be
        // borrowed mutably below).
        let mut tails = std::mem::take(&mut self.batch_tails);
        tails.clear();
        tails.extend(batch.iter().map(|z| HashBank::mips_tail(z)));
        let rows = self.cfg.rows;
        let buckets = self.cfg.buckets();
        let saturating = self.cfg.saturating;
        let bank = &self.bank;
        let threads = threads.clamp(1, rows);
        // One width dispatch per batch, then a monomorphic kernel over
        // the native cell type — the narrow tiers pay zero per-cell
        // branching on the hot path.
        match self.grid.store_mut() {
            CounterStore::U8(d) => {
                insert_batch_native(bank, rows, buckets, saturating, threads, batch, &tails, d)
            }
            CounterStore::U16(d) => {
                insert_batch_native(bank, rows, buckets, saturating, threads, batch, &tails, d)
            }
            CounterStore::U32(d) => {
                insert_batch_native(bank, rows, buckets, saturating, threads, batch, &tails, d)
            }
        }
        self.batch_tails = tails;
        self.count += batch.len() as u64;
    }

    /// Fused batch risk estimation: estimates for every candidate in
    /// `candidates` (each an augmented `theta~`, auto-rescaled into the
    /// unit ball exactly like [`Self::estimate_risk_scaled`]) written
    /// into `out` in order. A single scratch buffer is reused across
    /// candidates — zero per-candidate allocation, versus two `Vec`
    /// allocations per call on the scalar path. Results are bit-identical
    /// to per-candidate `estimate_risk_scaled` (property-tested).
    pub fn estimate_risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(candidates.len());
        if candidates.is_empty() {
            return;
        }
        let radius = crate::data::scale::query_radius();
        let mut scaled = vec![0.0; self.dim];
        for q in candidates {
            assert_eq!(q.len(), self.dim, "query dim mismatch");
            let n = norm2(q);
            let est = if n <= radius {
                self.fused_estimate(q)
            } else {
                for (s, v) in scaled.iter_mut().zip(q.iter()) {
                    *s = v * radius / n;
                }
                self.fused_estimate(&scaled)
            };
            out.push(est);
        }
    }

    /// Single fused risk readout for a query already inside the unit
    /// ball. [`Self::query`] itself is the fused bank pass now, so this
    /// is just the SCALE-normalized readout.
    fn fused_estimate(&self, q: &[f64]) -> f64 {
        self.query(q) / SCALE
    }

    /// Serve a whole optimizer candidate set through the rank-1
    /// incremental query engine ([`crate::lsh::query`]): one
    /// SCALE-normalized risk estimate per probe, in order, written into
    /// `out` (cleared first). `engine` must have been built from
    /// [`Self::bank`]. Buckets — and hence estimates — match
    /// [`Self::estimate_risk_batch`] on the materialized candidates
    /// exactly except at measure-zero floating-point hyperplane ties.
    pub fn estimate_risk_candidates(
        &self,
        engine: &mut QueryEngine,
        set: &CandidateSet,
        out: &mut Vec<f64>,
    ) {
        out.clear();
        if set.is_empty() {
            return;
        }
        assert_eq!(set.base.len(), self.dim, "query dim mismatch");
        if self.count == 0 {
            out.resize(set.len(), 0.0);
            return;
        }
        let rows = self.cfg.rows;
        let denom = rows as f64 * self.count as f64;
        let buckets = engine.probe_buckets(&self.bank, set);
        out.reserve(set.len());
        for probe in buckets.chunks_exact(rows) {
            let mut acc = 0.0;
            for (r, &b) in probe.iter().enumerate() {
                acc += self.grid.get(r, b) as f64;
            }
            out.push(acc / denom / SCALE);
        }
    }

    /// Bulk-add a `[R, B]` histogram delta produced by the XLA insert
    /// kernel for a batch of `batch_n` examples.
    pub fn add_batch_counts(&mut self, delta: &[u32], batch_n: u64) {
        self.grid.add_counts(delta);
        self.count += batch_n;
    }

    /// Replace-free accessor used by the serializer.
    pub(crate) fn parts(&self) -> (&CounterGrid, u64) {
        (&self.grid, self.count)
    }

    pub(crate) fn parts_mut(&mut self) -> (&mut CounterGrid, &mut u64) {
        (&mut self.grid, &mut self.count)
    }

    /// Exponential-decay step for non-stationary streams: scale every
    /// counter AND the example count to `keep_permille / 1000` (integer
    /// floor — see [`CounterGrid::decay`]). Applied at round boundaries
    /// by a decaying leader, recent rounds dominate the risk surface
    /// while old concept mass fades geometrically; the count decays in
    /// lockstep so the `1/n` query normalization stays consistent.
    pub fn decay(&mut self, keep_permille: u16) {
        self.grid.decay(keep_permille);
        self.count = self.count * keep_permille as u64 / 1000;
    }
}

/// Rows per tile of the batch insert: `16 rows x p planes x (d+2)` f64
/// coefficients (~12 KB at p=4, d=22) stays L1/L2-resident while the
/// whole batch streams past, instead of re-reading all `R*p` planes per
/// example.
const INSERT_ROW_BLOCK: usize = 16;

/// Row-chunk thread count heuristic: spawning only pays when the
/// `R x batch` work grid is large; small sketches are bound on the
/// counter array, not the projections.
fn auto_insert_threads(rows: usize, batch: usize) -> usize {
    if rows >= 256 && batch >= 64 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8)
    } else {
        1
    }
}

#[inline]
fn bump<C: CounterCell>(cell: &mut C, saturating: bool) {
    *cell = cell.add_u32(1, saturating);
}

/// Sequential-or-threaded batch accumulation over the grid's native cell
/// buffer (monomorphized per [`CounterCell`] width).
#[allow(clippy::too_many_arguments)]
fn insert_batch_native<C: CounterCell + Send>(
    bank: &HashBank,
    rows: usize,
    buckets: usize,
    saturating: bool,
    threads: usize,
    batch: &[Vec<f64>],
    tails: &[f64],
    data: &mut [C],
) {
    if threads == 1 {
        accumulate_row_range(bank, 0, rows, batch, tails, buckets, saturating, data);
    } else {
        let chunk_rows = (rows + threads - 1) / threads;
        std::thread::scope(|scope| {
            for (i, chunk) in data.chunks_mut(chunk_rows * buckets).enumerate() {
                let r0 = i * chunk_rows;
                let r1 = (r0 + chunk_rows).min(rows);
                scope.spawn(move || {
                    accumulate_row_range(bank, r0, r1, batch, tails, buckets, saturating, chunk);
                });
            }
        });
    }
}

/// Accumulate the counts of `batch` for rows `[r0, r1)` into `grid_rows`
/// (the row-major counter span of exactly those rows), tiled so each
/// row block's planes stay cache-resident across the batch.
#[allow(clippy::too_many_arguments)]
fn accumulate_row_range<C: CounterCell>(
    bank: &HashBank,
    r0: usize,
    r1: usize,
    batch: &[Vec<f64>],
    tails: &[f64],
    buckets: usize,
    saturating: bool,
    grid_rows: &mut [C],
) {
    let mut rb = r0;
    while rb < r1 {
        let re = (rb + INSERT_ROW_BLOCK).min(r1);
        for (z, &tail) in batch.iter().zip(tails) {
            for r in rb..re {
                let (bp, bn) = bank.data_pair(r, z, tail);
                let row_off = (r - r0) * buckets;
                bump(&mut grid_rows[row_off + bp], saturating);
                bump(&mut grid_rows[row_off + bn], saturating);
            }
        }
        rb = re;
    }
}

/// The mergeable-summary surface (previously the `Sketch` trait; now
/// inherent — the task-generic pipeline goes through
/// [`crate::sketch::RiskSketch`] instead).
impl StormSketch {
    /// Ingest one augmented example `z = [x, y]`.
    pub fn insert(&mut self, z: &[f64]) {
        assert_eq!(z.len(), self.dim, "insert dim mismatch");
        if self.hashes.is_empty() {
            // Structured families exist only in bank form.
            let tail = HashBank::mips_tail(z);
            for r in 0..self.cfg.rows {
                let (bp, bn) = self.bank.data_pair(r, z, tail);
                self.grid.increment(r, bp);
                self.grid.increment(r, bn);
            }
            self.count += 1;
            return;
        }
        // Dense scalar path, kept as the oracle the fused bank kernels
        // are property-tested against. Hot path: augment both PRP arms
        // ONCE — the augmentation (norm + sqrt + allocation) is identical
        // for every row, so hoisting it out of the row loop is a ~3x
        // insert-throughput win (see EXPERIMENTS.md §Perf).
        let aug_pos = crate::lsh::asym::augment(z, crate::lsh::asym::Side::Data);
        let neg: Vec<f64> = z.iter().map(|v| -v).collect();
        let aug_neg = crate::lsh::asym::augment(&neg, crate::lsh::asym::Side::Data);
        for (r, h) in self.hashes.iter().enumerate() {
            let (b1, b2) = h.insert_buckets_aug(&aug_pos, &aug_neg);
            self.grid.increment(r, b1);
            self.grid.increment(r, b2);
        }
        self.count += 1;
    }

    /// Number of examples ingested (by this sketch plus everything merged
    /// into it).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Raw normalized count estimate: `(1/n) * mean_r count[r, l_r(q)]`,
    /// via one fused bank pass — no augmented-vector allocation. Matches
    /// [`Self::query_scalar`] bit-for-bit on the dense family
    /// (property-tested: the bank kernels are bit-identical to the scalar
    /// hashes and the row accumulation order is unchanged).
    pub fn query(&self, q: &[f64]) -> f64 {
        assert_eq!(q.len(), self.dim, "query dim mismatch");
        if self.count == 0 {
            return 0.0;
        }
        let tail = HashBank::mips_tail(q);
        let mut acc = 0.0;
        for r in 0..self.cfg.rows {
            acc += self.grid.get(r, self.bank.query_bucket(r, q, tail)) as f64;
        }
        acc / (self.cfg.rows as f64 * self.count as f64)
    }

    /// Scalar-oracle version of [`Self::query`]: per-row augmented
    /// hashing through [`Self::hashes`], kept verbatim from the seed
    /// path for the equivalence proptests. Dense family only (structured
    /// families have no per-row scalar hashes).
    pub fn query_scalar(&self, q: &[f64]) -> f64 {
        assert_eq!(q.len(), self.dim, "query dim mismatch");
        assert!(
            !self.hashes.is_empty(),
            "query_scalar is the dense-family oracle (family is {})",
            self.bank.family()
        );
        if self.count == 0 {
            return 0.0;
        }
        let aug_q = crate::lsh::asym::augment(q, crate::lsh::asym::Side::Query);
        let mut acc = 0.0;
        for (r, h) in self.hashes.iter().enumerate() {
            acc += self.grid.get(r, h.query_bucket_aug(&aug_q)) as f64;
        }
        acc / (self.hashes.len() as f64 * self.count as f64)
    }

    /// Merge another sketch built with identical configuration/seeds.
    /// Widths may differ (narrow device sketches fold into wide
    /// accumulators exactly); geometry, policy, task, seed and dim may
    /// not.
    pub fn merge_from(&mut self, other: &Self) {
        assert!(self.cfg.merge_compatible(&other.cfg), "merge: config mismatch");
        assert_eq!(self.seed, other.seed, "merge: seed (hash family) mismatch");
        assert_eq!(self.dim, other.dim, "merge: dim mismatch");
        self.grid.merge_from(&other.grid);
        self.count += other.count;
    }

    /// Memory footprint of the counter array in bytes (width-true).
    pub fn bytes(&self) -> usize {
        self.grid.bytes()
    }
}

/// Classification-mode STORM sketch (Theorem 3): inserts `-y * x` with a
/// *single* asymmetric hash per row (no pairing); the expected normalized
/// count at query `theta` is `(1 - acos(-y <theta, x>)/pi)^p =
//  g(theta, [x,y]) / 2^p`.
///
/// Full pipeline parity with [`StormSketch`]: fused hash-bank batch
/// insert/query kernels (width-monomorphized, row-tiled, optionally
/// row-chunk threaded), epoch-tagged snapshot/delta support (see
/// [`super::delta`]), and the task-tagged v3 wire encoding — so a fleet
/// of devices can train a classifier end-to-end over labelled streams.
pub struct StormClassifierSketch {
    cfg: StormConfig,
    grid: CounterGrid,
    hashes: Vec<crate::lsh::asym::AsymmetricInnerProductHash>,
    /// Fused projection bank over the same hyperplanes (batch hot path).
    bank: HashBank,
    count: u64,
    /// Raw feature dimension d (labels fold into the hash sign).
    dim: usize,
    seed: u64,
    /// Scratch for the sign-folded example of a single insert — reused
    /// across calls instead of a fresh `Vec` per insert (hot path).
    fold: Vec<f64>,
    /// Flat `[n, d]` scratch of sign-folded examples for batch inserts.
    batch_folds: Vec<f64>,
    /// Per-example MIPS tails for batch inserts.
    batch_tails: Vec<f64>,
}

impl StormClassifierSketch {
    /// `dim` is the raw feature dimension d (labels fold into the sign).
    pub fn new(mut cfg: StormConfig, dim: usize, seed: u64) -> Self {
        assert!(dim >= 1);
        // The concrete type IS the task: normalize so deltas and wire
        // frames from this sketch always carry the classification tag.
        cfg.task = Task::Classification;
        let hashes: Vec<crate::lsh::asym::AsymmetricInnerProductHash> = match cfg.hash_family {
            HashFamily::Dense => (0..cfg.rows)
                .map(|r| {
                    crate::lsh::asym::AsymmetricInnerProductHash::new(
                        dim,
                        cfg.power,
                        seed.wrapping_mul(CLASSIFIER_ROW_SEED_MULT).wrapping_add(r as u64),
                    )
                })
                .collect(),
            _ => Vec::new(),
        };
        let bank = if cfg.hash_family == HashFamily::Dense {
            HashBank::from_asym_rows(&hashes)
        } else {
            let seeds = row_seeds(seed, CLASSIFIER_ROW_SEED_MULT, cfg.rows);
            structured_bank(cfg.hash_family, dim, cfg.power, &seeds)
        };
        StormClassifierSketch {
            grid: CounterGrid::with_width(
                cfg.rows,
                cfg.buckets(),
                cfg.saturating,
                cfg.counter_width,
            ),
            hashes,
            bank,
            count: 0,
            dim,
            cfg,
            seed,
            fold: vec![0.0; dim],
            batch_folds: Vec::new(),
            batch_tails: Vec::new(),
        }
    }

    /// Insert a labelled example, `y` in {-1, +1}. The sign fold is
    /// written into a long-lived scratch buffer (no per-insert
    /// allocation) and the hash goes through the same fused-bank kernel
    /// path as [`Self::insert_batch`] — bit-identical counters either
    /// way (property-tested).
    pub fn insert_labelled(&mut self, x: &[f64], y: f64) {
        assert_eq!(x.len(), self.dim);
        assert!(y == 1.0 || y == -1.0, "labels must be +-1");
        for (f, xi) in self.fold.iter_mut().zip(x) {
            *f = -y * xi;
        }
        let tail = HashBank::mips_tail(&self.fold);
        let rows = self.cfg.rows;
        let buckets = self.cfg.buckets();
        let saturating = self.cfg.saturating;
        let d = self.dim;
        let bank = &self.bank;
        let folds = &self.fold;
        match self.grid.store_mut() {
            CounterStore::U8(data) => classifier_accumulate_row_range(
                bank, 0, rows, folds, d, &[tail], buckets, saturating, data,
            ),
            CounterStore::U16(data) => classifier_accumulate_row_range(
                bank, 0, rows, folds, d, &[tail], buckets, saturating, data,
            ),
            CounterStore::U32(data) => classifier_accumulate_row_range(
                bank, 0, rows, folds, d, &[tail], buckets, saturating, data,
            ),
        }
        self.count += 1;
    }

    /// Fused batch insert of labelled examples `z = [x, y]` (the stream
    /// layout the fleet ships): fold every label into its sign and hash
    /// the whole batch against the contiguous projection bank with
    /// row-block tiling. Counters are bit-identical to sequential
    /// [`Self::insert_labelled`] calls (property-tested); row chunks run
    /// on scoped threads when the work grid is large enough.
    pub fn insert_batch(&mut self, batch: &[Vec<f64>]) {
        let threads = auto_insert_threads(self.cfg.rows, batch.len());
        self.insert_batch_with_threads(batch, threads);
    }

    /// [`Self::insert_batch`] with an explicit row-chunk thread count
    /// (1 = fully sequential; any count yields the same grid).
    pub fn insert_batch_with_threads(&mut self, batch: &[Vec<f64>], threads: usize) {
        if batch.is_empty() {
            return;
        }
        let d = self.dim;
        for z in batch {
            assert_eq!(z.len(), d + 1, "insert dim mismatch (examples are [x, y])");
            let y = z[d];
            assert!(y == 1.0 || y == -1.0, "labels must be +-1");
        }
        // Sign folds + shared MIPS tails once per example, into reusable
        // scratch buffers (zero steady-state allocation).
        self.batch_folds.clear();
        self.batch_folds.reserve(batch.len() * d);
        self.batch_tails.clear();
        self.batch_tails.reserve(batch.len());
        for z in batch {
            let y = z[d];
            self.batch_folds.extend(z[..d].iter().map(|xi| -y * xi));
        }
        for i in 0..batch.len() {
            self.batch_tails
                .push(HashBank::mips_tail(&self.batch_folds[i * d..(i + 1) * d]));
        }
        let rows = self.cfg.rows;
        let buckets = self.cfg.buckets();
        let saturating = self.cfg.saturating;
        let threads = threads.clamp(1, rows);
        let bank = &self.bank;
        let folds = &self.batch_folds;
        let tails = &self.batch_tails;
        match self.grid.store_mut() {
            CounterStore::U8(data) => classifier_insert_batch_native(
                bank, rows, buckets, saturating, threads, folds, d, tails, data,
            ),
            CounterStore::U16(data) => classifier_insert_batch_native(
                bank, rows, buckets, saturating, threads, folds, d, tails, data,
            ),
            CounterStore::U32(data) => classifier_insert_batch_native(
                bank, rows, buckets, saturating, threads, folds, d, tails, data,
            ),
        }
        self.count += batch.len() as u64;
    }

    /// Estimated mean margin loss `mean_i g(theta, [x_i, y_i])` (with the
    /// `2^p` constant of Theorem 3 restored), via one fused bank pass.
    pub fn estimate_risk(&self, theta: &[f64]) -> f64 {
        assert_eq!(theta.len(), self.dim);
        self.fused_estimate(theta)
    }

    /// Single fused margin-risk readout for a `theta` already inside the
    /// unit ball: one bank pass, no augmented-vector allocation. Matches
    /// the scalar per-row hash path bit-for-bit (property-tested).
    pub(crate) fn fused_estimate(&self, theta: &[f64]) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let tail = HashBank::mips_tail(theta);
        let mut acc = 0.0;
        for r in 0..self.cfg.rows {
            acc += self.grid.get(r, self.bank.query_bucket(r, theta, tail)) as f64;
        }
        let norm_count = acc / (self.cfg.rows as f64 * self.count as f64);
        norm_count * (self.cfg.buckets() as f64)
    }

    /// Query with unit-ball rescaling (same argument as the regression
    /// variant).
    pub fn estimate_risk_scaled(&self, theta: &[f64]) -> f64 {
        let n = norm2(theta);
        let radius = crate::data::scale::query_radius();
        if n <= radius {
            return self.estimate_risk(theta);
        }
        let scaled: Vec<f64> = theta.iter().map(|v| v * radius / n).collect();
        self.estimate_risk(&scaled)
    }

    /// Serve a whole optimizer candidate set through the rank-1
    /// incremental query engine ([`crate::lsh::query`]): one margin-risk
    /// estimate per probe (with Theorem 3's `2^p` constant restored), in
    /// order, written into `out` (cleared first). Candidates are the
    /// *augmented* `theta~ = [theta, -1]` the optimizers carry; the
    /// engine reads only the leading `d` head coordinates, exactly like
    /// the dense path, so axis probes at the label slot fold to the
    /// base. `engine` must have been built from [`Self::bank`].
    pub fn estimate_risk_candidates(
        &self,
        engine: &mut QueryEngine,
        set: &CandidateSet,
        out: &mut Vec<f64>,
    ) {
        out.clear();
        if set.is_empty() {
            return;
        }
        assert_eq!(set.base.len(), self.dim + 1, "query dim mismatch");
        if self.count == 0 {
            out.resize(set.len(), 0.0);
            return;
        }
        let rows = self.cfg.rows;
        let denom = rows as f64 * self.count as f64;
        let restore = self.cfg.buckets() as f64;
        let buckets = engine.probe_buckets(&self.bank, set);
        out.reserve(set.len());
        for probe in buckets.chunks_exact(rows) {
            let mut acc = 0.0;
            for (r, &b) in probe.iter().enumerate() {
                acc += self.grid.get(r, b) as f64;
            }
            out.push(acc / denom * restore);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bytes(&self) -> usize {
        self.grid.bytes()
    }

    pub fn config(&self) -> StormConfig {
        self.cfg
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Raw feature dimension d (streamed examples are `[x, y]`, length
    /// `d + 1`).
    pub fn feature_dim(&self) -> usize {
        self.dim
    }

    pub fn grid(&self) -> &CounterGrid {
        &self.grid
    }

    /// The fused projection bank (head dimension d — the incremental
    /// query engine binds to it and ignores the label slot of augmented
    /// candidates automatically).
    pub fn bank(&self) -> &HashBank {
        &self.bank
    }

    /// Per-row hash functions (tests verify the fused bank against
    /// them). Empty for structured hash families, which exist only in
    /// bank form.
    pub fn hashes(&self) -> &[crate::lsh::asym::AsymmetricInnerProductHash] {
        &self.hashes
    }

    pub fn merge_from(&mut self, other: &Self) {
        assert!(self.cfg.merge_compatible(&other.cfg), "merge: config mismatch");
        assert_eq!(self.seed, other.seed, "merge: seed (hash family) mismatch");
        assert_eq!(self.dim, other.dim, "merge: dim mismatch");
        self.grid.merge_from(&other.grid);
        self.count += other.count;
    }

    /// Grid + count accessors for the delta/serialize plumbing.
    pub(crate) fn parts_mut(&mut self) -> (&mut CounterGrid, &mut u64) {
        (&mut self.grid, &mut self.count)
    }

    /// Exponential-decay step — the classifier twin of
    /// [`StormSketch::decay`]: counters and the example count both scale
    /// to `keep_permille / 1000` (integer floor) so the margin-loss
    /// normalization tracks the decayed mass.
    pub fn decay(&mut self, keep_permille: u16) {
        self.grid.decay(keep_permille);
        self.count = self.count * keep_permille as u64 / 1000;
    }
}

/// Sequential-or-threaded single-arm batch accumulation over the grid's
/// native cell buffer (the classifier sibling of
/// [`insert_batch_native`]; one increment per row per example).
#[allow(clippy::too_many_arguments)]
fn classifier_insert_batch_native<C: CounterCell + Send>(
    bank: &HashBank,
    rows: usize,
    buckets: usize,
    saturating: bool,
    threads: usize,
    folds: &[f64],
    d: usize,
    tails: &[f64],
    data: &mut [C],
) {
    if threads == 1 {
        classifier_accumulate_row_range(bank, 0, rows, folds, d, tails, buckets, saturating, data);
    } else {
        let chunk_rows = (rows + threads - 1) / threads;
        std::thread::scope(|scope| {
            for (i, chunk) in data.chunks_mut(chunk_rows * buckets).enumerate() {
                let r0 = i * chunk_rows;
                let r1 = (r0 + chunk_rows).min(rows);
                scope.spawn(move || {
                    classifier_accumulate_row_range(
                        bank, r0, r1, folds, d, tails, buckets, saturating, chunk,
                    );
                });
            }
        });
    }
}

/// Accumulate the single-arm counts of a sign-folded batch for rows
/// `[r0, r1)` into `grid_rows`, tiled like the regression kernel so each
/// row block's planes stay cache-resident across the batch. `folds` is
/// the flat `[n, d]` buffer of `-y * x` vectors.
#[allow(clippy::too_many_arguments)]
fn classifier_accumulate_row_range<C: CounterCell>(
    bank: &HashBank,
    r0: usize,
    r1: usize,
    folds: &[f64],
    d: usize,
    tails: &[f64],
    buckets: usize,
    saturating: bool,
    grid_rows: &mut [C],
) {
    let mut rb = r0;
    while rb < r1 {
        let re = (rb + INSERT_ROW_BLOCK).min(r1);
        for (i, &tail) in tails.iter().enumerate() {
            let v = &folds[i * d..(i + 1) * d];
            for r in rb..re {
                let b = bank.data_bucket(r, v, tail);
                bump(&mut grid_rows[(r - r0) * buckets + b], saturating);
            }
        }
        rb = re;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::prp_loss::prp_surrogate;
    use crate::testing::{assert_close, gen_ball_point};
    use crate::util::mathx::dot;
    use crate::util::rng::Xoshiro256;

    fn exact_surrogate(theta_tilde: &[f64], data: &[Vec<f64>], p: u32) -> f64 {
        data.iter()
            .map(|z| prp_surrogate(dot(theta_tilde, z), p))
            .sum::<f64>()
            / data.len() as f64
    }

    #[test]
    fn estimates_surrogate_risk_unbiasedly() {
        let mut rng = Xoshiro256::new(3);
        let dim = 5;
        let data: Vec<Vec<f64>> = (0..300)
            .map(|_| gen_ball_point(&mut rng, dim, 0.9))
            .collect();
        let q = gen_ball_point(&mut rng, dim, 0.8);
        let cfg = StormConfig { rows: 2000, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, dim, 17);
        for z in &data {
            sk.insert(z);
        }
        let est = sk.estimate_risk(&q);
        let want = exact_surrogate(&q, &data, 4);
        assert_close(est, want, 0.02);
    }

    #[test]
    fn insert_example_augments() {
        let cfg = StormConfig { rows: 3, power: 2, saturating: true, ..Default::default() };
        let mut a = StormSketch::new(cfg, 3, 5);
        let mut b = StormSketch::new(cfg, 3, 5);
        a.insert_example(&[0.1, 0.2], 0.3);
        b.insert(&[0.1, 0.2, 0.3]);
        assert_eq!(a.grid().counts_u32(), b.grid().counts_u32());
    }

    #[test]
    fn insert_batch_matches_sequential_inserts_bitwise() {
        let cfg = StormConfig { rows: 37, power: 4, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(21);
        let data: Vec<Vec<f64>> = (0..77).map(|_| gen_ball_point(&mut rng, 5, 0.95)).collect();
        let mut scalar = StormSketch::new(cfg, 5, 13);
        for z in &data {
            scalar.insert(z);
        }
        let mut fused = StormSketch::new(cfg, 5, 13);
        fused.insert_batch(&data);
        assert_eq!(scalar.grid().counts_u32(), fused.grid().counts_u32());
        assert_eq!(scalar.count(), fused.count());
    }

    #[test]
    fn insert_batch_matches_scalar_at_every_width() {
        // The width-dispatched batch kernel must reproduce the scalar
        // path exactly at u8 and u16 too (77 examples -> max cell 154,
        // below even the u8 clip, so the counters are width-invariant).
        use crate::config::CounterWidth;
        for width in [CounterWidth::U8, CounterWidth::U16] {
            let cfg = StormConfig {
                rows: 37,
                power: 4,
                saturating: true,
                counter_width: width,
                ..Default::default()
            };
            let mut rng = Xoshiro256::new(21);
            let data: Vec<Vec<f64>> = (0..77).map(|_| gen_ball_point(&mut rng, 5, 0.95)).collect();
            let mut scalar = StormSketch::new(cfg, 5, 13);
            for z in &data {
                scalar.insert(z);
            }
            let mut fused = StormSketch::new(cfg, 5, 13);
            fused.insert_batch(&data);
            assert_eq!(scalar.grid().counts_u32(), fused.grid().counts_u32(), "{width:?}");
            assert_eq!(fused.grid().width(), width);
            assert_eq!(fused.bytes(), 37 * 16 * width.bytes(), "width-true memory");
            // And the same counters as the u32 build (no saturation).
            let mut wide = StormSketch::new(
                StormConfig { counter_width: CounterWidth::U32, ..cfg },
                5,
                13,
            );
            wide.insert_batch(&data);
            assert_eq!(wide.grid().counts_u32(), fused.grid().counts_u32(), "{width:?}");
        }
    }

    #[test]
    fn insert_batch_threaded_matches_sequential() {
        let cfg = StormConfig { rows: 50, power: 3, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(22);
        let data: Vec<Vec<f64>> = (0..64).map(|_| gen_ball_point(&mut rng, 4, 0.9)).collect();
        let mut seq = StormSketch::new(cfg, 4, 3);
        seq.insert_batch_with_threads(&data, 1);
        let mut par = StormSketch::new(cfg, 4, 3);
        par.insert_batch_with_threads(&data, 3);
        assert_eq!(seq.grid().counts_u32(), par.grid().counts_u32());
        assert_eq!(seq.count(), par.count());
    }

    #[test]
    fn insert_batch_empty_is_noop() {
        let cfg = StormConfig::default();
        let mut sk = StormSketch::new(cfg, 3, 1);
        sk.insert_batch(&[]);
        assert_eq!(sk.count(), 0);
        assert_eq!(sk.grid().total(), 0);
    }

    #[test]
    fn estimate_risk_batch_matches_scalar_bitwise() {
        let cfg = StormConfig { rows: 40, power: 4, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(23);
        let mut sk = StormSketch::new(cfg, 4, 9);
        for _ in 0..200 {
            sk.insert(&gen_ball_point(&mut rng, 4, 0.9));
        }
        // Mix of in-ball candidates and far-outside ones (rescale path).
        let mut cands: Vec<Vec<f64>> = (0..10).map(|_| gen_ball_point(&mut rng, 4, 0.8)).collect();
        for _ in 0..10 {
            let mut q = gen_ball_point(&mut rng, 4, 1.0);
            for v in &mut q {
                *v *= 6.0;
            }
            cands.push(q);
        }
        let mut out = Vec::new();
        sk.estimate_risk_batch(&cands, &mut out);
        assert_eq!(out.len(), cands.len());
        for (q, got) in cands.iter().zip(&out) {
            assert_eq!(*got, sk.estimate_risk_scaled(q), "q={q:?}");
        }
    }

    #[test]
    fn estimate_risk_batch_empty_sketch_is_zero() {
        let cfg = StormConfig::default();
        let sk = StormSketch::new(cfg, 3, 2);
        let mut out = Vec::new();
        sk.estimate_risk_batch(&[vec![0.2, 0.1, -1.0]], &mut out);
        assert_eq!(out, vec![0.0]);
    }

    #[test]
    fn two_increments_per_row_per_insert() {
        let cfg = StormConfig { rows: 6, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, 4, 2);
        let mut rng = Xoshiro256::new(1);
        for _ in 0..25 {
            let z = gen_ball_point(&mut rng, 4, 0.9);
            sk.insert(&z);
        }
        for r in 0..6 {
            let row_total: u64 = sk.grid().row(r).iter().map(|&c| c as u64).sum();
            assert_eq!(row_total, 50, "row {r}");
        }
    }

    #[test]
    fn merge_equals_union() {
        let cfg = StormConfig { rows: 15, power: 3, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(4);
        let d1: Vec<Vec<f64>> = (0..40).map(|_| gen_ball_point(&mut rng, 3, 0.9)).collect();
        let d2: Vec<Vec<f64>> = (0..60).map(|_| gen_ball_point(&mut rng, 3, 0.9)).collect();
        let mut s1 = StormSketch::new(cfg, 3, 9);
        let mut s2 = StormSketch::new(cfg, 3, 9);
        let mut su = StormSketch::new(cfg, 3, 9);
        for z in &d1 {
            s1.insert(z);
            su.insert(z);
        }
        for z in &d2 {
            s2.insert(z);
            su.insert(z);
        }
        s1.merge_from(&s2);
        assert_eq!(s1.grid().counts_u32(), su.grid().counts_u32());
        assert_eq!(s1.count(), 100);
        // And the estimates agree exactly.
        let q = gen_ball_point(&mut rng, 3, 0.8);
        assert_close(s1.estimate_risk(&q), su.estimate_risk(&q), 1e-12);
    }

    #[test]
    #[should_panic]
    fn merge_different_seeds_panics() {
        let cfg = StormConfig::default();
        let mut a = StormSketch::new(cfg, 3, 1);
        let b = StormSketch::new(cfg, 3, 2);
        a.merge_from(&b);
    }

    #[test]
    fn risk_scaled_handles_large_theta() {
        let cfg = StormConfig { rows: 50, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, 3, 8);
        let mut rng = Xoshiro256::new(6);
        for _ in 0..100 {
            let z = gen_ball_point(&mut rng, 3, 0.9);
            sk.insert(&z);
        }
        // Norm ~ 3.7 > 1: must not panic, must be finite.
        let big = vec![2.0, 2.0, -2.0];
        let r = sk.estimate_risk_scaled(&big);
        assert!(r.is_finite() && r >= 0.0);
    }

    #[test]
    fn classifier_sketch_estimates_margin_loss() {
        let mut rng = Xoshiro256::new(12);
        let dim = 3;
        let p = 2u32;
        let cfg = StormConfig { rows: 3000, power: p, saturating: true, ..Default::default() };
        let mut sk = StormClassifierSketch::new(cfg, dim, 31);
        let data: Vec<(Vec<f64>, f64)> = (0..200)
            .map(|i| {
                (
                    gen_ball_point(&mut rng, dim, 0.7),
                    if i % 2 == 0 { 1.0 } else { -1.0 },
                )
            })
            .collect();
        for (x, y) in &data {
            sk.insert_labelled(x, *y);
        }
        let theta = gen_ball_point(&mut rng, dim, 0.8);
        let est = sk.estimate_risk(&theta);
        let want: f64 = data
            .iter()
            .map(|(x, y)| crate::loss::margin::margin_loss(dot(&theta, x) * y, p))
            .sum::<f64>()
            / data.len() as f64;
        assert_close(est, want, 0.15 * want.max(0.5));
    }

    #[test]
    fn classifier_rejects_bad_labels() {
        let cfg = StormConfig::default();
        let mut sk = StormClassifierSketch::new(cfg, 2, 0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sk.insert_labelled(&[0.1, 0.1], 0.5);
        }));
        assert!(result.is_err());
    }

    /// Labelled ball points with exact ±1 labels.
    fn gen_labelled(rng: &mut Xoshiro256, n: usize, d: usize) -> Vec<(Vec<f64>, f64)> {
        (0..n)
            .map(|i| (gen_ball_point(rng, d, 0.9), if i % 2 == 0 { 1.0 } else { -1.0 }))
            .collect()
    }

    #[test]
    fn classifier_fused_insert_matches_scalar_hash_path_bitwise() {
        // The bank-kernel insert must reproduce the per-row augmented
        // scalar hashes exactly: rebuild the grid by hand from
        // `hashes()` and compare counter-for-counter.
        let cfg = StormConfig { rows: 23, power: 3, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(31);
        let data = gen_labelled(&mut rng, 60, 4);
        let mut sk = StormClassifierSketch::new(cfg, 4, 7);
        let mut reference = crate::sketch::counters::CounterGrid::new(23, 8, true);
        for (x, y) in &data {
            sk.insert_labelled(x, *y);
            let v: Vec<f64> = x.iter().map(|xi| -y * xi).collect();
            let aug = crate::lsh::asym::augment(&v, crate::lsh::asym::Side::Data);
            for (r, h) in sk.hashes().iter().enumerate() {
                reference.increment(r, h.hash_augmented(&aug));
            }
        }
        assert_eq!(sk.grid().counts_u32(), reference.counts_u32());
        assert_eq!(sk.count(), 60);
    }

    #[test]
    fn classifier_insert_batch_matches_sequential_inserts_bitwise() {
        let cfg = StormConfig { rows: 37, power: 4, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(33);
        let data = gen_labelled(&mut rng, 77, 5);
        let mut scalar = StormClassifierSketch::new(cfg, 5, 13);
        for (x, y) in &data {
            scalar.insert_labelled(x, *y);
        }
        // Batch path consumes [x, y] examples (the stream layout).
        let batch: Vec<Vec<f64>> = data
            .iter()
            .map(|(x, y)| {
                let mut z = x.clone();
                z.push(*y);
                z
            })
            .collect();
        let mut fused = StormClassifierSketch::new(cfg, 5, 13);
        fused.insert_batch(&batch);
        assert_eq!(scalar.grid().counts_u32(), fused.grid().counts_u32());
        assert_eq!(scalar.count(), fused.count());
        // And batch splits / thread counts don't change the grid.
        let mut split = StormClassifierSketch::new(cfg, 5, 13);
        split.insert_batch(&batch[..30]);
        split.insert_batch(&batch[30..]);
        assert_eq!(split.grid().counts_u32(), fused.grid().counts_u32());
        let mut threaded = StormClassifierSketch::new(cfg, 5, 13);
        threaded.insert_batch_with_threads(&batch, 3);
        assert_eq!(threaded.grid().counts_u32(), fused.grid().counts_u32());
    }

    #[test]
    fn classifier_insert_batch_matches_scalar_at_every_width() {
        use crate::config::CounterWidth;
        for width in [CounterWidth::U8, CounterWidth::U16] {
            let cfg = StormConfig {
                rows: 19,
                power: 3,
                saturating: true,
                counter_width: width,
                ..Default::default()
            };
            let mut rng = Xoshiro256::new(34);
            let data = gen_labelled(&mut rng, 50, 3);
            let mut scalar = StormClassifierSketch::new(cfg, 3, 5);
            for (x, y) in &data {
                scalar.insert_labelled(x, *y);
            }
            let batch: Vec<Vec<f64>> = data
                .iter()
                .map(|(x, y)| {
                    let mut z = x.clone();
                    z.push(*y);
                    z
                })
                .collect();
            let mut fused = StormClassifierSketch::new(cfg, 3, 5);
            fused.insert_batch(&batch);
            assert_eq!(scalar.grid().counts_u32(), fused.grid().counts_u32(), "{width:?}");
            assert_eq!(fused.grid().width(), width);
            assert_eq!(fused.bytes(), 19 * 8 * width.bytes(), "width-true memory");
        }
    }

    #[test]
    fn classifier_merge_equals_concatenation() {
        let cfg = StormConfig { rows: 15, power: 2, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(35);
        let d1 = gen_labelled(&mut rng, 40, 3);
        let d2 = gen_labelled(&mut rng, 60, 3);
        let mut s1 = StormClassifierSketch::new(cfg, 3, 9);
        let mut s2 = StormClassifierSketch::new(cfg, 3, 9);
        let mut su = StormClassifierSketch::new(cfg, 3, 9);
        for (x, y) in &d1 {
            s1.insert_labelled(x, *y);
            su.insert_labelled(x, *y);
        }
        for (x, y) in &d2 {
            s2.insert_labelled(x, *y);
            su.insert_labelled(x, *y);
        }
        s1.merge_from(&s2);
        assert_eq!(s1.grid().counts_u32(), su.grid().counts_u32());
        assert_eq!(s1.count(), 100);
        let theta = gen_ball_point(&mut rng, 3, 0.7);
        assert_eq!(s1.estimate_risk(&theta), su.estimate_risk(&theta));
    }

    #[test]
    #[should_panic]
    fn classifier_merge_different_seeds_panics() {
        let cfg = StormConfig::default();
        let mut a = StormClassifierSketch::new(cfg, 3, 1);
        let b = StormClassifierSketch::new(cfg, 3, 2);
        a.merge_from(&b);
    }

    #[test]
    fn query_matches_scalar_oracle_bitwise() {
        // The production query is one fused (possibly SIMD) bank pass;
        // the per-row augmented scalar path stays behind as the oracle.
        let cfg = StormConfig { rows: 40, power: 4, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(41);
        let mut sk = StormSketch::new(cfg, 4, 9);
        for _ in 0..150 {
            sk.insert(&gen_ball_point(&mut rng, 4, 0.9));
        }
        for _ in 0..20 {
            let q = gen_ball_point(&mut rng, 4, 0.9);
            assert_eq!(sk.query(&q), sk.query_scalar(&q));
        }
    }

    #[test]
    fn structured_families_run_the_full_regression_pipeline() {
        use crate::config::HashFamily;
        for family in [HashFamily::Sparse { density_permille: 300 }, HashFamily::Hadamard] {
            let cfg = StormConfig {
                rows: 25,
                power: 3,
                saturating: true,
                hash_family: family,
                ..Default::default()
            };
            let mut rng = Xoshiro256::new(43);
            let data: Vec<Vec<f64>> = (0..60).map(|_| gen_ball_point(&mut rng, 5, 0.9)).collect();
            let mut seq = StormSketch::new(cfg, 5, 7);
            for z in &data {
                seq.insert(z);
            }
            assert!(seq.hashes().is_empty(), "structured families exist only in bank form");
            let mut batched = StormSketch::new(cfg, 5, 7);
            batched.insert_batch(&data);
            assert_eq!(seq.grid().counts_u32(), batched.grid().counts_u32(), "{family}");
            for r in 0..25 {
                let row_total: u64 = seq.grid().row(r).iter().map(|&c| c as u64).sum();
                assert_eq!(row_total, 120, "two increments per row per insert");
            }
            let q = gen_ball_point(&mut rng, 5, 0.8);
            let est = seq.query(&q);
            assert!(est.is_finite() && (0.0..=2.0).contains(&est), "{family}: est={est}");
            assert_eq!(seq.query(&q), batched.query(&q));
            let mut merged = StormSketch::new(cfg, 5, 7);
            merged.merge_from(&seq);
            assert_eq!(merged.count(), 60);
        }
    }

    #[test]
    #[should_panic(expected = "config mismatch")]
    fn merge_across_hash_families_panics() {
        use crate::config::HashFamily;
        let mut a = StormSketch::new(StormConfig::default(), 3, 1);
        let b = StormSketch::new(
            StormConfig {
                hash_family: HashFamily::Sparse { density_permille: 100 },
                ..Default::default()
            },
            3,
            1,
        );
        a.merge_from(&b);
    }

    #[test]
    fn structured_classifier_insert_paths_agree() {
        use crate::config::HashFamily;
        for family in [HashFamily::Sparse { density_permille: 300 }, HashFamily::Hadamard] {
            let cfg = StormConfig {
                rows: 19,
                power: 3,
                saturating: true,
                hash_family: family,
                ..Default::default()
            };
            let mut rng = Xoshiro256::new(44);
            let data = gen_labelled(&mut rng, 50, 4);
            let mut scalar = StormClassifierSketch::new(cfg, 4, 5);
            for (x, y) in &data {
                scalar.insert_labelled(x, *y);
            }
            assert!(scalar.hashes().is_empty());
            let batch: Vec<Vec<f64>> = data
                .iter()
                .map(|(x, y)| {
                    let mut z = x.clone();
                    z.push(*y);
                    z
                })
                .collect();
            let mut fused = StormClassifierSketch::new(cfg, 4, 5);
            fused.insert_batch(&batch);
            assert_eq!(scalar.grid().counts_u32(), fused.grid().counts_u32(), "{family}");
            let theta = gen_ball_point(&mut rng, 4, 0.7);
            let est = scalar.estimate_risk(&theta);
            assert!(est.is_finite() && est >= 0.0);
            assert_eq!(est, fused.estimate_risk(&theta));
        }
    }

    #[test]
    fn classifier_task_is_normalized_by_the_constructor() {
        // Building a classifier from a default (regression-tagged) config
        // must still stamp its deltas and wire frames as classification.
        let sk = StormClassifierSketch::new(StormConfig::default(), 2, 1);
        assert_eq!(sk.config().task, crate::config::Task::Classification);
        let rk = StormSketch::new(
            StormConfig { task: crate::config::Task::Classification, ..Default::default() },
            3,
            1,
        );
        assert_eq!(rk.config().task, crate::config::Task::Regression);
    }
}
