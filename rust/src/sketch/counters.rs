//! The `R x B` integer counter array underlying every sketch.
//!
//! Counters are `u32` — the paper's "tiny array of integer counters" and
//! the natural edge-device representation (4 bytes/cell; a 100 x 16 STORM
//! sketch is 6.4 KB). Increments saturate rather than wrap so pathological
//! streams degrade gracefully instead of corrupting estimates.

/// Dense row-major counter grid.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CounterGrid {
    rows: usize,
    buckets: usize,
    data: Vec<u32>,
    saturating: bool,
}

impl CounterGrid {
    pub fn new(rows: usize, buckets: usize, saturating: bool) -> Self {
        assert!(rows > 0 && buckets > 0);
        CounterGrid {
            rows,
            buckets,
            data: vec![0; rows * buckets],
            saturating,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn buckets(&self) -> usize {
        self.buckets
    }

    #[inline]
    pub fn get(&self, row: usize, bucket: usize) -> u32 {
        debug_assert!(row < self.rows && bucket < self.buckets);
        self.data[row * self.buckets + bucket]
    }

    #[inline]
    pub fn increment(&mut self, row: usize, bucket: usize) {
        debug_assert!(row < self.rows && bucket < self.buckets);
        let cell = &mut self.data[row * self.buckets + bucket];
        *cell = if self.saturating {
            cell.saturating_add(1)
        } else {
            cell.wrapping_add(1)
        };
    }

    /// Add a raw count delta (bulk path: the XLA insert kernel returns a
    /// whole `[R, B]` histogram of a batch which is added in one pass).
    /// The saturation-policy branch is hoisted outside the loop so each
    /// arm is a straight-line elementwise pass the compiler can
    /// autovectorize (a per-element branch defeats that).
    pub fn add_counts(&mut self, delta: &[u32]) {
        assert_eq!(delta.len(), self.data.len(), "delta shape mismatch");
        if self.saturating {
            for (c, d) in self.data.iter_mut().zip(delta) {
                *c = c.saturating_add(*d);
            }
        } else {
            for (c, d) in self.data.iter_mut().zip(delta) {
                *c = c.wrapping_add(*d);
            }
        }
    }

    /// Merge another grid of identical shape (counter-wise addition —
    /// the mergeable-summary operation). Branch hoisted like
    /// [`Self::add_counts`].
    pub fn merge_from(&mut self, other: &CounterGrid) {
        assert_eq!(self.rows, other.rows, "merge: row mismatch");
        assert_eq!(self.buckets, other.buckets, "merge: bucket mismatch");
        if self.saturating {
            for (c, o) in self.data.iter_mut().zip(&other.data) {
                *c = c.saturating_add(*o);
            }
        } else {
            for (c, o) in self.data.iter_mut().zip(&other.data) {
                *c = c.wrapping_add(*o);
            }
        }
    }

    /// Row slice.
    pub fn row(&self, r: usize) -> &[u32] {
        &self.data[r * self.buckets..(r + 1) * self.buckets]
    }

    /// Raw buffer (serialization, XLA literal conversion).
    pub fn data(&self) -> &[u32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [u32] {
        &mut self.data
    }

    /// Counter memory in bytes.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<u32>()
    }

    /// Total of all counters (diagnostics / tests: equals inserts-per-row
    /// x rows for single-increment sketches, 2x for PRP pairs).
    pub fn total(&self) -> u64 {
        self.data.iter().map(|&c| c as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn increments_accumulate() {
        let mut g = CounterGrid::new(2, 4, true);
        g.increment(0, 1);
        g.increment(0, 1);
        g.increment(1, 3);
        assert_eq!(g.get(0, 1), 2);
        assert_eq!(g.get(1, 3), 1);
        assert_eq!(g.get(0, 0), 0);
        assert_eq!(g.total(), 3);
    }

    #[test]
    fn saturating_does_not_wrap() {
        let mut g = CounterGrid::new(1, 1, true);
        g.data_mut()[0] = u32::MAX;
        g.increment(0, 0);
        assert_eq!(g.get(0, 0), u32::MAX);
        g.add_counts(&[5]);
        assert_eq!(g.get(0, 0), u32::MAX);
    }

    #[test]
    fn merge_is_elementwise_sum() {
        let mut a = CounterGrid::new(2, 2, true);
        let mut b = CounterGrid::new(2, 2, true);
        a.increment(0, 0);
        b.increment(0, 0);
        b.increment(1, 1);
        a.merge_from(&b);
        assert_eq!(a.get(0, 0), 2);
        assert_eq!(a.get(1, 1), 1);
    }

    #[test]
    fn add_counts_bulk_path() {
        let mut g = CounterGrid::new(1, 3, true);
        g.add_counts(&[1, 2, 3]);
        g.add_counts(&[1, 0, 1]);
        assert_eq!(g.data(), &[2, 2, 4]);
    }

    #[test]
    fn bytes_accounting() {
        let g = CounterGrid::new(100, 16, true);
        assert_eq!(g.bytes(), 6400);
    }

    #[test]
    #[should_panic]
    fn merge_shape_mismatch_panics() {
        let mut a = CounterGrid::new(2, 2, true);
        let b = CounterGrid::new(2, 3, true);
        a.merge_from(&b);
    }
}
