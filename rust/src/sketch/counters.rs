//! The `R x B` integer counter array underlying every sketch.
//!
//! Counters are `u32` — the paper's "tiny array of integer counters" and
//! the natural edge-device representation (4 bytes/cell; a 100 x 16 STORM
//! sketch is 6.4 KB). Increments saturate rather than wrap so pathological
//! streams degrade gracefully instead of corrupting estimates.

/// A frozen copy of a grid's counters, taken at a sync barrier so the
/// next round can ship only what changed ([`CounterGrid::delta_since`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GridSnapshot {
    rows: usize,
    buckets: usize,
    data: Vec<u32>,
}

/// Dense row-major counter grid.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CounterGrid {
    rows: usize,
    buckets: usize,
    data: Vec<u32>,
    saturating: bool,
}

impl CounterGrid {
    pub fn new(rows: usize, buckets: usize, saturating: bool) -> Self {
        assert!(rows > 0 && buckets > 0);
        CounterGrid {
            rows,
            buckets,
            data: vec![0; rows * buckets],
            saturating,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn buckets(&self) -> usize {
        self.buckets
    }

    #[inline]
    pub fn get(&self, row: usize, bucket: usize) -> u32 {
        debug_assert!(row < self.rows && bucket < self.buckets);
        self.data[row * self.buckets + bucket]
    }

    #[inline]
    pub fn increment(&mut self, row: usize, bucket: usize) {
        debug_assert!(row < self.rows && bucket < self.buckets);
        let cell = &mut self.data[row * self.buckets + bucket];
        *cell = if self.saturating {
            cell.saturating_add(1)
        } else {
            cell.wrapping_add(1)
        };
    }

    /// Add a raw count delta (bulk path: the XLA insert kernel returns a
    /// whole `[R, B]` histogram of a batch which is added in one pass).
    /// The saturation-policy branch is hoisted outside the loop so each
    /// arm is a straight-line elementwise pass the compiler can
    /// autovectorize (a per-element branch defeats that).
    pub fn add_counts(&mut self, delta: &[u32]) {
        assert_eq!(delta.len(), self.data.len(), "delta shape mismatch");
        if self.saturating {
            for (c, d) in self.data.iter_mut().zip(delta) {
                *c = c.saturating_add(*d);
            }
        } else {
            for (c, d) in self.data.iter_mut().zip(delta) {
                *c = c.wrapping_add(*d);
            }
        }
    }

    /// Merge another grid of identical shape (counter-wise addition —
    /// the mergeable-summary operation). Branch hoisted like
    /// [`Self::add_counts`].
    pub fn merge_from(&mut self, other: &CounterGrid) {
        assert_eq!(self.rows, other.rows, "merge: row mismatch");
        assert_eq!(self.buckets, other.buckets, "merge: bucket mismatch");
        if self.saturating {
            for (c, o) in self.data.iter_mut().zip(&other.data) {
                *c = c.saturating_add(*o);
            }
        } else {
            for (c, o) in self.data.iter_mut().zip(&other.data) {
                *c = c.wrapping_add(*o);
            }
        }
    }

    /// Capture the current counter values for later [`Self::delta_since`].
    pub fn snapshot(&self) -> GridSnapshot {
        GridSnapshot {
            rows: self.rows,
            buckets: self.buckets,
            data: self.data.clone(),
        }
    }

    /// Counter increments accumulated since `snap` was taken, as a dense
    /// row-major `R x B` buffer. Counters only grow (inserts and merges
    /// add), so the elementwise difference is exact; if a saturating
    /// counter hit `u32::MAX` in between, the clipped increments are lost
    /// here exactly as they are lost in the grid itself (graceful
    /// degradation, not corruption).
    pub fn delta_since(&self, snap: &GridSnapshot) -> Vec<u32> {
        assert_eq!(self.rows, snap.rows, "delta_since: row mismatch");
        assert_eq!(self.buckets, snap.buckets, "delta_since: bucket mismatch");
        self.data
            .iter()
            .zip(&snap.data)
            .map(|(&cur, &old)| cur.wrapping_sub(old))
            .collect()
    }

    /// Apply a dense delta produced by [`Self::delta_since`] (or decoded
    /// from the wire — the v2 decoder materializes sparse runs into a
    /// dense buffer before applying). Identical arithmetic to
    /// [`Self::add_counts`]; the alias exists so the sync-round call
    /// sites read as what they are.
    pub fn apply_delta(&mut self, delta: &[u32]) {
        self.add_counts(delta);
    }

    /// Row slice.
    pub fn row(&self, r: usize) -> &[u32] {
        &self.data[r * self.buckets..(r + 1) * self.buckets]
    }

    /// Raw buffer (serialization, XLA literal conversion).
    pub fn data(&self) -> &[u32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [u32] {
        &mut self.data
    }

    /// Counter memory in bytes.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<u32>()
    }

    /// Total of all counters (diagnostics / tests: equals inserts-per-row
    /// x rows for single-increment sketches, 2x for PRP pairs).
    pub fn total(&self) -> u64 {
        self.data.iter().map(|&c| c as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn increments_accumulate() {
        let mut g = CounterGrid::new(2, 4, true);
        g.increment(0, 1);
        g.increment(0, 1);
        g.increment(1, 3);
        assert_eq!(g.get(0, 1), 2);
        assert_eq!(g.get(1, 3), 1);
        assert_eq!(g.get(0, 0), 0);
        assert_eq!(g.total(), 3);
    }

    #[test]
    fn saturating_does_not_wrap() {
        let mut g = CounterGrid::new(1, 1, true);
        g.data_mut()[0] = u32::MAX;
        g.increment(0, 0);
        assert_eq!(g.get(0, 0), u32::MAX);
        g.add_counts(&[5]);
        assert_eq!(g.get(0, 0), u32::MAX);
    }

    #[test]
    fn merge_is_elementwise_sum() {
        let mut a = CounterGrid::new(2, 2, true);
        let mut b = CounterGrid::new(2, 2, true);
        a.increment(0, 0);
        b.increment(0, 0);
        b.increment(1, 1);
        a.merge_from(&b);
        assert_eq!(a.get(0, 0), 2);
        assert_eq!(a.get(1, 1), 1);
    }

    #[test]
    fn add_counts_bulk_path() {
        let mut g = CounterGrid::new(1, 3, true);
        g.add_counts(&[1, 2, 3]);
        g.add_counts(&[1, 0, 1]);
        assert_eq!(g.data(), &[2, 2, 4]);
    }

    #[test]
    fn bytes_accounting() {
        let g = CounterGrid::new(100, 16, true);
        assert_eq!(g.bytes(), 6400);
    }

    #[test]
    fn delta_since_tracks_only_new_increments() {
        let mut g = CounterGrid::new(2, 3, true);
        g.increment(0, 1);
        g.increment(1, 2);
        let snap = g.snapshot();
        g.increment(0, 1);
        g.increment(0, 0);
        assert_eq!(g.delta_since(&snap), vec![1, 1, 0, 0, 0, 0]);
        // Applying the delta onto a copy of the snapshot state reproduces
        // the live grid.
        let mut replica = CounterGrid::new(2, 3, true);
        replica.increment(0, 1);
        replica.increment(1, 2);
        replica.apply_delta(&g.delta_since(&snap));
        assert_eq!(replica.data(), g.data());
    }

    #[test]
    #[should_panic]
    fn delta_since_shape_mismatch_panics() {
        let a = CounterGrid::new(2, 2, true);
        let b = CounterGrid::new(2, 3, true);
        a.delta_since(&b.snapshot());
    }

    #[test]
    #[should_panic]
    fn merge_shape_mismatch_panics() {
        let mut a = CounterGrid::new(2, 2, true);
        let b = CounterGrid::new(2, 3, true);
        a.merge_from(&b);
    }
}
