//! The `R x B` integer counter array underlying every sketch.
//!
//! Counters are width-generic: a [`CounterGrid`] stores its cells as a
//! dense `u8`, `u16` or `u32` buffer ([`CounterWidth`]), runtime-selected
//! so an MCU-class device can hold a 100 x 16 STORM sketch in 1.6 KB of
//! `u8` cells while an aggregator keeps exact `u32` accumulators (6.4 KB).
//! The public surface stays monomorphic in `u32`: reads widen, writes
//! clip at the grid's own width. Increments saturate (at the *native*
//! width) rather than wrap so pathological streams degrade gracefully
//! instead of corrupting estimates; cross-width merges widen
//! narrow-into-wide exactly.

pub use crate::config::CounterWidth;

/// One counter cell type. Everything the width-dispatched kernels need:
/// widening reads, clipping writes, and the two overflow policies at the
/// native width.
pub(crate) trait CounterCell: Copy + Default + Eq + std::fmt::Debug + 'static {
    const MAX_U32: u32;
    fn to_u32(self) -> u32;
    /// Truncating cast (mod `2^width`) — the wrapping-policy write.
    fn from_u32_lossy(v: u32) -> Self;

    /// `self + d` under the grid's overflow policy: clamp to the native
    /// maximum when saturating, wrap mod `2^width` otherwise.
    #[inline]
    fn add_u32(self, d: u32, saturating: bool) -> Self {
        if saturating {
            Self::from_u32_lossy(self.to_u32().saturating_add(d).min(Self::MAX_U32))
        } else {
            Self::from_u32_lossy(self.to_u32().wrapping_add(d))
        }
    }
}

macro_rules! impl_counter_cell {
    ($t:ty) => {
        impl CounterCell for $t {
            const MAX_U32: u32 = <$t>::MAX as u32;
            #[inline]
            fn to_u32(self) -> u32 {
                self as u32
            }
            #[inline]
            fn from_u32_lossy(v: u32) -> Self {
                v as $t
            }
        }
    };
}

impl_counter_cell!(u8);
impl_counter_cell!(u16);
impl_counter_cell!(u32);

/// The width-tagged dense buffer behind a grid (and a snapshot). One
/// enum, three vectors: call sites dispatch once and run a monomorphic
/// kernel over the native representation — no per-cell boxing, no
/// per-cell branching.
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) enum CounterStore {
    U8(Vec<u8>),
    U16(Vec<u16>),
    U32(Vec<u32>),
}

/// Dispatch a generic expression over the store's native cell type.
/// `$d` binds the `Vec<_>` (by value/ref/mut depending on the matched
/// binding mode at the call site).
macro_rules! with_store {
    ($store:expr, $d:ident => $body:expr) => {
        match $store {
            CounterStore::U8($d) => $body,
            CounterStore::U16($d) => $body,
            CounterStore::U32($d) => $body,
        }
    };
}

impl CounterStore {
    fn zeros(width: CounterWidth, len: usize) -> CounterStore {
        match width {
            CounterWidth::U8 => CounterStore::U8(vec![0; len]),
            CounterWidth::U16 => CounterStore::U16(vec![0; len]),
            CounterWidth::U32 => CounterStore::U32(vec![0; len]),
        }
    }

    fn width(&self) -> CounterWidth {
        match self {
            CounterStore::U8(_) => CounterWidth::U8,
            CounterStore::U16(_) => CounterWidth::U16,
            CounterStore::U32(_) => CounterWidth::U32,
        }
    }

    fn len(&self) -> usize {
        with_store!(self, d => d.len())
    }

    #[inline]
    fn get(&self, i: usize) -> u32 {
        with_store!(self, d => d[i].to_u32())
    }

    /// Widened copy of the whole buffer.
    fn to_u32_vec(&self) -> Vec<u32> {
        with_store!(self, d => d.iter().map(|c| c.to_u32()).collect())
    }

    fn total(&self) -> u64 {
        with_store!(self, d => d.iter().map(|c| c.to_u32() as u64).sum())
    }

    /// Rebuild a store from little-endian arena bytes at `width`.
    fn from_bytes(width: CounterWidth, src: &[u8]) -> CounterStore {
        assert_eq!(src.len() % width.bytes(), 0, "from_bytes: ragged buffer");
        match width {
            CounterWidth::U8 => CounterStore::U8(src.to_vec()),
            CounterWidth::U16 => CounterStore::U16(
                src.chunks_exact(2).map(|b| u16::from_le_bytes([b[0], b[1]])).collect(),
            ),
            CounterWidth::U32 => CounterStore::U32(
                src.chunks_exact(4)
                    .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect(),
            ),
        }
    }

    /// Overwrite cells in place from little-endian arena bytes (reuses
    /// the existing allocation; byte length must equal `len * width`).
    fn load_bytes(&mut self, src: &[u8]) {
        match self {
            CounterStore::U8(d) => d.copy_from_slice(src),
            CounterStore::U16(d) => {
                for (c, b) in d.iter_mut().zip(src.chunks_exact(2)) {
                    *c = u16::from_le_bytes([b[0], b[1]]);
                }
            }
            CounterStore::U32(d) => {
                for (c, b) in d.iter_mut().zip(src.chunks_exact(4)) {
                    *c = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                }
            }
        }
    }

    /// Serialize cells to little-endian arena bytes.
    fn store_bytes(&self, dst: &mut [u8]) {
        match self {
            CounterStore::U8(d) => dst.copy_from_slice(d),
            CounterStore::U16(d) => {
                for (c, b) in d.iter().zip(dst.chunks_exact_mut(2)) {
                    b.copy_from_slice(&c.to_le_bytes());
                }
            }
            CounterStore::U32(d) => {
                for (c, b) in d.iter().zip(dst.chunks_exact_mut(4)) {
                    b.copy_from_slice(&c.to_le_bytes());
                }
            }
        }
    }
}

/// `dst[i] += src[i]` under `dst`'s overflow policy, both at their own
/// native widths (src is widened per element — exact).
fn fold_into<D: CounterCell, S: CounterCell>(dst: &mut [D], src: &[S], saturating: bool) {
    if saturating {
        for (c, o) in dst.iter_mut().zip(src) {
            *c = c.add_u32(o.to_u32(), true);
        }
    } else {
        for (c, o) in dst.iter_mut().zip(src) {
            *c = c.add_u32(o.to_u32(), false);
        }
    }
}

/// `c -> floor(c * keep / 1000)` at the native width — the exponential
/// decay step. Results never exceed the input, so no overflow policy is
/// involved.
fn decay_cells<C: CounterCell>(cells: &mut [C], keep_permille: u64) {
    for c in cells.iter_mut() {
        *c = C::from_u32_lossy((c.to_u32() as u64 * keep_permille / 1000) as u32);
    }
}

/// A frozen copy of a grid's counters (at the grid's native width),
/// taken at a sync barrier so the next round can ship only what changed
/// ([`CounterGrid::delta_since`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GridSnapshot {
    rows: usize,
    buckets: usize,
    store: CounterStore,
}

impl GridSnapshot {
    /// Rebuild a snapshot from arena bytes (little-endian cells at
    /// `width`). The SoA fleet executor keeps per-device snapshots in
    /// one contiguous allocation and materializes this view per round.
    pub(crate) fn from_native(
        rows: usize,
        buckets: usize,
        width: CounterWidth,
        src: &[u8],
    ) -> Self {
        assert_eq!(src.len(), rows * buckets * width.bytes(), "from_native: size mismatch");
        GridSnapshot { rows, buckets, store: CounterStore::from_bytes(width, src) }
    }

    /// Serialize the snapshot cells back to arena bytes.
    pub(crate) fn store_native(&self, dst: &mut [u8]) {
        assert_eq!(
            dst.len(),
            self.store.len() * self.store.width().bytes(),
            "store_native: size mismatch"
        );
        self.store.store_bytes(dst);
    }
}

/// Dense row-major counter grid at a runtime-selected cell width.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CounterGrid {
    rows: usize,
    buckets: usize,
    store: CounterStore,
    saturating: bool,
}

impl CounterGrid {
    /// `u32` grid — the seed representation and the wide-accumulator tier.
    pub fn new(rows: usize, buckets: usize, saturating: bool) -> Self {
        Self::with_width(rows, buckets, saturating, CounterWidth::U32)
    }

    /// Grid with an explicit cell width.
    pub fn with_width(rows: usize, buckets: usize, saturating: bool, width: CounterWidth) -> Self {
        assert!(rows > 0 && buckets > 0);
        CounterGrid {
            rows,
            buckets,
            store: CounterStore::zeros(width, rows * buckets),
            saturating,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn buckets(&self) -> usize {
        self.buckets
    }

    /// Native cell width of this grid.
    pub fn width(&self) -> CounterWidth {
        self.store.width()
    }

    #[inline]
    pub fn get(&self, row: usize, bucket: usize) -> u32 {
        debug_assert!(row < self.rows && bucket < self.buckets);
        self.store.get(row * self.buckets + bucket)
    }

    #[inline]
    pub fn increment(&mut self, row: usize, bucket: usize) {
        debug_assert!(row < self.rows && bucket < self.buckets);
        let i = row * self.buckets + bucket;
        let saturating = self.saturating;
        with_store!(&mut self.store, d => {
            d[i] = d[i].add_u32(1, saturating);
        });
    }

    /// Add a raw count delta (bulk path: the XLA insert kernel returns a
    /// whole `[R, B]` histogram of a batch which is added in one pass).
    /// Values are clipped (saturating) or wrapped (non-saturating) at the
    /// grid's *native* width. The saturation-policy branch is hoisted
    /// outside the loop (inside [`fold_into`]) so each arm is a
    /// straight-line elementwise pass the compiler can autovectorize.
    pub fn add_counts(&mut self, delta: &[u32]) {
        assert_eq!(delta.len(), self.store.len(), "delta shape mismatch");
        let saturating = self.saturating;
        with_store!(&mut self.store, d => fold_into(d, delta, saturating));
    }

    /// Merge another grid of identical shape (counter-wise addition —
    /// the mergeable-summary operation). Widths may differ: a narrow
    /// grid folds into a wide one *exactly* (the widening merge of the
    /// fleet aggregation path); a wide grid folding into a narrow one
    /// clips at the destination width, exactly like local saturation.
    pub fn merge_from(&mut self, other: &CounterGrid) {
        assert_eq!(self.rows, other.rows, "merge: row mismatch");
        assert_eq!(self.buckets, other.buckets, "merge: bucket mismatch");
        let saturating = self.saturating;
        with_store!(&mut self.store, dst => {
            with_store!(&other.store, src => fold_into(dst, src, saturating));
        });
    }

    /// Capture the current counter values (at native width) for a later
    /// [`Self::delta_since`].
    pub fn snapshot(&self) -> GridSnapshot {
        GridSnapshot {
            rows: self.rows,
            buckets: self.buckets,
            store: self.store.clone(),
        }
    }

    /// Counter increments accumulated since `snap` was taken, as a dense
    /// row-major `R x B` `u32` buffer (widening is exact — counters only
    /// grow, so each native-width difference fits its own width). If a
    /// saturating counter hit its native maximum in between, the clipped
    /// increments are lost here exactly as they are lost in the grid
    /// itself (graceful degradation, not corruption).
    pub fn delta_since(&self, snap: &GridSnapshot) -> Vec<u32> {
        assert_eq!(self.rows, snap.rows, "delta_since: row mismatch");
        assert_eq!(self.buckets, snap.buckets, "delta_since: bucket mismatch");
        assert_eq!(self.width(), snap.store.width(), "delta_since: width mismatch");
        match (&self.store, &snap.store) {
            (CounterStore::U8(cur), CounterStore::U8(old)) => diff_u32(cur, old),
            (CounterStore::U16(cur), CounterStore::U16(old)) => diff_u32(cur, old),
            (CounterStore::U32(cur), CounterStore::U32(old)) => diff_u32(cur, old),
            _ => unreachable!("width equality asserted above"),
        }
    }

    /// Apply a dense delta produced by [`Self::delta_since`] (or decoded
    /// from the wire — the decoder materializes sparse runs into a dense
    /// buffer before applying). Identical arithmetic to
    /// [`Self::add_counts`]; the alias exists so the sync-round call
    /// sites read as what they are.
    pub fn apply_delta(&mut self, delta: &[u32]) {
        self.add_counts(delta);
    }

    /// Exponential-decay step for non-stationary streams: scale every
    /// cell to `floor(c * keep_permille / 1000)` at the native width.
    /// Applied at round boundaries by a decaying leader, this turns the
    /// cumulative grid into an exponentially-weighted one (half-life
    /// `ln 2 / ln(1000 / keep)` rounds), so old concept mass fades
    /// instead of anchoring risk estimates forever. Integer floor keeps
    /// the grid in native counters; `keep_permille = 1000` is the exact
    /// no-op spelling.
    pub fn decay(&mut self, keep_permille: u16) {
        assert!(keep_permille <= 1000, "decay keep fraction is per-mille in [0, 1000]");
        if keep_permille == 1000 {
            return;
        }
        let k = keep_permille as u64;
        with_store!(&mut self.store, d => decay_cells(d, k));
    }

    /// Row `r`'s counters, widened to `u32`.
    pub fn row(&self, r: usize) -> Vec<u32> {
        assert!(r < self.rows);
        (r * self.buckets..(r + 1) * self.buckets)
            .map(|i| self.store.get(i))
            .collect()
    }

    /// The whole buffer widened to `u32` (serialization, XLA literal
    /// conversion, cross-width comparison). Allocates; hot kernels
    /// dispatch on the native store instead (see `sketch::storm`).
    pub fn counts_u32(&self) -> Vec<u32> {
        self.store.to_u32_vec()
    }

    /// Native store access for the width-dispatched batch kernels.
    pub(crate) fn store_mut(&mut self) -> &mut CounterStore {
        &mut self.store
    }

    /// Overwrite this grid's cells from arena bytes (little-endian at
    /// the grid's native width) — the load half of the SoA executor's
    /// swap-in/swap-out of per-device state through one scratch sketch.
    pub(crate) fn load_native(&mut self, src: &[u8]) {
        assert_eq!(src.len(), self.bytes(), "load_native: size mismatch");
        self.store.load_bytes(src);
    }

    /// Write this grid's cells to arena bytes at native width.
    pub(crate) fn store_native(&self, dst: &mut [u8]) {
        assert_eq!(dst.len(), self.bytes(), "store_native: size mismatch");
        self.store.store_bytes(dst);
    }

    /// Counter memory in bytes (width-true: `cells x width.bytes()`).
    pub fn bytes(&self) -> usize {
        self.store.len() * self.width().bytes()
    }

    /// Total of all counters (diagnostics / tests: equals inserts-per-row
    /// x rows for single-increment sketches, 2x for PRP pairs).
    pub fn total(&self) -> u64 {
        self.store.total()
    }
}

/// Elementwise `cur - old` at the native width (mod `2^width`), widened
/// to `u32`. The truncating cast after the u32 subtraction IS the
/// native-width modular arithmetic: for a non-saturating narrow grid
/// whose cell wrapped (250 -> 4 on u8), the delta is 10, not the
/// 2^32-246 a plain u32 subtraction of widened values would produce.
fn diff_u32<C: CounterCell>(cur: &[C], old: &[C]) -> Vec<u32> {
    cur.iter()
        .zip(old)
        .map(|(&c, &o)| C::from_u32_lossy(c.to_u32().wrapping_sub(o.to_u32())).to_u32())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn increments_accumulate() {
        let mut g = CounterGrid::new(2, 4, true);
        g.increment(0, 1);
        g.increment(0, 1);
        g.increment(1, 3);
        assert_eq!(g.get(0, 1), 2);
        assert_eq!(g.get(1, 3), 1);
        assert_eq!(g.get(0, 0), 0);
        assert_eq!(g.total(), 3);
        assert_eq!(g.width(), CounterWidth::U32);
    }

    #[test]
    fn saturating_does_not_wrap() {
        let mut g = CounterGrid::new(1, 1, true);
        g.add_counts(&[u32::MAX]);
        g.increment(0, 0);
        assert_eq!(g.get(0, 0), u32::MAX);
        g.add_counts(&[5]);
        assert_eq!(g.get(0, 0), u32::MAX);
    }

    #[test]
    fn narrow_widths_saturate_at_their_own_max() {
        for (width, max) in [(CounterWidth::U8, 255u32), (CounterWidth::U16, 65_535)] {
            let mut g = CounterGrid::with_width(1, 2, true, width);
            g.add_counts(&[max - 1, 3]);
            g.increment(0, 0);
            assert_eq!(g.get(0, 0), max);
            g.increment(0, 0); // clipped, not wrapped
            g.add_counts(&[1_000_000, 0]);
            assert_eq!(g.get(0, 0), max, "{width:?}");
            // Neighbour untouched by the saturation.
            assert_eq!(g.get(0, 1), 3, "{width:?}");
            assert_eq!(g.bytes(), 2 * width.bytes());
        }
    }

    #[test]
    fn non_saturating_narrow_wraps_mod_width() {
        let mut g = CounterGrid::with_width(1, 1, false, CounterWidth::U8);
        g.add_counts(&[250]);
        g.add_counts(&[10]); // 260 mod 256
        assert_eq!(g.get(0, 0), 4);
    }

    #[test]
    fn merge_is_elementwise_sum() {
        let mut a = CounterGrid::new(2, 2, true);
        let mut b = CounterGrid::new(2, 2, true);
        a.increment(0, 0);
        b.increment(0, 0);
        b.increment(1, 1);
        a.merge_from(&b);
        assert_eq!(a.get(0, 0), 2);
        assert_eq!(a.get(1, 1), 1);
    }

    #[test]
    fn widening_merge_is_exact() {
        // u8 and u16 grids fold into a u32 accumulator with no clipping.
        let mut wide = CounterGrid::new(1, 3, true);
        let mut narrow8 = CounterGrid::with_width(1, 3, true, CounterWidth::U8);
        narrow8.add_counts(&[200, 0, 7]);
        let mut narrow16 = CounterGrid::with_width(1, 3, true, CounterWidth::U16);
        narrow16.add_counts(&[60_000, 2, 0]);
        wide.merge_from(&narrow8);
        wide.merge_from(&narrow16);
        assert_eq!(wide.counts_u32(), vec![60_200, 2, 7]);
        assert_eq!(wide.width(), CounterWidth::U32);
    }

    #[test]
    fn narrowing_merge_clips_like_local_saturation() {
        let mut narrow = CounterGrid::with_width(1, 2, true, CounterWidth::U8);
        let mut wide = CounterGrid::new(1, 2, true);
        wide.add_counts(&[300, 9]);
        narrow.merge_from(&wide);
        assert_eq!(narrow.counts_u32(), vec![255, 9]);
    }

    #[test]
    fn add_counts_bulk_path() {
        let mut g = CounterGrid::new(1, 3, true);
        g.add_counts(&[1, 2, 3]);
        g.add_counts(&[1, 0, 1]);
        assert_eq!(g.counts_u32(), vec![2, 2, 4]);
    }

    #[test]
    fn bytes_accounting_is_width_true() {
        assert_eq!(CounterGrid::new(100, 16, true).bytes(), 6400);
        assert_eq!(
            CounterGrid::with_width(100, 16, true, CounterWidth::U8).bytes(),
            1600
        );
        assert_eq!(
            CounterGrid::with_width(100, 16, true, CounterWidth::U16).bytes(),
            3200
        );
    }

    #[test]
    fn delta_since_tracks_only_new_increments() {
        for width in [CounterWidth::U8, CounterWidth::U16, CounterWidth::U32] {
            let mut g = CounterGrid::with_width(2, 3, true, width);
            g.increment(0, 1);
            g.increment(1, 2);
            let snap = g.snapshot();
            g.increment(0, 1);
            g.increment(0, 0);
            assert_eq!(g.delta_since(&snap), vec![1, 1, 0, 0, 0, 0], "{width:?}");
            // Applying the delta onto a copy of the snapshot state
            // reproduces the live grid.
            let mut replica = CounterGrid::with_width(2, 3, true, width);
            replica.increment(0, 1);
            replica.increment(1, 2);
            replica.apply_delta(&g.delta_since(&snap));
            assert_eq!(replica, g);
        }
    }

    #[test]
    fn non_saturating_narrow_delta_wraps_at_native_width() {
        // A wrapped u8 cell (250 + 10 -> 4) must yield the mod-256 delta
        // of 10 — not the near-u32::MAX value a widened subtraction
        // would produce (which would overflow the delta's width tag and
        // poison downstream merges).
        let mut g = CounterGrid::with_width(1, 2, false, CounterWidth::U8);
        g.add_counts(&[250, 1]);
        let snap = g.snapshot();
        g.add_counts(&[10, 2]);
        assert_eq!(g.get(0, 0), 4, "wrapped at 256");
        assert_eq!(g.delta_since(&snap), vec![10, 2]);
    }

    #[test]
    fn saturated_cell_freezes_its_delta_but_not_neighbours() {
        let mut g = CounterGrid::with_width(1, 3, true, CounterWidth::U8);
        g.add_counts(&[254, 1, 0]);
        let snap = g.snapshot();
        g.add_counts(&[10, 2, 3]); // cell 0 clips at 255
        let delta = g.delta_since(&snap);
        assert_eq!(delta, vec![1, 2, 3], "clipped increments are lost, neighbours exact");
    }

    #[test]
    fn decay_floors_at_every_width() {
        for width in [CounterWidth::U8, CounterWidth::U16, CounterWidth::U32] {
            let mut g = CounterGrid::with_width(1, 4, true, width);
            g.add_counts(&[200, 3, 1, 0]);
            g.decay(500);
            assert_eq!(g.counts_u32(), vec![100, 1, 0, 0], "{width:?}");
            // keep = 1000 is the exact no-op.
            let before = g.clone();
            g.decay(1000);
            assert_eq!(g, before, "{width:?}");
            // keep = 0 forgets everything.
            g.decay(0);
            assert_eq!(g.total(), 0, "{width:?}");
        }
    }

    #[test]
    fn repeated_decay_is_exponential() {
        let mut g = CounterGrid::new(1, 1, true);
        g.add_counts(&[1 << 20]);
        for _ in 0..4 {
            g.decay(500);
        }
        assert_eq!(g.get(0, 0), 1 << 16, "four halvings of 2^20");
    }

    #[test]
    #[should_panic]
    fn decay_rejects_keep_above_one() {
        CounterGrid::new(1, 1, true).decay(1001);
    }

    #[test]
    fn row_widens() {
        let mut g = CounterGrid::with_width(2, 2, true, CounterWidth::U8);
        g.increment(1, 0);
        assert_eq!(g.row(0), vec![0, 0]);
        assert_eq!(g.row(1), vec![1, 0]);
    }

    #[test]
    fn native_bytes_round_trip_every_width() {
        for width in [CounterWidth::U8, CounterWidth::U16, CounterWidth::U32] {
            let mut g = CounterGrid::with_width(2, 3, true, width);
            g.add_counts(&[1, 0, 200, 3, 0, 77]);
            let mut arena = vec![0u8; g.bytes()];
            g.store_native(&mut arena);
            let mut back = CounterGrid::with_width(2, 3, true, width);
            back.load_native(&arena);
            assert_eq!(back, g, "{width:?}");
            // Snapshot view over the same bytes sees the same counters.
            let snap = GridSnapshot::from_native(2, 3, width, &arena);
            assert_eq!(snap, g.snapshot(), "{width:?}");
            let mut out = vec![0u8; arena.len()];
            snap.store_native(&mut out);
            assert_eq!(out, arena, "{width:?}");
        }
    }

    #[test]
    fn native_bytes_preserve_values_above_narrow_range() {
        let mut g = CounterGrid::new(1, 2, true);
        g.add_counts(&[70_000, u32::MAX]);
        let mut arena = vec![0u8; g.bytes()];
        g.store_native(&mut arena);
        let mut back = CounterGrid::new(1, 2, true);
        back.load_native(&arena);
        assert_eq!(back.counts_u32(), vec![70_000, u32::MAX]);
    }

    #[test]
    #[should_panic]
    fn delta_since_shape_mismatch_panics() {
        let a = CounterGrid::new(2, 2, true);
        let b = CounterGrid::new(2, 3, true);
        a.delta_since(&b.snapshot());
    }

    #[test]
    #[should_panic]
    fn delta_since_width_mismatch_panics() {
        let a = CounterGrid::new(2, 2, true);
        let b = CounterGrid::with_width(2, 2, true, CounterWidth::U8);
        a.delta_since(&b.snapshot());
    }

    #[test]
    #[should_panic]
    fn merge_shape_mismatch_panics() {
        let mut a = CounterGrid::new(2, 2, true);
        let b = CounterGrid::new(2, 3, true);
        a.merge_from(&b);
    }
}
