//! Count sketches for risk estimation.
//!
//! * [`counters`] — the underlying `R x B` integer counter array:
//!   width-generic (`u8`/`u16`/`u32` cells, see
//!   [`crate::config::CounterWidth`]) with native-width saturating
//!   arithmetic and exact narrow-into-wide merging;
//! * [`race`] — the symmetric RACE sketch (Coleman & Shrivastava): KDE
//!   estimates for any LSH family with a closed-form collision
//!   probability;
//! * [`storm`] — the paper's STORM sketch: asymmetric insert/query with
//!   PRP pairing, estimating the regression surrogate loss (Thm 2) and the
//!   max-margin classification loss (Thm 3);
//! * [`delta`] — epoch-tagged counter deltas, the unit of round-based
//!   fleet synchronization (`SketchDelta`, `SketchSnapshot`);
//! * [`privacy`] — differentially-private release (Laplace count noise);
//! * [`serialize`] — the compact wire format devices ship over the
//!   simulated network (dense v1 + sparse delta v2);
//! * [`compose`] — sum/difference/product estimators over multiple
//!   sketches (Theorem 1 closure).

pub mod counters;
pub mod delta;
pub mod race;
pub mod storm;
pub mod privacy;
pub mod serialize;
pub mod compose;

/// Common behaviour of the count sketches in this crate.
///
/// All implementors are *mergeable summaries*: `merge` of two sketches
/// built with the same configuration and seeds equals the sketch of the
/// concatenated streams (exactly — counts are integers).
pub trait Sketch {
    /// Ingest one augmented example.
    fn insert(&mut self, z: &[f64]);

    /// Number of examples ingested (by this sketch plus everything merged
    /// into it).
    fn count(&self) -> u64;

    /// Estimate the sketch's target functional at a query point.
    fn query(&self, q: &[f64]) -> f64;

    /// Merge another sketch built with identical configuration/seeds.
    fn merge_from(&mut self, other: &Self);

    /// Memory footprint of the counter array in bytes.
    fn bytes(&self) -> usize;
}
