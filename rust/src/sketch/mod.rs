//! Count sketches for risk estimation.
//!
//! * [`counters`] — the underlying `R x B` integer counter array:
//!   width-generic (`u8`/`u16`/`u32` cells, see
//!   [`crate::config::CounterWidth`]) with native-width saturating
//!   arithmetic and exact narrow-into-wide merging;
//! * [`race`] — the symmetric RACE sketch (Coleman & Shrivastava): KDE
//!   estimates for any LSH family with a closed-form collision
//!   probability;
//! * [`storm`] — the paper's STORM sketches: the paired-PRP regression
//!   sketch estimating the surrogate loss (Thm 2) and the single-arm
//!   classifier sketch estimating the max-margin loss (Thm 3), both on
//!   the fused hash-bank batch kernels;
//! * [`model`] — the task-generic model layer: the [`RiskSketch`] trait
//!   (the unified insert/estimate/batch/snapshot/delta/merge surface the
//!   whole device → fleet → driver pipeline is written against) and
//!   [`model::StormModel`], the constructor dispatching on
//!   `[storm] task = "regression" | "classification"`;
//! * [`delta`] — epoch-tagged counter deltas, the unit of round-based
//!   fleet synchronization (`SketchDelta`, `SketchSnapshot`);
//! * [`privacy`] — differential privacy: delta-level epsilon-DP via
//!   two-sided geometric noise on shipped counter increments
//!   ([`privacy::noise_delta`], `[privacy] epsilon_per_round`) and the
//!   family-dispatched [`privacy::PrivateStormRelease`] for one-shot
//!   noisy sketch publication;
//! * [`serialize`] — the compact wire format devices ship over the
//!   simulated network (dense v1, sparse delta v2, width- and
//!   task-tagged v3);
//! * [`compose`] — sum/difference/product estimators over multiple
//!   sketches (Theorem 1 closure).

pub mod counters;
pub mod delta;
pub mod model;
pub mod race;
pub mod storm;
pub mod privacy;
pub mod serialize;
pub mod compose;

pub use model::RiskSketch;
