//! Differentially-private sketch release (paper §2.2, after Coleman &
//! Shrivastava 2020).
//!
//! A STORM insert touches exactly `2 R` counters (2 per row), so the L1
//! sensitivity of the counter array to one example is `2 R`. Adding
//! Laplace(`2R / epsilon`) noise to every cell therefore releases the
//! sketch with example-level epsilon-DP. Noise is added once, at release
//! time, on a *copy* — the device keeps its exact counters for further
//! streaming.

use super::storm::StormSketch;
use crate::util::rng::{Rng, Xoshiro256};

/// A privately-released view of a STORM sketch: real-valued noisy counts.
pub struct PrivateStormRelease {
    /// Noisy counts, row-major `[R, B]`.
    counts: Vec<f64>,
    rows: usize,
    buckets: usize,
    count: u64,
    /// The privacy budget this release satisfies.
    pub epsilon: f64,
    hashes_seed_dim: (u64, usize, crate::config::StormConfig),
}

impl PrivateStormRelease {
    /// Release `sketch` with example-level `epsilon`-DP.
    pub fn release(sketch: &StormSketch, epsilon: f64, noise_seed: u64) -> Self {
        assert!(epsilon > 0.0, "epsilon must be positive");
        let (grid, count) = sketch.parts();
        let sensitivity = 2.0 * grid.rows() as f64;
        let scale = sensitivity / epsilon;
        let mut rng = Xoshiro256::new(noise_seed);
        let counts: Vec<f64> = grid
            .counts_u32()
            .into_iter()
            .map(|c| c as f64 + rng.laplace(scale))
            .collect();
        PrivateStormRelease {
            counts,
            rows: grid.rows(),
            buckets: grid.buckets(),
            count,
            epsilon,
            hashes_seed_dim: (sketch.seed(), sketch.dim(), sketch.config()),
        }
    }

    /// Query the noisy release exactly like the exact sketch (requires
    /// reconstructing the hash family from the shared seed — releases are
    /// paired with the family seed, which is public randomness in the
    /// RACE/STORM privacy model).
    pub fn estimate_risk(&self, theta_tilde: &[f64]) -> f64 {
        let (seed, dim, cfg) = self.hashes_seed_dim;
        assert_eq!(theta_tilde.len(), dim);
        if self.count == 0 {
            return 0.0;
        }
        let mut acc = 0.0;
        for r in 0..self.rows {
            let h = crate::lsh::prp::PairedRandomProjection::new(
                dim,
                cfg.power,
                seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(r as u64),
            );
            let b = h.query_bucket(theta_tilde);
            acc += self.counts[r * self.buckets + b];
        }
        acc / (self.rows as f64 * self.count as f64) / super::storm::SCALE
    }

    /// Noisy counter array (for transmission / inspection).
    pub fn counts(&self) -> &[f64] {
        &self.counts
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
}

/// Gaussian projection noise for attribute-level (epsilon, delta)-DP LSH
/// (Kenthapadi et al.): returns hyperplane perturbation std for the given
/// budget and an L2 clip bound of 1 (inputs live in the unit ball).
pub fn gaussian_projection_sigma(epsilon: f64, delta: f64) -> f64 {
    assert!(epsilon > 0.0 && delta > 0.0 && delta < 1.0);
    // Analytic gaussian mechanism bound: sigma >= sqrt(2 ln(1.25/delta)) / eps.
    (2.0 * (1.25 / delta).ln()).sqrt() / epsilon
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StormConfig;
    use crate::testing::{assert_close, gen_ball_point};
    use crate::util::rng::Xoshiro256;

    fn filled_sketch(rows: usize, seed: u64) -> (StormSketch, Vec<Vec<f64>>) {
        let cfg = StormConfig { rows, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, 4, seed);
        let mut rng = Xoshiro256::new(99);
        let data: Vec<Vec<f64>> = (0..400).map(|_| gen_ball_point(&mut rng, 4, 0.9)).collect();
        for z in &data {
            sk.insert(z);
        }
        (sk, data)
    }

    #[test]
    fn release_preserves_estimates_at_moderate_epsilon() {
        let (sk, _) = filled_sketch(400, 5);
        let rel = PrivateStormRelease::release(&sk, 5.0, 1);
        let mut rng = Xoshiro256::new(7);
        let q = gen_ball_point(&mut rng, 4, 0.8);
        let exact = sk.estimate_risk(&q);
        let noisy = rel.estimate_risk(&q);
        assert_close(noisy, exact, 0.1 * exact.max(0.1));
    }

    #[test]
    fn lower_epsilon_means_more_noise() {
        let (sk, _) = filled_sketch(100, 6);
        let tight = PrivateStormRelease::release(&sk, 0.1, 2);
        let loose = PrivateStormRelease::release(&sk, 10.0, 2);
        let dev = |rel: &PrivateStormRelease| -> f64 {
            rel.counts()
                .iter()
                .zip(sk.parts().0.counts_u32())
                .map(|(n, c)| (n - c as f64).abs())
                .sum::<f64>()
                / rel.counts().len() as f64
        };
        assert!(dev(&tight) > 10.0 * dev(&loose));
    }

    #[test]
    fn release_does_not_mutate_source() {
        let (mut sk, _) = filled_sketch(50, 8);
        let before = sk.grid().counts_u32();
        let _ = PrivateStormRelease::release(&sk, 1.0, 3);
        assert_eq!(sk.grid().counts_u32(), &before[..]);
        // Device keeps streaming afterwards.
        sk.insert(&[0.1, 0.1, 0.1, 0.1]);
        assert_eq!(sk.count(), 401);
    }

    #[test]
    fn gaussian_sigma_decreases_with_epsilon() {
        let s1 = gaussian_projection_sigma(0.5, 1e-5);
        let s2 = gaussian_projection_sigma(2.0, 1e-5);
        assert!(s1 > s2);
        assert!(s2 > 0.0);
    }

    #[test]
    #[should_panic]
    fn zero_epsilon_rejected() {
        let (sk, _) = filled_sketch(10, 9);
        let _ = PrivateStormRelease::release(&sk, 0.0, 0);
    }
}
