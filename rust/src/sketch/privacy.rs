//! Differentially-private sketch release (paper §2.2, after Coleman &
//! Shrivastava 2020).
//!
//! A STORM regression insert touches exactly `2 R` counters (2 per row;
//! the margin classifier touches `R`), so the L1 sensitivity of the
//! counter array to one example is `2 R` (resp. `R`). Two mechanisms
//! live here:
//!
//! * [`PrivateStormRelease`] — the one-shot real-valued release: add
//!   Laplace(`2R / epsilon`) noise to every cell of a *copy* of the grid;
//!   the device keeps its exact counters for further streaming. Queries
//!   reconstruct the hash family from the shared (public) seed with the
//!   **same family dispatch as [`StormSketch::new`]** — dense per-row
//!   PRPs or the sparse/Hadamard structured banks — cached once at
//!   release time, not rebuilt per query.
//! * [`noise_delta`] — the round-pipeline mechanism: two-sided geometric
//!   (discrete Laplace) noise on the integer counter increments of a
//!   per-epoch [`SketchDelta`] before it is encoded, so narrow widths
//!   and the v3 wire format carry private deltas unchanged. Per-round
//!   epsilon spend is composed into a ledger by the coordinator.

use super::delta::SketchDelta;
use super::storm::{row_seeds, structured_bank, StormSketch, REGRESSION_ROW_SEED_MULT};
use crate::config::{HashFamily, Task};
use crate::lsh::bank::HashBank;
use crate::lsh::prp::PairedRandomProjection;
use crate::lsh::query::{CandidateSet, QueryEngine};
use crate::util::rng::{Rng, Xoshiro256};

/// A privately-released view of a STORM sketch: real-valued noisy counts.
pub struct PrivateStormRelease {
    /// Noisy counts, row-major `[R, B]`.
    counts: Vec<f64>,
    rows: usize,
    buckets: usize,
    count: u64,
    /// The privacy budget this release satisfies.
    pub epsilon: f64,
    dim: usize,
    /// The reconstructed hash bank — built once from the release's public
    /// family seed with the same dispatch as the exact sketch, so every
    /// query lands in the same buckets the device incremented.
    bank: HashBank,
}

impl PrivateStormRelease {
    /// Release `sketch` with example-level `epsilon`-DP.
    pub fn release(sketch: &StormSketch, epsilon: f64, noise_seed: u64) -> Self {
        assert!(epsilon > 0.0, "epsilon must be positive");
        let (grid, count) = sketch.parts();
        let sensitivity = 2.0 * grid.rows() as f64;
        let scale = sensitivity / epsilon;
        let mut rng = Xoshiro256::new(noise_seed);
        let counts: Vec<f64> = grid
            .counts_u32()
            .into_iter()
            .map(|c| c as f64 + rng.laplace(scale))
            .collect();
        let cfg = sketch.config();
        let (seed, dim) = (sketch.seed(), sketch.dim());
        // Rebuild the hash family exactly as `StormSketch::new` does:
        // dense rows become per-row PRPs fused into a bank; structured
        // families dispatch straight to their seeded bank constructors.
        let bank = match cfg.hash_family {
            HashFamily::Dense => {
                let hashes: Vec<PairedRandomProjection> = (0..cfg.rows)
                    .map(|r| {
                        PairedRandomProjection::new(
                            dim,
                            cfg.power,
                            seed.wrapping_mul(REGRESSION_ROW_SEED_MULT).wrapping_add(r as u64),
                        )
                    })
                    .collect();
                HashBank::from_rows(&hashes)
            }
            _ => {
                let seeds = row_seeds(seed, REGRESSION_ROW_SEED_MULT, cfg.rows);
                structured_bank(cfg.hash_family, dim, cfg.power, &seeds)
            }
        };
        PrivateStormRelease {
            counts,
            rows: grid.rows(),
            buckets: grid.buckets(),
            count,
            epsilon,
            dim,
            bank,
        }
    }

    /// Query the noisy release through the cached family bank — the same
    /// buckets the exact sketch reads, for every hash family (the family
    /// seed is public randomness in the RACE/STORM privacy model).
    pub fn estimate_risk(&self, theta_tilde: &[f64]) -> f64 {
        assert_eq!(theta_tilde.len(), self.dim);
        if self.count == 0 {
            return 0.0;
        }
        let tail = HashBank::mips_tail(theta_tilde);
        let mut acc = 0.0;
        for r in 0..self.rows {
            let b = self.bank.query_bucket(r, theta_tilde, tail);
            acc += self.counts[r * self.buckets + b];
        }
        acc / (self.rows as f64 * self.count as f64) / super::storm::SCALE
    }

    /// The reconstructed family bank (public randomness; the incremental
    /// query engine binds to it).
    pub fn bank(&self) -> &HashBank {
        &self.bank
    }

    /// Serve a whole optimizer candidate set against the noisy release
    /// through the rank-1 incremental engine ([`crate::lsh::query`]):
    /// the same buckets [`Self::estimate_risk`] walks per probe, read
    /// from the real-valued noisy counts. `engine` must have been built
    /// from [`Self::bank`]. Private training loops get the same
    /// `O(R * p)`-per-probe hot path as the exact sketch.
    pub fn estimate_risk_candidates(
        &self,
        engine: &mut QueryEngine,
        set: &CandidateSet,
        out: &mut Vec<f64>,
    ) {
        out.clear();
        if set.is_empty() {
            return;
        }
        assert_eq!(set.base.len(), self.dim, "query dim mismatch");
        if self.count == 0 {
            out.resize(set.len(), 0.0);
            return;
        }
        let denom = self.rows as f64 * self.count as f64;
        let buckets = engine.probe_buckets(&self.bank, set);
        out.reserve(set.len());
        for probe in buckets.chunks_exact(self.rows) {
            let mut acc = 0.0;
            for (r, &b) in probe.iter().enumerate() {
                acc += self.counts[r * self.buckets + b];
            }
            out.push(acc / denom / super::storm::SCALE);
        }
    }

    /// Noisy counter array (for transmission / inspection).
    pub fn counts(&self) -> &[f64] {
        &self.counts
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
}

/// Epsilon-DP noise for a per-epoch delta: two-sided geometric noise on
/// every counter increment, clamped to the delta's native counter width
/// so the frame still encodes at its tagged width. The noise is drawn
/// from `noise_seed` alone — deterministic, so a retransmitted or
/// re-cut frame for the same `(device, epoch)` re-ships byte-identical
/// noised counts and never spends budget twice.
///
/// Sensitivity follows the task: a regression insert touches 2 counters
/// per row, a classifier insert 1, so one example moves the increment
/// vector by `2R` (resp. `R`) in L1.
pub fn noise_delta(delta: &mut SketchDelta, epsilon: f64, noise_seed: u64) {
    assert!(epsilon > 0.0, "epsilon must be positive");
    let sensitivity = match delta.cfg.task {
        Task::Regression => 2.0 * delta.cfg.rows as f64,
        Task::Classification => delta.cfg.rows as f64,
    };
    let alpha = (-epsilon / sensitivity).exp();
    let max = delta.width.max_value() as i64;
    let mut rng = Xoshiro256::new(noise_seed);
    for c in delta.counts.iter_mut() {
        let noised = (*c as i64 + rng.two_sided_geometric(alpha)).clamp(0, max);
        *c = noised as u32;
    }
    delta.private = true;
}

/// Gaussian projection noise for attribute-level (epsilon, delta)-DP LSH
/// (Kenthapadi et al.): returns hyperplane perturbation std for the given
/// budget and an L2 clip bound of 1 (inputs live in the unit ball).
pub fn gaussian_projection_sigma(epsilon: f64, delta: f64) -> f64 {
    assert!(epsilon > 0.0 && delta > 0.0 && delta < 1.0);
    // Analytic gaussian mechanism bound: sigma >= sqrt(2 ln(1.25/delta)) / eps.
    (2.0 * (1.25 / delta).ln()).sqrt() / epsilon
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CounterWidth, StormConfig};
    use crate::testing::{assert_close, gen_ball_point};
    use crate::util::rng::Xoshiro256;

    fn filled_sketch(rows: usize, seed: u64) -> (StormSketch, Vec<Vec<f64>>) {
        let cfg = StormConfig { rows, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, 4, seed);
        let mut rng = Xoshiro256::new(99);
        let data: Vec<Vec<f64>> = (0..400).map(|_| gen_ball_point(&mut rng, 4, 0.9)).collect();
        for z in &data {
            sk.insert(z);
        }
        (sk, data)
    }

    #[test]
    fn release_preserves_estimates_at_moderate_epsilon() {
        let (sk, _) = filled_sketch(400, 5);
        let rel = PrivateStormRelease::release(&sk, 5.0, 1);
        let mut rng = Xoshiro256::new(7);
        let q = gen_ball_point(&mut rng, 4, 0.8);
        let exact = sk.estimate_risk(&q);
        let noisy = rel.estimate_risk(&q);
        assert_close(noisy, exact, 0.1 * exact.max(0.1));
    }

    #[test]
    fn huge_epsilon_release_matches_exact_for_every_family() {
        // Regression pin for the structured-family bucket bug: at near-zero
        // noise the release must reproduce the exact sketch's estimate,
        // which only happens if queries walk the same family-dispatched
        // bank the device hashed into.
        for family in [
            HashFamily::Dense,
            HashFamily::Sparse { density_permille: 200 },
            HashFamily::Hadamard,
        ] {
            let cfg = StormConfig {
                rows: 120,
                power: 4,
                saturating: true,
                hash_family: family,
                ..Default::default()
            };
            let mut sk = StormSketch::new(cfg, 4, 21);
            let mut rng = Xoshiro256::new(99);
            for _ in 0..300 {
                let z = gen_ball_point(&mut rng, 4, 0.9);
                sk.insert(&z);
            }
            let rel = PrivateStormRelease::release(&sk, 1e9, 77);
            let mut qrng = Xoshiro256::new(7);
            for _ in 0..5 {
                let q = gen_ball_point(&mut qrng, 4, 0.8);
                let exact = sk.estimate_risk(&q);
                let noisy = rel.estimate_risk(&q);
                assert!(
                    (noisy - exact).abs() <= 1e-6 + 1e-6 * exact.abs(),
                    "family {family}: noisy {noisy} vs exact {exact}"
                );
            }
        }
    }

    #[test]
    fn candidate_sets_match_scalar_release_queries() {
        // The incremental engine against the noisy release reads the
        // same buckets as the scalar query path — estimates identical
        // bit for bit on in-ball probes, for every hash family.
        use crate::lsh::query::Probe;
        for family in [
            HashFamily::Dense,
            HashFamily::Sparse { density_permille: 200 },
            HashFamily::Hadamard,
        ] {
            let cfg = StormConfig {
                rows: 60,
                power: 3,
                saturating: true,
                hash_family: family,
                ..Default::default()
            };
            let mut sk = StormSketch::new(cfg, 4, 13);
            let mut rng = Xoshiro256::new(31);
            for _ in 0..200 {
                let z = gen_ball_point(&mut rng, 4, 0.9);
                sk.insert(&z);
            }
            let rel = PrivateStormRelease::release(&sk, 2.0, 17);
            let base = gen_ball_point(&mut rng, 4, 0.5);
            let dirs = vec![gen_ball_point(&mut rng, 4, 0.2)];
            let probes = [
                Probe::Base,
                Probe::Axis { k: 1, value: 0.2 },
                Probe::Dir { dir: 0, step: 1.0 },
                Probe::Dir { dir: 0, step: -1.0 },
            ];
            let set = CandidateSet { base: &base, dirs: &dirs, probes: &probes };
            let mut engine = QueryEngine::new(rel.bank());
            let mut got = Vec::new();
            rel.estimate_risk_candidates(&mut engine, &set, &mut got);
            let mut dense = Vec::new();
            set.materialize(&mut dense);
            assert_eq!(got.len(), dense.len());
            for (q, g) in dense.iter().zip(&got) {
                let want = rel.estimate_risk(q);
                assert_eq!(g.to_bits(), want.to_bits(), "family {family}");
            }
        }
    }

    #[test]
    fn lower_epsilon_means_more_noise() {
        let (sk, _) = filled_sketch(100, 6);
        let tight = PrivateStormRelease::release(&sk, 0.1, 2);
        let loose = PrivateStormRelease::release(&sk, 10.0, 2);
        let dev = |rel: &PrivateStormRelease| -> f64 {
            rel.counts()
                .iter()
                .zip(sk.parts().0.counts_u32())
                .map(|(n, c)| (n - c as f64).abs())
                .sum::<f64>()
                / rel.counts().len() as f64
        };
        assert!(dev(&tight) > 10.0 * dev(&loose));
    }

    #[test]
    fn release_does_not_mutate_source() {
        let (mut sk, _) = filled_sketch(50, 8);
        let before = sk.grid().counts_u32();
        let _ = PrivateStormRelease::release(&sk, 1.0, 3);
        assert_eq!(sk.grid().counts_u32(), &before[..]);
        // Device keeps streaming afterwards.
        sk.insert(&[0.1, 0.1, 0.1, 0.1]);
        assert_eq!(sk.count(), 401);
    }

    fn small_delta(width: CounterWidth) -> SketchDelta {
        let cfg = StormConfig {
            rows: 2,
            power: 2,
            saturating: true,
            counter_width: width,
            ..Default::default()
        };
        let mut d = SketchDelta::empty(3, cfg, 3, 0xBEEF);
        d.width = width;
        d.count = 9;
        d.counts = vec![0, 3, 250, 1, 0, 7, 2, 0];
        d
    }

    #[test]
    fn noise_delta_is_deterministic_and_marks_private() {
        let mut a = small_delta(CounterWidth::U16);
        let mut b = small_delta(CounterWidth::U16);
        noise_delta(&mut a, 0.5, 42);
        noise_delta(&mut b, 0.5, 42);
        assert!(a.private && b.private);
        assert_eq!(a.counts, b.counts, "same seed => byte-identical noised frame");
        let mut c = small_delta(CounterWidth::U16);
        noise_delta(&mut c, 0.5, 43);
        assert_ne!(a.counts, c.counts, "different seed => different noise");
    }

    #[test]
    fn noise_delta_clamps_to_the_native_width() {
        let mut d = small_delta(CounterWidth::U8);
        // Tight budget on a tall sketch => alpha near 1 => heavy noise.
        noise_delta(&mut d, 0.01, 7);
        assert!(d.counts.iter().all(|&c| c <= u8::MAX as u32), "{:?}", d.counts);
    }

    #[test]
    fn noise_delta_huge_epsilon_is_identity_on_counts() {
        let mut d = small_delta(CounterWidth::U32);
        let before = d.counts.clone();
        noise_delta(&mut d, 1e9, 11);
        assert_eq!(d.counts, before, "alpha -> 0 => zero geometric noise");
        assert!(d.private, "the frame is still tagged private");
    }

    #[test]
    fn gaussian_sigma_decreases_with_epsilon() {
        let s1 = gaussian_projection_sigma(0.5, 1e-5);
        let s2 = gaussian_projection_sigma(2.0, 1e-5);
        assert!(s1 > s2);
        assert!(s2 > 0.0);
    }

    #[test]
    #[should_panic]
    fn zero_epsilon_rejected() {
        let (sk, _) = filled_sketch(10, 9);
        let _ = PrivateStormRelease::release(&sk, 0.0, 0);
    }
}
