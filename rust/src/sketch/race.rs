//! The symmetric RACE sketch (Repeated Arrays of Count Estimators;
//! Luo & Shrivastava / Coleman & Shrivastava).
//!
//! R rows, each indexed by an independent LSH function. Inserting `x`
//! increments one cell per row; querying `q` averages the counts at
//! `[r, l_r(q)]`, which is an unbiased estimator of
//! `sum_i k(q, x_i)` where `k` is the family's collision probability —
//! the kernel density estimate STORM generalizes.

use super::counters::{CounterGrid, CounterWidth};
use crate::lsh::srp::SignedRandomProjection;
use crate::lsh::LshFunction;

/// RACE sketch over a generic boxed LSH family (one function per row).
pub struct RaceSketch {
    grid: CounterGrid,
    hashes: Vec<Box<dyn LshFunction>>,
    count: u64,
    dim: usize,
}

impl RaceSketch {
    /// Build from per-row hash functions (must share dim and range),
    /// with `u32` counters.
    pub fn from_hashes(hashes: Vec<Box<dyn LshFunction>>, saturating: bool) -> Self {
        Self::from_hashes_with_width(hashes, saturating, CounterWidth::U32)
    }

    /// [`Self::from_hashes`] at an explicit counter width — the same
    /// narrow-tier storage knob as the STORM sketch (KDE counts clip at
    /// the native maximum; merges widen narrow-into-wide exactly).
    pub fn from_hashes_with_width(
        hashes: Vec<Box<dyn LshFunction>>,
        saturating: bool,
        width: CounterWidth,
    ) -> Self {
        assert!(!hashes.is_empty());
        let dim = hashes[0].dim();
        let range = hashes[0].range();
        for h in &hashes {
            assert_eq!(h.dim(), dim, "all rows must share input dim");
            assert_eq!(h.range(), range, "all rows must share bucket range");
        }
        RaceSketch {
            grid: CounterGrid::with_width(hashes.len(), range, saturating, width),
            hashes,
            count: 0,
            dim,
        }
    }

    /// Convenience: R rows of p-bit SRP at an explicit counter width.
    pub fn srp_with_width(rows: usize, dim: usize, p: u32, seed: u64, width: CounterWidth) -> Self {
        let hashes: Vec<Box<dyn LshFunction>> = (0..rows)
            .map(|r| {
                Box::new(SignedRandomProjection::new(
                    dim,
                    p,
                    seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(r as u64),
                )) as Box<dyn LshFunction>
            })
            .collect();
        RaceSketch::from_hashes_with_width(hashes, true, width)
    }

    /// Convenience: R rows of p-bit SRP, seeds derived from `seed`.
    pub fn srp(rows: usize, dim: usize, p: u32, seed: u64) -> Self {
        Self::srp_with_width(rows, dim, p, seed, CounterWidth::U32)
    }

    /// Convenience: R rows of p-bit *sparse Rademacher* planes (see
    /// [`crate::lsh::structured`]) — same per-row seed stream as
    /// [`Self::srp`], projection cost a few adds per nonzero.
    pub fn sparse(rows: usize, dim: usize, p: u32, seed: u64, density_permille: u16) -> Self {
        let hashes: Vec<Box<dyn LshFunction>> = (0..rows)
            .map(|r| {
                Box::new(crate::lsh::structured::SparseRademacherPlanes::new(
                    dim,
                    p,
                    seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(r as u64),
                    density_permille,
                )) as Box<dyn LshFunction>
            })
            .collect();
        RaceSketch::from_hashes(hashes, true)
    }

    /// Convenience: R rows of p-bit *fast-Hadamard* SRP (see
    /// [`crate::lsh::structured`]) — one O(d log d) transform per row.
    pub fn hadamard(rows: usize, dim: usize, p: u32, seed: u64) -> Self {
        let hashes: Vec<Box<dyn LshFunction>> = (0..rows)
            .map(|r| {
                Box::new(crate::lsh::structured::FastHadamardPlanes::new(
                    dim,
                    p,
                    seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(r as u64),
                )) as Box<dyn LshFunction>
            })
            .collect();
        RaceSketch::from_hashes(hashes, true)
    }

    pub fn rows(&self) -> usize {
        self.grid.rows()
    }

    pub fn grid(&self) -> &CounterGrid {
        &self.grid
    }

    /// Mean count at the query's buckets — the raw KDE-style estimator of
    /// `sum_i k(q, x_i)` (not normalized by n).
    pub fn query_sum(&self, q: &[f64]) -> f64 {
        assert_eq!(q.len(), self.dim);
        let mut acc = 0.0;
        for (r, h) in self.hashes.iter().enumerate() {
            acc += self.grid.get(r, h.hash(q)) as f64;
        }
        acc / self.hashes.len() as f64
    }
}

/// The mergeable-summary surface (previously the `Sketch` trait; now
/// inherent — see [`crate::sketch::RiskSketch`] for the task-generic
/// model surface the pipeline uses).
impl RaceSketch {
    /// Ingest one example.
    pub fn insert(&mut self, z: &[f64]) {
        assert_eq!(z.len(), self.dim, "insert dim mismatch");
        for (r, h) in self.hashes.iter().enumerate() {
            let b = h.hash(z);
            self.grid.increment(r, b);
        }
        self.count += 1;
    }

    /// Examples ingested (including everything merged in).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Normalized estimate: `(1/n) sum_i k(q, x_i)`.
    pub fn query(&self, q: &[f64]) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.query_sum(q) / self.count as f64
    }

    /// Merge another sketch built with identical hashes.
    pub fn merge_from(&mut self, other: &Self) {
        self.grid.merge_from(&other.grid);
        self.count += other.count;
    }

    /// Counter memory in bytes (width-true).
    pub fn bytes(&self) -> usize {
        self.grid.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::CollisionProbability;
    use crate::testing::{assert_close, gen_ball_point};
    use crate::util::rng::Xoshiro256;

    fn mean_collision(q: &[f64], data: &[Vec<f64>], p: u32) -> f64 {
        // Analytic target: mean over the dataset of the SRP collision prob.
        let probe = SignedRandomProjection::new(q.len(), p, 0);
        data.iter()
            .map(|x| probe.collision_probability(q, x))
            .sum::<f64>()
            / data.len() as f64
    }

    #[test]
    fn estimates_mean_collision_probability() {
        let mut rng = Xoshiro256::new(5);
        let dim = 4;
        let data: Vec<Vec<f64>> = (0..200).map(|_| gen_ball_point(&mut rng, dim, 1.0)).collect();
        let q = gen_ball_point(&mut rng, dim, 1.0);
        let mut sk = RaceSketch::srp(800, dim, 2, 7);
        for x in &data {
            sk.insert(x);
        }
        let est = sk.query(&q);
        let want = mean_collision(&q, &data, 2);
        assert_close(est, want, 0.05);
        assert_eq!(sk.count(), 200);
    }

    #[test]
    fn merge_equals_union_sketch() {
        let mut rng = Xoshiro256::new(9);
        let dim = 3;
        let d1: Vec<Vec<f64>> = (0..50).map(|_| gen_ball_point(&mut rng, dim, 1.0)).collect();
        let d2: Vec<Vec<f64>> = (0..70).map(|_| gen_ball_point(&mut rng, dim, 1.0)).collect();
        let mut s1 = RaceSketch::srp(20, dim, 3, 11);
        let mut s2 = RaceSketch::srp(20, dim, 3, 11); // same seed => same hashes
        let mut s_union = RaceSketch::srp(20, dim, 3, 11);
        for x in &d1 {
            s1.insert(x);
            s_union.insert(x);
        }
        for x in &d2 {
            s2.insert(x);
            s_union.insert(x);
        }
        s1.merge_from(&s2);
        assert_eq!(s1.grid().counts_u32(), s_union.grid().counts_u32());
        assert_eq!(s1.count(), s_union.count());
    }

    #[test]
    fn empty_sketch_queries_zero() {
        let sk = RaceSketch::srp(10, 3, 2, 0);
        assert_eq!(sk.query(&[0.1, 0.2, 0.3]), 0.0);
    }

    #[test]
    fn per_row_total_equals_inserts() {
        let mut sk = RaceSketch::srp(7, 2, 3, 1);
        let mut rng = Xoshiro256::new(2);
        for _ in 0..33 {
            let x = gen_ball_point(&mut rng, 2, 1.0);
            sk.insert(&x);
        }
        for r in 0..7 {
            let row_total: u64 = sk.grid().row(r).iter().map(|&c| c as u64).sum();
            assert_eq!(row_total, 33);
        }
    }

    #[test]
    fn bytes_matches_grid() {
        let sk = RaceSketch::srp(10, 3, 4, 0);
        assert_eq!(sk.bytes(), 10 * 16 * 4);
    }

    #[test]
    fn structured_race_sketches_merge_and_estimate() {
        // The generic boxed-LSH surface carries the structured families
        // too: same-seed sketches merge exactly, and the KDE estimate
        // stays a sane probability-like value.
        let mut rng = Xoshiro256::new(14);
        let dim = 8;
        let data: Vec<Vec<f64>> = (0..80).map(|_| gen_ball_point(&mut rng, dim, 1.0)).collect();
        let q = gen_ball_point(&mut rng, dim, 1.0);
        for mk in [
            (|| RaceSketch::sparse(30, 8, 3, 5, 300)) as fn() -> RaceSketch,
            || RaceSketch::hadamard(30, 8, 3, 5),
        ] {
            let mut a = mk();
            let mut b = mk();
            let mut u = mk();
            for x in &data[..40] {
                a.insert(x);
                u.insert(x);
            }
            for x in &data[40..] {
                b.insert(x);
                u.insert(x);
            }
            a.merge_from(&b);
            assert_eq!(a.grid().counts_u32(), u.grid().counts_u32());
            let est = u.query(&q);
            assert!((0.0..=1.0).contains(&est), "est={est}");
        }
    }

    #[test]
    fn narrow_width_race_matches_u32_and_quarters_memory() {
        // Same seeds, same stream: u8 and u32 RACE sketches hold the
        // same counts (33 inserts can't clip a u8 cell) at 1/4 the
        // bytes, and the narrow sketch folds into the wide one exactly.
        let mut narrow = RaceSketch::srp_with_width(7, 2, 3, 1, CounterWidth::U8);
        let mut wide = RaceSketch::srp(7, 2, 3, 1);
        let mut rng = Xoshiro256::new(2);
        for _ in 0..33 {
            let x = gen_ball_point(&mut rng, 2, 1.0);
            narrow.insert(&x);
            wide.insert(&x);
        }
        assert_eq!(narrow.grid().counts_u32(), wide.grid().counts_u32());
        assert_eq!(narrow.grid().width(), CounterWidth::U8);
        assert_eq!(narrow.bytes() * 4, wide.bytes());
        wide.merge_from(&narrow);
        assert_eq!(wide.count(), 66);
        let doubled: Vec<u32> = narrow.grid().counts_u32().iter().map(|c| c * 2).collect();
        assert_eq!(wide.grid().counts_u32(), doubled);
    }
}
