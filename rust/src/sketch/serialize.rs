//! Compact binary wire format for sketches — what edge devices actually
//! transmit over the simulated network. Layout (little-endian):
//!
//! ```text
//! magic  u32  = 0x53544F52 ("STOR")
//! version u16 = 1
//! power   u16
//! rows    u32
//! dim     u32
//! seed    u64
//! count   u64
//! counts  rows * 2^power * u32
//! crc     u32   (FNV-1a over everything above)
//! ```
//!
//! The hash-family *seed* travels with the counts so a receiver can verify
//! it merges compatible sketches; the hyperplanes themselves are
//! regenerated deterministically and never shipped.

use super::storm::StormSketch;
use crate::config::StormConfig;
use crate::sketch::Sketch;

const MAGIC: u32 = 0x53544F52;
const VERSION: u16 = 1;

/// Serialization errors.
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("buffer too short ({0} bytes)")]
    Truncated(usize),
    #[error("bad magic 0x{0:08x}")]
    BadMagic(u32),
    #[error("unsupported version {0}")]
    BadVersion(u16),
    #[error("checksum mismatch (got 0x{got:08x}, want 0x{want:08x})")]
    BadChecksum { got: u32, want: u32 },
    #[error("inconsistent header (rows={rows}, power={power})")]
    BadHeader { rows: u32, power: u16 },
}

fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

/// Encode a sketch into the wire format.
pub fn encode(sketch: &StormSketch) -> Vec<u8> {
    let (grid, count) = sketch.parts();
    let cfg = sketch.config();
    let mut out = Vec::with_capacity(32 + grid.bytes() + 4);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(cfg.power as u16).to_le_bytes());
    out.extend_from_slice(&(cfg.rows as u32).to_le_bytes());
    out.extend_from_slice(&(sketch.dim() as u32).to_le_bytes());
    out.extend_from_slice(&sketch.seed().to_le_bytes());
    out.extend_from_slice(&count.to_le_bytes());
    for &c in grid.data() {
        out.extend_from_slice(&c.to_le_bytes());
    }
    let crc = fnv1a(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Decode a wire buffer back into a sketch (rebuilding the hash family
/// from the embedded seed).
pub fn decode(bytes: &[u8]) -> Result<StormSketch, WireError> {
    const HEADER: usize = 4 + 2 + 2 + 4 + 4 + 8 + 8;
    if bytes.len() < HEADER + 4 {
        return Err(WireError::Truncated(bytes.len()));
    }
    let body = &bytes[..bytes.len() - 4];
    let crc_got = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    let crc_want = fnv1a(body);
    if crc_got != crc_want {
        return Err(WireError::BadChecksum { got: crc_got, want: crc_want });
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(WireError::BadVersion(version));
    }
    let power = u16::from_le_bytes(bytes[6..8].try_into().unwrap());
    let rows = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let dim = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    let seed = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let count = u64::from_le_bytes(bytes[24..32].try_into().unwrap());
    if power == 0 || power > 24 || rows == 0 {
        return Err(WireError::BadHeader { rows, power });
    }
    let buckets = 1usize << power;
    let expected = HEADER + rows as usize * buckets * 4 + 4;
    if bytes.len() != expected {
        return Err(WireError::Truncated(bytes.len()));
    }
    let cfg = StormConfig { rows: rows as usize, power: power as u32, saturating: true };
    let mut sketch = StormSketch::new(cfg, dim as usize, seed);
    {
        let (grid, cnt) = sketch.parts_mut();
        let data = grid.data_mut();
        for (i, cell) in data.iter_mut().enumerate() {
            let off = HEADER + i * 4;
            *cell = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        }
        *cnt = count;
    }
    Ok(sketch)
}

/// Wire size in bytes for a given configuration (network cost model).
pub fn wire_bytes(cfg: &StormConfig) -> usize {
    32 + cfg.rows * cfg.buckets() * 4 + 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gen_ball_point;
    use crate::util::rng::Xoshiro256;

    fn sample_sketch() -> StormSketch {
        let cfg = StormConfig { rows: 20, power: 4, saturating: true };
        let mut sk = StormSketch::new(cfg, 5, 77);
        let mut rng = Xoshiro256::new(3);
        for _ in 0..120 {
            let z = gen_ball_point(&mut rng, 5, 0.9);
            sk.insert(&z);
        }
        sk
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let sk = sample_sketch();
        let bytes = encode(&sk);
        assert_eq!(bytes.len(), wire_bytes(&sk.config()));
        let back = decode(&bytes).unwrap();
        assert_eq!(back.grid().data(), sk.grid().data());
        assert_eq!(back.count(), sk.count());
        assert_eq!(back.seed(), sk.seed());
        assert_eq!(back.dim(), sk.dim());
        // Estimates identical (same family regenerated from seed).
        let mut rng = Xoshiro256::new(4);
        let q = gen_ball_point(&mut rng, 5, 0.8);
        assert_eq!(back.estimate_risk(&q), sk.estimate_risk(&q));
    }

    #[test]
    fn decoded_sketch_can_merge_with_source() {
        let mut a = sample_sketch();
        let b = decode(&encode(&a)).unwrap();
        let count_before = a.count();
        a.merge_from(&b);
        assert_eq!(a.count(), count_before * 2);
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = encode(&sample_sketch());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(matches!(decode(&bytes), Err(WireError::BadChecksum { .. })));
    }

    #[test]
    fn truncation_detected() {
        let bytes = encode(&sample_sketch());
        assert!(matches!(decode(&bytes[..10]), Err(WireError::Truncated(_))));
        // Cut counters but keep a valid-length tail: checksum fires first.
        let cut = &bytes[..bytes.len() - 8];
        assert!(decode(cut).is_err());
    }

    #[test]
    fn bad_magic_detected() {
        let mut bytes = encode(&sample_sketch());
        bytes[0] = 0;
        // Fix checksum so the magic check is what fires.
        let crc = super::fnv1a(&bytes[..bytes.len() - 4]);
        let n = bytes.len();
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(decode(&bytes), Err(WireError::BadMagic(_))));
    }
}
