//! Compact binary wire formats for sketches — what edge devices actually
//! transmit over the simulated network.
//!
//! **v1** (dense full sketch), layout (little-endian):
//!
//! ```text
//! magic  u32  = 0x53544F52 ("STOR")
//! version u16 = 1
//! power   u16
//! rows    u32
//! dim     u32
//! seed    u64
//! count   u64
//! counts  rows * 2^power * u32
//! crc     u32   (FNV-1a over everything above)
//! ```
//!
//! **v2** (epoch-tagged delta, sparse or dense): same 32-byte header with
//! `version = 2`, then
//!
//! ```text
//! epoch   u64
//! flags   u8    (0 = dense, 1 = sparse)
//! payload
//!   dense : rows * 2^power * u32
//!   sparse: varint ncells, then ncells x (varint gap, varint count)
//! crc     u32   (FNV-1a over everything above)
//! ```
//!
//! **v3** (width- and task-tagged delta — the narrow-counter tiers and
//! every classification frame): same 32-byte header with `version = 3`,
//! then
//!
//! ```text
//! epoch   u64
//! width   u8    (bytes per counter cell: 1 | 2 | 4)
//! flags   u8    (bit 0: 0 = dense payload, 1 = sparse payload;
//!                bit 1: task — 0 = regression, 1 = classification;
//!                bits 2-3: hash family — 0 = dense Gaussian, 1 = sparse
//!                Rademacher, 2 = fast-Hadamard, 3 rejected;
//!                bit 4: privacy — the counter increments carry DP noise
//!                ([`crate::sketch::privacy::noise_delta`]);
//!                other bits reserved, rejected)
//! density u16   (sparse *hash family* only: nonzero density per-mille,
//!                1..=1000 — absent for every other family)
//! payload
//!   dense : rows * 2^power cells at the NATIVE width (1/2/4 bytes each)
//!   sparse: varint ncells, then ncells x (varint gap, varint count)
//! crc     u32   (FNV-1a over everything above)
//! ```
//!
//! Sparse cells are LEB128 varint runs over ascending row-major indices:
//! the first gap is the absolute index, each subsequent gap is the
//! distance to the previous index (>= 1); counts are >= 1. The encoder
//! goes sparse when at most half the cells changed and falls back to the
//! dense layout otherwise, so a worst-case delta never costs more than
//! ~the v1 counter block. Varint runs are width-agnostic; the v3 width
//! byte makes the *dense* fallback cost its native `cells x width` bytes
//! and lets the decoder bounds-check every run value against the
//! declared width (a frame claiming `u8` cells cannot smuggle a count
//! of 300). Decoding accepts all three versions everywhere: v1 is read
//! as an epoch-0 dense `u32` delta, v2 as a `u32` delta — so [`encode_delta`]
//! emits v2 for `u32` *regression* deltas (bit-identical to the
//! pre-width wire) and v3 for narrow widths and for every
//! *classification* delta (the task bit lives in the v3 flags byte, so
//! regression payloads at any width stay byte-identical to the
//! pre-task wire and the existing golden fixtures hold). A receiver can
//! therefore never fold a classification delta into a regression sketch:
//! the decoded config carries the task and the merge-compatibility check
//! rejects the mix.
//!
//! The hash-family *seed* travels with the counts so a receiver can verify
//! it merges compatible sketches; the hyperplanes themselves are
//! regenerated deterministically and never shipped.
//!
//! The hash *family* ([`crate::config::HashFamily`]) travels in bits 2–3
//! of the v3 flags byte, with the sparse family's density per-mille as a
//! trailing `u16` — two sketches only merge when `(seed, family)` agree,
//! so the wire must carry both. Only v3 has room for the tag: any
//! non-dense family forces a v3 frame (like classification does), while
//! dense frames leave the bits zero — every pre-family fixture in this
//! file stays byte-identical. Family bits on a v1/v2 frame, family code
//! 3, and an out-of-range density are all lying frames and rejected.
//!
//! The *privacy* bit (bit 4 of the v3 flags byte) marks a delta whose
//! increments carry DP noise. Like the task and family tags, only v3 has
//! room for it: a private delta always ships v3 (even u32 dense-family
//! regression), privacy-off frames leave the bit zero and stay
//! byte-identical to every pre-privacy fixture, and the bit on a v1/v2
//! frame is rejected.

use super::delta::SketchDelta;
use super::storm::StormSketch;
use crate::config::{CounterWidth, HashFamily, StormConfig, Task};

const MAGIC: u32 = 0x53544F52;
const VERSION_DENSE: u16 = 1;
const VERSION_DELTA: u16 = 2;
const VERSION_WIDTH: u16 = 3;

const FLAG_DENSE: u8 = 0;
const FLAG_SPARSE: u8 = 1;
/// Bit 1 of the v3 flags byte: the frame carries classification (margin
/// hash) increments. Clear = regression, which keeps every pre-task
/// regression frame byte-identical.
const FLAG_TASK_CLASSIFICATION: u8 = 2;
/// Bits 2–3 of the v3 flags byte: the hash family the counters were
/// accumulated under (0 = dense, 1 = sparse Rademacher, 2 = Hadamard;
/// 3 rejected). Zero for dense keeps every pre-family frame
/// byte-identical.
const FAMILY_SHIFT: u8 = 2;
const FAMILY_MASK: u8 = 0b11 << FAMILY_SHIFT;
/// Bit 4 of the v3 flags byte: the counter increments carry DP noise
/// ([`crate::sketch::privacy::noise_delta`]). Clear when privacy is off,
/// which keeps every pre-privacy frame byte-identical.
const FLAG_PRIVATE: u8 = 16;

fn family_to_code(f: HashFamily) -> u8 {
    match f {
        HashFamily::Dense => 0,
        HashFamily::Sparse { .. } => 1,
        HashFamily::Hadamard => 2,
    }
}

/// Shared header: magic + version + power + rows + dim + seed + count.
const HEADER: usize = 4 + 2 + 2 + 4 + 4 + 8 + 8;
/// v2 extends the header with epoch (u64) + flags (u8).
const HEADER_V2: usize = HEADER + 8 + 1;
/// v3 extends the header with epoch (u64) + width (u8) + flags (u8).
const HEADER_V3: usize = HEADER + 8 + 1 + 1;

/// Hard ceiling on decoded cell counts: headers are CRC-protected but not
/// trusted for allocation — a frame claiming more cells than any real
/// sketch configuration is rejected before any buffer is sized from it.
const MAX_CELLS: usize = 1 << 26;

/// Serialization errors.
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("buffer too short ({0} bytes)")]
    Truncated(usize),
    #[error("bad magic 0x{0:08x}")]
    BadMagic(u32),
    #[error("unsupported version {0}")]
    BadVersion(u16),
    #[error("checksum mismatch (got 0x{got:08x}, want 0x{want:08x})")]
    BadChecksum { got: u32, want: u32 },
    #[error("inconsistent header (rows={rows}, power={power})")]
    BadHeader { rows: u32, power: u16 },
    #[error("bad counter width byte {0} (expected 1, 2 or 4)")]
    BadWidth(u8),
    #[error("malformed payload: {0}")]
    BadPayload(&'static str),
}

fn width_to_byte(w: CounterWidth) -> u8 {
    w.bytes() as u8
}

fn width_from_byte(b: u8) -> Result<CounterWidth, WireError> {
    match b {
        1 => Ok(CounterWidth::U8),
        2 => Ok(CounterWidth::U16),
        4 => Ok(CounterWidth::U32),
        other => Err(WireError::BadWidth(other)),
    }
}

fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// Bounds-checked sequential reader over an untrusted frame body. Every
/// accessor surfaces a [`WireError`] instead of panicking — no indexing,
/// no `unwrap`, no unchecked arithmetic (stormlint's `wire-*` rules hold
/// the decode paths to this). `Truncated` always reports the *full*
/// frame length, matching the hand-rolled bounds checks this replaced.
struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Full frame length (body plus CRC), reported by `Truncated`.
    total: usize,
}

impl<'a> WireReader<'a> {
    fn new(buf: &'a [u8], total: usize) -> WireReader<'a> {
        WireReader { buf, pos: 0, total }
    }

    /// Bytes not yet consumed.
    fn remaining(&self) -> usize {
        self.buf.len().saturating_sub(self.pos)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated(self.total))?;
        let slice = self.buf.get(self.pos..end).ok_or(WireError::Truncated(self.total))?;
        self.pos = end;
        Ok(slice)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        let b = self.take(1)?;
        b.first().copied().ok_or(WireError::Truncated(self.total))
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?.try_into().map_err(|_| WireError::Truncated(self.total))?;
        Ok(u16::from_le_bytes(b))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?.try_into().map_err(|_| WireError::Truncated(self.total))?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?.try_into().map_err(|_| WireError::Truncated(self.total))?;
        Ok(u64::from_le_bytes(b))
    }

    /// LEB128 varint, at most 64 payload bits.
    fn varint(&mut self) -> Result<u64, WireError> {
        let mut val = 0u64;
        let mut shift = 0u32;
        loop {
            if self.remaining() == 0 {
                return Err(WireError::Truncated(self.total));
            }
            if shift >= 64 {
                return Err(WireError::BadPayload("varint longer than 64 bits"));
            }
            let b = self.u8()?;
            let payload = b & 0x7f;
            // The tenth byte holds only the top bit of a u64: anything more
            // would be silently shifted out — reject, don't truncate.
            if shift == 63 && payload > 1 {
                return Err(WireError::BadPayload("varint overflows 64 bits"));
            }
            val |= (payload as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(val);
            }
            shift = shift.saturating_add(7);
        }
    }
}

/// Decode a stream of back-to-back varints. Fuzz/corpus entry point
/// (`fuzz/fuzz_targets/varint.rs` and the replay test), not part of the
/// wire format proper.
#[doc(hidden)]
pub fn fuzz_varint_stream(bytes: &[u8]) -> Result<Vec<u64>, WireError> {
    let mut rd = WireReader::new(bytes, bytes.len());
    let mut out = Vec::new();
    while rd.remaining() != 0 {
        out.push(rd.varint()?);
    }
    Ok(out)
}

/// Encode one value as a varint (fuzz-roundtrip helper).
#[doc(hidden)]
pub fn varint_to_bytes(v: u64) -> Vec<u8> {
    let mut out = Vec::new();
    put_varint(&mut out, v);
    out
}

fn put_header(out: &mut Vec<u8>, version: u16, cfg: &StormConfig, dim: usize, seed: u64, count: u64) {
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(cfg.power as u16).to_le_bytes());
    out.extend_from_slice(&(cfg.rows as u32).to_le_bytes());
    out.extend_from_slice(&(dim as u32).to_le_bytes());
    out.extend_from_slice(&seed.to_le_bytes());
    out.extend_from_slice(&count.to_le_bytes());
}

/// Encode a full sketch into the dense v1 wire format. v1 predates the
/// family tag and is dense-family-only (panics otherwise) — structured
/// sketches ship as v3 deltas ([`encode_delta`] of a from-empty delta
/// carries the full state).
pub fn encode(sketch: &StormSketch) -> Vec<u8> {
    let (grid, count) = sketch.parts();
    let cfg = sketch.config();
    assert_eq!(
        cfg.hash_family,
        HashFamily::Dense,
        "the v1 full-sketch wire has no hash-family tag; ship {} sketches as v3 deltas",
        cfg.hash_family
    );
    let mut out = Vec::with_capacity(HEADER + grid.bytes() + 4);
    put_header(&mut out, VERSION_DENSE, &cfg, sketch.dim(), sketch.seed(), count);
    for c in grid.counts_u32() {
        out.extend_from_slice(&c.to_le_bytes());
    }
    let crc = fnv1a(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Encode an epoch-tagged delta: sparse varint runs when at most half
/// the cells changed, dense counters otherwise. `u32` *regression*
/// deltas under the *dense* hash family ship as v2 frames —
/// byte-identical to the pre-width wire format — narrow (`u8`/`u16`)
/// deltas as width-tagged v3 frames whose dense fallback costs only
/// `cells x width` payload bytes, and every *classification* or
/// *structured-family* delta as a v3 frame with the task/family bits
/// set (only v3 has a place for them; dense regression bytes are
/// untouched).
pub fn encode_delta(delta: &SketchDelta) -> Vec<u8> {
    if delta.width == CounterWidth::U32
        && delta.cfg.task == Task::Regression
        && delta.cfg.hash_family == HashFamily::Dense
        && !delta.private
    {
        encode_delta_version(delta, VERSION_DELTA)
    } else {
        encode_delta_version(delta, VERSION_WIDTH)
    }
}

/// Encode a delta as an explicit v3 frame regardless of width (the
/// golden-fixture tests pin the v3 layout at every width with this).
pub fn encode_delta_v3(delta: &SketchDelta) -> Vec<u8> {
    encode_delta_version(delta, VERSION_WIDTH)
}

fn encode_delta_version(delta: &SketchDelta, version: u16) -> Vec<u8> {
    let width = delta.width;
    let sparse = delta.populated_fraction() <= 0.5;
    // Only the v3 flags byte has task/family bits; pre-tag versions can
    // carry dense-family regression frames only.
    debug_assert!(
        version == VERSION_WIDTH
            || (delta.cfg.task == Task::Regression
                && delta.cfg.hash_family == HashFamily::Dense
                && !delta.private),
        "classification, structured-family and private deltas must ship on the v3 wire"
    );
    let tag_bits = if version == VERSION_WIDTH {
        let task_bit =
            if delta.cfg.task == Task::Classification { FLAG_TASK_CLASSIFICATION } else { 0 };
        let private_bit = if delta.private { FLAG_PRIVATE } else { 0 };
        task_bit | (family_to_code(delta.cfg.hash_family) << FAMILY_SHIFT) | private_bit
    } else {
        0
    };
    // The sparse hash family carries its density per-mille right after
    // the flags byte (merge compatibility depends on it).
    let density_field = match delta.cfg.hash_family {
        HashFamily::Sparse { density_permille } if version == VERSION_WIDTH => {
            Some(density_permille)
        }
        _ => None,
    };
    let header = if version == VERSION_WIDTH { HEADER_V3 } else { HEADER_V2 };
    let mut out =
        Vec::with_capacity(header + 4 + if sparse { 0 } else { delta.counts.len() * width.bytes() });
    put_header(&mut out, version, &delta.cfg, delta.dim, delta.seed, delta.count);
    out.extend_from_slice(&delta.epoch.to_le_bytes());
    if version == VERSION_WIDTH {
        out.push(width_to_byte(width));
    }
    if sparse {
        out.push(FLAG_SPARSE | tag_bits);
        if let Some(d) = density_field {
            out.extend_from_slice(&d.to_le_bytes());
        }
        let cells = delta.sparse_cells();
        put_varint(&mut out, cells.len() as u64);
        let mut prev: Option<u32> = None;
        for (idx, cnt) in cells {
            debug_assert!(cnt <= width.max_value(), "delta value outgrew its width tag");
            let gap = match prev {
                None => idx as u64,
                Some(p) => (idx - p) as u64,
            };
            put_varint(&mut out, gap);
            put_varint(&mut out, cnt as u64);
            prev = Some(idx);
        }
    } else {
        out.push(FLAG_DENSE | tag_bits);
        if let Some(d) = density_field {
            out.extend_from_slice(&d.to_le_bytes());
        }
        for &c in &delta.counts {
            debug_assert!(c <= width.max_value(), "delta value outgrew its width tag");
            match (version, width) {
                (VERSION_WIDTH, CounterWidth::U8) => out.push(c as u8),
                (VERSION_WIDTH, CounterWidth::U16) => {
                    out.extend_from_slice(&(c as u16).to_le_bytes())
                }
                // v2 frames (and v3-at-u32) carry full u32 cells.
                _ => out.extend_from_slice(&c.to_le_bytes()),
            }
        }
    }
    let crc = fnv1a(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Decode a wire buffer into an epoch-tagged delta. Accepts width-tagged
/// v3 frames, v2 frames (read as `u32`) and, backward-compatibly, v1
/// full-sketch frames (read as an epoch-0 dense `u32` delta). Every
/// length, index, count and width byte is validated — corrupt input
/// yields a [`WireError`], never a panic; a sparse run value the
/// declared width cannot hold is rejected, not clipped.
pub fn decode_delta(bytes: &[u8]) -> Result<SketchDelta, WireError> {
    let total = bytes.len();
    if total < HEADER.saturating_add(4) {
        return Err(WireError::Truncated(total));
    }
    let split = total.saturating_sub(4);
    let body = bytes.get(..split).ok_or(WireError::Truncated(total))?;
    let crc_bytes: [u8; 4] = bytes
        .get(split..)
        .and_then(|t| t.try_into().ok())
        .ok_or(WireError::Truncated(total))?;
    let crc_got = u32::from_le_bytes(crc_bytes);
    let crc_want = fnv1a(body);
    if crc_got != crc_want {
        return Err(WireError::BadChecksum { got: crc_got, want: crc_want });
    }
    let mut rd = WireReader::new(body, total);
    let magic = rd.u32()?;
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = rd.u16()?;
    if version != VERSION_DENSE && version != VERSION_DELTA && version != VERSION_WIDTH {
        return Err(WireError::BadVersion(version));
    }
    let power = rd.u16()?;
    let rows = rd.u32()?;
    let dim = rd.u32()?;
    let seed = rd.u64()?;
    let count = rd.u64()?;
    if power == 0 || power > 24 || rows == 0 {
        return Err(WireError::BadHeader { rows, power });
    }
    let buckets = 1usize << power;
    let cells = (rows as usize)
        .checked_mul(buckets)
        .ok_or(WireError::BadHeader { rows, power })?;
    if cells > MAX_CELLS {
        return Err(WireError::BadHeader { rows, power });
    }
    // v1/v2 frames predate the width byte: they are u32 by definition.
    // The reader sits right after the shared header here, so each arm
    // just consumes its own extension fields in order.
    let (epoch, width, flags) = match version {
        VERSION_DENSE => (0u64, CounterWidth::U32, FLAG_DENSE),
        VERSION_DELTA => {
            if body.len() < HEADER_V2 {
                return Err(WireError::Truncated(total));
            }
            let epoch = rd.u64()?;
            (epoch, CounterWidth::U32, rd.u8()?)
        }
        _ => {
            if body.len() < HEADER_V3 {
                return Err(WireError::Truncated(total));
            }
            let epoch = rd.u64()?;
            let width = width_from_byte(rd.u8()?)?;
            (epoch, width, rd.u8()?)
        }
    };
    // Bit 1 of the flags byte tags the task; only v3 frames have it
    // (regression stays byte-identical on every pre-task layout). Any
    // reserved bit — or a task bit on a pre-task version — is a lying
    // frame, not a silent default.
    let task = if flags & FLAG_TASK_CLASSIFICATION != 0 {
        if version != VERSION_WIDTH {
            return Err(WireError::BadPayload("task bit requires the v3 wire"));
        }
        Task::Classification
    } else {
        Task::Regression
    };
    // Bits 2–3 tag the hash family — extracted BEFORE the payload-mode
    // mask so a family code never masquerades as payload flags. The
    // sparse family's density per-mille rides as a u16 right after the
    // flags byte; everything about it is validated like the header.
    let family_code = (flags & FAMILY_MASK) >> FAMILY_SHIFT;
    if family_code != 0 && version != VERSION_WIDTH {
        return Err(WireError::BadPayload("hash-family bits require the v3 wire"));
    }
    let family = match family_code {
        0 => HashFamily::Dense,
        1 => {
            if rd.remaining() < 2 {
                return Err(WireError::Truncated(total));
            }
            let density = rd.u16()?;
            if density == 0 || density > 1000 {
                return Err(WireError::BadPayload("sparse-family density out of range"));
            }
            HashFamily::Sparse { density_permille: density }
        }
        2 => HashFamily::Hadamard,
        _ => return Err(WireError::BadPayload("unknown hash-family code")),
    };
    // Bit 4 tags DP-noised increments; like the other tags it only
    // exists on the v3 layout.
    let private = flags & FLAG_PRIVATE != 0;
    if private && version != VERSION_WIDTH {
        return Err(WireError::BadPayload("privacy bit requires the v3 wire"));
    }
    let mode = flags & !(FLAG_TASK_CLASSIFICATION | FAMILY_MASK | FLAG_PRIVATE);
    let cfg = StormConfig {
        rows: rows as usize,
        power: power as u32,
        saturating: true,
        counter_width: width,
        task,
        hash_family: family,
    };

    let counts = match mode {
        FLAG_DENSE => {
            let cell_bytes = if version == VERSION_WIDTH { width.bytes() } else { 4 };
            let want = cells.checked_mul(cell_bytes).ok_or(WireError::Truncated(total))?;
            if rd.remaining() != want {
                return Err(WireError::Truncated(total));
            }
            let payload = rd.take(want)?;
            let mut counts = vec![0u32; cells];
            for (cell, chunk) in counts.iter_mut().zip(payload.chunks_exact(cell_bytes)) {
                *cell = match cell_bytes {
                    1 => chunk.first().copied().map(u32::from).ok_or(WireError::Truncated(total))?,
                    2 => {
                        let b: [u8; 2] =
                            chunk.try_into().map_err(|_| WireError::Truncated(total))?;
                        u16::from_le_bytes(b) as u32
                    }
                    _ => {
                        let b: [u8; 4] =
                            chunk.try_into().map_err(|_| WireError::Truncated(total))?;
                        u32::from_le_bytes(b)
                    }
                };
            }
            counts
        }
        FLAG_SPARSE => {
            let ncells = rd.varint()?;
            if ncells > cells as u64 {
                return Err(WireError::BadPayload("sparse cell count exceeds grid"));
            }
            let mut counts = vec![0u32; cells];
            let mut idx: u64 = 0;
            for i in 0..ncells {
                let gap = rd.varint()?;
                if i > 0 && gap == 0 {
                    return Err(WireError::BadPayload("non-increasing sparse index"));
                }
                idx = idx
                    .checked_add(gap)
                    .ok_or(WireError::BadPayload("sparse index overflow"))?;
                if idx >= cells as u64 {
                    return Err(WireError::BadPayload("sparse index out of range"));
                }
                let cnt = rd.varint()?;
                if cnt == 0 || cnt > u32::MAX as u64 {
                    return Err(WireError::BadPayload("sparse count out of range"));
                }
                // Bounds-checked narrowing: a run value the declared
                // width cannot hold is a lying frame, not a clip.
                if cnt > width.max_value() as u64 {
                    return Err(WireError::BadPayload("sparse count exceeds declared width"));
                }
                let cell = counts
                    .get_mut(idx as usize)
                    .ok_or(WireError::BadPayload("sparse index out of range"))?;
                *cell = cnt as u32;
            }
            if rd.remaining() != 0 {
                return Err(WireError::BadPayload("trailing bytes after sparse cells"));
            }
            counts
        }
        _ => return Err(WireError::BadPayload("unknown payload flags")),
    };

    Ok(SketchDelta {
        epoch,
        cfg,
        dim: dim as usize,
        seed,
        count,
        width,
        counts,
        private,
    })
}

/// Decode a wire buffer back into a full *regression* sketch (rebuilding
/// the hash family from the embedded seed). Accepts v1, v2 and v3
/// frames; a v3 frame yields a sketch at the frame's native counter
/// width. Classification frames are rejected here — reassemble those
/// through [`decode_delta`] + [`crate::sketch::model::StormModel`].
pub fn decode(bytes: &[u8]) -> Result<StormSketch, WireError> {
    let delta = decode_delta(bytes)?;
    if delta.cfg.task != Task::Regression {
        return Err(WireError::BadPayload("classification frame on full-sketch decode"));
    }
    // Rebuilding the hash family allocates `dim`-proportional plane
    // storage, so the full-sketch path bounds the claimed dimension the
    // way the shared header bounds the cell count — a frame outside the
    // bound is rejected, never allocated for (and `dim = 0` would trip
    // the sketch constructor's geometry assert).
    if delta.dim == 0 || delta.dim > MAX_CELLS {
        return Err(WireError::BadPayload("example dimension out of range"));
    }
    Ok(StormSketch::from_delta(&delta))
}

/// Dense (v1) wire size in bytes for a given configuration — the
/// network-cost ceiling a sparse v2 delta is measured against. v1 cells
/// are always `u32`, whatever the in-memory width.
pub fn wire_bytes(cfg: &StormConfig) -> usize {
    HEADER + cfg.rows * cfg.buckets() * 4 + 4
}

/// Worst-case (dense-fallback) delta frame size for a configuration at
/// its native counter width: the per-round wire ceiling a narrow-tier
/// device pays on a busy round. `u32` dense-family regression configs
/// ship v2 frames; narrow widths, classification, and structured-family
/// configs ship v3 frames with native-width dense cells (plus the
/// 2-byte density field for the sparse family).
pub fn delta_wire_bytes(cfg: &StormConfig) -> usize {
    let cells = cfg.rows * cfg.buckets();
    match (cfg.counter_width, cfg.task, cfg.hash_family) {
        (CounterWidth::U32, Task::Regression, HashFamily::Dense) => HEADER_V2 + cells * 4 + 4,
        (w, _, f) => {
            let density = if matches!(f, HashFamily::Sparse { .. }) { 2 } else { 0 };
            HEADER_V3 + density + cells * w.bytes() + 4
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::storm::StormClassifierSketch;
    use crate::testing::gen_ball_point;
    use crate::util::rng::Xoshiro256;

    fn sample_sketch() -> StormSketch {
        let cfg = StormConfig { rows: 20, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, 5, 77);
        let mut rng = Xoshiro256::new(3);
        for _ in 0..120 {
            let z = gen_ball_point(&mut rng, 5, 0.9);
            sk.insert(&z);
        }
        sk
    }

    fn sparse_delta() -> SketchDelta {
        // 3 inserts into a 20 x 16 grid touch <= 120 of 320 cells.
        let cfg = StormConfig { rows: 20, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, 5, 77);
        let mut rng = Xoshiro256::new(9);
        let snap = sk.snapshot();
        for _ in 0..3 {
            let z = gen_ball_point(&mut rng, 5, 0.9);
            sk.insert(&z);
        }
        sk.delta_since(&snap, 7)
    }

    /// Recompute the trailing CRC after a deliberate mutation, so the
    /// checksum is NOT what trips the decoder.
    fn refix_crc(bytes: &mut [u8]) {
        let n = bytes.len();
        let crc = fnv1a(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let sk = sample_sketch();
        let bytes = encode(&sk);
        assert_eq!(bytes.len(), wire_bytes(&sk.config()));
        let back = decode(&bytes).unwrap();
        assert_eq!(back.grid().counts_u32(), sk.grid().counts_u32());
        assert_eq!(back.count(), sk.count());
        assert_eq!(back.seed(), sk.seed());
        assert_eq!(back.dim(), sk.dim());
        // Estimates identical (same family regenerated from seed).
        let mut rng = Xoshiro256::new(4);
        let q = gen_ball_point(&mut rng, 5, 0.8);
        assert_eq!(back.estimate_risk(&q), sk.estimate_risk(&q));
    }

    #[test]
    fn decoded_sketch_can_merge_with_source() {
        let mut a = sample_sketch();
        let b = decode(&encode(&a)).unwrap();
        let count_before = a.count();
        a.merge_from(&b);
        assert_eq!(a.count(), count_before * 2);
    }

    #[test]
    fn delta_roundtrip_sparse() {
        let delta = sparse_delta();
        assert!(delta.populated_fraction() <= 0.5);
        let bytes = encode_delta(&delta);
        assert_eq!(u16::from_le_bytes(bytes[4..6].try_into().unwrap()), 2);
        assert_eq!(bytes[HEADER + 8], FLAG_SPARSE);
        let back = decode_delta(&bytes).unwrap();
        assert_eq!(back, delta);
    }

    #[test]
    fn delta_roundtrip_dense_fallback() {
        // Saturate the grid: a tiny 1 x 2^1 sketch where every cell is hit.
        let cfg = StormConfig { rows: 2, power: 1, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, 3, 5);
        let snap = sk.snapshot();
        let mut rng = Xoshiro256::new(11);
        for _ in 0..40 {
            sk.insert(&gen_ball_point(&mut rng, 3, 0.9));
        }
        let delta = sk.delta_since(&snap, 3);
        assert!(delta.populated_fraction() > 0.5, "fraction {}", delta.populated_fraction());
        let bytes = encode_delta(&delta);
        assert_eq!(bytes[HEADER + 8], FLAG_DENSE);
        let back = decode_delta(&bytes).unwrap();
        assert_eq!(back, delta);
    }

    /// A narrow-width sketch's round delta (u8/u16 devices emit these).
    fn narrow_delta(width: CounterWidth, inserts: usize) -> SketchDelta {
        let cfg = StormConfig {
            rows: 20,
            power: 4,
            saturating: true,
            counter_width: width,
            ..Default::default()
        };
        let mut sk = StormSketch::new(cfg, 5, 77);
        let snap = sk.snapshot();
        let mut rng = Xoshiro256::new(9);
        for _ in 0..inserts {
            sk.insert(&gen_ball_point(&mut rng, 5, 0.9));
        }
        sk.delta_since(&snap, 7)
    }

    #[test]
    fn narrow_delta_roundtrips_as_v3_at_every_width() {
        for width in [CounterWidth::U8, CounterWidth::U16] {
            // Sparse regime.
            let sparse = narrow_delta(width, 3);
            assert!(sparse.populated_fraction() <= 0.5);
            let bytes = encode_delta(&sparse);
            assert_eq!(u16::from_le_bytes(bytes[4..6].try_into().unwrap()), 3, "{width:?}");
            assert_eq!(bytes[HEADER + 8], width.bytes() as u8);
            assert_eq!(bytes[HEADER + 9], FLAG_SPARSE);
            assert_eq!(decode_delta(&bytes).unwrap(), sparse, "{width:?}");
            // Dense regime: native-width cells on the wire.
            let dense = narrow_delta(width, 80);
            assert!(dense.populated_fraction() > 0.5);
            let bytes = encode_delta(&dense);
            assert_eq!(bytes[HEADER + 9], FLAG_DENSE);
            assert_eq!(bytes.len(), delta_wire_bytes(&dense.cfg), "{width:?}");
            assert_eq!(decode_delta(&bytes).unwrap(), dense, "{width:?}");
        }
    }

    #[test]
    fn dense_v3_narrow_frames_are_smaller_than_u32() {
        let u8_bytes = encode_delta(&narrow_delta(CounterWidth::U8, 80)).len();
        let u16_bytes = encode_delta(&narrow_delta(CounterWidth::U16, 80)).len();
        let u32_bytes = encode_delta(&narrow_delta(CounterWidth::U32, 80)).len();
        assert!(u8_bytes < u16_bytes && u16_bytes < u32_bytes, "{u8_bytes} {u16_bytes} {u32_bytes}");
        // The narrow dense payload is cells x width plus fixed framing.
        assert_eq!(u16_bytes - u8_bytes, 320);
        assert_eq!(u32_bytes + HEADER_V3 - HEADER_V2, u16_bytes + 640);
    }

    #[test]
    fn v2_decodes_as_u32_and_v3_u32_roundtrips() {
        // Backward compat: u32 deltas still ship v2 (pre-width bytes);
        // the explicit v3-at-u32 encoder round-trips too.
        let delta = sparse_delta();
        assert_eq!(delta.width, CounterWidth::U32);
        let v2 = encode_delta(&delta);
        assert_eq!(u16::from_le_bytes(v2[4..6].try_into().unwrap()), 2);
        assert_eq!(decode_delta(&v2).unwrap().width, CounterWidth::U32);
        let v3 = encode_delta_v3(&delta);
        assert_eq!(u16::from_le_bytes(v3[4..6].try_into().unwrap()), 3);
        assert_eq!(decode_delta(&v3).unwrap(), delta);
    }

    #[test]
    fn sparse_count_exceeding_declared_width_rejected() {
        // Bounds-checked narrowing: a frame declaring u8 cells cannot
        // smuggle a run value of 300, even with a valid checksum.
        let mut delta = narrow_delta(CounterWidth::U8, 3);
        delta.counts[0] = 0; // keep the fixture sparse
        let bytes = encode_delta(&delta);
        let mut b = bytes.clone();
        b.truncate(HEADER_V3);
        put_varint(&mut b, 1);
        put_varint(&mut b, 0); // index 0
        put_varint(&mut b, 300); // > u8::MAX
        b.extend_from_slice(&[0u8; 4]);
        refix_crc(&mut b);
        assert!(matches!(
            decode_delta(&b),
            Err(WireError::BadPayload("sparse count exceeds declared width"))
        ));
        // The same value under a u16 tag is fine.
        let mut b16 = b.clone();
        b16[HEADER + 8] = 2;
        refix_crc(&mut b16);
        let ok = decode_delta(&b16).unwrap();
        assert_eq!(ok.counts[0], 300);
        assert_eq!(ok.width, CounterWidth::U16);
    }

    #[test]
    fn sparse_delta_beats_dense_v1_bytes() {
        // Acceptance: a sparse round must cost strictly fewer wire bytes
        // than a dense v1 encode of the full sketch.
        let delta = sparse_delta();
        let sparse_bytes = encode_delta(&delta).len();
        assert!(
            sparse_bytes < wire_bytes(&delta.cfg),
            "sparse {} >= dense {}",
            sparse_bytes,
            wire_bytes(&delta.cfg)
        );
    }

    #[test]
    fn v1_frames_decode_as_epoch_zero_deltas() {
        let sk = sample_sketch();
        let delta = decode_delta(&encode(&sk)).unwrap();
        assert_eq!(delta.epoch, 0);
        assert_eq!(delta.count, sk.count());
        assert_eq!(delta.counts.as_slice(), sk.grid().counts_u32());
        assert_eq!(delta.seed, sk.seed());
    }

    #[test]
    fn v2_frames_decode_as_full_sketches() {
        let delta = sparse_delta();
        let sk = decode(&encode_delta(&delta)).unwrap();
        assert_eq!(sk.grid().counts_u32(), delta.counts.as_slice());
        assert_eq!(sk.count(), delta.count);
        assert_eq!(sk.seed(), delta.seed);
    }

    #[test]
    fn corruption_detected() {
        for bytes in [encode(&sample_sketch()), encode_delta(&sparse_delta())] {
            let mut bytes = bytes;
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0xFF;
            assert!(matches!(decode_delta(&bytes), Err(WireError::BadChecksum { .. })));
        }
    }

    #[test]
    fn truncation_detected() {
        for bytes in [encode(&sample_sketch()), encode_delta(&sparse_delta())] {
            assert!(matches!(decode(&bytes[..10]), Err(WireError::Truncated(_))));
            // Cut counters but keep a valid-length tail: checksum fires first.
            let cut = &bytes[..bytes.len() - 8];
            assert!(decode(cut).is_err());
        }
    }

    #[test]
    fn bad_magic_detected() {
        let mut bytes = encode(&sample_sketch());
        bytes[0] = 0;
        // Fix checksum so the magic check is what fires.
        refix_crc(&mut bytes);
        assert!(matches!(decode(&bytes), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn bad_version_detected() {
        // Version 3 is valid now (the width-tagged wire) — 9 is not.
        let mut bytes = encode(&sample_sketch());
        bytes[4] = 9;
        refix_crc(&mut bytes);
        assert!(matches!(decode(&bytes), Err(WireError::BadVersion(9))));
    }

    #[test]
    fn bad_width_byte_detected() {
        // A v3 frame whose width byte is not 1/2/4 is rejected before any
        // payload is interpreted, even with a valid checksum.
        let mut delta = sparse_delta();
        delta.width = CounterWidth::U8;
        delta.cfg.counter_width = CounterWidth::U8;
        let mut bytes = encode_delta(&delta);
        assert_eq!(u16::from_le_bytes(bytes[4..6].try_into().unwrap()), 3);
        for bad in [0u8, 3, 5, 8, 255] {
            bytes[HEADER + 8] = bad;
            refix_crc(&mut bytes);
            assert!(
                matches!(decode_delta(&bytes), Err(WireError::BadWidth(b)) if b == bad),
                "width byte {bad} accepted"
            );
        }
    }

    #[test]
    fn bad_flags_detected() {
        let mut bytes = encode_delta(&sparse_delta());
        bytes[HEADER + 8] = 7;
        refix_crc(&mut bytes);
        assert!(matches!(decode_delta(&bytes), Err(WireError::BadPayload(_))));
    }

    #[test]
    fn header_mutations_detected_with_valid_crc() {
        // Structural header lies (rows = 0, power = 0, power > 24) must be
        // caught by validation even when the checksum is recomputed.
        let base = encode_delta(&sparse_delta());
        for (off, val) in [(8usize, 0u8), (6, 0), (6, 30)] {
            let mut bytes = base.clone();
            match off {
                8 => bytes[8..12].copy_from_slice(&0u32.to_le_bytes()),
                _ => {
                    bytes[6] = val;
                    bytes[7] = 0;
                }
            }
            refix_crc(&mut bytes);
            assert!(
                matches!(decode_delta(&bytes), Err(WireError::BadHeader { .. })),
                "off={off} val={val}"
            );
        }
    }

    #[test]
    fn sparse_payload_lies_detected_with_valid_crc() {
        let delta = sparse_delta();
        let bytes = encode_delta(&delta);
        let payload_at = HEADER_V2;

        // ncells exceeding the grid.
        let mut b = bytes.clone();
        // Overwrite the ncells varint region with a huge 3-byte varint is
        // tricky in place; instead craft a fresh frame with a lying count.
        b.truncate(payload_at);
        put_varint(&mut b, (delta.counts.len() + 1) as u64);
        b.extend_from_slice(&[0u8; 4]); // room for crc
        refix_crc(&mut b);
        assert!(matches!(decode_delta(&b), Err(WireError::BadPayload(_))));

        // Zero-gap (non-increasing index) on the second cell.
        let mut b = bytes.clone();
        b.truncate(payload_at);
        put_varint(&mut b, 2);
        put_varint(&mut b, 1); // first index = 1
        put_varint(&mut b, 5); // count
        put_varint(&mut b, 0); // zero gap -> same index again
        put_varint(&mut b, 5);
        b.extend_from_slice(&[0u8; 4]);
        refix_crc(&mut b);
        assert!(matches!(decode_delta(&b), Err(WireError::BadPayload(_))));

        // Index past the end of the grid.
        let mut b = bytes.clone();
        b.truncate(payload_at);
        put_varint(&mut b, 1);
        put_varint(&mut b, delta.counts.len() as u64); // == cells -> out of range
        put_varint(&mut b, 5);
        b.extend_from_slice(&[0u8; 4]);
        refix_crc(&mut b);
        assert!(matches!(decode_delta(&b), Err(WireError::BadPayload(_))));

        // Zero count.
        let mut b = bytes.clone();
        b.truncate(payload_at);
        put_varint(&mut b, 1);
        put_varint(&mut b, 0);
        put_varint(&mut b, 0);
        b.extend_from_slice(&[0u8; 4]);
        refix_crc(&mut b);
        assert!(matches!(decode_delta(&b), Err(WireError::BadPayload(_))));

        // Trailing garbage after the declared cells.
        let mut b = bytes.clone();
        let n = b.len();
        b.insert(n - 4, 0x00);
        refix_crc(&mut b);
        assert!(matches!(decode_delta(&b), Err(WireError::BadPayload(_))));
    }

    // ---- Golden byte fixtures -------------------------------------
    //
    // Checked-in encodings of hand-constructed frames for every wire
    // layout: v1 dense full-sketch, v2 sparse delta, v2 dense-fallback
    // delta, and width-tagged v3 frames at all three counter widths.
    // Any silent format drift — field order, width, varint scheme, flag
    // values, checksum — fails these tests; bump the wire VERSION and
    // add new fixtures instead of editing these.

    const GOLDEN_V2_SPARSE_HEX: &str = "524f545302000200020000000300000088776655443322110500000000000000070000000000000001030103020104023fbdf029";
    const GOLDEN_V2_DENSE_HEX: &str = "524f545302000200020000000200000001020304050607080b0000000000000009000000000000000001000000020000000300000004000000050000000600000000000000070000008f89afde";
    const GOLDEN_V1_DENSE_HEX: &str = "524f5453010002000200000003000000887766554433221105000000000000000000000003000000000000000100000000000000000000000000000002000000b0a904dd";
    // v3: same logical deltas, width-tagged. u8 and u32 take the sparse
    // path (runs are width-agnostic, only the width byte differs); the
    // u16 fixture is dense-fallback with 2-byte little-endian cells.
    const GOLDEN_V3_U8_SPARSE_HEX: &str = "524f5453030002000200000003000000887766554433221105000000000000000700000000000000010103010302010402bfb4aeae";
    const GOLDEN_V3_U16_DENSE_HEX: &str = "524f545303000200020000000200000001020304050607080b000000000000000900000000000000020001002c0103000400050006000000bc02d6e008ec";
    const GOLDEN_V3_U32_SPARSE_HEX: &str = "524f54530300020002000000030000008877665544332211050000000000000007000000000000000401030103020104020cd7cc9e";
    // Classifier deltas (task bit set in the v3 flags byte): the same
    // logical grids as the fixtures above, at all three widths. The only
    // byte-level differences from the regression v3 frames are the flags
    // byte and the CRC — cross-computed with the Python encoder mirror
    // (python/tests/wire_mirror.py), which reproduces every fixture in
    // this file byte-for-byte.
    const GOLDEN_CLF_U8_SPARSE_HEX: &str = "524f5453030002000200000003000000887766554433221105000000000000000700000000000000010303010302010402b93c9fe8";
    const GOLDEN_CLF_U16_DENSE_HEX: &str = "524f545303000200020000000200000001020304050607080b000000000000000900000000000000020201002c0103000400050006000000bc02ac7097d0";
    const GOLDEN_CLF_U32_SPARSE_HEX: &str = "524f54530300020002000000030000008877665544332211050000000000000007000000000000000403030103020104029a81c144";
    // Structured hash families (flags bits 2-3 set; always v3). The
    // sparse family's frames carry the density per-mille as a u16 right
    // after the flags byte; Hadamard frames add no extra field. Cross-
    // computed with python/tests/wire_mirror.py like every fixture here.
    const GOLDEN_SPARSE_FAM_U32_SPARSE_HEX: &str = "524f54530300020002000000030000008877665544332211050000000000000007000000000000000405fa000301030201040282e7e877";
    const GOLDEN_HADAMARD_U8_SPARSE_HEX: &str = "524f5453030002000200000003000000887766554433221105000000000000000700000000000000010903010302010402c7adb999";
    const GOLDEN_SPARSE_FAM_CLF_U16_DENSE_HEX: &str = "524f545303000200020000000200000001020304050607080b0000000000000009000000000000000206640001002c0103000400050006000000bc02f4740a9e";
    // Private deltas (flags bit 4 set; always v3 — even u32 dense-family
    // regression, which would otherwise ship v2). Cross-computed with
    // python/tests/wire_mirror.py like every fixture here.
    const GOLDEN_PRIVATE_U32_SPARSE_HEX: &str = "524f5453030002000200000003000000887766554433221105000000000000000700000000000000041103010302010402fce4b6c8";
    const GOLDEN_PRIVATE_U8_SPARSE_HEX: &str = "524f5453030002000200000003000000887766554433221105000000000000000700000000000000011103010302010402afc298d8";
    const GOLDEN_PRIVATE_CLF_U16_DENSE_HEX: &str = "524f545303000200020000000200000001020304050607080b000000000000000900000000000000021201002c0103000400050006000000bc029c0ccd23";

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    fn unhex(s: &str) -> Vec<u8> {
        (0..s.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
            .collect()
    }

    /// 2 x 4 grid, 3 of 8 cells populated (37.5% -> sparse encoding).
    fn golden_sparse_delta() -> SketchDelta {
        golden_sparse_delta_at(CounterWidth::U32)
    }

    fn golden_sparse_delta_at(width: CounterWidth) -> SketchDelta {
        SketchDelta {
            epoch: 7,
            cfg: StormConfig {
                rows: 2,
                power: 2,
                saturating: true,
                counter_width: width,
                ..Default::default()
            },
            dim: 3,
            seed: 0x1122_3344_5566_7788,
            count: 5,
            width,
            counts: vec![0, 3, 0, 1, 0, 0, 0, 2],
            private: false,
        }
    }

    /// 2 x 4 grid, 7 of 8 cells populated (87.5% -> dense fallback).
    fn golden_dense_delta() -> SketchDelta {
        SketchDelta {
            epoch: 9,
            cfg: StormConfig { rows: 2, power: 2, saturating: true, ..Default::default() },
            dim: 2,
            seed: 0x0807_0605_0403_0201,
            count: 11,
            width: CounterWidth::U32,
            counts: vec![1, 2, 3, 4, 5, 6, 0, 7],
            private: false,
        }
    }

    /// The u16 dense fixture carries values above 255 so the 2-byte
    /// little-endian cell layout is actually exercised on the wire.
    fn golden_dense_delta_u16() -> SketchDelta {
        SketchDelta {
            epoch: 9,
            cfg: StormConfig {
                rows: 2,
                power: 2,
                saturating: true,
                counter_width: CounterWidth::U16,
                ..Default::default()
            },
            dim: 2,
            seed: 0x0807_0605_0403_0201,
            count: 11,
            width: CounterWidth::U16,
            counts: vec![1, 300, 3, 4, 5, 6, 0, 700],
            private: false,
        }
    }

    #[test]
    fn golden_v2_sparse_bytes_are_stable() {
        let delta = golden_sparse_delta();
        assert!(delta.populated_fraction() <= 0.5, "fixture must take the sparse path");
        assert_eq!(
            hex(&encode_delta(&delta)),
            GOLDEN_V2_SPARSE_HEX,
            "v2 sparse wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_V2_SPARSE_HEX)).unwrap(), delta);
    }

    #[test]
    fn golden_v2_dense_bytes_are_stable() {
        let delta = golden_dense_delta();
        assert!(delta.populated_fraction() > 0.5, "fixture must take the dense fallback");
        assert_eq!(
            hex(&encode_delta(&delta)),
            GOLDEN_V2_DENSE_HEX,
            "v2 dense-fallback wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_V2_DENSE_HEX)).unwrap(), delta);
    }

    #[test]
    fn golden_v1_bytes_are_stable() {
        let sk = StormSketch::from_delta(&golden_sparse_delta());
        assert_eq!(
            hex(&encode(&sk)),
            GOLDEN_V1_DENSE_HEX,
            "v1 wire encoding drifted — bump the wire version instead"
        );
        // The v1 fixture still decodes on both entry points.
        let back = decode(&unhex(GOLDEN_V1_DENSE_HEX)).unwrap();
        assert_eq!(back.grid().counts_u32(), sk.grid().counts_u32());
        assert_eq!(back.count(), 5);
        let as_delta = decode_delta(&unhex(GOLDEN_V1_DENSE_HEX)).unwrap();
        assert_eq!(as_delta.epoch, 0, "v1 reads as an epoch-0 dense delta");
        assert_eq!(as_delta.counts, golden_sparse_delta().counts);
    }

    #[test]
    fn golden_v3_bytes_are_stable_at_all_widths() {
        // u8 sparse: same runs as the v2 sparse fixture, width byte 1.
        let u8_delta = golden_sparse_delta_at(CounterWidth::U8);
        assert!(u8_delta.populated_fraction() <= 0.5);
        assert_eq!(
            hex(&encode_delta(&u8_delta)),
            GOLDEN_V3_U8_SPARSE_HEX,
            "v3 u8 sparse wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_V3_U8_SPARSE_HEX)).unwrap(), u8_delta);

        // u16 dense fallback: 2-byte LE cells, values past 255.
        let u16_delta = golden_dense_delta_u16();
        assert!(u16_delta.populated_fraction() > 0.5);
        assert_eq!(
            hex(&encode_delta(&u16_delta)),
            GOLDEN_V3_U16_DENSE_HEX,
            "v3 u16 dense wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_V3_U16_DENSE_HEX)).unwrap(), u16_delta);

        // u32 sparse via the explicit v3 encoder (the implicit path ships
        // v2 for u32 — pinned by the v2 fixture above).
        let u32_delta = golden_sparse_delta();
        assert_eq!(
            hex(&encode_delta_v3(&u32_delta)),
            GOLDEN_V3_U32_SPARSE_HEX,
            "v3 u32 sparse wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_V3_U32_SPARSE_HEX)).unwrap(), u32_delta);
    }

    /// The golden fixtures with the task switched to classification.
    fn golden_clf_delta_at(width: CounterWidth) -> SketchDelta {
        let mut d = golden_sparse_delta_at(width);
        d.cfg.task = Task::Classification;
        d
    }

    #[test]
    fn golden_classifier_bytes_are_stable_at_all_widths() {
        // u8 sparse, task bit set.
        let u8_delta = golden_clf_delta_at(CounterWidth::U8);
        assert_eq!(
            hex(&encode_delta(&u8_delta)),
            GOLDEN_CLF_U8_SPARSE_HEX,
            "classifier u8 sparse wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_CLF_U8_SPARSE_HEX)).unwrap(), u8_delta);

        // u16 dense fallback, task bit set.
        let mut u16_delta = golden_dense_delta_u16();
        u16_delta.cfg.task = Task::Classification;
        assert_eq!(
            hex(&encode_delta(&u16_delta)),
            GOLDEN_CLF_U16_DENSE_HEX,
            "classifier u16 dense wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_CLF_U16_DENSE_HEX)).unwrap(), u16_delta);

        // u32: classification always ships v3 (only v3 carries the task
        // bit), unlike regression u32 which stays on the pre-width v2.
        let u32_delta = golden_clf_delta_at(CounterWidth::U32);
        let bytes = encode_delta(&u32_delta);
        assert_eq!(u16::from_le_bytes(bytes[4..6].try_into().unwrap()), 3);
        assert_eq!(
            hex(&bytes),
            GOLDEN_CLF_U32_SPARSE_HEX,
            "classifier u32 sparse wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_CLF_U32_SPARSE_HEX)).unwrap(), u32_delta);

        // Task bit round-trips: decoded config carries Classification.
        assert_eq!(
            decode_delta(&bytes).unwrap().cfg.task,
            Task::Classification
        );
    }

    /// The sparse-payload golden fixture under a structured hash family.
    fn golden_family_delta(width: CounterWidth, family: HashFamily) -> SketchDelta {
        let mut d = golden_sparse_delta_at(width);
        d.cfg.hash_family = family;
        d
    }

    #[test]
    fn golden_structured_family_bytes_are_stable() {
        // Sparse Rademacher family at u32: forced onto v3 (u32 regression
        // would otherwise ship v2) with density 250 on the wire.
        let sp =
            golden_family_delta(CounterWidth::U32, HashFamily::Sparse { density_permille: 250 });
        let bytes = encode_delta(&sp);
        assert_eq!(u16::from_le_bytes(bytes[4..6].try_into().unwrap()), 3);
        assert_eq!(
            hex(&bytes),
            GOLDEN_SPARSE_FAM_U32_SPARSE_HEX,
            "sparse-family wire encoding drifted — bump the wire version instead"
        );
        let back = decode_delta(&unhex(GOLDEN_SPARSE_FAM_U32_SPARSE_HEX)).unwrap();
        assert_eq!(back, sp);
        assert_eq!(back.cfg.hash_family, HashFamily::Sparse { density_permille: 250 });

        // Hadamard family at u8: family code 2, no density field.
        let had = golden_family_delta(CounterWidth::U8, HashFamily::Hadamard);
        assert_eq!(
            hex(&encode_delta(&had)),
            GOLDEN_HADAMARD_U8_SPARSE_HEX,
            "Hadamard-family wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_HADAMARD_U8_SPARSE_HEX)).unwrap(), had);

        // Sparse family + classification + dense fallback at u16: every
        // v3 tag at once (width byte, task bit, family bits, density).
        let mut clf = golden_dense_delta_u16();
        clf.cfg.task = Task::Classification;
        clf.cfg.hash_family = HashFamily::Sparse { density_permille: 100 };
        assert_eq!(
            hex(&encode_delta(&clf)),
            GOLDEN_SPARSE_FAM_CLF_U16_DENSE_HEX,
            "sparse-family classifier wire encoding drifted — bump the wire version instead"
        );
        let back = decode_delta(&unhex(GOLDEN_SPARSE_FAM_CLF_U16_DENSE_HEX)).unwrap();
        assert_eq!(back, clf);
        assert_eq!(back.cfg.task, Task::Classification);
        // The dense-fallback frame size includes the density field.
        assert_eq!(encode_delta(&clf).len(), delta_wire_bytes(&clf.cfg));
    }

    #[test]
    fn golden_private_bytes_are_stable() {
        // u32 sparse regression, private: the privacy bit alone forces
        // the frame onto v3 (the non-private twin ships v2).
        let mut u32_delta = golden_sparse_delta();
        u32_delta.private = true;
        let bytes = encode_delta(&u32_delta);
        assert_eq!(u16::from_le_bytes(bytes[4..6].try_into().unwrap()), 3);
        assert_eq!(bytes[HEADER + 9] & FLAG_PRIVATE, FLAG_PRIVATE);
        assert_eq!(
            hex(&bytes),
            GOLDEN_PRIVATE_U32_SPARSE_HEX,
            "private u32 sparse wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_PRIVATE_U32_SPARSE_HEX)).unwrap(), u32_delta);

        // u8 sparse, private.
        let mut u8_delta = golden_sparse_delta_at(CounterWidth::U8);
        u8_delta.private = true;
        assert_eq!(
            hex(&encode_delta(&u8_delta)),
            GOLDEN_PRIVATE_U8_SPARSE_HEX,
            "private u8 sparse wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_PRIVATE_U8_SPARSE_HEX)).unwrap(), u8_delta);

        // u16 dense classifier, private: task + width + privacy at once.
        let mut clf = golden_dense_delta_u16();
        clf.cfg.task = Task::Classification;
        clf.private = true;
        assert_eq!(
            hex(&encode_delta(&clf)),
            GOLDEN_PRIVATE_CLF_U16_DENSE_HEX,
            "private classifier wire encoding drifted — bump the wire version instead"
        );
        let back = decode_delta(&unhex(GOLDEN_PRIVATE_CLF_U16_DENSE_HEX)).unwrap();
        assert_eq!(back, clf);
        assert!(back.private, "privacy bit round-trips");
    }

    #[test]
    fn privacy_bit_on_pre_v3_versions_rejected() {
        // A v2 frame whose flags byte smuggles the privacy bit is a lying
        // frame even with a valid checksum: only v3 carries the tag.
        let mut bytes = encode_delta(&sparse_delta());
        assert_eq!(u16::from_le_bytes(bytes[4..6].try_into().unwrap()), 2);
        bytes[HEADER + 8] |= FLAG_PRIVATE;
        refix_crc(&mut bytes);
        assert!(matches!(
            decode_delta(&bytes),
            Err(WireError::BadPayload("privacy bit requires the v3 wire"))
        ));
    }

    #[test]
    fn non_private_frames_never_set_the_privacy_bit() {
        // The acceptance bar for the privacy tag: privacy off must not
        // move a single byte at any width/task/family — the goldens above
        // pin the exact bytes; here we state the mechanism directly.
        let v2 = encode_delta(&sparse_delta());
        assert_eq!(u16::from_le_bytes(v2[4..6].try_into().unwrap()), 2);
        for width in [CounterWidth::U8, CounterWidth::U16] {
            let flags = encode_delta(&golden_sparse_delta_at(width))[HEADER + 9];
            assert_eq!(flags & FLAG_PRIVATE, 0, "{width:?}");
        }
        let clf = encode_delta(&golden_clf_delta_at(CounterWidth::U32));
        assert_eq!(clf[HEADER + 9] & FLAG_PRIVATE, 0);
    }

    #[test]
    fn structured_family_deltas_roundtrip_from_live_sketches() {
        // A from-empty delta carries the full structured sketch state:
        // encode -> decode -> from_delta must rebuild a sketch whose
        // estimates are bit-identical (same seed, same family, same
        // counters). This is the wire path structured fleets use in
        // place of the dense-only v1 full-sketch frame.
        for family in [
            HashFamily::Sparse { density_permille: 300 },
            HashFamily::Hadamard,
        ] {
            let cfg = StormConfig {
                rows: 20,
                power: 4,
                saturating: true,
                hash_family: family,
                ..Default::default()
            };
            let mut sk = StormSketch::new(cfg, 5, 77);
            let snap = StormSketch::new(cfg, 5, 77).snapshot();
            let mut rng = Xoshiro256::new(3);
            for _ in 0..40 {
                sk.insert(&gen_ball_point(&mut rng, 5, 0.9));
            }
            let delta = sk.delta_since(&snap, 4);
            let bytes = encode_delta(&delta);
            assert_eq!(u16::from_le_bytes(bytes[4..6].try_into().unwrap()), 3, "{family}");
            let back = decode_delta(&bytes).unwrap();
            assert_eq!(back, delta, "{family}");
            assert_eq!(back.cfg.hash_family, family);
            let rebuilt = decode(&bytes).unwrap();
            assert_eq!(rebuilt.grid().counts_u32(), sk.grid().counts_u32(), "{family}");
            let q = gen_ball_point(&mut rng, 5, 0.8);
            assert_eq!(rebuilt.estimate_risk(&q), sk.estimate_risk(&q), "{family}");
        }
    }

    #[test]
    #[should_panic(expected = "hash-family tag")]
    fn v1_encode_of_a_structured_sketch_panics() {
        let cfg = StormConfig {
            rows: 4,
            power: 2,
            saturating: true,
            hash_family: HashFamily::Hadamard,
            ..Default::default()
        };
        let sk = StormSketch::new(cfg, 3, 1);
        let _ = encode(&sk);
    }

    #[test]
    fn family_bits_on_pre_family_versions_rejected() {
        // A v2 frame whose flags byte smuggles family bits is a lying
        // frame even with a valid checksum: only v3 carries the tag.
        let base = encode_delta(&sparse_delta());
        assert_eq!(u16::from_le_bytes(base[4..6].try_into().unwrap()), 2);
        for code in [1u8, 2] {
            let mut bytes = base.clone();
            bytes[HEADER + 8] |= code << 2;
            refix_crc(&mut bytes);
            assert!(
                matches!(
                    decode_delta(&bytes),
                    Err(WireError::BadPayload("hash-family bits require the v3 wire"))
                ),
                "family code {code} accepted on v2"
            );
        }
    }

    #[test]
    fn reserved_family_code_rejected() {
        // Family code 3 is unassigned: reject, don't guess.
        let mut bytes = encode_delta(&narrow_delta(CounterWidth::U8, 3));
        bytes[HEADER + 9] |= 3 << 2;
        refix_crc(&mut bytes);
        assert!(matches!(
            decode_delta(&bytes),
            Err(WireError::BadPayload("unknown hash-family code"))
        ));
    }

    #[test]
    fn out_of_range_sparse_family_density_rejected() {
        // Density 0 and > 1000 per-mille are meaningless (validate.rs
        // enforces (0, 1] at config load); the decoder holds the same
        // line against hand-crafted frames.
        let good =
            golden_family_delta(CounterWidth::U32, HashFamily::Sparse { density_permille: 250 });
        let base = encode_delta(&good);
        for bad in [0u16, 1001, u16::MAX] {
            let mut bytes = base.clone();
            bytes[HEADER_V3..HEADER_V3 + 2].copy_from_slice(&bad.to_le_bytes());
            refix_crc(&mut bytes);
            assert!(
                matches!(
                    decode_delta(&bytes),
                    Err(WireError::BadPayload("sparse-family density out of range"))
                ),
                "density {bad} accepted"
            );
        }
        // A sparse-family frame cut off inside the density field is
        // truncation, not a panic.
        let mut short = base[..HEADER_V3 + 1].to_vec();
        short.extend_from_slice(&[0u8; 4]);
        refix_crc(&mut short);
        assert!(decode_delta(&short).is_err());
    }

    #[test]
    fn classifier_delta_roundtrips_from_a_live_sketch() {
        let cfg = StormConfig { rows: 20, power: 3, saturating: true, ..Default::default() };
        let mut sk = StormClassifierSketch::new(cfg, 4, 77);
        let snap = sk.snapshot();
        let mut rng = Xoshiro256::new(12);
        for i in 0..30 {
            let x = gen_ball_point(&mut rng, 4, 0.9);
            sk.insert_labelled(&x, if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        let delta = sk.delta_since(&snap, 5);
        assert_eq!(delta.cfg.task, Task::Classification);
        let bytes = encode_delta(&delta);
        let back = decode_delta(&bytes).unwrap();
        assert_eq!(back, delta);
        // Applying the decoded delta onto a fresh classifier reproduces
        // the live grid.
        let mut replica = StormClassifierSketch::new(cfg, 4, 77);
        replica.apply_delta(&back);
        assert_eq!(replica.grid().counts_u32(), sk.grid().counts_u32());
        assert_eq!(replica.count(), 30);
        // The full-sketch regression decoder refuses classification
        // frames rather than rebuilding the wrong hash family.
        assert!(matches!(decode(&bytes), Err(WireError::BadPayload(_))));
    }

    #[test]
    fn task_bit_on_pre_task_versions_rejected() {
        // A v2 frame whose flags byte smuggles the task bit is a lying
        // frame even with a valid checksum: only v3 carries the tag.
        let mut bytes = encode_delta(&sparse_delta());
        assert_eq!(u16::from_le_bytes(bytes[4..6].try_into().unwrap()), 2);
        bytes[HEADER + 8] |= 2;
        refix_crc(&mut bytes);
        assert!(matches!(
            decode_delta(&bytes),
            Err(WireError::BadPayload("task bit requires the v3 wire"))
        ));
    }

    #[test]
    fn regression_frames_stay_byte_identical_with_the_task_field() {
        // The acceptance bar for the task tag: adding it must not move a
        // single regression byte at any width. The pre-task golden
        // fixtures above pin the exact bytes; here we state the
        // mechanism directly — u32 regression still ships version 2, and
        // no regression frame ever sets the task bit.
        let delta = sparse_delta();
        assert_eq!(delta.cfg.task, Task::Regression);
        let v2 = encode_delta(&delta);
        assert_eq!(u16::from_le_bytes(v2[4..6].try_into().unwrap()), 2);
        assert_eq!(v2[HEADER + 8] & 2, 0, "v2 flags carry no task bit");
        for width in [CounterWidth::U8, CounterWidth::U16] {
            let d = golden_sparse_delta_at(width);
            let flags = encode_delta(&d)[HEADER + 9];
            assert_eq!(flags & 2, 0, "{width:?}: regression frames never set the task bit");
        }
    }

    #[test]
    fn varint_roundtrip_and_overflow() {
        let mut buf = Vec::new();
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            buf.clear();
            put_varint(&mut buf, v);
            let mut rd = WireReader::new(&buf, buf.len());
            assert_eq!(rd.varint().unwrap(), v);
            assert_eq!(rd.remaining(), 0);
            assert_eq!(fuzz_varint_stream(&buf).unwrap(), vec![v]);
            assert_eq!(varint_to_bytes(v), buf);
        }
        // 11-byte varint: more than 64 bits -> error, not wraparound.
        let over = [0x80u8; 10];
        let mut rd = WireReader::new(&over, over.len());
        assert!(rd.varint().is_err());
        assert!(fuzz_varint_stream(&over).is_err());
    }
}
