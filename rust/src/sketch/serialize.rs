//! Compact binary wire formats for sketches — what edge devices actually
//! transmit over the simulated network.
//!
//! **v1** (dense full sketch), layout (little-endian):
//!
//! ```text
//! magic  u32  = 0x53544F52 ("STOR")
//! version u16 = 1
//! power   u16
//! rows    u32
//! dim     u32
//! seed    u64
//! count   u64
//! counts  rows * 2^power * u32
//! crc     u32   (FNV-1a over everything above)
//! ```
//!
//! **v2** (epoch-tagged delta, sparse or dense): same 32-byte header with
//! `version = 2`, then
//!
//! ```text
//! epoch   u64
//! flags   u8    (0 = dense, 1 = sparse)
//! payload
//!   dense : rows * 2^power * u32
//!   sparse: varint ncells, then ncells x (varint gap, varint count)
//! crc     u32   (FNV-1a over everything above)
//! ```
//!
//! Sparse cells are LEB128 varint runs over ascending row-major indices:
//! the first gap is the absolute index, each subsequent gap is the
//! distance to the previous index (>= 1); counts are >= 1. The encoder
//! goes sparse when at most half the cells changed and falls back to the
//! dense layout otherwise, so a worst-case delta never costs more than
//! ~the v1 counter block. Decoding accepts both versions everywhere
//! (a v1 frame is read as an epoch-0 dense delta).
//!
//! The hash-family *seed* travels with the counts so a receiver can verify
//! it merges compatible sketches; the hyperplanes themselves are
//! regenerated deterministically and never shipped.

use super::delta::SketchDelta;
use super::storm::StormSketch;
use crate::config::StormConfig;

const MAGIC: u32 = 0x53544F52;
const VERSION_DENSE: u16 = 1;
const VERSION_DELTA: u16 = 2;

const FLAG_DENSE: u8 = 0;
const FLAG_SPARSE: u8 = 1;

/// Shared header: magic + version + power + rows + dim + seed + count.
const HEADER: usize = 4 + 2 + 2 + 4 + 4 + 8 + 8;
/// v2 extends the header with epoch (u64) + flags (u8).
const HEADER_V2: usize = HEADER + 8 + 1;

/// Hard ceiling on decoded cell counts: headers are CRC-protected but not
/// trusted for allocation — a frame claiming more cells than any real
/// sketch configuration is rejected before any buffer is sized from it.
const MAX_CELLS: usize = 1 << 26;

/// Serialization errors.
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("buffer too short ({0} bytes)")]
    Truncated(usize),
    #[error("bad magic 0x{0:08x}")]
    BadMagic(u32),
    #[error("unsupported version {0}")]
    BadVersion(u16),
    #[error("checksum mismatch (got 0x{got:08x}, want 0x{want:08x})")]
    BadChecksum { got: u32, want: u32 },
    #[error("inconsistent header (rows={rows}, power={power})")]
    BadHeader { rows: u32, power: u16 },
    #[error("malformed payload: {0}")]
    BadPayload(&'static str),
}

fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

fn get_varint(bytes: &[u8], pos: &mut usize) -> Result<u64, WireError> {
    let mut val = 0u64;
    let mut shift = 0u32;
    loop {
        if *pos >= bytes.len() {
            return Err(WireError::Truncated(bytes.len()));
        }
        if shift >= 64 {
            return Err(WireError::BadPayload("varint longer than 64 bits"));
        }
        let b = bytes[*pos];
        *pos += 1;
        let payload = b & 0x7f;
        // The tenth byte holds only the top bit of a u64: anything more
        // would be silently shifted out — reject, don't truncate.
        if shift == 63 && payload > 1 {
            return Err(WireError::BadPayload("varint overflows 64 bits"));
        }
        val |= (payload as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(val);
        }
        shift += 7;
    }
}

fn put_header(out: &mut Vec<u8>, version: u16, cfg: &StormConfig, dim: usize, seed: u64, count: u64) {
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(cfg.power as u16).to_le_bytes());
    out.extend_from_slice(&(cfg.rows as u32).to_le_bytes());
    out.extend_from_slice(&(dim as u32).to_le_bytes());
    out.extend_from_slice(&seed.to_le_bytes());
    out.extend_from_slice(&count.to_le_bytes());
}

/// Encode a full sketch into the dense v1 wire format.
pub fn encode(sketch: &StormSketch) -> Vec<u8> {
    let (grid, count) = sketch.parts();
    let cfg = sketch.config();
    let mut out = Vec::with_capacity(HEADER + grid.bytes() + 4);
    put_header(&mut out, VERSION_DENSE, &cfg, sketch.dim(), sketch.seed(), count);
    for &c in grid.data() {
        out.extend_from_slice(&c.to_le_bytes());
    }
    let crc = fnv1a(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Encode an epoch-tagged delta into the v2 wire format: sparse varint
/// runs when at most half the cells changed, dense counters otherwise.
pub fn encode_delta(delta: &SketchDelta) -> Vec<u8> {
    let sparse = delta.populated_fraction() <= 0.5;
    let mut out = Vec::with_capacity(HEADER_V2 + 4 + if sparse { 0 } else { delta.counts.len() * 4 });
    put_header(&mut out, VERSION_DELTA, &delta.cfg, delta.dim, delta.seed, delta.count);
    out.extend_from_slice(&delta.epoch.to_le_bytes());
    if sparse {
        out.push(FLAG_SPARSE);
        let cells = delta.sparse_cells();
        put_varint(&mut out, cells.len() as u64);
        let mut prev: Option<u32> = None;
        for (idx, cnt) in cells {
            let gap = match prev {
                None => idx as u64,
                Some(p) => (idx - p) as u64,
            };
            put_varint(&mut out, gap);
            put_varint(&mut out, cnt as u64);
            prev = Some(idx);
        }
    } else {
        out.push(FLAG_DENSE);
        for &c in &delta.counts {
            out.extend_from_slice(&c.to_le_bytes());
        }
    }
    let crc = fnv1a(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Decode a wire buffer into an epoch-tagged delta. Accepts v2 frames and,
/// backward-compatibly, v1 full-sketch frames (read as an epoch-0 dense
/// delta). Every length, index and count is validated — corrupt input
/// yields a [`WireError`], never a panic.
pub fn decode_delta(bytes: &[u8]) -> Result<SketchDelta, WireError> {
    if bytes.len() < HEADER + 4 {
        return Err(WireError::Truncated(bytes.len()));
    }
    let body = &bytes[..bytes.len() - 4];
    let crc_got = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    let crc_want = fnv1a(body);
    if crc_got != crc_want {
        return Err(WireError::BadChecksum { got: crc_got, want: crc_want });
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    if version != VERSION_DENSE && version != VERSION_DELTA {
        return Err(WireError::BadVersion(version));
    }
    let power = u16::from_le_bytes(bytes[6..8].try_into().unwrap());
    let rows = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let dim = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    let seed = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let count = u64::from_le_bytes(bytes[24..32].try_into().unwrap());
    if power == 0 || power > 24 || rows == 0 {
        return Err(WireError::BadHeader { rows, power });
    }
    let buckets = 1usize << power;
    let cells = rows as usize * buckets;
    if cells > MAX_CELLS {
        return Err(WireError::BadHeader { rows, power });
    }
    let cfg = StormConfig { rows: rows as usize, power: power as u32, saturating: true };

    let (epoch, flags, payload) = if version == VERSION_DENSE {
        (0u64, FLAG_DENSE, &body[HEADER..])
    } else {
        if body.len() < HEADER_V2 {
            return Err(WireError::Truncated(bytes.len()));
        }
        let epoch = u64::from_le_bytes(body[HEADER..HEADER + 8].try_into().unwrap());
        (epoch, body[HEADER + 8], &body[HEADER_V2..])
    };

    let counts = match flags {
        FLAG_DENSE => {
            if payload.len() != cells * 4 {
                return Err(WireError::Truncated(bytes.len()));
            }
            let mut counts = vec![0u32; cells];
            for (i, cell) in counts.iter_mut().enumerate() {
                *cell = u32::from_le_bytes(payload[i * 4..i * 4 + 4].try_into().unwrap());
            }
            counts
        }
        FLAG_SPARSE => {
            let mut pos = 0usize;
            let ncells = get_varint(payload, &mut pos)?;
            if ncells as usize > cells {
                return Err(WireError::BadPayload("sparse cell count exceeds grid"));
            }
            let mut counts = vec![0u32; cells];
            let mut idx: u64 = 0;
            for i in 0..ncells {
                let gap = get_varint(payload, &mut pos)?;
                if i > 0 && gap == 0 {
                    return Err(WireError::BadPayload("non-increasing sparse index"));
                }
                idx = idx
                    .checked_add(gap)
                    .ok_or(WireError::BadPayload("sparse index overflow"))?;
                if idx >= cells as u64 {
                    return Err(WireError::BadPayload("sparse index out of range"));
                }
                let cnt = get_varint(payload, &mut pos)?;
                if cnt == 0 || cnt > u32::MAX as u64 {
                    return Err(WireError::BadPayload("sparse count out of range"));
                }
                counts[idx as usize] = cnt as u32;
            }
            if pos != payload.len() {
                return Err(WireError::BadPayload("trailing bytes after sparse cells"));
            }
            counts
        }
        _ => return Err(WireError::BadPayload("unknown payload flags")),
    };

    Ok(SketchDelta {
        epoch,
        cfg,
        dim: dim as usize,
        seed,
        count,
        counts,
    })
}

/// Decode a wire buffer back into a full sketch (rebuilding the hash
/// family from the embedded seed). Accepts v1 and v2 frames.
pub fn decode(bytes: &[u8]) -> Result<StormSketch, WireError> {
    let delta = decode_delta(bytes)?;
    Ok(StormSketch::from_delta(&delta))
}

/// Dense (v1) wire size in bytes for a given configuration — the
/// network-cost ceiling a sparse v2 delta is measured against.
pub fn wire_bytes(cfg: &StormConfig) -> usize {
    HEADER + cfg.rows * cfg.buckets() * 4 + 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::Sketch;
    use crate::testing::gen_ball_point;
    use crate::util::rng::Xoshiro256;

    fn sample_sketch() -> StormSketch {
        let cfg = StormConfig { rows: 20, power: 4, saturating: true };
        let mut sk = StormSketch::new(cfg, 5, 77);
        let mut rng = Xoshiro256::new(3);
        for _ in 0..120 {
            let z = gen_ball_point(&mut rng, 5, 0.9);
            sk.insert(&z);
        }
        sk
    }

    fn sparse_delta() -> SketchDelta {
        // 3 inserts into a 20 x 16 grid touch <= 120 of 320 cells.
        let cfg = StormConfig { rows: 20, power: 4, saturating: true };
        let mut sk = StormSketch::new(cfg, 5, 77);
        let mut rng = Xoshiro256::new(9);
        let snap = sk.snapshot();
        for _ in 0..3 {
            let z = gen_ball_point(&mut rng, 5, 0.9);
            sk.insert(&z);
        }
        sk.delta_since(&snap, 7)
    }

    /// Recompute the trailing CRC after a deliberate mutation, so the
    /// checksum is NOT what trips the decoder.
    fn refix_crc(bytes: &mut [u8]) {
        let n = bytes.len();
        let crc = fnv1a(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let sk = sample_sketch();
        let bytes = encode(&sk);
        assert_eq!(bytes.len(), wire_bytes(&sk.config()));
        let back = decode(&bytes).unwrap();
        assert_eq!(back.grid().data(), sk.grid().data());
        assert_eq!(back.count(), sk.count());
        assert_eq!(back.seed(), sk.seed());
        assert_eq!(back.dim(), sk.dim());
        // Estimates identical (same family regenerated from seed).
        let mut rng = Xoshiro256::new(4);
        let q = gen_ball_point(&mut rng, 5, 0.8);
        assert_eq!(back.estimate_risk(&q), sk.estimate_risk(&q));
    }

    #[test]
    fn decoded_sketch_can_merge_with_source() {
        let mut a = sample_sketch();
        let b = decode(&encode(&a)).unwrap();
        let count_before = a.count();
        a.merge_from(&b);
        assert_eq!(a.count(), count_before * 2);
    }

    #[test]
    fn delta_roundtrip_sparse() {
        let delta = sparse_delta();
        assert!(delta.populated_fraction() <= 0.5);
        let bytes = encode_delta(&delta);
        assert_eq!(u16::from_le_bytes(bytes[4..6].try_into().unwrap()), 2);
        assert_eq!(bytes[HEADER + 8], FLAG_SPARSE);
        let back = decode_delta(&bytes).unwrap();
        assert_eq!(back, delta);
    }

    #[test]
    fn delta_roundtrip_dense_fallback() {
        // Saturate the grid: a tiny 1 x 2^1 sketch where every cell is hit.
        let cfg = StormConfig { rows: 2, power: 1, saturating: true };
        let mut sk = StormSketch::new(cfg, 3, 5);
        let snap = sk.snapshot();
        let mut rng = Xoshiro256::new(11);
        for _ in 0..40 {
            sk.insert(&gen_ball_point(&mut rng, 3, 0.9));
        }
        let delta = sk.delta_since(&snap, 3);
        assert!(delta.populated_fraction() > 0.5, "fraction {}", delta.populated_fraction());
        let bytes = encode_delta(&delta);
        assert_eq!(bytes[HEADER + 8], FLAG_DENSE);
        let back = decode_delta(&bytes).unwrap();
        assert_eq!(back, delta);
    }

    #[test]
    fn sparse_delta_beats_dense_v1_bytes() {
        // Acceptance: a sparse round must cost strictly fewer wire bytes
        // than a dense v1 encode of the full sketch.
        let delta = sparse_delta();
        let sparse_bytes = encode_delta(&delta).len();
        assert!(
            sparse_bytes < wire_bytes(&delta.cfg),
            "sparse {} >= dense {}",
            sparse_bytes,
            wire_bytes(&delta.cfg)
        );
    }

    #[test]
    fn v1_frames_decode_as_epoch_zero_deltas() {
        let sk = sample_sketch();
        let delta = decode_delta(&encode(&sk)).unwrap();
        assert_eq!(delta.epoch, 0);
        assert_eq!(delta.count, sk.count());
        assert_eq!(delta.counts.as_slice(), sk.grid().data());
        assert_eq!(delta.seed, sk.seed());
    }

    #[test]
    fn v2_frames_decode_as_full_sketches() {
        let delta = sparse_delta();
        let sk = decode(&encode_delta(&delta)).unwrap();
        assert_eq!(sk.grid().data(), delta.counts.as_slice());
        assert_eq!(sk.count(), delta.count);
        assert_eq!(sk.seed(), delta.seed);
    }

    #[test]
    fn corruption_detected() {
        for bytes in [encode(&sample_sketch()), encode_delta(&sparse_delta())] {
            let mut bytes = bytes;
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0xFF;
            assert!(matches!(decode_delta(&bytes), Err(WireError::BadChecksum { .. })));
        }
    }

    #[test]
    fn truncation_detected() {
        for bytes in [encode(&sample_sketch()), encode_delta(&sparse_delta())] {
            assert!(matches!(decode(&bytes[..10]), Err(WireError::Truncated(_))));
            // Cut counters but keep a valid-length tail: checksum fires first.
            let cut = &bytes[..bytes.len() - 8];
            assert!(decode(cut).is_err());
        }
    }

    #[test]
    fn bad_magic_detected() {
        let mut bytes = encode(&sample_sketch());
        bytes[0] = 0;
        // Fix checksum so the magic check is what fires.
        refix_crc(&mut bytes);
        assert!(matches!(decode(&bytes), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn bad_version_detected() {
        let mut bytes = encode(&sample_sketch());
        bytes[4] = 3;
        refix_crc(&mut bytes);
        assert!(matches!(decode(&bytes), Err(WireError::BadVersion(3))));
    }

    #[test]
    fn bad_flags_detected() {
        let mut bytes = encode_delta(&sparse_delta());
        bytes[HEADER + 8] = 7;
        refix_crc(&mut bytes);
        assert!(matches!(decode_delta(&bytes), Err(WireError::BadPayload(_))));
    }

    #[test]
    fn header_mutations_detected_with_valid_crc() {
        // Structural header lies (rows = 0, power = 0, power > 24) must be
        // caught by validation even when the checksum is recomputed.
        let base = encode_delta(&sparse_delta());
        for (off, val) in [(8usize, 0u8), (6, 0), (6, 30)] {
            let mut bytes = base.clone();
            match off {
                8 => bytes[8..12].copy_from_slice(&0u32.to_le_bytes()),
                _ => {
                    bytes[6] = val;
                    bytes[7] = 0;
                }
            }
            refix_crc(&mut bytes);
            assert!(
                matches!(decode_delta(&bytes), Err(WireError::BadHeader { .. })),
                "off={off} val={val}"
            );
        }
    }

    #[test]
    fn sparse_payload_lies_detected_with_valid_crc() {
        let delta = sparse_delta();
        let bytes = encode_delta(&delta);
        let payload_at = HEADER_V2;

        // ncells exceeding the grid.
        let mut b = bytes.clone();
        // Overwrite the ncells varint region with a huge 3-byte varint is
        // tricky in place; instead craft a fresh frame with a lying count.
        b.truncate(payload_at);
        put_varint(&mut b, (delta.counts.len() + 1) as u64);
        b.extend_from_slice(&[0u8; 4]); // room for crc
        refix_crc(&mut b);
        assert!(matches!(decode_delta(&b), Err(WireError::BadPayload(_))));

        // Zero-gap (non-increasing index) on the second cell.
        let mut b = bytes.clone();
        b.truncate(payload_at);
        put_varint(&mut b, 2);
        put_varint(&mut b, 1); // first index = 1
        put_varint(&mut b, 5); // count
        put_varint(&mut b, 0); // zero gap -> same index again
        put_varint(&mut b, 5);
        b.extend_from_slice(&[0u8; 4]);
        refix_crc(&mut b);
        assert!(matches!(decode_delta(&b), Err(WireError::BadPayload(_))));

        // Index past the end of the grid.
        let mut b = bytes.clone();
        b.truncate(payload_at);
        put_varint(&mut b, 1);
        put_varint(&mut b, delta.counts.len() as u64); // == cells -> out of range
        put_varint(&mut b, 5);
        b.extend_from_slice(&[0u8; 4]);
        refix_crc(&mut b);
        assert!(matches!(decode_delta(&b), Err(WireError::BadPayload(_))));

        // Zero count.
        let mut b = bytes.clone();
        b.truncate(payload_at);
        put_varint(&mut b, 1);
        put_varint(&mut b, 0);
        put_varint(&mut b, 0);
        b.extend_from_slice(&[0u8; 4]);
        refix_crc(&mut b);
        assert!(matches!(decode_delta(&b), Err(WireError::BadPayload(_))));

        // Trailing garbage after the declared cells.
        let mut b = bytes.clone();
        let n = b.len();
        b.insert(n - 4, 0x00);
        refix_crc(&mut b);
        assert!(matches!(decode_delta(&b), Err(WireError::BadPayload(_))));
    }

    // ---- Golden byte fixtures -------------------------------------
    //
    // Checked-in encodings of hand-constructed frames for every wire
    // layout: v1 dense full-sketch, v2 sparse delta, v2 dense-fallback
    // delta. Any silent format drift — field order, width, varint
    // scheme, flag values, checksum — fails these tests; bump the wire
    // VERSION and add new fixtures instead of editing these.

    const GOLDEN_V2_SPARSE_HEX: &str = "524f545302000200020000000300000088776655443322110500000000000000070000000000000001030103020104023fbdf029";
    const GOLDEN_V2_DENSE_HEX: &str = "524f545302000200020000000200000001020304050607080b0000000000000009000000000000000001000000020000000300000004000000050000000600000000000000070000008f89afde";
    const GOLDEN_V1_DENSE_HEX: &str = "524f5453010002000200000003000000887766554433221105000000000000000000000003000000000000000100000000000000000000000000000002000000b0a904dd";

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    fn unhex(s: &str) -> Vec<u8> {
        (0..s.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
            .collect()
    }

    /// 2 x 4 grid, 3 of 8 cells populated (37.5% -> sparse encoding).
    fn golden_sparse_delta() -> SketchDelta {
        SketchDelta {
            epoch: 7,
            cfg: StormConfig { rows: 2, power: 2, saturating: true },
            dim: 3,
            seed: 0x1122_3344_5566_7788,
            count: 5,
            counts: vec![0, 3, 0, 1, 0, 0, 0, 2],
        }
    }

    /// 2 x 4 grid, 7 of 8 cells populated (87.5% -> dense fallback).
    fn golden_dense_delta() -> SketchDelta {
        SketchDelta {
            epoch: 9,
            cfg: StormConfig { rows: 2, power: 2, saturating: true },
            dim: 2,
            seed: 0x0807_0605_0403_0201,
            count: 11,
            counts: vec![1, 2, 3, 4, 5, 6, 0, 7],
        }
    }

    #[test]
    fn golden_v2_sparse_bytes_are_stable() {
        let delta = golden_sparse_delta();
        assert!(delta.populated_fraction() <= 0.5, "fixture must take the sparse path");
        assert_eq!(
            hex(&encode_delta(&delta)),
            GOLDEN_V2_SPARSE_HEX,
            "v2 sparse wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_V2_SPARSE_HEX)).unwrap(), delta);
    }

    #[test]
    fn golden_v2_dense_bytes_are_stable() {
        let delta = golden_dense_delta();
        assert!(delta.populated_fraction() > 0.5, "fixture must take the dense fallback");
        assert_eq!(
            hex(&encode_delta(&delta)),
            GOLDEN_V2_DENSE_HEX,
            "v2 dense-fallback wire encoding drifted — bump the wire version instead"
        );
        assert_eq!(decode_delta(&unhex(GOLDEN_V2_DENSE_HEX)).unwrap(), delta);
    }

    #[test]
    fn golden_v1_bytes_are_stable() {
        let sk = StormSketch::from_delta(&golden_sparse_delta());
        assert_eq!(
            hex(&encode(&sk)),
            GOLDEN_V1_DENSE_HEX,
            "v1 wire encoding drifted — bump the wire version instead"
        );
        // The v1 fixture still decodes on both entry points.
        let back = decode(&unhex(GOLDEN_V1_DENSE_HEX)).unwrap();
        assert_eq!(back.grid().data(), sk.grid().data());
        assert_eq!(back.count(), 5);
        let as_delta = decode_delta(&unhex(GOLDEN_V1_DENSE_HEX)).unwrap();
        assert_eq!(as_delta.epoch, 0, "v1 reads as an epoch-0 dense delta");
        assert_eq!(as_delta.counts, golden_sparse_delta().counts);
    }

    #[test]
    fn varint_roundtrip_and_overflow() {
        let mut buf = Vec::new();
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            buf.clear();
            put_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
        // 11-byte varint: more than 64 bits -> error, not wraparound.
        let over = [0x80u8; 10];
        let mut pos = 0;
        assert!(get_varint(&over, &mut pos).is_err());
    }
}
